"""Serving layer: Eudoxia bridge (requests -> pipelines -> policy pick)
and the continuous batcher end to end on a tiny model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models import lm
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.bridge import (
    ServeRequest,
    evaluate_policies,
    pick_policy,
    requests_to_pipelines,
)


def _trace(n=10, seed=0):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(
            arrival_s=float(i * 0.15),
            prompt_tokens=int(rng.integers(32, 256)),
            new_tokens=32,
            interactive=bool(i % 2),
        )
        for i in range(n)
    ]


class TestBridge:
    def test_requests_become_two_op_pipelines(self):
        cfg = get_arch("gemma3_12b").model
        pipes = requests_to_pipelines(_trace(4), cfg)
        assert len(pipes) == 4
        for p in pipes:
            assert p.num_ops == 2
            prefill, decode = p.ops
            assert prefill.alpha == 1.0   # compute-bound
            assert decode.alpha == 0.0    # bandwidth-bound
            assert prefill.level == 0 and decode.level == 1
            assert p.ops[0].ram_gb > 0

    def test_evaluate_policies_and_pick(self):
        cfg = get_arch("gemma3_12b").model
        res = evaluate_policies(_trace(16), cfg, duration_s=20.0)
        assert set(res) == {"naive", "priority", "priority_pool"}
        for s in res.values():
            assert s["submitted"] == 16
        best = pick_policy(res)
        assert best in res
        # priority-aware policies must not lose to naive on interactive
        # latency (that is their whole purpose)
        def ilat(name):
            v = res[name]["per_priority"]["interactive"]["mean_latency_s"]
            return float("inf") if v != v else v

        assert min(ilat("priority"), ilat("priority_pool")) <= ilat("naive") + 1e-6


class TestContinuousBatcher:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_arch("rwkv6_7b").smoke
        params, _ = lm.lm_init(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def test_serves_all_requests(self, setup):
        cfg, params = setup
        b = ContinuousBatcher(cfg, params, slots=2, max_len=48)
        rng = np.random.default_rng(0)
        for i in range(5):
            b.submit(Request(rid=i,
                             tokens=rng.integers(2, cfg.vocab, 8).astype(np.int32),
                             max_new=6, interactive=bool(i % 2)))
        done = b.run_to_completion()
        assert len(done) == 5
        for r in done:
            assert len(r.out) >= 6

    def test_matches_unbatched_decode(self, setup):
        """Greedy output through the batcher == standalone prefill+decode."""
        cfg, params = setup
        rng = np.random.default_rng(1)
        toks = rng.integers(2, cfg.vocab, 8).astype(np.int32)

        b = ContinuousBatcher(cfg, params, slots=1, max_len=48)
        b.submit(Request(rid=0, tokens=toks, max_new=5))
        done = b.run_to_completion()
        got = done[0].out[:5]

        # reference: direct greedy decode
        logits, caches = lm.lm_prefill(
            cfg, params, {"tokens": jnp.asarray(toks)[None]}, max_len=48
        )
        ref = [int(jnp.argmax(logits[0]))]
        pos = len(toks)
        while len(ref) < 5:
            logits, caches = lm.lm_decode_step(
                cfg, params, caches, jnp.asarray([ref[-1]], jnp.int32), pos
            )
            pos += 1
            ref.append(int(jnp.argmax(logits[0])))
        assert got == ref
