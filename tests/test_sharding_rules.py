"""Property tests for the divisibility-aware sharding rules."""
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    DEFAULT_ACT_RULES,
    DEFAULT_PARAM_RULES,
    ShardingRules,
    spec_for,
)


class FakeMesh:
    """Duck-typed mesh: only axis_names + shape are consulted."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = shape


MESH_SINGLE = FakeMesh({"data": 16, "model": 16})
MESH_MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _flat_axes(spec):
    out = []
    for e in spec:
        if e is None:
            continue
        if isinstance(e, tuple):
            out.extend(e)
        else:
            out.append(e)
    return out


class TestSpecFor:
    def test_batch_takes_pod_and_data(self):
        spec = spec_for((256, 4096), "batch seq", MESH_MULTI, DEFAULT_ACT_RULES)
        assert spec == P(("pod", "data"))

    def test_batch_one_replicates(self):
        spec = spec_for((1, 4096), "batch seq", MESH_MULTI, DEFAULT_ACT_RULES)
        assert spec == P()

    def test_mqa_kv_head_replicates(self):
        spec = spec_for(
            (6144, 1, 128), "embed kv_heads head_dim", MESH_SINGLE,
            DEFAULT_PARAM_RULES,
        )
        assert spec == P("data")  # kv=1 can't shard 16 ways

    def test_gqa_kv_heads_shard_when_divisible(self):
        spec = spec_for(
            (5376, 16, 128), "embed kv_heads head_dim", MESH_SINGLE,
            DEFAULT_PARAM_RULES,
        )
        assert spec == P("data", "model")

    def test_expert_weights(self):
        spec = spec_for(
            (128, 7168, 4864), "expert embed_moe ff", MESH_SINGLE,
            DEFAULT_PARAM_RULES,
        )
        # expert takes model; ff can't reuse it; embed_moe FSDPs on data
        assert spec == P("model", "data")

    def test_axes_mismatch_is_replicated(self):
        assert spec_for((4, 4, 4), "embed ff", MESH_SINGLE,
                        DEFAULT_PARAM_RULES) == P()

    @settings(max_examples=60, deadline=None)
    @given(
        dims=st.lists(
            st.sampled_from([1, 2, 7, 16, 56, 64, 128, 131, 4096, 262144]),
            min_size=1, max_size=4,
        ),
        names=st.lists(
            st.sampled_from(
                ["batch", "seq", "embed", "heads", "kv_heads", "ff",
                 "expert", "vocab", "head_dim"]
            ),
            min_size=1, max_size=4,
        ),
        multi=st.booleans(),
        act=st.booleans(),
    )
    def test_invariants(self, dims, names, multi, act):
        n = min(len(dims), len(names))
        dims, names = dims[:n], names[:n]
        mesh = MESH_MULTI if multi else MESH_SINGLE
        rules = DEFAULT_ACT_RULES if act else DEFAULT_PARAM_RULES
        spec = spec_for(tuple(dims), " ".join(names), mesh, rules)
        flat = _flat_axes(spec)
        # 1. no mesh axis used twice
        assert len(flat) == len(set(flat))
        # 2. every sharded dim is divisible by its mesh-axis product
        for dim, entry in zip(dims, list(spec) + [None] * n):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            assert dim % prod == 0 and dim >= prod
        # 3. spec length never exceeds rank
        assert len(spec) <= n

    def test_override_mechanism(self):
        rules = ShardingRules().override(param={"head_dim": ("model",),
                                                "heads": ()})
        spec = spec_for(
            (5120, 40, 128), "embed heads head_dim", MESH_SINGLE, rules.param
        )
        assert spec == P("data", None, "model")
