"""Behavioural tests for the Eudoxia core (paper §3.2, §4.1.2 semantics)."""
import numpy as np
import pytest

from repro.core import (
    Operator,
    Pipeline,
    PipeStatus,
    Priority,
    SimParams,
    TICKS_PER_SECOND,
    container_schedule,
    generate_workload,
    run,
    workload_from_pipelines,
)
from repro.core.engine_python import container_schedule_py


def P(**kw) -> SimParams:
    base = dict(
        duration=0.5,
        waiting_ticks_mean=2000,
        op_base_seconds_mean=0.01,
        op_ram_gb_mean=1.0,
        max_pipelines=64,
        engine="event",
    )
    base.update(kw)
    return SimParams(**base)


# ---------------------------------------------------------------------------
# Container runtime model
# ---------------------------------------------------------------------------
class TestContainerSchedule:
    def _wl(self, ops, params=None):
        params = params or P()
        pipe = Pipeline(pid=0, priority=Priority.BATCH, arrival_tick=0, ops=ops)
        return workload_from_pipelines([pipe], params), pipe

    def test_single_op_io_bound_ignores_cpus(self):
        ops = [Operator(ram_gb=1.0, base_ticks=1000, alpha=0.0, level=0)]
        wl, pipe = self._wl(ops)
        for cpus in [1.0, 4.0, 16.0]:
            dur, oom = container_schedule(wl, 0, cpus, 8.0)
            assert int(dur) == 1000
            assert int(oom) == np.int32(2**31 - 1)

    def test_linear_scaling(self):
        ops = [Operator(ram_gb=1.0, base_ticks=1000, alpha=1.0, level=0)]
        wl, _ = self._wl(ops)
        dur4, _ = container_schedule(wl, 0, 4.0, 8.0)
        assert int(dur4) == 250
        dur8, _ = container_schedule(wl, 0, 8.0, 8.0)
        assert int(dur8) == 125

    def test_levels_share_cpus_and_sum(self):
        # level 0: two parallel ops (share CPUs), level 1: one op
        ops = [
            Operator(ram_gb=1.0, base_ticks=800, alpha=1.0, level=0),
            Operator(ram_gb=1.0, base_ticks=400, alpha=1.0, level=0),
            Operator(ram_gb=1.0, base_ticks=600, alpha=1.0, level=1),
        ]
        wl, _ = self._wl(ops)
        dur, _ = container_schedule(wl, 0, 4.0, 8.0)
        # level0: c_eff=2 -> max(800/2, 400/2)=400; level1: 600/4=150
        assert int(dur) == 550

    def test_oom_at_level_start(self):
        ops = [
            Operator(ram_gb=1.0, base_ticks=500, alpha=0.0, level=0),
            Operator(ram_gb=9.0, base_ticks=500, alpha=0.0, level=1),
        ]
        wl, _ = self._wl(ops)
        dur, oom = container_schedule(wl, 0, 4.0, 4.0)
        assert int(dur) == 1000
        assert int(oom) == 500  # second level starts after 500 ticks
        # enough RAM -> no OOM
        _, oom2 = container_schedule(wl, 0, 4.0, 10.5)
        assert int(oom2) == np.int32(2**31 - 1)

    def test_python_mirror_matches_jax(self):
        rng = np.random.default_rng(0)
        params = P()
        for _ in range(25):
            n = int(rng.integers(1, 6))
            lv = 0
            ops = []
            for j in range(n):
                if j and rng.random() < 0.5:
                    lv += 1
                ops.append(
                    Operator(
                        ram_gb=float(rng.uniform(0.1, 6.0)),
                        base_ticks=int(rng.integers(1, 20000)),
                        alpha=float(rng.choice([0.0, 0.5, 1.0])),
                        level=lv,
                    )
                )
            pipe = Pipeline(0, Priority.BATCH, 0, ops)
            wl = workload_from_pipelines([pipe], params)
            cpus = float(rng.uniform(1, 16))
            ram = float(rng.uniform(0.5, 20))
            dur_j, oom_j = container_schedule(wl, 0, cpus, ram)
            dur_p, oom_p = container_schedule_py(pipe, cpus, ram)
            assert int(dur_j) == dur_p
            oom_j = int(oom_j)
            if oom_p is None:
                assert oom_j == np.int32(2**31 - 1)
            else:
                assert oom_j == oom_p


# ---------------------------------------------------------------------------
# Scheduler semantics (paper §4.1.2)
# ---------------------------------------------------------------------------
def trace_pipe(pid, prio, arrive_s, ram, secs, alpha=0.0):
    return Pipeline(
        pid=pid,
        priority=prio,
        arrival_tick=int(arrive_s * TICKS_PER_SECOND),
        ops=[
            Operator(
                ram_gb=ram,
                base_ticks=int(secs * TICKS_PER_SECOND),
                alpha=alpha,
                level=0,
            )
        ],
    )


class TestNaive:
    def test_serializes_and_uses_all_resources(self):
        params = P(scheduling_algo="naive", max_pipelines=8)
        pipes = [
            trace_pipe(0, Priority.BATCH, 0.0, 1.0, 0.05),
            trace_pipe(1, Priority.BATCH, 0.001, 1.0, 0.05),
        ]
        wl = workload_from_pipelines(pipes, params)
        res = run(params, workload=wl)
        comp = np.asarray(res.state.pipe_completion)
        # second pipeline only starts after the first completes
        assert comp[1] >= comp[0] + int(0.05 * TICKS_PER_SECOND)
        assert res.summary()["done"] == 2

    def test_higher_priority_jumps_queue(self):
        params = P(scheduling_algo="naive", max_pipelines=8)
        pipes = [
            trace_pipe(0, Priority.BATCH, 0.0, 1.0, 0.05),
            trace_pipe(1, Priority.BATCH, 0.001, 1.0, 0.05),
            trace_pipe(2, Priority.INTERACTIVE, 0.002, 1.0, 0.01),
        ]
        wl = workload_from_pipelines(pipes, params)
        res = run(params, workload=wl)
        comp = np.asarray(res.state.pipe_completion)
        assert comp[2] < comp[1]  # interactive scheduled before 2nd batch

    def test_oom_with_everything_is_permanent_failure(self):
        params = P(scheduling_algo="naive", total_ram_gb=4.0, max_pipelines=4)
        pipes = [trace_pipe(0, Priority.BATCH, 0.0, 16.0, 0.05)]
        wl = workload_from_pipelines(pipes, params)
        res = run(params, workload=wl)
        s = res.summary()
        assert s["failed"] == 1 and s["oom_events"] == 1


class TestPriority:
    def test_chunk_is_ten_percent(self):
        params = P(scheduling_algo="priority", total_cpus=16.0, total_ram_gb=32.0)
        pipes = [trace_pipe(0, Priority.BATCH, 0.0, 1.0, 0.02, alpha=1.0)]
        wl = workload_from_pipelines(pipes, params)
        res = run(params, workload=wl)
        # 10% of 16 CPUs = 1.6 CPUs -> 0.02s base at alpha=1 -> 0.02/1.6
        expect = int(np.ceil(np.float32(0.02 * TICKS_PER_SECOND) / np.float32(1.6)))
        comp = np.asarray(res.state.pipe_completion)
        assert comp[0] == expect

    def test_oom_doubling_then_success(self):
        # needs 7GB; chunk = 3.2GB -> OOM -> 6.4 -> OOM -> 12.8 ok
        params = P(scheduling_algo="priority", total_ram_gb=32.0)
        pipes = [trace_pipe(0, Priority.BATCH, 0.0, 7.0, 0.01)]
        wl = workload_from_pipelines(pipes, params)
        res = run(params, workload=wl)
        s = res.summary()
        assert s["oom_events"] == 2
        assert s["done"] == 1
        last_ram = float(res.state.pipe_last_ram[0])
        assert last_ram == pytest.approx(12.8, rel=1e-5)

    def test_oom_beyond_cap_fails_to_user(self):
        # needs 20GB > 50% cap (16GB) -> 3.2 OOM, 6.4 OOM, 12.8 OOM,
        # 16 (cap) OOM -> permanent failure
        params = P(scheduling_algo="priority", total_ram_gb=32.0)
        pipes = [trace_pipe(0, Priority.BATCH, 0.0, 20.0, 0.01)]
        wl = workload_from_pipelines(pipes, params)
        res = run(params, workload=wl)
        s = res.summary()
        assert s["failed"] == 1
        assert s["oom_events"] == 4

    def test_preemption_of_batch_by_interactive(self):
        # Ten batch pipelines saturate the pool (10 x 10% chunks); an
        # interactive query arrives and must preempt exactly one of them.
        params = P(scheduling_algo="priority", waiting_ticks_mean=100)
        pipes = [
            trace_pipe(i, Priority.BATCH, 0.0, 1.0, 0.2) for i in range(10)
        ] + [trace_pipe(10, Priority.INTERACTIVE, 0.01, 1.0, 0.01)]
        wl = workload_from_pipelines(pipes, params)
        res = run(params, workload=wl)
        s = res.summary()
        assert s["preempt_events"] >= 1
        comp = np.asarray(res.state.pipe_completion)
        assert comp[10] < np.max(comp[:10])  # query beat the batch jobs
        assert s["done"] == 11  # preempted batch still finishes

    def test_preempted_pipeline_resumes_with_same_alloc(self):
        params = P(scheduling_algo="priority", waiting_ticks_mean=100)
        pipes = [
            trace_pipe(i, Priority.BATCH, 0.0, 1.0, 0.05) for i in range(10)
        ] + [trace_pipe(10, Priority.INTERACTIVE, 0.01, 1.0, 0.01)]
        wl = workload_from_pipelines(pipes, params)
        res = run(params, workload=wl)
        preempted = np.asarray(res.state.pipe_preempts)[:10]
        assert preempted.sum() >= 1
        victim = int(np.argmax(preempted))
        # resumed with the remembered 10% chunk
        assert float(res.state.pipe_last_cpus[victim]) == pytest.approx(1.6, rel=1e-5)
        assert int(res.state.pipe_status[victim]) == int(PipeStatus.DONE)


class TestPriorityPool:
    def test_spreads_across_pools(self):
        params = P(
            scheduling_algo="priority_pool",
            num_pools=2,
            total_cpus=16.0,
            total_ram_gb=32.0,
        )
        pipes = [trace_pipe(i, Priority.BATCH, 0.0, 1.0, 0.05) for i in range(4)]
        wl = workload_from_pipelines(pipes, params)
        res = run(params, workload=wl)
        # both pools saw some usage
        util = np.asarray(res.state.util_cpu_s)
        assert (util > 0).all()
        assert res.summary()["done"] == 4


# ---------------------------------------------------------------------------
# Generator + determinism
# ---------------------------------------------------------------------------
class TestWorkloadGenerator:
    def test_deterministic_same_seed(self):
        params = P(seed=7)
        a = generate_workload(params)
        b = generate_workload(params)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_different_seed_differs(self):
        a = generate_workload(P(seed=1))
        b = generate_workload(P(seed=2))
        assert not np.array_equal(np.asarray(a.arrival), np.asarray(b.arrival))

    def test_priorities_scale_sizes(self):
        params = P(seed=3, max_pipelines=512, interactive_scale=0.1)
        wl = generate_workload(params)
        prio = np.asarray(wl.prio)
        ram = np.asarray(wl.op_ram)
        valid = np.asarray(wl.op_valid)
        mean_batch = ram[(prio == 0)][valid[prio == 0]].mean()
        mean_inter = ram[(prio == 2)][valid[prio == 2]].mean()
        assert mean_inter < mean_batch

    def test_full_run_deterministic(self):
        params = P(seed=11)
        r1 = run(params)
        r2 = run(params)
        np.testing.assert_array_equal(
            np.asarray(r1.state.pipe_completion),
            np.asarray(r2.state.pipe_completion),
        )


# ---------------------------------------------------------------------------
# Resource accounting invariants
# ---------------------------------------------------------------------------
class TestInvariants:
    @pytest.mark.parametrize("algo", ["naive", "priority", "priority_pool"])
    def test_final_resources_balance(self, algo):
        params = P(
            scheduling_algo=algo,
            num_pools=2 if algo == "priority_pool" else 1,
            duration=1.0,
        )
        res = run(params)
        free = np.asarray(res.state.pool_cpu_free)
        cap = np.asarray(res.state.pool_cpu_cap)
        assert (free >= -1e-4).all()
        assert (free <= cap + 1e-4).all()
        # every RUNNING container's pipe is RUNNING and vice versa
        st = res.state
        running_pipes = np.asarray(st.ctr_pipe)[np.asarray(st.ctr_status) == 1]
        for pid in running_pipes:
            assert int(st.pipe_status[pid]) == int(PipeStatus.RUNNING)

    def test_latency_nonnegative_and_bookkeeping(self):
        params = P(duration=1.5)
        res = run(params)
        s = res.summary()
        assert s["done"] + s["failed"] + s["in_flight"] == s["submitted"]
        comp = np.asarray(res.state.pipe_completion)
        arr = np.asarray(res.workload.arrival)
        done = np.asarray(res.state.pipe_status) == int(PipeStatus.DONE)
        assert (comp[done] >= arr[done]).all()


class TestSJF:
    """Beyond-paper scheduler registered in both engine worlds."""

    def test_vector_equals_python(self):
        for seed in (0, 3, 9):
            params = P(
                scheduling_algo="sjf", seed=seed, waiting_ticks_mean=800,
            )
            from repro.core import generate_workload

            wl = generate_workload(params)
            rv = run(params, workload=wl, engine="event")
            rp = run(params, workload=wl, engine="python")
            np.testing.assert_array_equal(
                np.asarray(rv.state.pipe_completion),
                np.asarray(rp.state.pipe_completion),
            )

    def test_prefers_small_jobs(self):
        # one 8-op pipeline then four 1-op pipelines: SJF finishes the
        # singletons first even though the big job arrived earlier
        params = P(scheduling_algo="sjf", max_pipelines=8, total_ram_gb=64.0)
        big = Pipeline(
            pid=0, priority=Priority.BATCH, arrival_tick=0,
            ops=[Operator(1.0, 3000, 0.0, lv) for lv in range(8)],
        )
        smalls = [
            trace_pipe(i, Priority.BATCH, 0.001, 1.0, 0.01)
            for i in range(1, 5)
        ]
        wl = workload_from_pipelines([big] + smalls, params)
        res = run(params, workload=wl)
        comp = np.asarray(res.state.pipe_completion)
        assert (comp[1:5] < comp[0]).all()
        assert res.summary()["done"] == 5


class TestViz:
    def test_viz_renders(self):
        from repro.core.viz import (
            latency_histogram,
            per_priority_table,
            timeline_csv,
            utilization_timeline,
        )

        res = run(P(duration=0.5, op_base_seconds_mean=0.01))
        tl = utilization_timeline(res)
        assert "pool0 cpu" in tl and "mean" in tl
        assert "BATCH" in per_priority_table(res)
        csv = timeline_csv(res)
        assert csv.startswith("t_s,pool,cpu_util,ram_util")
        assert len(csv.splitlines()) > 10
        assert "s |" in latency_histogram(res)
