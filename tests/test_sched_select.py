"""Fused masked-selection kernels (Pallas phase 2) and lane binning.

The schedulers' hot path runs ``kernels.sched_select.masked_lex_argmin``
— one narrowing sweep — where the seed ran three-pass max/argmax
helpers. The helpers stay exported as the *oracles*; everything here
pins the fused path to them bitwise on the engine's domain (priorities
small and non-negative, entry/start ticks real, i.e. < INF_TICK), with
the all-masked / single-candidate / tie-heavy corners called out by the
issue exercised explicitly and by property sweep.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.extra_schedulers import _select_sjf
from repro.core.scheduler import select_next_pipe, select_victim
from repro.core.state import INF_TICK
from repro.kernels.sched_select import (
    masked_lex_argmin,
    masked_lex_argmin_ref,
)
from repro.kernels.sched_select import (
    select_next_pipe as fused_next_pipe,
)
from repro.kernels.sched_select import (
    select_sjf as fused_sjf,
)
from repro.kernels.sched_select import (
    select_victim as fused_victim,
)
from repro.kernels.sched_select.kernel import masked_lex_argmin_kernel


def _rng(seed):
    return np.random.default_rng(seed)


def _draw_tables(rng, n, tick_hi):
    """A random slice of the engine domain; small ``tick_hi`` makes the
    draw tie-heavy (many equal priorities/ticks -> the index tie-break
    carries the selection)."""
    mask = rng.random(n) < rng.random()
    prio = jnp.asarray(rng.integers(0, 3, n), jnp.int32)
    entered = jnp.asarray(rng.integers(0, tick_hi, n), jnp.int32)
    return jnp.asarray(mask), prio, entered


# ---------------------------------------------------------------------------
# Property sweeps: fused == three-pass oracle, bitwise.
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.sampled_from([1, 2, 7, 32, 128]),
    # 3 -> tie-heavy, INF_TICK - 1 -> full tick range
    tick_hi=st.sampled_from([3, 1000, int(INF_TICK) - 1]),
)
def test_fused_next_pipe_matches_oracle(seed, n, tick_hi):
    mask, prio, entered = _draw_tables(_rng(seed), n, tick_hi)
    a = select_next_pipe(mask, prio, entered)
    b = fused_next_pipe(mask, prio, entered)
    assert int(a) == int(b), (mask, prio, entered)


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.sampled_from([1, 2, 7, 32, 64]),
    tick_hi=st.sampled_from([3, 1000, int(INF_TICK) - 1]),
    below=st.integers(0, 3),
)
def test_fused_victim_matches_oracle(seed, n, tick_hi, below):
    live, prio, start = _draw_tables(_rng(seed), n, tick_hi)
    a = select_victim(live, prio, start, jnp.int32(below))
    b = fused_victim(live, prio, start, jnp.int32(below))
    assert int(a) == int(b), (live, prio, start, below)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.sampled_from([1, 8, 32]),
    tick_hi=st.sampled_from([3, 1000]),
)
def test_fused_sjf_matches_oracle(seed, n, tick_hi):
    rng = _rng(seed)
    mask, prio, entered = _draw_tables(rng, n, tick_hi)
    n_ops = jnp.asarray(rng.integers(1, 5, n), jnp.int32)
    a = _select_sjf(mask, n_ops, prio, entered)
    b = fused_sjf(mask, n_ops, prio, entered)
    assert int(a) == int(b)


# ---------------------------------------------------------------------------
# Named corners (also covered by the sweeps, but pinned explicitly).
# ---------------------------------------------------------------------------
def test_all_masked_returns_minus_one():
    n = 16
    mask = jnp.zeros((n,), bool)
    prio = jnp.zeros((n,), jnp.int32)
    entered = jnp.zeros((n,), jnp.int32)
    assert int(fused_next_pipe(mask, prio, entered)) == -1
    assert int(fused_victim(mask, prio, entered, jnp.int32(3))) == -1
    # victim mask can also empty via the priority bound alone
    live = jnp.ones((n,), bool)
    assert int(fused_victim(live, prio, entered, jnp.int32(0))) == -1


def test_single_candidate_is_selected():
    mask = jnp.zeros((8,), bool).at[5].set(True)
    prio = jnp.asarray([2, 2, 2, 2, 2, 0, 2, 2], jnp.int32)
    entered = jnp.arange(8, dtype=jnp.int32)
    assert int(fused_next_pipe(mask, prio, entered)) == 5


def test_full_tie_breaks_by_index():
    n = 12
    mask = jnp.ones((n,), bool)
    prio = jnp.full((n,), 1, jnp.int32)
    entered = jnp.full((n,), 77, jnp.int32)
    assert int(fused_next_pipe(mask, prio, entered)) == 0
    assert int(fused_victim(mask, prio, entered, jnp.int32(2))) == 0
    mask2 = mask.at[0].set(False)
    assert int(fused_next_pipe(mask2, prio, entered)) == 1


def test_lexicographic_order_of_keys():
    # higher prio wins over earlier entry; equal prio -> earlier entry
    mask = jnp.ones((3,), bool)
    prio = jnp.asarray([1, 2, 2], jnp.int32)
    entered = jnp.asarray([0, 9, 5], jnp.int32)
    assert int(fused_next_pipe(mask, prio, entered)) == 2
    # victim: lowest prio, then LATEST start
    live = jnp.ones((3,), bool)
    vprio = jnp.asarray([0, 0, 1], jnp.int32)
    start = jnp.asarray([4, 8, 100], jnp.int32)
    assert int(fused_victim(live, vprio, start, jnp.int32(2))) == 1


# ---------------------------------------------------------------------------
# Pallas kernel (interpret mode) vs the jnp reference, batched.
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 1000),
    # 6 exercises the fleet-axis padding path (6 % block_fleet=4 != 0)
    F=st.sampled_from([1, 4, 6, 16]),
    N=st.sampled_from([8, 37, 128]),
    K=st.integers(1, 3),
)
def test_select_kernel_matches_ref(seed, F, N, K):
    rng = _rng(seed)
    mask = jnp.asarray(rng.random((F, N)) < 0.4)
    keys = jnp.asarray(rng.integers(-50, 50, (F, K, N)), jnp.int32)
    ref = masked_lex_argmin_ref(mask, tuple(keys[:, j] for j in range(K)))
    out = masked_lex_argmin_kernel(mask, keys, block_fleet=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_dispatch_kernel_impl_matches_ref():
    rng = _rng(7)
    mask = jnp.asarray(rng.random((5, 33)) < 0.5)
    k1 = jnp.asarray(rng.integers(0, 3, (5, 33)), jnp.int32)
    k2 = jnp.asarray(rng.integers(0, 100, (5, 33)), jnp.int32)
    a = masked_lex_argmin(mask, (k1, k2))
    b = masked_lex_argmin(mask, (k1, k2), impl="kernel", interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Lane binning: fleet_run(shard="auto") is lane-for-lane bitwise
# identical with event-density binning on vs off.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("algo", ["priority", "cache_aware"])
def test_lane_binning_bitwise_identical(algo):
    import jax

    from repro.core import SimParams, fleet_run
    from repro.core.sweep import bin_lanes_by_density, make_workload_batch

    assert jax.local_device_count() >= 4, "conftest forces 4 host devices"
    params = SimParams(
        duration=0.04,
        scheduling_algo=algo,
        num_pools=2,
        waiting_ticks_mean=300.0,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.2,  # skewed lanes -> non-trivial sort
        max_pipelines=32,
        max_containers=32,
        cache_gb_per_pool=4.0 if algo == "cache_aware" else 0.0,
    )
    seeds = list(range(10))  # 10 lanes on 4 devices -> padding too
    a = fleet_run(params, seeds, shard="auto", bin_lanes=True)
    b = fleet_run(params, seeds, shard="auto", bin_lanes=False)
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)),
            np.asarray(getattr(b, f)),
            err_msg=f"binning changed field {f}",
        )
    # the permutation is real: the sort actually reorders these lanes
    wls = make_workload_batch(params, seeds)
    _, inv = bin_lanes_by_density(wls, params)
    assert not np.array_equal(inv, np.arange(len(seeds)))


def test_binning_permutation_roundtrip():
    from repro.core import SimParams
    from repro.core.sweep import bin_lanes_by_density, make_workload_batch

    params = SimParams(
        duration=0.02, max_pipelines=16, max_containers=8,
        waiting_ticks_mean=200.0,
    )
    wls = make_workload_batch(params, list(range(7)))
    sorted_wls, inv = bin_lanes_by_density(wls, params)
    for f in wls._fields:
        v = getattr(wls, f)
        if v is None:  # optional lane fields (e.g. faults) stay None
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(sorted_wls, f))[inv],
            np.asarray(v),
            err_msg=f"field {f}",
        )
