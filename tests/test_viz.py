"""Visualisation: resampling correctness and the trace-driven Gantt."""
import pytest

from repro.core import SimParams, run
from repro.core.viz import (
    latency_histogram,
    pipeline_gantt,
    timeline_csv,
    utilization_timeline,
)


def _params(**extra):
    kw = dict(
        duration=0.03,
        scheduling_algo="priority_pool",
        num_pools=2,
        waiting_ticks_mean=300.0,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.0,
        max_pipelines=32,
        max_containers=32,
        cache_gb_per_pool=4.0,
        scan_ticks_per_gb=50.0,
        cold_start_ticks=40,
        container_warm_ticks=2_000,
    )
    kw.update(extra)
    return SimParams(**kw)


def _bars(text):
    return [line.split("|")[1] for line in text.splitlines()]


def test_utilization_timeline_clamps_width_to_buckets():
    """Regression: asking for more columns than util_log buckets used to
    repeat linspace edges, rendering the same bucket in several columns
    (and over-weighting it in the printed mean). The width now clamps to
    the bucket count, so every column is a distinct bucket."""
    res = run(_params(util_log_buckets=8))
    wide = utilization_timeline(res, width=64)
    for bar in _bars(wide):
        assert len(bar) == 8  # clamped to B, not 64
    # clamped output is exactly the width=B rendering
    assert wide == utilization_timeline(res, width=8)


def test_utilization_timeline_means_unaffected_by_width():
    """The printed mean is the mean over distinct buckets; any width
    must report the same value it does at width=B (double-counted
    buckets used to skew it)."""
    res = run(_params(util_log_buckets=8))

    def means(text):
        return [line.rsplit("mean", 1)[1] for line in text.splitlines()]

    ref = means(utilization_timeline(res, width=8))
    for width in (9, 64, 1000):
        assert means(utilization_timeline(res, width=width)) == ref


def test_utilization_timeline_downsamples():
    res = run(_params(util_log_buckets=64))
    for bar in _bars(utilization_timeline(res, width=16)):
        assert len(bar) == 16


def test_timeline_csv_one_row_per_bucket_pool():
    res = run(_params(util_log_buckets=8))
    lines = timeline_csv(res).splitlines()
    assert lines[0] == "t_s,pool,cpu_util,ram_util"
    assert len(lines) == 1 + 8 * res.params.num_pools


def test_pipeline_gantt_needs_trace():
    res = run(_params())
    assert "trace=True" in pipeline_gantt(res)


def test_pipeline_gantt_renders_spans():
    res = run(_params(), trace=True)
    text = pipeline_gantt(res, width=40)
    lines = text.splitlines()
    spans = res.trace.spans()
    assert spans
    # one row per pipeline that ever ran, plus the header
    assert len(lines) == 1 + len({s.pipe for s in spans})
    for line in lines[1:]:
        bar = line.split("|")[1]
        assert len(bar) == 40
        assert set(bar) <= set(" =CPO>?")
        assert any(ch in "CPO>" for ch in bar)  # every span has an end mark


def test_latency_histogram_smoke():
    res = run(_params())
    assert "|" in latency_histogram(res)


@pytest.mark.parametrize("width", [1, 3, 7])
def test_gantt_tiny_widths(width):
    res = run(_params(), trace=True)
    for line in pipeline_gantt(res, width=width).splitlines()[1:]:
        assert len(line.split("|")[1]) == width


def test_gantt_reports_overflow():
    res = run(_params(), trace=True, trace_capacity=16)
    assert res.trace.events_dropped > 0
    assert "dropped" in pipeline_gantt(res)


def test_util_timeline_fleet_lane_smoke():
    # sanity: default-bucket rendering still works end to end
    res = run(_params())
    text = utilization_timeline(res)
    assert text.count("\n") + 1 == 2 * res.params.num_pools
    assert "mean" in text
