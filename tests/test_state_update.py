"""Fused executor state-update landings (Pallas phase 3) vs the seed passes.

``kernels/state_update`` replaces the executor's write-side scatters:
``retire_land`` fuses the ``.at[pid].add/max`` retirement landings of
``_apply_retirements``, and ``assign_gather`` lands the assignment rows
collected by ``apply_decision``'s early-exit loop as one masked scatter
instead of a full-state ``lax.cond`` per slot. The sequential passes
stay exported as the oracles; everything here pins the fused paths to
them bitwise — including the corners the issue calls out (capacity
edge, all-masked decisions, cache-full / LRU ties, simultaneous
retire + release + arrival) — and checks the Pallas kernels against
the jnp references in interpret mode so CPU CI covers them.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import SimParams, generate_workload
from repro.core import executor
from repro.core.scheduler import SchedDecision
from repro.core.state import INF_TICK, init_state
from repro.core.types import ContainerStatus, PipeStatus, TICKS_PER_SECOND
from repro.kernels.state_update import (
    assign_gather_ref,
    retire_land,
    retire_land_ref,
)
from repro.kernels.state_update.kernel import (
    assign_gather_kernel,
    retire_land_kernel,
)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# retire_land vs the seed's scatter landing (the exact ops of
# `_apply_retirements`), on arbitrary tables — duplicates included.
# ---------------------------------------------------------------------------
# jitted like the ref: the engine runs both under jit, and the f32
# latency sum's reduction order is only pinned within a compile context
@functools.partial(jax.jit, static_argnames=("timeout_on",))
def _retire_scatter_oracle(
    ctr_pipe, ctr_end, ctr_start, oomed, done, timed_in, arrival, prio,
    tick, timeout_on,
):
    i32 = jnp.int32
    MP = arrival.shape[0]
    retired = oomed | done
    if timeout_on:
        timed = done & timed_in
        done_eff = done & ~timed
    else:
        timed = jnp.zeros_like(done)
        done_eff = done
    pid = jnp.where(retired, ctr_pipe, MP)
    oom_hit = (
        jnp.zeros((MP,), i32).at[pid].add(oomed.astype(i32), mode="drop")
    ) > 0
    done_hit = (
        jnp.zeros((MP,), i32).at[pid].add(done_eff.astype(i32), mode="drop")
    ) > 0
    end_of = (
        jnp.full((MP,), 0, i32)
        .at[pid]
        .max(jnp.where(done_eff, ctr_end, 0), mode="drop")
    )
    timed_hit = (
        jnp.zeros((MP,), i32)
        .at[jnp.where(timed, ctr_pipe, MP)]
        .add(timed.astype(i32), mode="drop")
    ) > 0
    timed_wasted = jnp.sum(jnp.where(timed, tick - ctr_start, 0)).astype(i32)
    lat_s = (end_of - arrival).astype(jnp.float32) / TICKS_PER_SECOND
    lat_s = jnp.where(done_hit, lat_s, 0.0)
    prio_oh = prio[None, :] == jnp.arange(3, dtype=i32)[:, None]
    return (
        oom_hit, done_hit, timed_hit, end_of, timed_wasted,
        jnp.sum(lat_s),
        jnp.sum(jnp.where(prio_oh, lat_s[None, :], 0.0), axis=1),
        jnp.sum(prio_oh & done_hit[None, :], axis=1).astype(i32),
        jnp.sum(done_hit).astype(i32),
        jnp.sum(oom_hit).astype(i32),
    )


def _draw_retire_tables(rng, MC, MP, tick_hi):
    ctr_pipe = jnp.asarray(rng.integers(0, MP, MC), jnp.int32)
    ctr_end = jnp.asarray(rng.integers(0, tick_hi, MC), jnp.int32)
    ctr_start = jnp.asarray(rng.integers(0, tick_hi, MC), jnp.int32)
    oomed = jnp.asarray(rng.random(MC) < 0.3)
    done = jnp.asarray(rng.random(MC) < 0.4)
    timed = jnp.asarray(rng.random(MC) < 0.3)
    arrival = jnp.asarray(rng.integers(0, tick_hi, MP), jnp.int32)
    prio = jnp.asarray(rng.integers(0, 3, MP), jnp.int32)
    tick = jnp.asarray(rng.integers(0, tick_hi), jnp.int32)
    return ctr_pipe, ctr_end, ctr_start, oomed, done, timed, arrival, prio, tick


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    MC=st.sampled_from([1, 8, 32]),
    MP=st.sampled_from([4, 32, 128]),
    # 3 -> duplicate/tie-heavy (many containers of the same pipeline
    # retiring at the same tick), 200000 -> realistic range
    tick_hi=st.sampled_from([3, 200_000]),
    timeout_on=st.booleans(),
)
def test_retire_land_matches_scatter_oracle(seed, MC, MP, tick_hi, timeout_on):
    args = _draw_retire_tables(_rng(seed), MC, MP, tick_hi)
    ref = _retire_scatter_oracle(*args, timeout_on=timeout_on)
    out = retire_land(*args, timeout_on=timeout_on)
    for name, r, o in zip(
        "oom_hit done_hit timed_hit end_of timed_wasted lat_sum lat_prio"
        " done_prio n_done n_oom".split(), ref, out,
    ):
        np.testing.assert_array_equal(
            np.asarray(r), np.asarray(o), err_msg=name
        )


def test_retire_land_all_masked_is_identity_shaped():
    # no retirements at all -> every landing output is zero
    MC, MP = 8, 16
    z = jnp.zeros((MC,), bool)
    out = retire_land(
        jnp.zeros((MC,), jnp.int32), jnp.zeros((MC,), jnp.int32),
        jnp.zeros((MC,), jnp.int32), z, z, None,
        jnp.zeros((MP,), jnp.int32), jnp.zeros((MP,), jnp.int32),
        jnp.int32(7),
    )
    for o in out:
        assert not np.asarray(o).any()


# ---------------------------------------------------------------------------
# Fused phase 1 (arrival + release + retirement in one where-chain,
# retirements landed through retire_land) vs the sequential seed
# composition, on states where all three fire simultaneously.
# ---------------------------------------------------------------------------
def _phase1_params(**kw):
    return SimParams(
        duration=0.02, max_pipelines=32, max_containers=16, num_pools=2,
        waiting_ticks_mean=300.0, op_base_seconds_mean=0.005, **kw,
    )


def _random_phase1_state(params, wl, rng, tick):
    """A mid-flight state: some pipelines suspended with releases due at
    ``tick``, some containers running with retirements due at ``tick``,
    and (via the workload draw) arrivals due as well."""
    MP, MC = params.max_pipelines, params.max_containers
    NP = params.num_pools
    state = init_state(params)
    status = rng.choice(
        [int(PipeStatus.EMPTY), int(PipeStatus.WAITING),
         int(PipeStatus.SUSPENDED), int(PipeStatus.RUNNING),
         int(PipeStatus.DONE)],
        MP, p=[0.3, 0.2, 0.2, 0.2, 0.1],
    )
    release = rng.integers(0, int(tick) * 2 + 2, MP)
    ctr_status = rng.choice(
        [int(ContainerStatus.EMPTY), int(ContainerStatus.RUNNING)],
        MC, p=[0.4, 0.6],
    )
    running = ctr_status == int(ContainerStatus.RUNNING)
    end = rng.integers(0, int(tick) * 2 + 2, MC)
    oom = np.where(
        rng.random(MC) < 0.3, rng.integers(0, int(tick) * 2 + 2, MC),
        INF_TICK,
    )
    return state._replace(
        pipe_status=jnp.asarray(status, jnp.int32),
        pipe_release=jnp.asarray(
            np.where(status == int(PipeStatus.SUSPENDED), release, INF_TICK),
            jnp.int32,
        ),
        ctr_status=jnp.asarray(ctr_status, jnp.int32),
        ctr_pipe=jnp.asarray(
            np.where(running, rng.integers(0, MP, MC), -1), jnp.int32
        ),
        ctr_pool=jnp.asarray(
            np.where(running, rng.integers(0, NP, MC), 0), jnp.int32
        ),
        ctr_end=jnp.asarray(np.where(running, end, INF_TICK), jnp.int32),
        ctr_oom=jnp.asarray(np.where(running, oom, INF_TICK), jnp.int32),
        ctr_start=jnp.asarray(
            np.where(running, rng.integers(0, int(tick) + 1, MC), INF_TICK),
            jnp.int32,
        ),
        ctr_cpus=jnp.asarray(
            np.where(running, rng.integers(1, 8, MC), 0.0), jnp.float32
        ),
        ctr_ram=jnp.asarray(
            np.where(running, rng.integers(1, 16, MC), 0.0), jnp.float32
        ),
        ctr_prio=jnp.asarray(
            np.where(running, rng.integers(0, 3, MC), -1), jnp.int32
        ),
        ctr_timed=jnp.asarray(running & (rng.random(MC) < 0.4)),
    )


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    timeout=st.sampled_from([0, 5_000]),
)
def test_fused_phase1_matches_sequential(seed, timeout):
    from repro.kernels.sim_tick import fleet_tick

    params = _phase1_params(
        timeout_ticks=timeout, seed=seed % 97,
    )
    wl = generate_workload(params)
    rng = _rng(seed)
    tick = jnp.int32(rng.integers(1, 2_000))
    state = _random_phase1_state(params, wl, rng, tick)

    # jit both sides: that is how the engine runs them, and it pins the
    # f32 latency-sum reduction order to one compile context
    @jax.jit
    def seq_fn(s, w, t):
        s = executor.process_arrivals(s, w, t)
        s = executor.process_releases(s, t)
        return executor.process_completions(s, w, t, params)

    seq = seq_fn(state, wl, tick)

    ph = fleet_tick(
        state.ctr_status[None], state.ctr_end[None], state.ctr_oom[None],
        state.ctr_cpus[None], state.ctr_ram[None], state.ctr_pool[None],
        state.pipe_status[None], wl.arrival[None], state.pipe_release[None],
        tick[None], num_pools=params.num_pools,
    )
    fused = jax.jit(
        lambda s, w, t, p: executor.apply_fused_phase1(s, w, t, params, p)
    )(state, wl, tick, jax.tree.map(lambda x: x[0], ph))

    for f in seq._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(seq, f)), np.asarray(getattr(fused, f)),
            err_msg=f"phase1 field {f}",
        )


# ---------------------------------------------------------------------------
# apply_decision: fused early-exit landing vs the fori_loop cond-commit
# oracle, bitwise over the whole SimState — duplicates, capacity edges,
# cache-full / LRU-tie draws included.
# ---------------------------------------------------------------------------
def _decision_params(dp, timeout, **kw):
    extra = dict(
        cache_gb_per_pool=2.0,       # tiny -> constant LRU eviction
        scan_ticks_per_gb=50.0,
        cold_start_ticks=40,
        container_warm_ticks=2_000,
    ) if dp else {}
    extra.update(kw)
    extra.setdefault("num_pools", 2)
    return SimParams(
        duration=0.02, max_pipelines=32, max_containers=16,
        waiting_ticks_mean=300.0, op_base_seconds_mean=0.005,
        timeout_ticks=timeout, **extra,
    )


def _draw_decision(rng, params, full_slots=False, empty_decision=False):
    MP, MC = params.max_pipelines, params.max_containers
    K = params.max_assignments_per_tick
    if empty_decision:
        pipes = np.full(K, -1)
    else:
        # duplicates and invalid (-1) slots on purpose; duplicate pipes
        # exercise the carried waiting-mask vs the oracle's status read
        pipes = rng.integers(-1, MP, K)
        pipes[rng.random(K) < 0.3] = rng.integers(0, MP)
    return SchedDecision(
        suspend=jnp.asarray(rng.random(MC) < 0.15),
        reject=jnp.asarray(rng.random(MP) < 0.1),
        assign_pipe=jnp.asarray(pipes, jnp.int32),
        assign_pool=jnp.asarray(
            rng.integers(0, params.num_pools, K), jnp.int32
        ),
        assign_cpus=jnp.asarray(rng.integers(1, 8, K), jnp.float32),
        assign_ram=jnp.asarray(rng.integers(1, 16, K), jnp.float32),
    )


def _assert_states_equal(a, b, ctx):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: field {f}",
        )


def _check_decision_case(seed, dp, timeout, full_slots, empty_decision):
    params = _decision_params(dp, timeout, seed=seed % 89)
    wl = generate_workload(params)
    rng = _rng(seed)
    tick = jnp.int32(rng.integers(1, 2_000))
    state = _random_phase1_state(params, wl, rng, tick)
    if full_slots:
        # capacity edge: every container slot occupied -> no assignment
        # can land, whatever the decision says
        state = state._replace(
            ctr_status=jnp.full_like(
                state.ctr_status, int(ContainerStatus.RUNNING)
            )
        )
    # make plenty of pipelines actually waiting so assignments commit
    state = executor.process_arrivals(state, wl, tick + 500)
    dec = _draw_decision(rng, params, full_slots, empty_decision)

    def apply(early_exit, with_aux=False):
        return jax.jit(
            lambda s, w, d, t: executor.apply_decision(
                s, w, d, t, params, early_exit=early_exit, with_aux=with_aux
            )
        )(state, wl, dec, tick)

    oracle = apply(early_exit=False)
    fused = apply(early_exit=True)
    _assert_states_equal(oracle, fused, "early_exit")

    fused_aux, (aux_i, aux_f) = apply(early_exit=True, with_aux=True)
    _assert_states_equal(oracle, fused_aux, "with_aux")

    # the aux is the commit's own intermediates: committed rows name
    # waiting pipelines, and the miss sum is the bytes-moved delta
    aux_i = np.asarray(aux_i)
    aux_f = np.asarray(aux_f)
    valid = aux_i[:, 0] >= 0
    assert ((aux_i[~valid] == np.array([-1, -1, 0, 0])).all())
    assert (aux_f[~valid] == 0.0).all()
    for p in aux_i[valid, 0]:
        assert int(np.asarray(state.pipe_status)[p]) == int(PipeStatus.WAITING)
        assert int(np.asarray(oracle.pipe_status)[p]) == int(PipeStatus.RUNNING)
    np.testing.assert_allclose(
        aux_f[valid, 3].sum(),
        float(oracle.bytes_moved_gb) - float(state.bytes_moved_gb),
        rtol=1e-6,
    )


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    dp=st.booleans(),
    timeout=st.sampled_from([0, 5_000]),
)
def test_fused_assignments_match_fori_oracle(seed, dp, timeout):
    _check_decision_case(seed, dp, timeout, False, False)


def test_fused_assignments_capacity_edge():
    _check_decision_case(3, True, 0, True, False)


def test_fused_assignments_empty_decision():
    _check_decision_case(5, True, 5_000, False, True)


def test_fused_assignments_cache_lru_ties():
    # every assignment lands on pool 0 with identical output sizes: the
    # 2 GB cache is permanently full and eviction constantly tie-breaks
    params = _decision_params(True, 0, seed=13, num_pools=1)
    wl = generate_workload(params)
    rng = _rng(13)
    tick = jnp.int32(1_000)
    state = _random_phase1_state(params, wl, rng, tick)
    state = executor.process_arrivals(state, wl, tick + 500)
    K = params.max_assignments_per_tick
    waiting = np.flatnonzero(
        np.asarray(state.pipe_status) == int(PipeStatus.WAITING)
    )[:K]
    pipes = np.full(K, -1)
    pipes[: len(waiting)] = waiting
    dec = SchedDecision(
        suspend=jnp.zeros((params.max_containers,), bool),
        reject=jnp.zeros((params.max_pipelines,), bool),
        assign_pipe=jnp.asarray(pipes, jnp.int32),
        assign_pool=jnp.zeros((K,), jnp.int32),
        assign_cpus=jnp.full((K,), 2.0, jnp.float32),
        assign_ram=jnp.full((K,), 4.0, jnp.float32),
    )
    oracle = jax.jit(
        lambda s, w, d, t: executor.apply_decision(s, w, d, t, params)
    )(state, wl, dec, tick)
    fused = jax.jit(
        lambda s, w, d, t: executor.apply_decision(
            s, w, d, t, params, early_exit=True
        )
    )(state, wl, dec, tick)
    _assert_states_equal(oracle, fused, "lru_ties")
    assert float(oracle.pool_cache_used[0]) <= params.cache_gb_per_pool


# ---------------------------------------------------------------------------
# Pallas kernels (interpret mode) vs the jnp references, batched — what
# the TPU dispatch runs, checked on CPU CI.
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 1000),
    # 6 exercises the fleet-axis padding path (6 % block_fleet=4 != 0)
    F=st.sampled_from([1, 4, 6]),
    timeout_on=st.booleans(),
)
def test_retire_kernel_matches_ref(seed, F, timeout_on):
    rng = _rng(seed)
    MC, MP = 16, 32
    lanes = [_draw_retire_tables(rng, MC, MP, 50_000) for _ in range(F)]
    args = [jnp.stack([lane[i] for lane in lanes]) for i in range(9)]
    ref = retire_land_ref(*args, timeout_on=timeout_on)
    out = retire_land_kernel(
        *args, timeout_on=timeout_on, block_fleet=4, interpret=True
    )
    for name, r, o in zip(
        "oom_hit done_hit timed_hit end_of timed_wasted lat_sum lat_prio"
        " done_prio n_done n_oom".split(), ref, out,
    ):
        np.testing.assert_array_equal(
            np.asarray(r), np.asarray(o), err_msg=name
        )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), F=st.sampled_from([1, 4, 6]))
def test_assign_kernel_matches_ref(seed, F):
    rng = _rng(seed)
    K, MC, MP = 8, 16, 32
    # valid rows carry unique slots/pipes per lane (the loop invariant)
    valid = jnp.asarray(rng.random((F, K)) < 0.6)
    slot = jnp.stack([
        jnp.asarray(rng.permutation(MC)[:K], jnp.int32) for _ in range(F)
    ])
    pipe = jnp.stack([
        jnp.asarray(rng.permutation(MP)[:K], jnp.int32) for _ in range(F)
    ])
    pool = jnp.asarray(rng.integers(0, 4, (F, K)), jnp.int32)
    cpus = jnp.asarray(rng.integers(1, 8, (F, K)), jnp.float32)
    ram = jnp.asarray(rng.integers(1, 16, (F, K)), jnp.float32)
    end = jnp.asarray(rng.integers(0, 50_000, (F, K)), jnp.int32)
    oom = jnp.asarray(rng.integers(0, 50_000, (F, K)), jnp.int32)
    prio = jnp.asarray(rng.integers(0, 3, (F, K)), jnp.int32)
    warm = jnp.asarray(rng.random((F, K)) < 0.5)
    timed = jnp.asarray(rng.random((F, K)) < 0.3)
    args = (valid, slot, pipe, pool, cpus, ram, end, oom, prio, warm, timed)
    ref = assign_gather_ref(*args, max_containers=MC, max_pipelines=MP)
    out = assign_gather_kernel(
        *args, max_containers=MC, max_pipelines=MP, block_fleet=4,
        interpret=True,
    )
    for i, (r, o) in enumerate(zip(ref, out)):
        np.testing.assert_array_equal(
            np.asarray(r), np.asarray(o), err_msg=f"output {i}"
        )


def test_dispatch_kernel_impl_matches_ref():
    rng = _rng(7)
    args = _draw_retire_tables(rng, 16, 32, 10_000)
    batched = tuple(
        jnp.broadcast_to(a, (4,) + a.shape) for a in args
    )
    a = retire_land(*batched, timeout_on=True)
    b = retire_land(*batched, timeout_on=True, impl="kernel", interpret=True)
    for r, o in zip(a, b):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o))
