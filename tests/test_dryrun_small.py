"""Dry-run machinery on a reduced mesh (8 fake CPU devices, subprocess —
XLA device count is locked at first jax init so it cannot be set inside
the main test process)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

CODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.configs.registry import get_arch
    from repro.launch.lowering import lower_cell
    from repro.roofline.hlo_stats import analyze_hlo

    arch_name, shape, pod = sys.argv[1], sys.argv[2], sys.argv[3] == "pod"
    arch = get_arch(arch_name)
    # shrink to the smoke config so an 8-device compile is fast
    import dataclasses
    arch = dataclasses.replace(arch, model=arch.smoke, train_microbatches=2)
    if pod:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    else:
        mesh = jax.make_mesh((2, 4), ("data", "model"))
    lowered = lower_cell(arch, shape, mesh)
    compiled = lowered.compile()
    stats = analyze_hlo(compiled.as_text(), chips=8)
    mem = compiled.memory_analysis()
    print(json.dumps({
        "flops": stats.flops,
        "bytes": stats.bytes,
        "coll": stats.coll_bytes,
        "temp": getattr(mem, "temp_size_in_bytes", -1),
    }))
    """
)


def _run(arch, shape, mesh):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", CODE, arch, shape, mesh],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    return out


@pytest.mark.parametrize(
    "arch,shape",
    [
        ("gemma3_12b", "train_4k"),
        ("jamba_1p5_large_398b", "decode_32k"),
        ("rwkv6_7b", "train_4k"),
        ("whisper_small", "prefill_32k"),
        ("arctic_480b", "train_4k"),
    ],
)
def test_lower_compile_smoke_single(arch, shape):
    out = _run(arch, shape, "single")
    assert out["flops"] > 0
    assert out["bytes"] > 0


@pytest.mark.parametrize("arch", ["internvl2_2b", "llama4_maverick_400b_a17b"])
def test_lower_compile_smoke_multipod(arch):
    out = _run(arch, "train_4k", "pod")
    assert out["flops"] > 0


def test_collectives_present_when_sharded():
    """An FSDP+TP train step must emit collectives on an 8-way mesh."""
    out = _run("gemma3_12b", "train_4k", "single")
    assert out["coll"] > 0
