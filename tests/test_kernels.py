"""Per-kernel validation: Pallas (interpret=True) and chunked-jnp paths
vs. the pure-jnp oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref, mha_reference
from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_kernel
from repro.kernels.rwkv6_scan.ops import _rwkv6_chunked, rwkv6_decode_step
from repro.kernels.rwkv6_scan.ref import rwkv6_ref
from repro.kernels.sim_tick.kernel import fleet_tick_kernel
from repro.kernels.sim_tick.ref import fleet_tick_ref
from repro.kernels.ssm_scan.kernel import ssm_scan_kernel
from repro.kernels.ssm_scan.ops import _ssm_chunked, ssm_decode_step
from repro.kernels.ssm_scan.ref import ssm_scan_ref

TOL = dict(rtol=2e-2, atol=2e-2)       # bf16 inputs
TOL32 = dict(rtol=2e-4, atol=2e-4)     # f32 inputs


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KV,D,causal,window,bq,bk",
    [
        (1, 64, 2, 2, 32, True, 0, 16, 16),
        (2, 128, 4, 2, 64, True, 0, 32, 64),
        (2, 128, 4, 1, 64, False, 0, 64, 32),     # MQA
        (1, 256, 8, 4, 32, True, 64, 64, 64),     # sliding window
        (1, 96, 2, 2, 32, True, 0, 32, 32),       # ragged: S % block != 0
    ],
)
def test_flash_kernel_matches_reference(B, S, H, KV, D, causal, window, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    out = flash_attention_kernel(
        q, k, v, causal=causal, window=window, block_q=bq, block_k=bk,
        interpret=True,
    )
    tol = TOL32 if dtype == jnp.float32 else TOL
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol
    )


@settings(max_examples=12, deadline=None)
@given(
    s=st.sampled_from([32, 64, 160]),
    h=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    d=st.sampled_from([16, 32]),
    causal=st.booleans(),
    window=st.sampled_from([0, 16]),
)
def test_flash_ref_property(s, h, g, d, causal, window):
    """Blocked flash-style reference == naive softmax attention."""
    kv = max(h // g, 1)
    h = kv * g
    ks = jax.random.split(jax.random.PRNGKey(s * h + d), 3)
    q = jax.random.normal(ks[0], (1, s, h, d))
    k = jax.random.normal(ks[1], (1, s, kv, d))
    v = jax.random.normal(ks[2], (1, s, kv, d))
    a = mha_reference(q, k, v, causal=causal, window=window)
    b = flash_attention_ref(q, k, v, causal=causal, window=window, block_k=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL32)


def test_flash_decode_path_with_kv_len():
    """q_offset + kv_len (decode) against a sliced naive reference."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    S, used = 64, 40
    q = jax.random.normal(ks[0], (2, 1, 4, 32))
    k = jax.random.normal(ks[1], (2, S, 2, 32))
    v = jax.random.normal(ks[2], (2, S, 2, 32))
    out = flash_attention_ref(
        q, k, v, causal=True, q_offset=used - 1, kv_len=used, block_k=16
    )
    ref = mha_reference(q, k[:, :used], v[:, :used], causal=True, q_offset=used - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------
def _rwkv_inputs(key, B, S, H, N, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (B, S, H, N), dtype)
    k = jax.random.normal(ks[1], (B, S, H, N), dtype) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, N), dtype)
    x = jax.random.uniform(ks[3], (B, S, H, N), minval=-3.0, maxval=1.0)
    w = jnp.exp(-jnp.exp(x)).astype(dtype)
    u = (jax.random.normal(ks[4], (H, N)) * 0.3).astype(dtype)
    s0 = jax.random.normal(ks[5], (B, H, N, N), jnp.float32) * 0.1
    return r, k, v, w, u, s0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,N,chunk", [(1, 32, 2, 8, 8), (2, 64, 3, 16, 16), (1, 48, 1, 32, 16)])
def test_rwkv6_chunked_and_kernel(B, S, H, N, chunk, dtype):
    r, k, v, w, u, s0 = _rwkv_inputs(jax.random.PRNGKey(1), B, S, H, N, dtype)
    o_ref, S_ref = rwkv6_ref(r, k, v, w, u, s0)
    o_c, S_c = _rwkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    o_k, S_k = rwkv6_scan_kernel(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    tol = TOL32 if dtype == jnp.float32 else TOL
    np.testing.assert_allclose(
        np.asarray(o_c, np.float32), np.asarray(o_ref, np.float32), **tol
    )
    np.testing.assert_allclose(
        np.asarray(o_k, np.float32), np.asarray(o_ref, np.float32), **tol
    )
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S_ref), **TOL32)
    np.testing.assert_allclose(np.asarray(S_k), np.asarray(S_ref), **TOL32)


def test_rwkv6_decode_consistency():
    r, k, v, w, u, s0 = _rwkv_inputs(jax.random.PRNGKey(2), 2, 33, 2, 8)
    o_full, S_full = rwkv6_ref(r, k, v, w, u, s0)
    _, S_prefix = rwkv6_ref(
        r[:, :-1], k[:, :-1], v[:, :-1], w[:, :-1], u, s0
    )
    o_d, S_d = rwkv6_decode_step(
        r[:, -1], k[:, -1], v[:, -1], w[:, -1], u, S_prefix
    )
    np.testing.assert_allclose(
        np.asarray(o_d), np.asarray(o_full[:, -1]), **TOL32
    )
    np.testing.assert_allclose(np.asarray(S_d), np.asarray(S_full), **TOL32)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), chunk=st.sampled_from([4, 8, 16]))
def test_rwkv6_chunk_invariance(seed, chunk):
    """Output must not depend on the chunk size (pure perf knob)."""
    r, k, v, w, u, s0 = _rwkv_inputs(jax.random.PRNGKey(seed), 1, 32, 2, 8)
    o_a, S_a = _rwkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    o_b, S_b = _rwkv6_chunked(r, k, v, w, u, s0, chunk=32)
    np.testing.assert_allclose(np.asarray(o_a), np.asarray(o_b), **TOL32)
    np.testing.assert_allclose(np.asarray(S_a), np.asarray(S_b), **TOL32)


# ---------------------------------------------------------------------------
# ssm (mamba)
# ---------------------------------------------------------------------------
def _ssm_inputs(key, B, S, dim, N):
    ks = jax.random.split(key, 7)
    x = jax.random.normal(ks[0], (B, S, dim))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, dim)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (dim, N)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    D = jax.random.normal(ks[5], (dim,))
    h0 = jax.random.normal(ks[6], (B, dim, N)) * 0.1
    return x, dt, A, Bm, Cm, D, h0


@pytest.mark.parametrize(
    "B,S,dim,N,chunk,bd", [(1, 32, 8, 4, 8, 8), (2, 64, 16, 8, 16, 8), (1, 128, 8, 4, 32, 4)]
)
def test_ssm_chunked_and_kernel(B, S, dim, N, chunk, bd):
    x, dt, A, Bm, Cm, D, h0 = _ssm_inputs(jax.random.PRNGKey(3), B, S, dim, N)
    y_ref, h_ref = ssm_scan_ref(x, dt, A, Bm, Cm, D, h0)
    y_c, h_c = _ssm_chunked(x, dt, A, Bm, Cm, D, h0, chunk=chunk)
    y_k, h_k = ssm_scan_kernel(
        x, dt, A, Bm, Cm, D, h0, chunk=chunk, block_dim=bd, interpret=True
    )
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref), **TOL32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), **TOL32)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_ref), **TOL32)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref), **TOL32)


def test_ssm_decode_consistency():
    x, dt, A, Bm, Cm, D, h0 = _ssm_inputs(jax.random.PRNGKey(4), 2, 17, 8, 4)
    y_full, h_full = ssm_scan_ref(x, dt, A, Bm, Cm, D, h0)
    _, h_prefix = ssm_scan_ref(
        x[:, :-1], dt[:, :-1], A, Bm[:, :-1], Cm[:, :-1], D, h0
    )
    y_d, h_d = ssm_decode_step(
        x[:, -1], dt[:, -1], A, Bm[:, -1], Cm[:, -1], D, h_prefix
    )
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_full[:, -1]), **TOL32)
    np.testing.assert_allclose(np.asarray(h_d), np.asarray(h_full), **TOL32)


# ---------------------------------------------------------------------------
# sim_tick
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 1000),
    # 6 exercises the fleet-axis padding path (6 % block_fleet=4 != 0)
    F=st.sampled_from([4, 6, 16]),
    MC=st.sampled_from([8, 32]),
    MP=st.sampled_from([8, 16]),
    NP=st.integers(1, 4),
)
def test_fleet_tick_kernel_matches_ref(seed, F, MC, MP, NP):
    ks = jax.random.split(jax.random.PRNGKey(seed), 10)
    status = jax.random.randint(ks[0], (F, MC), 0, 2)
    end = jax.random.randint(ks[1], (F, MC), 0, 100)
    oom = jnp.where(
        jax.random.bernoulli(ks[2], 0.3, (F, MC)),
        jax.random.randint(ks[3], (F, MC), 0, 100),
        jnp.int32(2**31 - 1),
    )
    cpus = jax.random.uniform(ks[4], (F, MC)) * 4
    ram = jax.random.uniform(ks[5], (F, MC)) * 8
    pool = jax.random.randint(ks[6], (F, MC), 0, NP)
    # pipe table: EMPTY / WAITING / SUSPENDED mix, arrivals + releases
    pstat = jnp.asarray([0, 2, 4], jnp.int32)[
        jax.random.randint(ks[7], (F, MP), 0, 3)
    ]
    arrival = jax.random.randint(ks[8], (F, MP), 0, 150)
    release = jax.random.randint(ks[9], (F, MP), 0, 150)
    tick = (jnp.arange(F, dtype=jnp.int32) * 7) % 100
    args = (status, end, oom, cpus, ram, pool, pstat, arrival, release, tick)
    ref = fleet_tick_ref(*args, num_pools=NP)
    out = fleet_tick_kernel(*args, num_pools=NP, block_fleet=4, interpret=True)
    assert len(ref) == len(out) == 9
    for a, b in zip(ref, out):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-6
        )
