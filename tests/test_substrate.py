"""Substrate tests: data determinism, optimizers, checkpointing (incl.
elastic restore across mesh shapes), compressed collectives, failure
handling, and the fault-tolerant train loop."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.data.pipeline import SyntheticLM
from repro.optim.optimizers import (
    OptConfig,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
)
from repro.runtime.failures import (
    FailureInjector,
    StragglerMonitor,
    advise_checkpoint_cadence,
)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
class TestData:
    def test_deterministic_across_instances(self):
        a = SyntheticLM(vocab=512, seq_len=64, global_batch=4, seed=3)
        b = SyntheticLM(vocab=512, seq_len=64, global_batch=4, seed=3)
        np.testing.assert_array_equal(
            a.batch_at(7)["tokens"], b.batch_at(7)["tokens"]
        )

    def test_steps_differ_and_tokens_in_range(self):
        ds = SyntheticLM(vocab=512, seq_len=64, global_batch=4, seed=0)
        t0, t5 = ds.batch_at(0)["tokens"], ds.batch_at(5)["tokens"]
        assert not np.array_equal(t0, t5)
        assert t0.min() >= 0 and t0.max() < 512

    def test_vlm_frontend_embeds(self):
        ds = SyntheticLM(
            vocab=64, seq_len=32, global_batch=2, family="vlm", n_img_tokens=4
        )
        b = ds.batch_at(0)
        assert b["frontend_embeds"].shape == (2, 4, 1024)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def _quad_params():
    return {"w": jnp.array([2.0, -3.0, 1.5]), "b": jnp.array([[1.0, -1.0]] * 2)}


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizers_minimize_quadratic(name):
    cfg = OptConfig(name=name, peak_lr=0.1, warmup_steps=5, total_steps=200,
                    weight_decay=0.0)
    params = _quad_params()
    init, update = (
        (adamw_init, adamw_update) if name == "adamw"
        else (adafactor_init, adafactor_update)
    )
    state = init(cfg, params)
    loss = lambda p: sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(p))
    l0 = float(loss(params))
    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state, gn = update(cfg, grads, state, params)
    assert float(loss(params)) < 0.05 * l0
    assert int(state.step) == 150


def test_adafactor_state_is_factored():
    cfg = OptConfig(name="adafactor")
    params = {"big": jnp.zeros((64, 32)), "vec": jnp.zeros((16,))}
    st = adafactor_init(cfg, params)
    assert set(st.inner["big"].keys()) == {"vr", "vc"}
    assert st.inner["big"]["vr"].shape == (64,)
    assert st.inner["big"]["vc"].shape == (32,)
    assert set(st.inner["vec"].keys()) == {"v"}


def test_grad_clipping_bounds_update():
    cfg = OptConfig(name="adamw", peak_lr=1.0, clip_norm=1.0, warmup_steps=1)
    params = {"w": jnp.zeros((4,))}
    st = adamw_init(cfg, params)
    huge = {"w": jnp.full((4,), 1e6)}
    p2, st, gn = adamw_update(cfg, huge, st, params)
    assert float(gn) > 1e5          # reported raw norm
    assert np.isfinite(np.asarray(p2["w"])).all()
    assert np.abs(np.asarray(p2["w"])).max() < 10.0


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
class TestCheckpoint:
    def _state(self, key=0):
        k = jax.random.PRNGKey(key)
        return {
            "w": jax.random.normal(k, (8, 16), jnp.float32),
            "nested": {"m": jnp.arange(12, dtype=jnp.int32).reshape(3, 4)},
            "scalar": jnp.asarray(3, jnp.int32),
        }

    def test_roundtrip(self, tmp_path):
        st = self._state()
        save_checkpoint(st, tmp_path, 5)
        restored, manifest = restore_checkpoint(tmp_path, st)
        assert manifest["step"] == 5
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_tmp_never_visible(self, tmp_path):
        st = self._state()
        save_checkpoint(st, tmp_path, 1)
        assert not list(tmp_path.glob("*.tmp"))

    def test_keep_last_k(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        st = self._state()
        for s in range(5):
            mgr.save(st, s)
        steps = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(steps) == 2
        assert mgr.latest_step() == 4

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3)
        st = self._state()
        mgr.async_save(st, 7)
        mgr.wait()
        assert mgr.latest_step() == 7
        restored, _ = mgr.restore(st)
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.asarray(st["w"])
        )

    def test_restore_latest_of_many(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=5)
        for s in [1, 3, 9]:
            mgr.save(self._state(s), s)
        _, manifest = mgr.restore(self._state())
        assert manifest["step"] == 9


def test_elastic_restore_across_mesh_shapes():
    """Save on a 4-device mesh, restore onto an 8-device mesh (subprocess
    with a different XLA device count)."""
    code = textwrap.dedent(
        """
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        mesh = jax.make_mesh((%d,), ("data",))
        sh = NamedSharding(mesh, P("data"))
        state = {"w": jax.device_put(jnp.arange(32, dtype=jnp.float32).reshape(8, 4), sh)}
        if "%s" == "save":
            save_checkpoint(state, sys.argv[1], 3)
        else:
            restored, m = restore_checkpoint(sys.argv[1], state, shardings={"w": sh})
            np.testing.assert_array_equal(
                np.asarray(restored["w"]), np.arange(32, dtype=np.float32).reshape(8, 4))
            assert m["step"] == 3
            print("RESTORE_OK")
        """
    )
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ, PYTHONPATH="src")
        r1 = subprocess.run(
            [sys.executable, "-c", code % (4, 4, "save"), d],
            capture_output=True, text=True, env=env, cwd=os.getcwd(),
        )
        assert r1.returncode == 0, r1.stderr[-2000:]
        r2 = subprocess.run(
            [sys.executable, "-c", code % (8, 8, "restore"), d],
            capture_output=True, text=True, env=env, cwd=os.getcwd(),
        )
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert "RESTORE_OK" in r2.stdout


# ---------------------------------------------------------------------------
# failures / stragglers / cadence advisor
# ---------------------------------------------------------------------------
def test_failure_injector_deterministic():
    a = FailureInjector(seed=1, mtbf_steps=50, max_failures=3)
    b = FailureInjector(seed=1, mtbf_steps=50, max_failures=3)
    assert a.schedule == b.schedule
    fails = [s for s in range(1000) if a.should_fail(s)]
    assert len(fails) == 3


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0)
    for s in range(10):
        mon.observe(s, 0.1)
    assert mon.observe(10, 0.5) is True
    assert mon.observe(11, 0.1) is False
    assert len(mon.flagged) == 1


def test_checkpoint_cadence_advisor_tradeoff():
    out = advise_checkpoint_cadence(
        step_time_s=1.0, ckpt_write_s=5.0, restart_s=30.0,
        mtbf_steps=200.0, horizon_steps=500,
    )
    assert out["best_interval"] in out["total_time_s"]
    # sanity: checkpointing every 10 steps must beat every 500 under
    # frequent failures (write cost << expected lost work)
    t = out["total_time_s"]
    assert t[10] < t[500] or out["best_interval"] <= 100


# ---------------------------------------------------------------------------
# compressed collectives
# ---------------------------------------------------------------------------
def test_error_feedback_quantization_converges():
    from repro.parallel.collectives import ef_compress_grad, dequantize_int8

    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    err = jnp.zeros_like(g)
    # accumulated dequantised updates track the true gradient sum
    acc = jnp.zeros_like(g)
    for i in range(20):
        q, scale, err = ef_compress_grad(g, err)
        acc = acc + dequantize_int8(q, scale)
    rel = float(jnp.linalg.norm(acc - 20 * g) / jnp.linalg.norm(20 * g))
    assert rel < 0.01, rel
