"""The policy-search determinism / property wall.

Three layers:

* **Pure-helper properties** (hypothesis vs independent numpy oracles):
  ``scalarize`` / ``elite_select`` / ``halving_lane_counts`` and the
  Pareto edge cases (ties, NaN guards, single-candidate fronts) — the
  host-side math the CEM driver leans on.
* **Driver invariants**: elites ⊆ full-fidelity survivors, history
  shape, per-generation best score monotone non-increasing (the
  elitist-carryover guarantee).
* **End-to-end determinism**: the whole CEM run — engine evaluations
  included — is byte-identical across two same-seed runs and across
  ``shard=None`` vs ``shard="auto"`` (``SearchResult.to_json()`` is
  the canonical artifact the comparison diffs).

Plus the cross-engine parity leg: the Python reference engine accepts
``PolicyParams`` vectors through the same dynamic ``"policy"`` key and
matches the fused engine's states on them.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import SimParams, generate_workload, run
from repro.core.policy import DEFAULT_POINTS, PolicyParams
from repro.search import (
    PolicySpace,
    cem_search,
    dominates,
    elite_select,
    halving_lane_counts,
    pareto_front,
    scalarize,
    weakly_dominates,
)
from repro.search.grid import OBJECTIVES, evaluate_policies, scenario_factory

# ---------------------------------------------------------------------------
# Pareto edge cases
# ---------------------------------------------------------------------------
def test_pareto_ties_all_stay():
    objs = [[1.0, 2.0], [1.0, 2.0], [1.0, 2.0]]
    assert pareto_front(objs).tolist() == [0, 1, 2]
    assert not dominates(objs[0], objs[1])
    assert weakly_dominates(objs[0], objs[1])


def test_pareto_single_candidate():
    assert pareto_front([[5.0, 5.0, 5.0]]).tolist() == [0]
    assert pareto_front(np.empty((0, 3))).tolist() == []


def test_pareto_nan_guard():
    objs = [[1.0, np.nan], [2.0, 3.0], [np.nan, np.nan]]
    # NaN -> +inf: row 0 survives on its finite column, row 2 is
    # dominated by row 1 (finite everywhere)
    assert pareto_front(objs).tolist() == [0, 1]
    assert dominates(objs[1], objs[2])
    assert not dominates(objs[2], objs[1])
    assert not weakly_dominates(objs[2], objs[1])


def test_pareto_classic_front():
    objs = [[1.0, 4.0], [2.0, 3.0], [3.0, 3.0], [2.0, 5.0]]
    assert pareto_front(objs).tolist() == [0, 1]


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 12),
    seed=st.integers(0, 2**16),
)
def test_pareto_front_oracle(n, seed):
    """Front == brute-force 'no other row strictly dominates me'."""
    rng = np.random.default_rng(seed)
    objs = rng.integers(0, 4, size=(n, 3)).astype(float)  # ties likely
    objs[rng.random(size=n) < 0.15] = np.nan  # NaN rows
    front = set(pareto_front(objs).tolist())
    for i in range(n):
        dominated = any(
            dominates(objs[j], objs[i]) for j in range(n) if j != i
        )
        assert (i not in front) == dominated


# ---------------------------------------------------------------------------
# Pure CEM helpers vs numpy oracles
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 20), seed=st.integers(0, 2**16))
def test_scalarize_oracle(n, seed):
    rng = np.random.default_rng(seed)
    objs = rng.normal(size=(n, 4))
    objs[rng.random(size=(n, 4)) < 0.1] = np.nan
    w = rng.uniform(0.1, 2.0, size=4)
    got = scalarize(objs, w)
    clean = np.where(np.isnan(objs), np.inf, objs)
    want = clean @ w
    want = np.where(np.isfinite(want), want, np.inf)
    np.testing.assert_array_equal(got, want)
    assert (got[np.isnan(objs).any(axis=1)] == np.inf).all()


def test_scalarize_rejects_bad_weights():
    with pytest.raises(ValueError):
        scalarize(np.zeros((2, 4)), weights=(1.0, 2.0))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 30),
    k=st.integers(1, 30),
    seed=st.integers(0, 2**16),
)
def test_elite_select_oracle(n, k, seed):
    if k > n:
        k = n
    rng = np.random.default_rng(seed)
    scores = rng.integers(0, 5, size=n).astype(float)  # heavy ties
    idx = elite_select(scores, k)
    assert idx.shape == (k,)
    assert len(set(idx.tolist())) == k
    # oracle: stable sort by score keeps index order inside ties
    want = np.argsort(scores, kind="stable")[:k]
    np.testing.assert_array_equal(idx, want)


def test_elite_select_bounds():
    with pytest.raises(ValueError):
        elite_select(np.zeros(3), 0)
    with pytest.raises(ValueError):
        elite_select(np.zeros(3), 4)


@settings(max_examples=25, deadline=None)
@given(
    n_lanes=st.integers(1, 64),
    seed=st.integers(0, 2**16),
)
def test_halving_lane_counts_invariants(n_lanes, seed):
    rng = np.random.default_rng(seed)
    rungs = sorted(rng.uniform(0.05, 1.0, size=rng.integers(1, 4)))
    counts = halving_lane_counts(n_lanes, rungs)
    assert counts[-1] == n_lanes
    assert all(c >= 1 for c in counts)
    assert all(b > a for a, b in zip(counts, counts[1:]))  # strictly up


def test_halving_rejects_bad_fraction():
    with pytest.raises(ValueError):
        halving_lane_counts(8, (0.0, 1.0))
    with pytest.raises(ValueError):
        halving_lane_counts(8, (1.5,))


# ---------------------------------------------------------------------------
# PolicySpace
# ---------------------------------------------------------------------------
def test_space_normalize_roundtrip_on_defaults():
    sp = PolicySpace()
    for name, pt in DEFAULT_POINTS.items():
        vec = pt.to_vector()
        u = sp.normalize(vec)
        assert (u >= 0).all() and (u <= 1).all(), name
        np.testing.assert_allclose(
            sp.denormalize(u), vec, rtol=1e-6, atol=1e-6
        )


def test_space_rejects_bad_bounds():
    lo, hi = PolicySpace().lo, PolicySpace().hi
    with pytest.raises(ValueError):
        PolicySpace(lo=hi, hi=lo)  # hi < lo somewhere
    with pytest.raises(ValueError):
        PolicySpace(lo=lo[:3], hi=hi[:3])


# ---------------------------------------------------------------------------
# End-to-end CEM: determinism + invariants (the expensive leg)
# ---------------------------------------------------------------------------
def _arena() -> SimParams:
    return SimParams(
        duration=0.05,
        seed=0,
        scheduling_algo="policy",
        num_pools=2,
        waiting_ticks_mean=400.0,
        op_base_seconds_mean=0.004,
        max_pipelines=16,
        max_containers=32,
        total_cpus=4,
        total_ram_gb=8,
        cache_gb_per_pool=4.0,
        scan_ticks_per_gb=50.0,
        cold_start_ticks=40,
        container_warm_ticks=2_000,
        cloud_scaling=True,
    )


def _small_search(shard=None, seed=5):
    make = scenario_factory(["bursty"], _arena(), 2, seed=11)
    return cem_search(
        make,
        seed=seed,
        generations=2,
        population=10,
        rungs=(0.5, 1.0),
        shard=shard,
    )


@pytest.fixture(scope="module")
def small_search_result():
    return _small_search()


def test_cem_same_seed_bitwise(small_search_result):
    res2 = _small_search()
    assert small_search_result.to_json() == res2.to_json()


def test_cem_shard_invariant(small_search_result):
    res_sharded = _small_search(shard="auto")
    assert small_search_result.to_json() == res_sharded.to_json()


def test_cem_seed_actually_matters(small_search_result):
    assert small_search_result.to_json() != _small_search(seed=6).to_json()


def test_cem_history_invariants(small_search_result):
    res = small_search_result
    assert len(res.history) == 2
    pop = res.meta["population"]
    for g in res.history:
        n_cand = len(g["policies"])
        assert n_cand == pop
        survivors = g["survivors"]
        elites = g["elites"]
        assert set(elites) <= set(survivors) <= set(range(n_cand))
        # rung lane counts increase, last rung is the full batch
        lanes = [r["lanes"] for r in g["rungs"]]
        assert lanes == res.meta["lane_counts"]
        # the baseline block (indices < B) heads every generation
        B = len(res.baseline_names)
        assert g["origin"][:B] == [
            f"baseline:{n}" for n in res.baseline_names
        ]
    # elitist carryover: best full-fidelity score never worsens
    bests = [g["best_score"] for g in res.history]
    assert all(b >= a for a, b in zip(bests[1:], bests[:-1]))


def test_cem_front_is_nondominated(small_search_result):
    objs = small_search_result.pareto_objectives
    n = objs.shape[0]
    assert n >= 1
    for i in range(n):
        for j in range(n):
            if i != j:
                assert not dominates(objs[j], objs[i])


def test_evaluate_policies_shapes_and_guards():
    make = scenario_factory(["bursty"], _arena(), 2, seed=11)
    pols = np.stack(
        [
            DEFAULT_POINTS["priority"].to_vector(),
            DEFAULT_POINTS["sjf"].to_vector(),
        ]
    )
    res = evaluate_policies(make, pols)
    assert res["C"] == 2 and res["S"] == 2
    assert res["objectives"].shape == (2, len(OBJECTIVES))
    with pytest.raises(ValueError):
        evaluate_policies(make, pols, lane_limit=0)


# ---------------------------------------------------------------------------
# Cross-engine parity: the Python reference accepts PolicyParams too
# ---------------------------------------------------------------------------
PARITY_FIELDS = [
    "pipe_status",
    "pipe_completion",
    "pipe_fails",
    "pipe_preempts",
    "done_count",
    "failed_count",
    "oom_events",
    "preempt_events",
    "cache_hits",
    "cold_starts",
    "bytes_moved_gb",
]


@pytest.mark.parametrize(
    "point",
    [
        DEFAULT_POINTS["priority_pool"],
        DEFAULT_POINTS["sjf"],
        # an off-grid point no named scheduler maps to
        PolicyParams(
            chunk_frac=0.2,
            size_weight=0.5,
            prio_weight=1.0,
            preempt=1.0,
            multi_pool=1.0,
            cache_pin=1.0,
        ),
    ],
    ids=["priority_pool", "sjf", "searched"],
)
def test_python_engine_policy_parity(point):
    params = _arena().replace(max_pipelines=24)
    wl = generate_workload(params)
    wl = wl._replace(policy=point.to_vector())
    fused = run(params, workload=wl, engine="event")
    ref = run(params, workload=wl, engine="python")
    assert int(np.asarray(fused.state.done_count)) > 0  # non-trivial sim
    for f in PARITY_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(fused.state, f)),
            np.asarray(getattr(ref.state, f)),
            err_msg=f"policy-parity/{f}",
        )


def test_python_engine_policy_requires_vector():
    params = _arena()
    wl = generate_workload(params)
    with pytest.raises(ValueError, match="policy"):
        run(params, workload=wl, engine="python")
