"""Docs suite guards: markdown links resolve, docstring examples run.

The CI ``docs`` job runs the same two checks standalone (no test deps);
having them in tier-1 means a PR can't land a dangling docs link or a
rotten docstring example even when only the code side changed.
"""
import doctest
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_links  # noqa: E402  (tools/ is not a package)


def test_markdown_links_resolve():
    broken = check_links.main(REPO)
    assert not broken, f"dangling markdown links: {broken}"


def test_docs_directory_complete():
    """The documented docs map: every page README links into exists."""
    for page in ("architecture.md", "trace-format.md",
                 "scheduler-authoring.md", "scenarios.md",
                 "observability.md", "faults.md", "closed-loop.md",
                 "policy-search.md"):
        assert (REPO / "docs" / page).exists(), f"docs/{page} missing"


def _run_doctests(module):
    result = doctest.testmod(module, verbose=False)
    assert result.attempted > 0, f"{module.__name__}: no doctests collected"
    assert result.failed == 0, (
        f"{module.__name__}: {result.failed}/{result.attempted} "
        "doctest(s) failed"
    )


def test_workload_doctests():
    from repro.core import workload

    _run_doctests(workload)


def test_scenarios_doctests():
    from repro.core import scenarios
    from repro.core.scenarios import families

    _run_doctests(scenarios)
    _run_doctests(families)


def test_sweep_doctests():
    """The public fleet API examples (fleet_run, make_workload_batch,
    pad_lanes, bin_lanes_by_density) stay runnable."""
    from repro.core import sweep

    _run_doctests(sweep)


def test_admission_doctests():
    """The policy-registry and AdmissionView examples backing
    docs/closed-loop.md stay runnable."""
    from repro.core import admission

    _run_doctests(admission)


def test_telemetry_doctests():
    """The trace decode/export examples in docs/observability.md's
    backing modules stay runnable."""
    from repro.core.telemetry import decode, export

    _run_doctests(decode)
    _run_doctests(export)


def test_policy_doctests():
    """The PolicyParams space examples backing docs/policy-search.md
    stay runnable."""
    from repro.core import policy

    _run_doctests(policy)


def test_search_doctests():
    """The search-stack examples (Pareto dominance, PolicySpace
    sampling, halving rungs) stay runnable."""
    from repro.search import driver, pareto, space

    _run_doctests(pareto)
    _run_doctests(space)
    _run_doctests(driver)
