"""GPipe over a mesh axis: forward + gradient equivalence against the
sequential stack (subprocess with 8 fake devices)."""
import os
import subprocess
import sys
import textwrap

CODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import gpipe, bubble_fraction

    S, M, B, D = 4, 6, 2, 16
    mesh = jax.make_mesh((S, 2), ("pod", "data"))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, D, D)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))

    # sequential reference: stage 0..S-1 applied in order
    def seq(ws, x):
        for s in range(S):
            x = stage_fn(ws[s], x)
        return x

    ref = jax.vmap(lambda mb: seq(ws, mb))(x)
    pipe = gpipe(stage_fn, mesh, stage_axis="pod")
    with mesh:
        out = jax.jit(pipe)(ws, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

    # gradients flow through ppermute identically
    ct = jax.random.normal(jax.random.PRNGKey(2), ref.shape)
    g_ref = jax.grad(lambda w: jnp.vdot(jax.vmap(lambda mb: seq(w, mb))(x), ct))(ws)
    with mesh:
        g_pipe = jax.grad(lambda w: jnp.vdot(pipe(w, x), ct))(ws)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-5)

    assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
    print("PIPELINE_OK")
    """
)


def test_gpipe_matches_sequential():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", CODE],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPELINE_OK" in r.stdout
