"""The parameterised-scheduler identity wall.

Every named scheduler is now ONE POINT in the ``PolicyParams`` space
(``repro.core.policy.DEFAULT_POINTS``), executed by the unified
``_policy_family`` step. These tests pin the refactor's contract: at
its default point the family is **bitwise identical** to the legacy
decision loop it replaced — per named scheduler, with the data plane
on and off, on the single-sim path, the fused fleet path, and the
device-sharded fleet path — and the dynamic per-lane ``"policy"``
scheduler reproduces the same states from the point *vectors*.

The legacy loops stay registered as ``<name>_ref`` oracles purely so
this wall can keep comparing against the original code, not a
re-derivation of it.
"""
import jax
import numpy as np
import pytest

from repro.core import (
    SimParams,
    fleet_run,
    generate_workload,
    run,
)
from repro.core.policy import DEFAULT_POINTS, N_POLICY_PARAMS, PolicyParams
from repro.core.scheduler import get_policy_point, has_policy_point, policy_points
from repro.core.sweep import attach_policies, make_workload_batch

NAMED = sorted(DEFAULT_POINTS)  # the six built-in schedulers

DATA_PLANE = dict(
    cache_gb_per_pool=4.0,
    scan_ticks_per_gb=50.0,
    cold_start_ticks=40,
    container_warm_ticks=2_000,
)


def _params(algo: str, dp: bool, seed: int = 0) -> SimParams:
    return SimParams(
        duration=0.05,
        seed=seed,
        scheduling_algo=algo,
        num_pools=2,
        waiting_ticks_mean=400.0,
        op_base_seconds_mean=0.004,
        op_base_seconds_sigma=1.0,
        op_ram_gb_mean=2.0,
        max_pipelines=32,
        max_containers=32,
        **(DATA_PLANE if dp else {}),
    )


def _assert_states_bitwise(a, b, ctx=""):
    """EVERY array leaf equal — both sides run the same fused engine,
    so the family refactor owes exact, not approximate, agreement."""
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=ctx
        )


# ---------------------------------------------------------------------------
# Registry surface
# ---------------------------------------------------------------------------
def test_policy_points_registry():
    pts = policy_points()
    assert set(NAMED) <= set(pts)
    for name in NAMED:
        assert has_policy_point(name)
        pt = get_policy_point(name)
        assert isinstance(pt, PolicyParams)
        vec = pt.to_vector()
        assert vec.shape == (N_POLICY_PARAMS,)
        # vector-level round trip is bitwise (f32 quantisation applies
        # once: python floats like 0.1 land on the nearest f32)
        rt = PolicyParams.from_vector(vec).to_vector()
        np.testing.assert_array_equal(rt, vec, err_msg=name)
    assert not has_policy_point("policy")  # the dynamic family has no point


# ---------------------------------------------------------------------------
# Identity: named scheduler == legacy oracle, single-sim path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dp", [False, True], ids=["plain", "data_plane"])
@pytest.mark.parametrize("algo", NAMED)
def test_named_equals_legacy_run(algo, dp):
    params = _params(algo, dp)
    wl = generate_workload(params)
    got = run(params, workload=wl, engine="event")
    want = run(
        params.replace(scheduling_algo=f"{algo}_ref"),
        workload=wl,
        engine="event",
    )
    _assert_states_bitwise(got.state, want.state, ctx=f"run/{algo}/dp={dp}")


# ---------------------------------------------------------------------------
# Identity: fused fleet and device-sharded fleet paths
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shard", [None, "auto"], ids=["fused", "sharded"])
@pytest.mark.parametrize("dp", [False, True], ids=["plain", "data_plane"])
@pytest.mark.parametrize("algo", NAMED)
def test_named_equals_legacy_fleet(algo, dp, shard):
    params = _params(algo, dp)
    seeds = [0, 1, 2, 3]
    got = fleet_run(
        params, workloads=make_workload_batch(params, seeds), shard=shard
    )
    want = fleet_run(
        params.replace(scheduling_algo=f"{algo}_ref"),
        workloads=make_workload_batch(params, seeds),
        shard=shard,
    )
    _assert_states_bitwise(
        got, want, ctx=f"fleet/{algo}/dp={dp}/shard={shard}"
    )


# ---------------------------------------------------------------------------
# Identity: the DYNAMIC family fed the point vector == the named build
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shard", [None, "auto"], ids=["fused", "sharded"])
@pytest.mark.parametrize("algo", ["priority", "cache_aware", "sjf"])
def test_dynamic_vector_equals_named_fleet(algo, shard):
    params = _params(algo, dp=True)
    seeds = [0, 1, 2, 3]
    named = fleet_run(
        params, workloads=make_workload_batch(params, seeds), shard=shard
    )
    dyn_wls = attach_policies(
        make_workload_batch(params, seeds), DEFAULT_POINTS[algo]
    )
    dyn = fleet_run(
        params.replace(scheduling_algo="policy"),
        workloads=dyn_wls,
        shard=shard,
    )
    _assert_states_bitwise(dyn, named, ctx=f"dyn/{algo}/shard={shard}")


def test_mixed_policy_lanes_match_named_lanes():
    """A fleet mixing per-lane policy VECTORS (priority on lanes 0/2,
    sjf on lanes 1/3) reproduces each lane's named-scheduler state."""
    params = _params("priority", dp=True)
    seeds = [0, 1, 2, 3]
    pol = np.stack(
        [
            DEFAULT_POINTS[n].to_vector()
            for n in ("priority", "sjf", "priority", "sjf")
        ]
    )
    mixed = fleet_run(
        params.replace(scheduling_algo="policy"),
        workloads=attach_policies(make_workload_batch(params, seeds), pol),
    )
    for algo, lanes in (("priority", [0, 2]), ("sjf", [1, 3])):
        named = fleet_run(
            params.replace(scheduling_algo=algo),
            workloads=make_workload_batch(params, seeds),
        )
        for f in ("pipe_status", "pipe_completion", "done_count",
                  "preempt_events", "util_cpu_s", "cost_dollars"):
            np.testing.assert_array_equal(
                np.asarray(getattr(mixed, f))[lanes],
                np.asarray(getattr(named, f))[lanes],
                err_msg=f"mixed/{algo}/{f}",
            )


# ---------------------------------------------------------------------------
# Guards
# ---------------------------------------------------------------------------
def test_policy_key_requires_vectors():
    params = _params("priority", dp=False).replace(scheduling_algo="policy")
    with pytest.raises(ValueError, match="policy"):
        fleet_run(params, workloads=make_workload_batch(params, [0, 1]))


def test_attach_policies_validates_shape():
    params = _params("priority", dp=False)
    wls = make_workload_batch(params, [0, 1])
    with pytest.raises(ValueError):
        attach_policies(wls, np.zeros((3, N_POLICY_PARAMS), np.float32))
    with pytest.raises(ValueError):
        attach_policies(wls, np.zeros((2, N_POLICY_PARAMS + 1), np.float32))
