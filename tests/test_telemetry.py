"""Telemetry guarantees: tracing never changes the simulation.

Three properties the observability layer stands on:

* **Off is free**: with ``trace`` off (the default), every engine entry
  point produces final states SHA-256-identical to the pre-telemetry
  capture (``tests/captures/trace_off_digests.json``, recorded by
  ``tools/record_telemetry_capture.py`` before the recorder existed).
* **On is invisible**: with ``trace=True`` the *simulated* states hash
  to the same digests — the recorder only reads.
* **Overflow is truncation**: a full buffer drops new records, counts
  them in ``events_dropped``, and never corrupts what it already holds.

Plus the exporter round-trip: per-kind event counts survive the
Perfetto JSON and reconcile with ``summarize()``.
"""
import hashlib
import json
import pathlib

import numpy as np
import pytest

from repro.core import SimParams, fleet_run, run, to_perfetto_json
from repro.core.telemetry import EventKind

CAPTURE = pathlib.Path(__file__).parent / "captures" / "trace_off_digests.json"

ALL_SCHEDULERS = [
    "naive", "priority", "priority_pool", "sjf", "cache_aware",
    "locality_pool",
]
DATA_PLANE = dict(
    cache_gb_per_pool=4.0,
    scan_ticks_per_gb=50.0,
    cold_start_ticks=40,
    container_warm_ticks=2_000,
)
FLEET_SEEDS = [0, 1, 2, 3, 4, 5]


def _params(algo, dp, **extra):
    # mirrors tools/record_telemetry_capture.py:capture_params exactly —
    # the digests are only meaningful on the same simulation
    kw = dict(DATA_PLANE) if dp else {}
    kw.update(extra)
    return SimParams(
        duration=0.03,
        scheduling_algo=algo,
        num_pools=1 if algo == "naive" else 2,
        waiting_ticks_mean=300.0,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.0,
        max_pipelines=32,
        max_containers=32,
        **kw,
    )


def _digest(state, fields=None) -> str:
    # default to the PRE-FAULT field list: the faults-off captures were
    # recorded before the chaos layer appended its SimState fields, and
    # with every fault knob at its zero default the legacy fields are
    # bitwise unchanged (test_faults.py asserts the new fields are
    # deterministic zeros). The closed-loop layer (PR: admission
    # control) appended another field block under the same contract —
    # test_closed_loop.py pins its fields to deterministic zeros when
    # the loop is off — so each capture generation hashes the complement
    # of every LATER schema extension: the recorded hex strings stay
    # verbatim-valid forever. Chaos digests pass the chaos-era field
    # list explicitly (see test_states_match_chaos_capture).
    if fields is None:
        from repro.core.state import CHAOS_FIELDS, CLOSED_LOOP_FIELDS

        skip = set(CHAOS_FIELDS) | set(CLOSED_LOOP_FIELDS)
        fields = [f for f in state._fields if f not in skip]
    h = hashlib.sha256()
    for f in fields:
        a = np.ascontiguousarray(np.asarray(getattr(state, f)))
        h.update(f.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def _capture(key="digests"):
    import platform

    import jax

    if not CAPTURE.exists():
        pytest.skip("no trace-off capture recorded on this machine")
    payload = json.loads(CAPTURE.read_text())
    same_machine = (
        payload["backend"] == jax.default_backend()
        and payload["machine"] == platform.machine()
        and payload["n_devices"] == jax.local_device_count()
    )
    if not same_machine:
        pytest.skip(
            "capture was recorded on a different backend/machine "
            f"({payload['backend']}/{payload['machine']}); digests are "
            "only comparable on the recording machine class"
        )
    if key not in payload:
        pytest.skip(f"capture predates the {key!r} section")
    return payload[key]


def _run_config(algo, dp, path, trace):
    params = _params(algo, dp).replace(seed=7)
    kw = dict(trace=True, trace_capacity=2048) if trace else {}
    if path == "run":
        return run(params, **kw).state
    shard, bins = {
        "fleet": (None, True),
        "shard": ("auto", True),
        "shard_nobin": ("auto", False),
    }[path]
    out = fleet_run(params, FLEET_SEEDS, shard=shard, bin_lanes=bins, **kw)
    return out[0] if trace else out


@pytest.mark.parametrize("dp", [False, True], ids=["plain", "dataplane"])
@pytest.mark.parametrize("algo", ALL_SCHEDULERS)
@pytest.mark.parametrize(
    "path", ["run", "fleet", "shard", "shard_nobin"]
)
def test_states_match_pretelemetry_capture(algo, dp, path):
    """Trace OFF and trace ON both reproduce the pre-telemetry digests:
    the off path compiles to the same program as before this subsystem
    existed, and the on path's recorder is read-only."""
    digests = _capture()
    want = digests[f"{algo}/dp={int(dp)}/{path}"]
    assert _digest(_run_config(algo, dp, path, trace=False)) == want, (
        f"{algo}/dp={dp}/{path}: trace-off state diverged from the "
        "pre-telemetry capture"
    )
    assert _digest(_run_config(algo, dp, path, trace=True)) == want, (
        f"{algo}/dp={dp}/{path}: enabling the trace changed the simulation"
    )


# mirrors tools/record_telemetry_capture.py:CHAOS — the faults-ON grid
_CHAOS = dict(
    crash_mtbf_ticks=400.0,
    outage_mtbf_ticks=1_200.0,
    outage_duration_ticks=250.0,
    straggler_prob=0.1,
    timeout_ticks=40_000,
    max_retries=3,
    base_backoff_ticks=50,
)


@pytest.mark.parametrize("algo", ["naive", "priority_pool"])
@pytest.mark.parametrize("path", ["run", "fleet"])
def test_chaos_states_match_capture(algo, path):
    """Faults-ON runs are bitwise-reproducible: every SimState field
    (chaos counters included) hashes to the recorded capture, with and
    without the trace recorder."""
    digests = _capture("digests_chaos")
    want = digests[f"{algo}/chaos/{path}"]
    params = _params(algo, dp=True).replace(seed=7, **_CHAOS)

    def run_path(trace):
        kw = dict(trace=True, trace_capacity=2048) if trace else {}
        if path == "run":
            return run(params, **kw).state
        out = fleet_run(params, FLEET_SEEDS, shard=None, **kw)
        return out[0] if trace else out

    # the chaos captures hashed the full schema OF THEIR ERA — i.e.
    # everything up to and including CHAOS_FIELDS but none of the
    # closed-loop fields appended later
    from repro.core.state import CLOSED_LOOP_FIELDS

    for trace in (False, True):
        state = run_path(trace)
        chaos_era = [
            f for f in state._fields if f not in CLOSED_LOOP_FIELDS
        ]
        assert _digest(state, fields=chaos_era) == want, (
            f"{algo}/chaos/{path} trace={trace}: faults-on state diverged "
            "from the recorded capture"
        )


# mirrors tools/record_telemetry_capture.py:CLOSED_LOOP — admission
# control + closed-loop clients layered on top of the chaos grid
_CLOSED_LOOP = dict(
    client_max_inflight=6,
    client_think_ticks=30,
    client_max_retries=3,
    client_backoff_ticks=40,
    admission_policy="queue_threshold",
    admit_queue_limit=4,
    metastable_window_ticks=400,
)


@pytest.mark.parametrize("algo", ["naive", "priority_pool"])
@pytest.mark.parametrize("path", ["run", "fleet"])
def test_closed_loop_states_match_capture(algo, path):
    """Closed-loop-ON runs are bitwise-reproducible: every SimState
    field (admission/client counters included) hashes to the recorded
    capture, with and without the trace recorder."""
    digests = _capture("digests_closed_loop")
    want = digests[f"{algo}/closed_loop/{path}"]
    params = _params(algo, dp=True).replace(
        seed=7, **_CHAOS, **_CLOSED_LOOP
    )

    def run_path(trace):
        kw = dict(trace=True, trace_capacity=4096) if trace else {}
        if path == "run":
            return run(params, **kw).state
        out = fleet_run(params, FLEET_SEEDS, shard=None, **kw)
        return out[0] if trace else out

    for trace in (False, True):
        state = run_path(trace)
        assert _digest(state, fields=state._fields) == want, (
            f"{algo}/closed_loop/{path} trace={trace}: closed-loop state "
            "diverged from the recorded capture"
        )


# ---------------------------------------------------------------------------
# ring-buffer overflow
# ---------------------------------------------------------------------------
def _overflow_params():
    return _params("priority_pool", dp=True).replace(seed=11)


def test_overflow_truncates_never_corrupts():
    full = run(_overflow_params(), trace=True, trace_capacity=8192).trace
    assert full.events_dropped == 0, "reference trace must not overflow"
    assert full.n > 16, "config too quiet to exercise overflow"

    cap = 16
    small = run(_overflow_params(), trace=True, trace_capacity=cap).trace
    assert small.n == cap
    assert small.capacity == cap
    assert small.events_dropped == full.n - cap
    # earlier records are untouched: the truncated trace is exactly the
    # prefix of the full one
    np.testing.assert_array_equal(small.records, full.records[:cap])


def test_overflow_reported_in_summary():
    params = _overflow_params()
    res = run(params, trace=True, trace_capacity=16)
    s = res.summary()
    assert s["trace_enabled"] is True
    assert s["events_dropped"] == res.trace.events_dropped > 0
    # trace off -> no telemetry keys at all
    assert "trace_enabled" not in run(params).summary()


def test_records_are_time_ordered():
    trace = run(_overflow_params(), trace=True, trace_capacity=8192).trace
    assert (np.diff(trace.tick) >= 0).all()


# ---------------------------------------------------------------------------
# Perfetto export round-trip
# ---------------------------------------------------------------------------
def test_perfetto_json_reconciles_with_summarize():
    res = run(_overflow_params(), trace=True, trace_capacity=8192)
    assert res.trace.events_dropped == 0
    s = res.summary()

    doc = json.loads(to_perfetto_json(res.trace, res.params))
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    by_cat: dict[str, int] = {}
    for ev in doc["traceEvents"]:
        if ev.get("ph") in ("X", "i"):
            by_cat[ev.get("cat")] = by_cat.get(ev.get("cat"), 0) + 1

    # every countable kind survives the JSON and matches the simulation's
    # own counters
    assert by_cat.get("complete", 0) == s["done"]
    assert by_cat.get("preempt", 0) == s["preempt_events"]
    assert by_cat.get("cold_start", 0) == s["cold_starts"]
    assert by_cat.get("cache_hit", 0) == s["cache_hits"]
    assert by_cat.get("oom", 0) == s["oom_events"]
    # ...and agree with the decoded trace itself
    counts = res.trace.counts_by_kind()
    for kind in ("complete", "preempt", "cold_start", "cache_hit", "oom"):
        assert by_cat.get(kind, 0) == counts[kind]


def test_trace_counts_match_state_counters_across_schedulers():
    for algo in ("naive", "sjf", "cache_aware"):
        params = _params(algo, dp=True).replace(seed=3)
        res = run(params, trace=True, trace_capacity=8192)
        assert res.trace.events_dropped == 0
        s = res.summary()
        counts = res.trace.counts_by_kind()
        ctx = f"algo={algo}"
        assert counts["complete"] == s["done"], ctx
        assert counts["preempt"] == s["preempt_events"], ctx
        assert counts["oom"] == s["oom_events"], ctx
        assert counts["cold_start"] == s["cold_starts"], ctx
        assert counts["cache_hit"] == s["cache_hits"], ctx
        assert counts["reject"] == s["failed"], ctx


# ---------------------------------------------------------------------------
# decoded structure
# ---------------------------------------------------------------------------
def test_spans_and_series_wellformed():
    res = run(_overflow_params(), trace=True, trace_capacity=8192)
    trace = res.trace
    spans = trace.spans()
    assert spans, "expected at least one execution span"
    n_starts = trace.counts_by_kind()["start"]
    assert len(spans) == n_starts
    horizon = res.params.horizon_ticks
    for sp in spans:
        assert 0 <= sp.start_tick <= sp.end_tick <= horizon
        assert sp.end_kind in ("complete", "preempt", "oom", "open")
        assert sp.cpus > 0 and sp.ram_gb > 0

    ticks, qdepth, free_cpu, free_ram, cache_gb = trace.series()
    assert (qdepth >= 0).all()
    assert (free_cpu >= 0).all() and (free_ram >= 0).all()
    assert (cache_gb >= -1e-6).all()

    csv = trace.to_csv()
    lines = csv.splitlines()
    assert lines[0].startswith("tick,kind,")
    assert len(lines) == trace.n + 1


def test_sched_decision_provenance_recorded():
    trace = run(_overflow_params(), trace=True, trace_capacity=8192).trace
    decisions = trace.of_kind(EventKind.SCHED_DECISION)
    assert len(decisions) > 0
    from repro.core.telemetry.schema import COL_A, COL_PIPE

    chosen = decisions[:, COL_PIPE]
    runner = decisions[:, COL_A]
    assert (chosen >= 0).all()  # a decision record implies an assignment
    # the runner-up, when present, is never the chosen pipeline
    has_runner = runner >= 0
    assert (runner[has_runner] != chosen[has_runner]).all()


def test_python_engine_rejects_trace():
    with pytest.raises(ValueError, match="Python reference engine"):
        run(_params("priority", dp=False), engine="python", trace=True)


def test_bad_capacity_rejected():
    with pytest.raises(ValueError, match="positive"):
        run(_params("priority", dp=False), trace=True, trace_capacity=0)
