"""Lane-major core: equivalence, sharding and registry-unification tests.

One compiled engine (`engine._fleet_compiled`) advances everything:
`run()` is a fleet of one (squeezed), `fleet_run` is N lanes, and
`fleet_run(shard="auto")` splits the fleet axis across local devices
with shard_map (conftest forces 4 XLA host devices so the sharded path
runs on CPU CI). Everything here checks the headline safety property:
lanes are *bitwise* the same simulation however they are batched or
sharded.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SimParams,
    fleet_run,
    generate_workload,
    make_workload_batch,
    run,
)
from repro.core import engine as engine_mod
from repro.core import executor
from repro.core.scheduler import (
    get_fleet_vector_scheduler,
    get_vector_scheduler,
    get_vector_scheduler_init,
    register_fleet_vector_scheduler,
    register_vector_scheduler,
)
from repro.core.state import INF_TICK, init_state
from repro.core.sweep import _fleet_compiled, pad_lanes

DATA_PLANE = dict(
    cache_gb_per_pool=4.0,
    scan_ticks_per_gb=50.0,
    cold_start_ticks=40,
    container_warm_ticks=2_000,
)


def _params(algo, dp, duration=0.04, **extra):
    kw = dict(DATA_PLANE) if dp else {}
    kw.update(extra)
    return SimParams(
        duration=duration,
        scheduling_algo=algo,
        num_pools=1 if algo == "naive" else 2,
        waiting_ticks_mean=300.0,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.0,
        max_pipelines=32,
        max_containers=32,
        **kw,
    )


# cost_dollars is a f32 accumulator whose multiply-add chain XLA codegens
# differently at different batch widths (~1 ULP); comparisons across
# DIFFERENT fleet sizes exempt it. Same-width comparisons (run vs
# fleet-of-one, sharded vs unsharded) stay strict on every field.
BITWISE_EXEMPT = {"cost_dollars"}


def _assert_lane_equal(states, lane, ref_state, ctx="", exempt=()):
    for f in states._fields:
        a = np.asarray(getattr(states, f))[lane]
        b = np.asarray(getattr(ref_state, f))
        if f in exempt:
            np.testing.assert_allclose(
                a, b, rtol=1e-6, atol=1e-9, err_msg=f"{ctx}: field {f}"
            )
        else:
            np.testing.assert_array_equal(a, b, err_msg=f"{ctx}: field {f}")


def _assert_states_equal(a, b, ctx=""):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)),
            np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: field {f}",
        )


ALL_SCHEDULERS = [
    "naive", "priority", "priority_pool", "sjf", "cache_aware",
    "locality_pool",
]


# ---------------------------------------------------------------------------
# run() is a fleet of one, and fleet lanes are independent simulations.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dp", [False, True], ids=["plain", "data_plane"])
@pytest.mark.parametrize("algo", ALL_SCHEDULERS)
def test_run_equals_fleet_lane(algo, dp):
    """run(seed) == fleet_run([seed])[0] bitwise, and every lane of a
    wider fleet equals the same workload run alone."""
    params = _params(algo, dp).replace(seed=11)
    single = run(params, engine="event")
    lane0 = fleet_run(params, [11])
    _assert_lane_equal(lane0, 0, single.state, ctx=f"{algo}/dp={dp}/run-vs-1")

    seeds = [0, 1, 2]
    states = fleet_run(params, seeds)
    wls = make_workload_batch(params, seeds)
    for i, s in enumerate(seeds):
        wl = jax.tree.map(lambda x: x[i], wls)
        ref = run(params, workload=wl, engine="event")
        _assert_lane_equal(
            states, i, ref.state, ctx=f"{algo}/dp={dp}/s{s}",
            exempt=BITWISE_EXEMPT,  # cross-batch-width comparison
        )


# ---------------------------------------------------------------------------
# Device sharding: shard="auto" on 4 forced host devices, lane-for-lane.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dp", [False, True], ids=["plain", "data_plane"])
@pytest.mark.parametrize("algo", ALL_SCHEDULERS)
def test_sharded_fleet_matches_unsharded(algo, dp):
    assert jax.local_device_count() >= 4, "conftest forces 4 host devices"
    params = _params(algo, dp)
    seeds = list(range(6))  # 6 lanes on 4 devices -> exercises lane padding
    a = fleet_run(params, seeds, shard=None)
    b = fleet_run(params, seeds, shard="auto")
    _assert_states_equal(a, b, ctx=f"{algo}/dp={dp}/shard")


def test_shard_validates_device_count():
    with pytest.raises(ValueError, match="device"):
        fleet_run(_params("priority", False), [0, 1],
                  shard=jax.local_device_count() + 1)


def test_pad_lanes_shapes_and_inertness():
    params = _params("priority", False)
    wls = make_workload_batch(params, [0, 1, 2])
    padded = pad_lanes(wls, 8)
    assert padded.arrival.shape[0] == 8
    # padding lanes never receive an arrival
    assert (np.asarray(padded.arrival)[3:] == INF_TICK).all()
    # original lanes are untouched (faults is None when the chaos layer
    # is off — nothing to pad there)
    for f in wls._fields:
        v = getattr(wls, f)
        if v is None:
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(padded, f))[:3], np.asarray(v)
        )


def test_finished_lane_untouched():
    """A lane whose workload exhausts early must come out of a mixed
    fleet bit-identical to running it alone — finished lanes pass
    through the shared loop untouched."""
    params = _params("priority", dp=False, duration=0.05)
    wls = make_workload_batch(params, [7, 8])
    # lane 0: single early arrival, everything else never arrives
    sparse_arrival = (
        jnp.full_like(wls.arrival[0], INF_TICK).at[0].set(wls.arrival[0][0])
    )
    wls = wls._replace(arrival=wls.arrival.at[0].set(sparse_arrival))

    # slice lane 0 out BEFORE the compiled call: _fleet_compiled donates
    # the workload batch, so wls is consumed by it
    wl0 = jax.tree.map(lambda x: x[0], wls)
    with engine_mod._quiet_partial_donation():
        states, _ = _fleet_compiled(params, wls, "priority")
    ref = run(params, workload=wl0, engine="event")
    _assert_lane_equal(
        states, 0, ref.state, ctx="sparse lane", exempt=BITWISE_EXEMPT
    )
    # sanity: the busy lane really does run longer than the sparse one
    assert int(ref.state.done_count) <= 1
    assert int(states.done_count[1]) > int(states.done_count[0])


# ---------------------------------------------------------------------------
# Next-event oracle: the registers the unified engine navigates by equal
# the recompute-from-scratch `_next_event` at every event of the actual
# lane step.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "algo,dp", [("priority", False), ("priority_pool", True)]
)
def test_next_event_registers_match_full_recompute(algo, dp):
    from repro.kernels.sim_tick import fleet_tick

    params = _params(algo, dp, duration=0.03)
    wl = generate_workload(params)
    scheduler_fn = get_vector_scheduler(algo, early_exit=True)
    ss = get_vector_scheduler_init(algo)(params)
    arr_sorted = engine_mod._sorted_arrivals(wl.arrival)
    horizon = jnp.int32(params.horizon_ticks)

    @jax.jit
    def step(state, ss):
        tick = state.tick
        ph = fleet_tick(
            state.ctr_status[None], state.ctr_end[None], state.ctr_oom[None],
            state.ctr_cpus[None], state.ctr_ram[None], state.ctr_pool[None],
            state.pipe_status[None], wl.arrival[None],
            state.pipe_release[None], tick[None],
            num_pools=params.num_pools,
        )
        ph_l = jax.tree.map(lambda x: x[0], ph)
        # recompute the oracle on the exact state the engine's register
        # read sees (post fused phase 1 + decision application)
        st1 = executor.apply_fused_phase1(state, wl, tick, params, ph_l)
        ss1, dec = scheduler_fn(ss, st1, wl, params)
        st2 = executor.apply_decision(
            st1, wl, dec, tick, params, early_exit=True
        )
        acted = (
            jnp.any(dec.suspend)
            | jnp.any(dec.reject)
            | jnp.any(dec.assign_pipe >= 0)
        )
        nxt_full = engine_mod._next_event(st2, wl, tick, acted)
        new_state, new_ss = engine_mod.lane_event_step(
            params, horizon, scheduler_fn, state, ss, wl, arr_sorted, tick,
            ph_l,
        )
        return new_state, new_ss, nxt_full

    state = init_state(params)
    n_events = 0
    while int(state.tick) < params.horizon_ticks:
        state, ss, nxt_full = step(state, ss)
        # the engine's register-based jump == the oracle, clipped to horizon
        assert int(state.tick) == min(int(nxt_full), params.horizon_ticks), (
            f"event {n_events}: engine jumped to {int(state.tick)}, "
            f"oracle says {int(nxt_full)}"
        )
        n_events += 1
    assert n_events > 10  # the run actually exercised the loop


# ---------------------------------------------------------------------------
# Registry unification + deprecation shims.
# ---------------------------------------------------------------------------
def test_unified_registry_families_and_plain_schedulers():
    # families build distinct early-exit / static-loop variants...
    assert get_vector_scheduler("priority", early_exit=True) is not (
        get_vector_scheduler("priority", early_exit=False)
    )
    # ...cached per variant
    assert get_vector_scheduler("priority", early_exit=True) is (
        get_vector_scheduler("priority", early_exit=True)
    )
    # plain registrations (the custom-scheduler path) serve both variants
    from repro.core.scheduler import naive_scheduler

    key = "_test_only_custom_sched"
    register_vector_scheduler(key)(naive_scheduler)
    assert get_vector_scheduler(key, early_exit=True) is naive_scheduler
    assert get_vector_scheduler(key, early_exit=False) is naive_scheduler


def test_fleet_registry_shims_warn_and_alias():
    with pytest.warns(DeprecationWarning):
        fn = get_fleet_vector_scheduler("priority")
    assert fn is get_vector_scheduler("priority", early_exit=True)

    from repro.core.scheduler import naive_scheduler

    key = "_test_only_fleet_shim"
    with pytest.warns(DeprecationWarning):
        register_fleet_vector_scheduler(key)(naive_scheduler)
    assert get_vector_scheduler(key, early_exit=True) is naive_scheduler


def test_fleet_shim_survives_plain_reregistration():
    """Under the old dual registries, registering the plain variant
    never clobbered a fleet-specialised one — order must stay
    irrelevant through the deprecation shim."""
    from repro.core.scheduler import naive_scheduler

    def plain(ss, sim, wl, params):  # pragma: no cover - never invoked
        return naive_scheduler(ss, sim, wl, params)

    key = "_test_only_shim_order"
    with pytest.warns(DeprecationWarning):
        register_fleet_vector_scheduler(key)(naive_scheduler)
    register_vector_scheduler(key)(plain)  # PR-2-era code, any order
    assert get_vector_scheduler(key, early_exit=True) is naive_scheduler
    assert get_vector_scheduler(key, early_exit=False) is plain


def test_fleet_engine_kwarg_deprecated():
    params = _params("priority", False, duration=0.01)
    with pytest.warns(DeprecationWarning):
        fleet_run(params, [0], fleet_engine="fused")
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="removed"):
            fleet_run(params, [0], fleet_engine="vmap")


def test_tick_engine_removed():
    with pytest.raises(ValueError, match="lane-major"):
        run(_params("priority", False), engine="tick")


def test_custom_scheduler_runs_in_fleet():
    """A plain-registered custom scheduler runs through the unified core
    (single and fleet) without a fleet-specific registration."""
    from repro.core.scheduler import naive_scheduler

    key = "_test_only_fleet_custom"
    register_vector_scheduler(key)(naive_scheduler)
    params = _params("naive", False, duration=0.02).replace(
        scheduling_algo=key
    )
    ref = _params("naive", False, duration=0.02)
    a = fleet_run(params, [0, 1])
    b = fleet_run(ref, [0, 1])
    _assert_states_equal(a, b, ctx="custom-vs-naive")


def test_make_workload_batch_matches_host_loop():
    """vmapped PRNGKey construction == the old per-seed host loop."""
    params = _params("priority", dp=False)
    seeds = [0, 5, 123, 2**31 - 1]
    batch = make_workload_batch(params, seeds)
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    ref = jax.vmap(lambda k: generate_workload(params, k))(keys)
    for f in batch._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(batch, f)),
            np.asarray(getattr(ref, f)),
            err_msg=f"field {f}",
        )


def test_no_stray_deprecation_warnings_on_default_paths():
    """The default entry points must not trip the deprecation shims."""
    params = _params("priority", False, duration=0.01)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        run(params, engine="event")
        fleet_run(params, [0, 1], shard="auto")
    ours = [
        w for w in rec
        if issubclass(w.category, DeprecationWarning)
        and ("fleet_vector_scheduler" in str(w.message)
             or "fleet_engine" in str(w.message))
    ]
    assert not ours, [str(w.message) for w in ours]
