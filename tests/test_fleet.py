"""Fleet-native event engine: equivalence + next-event register tests.

The fleet engine (`engine._run_fleet_event_engine`, the default
`fleet_run` path) batches the event loop by hand: shared masked
while_loop, fused phase-1 pass (`kernels.sim_tick.fleet_tick`),
early-exit scheduler/apply variants and incremental next-event
registers. Everything here checks the headline safety property: each
lane is *bitwise* the same simulation as `run(..., engine="event")`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SimParams,
    fleet_run,
    generate_workload,
    make_workload_batch,
    run,
)
from repro.core import engine as engine_mod
from repro.core import executor
from repro.core.scheduler import (
    get_fleet_vector_scheduler,
    get_vector_scheduler,
    get_vector_scheduler_init,
)
from repro.core.state import INF_TICK
from repro.core.sweep import _fleet_compiled

DATA_PLANE = dict(
    cache_gb_per_pool=4.0,
    scan_ticks_per_gb=50.0,
    cold_start_ticks=40,
    container_warm_ticks=2_000,
)

# cost_dollars is a f32 sum whose reduction the XLA batcher may
# reassociate (~1 ULP); every other field must agree bit-for-bit.
BITWISE_EXEMPT = {"cost_dollars"}


def _params(algo, dp, duration=0.04, **extra):
    kw = dict(DATA_PLANE) if dp else {}
    kw.update(extra)
    return SimParams(
        duration=duration,
        scheduling_algo=algo,
        num_pools=1 if algo == "naive" else 2,
        waiting_ticks_mean=300.0,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.0,
        max_pipelines=32,
        max_containers=32,
        **kw,
    )


def _assert_lane_equal(states, lane, ref_state, ctx=""):
    for f in states._fields:
        a = np.asarray(getattr(states, f))[lane]
        b = np.asarray(getattr(ref_state, f))
        if f in BITWISE_EXEMPT:
            np.testing.assert_allclose(
                a, b, rtol=1e-6, atol=1e-9, err_msg=f"{ctx}: field {f}"
            )
        else:
            np.testing.assert_array_equal(a, b, err_msg=f"{ctx}: field {f}")


ALL_SCHEDULERS = [
    "naive", "priority", "priority_pool", "sjf", "cache_aware",
    "locality_pool",
]


@pytest.mark.parametrize("dp", [False, True], ids=["plain", "data_plane"])
@pytest.mark.parametrize("algo", ALL_SCHEDULERS)
def test_fleet_fused_bitwise_equals_per_seed(algo, dp):
    """Every fleet lane == the same seed run alone in the event engine."""
    params = _params(algo, dp)
    seeds = [0, 1, 2]
    states = fleet_run(params, seeds, fleet_engine="fused")
    wls = make_workload_batch(params, seeds)
    for i, s in enumerate(seeds):
        wl = jax.tree.map(lambda x: x[i], wls)
        ref = run(params, workload=wl, engine="event")
        _assert_lane_equal(states, i, ref.state, ctx=f"{algo}/dp={dp}/s{s}")


@pytest.mark.parametrize("algo", ["priority", "cache_aware"])
def test_fleet_fused_bitwise_equals_legacy_vmap(algo):
    """Fused vs legacy vmap path: all fields bitwise, no exemptions."""
    params = _params(algo, dp=True)
    seeds = [0, 1, 2, 3]
    a = fleet_run(params, seeds, fleet_engine="fused")
    b = fleet_run(params, seeds, fleet_engine="vmap")
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)),
            np.asarray(getattr(b, f)),
            err_msg=f"{algo}: field {f}",
        )


def test_finished_lane_untouched():
    """A lane whose workload exhausts early must come out of a mixed
    fleet bit-identical to running it alone — finished lanes pass
    through the shared loop untouched."""
    params = _params("priority", dp=False, duration=0.05)
    wls = make_workload_batch(params, [7, 8])
    # lane 0: single early arrival, everything else never arrives
    sparse_arrival = (
        jnp.full_like(wls.arrival[0], INF_TICK).at[0].set(wls.arrival[0][0])
    )
    wls = wls._replace(arrival=wls.arrival.at[0].set(sparse_arrival))

    states = _fleet_compiled(params, wls, "priority", "event", "fused")
    wl0 = jax.tree.map(lambda x: x[0], wls)
    ref = run(params, workload=wl0, engine="event")
    _assert_lane_equal(states, 0, ref.state, ctx="sparse lane")
    # sanity: the busy lane really does run longer than the sparse one
    assert int(ref.state.done_count) <= 1
    assert int(states.done_count[1]) > int(states.done_count[0])


@pytest.mark.parametrize(
    "algo,dp", [("priority", False), ("priority_pool", True)]
)
def test_next_event_registers_match_full_recompute(algo, dp):
    """At every event, the register-based next-event (binary-searched
    arrivals + executor-maintained nxt_retire/nxt_release) equals the
    recomputed-from-scratch `_next_event` table reduction."""
    params = _params(algo, dp, duration=0.03)
    wl = generate_workload(params)
    scheduler_fn = get_vector_scheduler(algo)
    ss = get_vector_scheduler_init(algo)(params)
    arr_sorted = engine_mod._sorted_arrivals(wl.arrival)
    horizon = jnp.int32(params.horizon_ticks)

    @jax.jit
    def step(state, ss):
        tick = state.tick
        state, ss, acted = engine_mod._tick_body(
            state, ss, wl, params, scheduler_fn, tick
        )
        nxt_full = engine_mod._next_event(state, wl, tick, acted)
        nxt_reg, cursor = engine_mod._next_event_registers(
            state, arr_sorted, tick, acted
        )
        nxt = jnp.minimum(nxt_full, horizon)
        state = executor.integrate(state, tick, nxt, params, exact_buckets=True)
        state = state._replace(tick=nxt, nxt_arrival_cursor=cursor)
        return state, ss, nxt_full, nxt_reg

    from repro.core.state import init_state

    state = init_state(params)
    n_events = 0
    while int(state.tick) < params.horizon_ticks:
        state, ss, nxt_full, nxt_reg = step(state, ss)
        assert int(nxt_full) == int(nxt_reg), (
            f"event {n_events} @tick {int(state.tick)}: "
            f"full {int(nxt_full)} != registers {int(nxt_reg)}"
        )
        n_events += 1
    assert n_events > 10  # the run actually exercised the loop


def test_fleet_scheduler_fallback_for_custom_schedulers():
    """Schedulers registered only in the plain registry (i.e. custom
    user schedulers) fall back to that variant in fleets."""
    from repro.core.scheduler import (
        naive_scheduler,
        register_vector_scheduler,
    )

    key = "_test_only_custom_sched"
    register_vector_scheduler(key)(naive_scheduler)
    assert get_fleet_vector_scheduler(key) is naive_scheduler
    # registered specialisations are distinct callables
    assert get_fleet_vector_scheduler("priority") is not (
        get_vector_scheduler("priority")
    )


def test_make_workload_batch_matches_host_loop():
    """vmapped PRNGKey construction == the old per-seed host loop."""
    params = _params("priority", dp=False)
    seeds = [0, 5, 123, 2**31 - 1]
    batch = make_workload_batch(params, seeds)
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    ref = jax.vmap(lambda k: generate_workload(params, k))(keys)
    for f in batch._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(batch, f)),
            np.asarray(getattr(ref, f)),
            err_msg=f"field {f}",
        )
