"""Overload-layer guarantees (docs/closed-loop.md).

Four properties the closed-loop/admission subsystem stands on:

* **Off is free**: with every client and admission knob at its zero
  default, the closed-loop fields of the final state are deterministic
  init values — combined with the pinned-field digests of
  tests/test_telemetry.py, a default run is bitwise what it was before
  the layer existed.
* **One semantics**: the fused lane-major engine and the Python
  reference agree exactly on every offer/admit/shed/defer counter,
  client attempt, and final pipeline status under every built-in
  admission policy, with and without chaos underneath.
* **The client retry contract**: rejected offers return at
  ``tick + client_backoff_ticks * 2**attempt`` (capped) exactly, and an
  exhausted budget sheds the pipeline as FAILED at the reject tick.
* **Honest accounting**: ``admit_all`` with no rejects can never show
  retry amplification; empty priority buckets report NaN, never a
  crash; Jain's index obeys its textbook extremes.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (
    SimParams,
    fleet_run,
    fleet_summary,
    generate_workload,
    run,
)
from repro.core.metrics import _jain
from repro.core.state import CLOSED_LOOP_FIELDS, INF_TICK
from repro.core.telemetry.schema import (
    COL_A,
    COL_B,
    COL_PIPE,
    COL_TICK,
    EventKind,
)

CLOSED_LOOP = dict(
    client_max_inflight=6,
    client_think_ticks=30,
    client_max_retries=3,
    client_backoff_ticks=40,
    admission_policy="queue_threshold",
    admit_queue_limit=4,
    metastable_window_ticks=400,
)


def _params(seed=0, algo="priority", duration=0.04, **extra):
    kw = dict(
        duration=duration,
        seed=seed,
        scheduling_algo=algo,
        num_pools=1 if algo == "naive" else 2,
        waiting_ticks_mean=400.0,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.0,
        max_pipelines=32,
        max_containers=32,
    )
    kw.update(extra)
    return SimParams(**kw)


CL_COMPARE = list(CLOSED_LOOP_FIELDS) + [
    "pipe_status",
    "pipe_completion",
    "done_count",
    "failed_count",
]


def _assert_closed_loop_equal(a, b, ctx=""):
    for f in CL_COMPARE:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)),
            np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: field {f}",
        )


# ---------------------------------------------------------------------------
# Off is free.
# ---------------------------------------------------------------------------
def test_closed_loop_off_state_is_pristine():
    """A default run leaves every closed-loop field at its init value —
    the structural half of the pinned-digest guarantee (the digest
    families hash the complement of CLOSED_LOOP_FIELDS, so these fields
    being inert is what keeps the PR-6/7 captures verbatim-valid)."""
    res = run(_params())
    state = res.state
    inf_fields = {"codel_above_since", "last_fault_tick", "drain_tick"}
    for f in CLOSED_LOOP_FIELDS:
        a = np.asarray(getattr(state, f))
        if f in inf_fields:
            assert (a == INF_TICK).all(), f
        elif f == "prefault_backlog":
            assert (a == -1).all(), f
        else:
            assert not a.any(), f"{f} changed in a closed-loop-off run"
    s = res.summary()
    assert s["offered"] == s["shed"] == s["client_retries"] == 0
    assert np.isnan(s["retry_amplification"])
    assert np.isnan(s["time_to_drain_s"])
    assert s["metastable"] is False


# ---------------------------------------------------------------------------
# One semantics: fused == Python reference under every policy.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "knobs",
    [
        dict(client_max_inflight=4, client_think_ticks=50),
        dict(admission_policy="queue_threshold", admit_queue_limit=3,
             client_max_retries=3, client_backoff_ticks=40),
        dict(admission_policy="token_bucket", admit_rate_per_s=2_000.0,
             admit_burst=4.0),
        dict(admission_policy="codel", codel_target_ticks=300,
             codel_interval_ticks=150, client_max_retries=2,
             client_backoff_ticks=30),
        dict(outage_mtbf_ticks=1_200.0, outage_duration_ticks=300.0,
             max_retries=3, base_backoff_ticks=40, **CLOSED_LOOP),
    ],
    ids=["client_gate", "queue_threshold", "token_bucket", "codel",
         "all_plus_chaos"],
)
@pytest.mark.parametrize("algo", ["priority", "naive"])
def test_event_equals_python_closed_loop(knobs, algo):
    params = _params(seed=5, algo=algo, **knobs)
    wl = generate_workload(params)
    r_event = run(params, workload=wl, engine="event")
    r_python = run(params, workload=wl, engine="python")
    assert int(r_event.state.offered_total) > 0, "config too quiet"
    _assert_closed_loop_equal(
        r_event.state, r_python.state, ctx=f"{algo}/{sorted(knobs)}"
    )


@settings(
    max_examples=10,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    seed=st.integers(0, 2**16),
    algo=st.sampled_from(["naive", "priority", "priority_pool"]),
    policy=st.sampled_from(
        ["admit_all", "queue_threshold", "token_bucket", "codel"]
    ),
    inflight=st.sampled_from([0, 4]),
    retries=st.integers(0, 3),
)
def test_event_equals_python_closed_loop_property(
    seed, algo, policy, inflight, retries
):
    params = _params(
        seed=seed,
        algo=algo,
        admission_policy=policy,
        admit_queue_limit=3 if policy == "queue_threshold" else 0,
        admit_rate_per_s=1_500.0 if policy == "token_bucket" else 0.0,
        admit_burst=3.0 if policy == "token_bucket" else 0.0,
        codel_target_ticks=250 if policy == "codel" else 0,
        codel_interval_ticks=125 if policy == "codel" else 0,
        client_max_inflight=inflight,
        client_think_ticks=40 if inflight else 0,
        client_max_retries=retries,
        client_backoff_ticks=35 if retries else 0,
    )
    wl = generate_workload(params)
    r_event = run(params, workload=wl, engine="event")
    r_python = run(params, workload=wl, engine="python")
    _assert_closed_loop_equal(
        r_event.state, r_python.state,
        ctx=f"{algo}/{policy}/s{seed}/i{inflight}/r{retries}",
    )


# ---------------------------------------------------------------------------
# The client retry contract.
# ---------------------------------------------------------------------------
def test_client_backoff_schedule_exact():
    """Every CLIENT_RETRY record's release tick obeys
    tick + max(min(client_backoff_ticks * 2**(attempt-1), 2**30), 1) —
    the recorded attempt is the post-increment count."""
    params = _params(
        seed=11,
        admission_policy="queue_threshold",
        admit_queue_limit=2,
        client_max_retries=4,
        client_backoff_ticks=37,
    )
    res = run(params, trace=True, trace_capacity=8192)
    assert res.trace.events_dropped == 0
    retries = res.trace.of_kind(EventKind.CLIENT_RETRY)
    assert len(retries) > 0, "config too quiet: no client retries recorded"
    base = params.client_backoff_ticks
    for row in retries:
        tick, attempt, release = (
            int(row[COL_TICK]), int(row[COL_A]), int(row[COL_B])
        )
        assert attempt >= 1
        want = tick + max(min(base * 2 ** (attempt - 1), 2**30), 1)
        assert release == want, (
            f"CLIENT_RETRY at {tick}, attempt {attempt}: "
            f"release {release} != {want}"
        )
    # per-pipe attempts are strictly increasing (re-offer ordering)
    by_pipe = {}
    for row in retries:
        by_pipe.setdefault(int(row[COL_PIPE]), []).append(int(row[COL_A]))
    for pipe, attempts in by_pipe.items():
        assert attempts == sorted(attempts), f"pipe {pipe}: {attempts}"
        assert len(set(attempts)) == len(attempts), f"pipe {pipe}: {attempts}"


def test_client_retry_budget_contract():
    """With a client retry budget, rejects are re-offered (retry events,
    amplification > 1); with client_max_retries=0 every reject is a
    permanent shed — the pipeline FAILS without ever starting."""
    gate = dict(admission_policy="queue_threshold", admit_queue_limit=2)
    lenient = run(
        _params(seed=4, client_max_retries=5, client_backoff_ticks=40, **gate)
    ).summary()
    strict_res = run(_params(seed=4, client_max_retries=0, **gate))
    strict = strict_res.summary()
    assert lenient["shed"] > 0, "config too quiet: no rejects"
    assert lenient["client_retries"] > 0
    assert lenient["retry_amplification"] > 1.0
    assert strict["client_retries"] == 0
    assert strict["retry_amplification"] == 1.0
    assert strict["failed"] >= strict["shed"] > 0
    # a shed pipeline never started: completion stamped, first_start INF
    st = strict_res.state
    shed_mask = (
        (np.asarray(st.pipe_status) == 6)  # FAILED
        & (np.asarray(st.pipe_first_start) == INF_TICK)
    )
    assert shed_mask.sum() == strict["shed"]
    assert (np.asarray(st.pipe_completion)[shed_mask] < INF_TICK).all()


def test_admit_all_never_amplifies():
    """The control-arm invariant of the overload comparisons: without an
    admission gate there are no rejects, so no client re-offers — the
    concurrency gate alone (deferred arrivals were never offered) keeps
    retry_amplification at exactly 1.0."""
    s = run(
        _params(seed=2, waiting_ticks_mean=100.0,
                client_max_inflight=4, client_think_ticks=50)
    ).summary()
    assert s["offered"] > 0
    assert s["deferred"] > 0, "config too quiet: gate never engaged"
    assert s["shed"] == 0
    assert s["client_retries"] == 0
    assert s["retry_amplification"] == 1.0


# ---------------------------------------------------------------------------
# Drain / metastability detection.
# ---------------------------------------------------------------------------
def test_drain_and_metastable_definitions_cohere():
    """With window 0, metastable is exactly "faulted and never drained":
    the flag and time_to_drain_s can never disagree."""
    params = _params(
        seed=7,
        outage_mtbf_ticks=1_500.0,
        outage_duration_ticks=300.0,
        max_retries=3,
        base_backoff_ticks=40,
        **{**CLOSED_LOOP, "metastable_window_ticks": 0},
    )
    s = run(params).summary()
    assert s["faults_injected"] > 0, "config too quiet: no faults"
    assert s["metastable"] == bool(np.isnan(s["time_to_drain_s"]))
    if not np.isnan(s["time_to_drain_s"]):
        assert s["time_to_drain_s"] >= 0.0


# ---------------------------------------------------------------------------
# Honest accounting: empty buckets, fairness extremes, fleet means.
# ---------------------------------------------------------------------------
def test_empty_priority_buckets_report_nan():
    """A run where nothing finishes (and nothing is offered) must
    summarise cleanly: every per-priority latency/admission statistic is
    NaN, never an empty-percentile crash or divide-by-zero."""
    s = run(
        _params(duration=0.002, op_base_seconds_mean=0.05)
    ).summary()
    assert s["done"] == 0
    assert np.isnan(s["p99_latency_s"])
    assert np.isnan(s["fairness_jain_latency"])
    assert np.isnan(s["fairness_jain_admission"])
    for name, blk in s["per_priority"].items():
        assert blk["done"] == 0, name
        assert np.isnan(blk["mean_latency_s"]), name
        assert np.isnan(blk["p99_latency_s"]), name
        assert np.isnan(blk["admitted_fraction"]), name


def test_jain_index_extremes():
    assert _jain(np.full(8, 3.7)) == pytest.approx(1.0)
    assert _jain(np.array([1.0, 0.0, 0.0, 0.0])) == pytest.approx(0.25)
    assert np.isnan(_jain(np.array([])))
    assert np.isnan(_jain(np.zeros(4)))
    # non-finite entries are dropped, not propagated
    assert _jain(np.array([2.0, 2.0, np.nan, np.inf])) == pytest.approx(1.0)


def test_fleet_summary_carries_overload_means():
    params = _params(seed=1, **CLOSED_LOOP)
    states = fleet_run(params, seeds=[0, 1, 2, 3])
    fs = fleet_summary(states, params)
    assert fs["offered_mean"] > 0
    assert 0.0 < fs["admitted_fraction_mean"] <= 1.0
    assert 0.0 < fs["fairness_jain_done"] <= 1.0
    for k in ("shed_mean", "deferred_mean", "client_retries_mean"):
        assert np.isfinite(fs[k]), k
