"""Chaos-layer guarantees (docs/faults.md).

Four properties the fault-injection subsystem stands on:

* **Off is free**: with every fault knob at its zero default, the chaos
  fields of the final state are deterministic zeros/INF — combined with
  the pinned-field digests of tests/test_telemetry.py, a default run is
  bitwise what it was before the chaos layer existed.
* **One semantics**: the fused lane-major engine and the Python
  reference agree exactly on every chaos counter, retry count and final
  pipeline status under crashes, outages, timeouts and stragglers.
* **Deterministic chaos**: same (params, seed) -> bitwise-identical
  faults, kills and recoveries; the fault trace round-trips through its
  record form.
* **The retry contract**: exhausted budgets FAIL, budgets > 0 absorb
  transient kills via exponential-backoff re-queues whose release times
  follow ``tick + base_backoff_ticks * 2**attempt`` exactly.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (
    SimParams,
    fleet_run,
    generate_workload,
    run,
)
from repro.core.faults import (
    attach_fault_trace,
    fault_trace_from_records,
    fault_trace_to_records,
    generate_fault_trace,
)
from repro.core.state import CHAOS_FIELDS, INF_TICK
from repro.core.telemetry.schema import (
    COL_A,
    COL_B,
    COL_PIPE,
    COL_POOL,
    COL_TICK,
    EventKind,
)

CHAOS = dict(
    crash_mtbf_ticks=500.0,
    outage_mtbf_ticks=1_500.0,
    outage_duration_ticks=300.0,
    straggler_prob=0.15,
    timeout_ticks=30_000,
    max_retries=3,
    base_backoff_ticks=40,
)


def _params(seed=0, algo="priority", duration=0.04, **extra):
    return SimParams(
        duration=duration,
        seed=seed,
        scheduling_algo=algo,
        num_pools=1 if algo == "naive" else 2,
        waiting_ticks_mean=400.0,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.0,
        max_pipelines=32,
        max_containers=32,
        **extra,
    )


CHAOS_COMPARE = [
    "pipe_status",
    "pipe_completion",
    "pipe_retries",
    "done_count",
    "failed_count",
    "oom_events",
    "preempt_events",
    "crash_events",
    "outage_events",
    "timeout_events",
    "retry_events",
    "fault_kills",
    "wasted_ticks",
    "pool_down_until",
    "crash_cursor",
    "outage_cursor",
    "ctr_timed",
]


def _assert_chaos_equal(a, b, ctx=""):
    for f in CHAOS_COMPARE:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)),
            np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: field {f}",
        )
    np.testing.assert_allclose(
        np.asarray(a.pool_down_s), np.asarray(b.pool_down_s),
        rtol=1e-3, atol=1e-4, err_msg=f"{ctx}: pool_down_s",
    )


# ---------------------------------------------------------------------------
# Off is free.
# ---------------------------------------------------------------------------
def test_faults_off_state_is_pristine():
    """A default run leaves every chaos field at its init value — the
    structural half of the pinned-digest guarantee."""
    res = run(_params())
    state = res.state
    assert res.workload.faults is None  # no trace even materialised
    for f in CHAOS_FIELDS:
        a = np.asarray(getattr(state, f))
        if f == "nxt_fault":
            assert (a == INF_TICK).all(), f
        else:
            assert not a.any(), f"{f} changed in a faults-off run"
    s = res.summary()
    assert s["faults_injected"] == s["retries"] == s["timeouts"] == 0
    assert np.isnan(s["mttr_s"])


# ---------------------------------------------------------------------------
# One semantics: fused == Python reference under every fault class.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "knobs",
    [
        dict(crash_mtbf_ticks=500.0, max_retries=3, base_backoff_ticks=40),
        dict(outage_mtbf_ticks=1_200.0, outage_duration_ticks=300.0,
             max_retries=3, base_backoff_ticks=40),
        dict(timeout_ticks=25_000, max_retries=2, base_backoff_ticks=30),
        dict(straggler_prob=0.3),
        CHAOS,
    ],
    ids=["crash", "outage", "timeout", "straggler", "all"],
)
@pytest.mark.parametrize("algo", ["priority", "naive"])
def test_event_equals_python_under_faults(knobs, algo):
    params = _params(seed=5, algo=algo, **knobs)
    wl = generate_workload(params)
    r_event = run(params, workload=wl, engine="event")
    r_python = run(params, workload=wl, engine="python")
    _assert_chaos_equal(
        r_event.state, r_python.state, ctx=f"{algo}/{sorted(knobs)}"
    )


@settings(
    max_examples=10,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    seed=st.integers(0, 2**16),
    algo=st.sampled_from(["naive", "priority", "priority_pool"]),
    crash_mtbf=st.sampled_from([0.0, 400.0, 2_000.0]),
    outage_mtbf=st.sampled_from([0.0, 1_500.0]),
    max_retries=st.integers(0, 4),
)
def test_event_equals_python_under_faults_property(
    seed, algo, crash_mtbf, outage_mtbf, max_retries
):
    params = _params(
        seed=seed,
        algo=algo,
        crash_mtbf_ticks=crash_mtbf,
        outage_mtbf_ticks=outage_mtbf,
        outage_duration_ticks=250.0 if outage_mtbf else 0.0,
        max_retries=max_retries,
        base_backoff_ticks=25,
        timeout_ticks=40_000,
    )
    wl = generate_workload(params)
    r_event = run(params, workload=wl, engine="event")
    r_python = run(params, workload=wl, engine="python")
    _assert_chaos_equal(
        r_event.state, r_python.state,
        ctx=f"{algo}/s{seed}/c{crash_mtbf}/o{outage_mtbf}/r{max_retries}",
    )


# ---------------------------------------------------------------------------
# Deterministic chaos.
# ---------------------------------------------------------------------------
def test_same_seed_same_faults():
    params = _params(seed=9, **CHAOS)
    a, b = run(params).state, run(params).state
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f
        )


def test_fault_trace_roundtrip():
    params = _params(seed=3, **CHAOS)
    ft = generate_fault_trace(params)
    back = fault_trace_from_records(fault_trace_to_records(ft), params)
    for f in ft._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ft, f)), np.asarray(getattr(back, f)), err_msg=f
        )


def test_fleet_lanes_draw_independent_faults():
    from repro.core import make_workload_batch

    params = _params(seed=2, crash_mtbf_ticks=400.0, max_retries=3,
                     base_backoff_ticks=40)
    batch = make_workload_batch(params, seeds=[0, 1, 2, 3])
    assert batch.faults is not None
    crash = np.asarray(batch.faults.crash_time)
    # per-lane keys -> independent chaos schedules, not one broadcast
    assert any(
        not np.array_equal(crash[0], crash[i]) for i in range(1, 4)
    )
    states = fleet_run(params, seeds=[0, 1, 2, 3])
    assert np.asarray(states.crash_events).sum() > 0


# ---------------------------------------------------------------------------
# The retry contract.
# ---------------------------------------------------------------------------
def test_retry_backoff_schedule_exact():
    """Every RETRY record's release tick obeys
    tick + max(base_backoff_ticks * 2**(attempt-1), 1) — the recorded
    attempt is the post-increment count."""
    params = _params(seed=11, **CHAOS)
    res = run(params, trace=True, trace_capacity=8192)
    assert res.trace.events_dropped == 0
    retries = res.trace.of_kind(EventKind.RETRY)
    assert len(retries) > 0, "config too quiet: no retries recorded"
    base = params.base_backoff_ticks
    for row in retries:
        tick, attempt, release = (
            int(row[COL_TICK]), int(row[COL_A]), int(row[COL_B])
        )
        assert attempt >= 1
        want = tick + max(base * 2 ** (attempt - 1), 1)
        assert release == want, (
            f"RETRY at {tick}, attempt {attempt}: release {release} != {want}"
        )
    # per-pipe attempts are strictly increasing (re-queue ordering)
    by_pipe = {}
    for row in retries:
        by_pipe.setdefault(int(row[COL_PIPE]), []).append(int(row[COL_A]))
    for pipe, attempts in by_pipe.items():
        assert attempts == sorted(attempts), f"pipe {pipe}: {attempts}"
        assert len(set(attempts)) == len(attempts), f"pipe {pipe}: {attempts}"


def test_retry_budget_contract():
    """With a retry budget, transient kills are absorbed (zero FAILED);
    with max_retries=0, the same chaos fails pipelines to the user."""
    chaos = dict(crash_mtbf_ticks=400.0, base_backoff_ticks=40)
    lenient = run(_params(seed=4, max_retries=5, **chaos)).summary()
    strict = run(_params(seed=4, max_retries=0, **chaos)).summary()
    assert lenient["fault_kills"] > 0, "config too quiet: no kills"
    assert lenient["failed"] == 0
    assert lenient["retries"] > 0
    assert strict["failed"] > 0
    assert strict["retries"] == 0


def test_timeouts_kill_and_requeue():
    params = _params(
        seed=8, timeout_ticks=2_000, max_retries=2, base_backoff_ticks=30
    )
    s = run(params).summary()
    assert s["timeouts"] > 0, "config too quiet: no timeouts"
    assert s["retries"] > 0
    assert s["wasted_work_s"] > 0
    # timed-out work never counts as DONE throughput at the deadline
    assert s["done"] + s["failed"] + s["in_flight"] == s["submitted"]


def test_no_assignments_to_down_pools():
    """Between POOL_DOWN and recovery, no container starts on the pool."""
    params = _params(
        seed=6, algo="priority_pool",
        outage_mtbf_ticks=800.0, outage_duration_ticks=400.0,
        max_retries=3, base_backoff_ticks=40,
    )
    res = run(params, trace=True, trace_capacity=8192)
    assert res.trace.events_dropped == 0
    downs = res.trace.of_kind(EventKind.POOL_DOWN)
    assert len(downs) > 0, "config too quiet: no outages"
    starts = res.trace.of_kind(EventKind.START)
    for d in downs:
        pool, t0, until = int(d[COL_POOL]), int(d[COL_TICK]), int(d[COL_A])
        bad = [
            int(s[COL_TICK]) for s in starts
            if int(s[COL_POOL]) == pool and t0 <= int(s[COL_TICK]) < until
        ]
        assert not bad, (
            f"pool {pool} down [{t0}, {until}) but containers started at {bad}"
        )


# ---------------------------------------------------------------------------
# Next-event oracle under faults: the nxt_fault register agrees with a
# recompute-from-scratch at every event of a faults-on run.
# ---------------------------------------------------------------------------
def test_next_event_registers_match_oracle_under_faults():
    import jax
    import jax.numpy as jnp

    from repro.core import engine as engine_mod
    from repro.core import executor
    from repro.core.engine import _filter_down_pool_assignments
    from repro.core.scheduler import (
        get_vector_scheduler,
        get_vector_scheduler_init,
        mask_down_pools,
    )
    from repro.core.state import init_state
    from repro.kernels.sim_tick import fleet_tick

    params = _params(seed=13, algo="priority", **CHAOS)
    wl = generate_workload(params)
    assert wl.faults is not None
    scheduler_fn = get_vector_scheduler("priority", early_exit=True)
    ss = get_vector_scheduler_init("priority")(params)
    arr_sorted = engine_mod._sorted_arrivals(wl.arrival)
    horizon = jnp.int32(params.horizon_ticks)

    @jax.jit
    def step(state, ss):
        tick = state.tick
        ph = fleet_tick(
            state.ctr_status[None], state.ctr_end[None], state.ctr_oom[None],
            state.ctr_cpus[None], state.ctr_ram[None], state.ctr_pool[None],
            state.pipe_status[None], wl.arrival[None],
            state.pipe_release[None], tick[None],
            num_pools=params.num_pools,
        )
        ph_l = jax.tree.map(lambda x: x[0], ph)
        # recompute the oracle on the exact state the engine's register
        # read sees (post phase 1 + faults + decision application)
        st1 = executor.apply_fused_phase1(state, wl, tick, params, ph_l)
        st1, _ = executor.apply_faults(st1, wl, tick, params)
        view = mask_down_pools(st1, tick)
        ss1, dec = scheduler_fn(ss, view, wl, params)
        dec = _filter_down_pool_assignments(dec, st1, tick, params)
        st2 = executor.apply_decision(
            st1, wl, dec, tick, params, early_exit=True
        )
        acted = (
            jnp.any(dec.suspend)
            | jnp.any(dec.reject)
            | jnp.any(dec.assign_pipe >= 0)
        )
        nxt_full = engine_mod._next_event(st2, wl, tick, acted)
        new_state, new_ss = engine_mod.lane_event_step(
            params, horizon, scheduler_fn, state, ss, wl, arr_sorted, tick,
            ph_l,
        )
        return new_state, new_ss, nxt_full

    state = init_state(params)
    n_events = 0
    while int(state.tick) < params.horizon_ticks:
        state, ss, nxt_full = step(state, ss)
        assert int(state.tick) == min(int(nxt_full), params.horizon_ticks), (
            f"event {n_events}: engine jumped to {int(state.tick)}, "
            f"oracle says {int(nxt_full)}"
        )
        n_events += 1
    assert n_events > 10
    assert int(state.crash_events) > 0 or int(state.outage_events) > 0


# ---------------------------------------------------------------------------
# Satellite: advise_checkpoint_cadence shares the engine's failure model.
# ---------------------------------------------------------------------------
def test_checkpoint_cadence_crosschecks_engine_wasted_work():
    """The cadence advisor's failure model (exponential gaps, lost work
    since the last safe point) must move with MTBF the same way the real
    engine's wasted_ticks counter does under crash injection: less MTBF,
    more lost work — and a shorter recommended interval."""
    from repro.runtime.failures import advise_checkpoint_cadence

    frequent = advise_checkpoint_cadence(
        step_time_s=0.1, ckpt_write_s=0.5, restart_s=2.0,
        mtbf_steps=50.0, horizon_steps=500, seed=0,
    )
    rare = advise_checkpoint_cadence(
        step_time_s=0.1, ckpt_write_s=0.5, restart_s=2.0,
        mtbf_steps=5_000.0, horizon_steps=500, seed=0,
    )
    assert frequent["best_interval"] <= rare["best_interval"]
    assert (
        min(frequent["total_time_s"].values())
        >= min(rare["total_time_s"].values())
    )

    wasted = []
    for mtbf in (300.0, 3_000.0):
        s = run(
            _params(seed=1, crash_mtbf_ticks=mtbf, max_retries=6,
                    base_backoff_ticks=40)
        ).summary()
        wasted.append(s["wasted_work_s"])
    assert wasted[0] > wasted[1], (
        "engine wasted work should grow as crash MTBF shrinks, like the "
        f"advisor's lost-work model: {wasted}"
    )
