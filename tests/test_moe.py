"""MoE dispatch correctness: row-local argsort dispatch vs an explicit
per-token dense reference; sharding-context equivalence; capacity
dropping semantics."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.attention import unzip
from repro.models.common import ModelConfig, MoEConfig
from repro.models.mlp import moe_apply, moe_init
from repro.parallel.ctx import sharding_ctx
from repro.parallel.sharding import ShardingRules


def _cfg(E=8, K=2, cf=8.0, d=32, f=48):
    return ModelConfig(
        name="m", d_model=d, d_ff=64, vocab=64,
        moe=MoEConfig(n_experts=E, top_k=K, expert_ff=f, capacity_factor=cf),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )


def _dense_reference(cfg, p, x):
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    gates = jax.nn.softmax(logits, -1)
    gk, ek = jax.lax.top_k(gates, cfg.moe.top_k)
    gk = gk / gk.sum(-1, keepdims=True)
    B, S, d = x.shape
    ref = np.zeros(x.shape, np.float32)
    for b in range(B):
        for s in range(S):
            acc = np.zeros(d, np.float32)
            for j in range(cfg.moe.top_k):
                e = int(ek[b, s, j])
                xi = x[b, s]
                g = xi @ p["we_gate"][e]
                u = xi @ p["we_up"][e]
                acc += float(gk[b, s, j]) * np.asarray(
                    (jax.nn.silu(g) * u) @ p["we_down"][e]
                )
            ref[b, s] = acc
    return jnp.asarray(ref)


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 100),
    E=st.sampled_from([4, 8]),
    K=st.sampled_from([1, 2]),
)
def test_moe_matches_dense_reference(seed, E, K):
    cfg = _cfg(E=E, K=K, cf=8.0)  # capacity high enough: no drops
    p, _ = unzip(moe_init(cfg, jax.random.PRNGKey(seed)))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 12, 32))
    out, aux = moe_apply(cfg, p, x)
    ref = _dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    # E*sum(f*p) ~ 1 for near-uniform routing (exactly 1 iff f == p);
    # random-init routers sit close but not above the bound
    assert 0.7 < float(aux) < float(cfg.moe.n_experts)


def test_moe_sharded_ctx_equals_plain():
    cfg = _cfg()
    p, _ = unzip(moe_init(cfg, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    out_plain, _ = moe_apply(cfg, p, x)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, sharding_ctx(mesh, ShardingRules().act):
        out_ctx, _ = jax.jit(lambda p, x: moe_apply(cfg, p, x))(p, x)
    np.testing.assert_allclose(
        np.asarray(out_plain), np.asarray(out_ctx), rtol=1e-6
    )


def test_capacity_drop_zeroes_overflow_tokens():
    """With capacity 1 token/expert, overflow tokens get zero MoE output
    (they survive via the residual in the block)."""
    cfg = _cfg(E=2, K=1, cf=0.0)  # floor -> C = 8 min... force tiny:
    cfg = ModelConfig(
        name="m", d_model=8, d_ff=16, vocab=16,
        moe=MoEConfig(n_experts=2, top_k=1, expert_ff=16,
                      capacity_factor=1e-9),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    p, _ = unzip(moe_init(cfg, jax.random.PRNGKey(0)))
    S = 64  # >> E*C = 2*8 slots -> most tokens dropped
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, 8))
    out, _ = moe_apply(cfg, p, x)
    zero_rows = np.sum(np.all(np.asarray(out) == 0.0, axis=-1))
    assert zero_rows >= S - 2 * 8
    assert np.isfinite(np.asarray(out)).all()
