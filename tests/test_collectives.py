"""int8 error-feedback gradient all-reduce on a real (fake-device) mesh."""
import os
import subprocess
import sys
import textwrap

CODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.collectives import compressed_psum_mean

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    # per-rank gradients: same tree, different values per rank -> we test
    # the mean against numpy. Leaves replicated in spec; emulate per-rank
    # difference by adding axis_index inside a wrapper... simplest: the
    # exact-mean check with identical replicas (mean == value), plus the
    # EF residual bound across steps with changing grads.
    g = {"w": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal((128,)), jnp.float32)}
    e = jax.tree.map(jnp.zeros_like, g)
    with mesh:
        mean, e2 = compressed_psum_mean(g, e, mesh, axis="data")
    for k in g:
        q_err = np.abs(np.asarray(mean[k]) - np.asarray(g[k])).max()
        scale = np.abs(np.asarray(g[k])).max() / 127.0
        assert q_err <= scale + 1e-6, (k, q_err, scale)
        # error feedback buffer holds exactly the quantisation residual
        np.testing.assert_allclose(
            np.asarray(e2[k]), np.asarray(g[k]) - np.asarray(mean[k]),
            rtol=1e-5, atol=1e-6)
    # across steps the EF-corrected stream is unbiased: sum of means -> sum of grads
    acc = jax.tree.map(jnp.zeros_like, g)
    e = jax.tree.map(jnp.zeros_like, g)
    with mesh:
        for i in range(30):
            mean, e = compressed_psum_mean(g, e, mesh, axis="data")
            acc = jax.tree.map(lambda a, m: a + m, acc, mean)
    for k in g:
        rel = (np.linalg.norm(np.asarray(acc[k]) - 30*np.asarray(g[k]))
               / np.linalg.norm(30*np.asarray(g[k])))
        assert rel < 0.01, (k, rel)
    print("COLLECTIVES_OK")
    """
)


def test_compressed_psum_on_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", CODE],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COLLECTIVES_OK" in r.stdout
