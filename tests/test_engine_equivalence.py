"""Property tests: the compiled lane-major core and the Python
reference engine implement one semantics.

The event-skip lane-major core is the headline optimisation over the
paper's tick-per-iteration design; these tests are the evidence that
the optimisation is semantics-preserving (EXPERIMENTS.md §Perf). The
Python engine — a per-tick plain-object loop — is the paper-faithful
executable specification the compiled core is checked against.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import SimParams, generate_workload, run

COMPARE_FIELDS = [
    "pipe_status",
    "pipe_completion",
    "pipe_fails",
    "pipe_preempts",
    "done_count",
    "failed_count",
    "oom_events",
    "preempt_events",
    # ---- data plane: exact agreement expected (quantised f32 arithmetic,
    # identical accumulation order across engines) ------------------------
    "cache_hits",
    "cache_lookups",
    "cold_starts",
    "warm_starts",
    "cold_start_tick_total",
    "cache_hit_gb",
    "bytes_moved_gb",
    "cache_bytes",
    "cache_last",
    "pool_cache_used",
    # ---- chaos layer: exact agreement expected (same int arithmetic and
    # f32 backoff/stretch formulas in both engines) ------------------------
    "pipe_retries",
    "ctr_timed",
    "pool_down_until",
    "crash_cursor",
    "outage_cursor",
    "nxt_fault",
    "crash_events",
    "outage_events",
    "timeout_events",
    "retry_events",
    "fault_kills",
    "wasted_ticks",
]


def _params(seed, algo, num_pools, waiting_mean, ram_mean, duration=0.05,
            **extra):
    return SimParams(
        duration=duration,
        seed=seed,
        scheduling_algo=algo,
        num_pools=num_pools,
        waiting_ticks_mean=waiting_mean,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.0,
        op_ram_gb_mean=ram_mean,
        max_pipelines=32,
        max_containers=32,
        **extra,
    )


DATA_PLANE = dict(
    cache_gb_per_pool=4.0,
    scan_ticks_per_gb=50.0,
    cold_start_ticks=40,
    container_warm_ticks=2_000,
)


def _assert_states_equal(a, b, ctx=""):
    for f in COMPARE_FIELDS:
        x = np.asarray(getattr(a, f))
        y = np.asarray(getattr(b, f))
        np.testing.assert_array_equal(x, y, err_msg=f"{ctx}: field {f}")
    # float accumulators agree loosely (different summation orders)
    np.testing.assert_allclose(
        np.asarray(a.util_cpu_s), np.asarray(b.util_cpu_s), rtol=1e-3, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(a.pool_down_s), np.asarray(b.pool_down_s),
        rtol=1e-3, atol=1e-4,
    )


@settings(
    max_examples=15,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    seed=st.integers(0, 2**16),
    algo=st.sampled_from(["naive", "priority", "priority_pool"]),
    num_pools=st.integers(1, 3),
    waiting_mean=st.sampled_from([200.0, 800.0, 3000.0]),
    ram_mean=st.sampled_from([0.5, 2.0, 6.0]),
)
def test_event_equals_python(seed, algo, num_pools, waiting_mean, ram_mean):
    """Event-skip compiled engine == reference Python engine, exactly."""
    params = _params(seed, algo, num_pools, waiting_mean, ram_mean)
    wl = generate_workload(params)
    r_event = run(params, workload=wl, engine="event")
    r_python = run(params, workload=wl, engine="python")
    _assert_states_equal(
        r_event.state, r_python.state, ctx=f"{algo}/s{seed}/p{num_pools}"
    )


# ---------------------------------------------------------------------------
# Data-plane equivalence: with nonzero cache capacity, scan cost and
# cold-start latency, the compiled core and the per-tick Python
# reference must agree exactly on cache hits, bytes moved and
# cold-start ticks (ISSUE 1 acceptance criterion).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("algo", ["priority_pool", "cache_aware"])
def test_data_plane_metrics_equivalence(seed, algo):
    params = _params(
        seed, algo, 2, 400.0, 2.0, duration=0.02, **DATA_PLANE
    )
    wl = generate_workload(params)
    r_event = run(params, workload=wl, engine="event")
    r_python = run(params, workload=wl, engine="python")
    _assert_states_equal(
        r_event.state, r_python.state, ctx=f"event-vs-python/{algo}/s{seed}"
    )


@settings(
    max_examples=6,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    seed=st.integers(0, 2**16),
    algo=st.sampled_from(["priority", "priority_pool", "cache_aware",
                          "locality_pool"]),
    cache_gb=st.sampled_from([0.5, 4.0, 64.0]),
)
def test_event_equals_python_with_data_plane(seed, algo, cache_gb):
    """Property version: random seeds/cache sizes, event vs python."""
    params = _params(
        seed,
        algo,
        2,
        500.0,
        2.0,
        cache_gb_per_pool=cache_gb,
        scan_ticks_per_gb=25.0,
        cold_start_ticks=30,
        container_warm_ticks=1_500,
    )
    wl = generate_workload(params)
    r_event = run(params, workload=wl, engine="event")
    r_python = run(params, workload=wl, engine="python")
    _assert_states_equal(
        r_event.state, r_python.state, ctx=f"dp/{algo}/s{seed}/c{cache_gb}"
    )
    # cache invariants: occupancy == Σ resident entries, never over capacity
    cb = np.asarray(r_event.state.cache_bytes)
    used = np.asarray(r_event.state.pool_cache_used)
    np.testing.assert_allclose(cb.sum(axis=1), used, rtol=1e-5, atol=1e-5)
    assert (used <= cache_gb + 1e-4).all()


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    algo=st.sampled_from(["naive", "priority", "priority_pool"]),
)
def test_conservation_properties(seed, algo):
    """System invariants hold for arbitrary seeds."""
    params = _params(seed, algo, 2 if algo == "priority_pool" else 1, 500.0, 3.0)
    res = run(params, engine="event")
    st_ = res.state
    free_c = np.asarray(st_.pool_cpu_free)
    cap_c = np.asarray(st_.pool_cpu_cap)
    assert (free_c >= -1e-3).all() and (free_c <= cap_c + 1e-3).all()
    free_r = np.asarray(st_.pool_ram_free)
    cap_r = np.asarray(st_.pool_ram_cap)
    assert (free_r >= -1e-3).all() and (free_r <= cap_r + 1e-3).all()
    s = res.summary()
    assert s["done"] + s["failed"] + s["in_flight"] == s["submitted"]
    assert 0.0 <= s["cpu_utilization"] <= 1.0 + 1e-6
    # a pipeline is never both done and running
    status = np.asarray(st_.pipe_status)
    live_pipes = np.asarray(st_.ctr_pipe)[np.asarray(st_.ctr_status) == 1]
    assert not np.isin(live_pipes, np.where(status == 5)[0]).any()
