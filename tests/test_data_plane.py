"""Data-plane semantics, hand-computed (ISSUE 1).

Every scenario here is a small explicit trace whose cold-start charges,
scan costs, cache hits and LRU evictions are worked out by hand in the
comments; the engine must reproduce the numbers exactly.
"""
import numpy as np
import pytest

from repro.core import (
    Operator,
    Pipeline,
    Priority,
    SimParams,
    generate_workload,
    run,
    workload_from_pipelines,
)

def one_op_pipe(pid, arrive_tick, *, ram=1.0, base=100, out_gb=0.0,
                prio=Priority.BATCH):
    return Pipeline(
        pid=pid,
        priority=prio,
        arrival_tick=arrive_tick,
        ops=[Operator(ram_gb=ram, base_ticks=base, alpha=0.0, level=0,
                      out_gb=out_gb)],
    )


def P(**kw) -> SimParams:
    base = dict(
        duration=0.05,
        scheduling_algo="naive",
        total_cpus=16.0,
        total_ram_gb=32.0,
        max_pipelines=8,
        max_containers=8,
        engine="event",
    )
    base.update(kw)
    return SimParams(**base)


# "event" is the lane-major compiled core; "python" is the per-tick
# reference engine (the compiled tick engine was deleted in the
# lane-major unification)
ENGINES = ["event", "python"]


# ---------------------------------------------------------------------------
# Cold / warm starts
# ---------------------------------------------------------------------------
class TestColdStart:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_cold_then_warm(self, engine):
        # p0 arrives t=0 on a cold slot: 50 boot + 100 run -> done t=150.
        # p1 arrives t=200; slot 0 is warm until 150+10000 -> no boot,
        # done t=300.
        params = P(cold_start_ticks=50, container_warm_ticks=10_000)
        wl = workload_from_pipelines(
            [one_op_pipe(0, 0), one_op_pipe(1, 200)], params
        )
        res = run(params, workload=wl, engine=engine)
        comp = np.asarray(res.state.pipe_completion)
        assert comp[0] == 150
        assert comp[1] == 300
        assert int(res.state.cold_starts) == 1
        assert int(res.state.warm_starts) == 1
        assert int(res.state.cold_start_tick_total) == 50

    @pytest.mark.parametrize("engine", ENGINES)
    def test_warmth_expires(self, engine):
        # warm window only 30 ticks: p1 at t=200 > 150+30 -> cold again.
        params = P(cold_start_ticks=50, container_warm_ticks=30)
        wl = workload_from_pipelines(
            [one_op_pipe(0, 0), one_op_pipe(1, 200)], params
        )
        res = run(params, workload=wl, engine=engine)
        comp = np.asarray(res.state.pipe_completion)
        assert comp[0] == 150
        assert comp[1] == 350  # 200 + 50 boot + 100 run
        assert int(res.state.cold_starts) == 2
        assert int(res.state.warm_starts) == 0
        assert int(res.state.cold_start_tick_total) == 100

    def test_zero_cold_start_charges_nothing(self):
        params = P()  # all data-plane knobs at their 0 defaults
        wl = workload_from_pipelines(
            [one_op_pipe(0, 0), one_op_pipe(1, 200)], params
        )
        res = run(params, workload=wl, engine="event")
        comp = np.asarray(res.state.pipe_completion)
        assert comp[0] == 100 and comp[1] == 300
        assert int(res.state.cold_start_tick_total) == 0


# ---------------------------------------------------------------------------
# Data-scan cost + cache hit on re-run (OOM retry path)
# ---------------------------------------------------------------------------
class TestScanAndCacheHit:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_oom_retry_hits_cache(self, engine):
        # priority scheduler: chunk_ram = 10% of 32 = 3.2 GB. The op needs
        # 5 GB -> OOM on the first attempt, retried at 6.4 GB.
        #   run 1 (t=0):   cache empty -> scan 2 GB * 100 t/GB = 200 ticks;
        #                  OOM fires at 200 + max(1, 0) = 201.
        #   run 2 (t=201): 2 GB resident -> no scan; done 201 + 100 = 301.
        params = P(
            scheduling_algo="priority",
            cache_gb_per_pool=10.0,
            scan_ticks_per_gb=100.0,
        )
        wl = workload_from_pipelines(
            [one_op_pipe(0, 0, ram=5.0, out_gb=2.0)], params
        )
        res = run(params, workload=wl, engine=engine)
        st = res.state
        assert int(st.oom_events) == 1
        assert np.asarray(st.pipe_completion)[0] == 301
        assert float(st.bytes_moved_gb) == 2.0
        assert float(st.cache_hit_gb) == 2.0
        assert int(st.cache_hits) == 1
        assert int(st.cache_lookups) == 2
        s = res.summary()
        assert s["cache_hit_rate"] == pytest.approx(0.5)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_cache_capacity_zero_never_hits(self, engine):
        # same scenario but no cache: both runs scan the full 2 GB
        params = P(
            scheduling_algo="priority",
            cache_gb_per_pool=0.0,
            scan_ticks_per_gb=100.0,
        )
        wl = workload_from_pipelines(
            [one_op_pipe(0, 0, ram=5.0, out_gb=2.0)], params
        )
        res = run(params, workload=wl, engine=engine)
        st = res.state
        assert float(st.bytes_moved_gb) == 4.0
        assert float(st.cache_hit_gb) == 0.0
        assert int(st.cache_hits) == 0
        # second run re-scans: completion = 201 + 200 + 100
        assert np.asarray(st.pipe_completion)[0] == 501


# ---------------------------------------------------------------------------
# LRU eviction
# ---------------------------------------------------------------------------
class TestLRU:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_oldest_entry_evicted_first(self, engine):
        # cap 5 GB; A (2 GB, t=0), B (2 GB, t=200), C (2 GB, t=400).
        # Inserting C needs 4 + 2 - 5 = 1 GB freed -> evict A (oldest,
        # 2 GB >= 1) and stop; B survives.
        params = P(cache_gb_per_pool=5.0)
        wl = workload_from_pipelines(
            [
                one_op_pipe(0, 0, out_gb=2.0),
                one_op_pipe(1, 200, out_gb=2.0),
                one_op_pipe(2, 400, out_gb=2.0),
            ],
            params,
        )
        res = run(params, workload=wl, engine=engine)
        cb = np.asarray(res.state.cache_bytes)[0]
        assert cb[0] == 0.0          # A evicted
        assert cb[1] == 2.0 and cb[2] == 2.0
        assert float(res.state.pool_cache_used[0]) == 4.0
        last = np.asarray(res.state.cache_last)[0]
        assert last[1] == 200 and last[2] == 400

    @pytest.mark.parametrize("engine", ENGINES)
    def test_eviction_cascades_until_fit(self, engine):
        # cap 5 GB; A (2), B (2), then D (4.5): needs 4 + 4.5 - 5 = 3.5
        # freed -> evict A (2 < 3.5), then B (4 >= 3.5). Only D remains.
        params = P(cache_gb_per_pool=5.0)
        wl = workload_from_pipelines(
            [
                one_op_pipe(0, 0, out_gb=2.0),
                one_op_pipe(1, 200, out_gb=2.0),
                one_op_pipe(2, 400, out_gb=4.5),
            ],
            params,
        )
        res = run(params, workload=wl, engine=engine)
        cb = np.asarray(res.state.cache_bytes)[0]
        assert cb[0] == 0.0 and cb[1] == 0.0 and cb[2] == 4.5
        assert float(res.state.pool_cache_used[0]) == 4.5

    @pytest.mark.parametrize("engine", ENGINES)
    def test_oversized_dataset_not_cached(self, engine):
        # 7 GB dataset > 5 GB cache: never inserted, resident set intact
        params = P(cache_gb_per_pool=5.0)
        wl = workload_from_pipelines(
            [
                one_op_pipe(0, 0, out_gb=2.0),
                one_op_pipe(1, 200, out_gb=7.0),
            ],
            params,
        )
        res = run(params, workload=wl, engine=engine)
        cb = np.asarray(res.state.cache_bytes)[0]
        assert cb[0] == 2.0 and cb[1] == 0.0
        assert float(res.state.pool_cache_used[0]) == 2.0


# ---------------------------------------------------------------------------
# Cache-aware scheduling
# ---------------------------------------------------------------------------
class TestCacheAwareScheduler:
    def _retry_workload(self, params):
        # one big pipeline that OOMs once (5 GB > 10% chunk of 3.2 GB)
        # and carries a 2 GB intermediate dataset
        return workload_from_pipelines(
            [one_op_pipe(0, 0, ram=5.0, out_gb=2.0)], params
        )

    @pytest.mark.parametrize("engine", ["event", "python"])
    def test_retry_lands_on_cached_pool(self, engine):
        params = P(
            scheduling_algo="cache_aware",
            num_pools=2,
            cache_gb_per_pool=10.0,
            scan_ticks_per_gb=100.0,
        )
        wl = self._retry_workload(params)
        res = run(params, workload=wl, engine=engine)
        st = res.state
        assert int(st.oom_events) == 1
        # the retry found its parent outputs resident
        assert float(st.cache_hit_gb) == 2.0
        assert int(st.cache_hits) == 1
        # data lives on exactly one pool
        cb = np.asarray(st.cache_bytes)
        assert (cb > 0).sum() == 1

    def test_cache_aware_beats_priority_pool_on_bytes_moved(self):
        # churny workload with tight resources -> OOM retries; the
        # cache-aware placement must re-scan no more than priority_pool
        params = P(
            scheduling_algo="priority_pool",
            num_pools=2,
            duration=0.2,
            waiting_ticks_mean=400,
            max_pipelines=64,
            max_containers=32,
            op_ram_gb_mean=4.0,
            op_base_seconds_mean=0.003,
            cache_gb_per_pool=8.0,
            scan_ticks_per_gb=50.0,
            seed=4,
        )
        wl = generate_workload(params)
        base = run(params, workload=wl, engine="event").summary()
        aware = run(
            params.replace(scheduling_algo="cache_aware"),
            workload=wl,
            engine="event",
        ).summary()
        assert aware["cache_hit_gb"] > 0  # the scenario really exercises it
        assert aware["cache_hit_gb"] >= base["cache_hit_gb"]
        assert aware["bytes_moved_gb"] <= base["bytes_moved_gb"]

    def test_locality_pool_runs_and_reports(self):
        params = P(
            scheduling_algo="locality_pool",
            num_pools=2,
            duration=0.1,
            waiting_ticks_mean=600,
            op_base_seconds_mean=0.003,
            max_pipelines=32,
            cache_gb_per_pool=8.0,
            scan_ticks_per_gb=50.0,
            cold_start_ticks=40,
        )
        res = run(params, engine="event")
        s = res.summary()
        assert s["done"] > 0
        assert 0.0 <= s["cache_hit_rate"] <= 1.0
        assert s["cold_starts"] + s["warm_starts"] >= s["done"]


# ---------------------------------------------------------------------------
# Backward compatibility: data plane off == pre-data-plane behaviour
# ---------------------------------------------------------------------------
class TestBackwardCompat:
    def test_defaults_are_inert(self):
        params = P(
            scheduling_algo="priority",
            duration=0.1,
            waiting_ticks_mean=500,
            seed=9,
        )
        assert not params.data_plane_active
        res = run(params, engine="event")
        st = res.state
        # no ticks were ever charged by the data plane
        assert int(st.cold_start_tick_total) == 0
        assert float(st.pool_cache_used.sum()) == 0.0
        # done/failed bookkeeping unaffected
        s = res.summary()
        assert s["done"] + s["failed"] + s["in_flight"] == s["submitted"]

    def test_workload_generation_unchanged_by_data_plane_params(self):
        # the out-size draws must not perturb the pre-existing columns
        a = generate_workload(P(seed=5))
        b = generate_workload(P(seed=5, op_out_gb_mean=64.0,
                                out_runtime_corr=0.9))
        for field in ("arrival", "prio", "op_ram", "op_base", "op_alpha"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
            )
        assert not np.array_equal(np.asarray(a.op_out), np.asarray(b.op_out))

    def test_out_sizes_correlate_with_runtime(self):
        params = P(seed=2, max_pipelines=512, out_runtime_corr=0.9,
                   op_out_gb_sigma=1.0)
        wl = generate_workload(params)
        valid = np.asarray(wl.op_valid)
        out = np.log(np.asarray(wl.op_out)[valid])
        base = np.log(np.asarray(wl.op_base)[valid])
        r = np.corrcoef(out, base)[0, 1]
        assert r > 0.5

    def test_out_sizes_are_mib_quantised(self):
        wl = generate_workload(P(seed=3))
        out = np.asarray(wl.op_out, dtype=np.float64)
        np.testing.assert_allclose(out * 1024, np.round(out * 1024),
                                   atol=1e-4)
