"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED config of each family and run one forward/train step on CPU,
asserting output shapes and no NaNs. Full configs are only exercised via
the dry-run (abstract, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch, list_archs
from repro.models import lm
from repro.models.encdec import encdec_init
from repro.optim.optimizers import OptConfig
from repro.runtime.steps import make_serve_steps, make_train_step

SMOKE_SEQ = 32
SMOKE_BATCH = 2


def smoke_batch(cfg, key):
    ks = jax.random.split(key, 2)
    batch = {
        "tokens": jax.random.randint(
            ks[0],
            (SMOKE_BATCH, SMOKE_SEQ if cfg.family != "audio" else max(8, SMOKE_SEQ // 4)),
            0,
            cfg.vocab,
        )
    }
    if cfg.family == "vlm":
        batch["frontend_embeds"] = jax.random.normal(
            ks[1], (SMOKE_BATCH, cfg.n_img_tokens, lm.VIT_DIM), jnp.float32
        )
    if cfg.family == "audio":
        batch["frontend_embeds"] = jax.random.normal(
            ks[1], (SMOKE_BATCH, SMOKE_SEQ, lm.VIT_DIM), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch_name", list_archs())
def test_smoke_train_step(arch_name):
    arch = get_arch(arch_name)
    cfg = arch.smoke
    opt = OptConfig(name=arch.optimizer, warmup_steps=2, total_steps=10)
    init_fn, step_fn = make_train_step(cfg, opt, microbatches=2)
    state, axes = init_fn(jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, jax.random.PRNGKey(1))
    state2, metrics = jax.jit(step_fn)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch_name}: loss not finite"
    assert loss > 0.1, f"{arch_name}: suspicious loss {loss}"
    assert int(metrics["step"]) == 1
    # params actually changed
    delta = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params,
        state2.params,
    )
    assert max(jax.tree.leaves(delta)) > 0.0
    # no NaNs anywhere in the updated state
    for leaf in jax.tree.leaves(state2.params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch_name", list_archs())
def test_smoke_prefill_decode(arch_name):
    arch = get_arch(arch_name)
    cfg = arch.smoke
    prefill, decode = make_serve_steps(cfg)
    if cfg.family == "audio":
        params, _ = encdec_init(cfg, jax.random.PRNGKey(0))
    else:
        params, _ = lm.lm_init(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, jax.random.PRNGKey(1))
    S = batch["tokens"].shape[1]
    logits, caches = prefill(params, batch, S + 4)
    assert logits.shape == (SMOKE_BATCH, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, caches = decode(params, caches, tok, S)
    assert logits2.shape == (SMOKE_BATCH, cfg.vocab)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch_name", list_archs())
def test_smoke_two_steps_loss_moves(arch_name):
    """Two optimizer steps on the same batch should reduce the loss."""
    arch = get_arch(arch_name)
    cfg = arch.smoke
    opt = OptConfig(
        name=arch.optimizer, peak_lr=5e-3, warmup_steps=1, total_steps=50
    )
    init_fn, step_fn = make_train_step(cfg, opt)
    state, _ = init_fn(jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, jax.random.PRNGKey(1))
    jstep = jax.jit(step_fn)
    losses = []
    for _ in range(3):
        state, m = jstep(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], f"{arch_name}: loss did not drop {losses}"
