"""Shared test config.

Two jobs:

* Force 4 XLA host devices (before anything imports jax) so the
  device-sharded fleet path (``fleet_run(shard="auto")``) is exercised
  by every test run, CPU CI included.
* When the real ``hypothesis`` package is unavailable (hermetic CI
  images, minimal containers), install a tiny deterministic stand-in:
  each ``@given`` test runs ``max_examples`` pseudo-random examples
  drawn from a PRNG seeded by the test's qualified name. This keeps the
  property suites runnable everywhere; real hypothesis (with shrinking
  and a database) is used automatically whenever it is installed.
"""
from __future__ import annotations

import enum
import functools
import inspect
import os
import random
import sys
import types

if "--xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    ).strip()


def _install_mini_hypothesis() -> None:
    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[rng.randrange(len(options))])

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def lists(inner, min_size=0, max_size=8):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [inner.example_from(rng) for _ in range(n)]

        return _Strategy(draw)

    class HealthCheck(enum.Enum):
        too_slow = "too_slow"
        data_too_large = "data_too_large"
        filter_too_much = "filter_too_much"

    def settings(max_examples=10, deadline=None, suppress_health_check=()):
        def deco(fn):
            fn._mini_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_mini_max_examples", 10)
                rng = random.Random(f"mini-hypothesis:{fn.__qualname__}")
                for _ in range(n):
                    drawn = {
                        k: s.example_from(rng) for k, s in strategies.items()
                    }
                    fn(*args, **drawn, **kwargs)

            # hide the strategy params from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p
                    for name, p in sig.parameters.items()
                    if name not in strategies
                ]
            )
            del wrapper.__wrapped__
            return wrapper

        return deco

    mod.given = given
    mod.settings = settings
    mod.HealthCheck = HealthCheck
    mod.strategies = st_mod
    st_mod.integers = integers
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans
    st_mod.floats = floats
    st_mod.lists = lists
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover
    _install_mini_hypothesis()
