"""Trace + scenario subsystem: lossless round-trips and fleet replay.

The contract under test (docs/trace-format.md):

* ``workload_to_trace_records`` is the exact inverse of ingestion —
  ``generate_workload -> records -> workload_batch_from_traces`` is
  bitwise on every ``Workload`` field, and ingestion is idempotent
  (batch -> records -> batch is a fixed point);
* batched ingestion equals single-lane ingestion lane-for-lane;
* ``fleet_run(workloads=...)`` over a trace batch is lane-for-lane
  bitwise identical to per-lane ``run()`` on the same traces, across
  every registered scheduler × data-plane on/off × ``shard="auto"`` ×
  ``bin_lanes`` on/off (the PR's acceptance bar);
* TOML and JSON spellings of a trace ingest identically.
"""
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.core import (
    SimParams,
    fleet_run,
    generate_workload,
    load_trace,
    run,
    workload_batch_from_traces,
    workload_from_trace_records,
    workload_to_trace_records,
)
from repro.core.scenarios import (
    SCENARIOS,
    get_scenario,
    list_scenarios,
    scenario_fleet,
)

ALL_SCHEDULERS = [
    "naive", "priority", "priority_pool", "sjf", "cache_aware",
    "locality_pool",
]

DATA_PLANE = dict(
    cache_gb_per_pool=4.0,
    scan_ticks_per_gb=50.0,
    cold_start_ticks=40,
    container_warm_ticks=2_000,
)

# f32 accumulator chains XLA codegens differently at different batch
# widths (~1 ULP); comparisons across DIFFERENT fleet sizes exempt it
# (same convention as tests/test_fleet.py).
BITWISE_EXEMPT = {"cost_dollars"}


def _params(algo="priority", dp=False, **extra):
    kw = dict(DATA_PLANE) if dp else {}
    kw.update(extra)
    return SimParams(
        duration=0.03,
        scheduling_algo=algo,
        num_pools=1 if algo == "naive" else 2,
        waiting_ticks_mean=300.0,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.0,
        max_pipelines=32,
        max_containers=32,
        **kw,
    )


def _assert_workloads_equal(a, b, ctx=""):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)),
            np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: field {f}",
        )


# ---------------------------------------------------------------------------
# Round trips.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 7])
def test_generated_roundtrip_bitwise(seed):
    """generate -> records -> batch ingestion is bitwise on every field,
    including fractional-tick runtimes and MiB-grid out_gb sizes."""
    params = _params().replace(seed=seed)
    wl = generate_workload(params)
    recs = workload_to_trace_records(wl)
    batch, p2 = workload_batch_from_traces([recs], params)
    assert p2 == params  # capacity untouched when it already fits
    lane0 = jax.tree.map(lambda x: x[0], batch)
    _assert_workloads_equal(wl, lane0, ctx=f"seed {seed}")


def test_records_are_json_safe_and_survive_serialisation():
    """The emitted records are plain JSON types, and a JSON round trip
    loses nothing (exactness rides on int ticks + f64-exact floats)."""
    params = _params()
    wl = generate_workload(params)
    recs = json.loads(json.dumps(workload_to_trace_records(wl)))
    batch, _ = workload_batch_from_traces([recs], params)
    _assert_workloads_equal(wl, jax.tree.map(lambda x: x[0], batch))


@pytest.mark.parametrize("dp", [False, True], ids=["plain", "data_plane"])
@pytest.mark.parametrize("family", sorted(SCENARIOS))
def test_scenario_roundtrip_fixed_point(family, dp):
    """Ingestion is a fixed point for every scenario family: batch ->
    records -> batch reproduces the arrays bitwise. ``dp=False`` strips
    the out_gb sizes first (a data-plane-free trace stays inert)."""
    base = _params(dp=dp).replace(max_pipelines=0, max_ops_per_pipeline=0)
    recs = get_scenario(family)(base, seed=3)
    assert recs, f"{family} produced an empty trace"
    if not dp:
        recs = [
            {**r, "ops": [
                {k: v for k, v in o.items() if k != "out_gb"}
                for o in r["ops"]
            ]}
            for r in recs
        ]
    batch, p = workload_batch_from_traces([recs], base)
    if not dp:
        assert not np.asarray(batch.op_out).any()
    back = workload_to_trace_records(jax.tree.map(lambda x: x[0], batch))
    batch2, p2 = workload_batch_from_traces([back], p)
    assert (p2.max_pipelines, p2.max_ops_per_pipeline) == (
        p.max_pipelines, p.max_ops_per_pipeline
    )
    _assert_workloads_equal(batch, batch2, ctx=family)


def test_batch_lane_equals_single_ingestion():
    """Vectorised batch ingestion == the Pipeline-object path, per lane."""
    base = _params().replace(max_pipelines=0, max_ops_per_pipeline=0)
    lanes = [get_scenario(f)(base, seed=i)
             for i, f in enumerate(list_scenarios())]
    batch, p = workload_batch_from_traces(lanes, base)
    for i, recs in enumerate(lanes):
        single = workload_from_trace_records(recs, p)
        _assert_workloads_equal(
            single, jax.tree.map(lambda x: x[i], batch), ctx=f"lane {i}"
        )


# ---------------------------------------------------------------------------
# Capacity derivation / validation.
# ---------------------------------------------------------------------------
def test_capacity_derivation_and_validation():
    recs = [
        {"arrival_s": 0.0, "ops": [{"ram_gb": 1.0, "base_s": 0.01}] * 3},
        {"arrival_s": 0.1, "ops": [{"ram_gb": 1.0, "base_s": 0.01}]},
    ]
    wls, p = workload_batch_from_traces(
        [recs], SimParams(max_pipelines=0, max_ops_per_pipeline=0)
    )
    assert (p.max_pipelines, p.max_ops_per_pipeline) == (2, 3)
    assert wls.arrival.shape == (1, 2) and wls.op_ram.shape == (1, 2, 3)

    with pytest.raises(ValueError, match="max_pipelines=0"):
        workload_batch_from_traces([recs], SimParams(max_pipelines=1))
    with pytest.raises(ValueError, match="max_ops_per_pipeline=0"):
        workload_batch_from_traces(
            [recs], SimParams(max_ops_per_pipeline=2)
        )
    with pytest.raises(ValueError, match="empty"):
        workload_batch_from_traces([], SimParams())


def test_scenarios_respect_table_capacity():
    """A positive max_pipelines truncates the scenario like the seed
    generator's fixed arrival table."""
    p = _params().replace(max_pipelines=5, waiting_ticks_mean=50.0)
    for family in list_scenarios():
        assert len(get_scenario(family)(p, seed=0)) <= 5, family


def test_fleet_run_input_validation():
    p = _params()
    with pytest.raises(ValueError, match="exactly one"):
        fleet_run(p)
    with pytest.raises(ValueError, match="exactly one"):
        wls, p2 = scenario_fleet("diurnal", p, 2)
        fleet_run(p2, [0, 1], workloads=wls)
    # the returned-params footgun: a derived-capacity batch must run
    # with the params that carry the derived capacities
    derived = p.replace(max_pipelines=0, max_ops_per_pipeline=0)
    wls, p2 = scenario_fleet("diurnal", derived, 2)
    with pytest.raises(ValueError, match="returned"):
        fleet_run(p, workloads=wls)
    # a single unbatched workload must be rejected, not silently
    # reinterpreted as a fleet of max_pipelines lanes
    single = generate_workload(p)
    with pytest.raises(ValueError, match="BATCH"):
        fleet_run(p, workloads=single)
    with pytest.raises(ValueError, match="at least one family"):
        scenario_fleet([], p, 2)


# ---------------------------------------------------------------------------
# TOML.
# ---------------------------------------------------------------------------
def test_toml_trace_equals_json_trace(tmp_path: pathlib.Path):
    records = [
        {
            "arrival_s": 0.0,
            "priority": "QUERY",
            "ops": [
                {"ram_gb": 2.0, "base_s": 0.01, "alpha": 1.0, "level": 0,
                 "out_gb": 0.5},
            ],
        },
        {
            "arrival_s": 0.05,
            "priority": "BATCH",
            "ops": [
                {"ram_gb": 1.0, "base_s": 0.02, "alpha": 0.5, "level": 0},
                {"ram_gb": 1.5, "base_s": 0.03, "alpha": 0.0, "level": 1},
            ],
        },
    ]
    json_path = tmp_path / "trace.json"
    json_path.write_text(json.dumps(records))
    lines = []
    for rec in records:
        lines += ["[[pipeline]]", f"arrival_s = {rec['arrival_s']}",
                  f'priority = "{rec["priority"]}"']
        for op in rec["ops"]:
            lines.append("[[pipeline.ops]]")
            lines += [f"{k} = {v}" for k, v in op.items()]
    toml_path = tmp_path / "trace.toml"
    toml_path.write_text("\n".join(lines) + "\n")

    params = _params()
    _assert_workloads_equal(
        load_trace(json_path, params), load_trace(toml_path, params),
        ctx="toml-vs-json",
    )


def test_toml_fallback_parser_matches_real_toml(tmp_path: pathlib.Path,
                                                monkeypatch):
    """The minimal fallback parser (used when tomllib/tomli are both
    absent) ingests the trace spelling identically to the real parser,
    and reports header/key collisions as ValueError, not a crash."""
    from repro.core import params as params_mod

    text = (
        "[[pipeline]]\narrival_s = 0.0\npriority = \"QUERY\"\n"
        "[[pipeline.ops]]\nram_gb = 2.0\nbase_s = 0.01\n"
        "[[pipeline.ops]]\nram_gb = 3.0\nbase_s = 0.02\n"
        "[[pipeline]]\narrival_s = 0.5\n"
        "[[pipeline.ops]]\nram_gb = 1.0\nbase_s = 0.03\n"
    )
    parsed_real = (
        params_mod._toml_loads(text) if params_mod._toml is not None else None
    )
    monkeypatch.setattr(params_mod, "_toml", None)
    parsed_fallback = params_mod._toml_loads(text)
    if parsed_real is not None:
        assert parsed_fallback == parsed_real
    trace = tmp_path / "t.toml"
    trace.write_text(text)
    wl = load_trace(trace, _params())
    assert [int(n) for n in np.asarray(wl.n_ops)[:2]] == [2, 1]
    with pytest.raises(ValueError, match="collides"):
        params_mod._toml_loads("pipeline = 1\n[[pipeline]]\n")


def test_toml_trace_without_pipelines_errors(tmp_path: pathlib.Path):
    bad = tmp_path / "bad.toml"
    bad.write_text("duration = 1.0\n")
    with pytest.raises(ValueError, match="pipeline"):
        load_trace(bad, _params())


def test_json_dict_form_and_missing_key(tmp_path: pathlib.Path):
    """JSON object traces accept the same pipeline/pipelines keys as
    TOML, and a keyless object raises a descriptive error."""
    recs = [{"arrival_s": 0.0,
             "ops": [{"ram_gb": 1.0, "base_s": 0.01}]}]
    for key in ("pipeline", "pipelines"):
        f = tmp_path / f"{key}.json"
        f.write_text(json.dumps({key: recs}))
        assert int(np.asarray(load_trace(f, _params()).n_ops)[0]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"duration": 1.0}))
    with pytest.raises(ValueError, match="pipeline"):
        load_trace(bad, _params())


def test_arrival_beyond_int32_clamps_to_never():
    """A recorded day in real seconds can exceed the int32 tick range:
    both spellings clamp to INF_TICK ('never arrives') instead of
    overflowing the arrival table."""
    from repro.core.state import INF_TICK

    for rec in ({"arrival_s": 1e6, "ops": []},
                {"arrival_tick": 2**40, "ops": []}):
        wl = workload_from_trace_records([rec], _params())
        assert int(np.asarray(wl.arrival)[0]) == int(INF_TICK)


# ---------------------------------------------------------------------------
# THE acceptance bar: fleet trace replay is bitwise per-lane run().
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dp", [False, True], ids=["plain", "data_plane"])
@pytest.mark.parametrize("algo", ALL_SCHEDULERS)
def test_fleet_trace_replay_bitwise(algo, dp):
    """fleet_run over a trace batch (scenario-family lanes, round-robin)
    == per-lane run() on the same traces, and sharded (bin_lanes on AND
    off) == unsharded, strictly bitwise. Six lanes over four devices so
    the sharded run exercises lane padding AND keeps >= 2 lanes per
    device — at per-device width 1 the f32 cost_dollars chain codegens
    differently (~1 ULP), the same cross-width caveat test_fleet.py
    documents."""
    base = _params(algo, dp).replace(
        max_pipelines=0, max_ops_per_pipeline=0
    )
    families = list_scenarios()
    lanes = [get_scenario(families[i % len(families)])(base, seed=11 + i)
             for i in range(6)]
    wls, params = workload_batch_from_traces(lanes, base)

    states = fleet_run(params, workloads=wls)
    for variant, kw in (
        ("bin", dict(shard="auto", bin_lanes=True)),
        ("nobin", dict(shard="auto", bin_lanes=False)),
    ):
        wls_i, _ = workload_batch_from_traces(lanes, base)
        sharded = fleet_run(params, workloads=wls_i, **kw)
        for f in states._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(states, f)),
                np.asarray(getattr(sharded, f)),
                err_msg=f"{algo}/dp={dp}/{variant}: field {f}",
            )

    for i, recs in enumerate(lanes):
        ref = run(params, workload=workload_from_trace_records(recs, params),
                  engine="event")
        for f in states._fields:
            a = np.asarray(getattr(states, f))[i]
            b = np.asarray(getattr(ref.state, f))
            if f in BITWISE_EXEMPT:  # cross-batch-width comparison
                np.testing.assert_allclose(
                    a, b, rtol=1e-6, atol=1e-9,
                    err_msg=f"{algo}/dp={dp}/lane{i}: field {f}",
                )
            else:
                np.testing.assert_array_equal(
                    a, b, err_msg=f"{algo}/dp={dp}/lane{i}: field {f}"
                )
    # the lanes actually simulate something
    assert int(np.asarray(states.done_count).sum()) > 0
