"""Scheduler comparison (paper §4.1.2): the three built-ins on one
workload mix, plus the per-priority latency view that motivates the
priority/preemption design."""
from __future__ import annotations

import time

from repro.core import SimParams, generate_workload, run


def main(print_rows: bool = True) -> list[dict]:
    rows = []
    base = SimParams(
        duration=2.0,
        waiting_ticks_mean=2500,
        op_base_seconds_mean=0.03,
        op_ram_gb_mean=2.0,
        max_pipelines=256,
        max_containers=64,
        seed=11,
    )
    for algo in ("naive", "priority", "priority_pool", "sjf"):
        params = base.replace(
            scheduling_algo=algo,
            num_pools=2 if algo == "priority_pool" else 1,
        )
        wl = generate_workload(params)
        t0 = time.time()
        res = run(params, workload=wl, engine="event")
        wall = time.time() - t0
        s = res.summary()
        row = {
            "scheduler": algo,
            "done": s["done"],
            "throughput_per_s": round(s["throughput_per_s"], 2),
            "mean_latency_s": round(s["mean_latency_s"], 4),
            "p99_latency_s": round(s["p99_latency_s"], 4),
            "interactive_latency_s": round(
                s["per_priority"]["interactive"]["mean_latency_s"], 4
            ),
            "batch_latency_s": round(
                s["per_priority"]["batch"]["mean_latency_s"], 4
            ),
            "cpu_utilization": round(s["cpu_utilization"], 3),
            "oom_events": s["oom_events"],
            "preempt_events": s["preempt_events"],
            "wall_s": round(wall, 3),
        }
        rows.append(row)
        if print_rows:
            print(row)
    return rows


if __name__ == "__main__":
    main()
