"""Scheduler comparison (paper §4.1.2): the three built-ins on one
workload mix, plus the per-priority latency view that motivates the
priority/preemption design.

``cache_sensitivity`` is the data-plane scenario (EXPERIMENTS.md):
sweep zero-copy cache capacity × {naive, priority_pool, cache_aware}
and watch cache-aware placement convert re-runs into cache hits.

``scenario_comparison`` widens the policy table beyond the paper's
single open-loop arrival process: every scenario family of the library
(docs/scenarios.md — diurnal, bursty, heavy-tail, priority-skew,
spot-churn) is drawn once as an 8-lane trace batch and replayed under
each policy with ``fleet_run(workloads=...)``, so the cells compare
policies on the *same* recorded arrival tapes.

``resilience_comparison`` is the chaos table (docs/faults.md): the
spot_churn scenario replayed per policy with fault injection OFF and
ON, reporting goodput degradation, retries, wasted work and SLO
attainment under churn — the measured numbers behind EXPERIMENTS.md
§Scheduler-Resilience.

``overload_comparison`` is the graceful-degradation table
(docs/closed-loop.md): the retry_storm scenario (surge + mid-surge pool
outage + retrying clients) replayed under each admission policy on the
same tapes, reporting offered vs admitted load, shed/deferred counts,
retry amplification, time-to-drain and the metastability verdict — how
each policy trades goodput for stability when the fleet is overrun."""
from __future__ import annotations

import time

import jax

from repro.core import (
    SimParams,
    fleet_run,
    fleet_summary,
    generate_workload,
    run,
    workload_batch_from_traces,
)
from repro.core.scenarios import list_scenarios, scenario_lane_batch


def main(print_rows: bool = True) -> list[dict]:
    rows = []
    base = SimParams(
        duration=2.0,
        waiting_ticks_mean=2500,
        op_base_seconds_mean=0.03,
        op_ram_gb_mean=2.0,
        max_pipelines=256,
        max_containers=64,
        seed=11,
    )
    for algo in ("naive", "priority", "priority_pool", "sjf"):
        params = base.replace(
            scheduling_algo=algo,
            num_pools=2 if algo == "priority_pool" else 1,
        )
        wl = generate_workload(params)
        t0 = time.time()
        res = run(params, workload=wl, engine="event")
        wall = time.time() - t0
        s = res.summary()
        row = {
            "scheduler": algo,
            "done": s["done"],
            "throughput_per_s": round(s["throughput_per_s"], 2),
            "mean_latency_s": round(s["mean_latency_s"], 4),
            "p99_latency_s": round(s["p99_latency_s"], 4),
            "interactive_latency_s": round(
                s["per_priority"]["interactive"]["mean_latency_s"], 4
            ),
            "batch_latency_s": round(
                s["per_priority"]["batch"]["mean_latency_s"], 4
            ),
            "cpu_utilization": round(s["cpu_utilization"], 3),
            "oom_events": s["oom_events"],
            "preempt_events": s["preempt_events"],
            "wall_s": round(wall, 3),
        }
        rows.append(row)
        if print_rows:
            print(row)
    return rows


def cache_sensitivity(print_rows: bool = True) -> list[dict]:
    """Cache capacity × scheduler sweep (data-plane scenario)."""
    rows = []
    base = SimParams(
        duration=2.0,
        waiting_ticks_mean=1500,
        num_pools=2,
        op_base_seconds_mean=0.02,
        op_ram_gb_mean=3.0,
        op_out_gb_mean=2.0,
        scan_ticks_per_gb=50.0,
        cold_start_ticks=100,
        container_warm_ticks=50_000,
        max_pipelines=256,
        max_containers=64,
        seed=11,
    )
    # workload generation depends only on seed + shape knobs, so every
    # (cache, algo) cell replays the exact same arrival table
    wl = generate_workload(base)
    for cache_gb in (0.0, 2.0, 8.0, 32.0):
        for algo in ("naive", "priority_pool", "cache_aware"):
            params = base.replace(
                scheduling_algo=algo, cache_gb_per_pool=cache_gb
            )
            t0 = time.time()
            res = run(params, workload=wl, engine="event")
            wall = time.time() - t0
            s = res.summary()
            row = {
                "scheduler": algo,
                "cache_gb_per_pool": cache_gb,
                "done": s["done"],
                "throughput_per_s": round(s["throughput_per_s"], 2),
                "mean_latency_s": round(s["mean_latency_s"], 4),
                "cache_hit_rate": round(s["cache_hit_rate"], 3),
                "bytes_moved_gb": round(s["bytes_moved_gb"], 1),
                "cache_hit_gb": round(s["cache_hit_gb"], 1),
                "cold_starts": s["cold_starts"],
                "warm_starts": s["warm_starts"],
                "wall_s": round(wall, 3),
            }
            rows.append(row)
            if print_rows:
                print(row)
    return rows


SCENARIO_ALGOS = ("naive", "priority", "priority_pool", "sjf", "cache_aware")


def scenario_comparison(print_rows: bool = True) -> list[dict]:
    """Policy × scenario-family table on shared 8-lane trace batches.

    Data-plane knobs are ON (cache + cold starts + scan costs) so the
    cache-aware policy differentiates; capacity is derived from each
    family's traces (``max_pipelines=0``). The same per-family record
    lists are re-ingested for every policy — a policy cell differs from
    its neighbours only by the scheduler.
    """
    rows = []
    base = SimParams(
        duration=1.0,
        waiting_ticks_mean=2500,
        op_base_seconds_mean=0.03,
        op_ram_gb_mean=2.0,
        op_out_gb_mean=1.0,
        cache_gb_per_pool=8.0,
        scan_ticks_per_gb=50.0,
        cold_start_ticks=100,
        max_pipelines=0,
        max_ops_per_pipeline=0,
        max_containers=64,
        seed=11,
    )
    n_lanes = 8
    for scen in list_scenarios():
        lanes = scenario_lane_batch(scen, base, n_lanes, seed=11)
        for algo in SCENARIO_ALGOS:
            params = base.replace(
                scheduling_algo=algo,
                num_pools=1 if algo in ("naive", "sjf") else 2,
            )
            wls, params = workload_batch_from_traces(lanes, params)
            t0 = time.time()
            states = jax.block_until_ready(fleet_run(params, workloads=wls))
            wall = time.time() - t0
            s = fleet_summary(states, params)
            row = {
                "scenario": scen,
                "scheduler": algo,
                "lanes": n_lanes,
                "throughput_per_s": round(s["throughput_per_s_mean"], 2),
                "mean_latency_s": round(s["mean_latency_s_mean"], 4),
                "cpu_utilization": round(s["cpu_utilization_mean"], 3),
                "preempt_events": round(s["preempt_events_mean"], 1),
                "oom_events": round(s["oom_events_mean"], 1),
                "cache_hit_rate": round(s["cache_hit_rate_mean"], 3),
                "cold_starts": round(s["cold_starts_mean"], 1),
                "wall_s": round(wall, 3),
            }
            rows.append(row)
            if print_rows:
                print(row)
    return rows


RESILIENCE_ALGOS = ("naive", "priority", "priority_pool", "sjf")


def resilience_comparison(print_rows: bool = True) -> list[dict]:
    """Policy × chaos table on shared spot_churn trace batches.

    Each policy replays the SAME 8-lane spot_churn tapes twice — fault
    injection off, then on via ``spot_churn_params`` (crash + outage
    MTBFs, retry budget, per-priority SLO targets) — so the goodput
    delta in a row is attributable to how the policy behaves under
    churn, not to workload variance. ``goodput_degradation_pct`` is the
    faults-on goodput drop vs the same policy's faults-off run.
    """
    from repro.core.scenarios import spot_churn_params

    rows = []
    base = SimParams(
        duration=1.0,
        waiting_ticks_mean=2500,
        op_base_seconds_mean=0.03,
        op_ram_gb_mean=2.0,
        max_pipelines=0,
        max_ops_per_pipeline=0,
        max_containers=64,
        seed=11,
        slo_latency_s=(30.0, 10.0, 5.0),
    )
    n_lanes = 8
    lanes = scenario_lane_batch("spot_churn", base, n_lanes, seed=11)
    for algo in RESILIENCE_ALGOS:
        params = base.replace(
            scheduling_algo=algo,
            num_pools=1 if algo in ("naive", "sjf") else 2,
        )
        wls, params = workload_batch_from_traces(lanes, params)
        calm = fleet_summary(
            jax.block_until_ready(fleet_run(params, workloads=wls)), params
        )
        chaos = spot_churn_params(params)
        wls, _ = workload_batch_from_traces(lanes, params)
        t0 = time.time()
        states = jax.block_until_ready(fleet_run(chaos, workloads=wls))
        wall = time.time() - t0
        s = fleet_summary(states, chaos)
        calm_thr = max(calm["throughput_per_s_mean"], 1e-9)
        row = {
            "scenario": "spot_churn",
            "scheduler": algo,
            "lanes": n_lanes,
            "goodput_per_s": round(s["throughput_per_s_mean"], 2),
            "goodput_calm_per_s": round(calm["throughput_per_s_mean"], 2),
            "goodput_degradation_pct": round(
                (1.0 - s["throughput_per_s_mean"] / calm_thr) * 100, 1
            ),
            "fault_kills": round(s["fault_kills_mean"], 1),
            "retries": round(s["retries_mean"], 1),
            "failed": round(s["failed_mean"], 1),
            "wasted_work_s": round(s["wasted_work_s_mean"], 3),
            "pool_down_s": round(s["pool_down_s_mean"], 3),
            "mean_latency_s": round(s["mean_latency_s_mean"], 4),
            "wall_s": round(wall, 3),
        }
        rows.append(row)
        if print_rows:
            print(row)
    return rows


# policy -> the knobs that arm it (docs/closed-loop.md); every arm
# replays the same retry_storm tapes under the same outage schedule
OVERLOAD_POLICIES = (
    ("admit_all", {}),
    ("queue_threshold", {"admit_queue_limit": 3}),
    ("token_bucket", {"admit_rate_per_s": 400.0, "admit_burst": 4.0}),
    ("codel", {"codel_target_ticks": 400, "codel_interval_ticks": 200}),
)


def overload_comparison(print_rows: bool = True) -> list[dict]:
    """Admission-policy × overload table on shared retry_storm tapes.

    Each policy replays the SAME 8-lane surge tapes (quiet tail after
    the surge, early pool outages, clients that retry rejects with
    exponential backoff), so the differences in a column are
    attributable to the admission decision alone. ``admitted_fraction``
    vs ``goodput_per_s`` is the throughput-vs-goodput trade;
    ``metastable_lanes`` counts lanes whose backlog never returned to
    its pre-fault level — the arm the control policy (admit_all) loses.
    """
    import numpy as np

    from repro.core.scenarios import retry_storm_params
    from repro.core.state import INF_TICK

    rows = []
    base = SimParams(
        duration=0.08,
        max_pipelines=0,
        max_ops_per_pipeline=0,
        max_containers=16,
        waiting_ticks_mean=150.0,
        op_base_seconds_mean=0.008,
        op_base_seconds_sigma=1.0,
        num_pools=2,
        total_cpus=4,
        total_ram_gb=8,
        scheduling_algo="priority_pool",
        seed=11,
    )
    n_lanes = 8
    lanes = scenario_lane_batch(
        "retry_storm", base.replace(duration=0.06), n_lanes,
        seed=11, surge_factor=6.0,
    )
    for policy, knobs in OVERLOAD_POLICIES:
        wls, params = workload_batch_from_traces(lanes, base)
        armed = retry_storm_params(
            params,
            admission_policy=policy,
            outage_mtbf_s=0.02,
            outage_duration_s=0.006,
            client_max_retries=3,
        ).replace(max_fault_events=2, **knobs)
        t0 = time.time()
        states = jax.block_until_ready(fleet_run(armed, workloads=wls))
        wall = time.time() - t0
        s = fleet_summary(states, armed)
        offered = int(np.asarray(states.offered_total).sum())
        unique = int(np.asarray(states.offered_unique).sum())
        drain = np.asarray(states.drain_tick)
        row = {
            "scenario": "retry_storm",
            "policy": policy,
            "lanes": n_lanes,
            "offered": offered,
            "admitted": int(np.asarray(states.admitted_total).sum()),
            "admitted_fraction": round(s["admitted_fraction_mean"], 3),
            "shed": int(np.asarray(states.shed_total).sum()),
            "deferred": int(np.asarray(states.deferred_total).sum()),
            "client_retries": int(
                np.asarray(states.client_retry_events).sum()
            ),
            "retry_amplification": round(offered / max(unique, 1), 2),
            "goodput_per_s": round(s["throughput_per_s_mean"], 2),
            "mean_latency_s": round(s["mean_latency_s_mean"], 4),
            "drained_lanes": int(np.sum(drain < INF_TICK)),
            "metastable_lanes": int(np.sum(drain >= INF_TICK)),
            "fairness_jain_done": round(s["fairness_jain_done"], 3),
            "wall_s": round(wall, 3),
        }
        rows.append(row)
        if print_rows:
            print(row)
    return rows


if __name__ == "__main__":
    main()
    cache_sensitivity()
    scenario_comparison()
    resilience_comparison()
    overload_comparison()
