"""Render the §Dry-run / §Roofline markdown tables from the dry-run JSON
reports.

    PYTHONPATH=src python -m benchmarks.roofline_report \
        reports/dryrun_optimized.json [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import json
import pathlib


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def fmt_ms(s):
    if s >= 1.0:
        return f"{s:.2f}s"
    return f"{s*1e3:.1f}ms"


def render(path: str, mesh: str = "single", out_md: bool = True) -> str:
    rows = json.loads(pathlib.Path(path).read_text())
    rows = [r for r in rows if r.get("mesh") == mesh]
    lines = []
    hdr = (
        "| arch | shape | status | peak GiB/dev | fits | t_comp | t_mem |"
        " t_coll | bound | useful | roofline |"
    )
    lines.append(hdr)
    lines.append("|" + "---|" * 11)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | SKIP ({r['reason'][:40]}) |"
                + " — |" * 8
            )
            continue
        if r["status"] == "error":
            lines.append(
                f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | — | — |"
                f" — | — | — |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok |"
            f" {fmt_bytes(r['peak_bytes_per_device'])} |"
            f" {'Y' if r['fits_hbm'] else 'N'} |"
            f" {fmt_ms(r['t_compute_s'])} | {fmt_ms(r['t_memory_s'])} |"
            f" {fmt_ms(r['t_collective_s'])} | {r['dominant'][:4]} |"
            f" {r['useful_flops_fraction']*100:.0f}% |"
            f" {r['roofline_fraction']*100:.1f}% |"
        )
    return "\n".join(lines)


def summarize(path: str) -> str:
    rows = json.loads(pathlib.Path(path).read_text())
    ok = [r for r in rows if r["status"] == "ok"]
    sk = [r for r in rows if r["status"] == "skipped"]
    er = [r for r in rows if r["status"] == "error"]
    fits = [r for r in ok if r.get("fits_hbm")]
    return (
        f"{len(rows)} cells: {len(ok)} compiled ok ({len(fits)} fit 16 GiB/chip), "
        f"{len(sk)} documented skips, {len(er)} errors"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("report")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    print(summarize(args.report))
    print()
    print(render(args.report, args.mesh))


if __name__ == "__main__":
    main()
