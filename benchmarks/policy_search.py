"""Policy-search benchmarks (docs/policy-search.md).

Two entry points, both recorded into ``BENCH_fleet.json``:

* ``search_smoke`` — a tiny-budget CEM run (≤16 candidates, ONE
  scenario family) that rides the CI bench-smoke job. It asserts the
  search machinery end to end: every named baseline in the grid is
  weakly dominated by some Pareto-front member (the baselines ride in
  every generation's candidate block, so a front that fails this has a
  dominance or NaN-guard bug, not a search-quality problem), and it
  reports candidates/s throughput for the ``search_rows`` block.
* ``acceptance_search`` — the PR's acceptance run: a 64-candidate CEM
  over TWO scenario families (bursty + heavy_tail lanes round-robined
  on a deliberately small 4-CPU box with cloud bursting enabled, so
  the premium overflow decouples cost from raw utilisation). Run
  twice from the same seed and asserted byte-identical, it must
  return a front containing a champion that weakly dominates every
  named baseline on (mean latency, utilisation, cost_dollars); the
  run's candidate history is what ``benchmarks.run`` records under
  ``search_history``.

All objectives are minimised — see ``repro.search.grid.OBJECTIVES``
for why utilisation counts as footprint rather than merit here.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SimParams
from repro.search import cem_search, weakly_dominates
from repro.search.grid import scenario_factory

# the search arena: a SATURATING 4-CPU box — the horizon is generous
# enough that every baseline finishes every pipeline, so the censored
# latency estimator reduces to true latency and the utilisation/cost
# spread measures pure efficiency (cache-miss rescans, preemption
# restarts, cloud-overflow premium: with cloud bursting on, overflow
# pays a 1.5x premium, which keeps cost a separate axis from raw
# utilisation). In an overloaded arena the dominance target is
# geometrically unreachable: whoever completes least anchors the
# utilisation envelope while whoever completes most anchors the
# latency envelope, and no single policy can do both.
SEARCH_PARAMS = dict(
    seed=0,
    scheduling_algo="policy",
    max_pipelines=24,
    max_containers=32,
    duration=0.2,
    waiting_ticks_mean=500.0,
    op_base_seconds_mean=0.002,
    num_pools=2,
    total_cpus=4,
    total_ram_gb=8,
    cache_gb_per_pool=4.0,
    scan_ticks_per_gb=100.0,
    cold_start_ticks=40,
    container_warm_ticks=2_000,
    cloud_scaling=True,
)


def _arena() -> SimParams:
    return SimParams.from_dict(dict(SEARCH_PARAMS))


def _assert_front_dominates_baselines(res) -> None:
    """Every named baseline must be weakly dominated by some front
    member (on all objective columns — the baselines themselves sit in
    the candidate pool, so this checks the Pareto/NaN machinery)."""
    for name, brow in zip(res.baseline_names, res.baseline_objectives):
        covered = any(
            weakly_dominates(frow, brow) for frow in res.pareto_objectives
        )
        assert covered, (
            f"no Pareto-front member weakly dominates baseline {name!r} "
            f"({brow.tolist()})"
        )


def _row(name: str, res, wall_s: float, n_candidates: int) -> dict:
    return {
        "search": name,
        "candidates": n_candidates,
        "evaluations": res.evaluations,
        "wall_s": round(wall_s, 3),
        "candidates_per_s": round(n_candidates / max(wall_s, 1e-9), 2),
        "lane_evals_per_s": round(res.evaluations / max(wall_s, 1e-9), 1),
        "front_size": int(len(res.pareto_objectives)),
        "champion": res.champion is not None,
    }


def search_smoke(print_rows: bool = True) -> list[dict]:
    """CI smoke: ≤16 candidates over ONE scenario family."""
    generations, population = 1, 12  # 12 candidates: 6 baselines + 6 samples
    make = scenario_factory(["bursty"], _arena(), 4, seed=7)
    t0 = time.time()
    res = cem_search(
        make, seed=3, generations=generations, population=population,
        rungs=(0.5, 1.0),
    )
    wall = time.time() - t0
    n_cand = generations * population
    assert n_cand <= 16, "smoke budget is <= 16 candidates"
    _assert_front_dominates_baselines(res)
    assert res.pareto_objectives.shape[0] >= 1, "empty Pareto front"
    row = _row("cem_smoke", res, wall, n_cand)
    if print_rows:
        print(row)
    return [row]


def _history_block(res) -> dict:
    """The compact candidate-history artifact committed to
    BENCH_fleet.json: per generation the full-fidelity survivor
    policies + objectives (the rows that fed the front and the elite
    refit), plus the judgement baselines, front, and champion. The
    byte-exact full record (every rung's scores) stays in
    ``SearchResult.to_json()`` for the determinism tests."""
    gens = []
    for g in res.history:
        full = g["rungs"][-1]
        gens.append(
            {
                "generation": g["generation"],
                "best_score": g["best_score"],
                "survivors": g["survivors"],
                "elites": g["elites"],
                "policies": [g["policies"][i] for i in g["survivors"]],
                "objectives": full["objectives"],
                "scores": full["scores"],
                "mean": g["mean"],
                "std": g["std"],
            }
        )
    return {
        "seed": res.seed,
        "objectives": list(res.objectives),
        "evaluations": res.evaluations,
        "baselines": {
            n: [float(v) for v in row]
            for n, row in zip(res.baseline_names, res.baseline_objectives)
        },
        "generations": gens,
        "pareto_objectives": res.pareto_objectives.tolist(),
        "pareto_policies": res.pareto_policies.tolist(),
        "champion": res.champion,
        "meta": res.meta,
    }


def acceptance_search(print_rows: bool = True) -> tuple[list[dict], dict]:
    """The acceptance run: 64 candidates, 2 scenario families, run
    TWICE and asserted bitwise-reproducible; returns ``(search_rows,
    search_history)`` for BENCH_fleet.json."""
    generations, population = 4, 16  # 4 x 16 = 64 candidates
    make = scenario_factory(["bursty", "heavy_tail"], _arena(), 4, seed=7)

    def one():
        t0 = time.time()
        r = cem_search(
            make, seed=3, generations=generations, population=population,
            rungs=(0.5, 1.0),
        )
        return r, time.time() - t0

    res, wall = one()
    res2, _ = one()
    assert res.to_json() == res2.to_json(), (
        "same-seed acceptance search is not bitwise-reproducible"
    )
    _assert_front_dominates_baselines(res)
    assert res.champion is not None, (
        "no front member weakly dominates every named baseline on "
        "(mean latency, utilisation, cost_dollars)"
    )
    # the champion's acceptance triple, spelled out for the record
    tri = np.asarray(res.champion["objectives"])[[0, 2, 3]]
    base_tri = res.baseline_objectives[:, [0, 2, 3]]
    assert all(weakly_dominates(tri, b) for b in base_tri)
    row = _row("cem_acceptance", res, wall, generations * population)
    row["champion_objectives"] = [
        float(v) for v in res.champion["objectives"]
    ]
    if print_rows:
        print(row)
        print(
            "champion (lat, util, cost):", [float(v) for v in tri],
            "vs baseline envelope:",
            [float(v) for v in base_tri.min(axis=0)],
        )
    return [row], _history_block(res)


def main(print_rows: bool = True) -> tuple[list[dict], dict]:
    rows, history = acceptance_search(print_rows=print_rows)
    return rows, history


if __name__ == "__main__":
    main()
