"""Interleaving experiment (paper §2.2 / Table 1 claim): running
interactive and batch workloads on ONE shared pool beats splitting the
same hardware into dedicated pools — the XFaaS/Borg observation that
motivates the unified FaaS runtime."""
from __future__ import annotations

from repro.core import SimParams, generate_workload, run



from repro.core.engine_python import pipelines_from_workload
from repro.core import workload_from_pipelines


def main(print_rows: bool = True) -> dict:
    # heavy load so contention matters (the regime the claim is about)
    base = dict(
        duration=2.0,
        waiting_ticks_mean=600,
        op_base_seconds_mean=0.06,
        op_ram_gb_mean=2.0,
        max_pipelines=512,
        max_containers=128,
        seed=5,
        total_cpus=32.0,
        total_ram_gb=64.0,
    )
    # --- interleaved: ONE shared system, priority scheduler ------------
    inter = SimParams(**base, scheduling_algo="priority", num_pools=1)
    wl = generate_workload(inter)
    res_inter = run(inter, workload=wl).summary()

    # --- dedicated systems: split the SAME workload by kind onto two
    # half-size, isolated instances (the "warehouse + batch cluster"
    # deployment the paper argues against) ------------------------------
    pipes = pipelines_from_workload(wl)
    inter_pipes = [p for p in pipes if int(p.priority) > 0]
    batch_pipes = [p for p in pipes if int(p.priority) == 0]
    half = dict(base)
    half["total_cpus"] = base["total_cpus"] / 2
    half["total_ram_gb"] = base["total_ram_gb"] / 2
    split_res = []
    for sub in (inter_pipes, batch_pipes):
        for p in sub:
            p.failed_before, p.last_cpus, p.last_ram_gb = False, 0.0, 0.0
        params = SimParams(**half, scheduling_algo="priority", num_pools=1)
        wl_sub = workload_from_pipelines(
            [_reindex(i, p) for i, p in enumerate(sub)], params
        )
        split_res.append(run(params, workload=wl_sub).summary())
    s_inter, s_batch = split_res

    done_split = s_inter["done"] + s_batch["done"]
    out = {
        "interleaved": {
            "done": res_inter["done"],
            "throughput_per_s": res_inter["throughput_per_s"],
            "interactive_latency_s": res_inter["per_priority"]["interactive"]["mean_latency_s"],
            "cpu_utilization": res_inter["cpu_utilization"],
        },
        "split_dedicated": {
            "done": done_split,
            "throughput_per_s": done_split / base["duration"],
            "interactive_latency_s": s_inter["per_priority"]["interactive"]["mean_latency_s"],
            "cpu_utilization": (
                s_inter["cpu_utilization"] + s_batch["cpu_utilization"]
            ) / 2,
        },
    }
    if print_rows:
        for k, v in out.items():
            print(k, v)
    return out


def _reindex(i, p):
    import dataclasses

    return dataclasses.replace(p, pid=i)


if __name__ == "__main__":
    main()
