"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus the
section tables used by EXPERIMENTS.md, and writes machine-readable
fleet-throughput results to ``BENCH_fleet.json`` so the perf trajectory
is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--fast | --smoke]

``--smoke`` runs only a tiny fleet bench and validates the JSON output
(used by CI to keep the benchmark code from rotting).
"""
from __future__ import annotations

import argparse
import contextlib
import json
import pathlib
import time

BENCH_JSON = pathlib.Path("BENCH_fleet.json")
# smoke runs validate the same machinery but must not clobber the
# committed cross-PR perf record
BENCH_JSON_SMOKE = pathlib.Path("BENCH_fleet.smoke.json")
# the COMMITTED smoke baseline the CI regression gate compares against
# (BENCH_fleet.smoke.json itself is gitignored scratch); re-record
# deliberately with --record-smoke-baseline
SMOKE_BASELINE = pathlib.Path(__file__).resolve().parent / "smoke_baseline.json"


def _csv(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


ENGINE_ROWS = ("vmap", "fused", "sharded")
# small Perfetto trace written by --smoke runs; uploaded as a CI
# artifact next to BENCH_fleet.smoke.json (docs/observability.md)
SMOKE_PERFETTO = pathlib.Path("BENCH_trace.perfetto.json")


def write_fleet_json(
    rows: list[dict],
    smoke: bool,
    phase_breakdown: dict | None = None,
    scenario_rows: list[dict] | None = None,
    search_rows: list[dict] | None = None,
    search_history: dict | None = None,
) -> dict:
    """Persist the fleet-engine rows; returns the validated payload.

    The ``vmap`` row is the benchmark-local reconstruction of the
    deleted legacy fleet path (see ``engine_throughput``), kept so the
    lane-major core's speedup stays tracked across PRs; ``sharded`` is
    the same core shard_mapped over every local device (event-density
    lane binning on). The ``selection`` row is the scheduler-selection
    microbench (three-pass helpers vs the fused ``sched_select``
    kernel), and ``phase_breakdown`` the per-event phase shares —
    both feed EXPERIMENTS.md §Scheduler-Perf. ``scenario_rows``
    (``engine_throughput.scenario_fleet_bench``) track fused/sharded
    throughput per scenario family — realistic-skew numbers for future
    binning/engine PRs, not just seed-batch variance. ``search_rows``
    (``benchmarks.policy_search``) track policy-search throughput in
    candidates/s, and ``search_history`` is the acceptance run's
    candidate-history artifact (docs/policy-search.md).
    """
    path = BENCH_JSON_SMOKE if smoke else BENCH_JSON
    fleet_rows = [r for r in rows if "fleet_engine" in r]
    by_engine = {r["fleet_engine"]: r for r in fleet_rows}
    payload = {
        "benchmark": "fleet_engine_throughput",
        "smoke": smoke,
        "fleet_size": next(
            (r["fleet_size"] for r in fleet_rows if "fleet_size" in r), 0
        ),
        "devices": by_engine.get("sharded", {}).get("devices", 1),
        "rows": fleet_rows,
        "speedup_fused_vs_vmap": by_engine.get("fused", {}).get(
            "speedup_vs_vmap"
        ),
        "speedup_sharded_vs_vmap": by_engine.get("sharded", {}).get(
            "speedup_vs_vmap"
        ),
    }
    traced = by_engine.get("fused_traced")
    if traced is not None:
        # telemetry cost on the fused path (engine_throughput.
        # trace_overhead_bench): tracked across PRs with a <10% bar
        # (EXPERIMENTS.md §Telemetry)
        payload["trace_overhead_pct"] = traced.get("trace_overhead_pct")
    faulted = by_engine.get("fused_faults")
    if faulted is not None:
        # chaos-layer cost on the fused path (engine_throughput.
        # faults_overhead_bench, EXPERIMENTS.md §Scheduler-Resilience)
        payload["faults_overhead_pct"] = faulted.get("faults_overhead_pct")
    closed = by_engine.get("fused_closed_loop")
    if closed is not None:
        # closed-loop-layer cost on the fused path (engine_throughput.
        # closed_loop_overhead_bench, docs/closed-loop.md)
        payload["closed_loop_overhead_pct"] = closed.get(
            "closed_loop_overhead_pct"
        )
    if phase_breakdown is not None:
        payload["phase_breakdown"] = phase_breakdown
    if scenario_rows is not None:
        payload["scenario_rows"] = scenario_rows
    if search_rows is not None:
        payload["search_rows"] = search_rows
    if search_history is not None:
        payload["search_history"] = search_history
    path.write_text(json.dumps(payload, indent=2) + "\n")
    # read-back validation: well-formed JSON with the tracked metrics
    loaded = json.loads(path.read_text())
    assert loaded["benchmark"] == "fleet_engine_throughput"
    assert loaded["rows"], "no fleet rows recorded"
    recorded = {r["fleet_engine"] for r in loaded["rows"]}
    assert recorded >= set(ENGINE_ROWS), "missing fleet path rows"
    if not smoke:
        assert "selection" in recorded, "missing selection microbench row"
        assert "apply" in recorded, "missing apply microbench row"
    for r in loaded["rows"]:
        if r["fleet_engine"] not in ENGINE_ROWS:
            continue
        for key in ("fleet_engine", "fleet_size", "wall_s", "wall_s_min",
                    "ticks_per_s", "sim_s_per_wall_s"):
            assert key in r, f"missing {key} in {r}"
    if scenario_rows is not None:
        recorded_scen = {
            (r["scenario"], r["fleet_engine"])
            for r in loaded["scenario_rows"]
        }
        scens = {s for s, _ in recorded_scen}
        assert len(scens) >= 4, f"expected >= 4 scenario families: {scens}"
        for s in scens:
            assert {(s, "fused"), (s, "sharded")} <= recorded_scen, (
                f"scenario {s} missing a fused/sharded row"
            )
        for r in loaded["scenario_rows"]:
            for key in ("scenario", "fleet_engine", "wall_s_min",
                        "ticks_per_s"):
                assert key in r, f"missing {key} in {r}"
    if search_rows is not None:
        assert loaded["search_rows"], "no search rows recorded"
        for r in loaded["search_rows"]:
            for key in ("search", "candidates", "evaluations",
                        "candidates_per_s", "front_size", "champion"):
                assert key in r, f"missing {key} in {r}"
    if search_history is not None:
        sh = loaded["search_history"]
        for key in ("seed", "objectives", "generations", "baselines",
                    "pareto_objectives", "champion", "evaluations"):
            assert key in sh, f"missing {key} in search_history"
        assert sh["champion"] is not None, (
            "acceptance search_history recorded without a champion"
        )
    print(f"wrote {path} "
          f"(speedup vs vmap baseline: fused "
          f"{loaded['speedup_fused_vs_vmap']}, sharded "
          f"{loaded['speedup_sharded_vs_vmap']} "
          f"on {loaded['devices']} device(s))")
    return loaded


def _fused_vs_vmap(payload: dict) -> float | None:
    rows = {r["fleet_engine"]: r for r in payload.get("rows", [])}
    fused, vmap = rows.get("fused"), rows.get("vmap")
    if not fused or not vmap:
        return None
    return fused["ticks_per_s"] / max(vmap["ticks_per_s"], 1)


def _faults_ratio(payload: dict) -> float | None:
    """Faults-ON / faults-OFF wall ratio (same-run, machine-neutral)."""
    pct = payload.get("faults_overhead_pct")
    if pct is None:
        return None
    return 1.0 + pct / 100.0


def _closed_loop_ratio(payload: dict) -> float | None:
    """Closed-loop-ON / OFF wall ratio (same-run, machine-neutral)."""
    pct = payload.get("closed_loop_overhead_pct")
    if pct is None:
        return None
    return 1.0 + pct / 100.0


def check_smoke_regression(loaded: dict, baseline: dict | None) -> bool | None:
    """One gate measurement: did fused throughput regress >20% vs the
    *committed* smoke baseline?

    Absolute ticks/s is not comparable across runs — CI runners and
    this container differ in speed and background load by more than
    any real regression — so the gate compares the fused engine
    against the vmap baseline *measured in the same run*: the
    fused/vmap throughput ratio normalises machine speed out, leaving
    the hot-path code as the only variable. Returns True when the
    ratio holds ≥80% of the recorded baseline's, False when it drops
    below, None when no baseline is available (gate skipped). The
    caller retries a False — a real regression fails every attempt, a
    runner load spike does not.
    """
    if not baseline or baseline.get("benchmark") != "fleet_engine_throughput":
        print("no recorded smoke baseline - regression gate skipped")
        return None
    base_ratio = _fused_vs_vmap(baseline)
    new_ratio = _fused_vs_vmap(loaded)
    if base_ratio is None or new_ratio is None:
        print("smoke baseline lacks fused/vmap rows - regression gate skipped")
        return None
    rel = new_ratio / base_ratio
    verdict = "OK" if rel >= 0.8 else "REGRESSED"
    print(f"fused/vmap smoke ratio: {new_ratio:.2f} vs recorded "
          f"{base_ratio:.2f} ({rel:.2f}x) {verdict}")
    ok = rel >= 0.8
    # second gate, same normalisation trick: the faults-ON/faults-OFF
    # wall ratio is measured in one run, so machine speed cancels and a
    # >20% regression means the chaos layer's hot-path cost grew (e.g.
    # the nxt_fault register gate stopped eliding the fault pass).
    # Skipped when the committed baseline predates the metric.
    base_fr = _faults_ratio(baseline)
    new_fr = _faults_ratio(loaded)
    if base_fr is None or new_fr is None:
        print("no recorded faults ratio - faults-overhead gate skipped")
        return ok
    frel = new_fr / base_fr
    fverdict = "OK" if frel <= 1.2 else "REGRESSED"
    print(f"faults-on/off smoke ratio: {new_fr:.2f} vs recorded "
          f"{base_fr:.2f} ({frel:.2f}x) {fverdict}")
    ok = ok and frel <= 1.2
    # third gate, same trick again: the closed-loop-ON/OFF wall ratio is
    # a same-run measurement, so a >20% rise means the admission/client
    # pass itself got slower (e.g. its static gate stopped compiling the
    # layer out of loop-off programs). Skipped when the committed
    # baseline predates the metric.
    base_cl = _closed_loop_ratio(baseline)
    new_cl = _closed_loop_ratio(loaded)
    if base_cl is None or new_cl is None:
        print("no recorded closed-loop ratio - closed-loop gate skipped")
        return ok
    crel = new_cl / base_cl
    cverdict = "OK" if crel <= 1.2 else "REGRESSED"
    print(f"closed-loop on/off smoke ratio: {new_cl:.2f} vs recorded "
          f"{base_cl:.2f} ({crel:.2f}x) {cverdict}")
    return ok and crel <= 1.2


def _maybe_profile(trace_dir: str | None):
    """Opt-in ``jax.profiler.trace`` around the benchmark body; the
    engine's ``jax.named_scope`` phase annotations (phase1 / scheduler /
    apply / advance / telemetry) label the resulting timeline. View the
    output in TensorBoard or https://ui.perfetto.dev."""
    if not trace_dir:
        return contextlib.nullcontext()
    import jax

    print(f"profiling to {trace_dir} (open in Perfetto or TensorBoard)")
    return jax.profiler.trace(trace_dir)


def _chaos_smoke() -> None:
    """CI chaos smoke (docs/faults.md): the spot_churn scenario under
    two schedulers must finish with ZERO user-visible failures when the
    retry budget is on, and with nonzero FAILED pipelines when
    ``max_retries=0`` — both sides of the retry contract, asserted on
    the real fused engine every CI run."""
    import numpy as np

    from repro.core import SimParams, fleet_run
    from repro.core.scenarios import scenario_fleet, spot_churn_params

    base = SimParams(
        duration=0.05,
        max_pipelines=0,
        max_ops_per_pipeline=0,
        max_containers=32,
        waiting_ticks_mean=400.0,
        op_base_seconds_mean=0.004,
        num_pools=2,
    )
    for algo in ("priority", "priority_pool"):
        wls, params = scenario_fleet(
            "spot_churn", base.replace(scheduling_algo=algo), 4
        )
        chaos = spot_churn_params(params)
        lenient = fleet_run(chaos, workloads=wls)
        kills = int(np.asarray(lenient.fault_kills).sum())
        failed = int(np.asarray(lenient.failed_count).sum())
        retries = int(np.asarray(lenient.retry_events).sum())
        assert kills > 0, f"{algo}: chaos smoke injected no kills"
        assert failed == 0, (
            f"{algo}: {failed} pipelines FAILED despite a retry budget"
        )
        assert retries > 0, f"{algo}: kills absorbed without any retries"

        wls, params = scenario_fleet(
            "spot_churn", base.replace(scheduling_algo=algo), 4
        )
        strict = fleet_run(
            spot_churn_params(params, max_retries=0), workloads=wls
        )
        failed0 = int(np.asarray(strict.failed_count).sum())
        assert failed0 > 0, (
            f"{algo}: max_retries=0 chaos run failed no pipelines"
        )
        print(
            f"chaos smoke {algo}: kills={kills} retries={retries} "
            f"failed(budget)=0 failed(no-budget)={failed0}"
        )
    print("chaos smoke OK")


def _overload_smoke() -> None:
    """CI overload smoke (docs/closed-loop.md): the retry_storm scenario
    must produce a reproducible retry storm that a queue-threshold
    admission policy survives and ``admit_all`` does not. Both arms
    replay the SAME surge tapes (quiet tail after the surge, two early
    pool outages); the treatment arm rejects at the gate — client
    retries amplify its offered load and the excess is shed, but the
    backlog drains back to its pre-fault level on every lane. The
    control arm admits everything: amplification stays 1.0 (nothing for
    clients to retry) yet the backlog never recovers — the metastable
    signature, asserted on the real fused engine every CI run."""
    import numpy as np

    from repro.core import SimParams, fleet_run, workload_batch_from_traces
    from repro.core.scenarios import retry_storm_params, scenario_lane_batch
    from repro.core.state import INF_TICK

    base = SimParams(
        duration=0.08,
        max_pipelines=0,
        max_ops_per_pipeline=0,
        max_containers=16,
        waiting_ticks_mean=150.0,
        op_base_seconds_mean=0.008,
        op_base_seconds_sigma=1.0,
        num_pools=2,
        total_cpus=4,
        total_ram_gb=8,
        scheduling_algo="priority_pool",
    )
    n_lanes = 4
    # tape stops at 0.06s: a quiet tail the backlog COULD drain into —
    # whether it does is exactly what separates the two arms
    lanes = scenario_lane_batch(
        "retry_storm", base.replace(duration=0.06), n_lanes,
        seed=3, surge_factor=6.0,
    )

    def arm(policy: str, limit: int = 0):
        wls, params = workload_batch_from_traces(lanes, base)
        p = retry_storm_params(
            params,
            admission_policy=policy,
            admit_queue_limit=limit,
            outage_mtbf_s=0.02,
            outage_duration_s=0.006,
            client_max_retries=3,
        ).replace(max_fault_events=2)  # outages stop early, tail is calm
        st = fleet_run(p, workloads=wls)
        offered = int(np.asarray(st.offered_total).sum())
        unique = int(np.asarray(st.offered_unique).sum())
        return {
            "amp": offered / max(unique, 1),
            "shed": int(np.asarray(st.shed_total).sum()),
            "client_retries": int(np.asarray(st.client_retry_events).sum()),
            "faulted": int(np.sum(np.asarray(st.last_fault_tick) < INF_TICK)),
            "drained": int(np.sum(np.asarray(st.drain_tick) < INF_TICK)),
        }

    control = arm("admit_all")
    treated = arm("queue_threshold", limit=3)
    for name, r in (("admit_all", control), ("queue_threshold", treated)):
        assert r["faulted"] == n_lanes, (
            f"{name}: only {r['faulted']}/{n_lanes} lanes saw an outage"
        )
    # the storm is real: client retries amplify the treated arm's load
    assert treated["amp"] > 1.5, (
        f"queue_threshold: no retry storm (amplification {treated['amp']:.2f})"
    )
    assert treated["shed"] > 0, "queue_threshold: policy never shed load"
    assert control["amp"] == 1.0 and control["shed"] == 0, (
        f"admit_all rejected something: amp={control['amp']:.2f} "
        f"shed={control['shed']}"
    )
    # ...and the policy survives it while admit_all goes metastable
    assert treated["drained"] == n_lanes, (
        f"queue_threshold: backlog stuck above the pre-fault level on "
        f"{n_lanes - treated['drained']}/{n_lanes} lanes"
    )
    assert control["drained"] < n_lanes, (
        "admit_all drained every lane - the smoke config no longer "
        "overloads the fleet"
    )
    print(
        f"overload smoke: admit_all amp={control['amp']:.2f} "
        f"drained={control['drained']}/{n_lanes} | queue_threshold "
        f"amp={treated['amp']:.2f} shed={treated['shed']} "
        f"retries={treated['client_retries']} "
        f"drained={treated['drained']}/{n_lanes}"
    )
    print("overload smoke OK")


def _write_smoke_perfetto() -> None:
    """A small real Perfetto trace for the CI artifact: one traced
    single-sim run, exported with ``telemetry.to_perfetto_json``."""
    from repro.core import SimParams, run, to_perfetto_json

    params = SimParams(
        duration=0.05,
        scheduling_algo="priority_pool",
        num_pools=2,
        max_pipelines=32,
        max_containers=32,
        waiting_ticks_mean=400.0,
        op_base_seconds_mean=0.004,
        cache_gb_per_pool=4.0,
        scan_ticks_per_gb=50.0,
        cold_start_ticks=40,
        container_warm_ticks=2_000,
    )
    res = run(params, trace=True)
    SMOKE_PERFETTO.write_text(to_perfetto_json(res.trace, res.params))
    print(f"wrote {SMOKE_PERFETTO} ({res.trace.n} events, "
          f"{res.trace.events_dropped} dropped)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the slower benches (tick engine, fleet)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fleet bench only; asserts BENCH_fleet.json "
                         "is produced and well-formed, and fails if fused "
                         "throughput or the faults-on/off overhead ratio "
                         "regressed >20% vs the recorded smoke baseline (CI)")
    ap.add_argument("--no-regression-gate", action="store_true",
                    help="skip the --smoke fused-throughput regression gate")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="wrap the benchmark body in jax.profiler.trace(DIR); "
                         "the engine's named_scope phase annotations label "
                         "the timeline (view in Perfetto / TensorBoard)")
    ap.add_argument("--record-smoke-baseline", action="store_true",
                    help="with --smoke: run the smoke bench three times and "
                         "record the LOWEST fused/vmap ratio as the committed "
                         "baseline (benchmarks/smoke_baseline.json) instead "
                         "of gating — a conservative floor, so load spikes "
                         "on the recording host don't set an unbeatable bar")
    args = ap.parse_args()
    if (args.record_smoke_baseline or args.no_regression_gate) and not args.smoke:
        ap.error("--record-smoke-baseline / --no-regression-gate only "
                 "apply to --smoke runs")

    if args.smoke:
        from benchmarks import engine_throughput

        baseline = None
        if SMOKE_BASELINE.exists():
            try:
                baseline = json.loads(SMOKE_BASELINE.read_text())
            except json.JSONDecodeError:
                pass
        if args.record_smoke_baseline:
            # conservative floor: lowest fused/vmap ratio of three runs,
            # so one quiet-host run doesn't set a bar the gate's 20%
            # margin can't absorb under normal runner load
            candidates = []
            faults_ratios = []
            closed_ratios = []
            for i in range(3):
                rows = engine_throughput.fleet_bench(smoke=True)
                rows += engine_throughput.faults_overhead_bench(smoke=True)
                rows += engine_throughput.closed_loop_overhead_bench(
                    smoke=True
                )
                loaded = write_fleet_json(rows, smoke=True)
                ratio = _fused_vs_vmap(loaded)
                fr = _faults_ratio(loaded)
                cr = _closed_loop_ratio(loaded)
                print(f"recording run {i + 1}/3: fused/vmap {ratio:.2f}, "
                      f"faults on/off {fr:.2f}, closed-loop on/off {cr:.2f}")
                candidates.append((ratio, loaded))
                faults_ratios.append(fr)
                closed_ratios.append(cr)
            _, floor = min(candidates, key=lambda c: c[0])
            # the faults/closed-loop gates fail on ratios ABOVE baseline,
            # so their conservative record is the highest of the three runs
            frs = [fr for fr in faults_ratios if fr is not None]
            if frs:
                floor["faults_overhead_pct"] = round(
                    (max(frs) - 1.0) * 100, 1
                )
            crs = [cr for cr in closed_ratios if cr is not None]
            if crs:
                floor["closed_loop_overhead_pct"] = round(
                    (max(crs) - 1.0) * 100, 1
                )
            SMOKE_BASELINE.write_text(json.dumps(floor, indent=2) + "\n")
            print(f"recorded smoke baseline (floor of 3) -> {SMOKE_BASELINE}")
            print("benchmarks smoke OK")
            return
        with _maybe_profile(args.profile):
            rows = engine_throughput.fleet_bench(smoke=True)
            rows += engine_throughput.trace_overhead_bench(smoke=True)
            rows += engine_throughput.faults_overhead_bench(smoke=True)
            rows += engine_throughput.closed_loop_overhead_bench(smoke=True)
        for r in rows:
            print(r)
        from benchmarks import policy_search

        search_rows = policy_search.search_smoke()
        loaded = write_fleet_json(rows, smoke=True, search_rows=search_rows)
        _write_smoke_perfetto()
        _chaos_smoke()
        _overload_smoke()
        if not args.no_regression_gate:
            ok = check_smoke_regression(loaded, baseline)
            attempts = 1
            while ok is False and attempts < 3:
                # re-measure before failing: a real hot-path regression
                # reproduces on every run, a runner load spike does not
                print(f"re-measuring (attempt {attempts + 1}/3)...")
                rows = engine_throughput.fleet_bench(smoke=True)
                rows += engine_throughput.faults_overhead_bench(smoke=True)
                rows += engine_throughput.closed_loop_overhead_bench(
                    smoke=True
                )
                loaded = write_fleet_json(rows, smoke=True)
                ok = check_smoke_regression(loaded, baseline)
                attempts += 1
            if ok is False:
                raise SystemExit(
                    "smoke gate failed in 3/3 measurements: fused/vmap "
                    "throughput down >20%, or the faults-on/off or "
                    "closed-loop-on/off overhead ratio up >20% "
                    "vs the recorded baseline; if intentional, re-record the "
                    "committed baseline with `--smoke "
                    "--record-smoke-baseline` "
                    "(benchmarks/smoke_baseline.json), or pass "
                    "--no-regression-gate"
                )
        print("benchmarks smoke OK")
        return

    print("== tpch_validation (paper Fig. 3) ==")
    from benchmarks import tpch_validation

    t0 = time.time()
    out = tpch_validation.main(print_rows=False)
    _csv(
        "tpch_validation",
        (time.time() - t0) * 1e6 / max(out["n_queries"], 1),
        f"mean_err={out['mean_err_pct']:.2f}%_paper_band=0.44-3.08%",
    )

    print("== scheduler_comparison (paper §4.1.2) ==")
    from benchmarks import scheduler_comparison

    rows = scheduler_comparison.main(print_rows=False)
    for r in rows:
        _csv(
            f"sched_{r['scheduler']}",
            r["wall_s"] * 1e6,
            f"thr={r['throughput_per_s']}/s_p99={r['p99_latency_s']}s"
            f"_pre={r['preempt_events']}",
        )

    if not args.fast:
        print("== cache_sensitivity (data plane, EXPERIMENTS.md) ==")
        rows = scheduler_comparison.cache_sensitivity(print_rows=False)
        for r in rows:
            _csv(
                f"cache_{r['scheduler']}_{r['cache_gb_per_pool']:g}gb",
                r["wall_s"] * 1e6,
                f"hit={r['cache_hit_rate']}_moved={r['bytes_moved_gb']}gb"
                f"_lat={r['mean_latency_s']}s_cold={r['cold_starts']}",
            )

        print("== scenario_comparison (scenario library, docs/scenarios.md) ==")
        rows = scheduler_comparison.scenario_comparison(print_rows=False)
        for r in rows:
            _csv(
                f"scenario_{r['scenario']}_{r['scheduler']}",
                r["wall_s"] * 1e6,
                f"thr={r['throughput_per_s']}/s_lat={r['mean_latency_s']}s"
                f"_pre={r['preempt_events']}_hit={r['cache_hit_rate']}",
            )

        print("== resilience_comparison (chaos layer, docs/faults.md) ==")
        rows = scheduler_comparison.resilience_comparison(print_rows=False)
        for r in rows:
            _csv(
                f"resilience_{r['scheduler']}",
                r["wall_s"] * 1e6,
                f"goodput={r['goodput_per_s']}/s"
                f"_degr={r['goodput_degradation_pct']}%"
                f"_retries={r['retries']}_failed={r['failed']}"
                f"_wasted={r['wasted_work_s']}s",
            )

    print("== interleaving (paper §2.2 / Table 1) ==")
    from benchmarks import interleaving

    out = interleaving.main(print_rows=False)
    for k, v in out.items():
        _csv(
            f"interleave_{k}",
            0.0,
            f"thr={v['throughput_per_s']:.1f}/s"
            f"_interlat={v['interactive_latency_s']:.4f}s"
            f"_util={v['cpu_utilization']:.3f}",
        )

    print("== engine_throughput (§Perf + §Fleet-Perf headline) ==")
    from benchmarks import engine_throughput

    if not args.fast:
        with _maybe_profile(args.profile):
            rows = engine_throughput.main(print_rows=False)
        for r in rows:
            if r.get("fleet_engine") == "selection":
                _csv("engine_selection_microbench", r["fused_us"],
                     f"three_pass={r['three_pass_us']}us_"
                     f"speedup={r['speedup']}x")
                continue
            if r.get("fleet_engine") == "apply":
                _csv("engine_apply_microbench", r["fused_us"],
                     f"legacy={r['legacy_us']}us_speedup={r['speedup']}x")
                continue
            _csv(
                f"engine_{r['engine'].split()[0]}_{r.get('fleet_engine', '')}"
                .rstrip("_"),
                r["wall_s"] * 1e6,
                f"ticks/s={r['ticks_per_s']}",
            )
        scenario_rows = engine_throughput.scenario_fleet_bench()
        for r in scenario_rows:
            _csv(
                f"engine_scenario_{r['scenario']}_{r['fleet_engine']}",
                r["wall_s"] * 1e6,
                f"ticks/s={r['ticks_per_s']}",
            )
        breakdown = engine_throughput.phase_breakdown()
        print("phase breakdown (us/event):", breakdown["us_per_event"])
        print("phase shares:", breakdown["share"])

        print("== policy_search (docs/policy-search.md acceptance) ==")
        from benchmarks import policy_search

        search_rows, search_history = policy_search.acceptance_search()
        write_fleet_json(rows, smoke=False, phase_breakdown=breakdown,
                         scenario_rows=scenario_rows,
                         search_rows=search_rows,
                         search_history=search_history)

    print("== kernels ==")
    from benchmarks import kernels_bench

    rows = kernels_bench.main(print_rows=False)
    for r in rows:
        _csv(r["name"], r["us_per_call"], r.get("derived", ""))

    print("== serving policy pick (bridge) ==")
    from repro.serving.bridge import ServeRequest, evaluate_policies, pick_policy
    import numpy as np

    rng = np.random.default_rng(0)
    trace = [
        ServeRequest(
            arrival_s=float(i * 0.2),
            prompt_tokens=int(rng.integers(64, 512)),
            new_tokens=64,
            interactive=bool(rng.random() < 0.5),
        )
        for i in range(32)
    ]
    from repro.configs.registry import get_arch

    t0 = time.time()
    res = evaluate_policies(trace, get_arch("gemma3_12b").model)
    pol = pick_policy(res)
    _csv("serving_policy_eval", (time.time() - t0) * 1e6 / 3, f"picked={pol}")

    print("benchmarks complete")


if __name__ == "__main__":
    main()
