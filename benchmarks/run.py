"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus the
section tables used by EXPERIMENTS.md, and writes machine-readable
fleet-throughput results to ``BENCH_fleet.json`` so the perf trajectory
is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--fast | --smoke]

``--smoke`` runs only a tiny fleet bench and validates the JSON output
(used by CI to keep the benchmark code from rotting).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

BENCH_JSON = pathlib.Path("BENCH_fleet.json")
# smoke runs validate the same machinery but must not clobber the
# committed cross-PR perf record
BENCH_JSON_SMOKE = pathlib.Path("BENCH_fleet.smoke.json")


def _csv(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


def write_fleet_json(rows: list[dict], smoke: bool) -> dict:
    """Persist the fleet-engine rows; returns the validated payload.

    The ``vmap`` row is the benchmark-local reconstruction of the
    deleted legacy fleet path (see ``engine_throughput``), kept so the
    lane-major core's speedup stays tracked across PRs; ``sharded`` is
    the same core shard_mapped over every local device.
    """
    path = BENCH_JSON_SMOKE if smoke else BENCH_JSON
    fleet_rows = [r for r in rows if "fleet_engine" in r]
    by_engine = {r["fleet_engine"]: r for r in fleet_rows}
    payload = {
        "benchmark": "fleet_engine_throughput",
        "smoke": smoke,
        "fleet_size": fleet_rows[0]["fleet_size"] if fleet_rows else 0,
        "devices": by_engine.get("sharded", {}).get("devices", 1),
        "rows": fleet_rows,
        "speedup_fused_vs_vmap": by_engine.get("fused", {}).get(
            "speedup_vs_vmap"
        ),
        "speedup_sharded_vs_vmap": by_engine.get("sharded", {}).get(
            "speedup_vs_vmap"
        ),
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    # read-back validation: well-formed JSON with the tracked metrics
    loaded = json.loads(path.read_text())
    assert loaded["benchmark"] == "fleet_engine_throughput"
    assert loaded["rows"], "no fleet rows recorded"
    assert {r["fleet_engine"] for r in loaded["rows"]} >= {
        "vmap", "fused", "sharded"
    }, "missing fleet path rows"
    for r in loaded["rows"]:
        for key in ("fleet_engine", "fleet_size", "wall_s", "wall_s_min",
                    "ticks_per_s", "sim_s_per_wall_s"):
            assert key in r, f"missing {key} in {r}"
    print(f"wrote {path} "
          f"(speedup vs vmap baseline: fused "
          f"{loaded['speedup_fused_vs_vmap']}, sharded "
          f"{loaded['speedup_sharded_vs_vmap']} "
          f"on {loaded['devices']} device(s))")
    return loaded


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the slower benches (tick engine, fleet)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fleet bench only; asserts BENCH_fleet.json "
                         "is produced and well-formed (CI)")
    args = ap.parse_args()

    if args.smoke:
        from benchmarks import engine_throughput

        rows = engine_throughput.fleet_bench(smoke=True)
        for r in rows:
            print(r)
        write_fleet_json(rows, smoke=True)
        print("benchmarks smoke OK")
        return

    print("== tpch_validation (paper Fig. 3) ==")
    from benchmarks import tpch_validation

    t0 = time.time()
    out = tpch_validation.main(print_rows=False)
    _csv(
        "tpch_validation",
        (time.time() - t0) * 1e6 / max(out["n_queries"], 1),
        f"mean_err={out['mean_err_pct']:.2f}%_paper_band=0.44-3.08%",
    )

    print("== scheduler_comparison (paper §4.1.2) ==")
    from benchmarks import scheduler_comparison

    rows = scheduler_comparison.main(print_rows=False)
    for r in rows:
        _csv(
            f"sched_{r['scheduler']}",
            r["wall_s"] * 1e6,
            f"thr={r['throughput_per_s']}/s_p99={r['p99_latency_s']}s"
            f"_pre={r['preempt_events']}",
        )

    if not args.fast:
        print("== cache_sensitivity (data plane, EXPERIMENTS.md) ==")
        rows = scheduler_comparison.cache_sensitivity(print_rows=False)
        for r in rows:
            _csv(
                f"cache_{r['scheduler']}_{r['cache_gb_per_pool']:g}gb",
                r["wall_s"] * 1e6,
                f"hit={r['cache_hit_rate']}_moved={r['bytes_moved_gb']}gb"
                f"_lat={r['mean_latency_s']}s_cold={r['cold_starts']}",
            )

    print("== interleaving (paper §2.2 / Table 1) ==")
    from benchmarks import interleaving

    out = interleaving.main(print_rows=False)
    for k, v in out.items():
        _csv(
            f"interleave_{k}",
            0.0,
            f"thr={v['throughput_per_s']:.1f}/s"
            f"_interlat={v['interactive_latency_s']:.4f}s"
            f"_util={v['cpu_utilization']:.3f}",
        )

    print("== engine_throughput (§Perf + §Fleet-Perf headline) ==")
    from benchmarks import engine_throughput

    if not args.fast:
        rows = engine_throughput.main(print_rows=False)
        for r in rows:
            _csv(
                f"engine_{r['engine'].split()[0]}_{r.get('fleet_engine', '')}"
                .rstrip("_"),
                r["wall_s"] * 1e6,
                f"ticks/s={r['ticks_per_s']}",
            )
        write_fleet_json(rows, smoke=False)

    print("== kernels ==")
    from benchmarks import kernels_bench

    rows = kernels_bench.main(print_rows=False)
    for r in rows:
        _csv(r["name"], r["us_per_call"], r.get("derived", ""))

    print("== serving policy pick (bridge) ==")
    from repro.serving.bridge import ServeRequest, evaluate_policies, pick_policy
    import numpy as np

    rng = np.random.default_rng(0)
    trace = [
        ServeRequest(
            arrival_s=float(i * 0.2),
            prompt_tokens=int(rng.integers(64, 512)),
            new_tokens=64,
            interactive=bool(rng.random() < 0.5),
        )
        for i in range(32)
    ]
    from repro.configs.registry import get_arch

    t0 = time.time()
    res = evaluate_policies(trace, get_arch("gemma3_12b").model)
    pol = pick_policy(res)
    _csv("serving_policy_eval", (time.time() - t0) * 1e6 / 3, f"picked={pol}")

    print("benchmarks complete")


if __name__ == "__main__":
    main()
