"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus the
section tables used by EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time


def _csv(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the slower benches (tick engine, fleet)")
    args = ap.parse_args()

    print("== tpch_validation (paper Fig. 3) ==")
    from benchmarks import tpch_validation

    t0 = time.time()
    out = tpch_validation.main(print_rows=False)
    _csv(
        "tpch_validation",
        (time.time() - t0) * 1e6 / max(out["n_queries"], 1),
        f"mean_err={out['mean_err_pct']:.2f}%_paper_band=0.44-3.08%",
    )

    print("== scheduler_comparison (paper §4.1.2) ==")
    from benchmarks import scheduler_comparison

    rows = scheduler_comparison.main(print_rows=False)
    for r in rows:
        _csv(
            f"sched_{r['scheduler']}",
            r["wall_s"] * 1e6,
            f"thr={r['throughput_per_s']}/s_p99={r['p99_latency_s']}s"
            f"_pre={r['preempt_events']}",
        )

    if not args.fast:
        print("== cache_sensitivity (data plane, EXPERIMENTS.md) ==")
        rows = scheduler_comparison.cache_sensitivity(print_rows=False)
        for r in rows:
            _csv(
                f"cache_{r['scheduler']}_{r['cache_gb_per_pool']:g}gb",
                r["wall_s"] * 1e6,
                f"hit={r['cache_hit_rate']}_moved={r['bytes_moved_gb']}gb"
                f"_lat={r['mean_latency_s']}s_cold={r['cold_starts']}",
            )

    print("== interleaving (paper §2.2 / Table 1) ==")
    from benchmarks import interleaving

    out = interleaving.main(print_rows=False)
    for k, v in out.items():
        _csv(
            f"interleave_{k}",
            0.0,
            f"thr={v['throughput_per_s']:.1f}/s"
            f"_interlat={v['interactive_latency_s']:.4f}s"
            f"_util={v['cpu_utilization']:.3f}",
        )

    print("== engine_throughput (§Perf headline) ==")
    from benchmarks import engine_throughput

    if not args.fast:
        rows = engine_throughput.main(print_rows=False)
        for r in rows:
            _csv(
                f"engine_{r['engine'].split()[0]}",
                r["wall_s"] * 1e6,
                f"ticks/s={r['ticks_per_s']}",
            )

    print("== kernels ==")
    from benchmarks import kernels_bench

    rows = kernels_bench.main(print_rows=False)
    for r in rows:
        _csv(r["name"], r["us_per_call"], r.get("derived", ""))

    print("== serving policy pick (bridge) ==")
    from repro.serving.bridge import ServeRequest, evaluate_policies, pick_policy
    import numpy as np

    rng = np.random.default_rng(0)
    trace = [
        ServeRequest(
            arrival_s=float(i * 0.2),
            prompt_tokens=int(rng.integers(64, 512)),
            new_tokens=64,
            interactive=bool(rng.random() < 0.5),
        )
        for i in range(32)
    ]
    from repro.configs.registry import get_arch

    t0 = time.time()
    res = evaluate_policies(trace, get_arch("gemma3_12b").model)
    pol = pick_policy(res)
    _csv("serving_policy_eval", (time.time() - t0) * 1e6 / 3, f"picked={pol}")

    print("benchmarks complete")


if __name__ == "__main__":
    main()
