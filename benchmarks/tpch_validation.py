"""TPC-H validation (paper §4.2 / Fig. 3 analogue).

The paper runs the 22 TPC-H queries (10 GB) on a real Bauplan instance
(c5ad.4xlarge, 16 vCPU / 32 GB) and compares measured runtimes against
Eudoxia's estimates: percent error 0.44-3.08 %, mean 1.74 %; three
queries (11, 16, 22) dropped for too-short telemetry.

Real Bauplan is unreachable from this container, so the methodology is
reproduced against a high-fidelity *oracle executor*: a continuous-time
model of the worker with effects the tick simulator abstracts away —
non-integral time, per-function container startup overhead, and a
deterministic cache-state perturbation of CPU efficiency. The "measured"
runtime is the oracle; Eudoxia replays the same trace with fitted
per-query scaling functions; we report the same percent-error statistic.

Query profile source: published DuckDB-class runtimes for TPC-H SF10 on
a 16-vCPU machine (order-of-magnitude realistic; values recorded in
QUERY_PROFILES below).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Operator,
    Pipeline,
    Priority,
    SimParams,
    TICKS_PER_SECOND,
    run,
    workload_from_pipelines,
)

# (query, base_seconds at 16 vCPUs, alpha, ram_gb) — SF10-class profile
QUERY_PROFILES = {
    1: (0.55, 1.0, 4.2), 2: (0.12, 0.5, 2.1), 3: (0.45, 1.0, 5.6),
    4: (0.30, 1.0, 3.8), 5: (0.50, 1.0, 6.1), 6: (0.18, 1.0, 2.4),
    7: (0.48, 1.0, 5.9), 8: (0.42, 0.5, 5.2), 9: (0.85, 1.0, 7.8),
    10: (0.44, 1.0, 6.3), 12: (0.33, 1.0, 3.5), 13: (0.61, 0.5, 4.9),
    14: (0.21, 1.0, 2.8), 15: (0.25, 1.0, 3.0), 17: (0.58, 0.5, 5.4),
    18: (0.92, 1.0, 8.6), 19: (0.38, 1.0, 4.4), 20: (0.35, 0.5, 3.9),
    21: (0.99, 1.0, 8.1),
    # 11, 16, 22 dropped — "runtime was so short that resource
    # utilization statistics could not be gathered" (paper §4.2)
}

CPUS = 16.0
RAM = 32.0
STARTUP_S = 0.004          # per-function container spawn (oracle-only)


def oracle_runtime_s(q: int, rng: np.random.Generator) -> float:
    """Continuous-time 'real system': exact scaling + startup overhead +
    deterministic cache-efficiency perturbation."""
    base, alpha, _ = QUERY_PROFILES[q]
    eff = 1.0 + rng.uniform(-0.02, 0.02)       # cache/NUMA efficiency
    return STARTUP_S + base / (CPUS ** alpha) * eff


def simulate_runtime_s(q: int, fitted_base: float, alpha: float) -> float:
    """Eudoxia's estimate: replay the single-query trace (whole machine,
    naive scheduler — matches the paper's isolated-query setup)."""
    params = SimParams(
        duration=5.0,
        scheduling_algo="naive",
        total_cpus=CPUS,
        total_ram_gb=RAM,
        max_pipelines=4,
        max_containers=4,
    )
    pipe = Pipeline(
        pid=0,
        priority=Priority.QUERY,
        arrival_tick=0,
        ops=[
            Operator(
                ram_gb=QUERY_PROFILES[q][2],
                base_ticks=int(round(fitted_base * TICKS_PER_SECOND)),
                alpha=alpha,
                level=0,
            )
        ],
    )
    wl = workload_from_pipelines([pipe], params)
    res = run(params, workload=wl, engine="event")
    comp = int(res.state.pipe_completion[0])
    return comp / TICKS_PER_SECOND


def main(print_rows: bool = True) -> dict:
    rng = np.random.default_rng(42)
    errors = []
    rows = []
    for q in sorted(QUERY_PROFILES):
        base, alpha, _ = QUERY_PROFILES[q]
        real = oracle_runtime_s(q, rng)
        # fit the scaling function from the trace the way a user would:
        # two calibration observations (4 and 8 vCPUs) identify both the
        # fixed startup overhead and the scalable base ("plugging
        # real-world scaling functions estimated from traces", paper §6)
        t4 = STARTUP_S + base / (4.0 ** alpha)
        t8 = STARTUP_S + base / (8.0 ** alpha)
        fit_base = (t4 - t8) / (4.0 ** -alpha - 8.0 ** -alpha)
        fit_startup = t8 - fit_base / (8.0 ** alpha)
        # fold the fitted startup into base_ticks at the target CPU count
        fitted_base = fit_startup * (CPUS ** alpha) + fit_base
        sim = simulate_runtime_s(q, fitted_base, alpha)
        err = abs(sim - real) / real * 100.0
        errors.append(err)
        rows.append((q, real, sim, err))
    errors = np.asarray(errors)
    out = {
        "n_queries": len(errors),
        "min_err_pct": float(errors.min()),
        "max_err_pct": float(errors.max()),
        "mean_err_pct": float(errors.mean()),
        "paper_band": (0.44, 3.08, 1.74),
    }
    if print_rows:
        print("q,real_s,sim_s,err_pct")
        for q, real, sim, err in rows:
            print(f"{q},{real:.4f},{sim:.4f},{err:.2f}")
        print(
            f"# percent error: min {out['min_err_pct']:.2f} "
            f"max {out['max_err_pct']:.2f} mean {out['mean_err_pct']:.2f} "
            f"(paper: 0.44 / 3.08 / 1.74)"
        )
    return out


if __name__ == "__main__":
    main()
