"""Simulator engine throughput (paper §3.1 "low-cost" claim, and the
headline §Perf / §Fleet-Perf hillclimbs): the unified lane-major core
vs the Python reference, in simulated-seconds per wall-second, and the
fleet section on a 64-lane batch with skewed per-lane durations/event
counts (LogNormal ``op_base_seconds_sigma=1.2`` — the chained-pipeline
regime where lockstep batching wastes the most work).

The fleet rows compare three paths:

* ``vmap`` — a benchmark-local reconstruction of the DELETED legacy
  fleet path (vmap of a per-simulation event while_loop over the
  reference ``_tick_body`` composition). It exists only here, as the
  baseline the lane-major core is tracked against across PRs
  (BENCH_fleet.json).
* ``fused`` — the lane-major core, ``fleet_run(..., shard=None)``.
* ``sharded`` — ``fleet_run(..., shard="auto")``: the same core
  shard_mapped over every local device (force >1 on CPU with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import SimParams, fleet_run, generate_workload, run
from repro.core import engine as engine_mod
from repro.core import executor
from repro.core.scheduler import (
    get_vector_scheduler,
    get_vector_scheduler_init,
)
from repro.core.state import init_state
from repro.core.sweep import make_workload_batch


def _time(fn, reps=3):
    """Post-compile wall-clock: (min, mean) over ``reps`` runs."""
    fn()  # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts), sum(ts) / len(ts)


def _legacy_vmap_runner(params: SimParams, scheduler_key: str):
    """Reconstruct the deleted ``fleet_engine="vmap"`` path: vmap of a
    per-simulation event while_loop over the generic tick body. Kept
    only as the benchmark baseline."""
    scheduler_fn = get_vector_scheduler(scheduler_key)
    sched_state0 = get_vector_scheduler_init(scheduler_key)(params)
    horizon = jnp.int32(params.horizon_ticks)

    def one(wl):
        arr_sorted = engine_mod._sorted_arrivals(wl.arrival)

        def cond(carry):
            state, _ = carry
            return state.tick < horizon

        def body(carry):
            state, ss = carry
            tick = state.tick
            state, ss, acted = engine_mod._tick_body(
                state, ss, wl, params, scheduler_fn, tick
            )
            nxt, cursor = engine_mod._next_event_registers(
                state, arr_sorted, tick, acted
            )
            nxt = jnp.minimum(nxt, horizon)
            state = executor.integrate(
                state, tick, nxt, params, exact_buckets=True
            )
            return state._replace(tick=nxt, nxt_arrival_cursor=cursor), ss

        state, _ = jax.lax.while_loop(
            cond, body, (init_state(params), sched_state0)
        )
        return state

    return jax.jit(jax.vmap(one))


def fleet_bench(smoke: bool = False) -> list[dict]:
    """Lane-major core (unsharded + sharded) vs the deleted vmap path."""
    fleet_size = 8 if smoke else 64
    params = SimParams(
        duration=0.05 if smoke else 1.0,
        waiting_ticks_mean=5_000,      # the simulator default arrival rate
        op_base_seconds_mean=0.03,
        op_base_seconds_sigma=1.2,     # heavy-tailed durations -> skew
        op_ram_gb_mean=2.0,
        max_pipelines=32 if smoke else 128,
        max_containers=32 if smoke else 64,
        scheduling_algo="priority",
    )
    seeds = list(range(fleet_size))
    horizon = params.horizon_ticks
    reps = 1 if smoke else 3
    n_dev = jax.local_device_count()

    legacy = _legacy_vmap_runner(params, "priority")
    wls = make_workload_batch(params, seeds)

    runners = {
        "vmap": lambda: jax.block_until_ready(legacy(wls).done_count),
        "fused": lambda: jax.block_until_ready(
            fleet_run(params, seeds, shard=None).done_count
        ),
        "sharded": lambda: jax.block_until_ready(
            fleet_run(params, seeds, shard="auto").done_count
        ),
    }

    rows = []
    for name, go in runners.items():
        t_min, t_mean = _time(go, reps=reps)
        rows.append(
            {
                "engine": f"fleet {name} x{fleet_size}",
                "fleet_engine": name,
                "fleet_size": fleet_size,
                "devices": n_dev if name == "sharded" else 1,
                "wall_s": round(t_mean, 4),
                "wall_s_min": round(t_min, 4),
                "ticks_per_s": round(fleet_size * horizon / t_min),
                "sim_s_per_wall_s": round(
                    fleet_size * params.duration / t_min, 2
                ),
            }
        )
    base = rows[0]["wall_s_min"]
    for r in rows[1:]:
        r["speedup_vs_vmap"] = round(base / r["wall_s_min"], 2)
    return rows


def main(print_rows: bool = True, smoke: bool = False) -> list[dict]:
    rows = []
    params = SimParams(
        duration=0.05 if smoke else 1.0,
        waiting_ticks_mean=2500,
        op_base_seconds_mean=0.03,
        op_ram_gb_mean=2.0,
        max_pipelines=32 if smoke else 128,
        max_containers=32 if smoke else 64,
        scheduling_algo="priority",
    )
    wl = generate_workload(params)
    horizon = params.horizon_ticks

    def event_run():
        jax.block_until_ready(
            run(params, workload=wl, engine="event").state.done_count
        )

    t_event, t_event_mean = _time(event_run, reps=1 if smoke else 3)
    rows.append(
        {
            "engine": "lane-major core (F=1)",
            "wall_s": round(t_event_mean, 4),
            "wall_s_min": round(t_event, 4),
            "ticks_per_s": round(horizon / t_event),
            "sim_s_per_wall_s": round(params.duration / t_event, 2),
        }
    )

    # python reference engine (per-tick plain-object loop)
    t0 = time.perf_counter()
    run(params, workload=wl, engine="python")
    t_py = time.perf_counter() - t0
    rows.append(
        {
            "engine": "python (reference)",
            "wall_s": round(t_py, 4),
            "wall_s_min": round(t_py, 4),
            "ticks_per_s": round(horizon / t_py),
            "sim_s_per_wall_s": round(params.duration / t_py, 2),
            "speedup_core_vs_python": round(t_py / t_event, 1),
        }
    )

    rows.extend(fleet_bench(smoke=smoke))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
