"""Simulator engine throughput (paper §3.1 "low-cost" claim, and the
headline §Perf hillclimb): paper-faithful tick loop vs event-skip vs
the fleet engines, in simulated-seconds per wall-second and ticks/s.

The fleet section compares the fleet-native fused engine (default
`fleet_run` path) against the legacy vmap-of-while_loop path on a
64-lane batch with skewed per-lane durations/event counts (LogNormal
`op_base_seconds_sigma=1.2` — the chained-pipeline regime where
lockstep vmap wastes the most work; see EXPERIMENTS.md §Fleet-Perf).
"""
from __future__ import annotations

import time

import jax

from repro.core import SimParams, fleet_run, generate_workload, run


def _time(fn, reps=3):
    """Post-compile wall-clock: (min, mean) over ``reps`` runs."""
    fn()  # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts), sum(ts) / len(ts)


def fleet_bench(smoke: bool = False) -> list[dict]:
    """Fused fleet engine vs legacy vmap path on a skewed batch."""
    fleet_size = 8 if smoke else 64
    params = SimParams(
        duration=0.05 if smoke else 1.0,
        waiting_ticks_mean=5_000,      # the simulator default arrival rate
        op_base_seconds_mean=0.03,
        op_base_seconds_sigma=1.2,     # heavy-tailed durations -> skew
        op_ram_gb_mean=2.0,
        max_pipelines=32 if smoke else 128,
        max_containers=32 if smoke else 64,
        scheduling_algo="priority",
    )
    seeds = list(range(fleet_size))
    horizon = params.horizon_ticks
    reps = 1 if smoke else 3

    rows = []
    for fleet_engine in ("vmap", "fused"):
        def go(fe=fleet_engine):
            jax.block_until_ready(
                fleet_run(params, seeds, fleet_engine=fe).done_count
            )

        t_min, t_mean = _time(go, reps=reps)
        rows.append(
            {
                "engine": f"fleet {fleet_engine} x{fleet_size}",
                "fleet_engine": fleet_engine,
                "fleet_size": fleet_size,
                "wall_s": round(t_mean, 4),
                "wall_s_min": round(t_min, 4),
                "ticks_per_s": round(fleet_size * horizon / t_min),
                "sim_s_per_wall_s": round(
                    fleet_size * params.duration / t_min, 2
                ),
            }
        )
    rows[1]["speedup_vs_vmap"] = round(
        rows[0]["wall_s_min"] / rows[1]["wall_s_min"], 2
    )
    return rows


def main(print_rows: bool = True, smoke: bool = False) -> list[dict]:
    rows = []
    params = SimParams(
        duration=0.05 if smoke else 1.0,
        waiting_ticks_mean=2500,
        op_base_seconds_mean=0.03,
        op_ram_gb_mean=2.0,
        max_pipelines=32 if smoke else 128,
        max_containers=32 if smoke else 64,
        scheduling_algo="priority",
    )
    wl = generate_workload(params)
    horizon = params.horizon_ticks

    def tick_run():
        jax.block_until_ready(
            run(params, workload=wl, engine="tick").state.done_count
        )

    def event_run():
        jax.block_until_ready(
            run(params, workload=wl, engine="event").state.done_count
        )

    t_tick, t_tick_mean = _time(tick_run, reps=1)
    t_event, t_event_mean = _time(event_run, reps=1 if smoke else 3)
    rows.append(
        {
            "engine": "tick (paper-faithful)",
            "wall_s": round(t_tick_mean, 4),
            "wall_s_min": round(t_tick, 4),
            "ticks_per_s": round(horizon / t_tick),
            "sim_s_per_wall_s": round(params.duration / t_tick, 2),
        }
    )
    rows.append(
        {
            "engine": "event-skip",
            "wall_s": round(t_event_mean, 4),
            "wall_s_min": round(t_event, 4),
            "ticks_per_s": round(horizon / t_event),
            "sim_s_per_wall_s": round(params.duration / t_event, 2),
            "speedup_vs_tick": round(t_tick / t_event, 1),
        }
    )

    # python reference engine
    t0 = time.perf_counter()
    run(params, workload=wl, engine="python")
    t_py = time.perf_counter() - t0
    rows.append(
        {
            "engine": "python (reference)",
            "wall_s": round(t_py, 4),
            "wall_s_min": round(t_py, 4),
            "ticks_per_s": round(horizon / t_py),
            "sim_s_per_wall_s": round(params.duration / t_py, 2),
        }
    )

    rows.extend(fleet_bench(smoke=smoke))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
