"""Simulator engine throughput (paper §3.1 "low-cost" claim, and the
headline §Perf hillclimb): paper-faithful tick loop vs event-skip vs
vmap fleet, in simulated-seconds per wall-second and ticks/second."""
from __future__ import annotations

import time

import jax

from repro.core import SimParams, TICKS_PER_SECOND, fleet_run, generate_workload, run


def _time(fn, reps=3):
    fn()  # compile
    t0 = time.time()
    for _ in range(reps):
        fn()
    return (time.time() - t0) / reps


def main(print_rows: bool = True) -> list[dict]:
    rows = []
    params = SimParams(
        duration=1.0,
        waiting_ticks_mean=2500,
        op_base_seconds_mean=0.03,
        op_ram_gb_mean=2.0,
        max_pipelines=128,
        max_containers=64,
        scheduling_algo="priority",
    )
    wl = generate_workload(params)
    horizon = params.horizon_ticks

    def tick_run():
        jax.block_until_ready(
            run(params, workload=wl, engine="tick").state.done_count
        )

    def event_run():
        jax.block_until_ready(
            run(params, workload=wl, engine="event").state.done_count
        )

    t_tick = _time(tick_run, reps=1)
    t_event = _time(event_run)
    rows.append(
        {
            "engine": "tick (paper-faithful)",
            "wall_s": round(t_tick, 4),
            "ticks_per_s": round(horizon / t_tick),
            "sim_s_per_wall_s": round(params.duration / t_tick, 2),
        }
    )
    rows.append(
        {
            "engine": "event-skip",
            "wall_s": round(t_event, 4),
            "ticks_per_s": round(horizon / t_event),
            "sim_s_per_wall_s": round(params.duration / t_event, 2),
            "speedup_vs_tick": round(t_tick / t_event, 1),
        }
    )

    # python reference engine
    t0 = time.time()
    run(params, workload=wl, engine="python")
    t_py = time.time() - t0
    rows.append(
        {
            "engine": "python (reference)",
            "wall_s": round(t_py, 4),
            "ticks_per_s": round(horizon / t_py),
            "sim_s_per_wall_s": round(params.duration / t_py, 2),
        }
    )

    # vmap fleet: 64 simulations at once
    seeds = list(range(64))

    def fleet():
        jax.block_until_ready(fleet_run(params, seeds).done_count)

    t_fleet = _time(fleet)
    rows.append(
        {
            "engine": "vmap fleet x64",
            "wall_s": round(t_fleet, 4),
            "ticks_per_s": round(64 * horizon / t_fleet),
            "sim_s_per_wall_s": round(64 * params.duration / t_fleet, 2),
            "speedup_vs_serial_event": round(64 * t_event / t_fleet, 1),
        }
    )
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
