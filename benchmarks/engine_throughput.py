"""Simulator engine throughput (paper §3.1 "low-cost" claim, and the
headline §Perf / §Fleet-Perf hillclimbs): the unified lane-major core
vs the Python reference, in simulated-seconds per wall-second, and the
fleet section on a 64-lane batch with skewed per-lane durations/event
counts (LogNormal ``op_base_seconds_sigma=1.2`` — the chained-pipeline
regime where lockstep batching wastes the most work).

The fleet rows compare three paths:

* ``vmap`` — a benchmark-local reconstruction of the DELETED legacy
  fleet path (vmap of a per-simulation event while_loop over the
  reference ``_tick_body`` composition). It exists only here, as the
  baseline the lane-major core is tracked against across PRs
  (BENCH_fleet.json).
* ``fused`` — the lane-major core, ``fleet_run(..., shard=None)``.
* ``sharded`` — ``fleet_run(..., shard="auto")``: the same core
  shard_mapped over every local device (force >1 on CPU with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``), with
  event-density lane binning on (the default).

On top (EXPERIMENTS.md §Scheduler-Perf): ``selection_bench`` times the
schedulers' fused masked selection against the seed three-pass
helpers, and ``phase_breakdown`` attributes one event's wall clock to
phase-1 / scheduler / apply-decision / next-event+integrate; both are
recorded into BENCH_fleet.json by ``benchmarks.run``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import SimParams, fleet_run, generate_workload, run
from repro.core import engine as engine_mod
from repro.core import executor
from repro.core.scheduler import (
    get_vector_scheduler,
    get_vector_scheduler_init,
    select_next_pipe,
    select_victim,
)
from repro.core.state import broadcast_lanes, init_state
from repro.core.sweep import make_workload_batch
from repro.kernels.dispatch import resolved_impl
from repro.kernels.sched_select import masked_lex_argmin
from repro.kernels.sim_tick import fleet_tick


def _time(fn, reps=3):
    """Post-compile wall-clock: (min, mean) over ``reps`` runs."""
    fn()  # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts), sum(ts) / len(ts)


def _legacy_vmap_runner(params: SimParams, scheduler_key: str):
    """Reconstruct the deleted ``fleet_engine="vmap"`` path: vmap of a
    per-simulation event while_loop over the generic tick body. Kept
    only as the benchmark baseline."""
    scheduler_fn = get_vector_scheduler(scheduler_key)
    sched_state0 = get_vector_scheduler_init(scheduler_key)(params)
    horizon = jnp.int32(params.horizon_ticks)

    def one(wl):
        arr_sorted = engine_mod._sorted_arrivals(wl.arrival)

        def cond(carry):
            state, _ = carry
            return state.tick < horizon

        def body(carry):
            state, ss = carry
            tick = state.tick
            state, ss, acted = engine_mod._tick_body(
                state, ss, wl, params, scheduler_fn, tick
            )
            nxt, cursor = engine_mod._next_event_registers(
                state, arr_sorted, tick, acted
            )
            nxt = jnp.minimum(nxt, horizon)
            state = executor.integrate(
                state, tick, nxt, params, exact_buckets=True
            )
            return state._replace(tick=nxt, nxt_arrival_cursor=cursor), ss

        state, _ = jax.lax.while_loop(
            cond, body, (init_state(params), sched_state0)
        )
        return state

    return jax.jit(jax.vmap(one))


def _fleet_params(smoke: bool) -> SimParams:
    # smoke keeps the compile cheap (small tables: MP=32, MC=32, F=32)
    # but simulates the full duration so walls land ~0.2 s — sub-0.1 s
    # walls on a loaded 2-core runner swing 3x, which would make the CI
    # regression gate's fused/vmap ratio pure jitter (min-of-3 reps and
    # the same-run ratio absorb the rest of the load noise)
    return SimParams(
        duration=1.0,
        waiting_ticks_mean=5_000,      # the simulator default arrival rate
        op_base_seconds_mean=0.03,
        op_base_seconds_sigma=1.2,     # heavy-tailed durations -> skew
        op_ram_gb_mean=2.0,
        max_pipelines=32 if smoke else 128,
        max_containers=32 if smoke else 64,
        scheduling_algo="priority",
    )


def fleet_bench(smoke: bool = False) -> list[dict]:
    """Lane-major core (unsharded + sharded) vs the deleted vmap path."""
    fleet_size = 32 if smoke else 64
    params = _fleet_params(smoke)
    seeds = list(range(fleet_size))
    horizon = params.horizon_ticks
    # smoke walls are ~0.1 s, so extra reps are cheap and the min-of-3
    # feeds the CI regression gate (which compares fused/vmap ratios)
    reps = 3
    n_dev = jax.local_device_count()

    legacy = _legacy_vmap_runner(params, "priority")

    # every path pays workload-batch construction inside the clock —
    # fleet_run has to rebuild per call (the batch is donated), so the
    # vmap baseline rebuilds too, keeping the fused/vmap ratio the CI
    # gate trusts a pure engine comparison
    runners = {
        "vmap": lambda: jax.block_until_ready(
            legacy(make_workload_batch(params, seeds)).done_count
        ),
        "fused": lambda: jax.block_until_ready(
            fleet_run(params, seeds, shard=None).done_count
        ),
        "sharded": lambda: jax.block_until_ready(
            fleet_run(params, seeds, shard="auto").done_count
        ),
    }

    rows = []
    for name, go in runners.items():
        t_min, t_mean = _time(go, reps=reps)
        rows.append(
            {
                "engine": f"fleet {name} x{fleet_size}",
                "fleet_engine": name,
                "fleet_size": fleet_size,
                "devices": n_dev if name == "sharded" else 1,
                "wall_s": round(t_mean, 4),
                "wall_s_min": round(t_min, 4),
                "ticks_per_s": round(fleet_size * horizon / t_min),
                "sim_s_per_wall_s": round(
                    fleet_size * params.duration / t_min, 2
                ),
            }
        )
    base = rows[0]["wall_s_min"]
    for r in rows[1:]:
        r["speedup_vs_vmap"] = round(base / r["wall_s_min"], 2)
    return rows


def trace_overhead_bench(smoke: bool = False, reps: int = 7) -> list[dict]:
    """Telemetry cost on the fused fleet path: the same bench as the
    ``fused`` row with ``trace=True`` at the default ring capacity,
    including the host-side decode. Off-path throughput is re-measured
    in the same call so ``trace_overhead_pct`` is a same-run ratio
    (machine speed and load normalise out, as in the CI smoke gate).
    Feeds the ``fused_traced`` row of BENCH_fleet.json; the <10%
    acceptance bar lives in EXPERIMENTS.md §Telemetry. This row gets
    min-of-7 (vs min-of-3 for the throughput rows): the overhead is a
    ratio of two ~0.1-0.5 s walls, so scheduler jitter that the
    absolute rows shrug off would dominate it at 3 reps.
    """
    from repro.core.telemetry.schema import DEFAULT_TRACE_CAPACITY

    fleet_size = 32 if smoke else 64
    params = _fleet_params(smoke)
    seeds = list(range(fleet_size))
    horizon = params.horizon_ticks

    def fused_off():
        return jax.block_until_ready(
            fleet_run(params, seeds, shard=None).done_count
        )

    def fused_on():
        states, traces = fleet_run(params, seeds, shard=None, trace=True)
        jax.block_until_ready(states.done_count)
        return traces

    t_off_min, _ = _time(fused_off, reps=reps)
    t_on_min, t_on_mean = _time(fused_on, reps=reps)
    traces = fused_on()
    overhead_pct = round((t_on_min / t_off_min - 1.0) * 100, 1)
    return [
        {
            "engine": f"fleet fused+trace x{fleet_size}",
            "fleet_engine": "fused_traced",
            "fleet_size": fleet_size,
            "devices": 1,
            "wall_s": round(t_on_mean, 4),
            "wall_s_min": round(t_on_min, 4),
            "ticks_per_s": round(fleet_size * horizon / t_on_min),
            "sim_s_per_wall_s": round(
                fleet_size * params.duration / t_on_min, 2
            ),
            "trace_capacity": DEFAULT_TRACE_CAPACITY,
            "events_recorded": int(sum(t.n for t in traces)),
            "events_dropped": int(sum(t.events_dropped for t in traces)),
            "untraced_wall_s_min": round(t_off_min, 4),
            "trace_overhead_pct": overhead_pct,
        }
    ]


def faults_overhead_bench(smoke: bool = False, reps: int = 7) -> list[dict]:
    """Chaos-layer cost on the fused fleet path (docs/faults.md): the
    ``fused`` bench re-measured with crash/outage/timeout injection and
    a retry budget on, against a faults-off run timed in the same call
    — ``faults_overhead_pct`` is a same-run ratio like the trace and CI
    smoke gates, so machine speed normalises out. The faults-ON run
    pays fault-trace generation inside the clock (it rides workload
    construction, which every fleet row pays). Feeds the
    ``fused_faults`` row of BENCH_fleet.json. Min-of-7 for the same
    reason as ``trace_overhead_bench``: a ratio of two short walls
    needs more reps than an absolute row."""
    fleet_size = 32 if smoke else 64
    params_off = _fleet_params(smoke)
    # moderate churn (~5 crashes + ~2 outages per lane-horizon): enough
    # to keep every chaos path hot without the extra *simulated* events
    # dwarfing the layer's fixed per-event cost in the ratio
    params_on = params_off.replace(
        crash_mtbf_ticks=20_000.0,
        outage_mtbf_ticks=50_000.0,
        outage_duration_ticks=2_000.0,
        straggler_prob=0.05,
        timeout_ticks=200_000,
        max_retries=3,
        base_backoff_ticks=100,
    )
    seeds = list(range(fleet_size))
    horizon = params_off.horizon_ticks

    def fused_off():
        return jax.block_until_ready(
            fleet_run(params_off, seeds, shard=None).done_count
        )

    def fused_on():
        return jax.block_until_ready(
            fleet_run(params_on, seeds, shard=None).done_count
        )

    t_off_min, _ = _time(fused_off, reps=reps)
    t_on_min, t_on_mean = _time(fused_on, reps=reps)
    states = fleet_run(params_on, seeds, shard=None)
    overhead_pct = round((t_on_min / t_off_min - 1.0) * 100, 1)
    return [
        {
            "engine": f"fleet fused+faults x{fleet_size}",
            "fleet_engine": "fused_faults",
            "fleet_size": fleet_size,
            "devices": 1,
            "wall_s": round(t_on_mean, 4),
            "wall_s_min": round(t_on_min, 4),
            "ticks_per_s": round(fleet_size * horizon / t_on_min),
            "sim_s_per_wall_s": round(
                fleet_size * params_on.duration / t_on_min, 2
            ),
            "fault_kills": int(jnp.sum(states.fault_kills)),
            "retries": int(jnp.sum(states.retry_events)),
            "timeouts": int(jnp.sum(states.timeout_events)),
            "unfaulted_wall_s_min": round(t_off_min, 4),
            "faults_overhead_pct": overhead_pct,
        }
    ]


def closed_loop_overhead_bench(smoke: bool = False, reps: int = 7) -> list[dict]:
    """Closed-loop-layer cost on the fused fleet path
    (docs/closed-loop.md): the ``fused`` bench re-measured with the
    client concurrency gate, client retries, and a queue-threshold
    admission policy on, against a loop-off run timed in the same call —
    ``closed_loop_overhead_pct`` is a same-run ratio like the trace and
    faults gates, so machine speed normalises out. Feeds the
    ``fused_closed_loop`` row of BENCH_fleet.json. Min-of-7 for the same
    reason as ``trace_overhead_bench``: a ratio of two short walls needs
    more reps than an absolute row."""
    fleet_size = 32 if smoke else 64
    params_off = _fleet_params(smoke)
    # a gate tight enough that admission actually rejects/defers (every
    # closed-loop path stays hot) but loose enough that the *simulated*
    # work doesn't collapse and skew the wall-clock ratio
    params_on = params_off.replace(
        client_max_inflight=6,
        client_think_ticks=200,
        client_max_retries=3,
        client_backoff_ticks=200,
        admission_policy="queue_threshold",
        admit_queue_limit=4,
    )
    seeds = list(range(fleet_size))
    horizon = params_off.horizon_ticks

    def loop_off():
        return jax.block_until_ready(
            fleet_run(params_off, seeds, shard=None).done_count
        )

    def loop_on():
        return jax.block_until_ready(
            fleet_run(params_on, seeds, shard=None).done_count
        )

    t_off_min, _ = _time(loop_off, reps=reps)
    t_on_min, t_on_mean = _time(loop_on, reps=reps)
    states = fleet_run(params_on, seeds, shard=None)
    overhead_pct = round((t_on_min / t_off_min - 1.0) * 100, 1)
    return [
        {
            "engine": f"fleet fused+closed-loop x{fleet_size}",
            "fleet_engine": "fused_closed_loop",
            "fleet_size": fleet_size,
            "devices": 1,
            "wall_s": round(t_on_mean, 4),
            "wall_s_min": round(t_on_min, 4),
            "ticks_per_s": round(fleet_size * horizon / t_on_min),
            "sim_s_per_wall_s": round(
                fleet_size * params_on.duration / t_on_min, 2
            ),
            "offered": int(jnp.sum(states.offered_total)),
            "shed": int(jnp.sum(states.shed_total)),
            "deferred": int(jnp.sum(states.deferred_total)),
            "client_retries": int(jnp.sum(states.client_retry_events)),
            "open_loop_wall_s_min": round(t_off_min, 4),
            "closed_loop_overhead_pct": overhead_pct,
        }
    ]


def scenario_fleet_bench(smoke: bool = False) -> list[dict]:
    """Scenario-family throughput rows (fused vs sharded) for
    BENCH_fleet.json: each family of the scenario library is drawn as a
    trace batch and replayed through ``fleet_run(workloads=...)``, so
    the perf record tracks the engine on *realistic skew* (diurnal
    ramps, bursts, heavy tails, priority storms) and not just seed
    variance — the regime event-density lane binning targets. Like the
    seed-fleet rows, every path pays workload construction (here: trace
    ingestion) inside the clock; the batch is donated, so it is rebuilt
    per call on both paths and the fused/sharded comparison stays fair.
    """
    from repro.core import workload_batch_from_traces
    from repro.core.scenarios import list_scenarios, scenario_lane_batch

    fleet_size = 8 if smoke else 32
    base = _fleet_params(smoke).replace(
        max_pipelines=0, max_ops_per_pipeline=0
    )
    n_dev = jax.local_device_count()
    rows = []
    for scen in list_scenarios():
        lanes = scenario_lane_batch(scen, base, fleet_size, seed=0)
        _, params = workload_batch_from_traces(lanes, base)
        horizon = params.horizon_ticks

        def replay(shard, params=params, lanes=lanes):
            wls, _ = workload_batch_from_traces(lanes, params)
            return jax.block_until_ready(
                fleet_run(params, workloads=wls, shard=shard).done_count
            )

        for engine, shard in (("fused", None), ("sharded", "auto")):
            t_min, t_mean = _time(lambda s=shard: replay(s), reps=3)
            rows.append(
                {
                    "scenario": scen,
                    "fleet_engine": engine,
                    "fleet_size": fleet_size,
                    "devices": n_dev if engine == "sharded" else 1,
                    "max_pipelines": params.max_pipelines,
                    "wall_s": round(t_mean, 4),
                    "wall_s_min": round(t_min, 4),
                    "ticks_per_s": round(fleet_size * horizon / t_min),
                    "sim_s_per_wall_s": round(
                        fleet_size * params.duration / t_min, 2
                    ),
                }
            )
    return rows


def selection_bench(n_rounds: int = 24, reps: int = 7) -> dict:
    """Scheduler-selection microbench: the seed three-pass helpers vs
    the fused ``sched_select.masked_lex_argmin``, replicating the
    engine's decision loop exactly — a sequential drain of the waiting
    queue on the shapes the 64-lane fleet batches ([64, MP] pipes +
    [64, MC] containers), where each slot's candidate mask excludes the
    pipes already tried and each victim leaves the live set. The whole
    drain runs inside one jitted ``lax.scan`` so the clock sees the
    selection chain's compute (it IS the critical path of a decision),
    not per-call dispatch. Feeds the ``selection`` row of
    BENCH_fleet.json and the EXPERIMENTS kernel speedup table.
    """
    F, MP, MC = 64, 128, 64
    K = 16  # max_assignments_per_tick: slots per drain
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    base = jax.random.bernoulli(ks[0], 0.3, (F, MP))
    prio = jax.random.randint(ks[1], (F, MP), 0, 3)
    entered = jax.random.randint(ks[2], (F, MP), 0, 100_000)
    live0 = jax.random.bernoulli(ks[3], 0.5, (F, MC))
    cprio = jax.random.randint(ks[4], (F, MC), 0, 3)
    cstart = jax.random.randint(ks[5], (F, MC), 0, 100_000)
    below = jnp.full((F,), 2, jnp.int32)
    rows = jnp.arange(F)

    def drain(select_pipe, select_vic):
        def slot(carry, _):
            tried, live, acc = carry
            pipe = select_pipe(base & ~tried)
            victim = select_vic(live)
            tried = tried.at[rows, jnp.maximum(pipe, 0)].set(True)
            live = live.at[rows, jnp.maximum(victim, 0)].set(False)
            return (tried, live, acc + pipe + victim), None

        def rounds(_, __):
            carry0 = (jnp.zeros((F, MP), bool), live0, jnp.zeros((F,), jnp.int32))
            (_, _, acc), _ = jax.lax.scan(slot, carry0, None, length=K)
            return acc, None

        acc, _ = jax.lax.scan(rounds, jnp.zeros((F,), jnp.int32), None,
                              length=n_rounds)
        return acc

    @jax.jit
    def three_pass():
        return drain(
            lambda m: jax.vmap(select_next_pipe)(m, prio, entered),
            lambda lv: jax.vmap(select_victim)(lv, cprio, cstart, below),
        )

    @jax.jit
    def fused():
        return drain(
            lambda m: masked_lex_argmin(m, (-prio, entered)),
            lambda lv: masked_lex_argmin(
                lv & (cprio < below[:, None]), (cprio, -cstart)
            ),
        )

    out = {}
    n_slots = n_rounds * K
    for name, fn in (("three_pass", three_pass), ("fused", fused)):
        jax.block_until_ready(fn())
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        out[f"{name}_us"] = round(min(ts) * 1e6 / n_slots, 2)
    out["speedup"] = round(out["three_pass_us"] / out["fused_us"], 2)
    # sanity: both selection chains agree before we publish a speedup
    assert bool(jnp.array_equal(three_pass(), fused()))
    return out


def apply_bench(n_rounds: int = 24, reps: int = 7) -> dict:
    """Decision-application microbench: the seed's fixed ``fori_loop``
    of per-slot ``lax.cond`` commits vs the fused early-exit rows loop
    + ``state_update.assign_gather`` landing, on the engine's own
    shapes — 64 lanes of real scheduler decisions applied to mid-flight
    states. Like ``selection_bench`` the whole drain runs inside one
    jitted ``lax.scan`` (the tick offset threads the carry so the body
    is not loop-invariant), so the clock sees the commit chain's
    compute rather than per-call dispatch. Feeds the ``apply`` row of
    BENCH_fleet.json.
    """
    params = _fleet_params(smoke=False)
    F = 64
    scheduler_fn = get_vector_scheduler("priority", early_exit=True)
    ss0 = broadcast_lanes(get_vector_scheduler_init("priority")(params), F)
    wls = make_workload_batch(params, list(range(F)))
    states = broadcast_lanes(init_state(params), F)
    # land the early arrivals so the scheduler has real work to hand out
    tick = jnp.full((F,), 2_000, jnp.int32)
    states = jax.jit(jax.vmap(executor.process_arrivals))(states, wls, tick)
    states = states._replace(tick=tick)
    _, decs = jax.jit(
        jax.vmap(lambda ss, s, w: scheduler_fn(ss, s, w, params))
    )(ss0, states, wls)

    def make(early_exit):
        @jax.jit
        def fn():
            def round_(tok, _):
                out = jax.vmap(
                    lambda s, w, d, t: executor.apply_decision(
                        s, w, d, t, params, early_exit=early_exit
                    )
                )(states, wls, decs, states.tick + tok)
                return tok + 1, jnp.sum(out.done_count) + jnp.sum(
                    out.ctr_pipe
                )
            _, outs = jax.lax.scan(
                round_, jnp.int32(0), None, length=n_rounds
            )
            return outs
        return fn

    legacy, fused = make(early_exit=False), make(early_exit=True)
    out = {}
    for name, fn in (("legacy", legacy), ("fused", fused)):
        jax.block_until_ready(fn())
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        out[f"{name}_us"] = round(min(ts) * 1e6 / n_rounds, 2)
    out["speedup"] = round(out["legacy_us"] / out["fused_us"], 2)
    # sanity: both commit paths agree before we publish a speedup
    assert bool(jnp.array_equal(legacy(), fused()))
    return out


def phase_breakdown(n_events: int = 150) -> dict:
    """Per-phase cost attribution on the 64-lane skewed batch.

    Steps the lane-major loop body event by event from the host, with
    each phase jitted separately and synchronised, so the wall clock of
    one event splits into phase-1 (fused sim_tick + its application),
    scheduler, apply-decision, and next-event + utilisation
    integration. The per-phase *shares* are the signal (host sync adds
    a constant per phase); absolute engine throughput lives in the
    fleet rows. Finished lanes are not masked out here — attribution
    only, not a semantics path.
    """
    params = _fleet_params(smoke=False)
    scheduler_fn = get_vector_scheduler("priority", early_exit=True)
    ss0 = get_vector_scheduler_init("priority")(params)
    F = 64
    wls = make_workload_batch(params, list(range(F)))
    horizon = jnp.int32(params.horizon_ticks)
    arr_sorted = engine_mod._sorted_arrivals(wls.arrival)
    states = broadcast_lanes(init_state(params), F)
    scheds = broadcast_lanes(ss0, F)

    @jax.jit
    def f_phase1(states, wls):
        ph = fleet_tick(
            states.ctr_status, states.ctr_end, states.ctr_oom,
            states.ctr_cpus, states.ctr_ram, states.ctr_pool,
            states.pipe_status, wls.arrival, states.pipe_release,
            states.tick, num_pools=params.num_pools,
        )
        return jax.vmap(
            lambda s, w, t, p: executor.apply_fused_phase1(s, w, t, params, p)
        )(states, wls, states.tick, ph)

    @jax.jit
    def f_sched(scheds, states, wls):
        return jax.vmap(
            lambda ss, s, w: scheduler_fn(ss, s, w, params)
        )(scheds, states, wls)

    @jax.jit
    def f_apply(states, wls, decs):
        return jax.vmap(
            lambda s, w, d, t: executor.apply_decision(
                s, w, d, t, params, early_exit=True
            )
        )(states, wls, decs, states.tick)

    @jax.jit
    def f_advance(states, wls, arr_sorted, decs):
        def one(state, wl, arr, dec):
            tick = state.tick
            acted = (
                jnp.any(dec.suspend)
                | jnp.any(dec.reject)
                | jnp.any(dec.assign_pipe >= 0)
            )
            nxt, cursor = engine_mod._next_event_registers(
                state, arr, tick, acted
            )
            nxt = jnp.minimum(nxt, horizon)
            state = executor.integrate(
                state, tick, nxt, params, exact_buckets=True
            )
            return state._replace(tick=nxt, nxt_arrival_cursor=cursor)

        return jax.vmap(one)(states, wls, arr_sorted, decs)

    # compile everything once off the clock
    s1 = f_phase1(states, wls)
    sc, decs = f_sched(scheds, s1, wls)
    s2 = f_apply(s1, wls, decs)
    jax.block_until_ready(f_advance(s2, wls, arr_sorted, decs))

    acc = {"phase1": 0.0, "scheduler": 0.0, "apply": 0.0, "advance": 0.0}
    for _ in range(n_events):
        t0 = time.perf_counter()
        states = jax.block_until_ready(f_phase1(states, wls))
        t1 = time.perf_counter()
        scheds, decs = jax.block_until_ready(f_sched(scheds, states, wls))
        t2 = time.perf_counter()
        states = jax.block_until_ready(f_apply(states, wls, decs))
        t3 = time.perf_counter()
        states = jax.block_until_ready(
            f_advance(states, wls, arr_sorted, decs)
        )
        t4 = time.perf_counter()
        acc["phase1"] += t1 - t0
        acc["scheduler"] += t2 - t1
        acc["apply"] += t3 - t2
        acc["advance"] += t4 - t3
    total = sum(acc.values())
    return {
        "n_events": n_events,
        "us_per_event": {
            k: round(v * 1e6 / n_events, 1) for k, v in acc.items()
        },
        "share": {k: round(v / total, 3) for k, v in acc.items()},
        # what each fused kernel resolved to on THIS backend, with the
        # batching each call site actually uses: fleet_tick sees the
        # explicit [F, ...] batch; the state_update and sched_select
        # landings run per-lane under the engine's vmap (ref by design
        # — see docs/architecture.md §"Kernel subsystems")
        "impl": {
            "sim_tick.fleet_tick": resolved_impl(batched=True),
            "state_update.retire_land": resolved_impl(batched=False),
            "state_update.assign_gather": resolved_impl(batched=False),
            "sched_select.masked_lex_argmin": resolved_impl(batched=False),
        },
    }


def main(print_rows: bool = True, smoke: bool = False) -> list[dict]:
    rows = []
    params = SimParams(
        duration=0.05 if smoke else 1.0,
        waiting_ticks_mean=2500,
        op_base_seconds_mean=0.03,
        op_ram_gb_mean=2.0,
        max_pipelines=32 if smoke else 128,
        max_containers=32 if smoke else 64,
        scheduling_algo="priority",
    )
    wl = generate_workload(params)
    horizon = params.horizon_ticks

    def event_run():
        jax.block_until_ready(
            run(params, workload=wl, engine="event").state.done_count
        )

    t_event, t_event_mean = _time(event_run, reps=1 if smoke else 3)
    rows.append(
        {
            "engine": "lane-major core (F=1)",
            "wall_s": round(t_event_mean, 4),
            "wall_s_min": round(t_event, 4),
            "ticks_per_s": round(horizon / t_event),
            "sim_s_per_wall_s": round(params.duration / t_event, 2),
        }
    )

    # python reference engine (per-tick plain-object loop)
    t0 = time.perf_counter()
    run(params, workload=wl, engine="python")
    t_py = time.perf_counter() - t0
    rows.append(
        {
            "engine": "python (reference)",
            "wall_s": round(t_py, 4),
            "wall_s_min": round(t_py, 4),
            "ticks_per_s": round(horizon / t_py),
            "sim_s_per_wall_s": round(params.duration / t_py, 2),
            "speedup_core_vs_python": round(t_py / t_event, 1),
        }
    )

    rows.extend(fleet_bench(smoke=smoke))
    rows.extend(trace_overhead_bench(smoke=smoke))
    rows.extend(faults_overhead_bench(smoke=smoke))
    rows.extend(closed_loop_overhead_bench(smoke=smoke))
    if not smoke:
        # scheduler-selection microbench -> the `selection` row of
        # BENCH_fleet.json (three-pass helpers vs fused kernel)
        rows.append(
            {
                "engine": "selection microbench [64,128]+[64,64]",
                "fleet_engine": "selection",
                **selection_bench(),
            }
        )
        # decision-application microbench -> the `apply` row (legacy
        # fori_loop cond-commits vs the fused assign_gather landing)
        rows.append(
            {
                "engine": "apply microbench F=64",
                "fleet_engine": "apply",
                **apply_bench(),
            }
        )
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
