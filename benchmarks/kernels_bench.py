"""Kernel microbenchmarks: chunked/oracle implementations wall-time on
CPU (the Pallas kernels themselves target TPU; their interpret-mode
correctness is covered in tests/test_kernels.py)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import flash_attention_ref, mha_reference
from repro.kernels.rwkv6_scan.ops import _rwkv6_chunked
from repro.kernels.rwkv6_scan.ref import rwkv6_ref
from repro.kernels.sim_tick.ref import fleet_tick_ref
from repro.kernels.ssm_scan.ops import _ssm_chunked
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def _bench(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6  # us


def main(print_rows: bool = True) -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention: blocked ref vs naive (memory-feasible shape)
    B, S, H, KV, D = 1, 2048, 8, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    t_naive = _bench(lambda: mha_reference(q, k, v, causal=True))
    t_flash = _bench(
        lambda: flash_attention_ref(q, k, v, causal=True, block_k=512)
    )
    rows.append({"name": "attention_naive_2k", "us_per_call": round(t_naive)})
    rows.append({"name": "attention_flashref_2k", "us_per_call": round(t_flash)})

    # rwkv6: sequential oracle vs chunked
    B, S, Hh, N = 2, 1024, 8, 64
    r_, k_, v_ = (
        jax.random.normal(kk, (B, S, Hh, N), jnp.float32)
        for kk in jax.random.split(ks[0], 3)
    )
    w_ = jnp.exp(-jnp.exp(jax.random.uniform(ks[1], (B, S, Hh, N), minval=-3, maxval=1)))
    u_ = jax.random.normal(ks[2], (Hh, N)) * 0.3
    t_seq = _bench(lambda: rwkv6_ref(r_, k_, v_, w_, u_), reps=2)
    t_chk = _bench(lambda: _rwkv6_chunked(r_, k_, v_, w_, u_,
                                          jnp.zeros((B, Hh, N, N)), chunk=32))
    rows.append({"name": "rwkv6_sequential_1k", "us_per_call": round(t_seq)})
    rows.append({
        "name": "rwkv6_chunked_1k",
        "us_per_call": round(t_chk),
        "derived": f"cpu_ratio={t_seq / t_chk:.2f}x_(chunked_form_targets_MXU_matmuls)",
    })

    # mamba ssm
    B, S, dim, N = 2, 1024, 128, 16
    x = jax.random.normal(ks[0], (B, S, dim))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, dim)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (dim, N)))
    Bm = jax.random.normal(ks[0], (B, S, N))
    Cm = jax.random.normal(ks[1], (B, S, N))
    Dm = jax.random.normal(ks[2], (dim,))
    t_seq = _bench(lambda: ssm_scan_ref(x, dt, A, Bm, Cm, Dm), reps=2)
    t_chk = _bench(
        lambda: _ssm_chunked(x, dt, A, Bm, Cm, Dm,
                             jnp.zeros((B, dim, N)), chunk=256)
    )
    rows.append({"name": "ssm_sequential_1k", "us_per_call": round(t_seq)})
    rows.append({
        "name": "ssm_chunked_1k",
        "us_per_call": round(t_chk),
        "derived": f"cpu_ratio={t_seq / t_chk:.2f}x_(chunked_form_targets_MXU_matmuls)",
    })

    # sim_tick fused fleet phase-1 update
    F, MC, MP, NP = 4096, 64, 128, 2
    ks2 = jax.random.split(key, 9)
    status = jax.random.randint(ks2[0], (F, MC), 0, 2)
    end = jax.random.randint(ks2[1], (F, MC), 0, 1000)
    oom = jnp.full((F, MC), 2**31 - 1, jnp.int32)
    cpus = jax.random.uniform(ks2[2], (F, MC)) * 4
    ram = jax.random.uniform(ks2[3], (F, MC)) * 8
    pool = jax.random.randint(ks2[4], (F, MC), 0, NP)
    pstat = jnp.asarray([0, 2, 4], jnp.int32)[
        jax.random.randint(ks2[5], (F, MP), 0, 3)
    ]
    arrival = jax.random.randint(ks2[6], (F, MP), 0, 5000)
    release = jax.random.randint(ks2[7], (F, MP), 0, 5000)
    tick = jnp.arange(F, dtype=jnp.int32)
    t = _bench(
        lambda: fleet_tick_ref(status, end, oom, cpus, ram, pool,
                               pstat, arrival, release, tick, num_pools=NP)
    )
    rows.append({
        "name": "sim_tick_fleet4096",
        "us_per_call": round(t),
        "derived": f"{F / (t / 1e6) / 1e6:.1f}M sims-ticks/s",
    })

    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
