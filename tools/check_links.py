"""Markdown link checker for the docs suite (CI `docs` job).

Checks every relative markdown link target in README.md,
EXPERIMENTS.md and docs/*.md resolves to an existing file (anchors are
stripped; http(s)/mailto links are not fetched). Zero dependencies, so
the CI job needs no install step and tests/test_docs.py can assert the
same invariant inside the tier-1 suite.

    python tools/check_links.py          # repo root inferred
    python tools/check_links.py <root>
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — excluding images' leading ! is unnecessary: image
# targets must exist too.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

DEFAULT_SOURCES = ("README.md", "EXPERIMENTS.md", "ROADMAP.md", "docs")


def iter_markdown_files(root: pathlib.Path):
    for src in DEFAULT_SOURCES:
        p = root / src
        if p.is_dir():
            yield from sorted(p.glob("**/*.md"))
        elif p.exists():
            yield p


def check_file(path: pathlib.Path, root: pathlib.Path) -> list[str]:
    """Return 'file: broken-target' strings for dangling relative links.

    Leading-``/`` targets are repo-root-relative (GitHub's rendering
    rule), everything else resolves against the linking file.
    """
    broken = []
    for target in _LINK.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        base = root if rel.startswith("/") else path.parent
        if not (base / rel.lstrip("/")).exists():
            broken.append(f"{path}: {target}")
    return broken


def main(root: str | pathlib.Path | None = None) -> list[str]:
    root = pathlib.Path(
        root
        if root is not None
        else pathlib.Path(__file__).resolve().parents[1]
    )
    broken = []
    n_files = 0
    for md in iter_markdown_files(root):
        n_files += 1
        broken.extend(check_file(md, root))
    print(f"checked {n_files} markdown files: "
          f"{len(broken)} broken link(s)")
    for b in broken:
        print(f"  BROKEN {b}")
    return broken


if __name__ == "__main__":
    sys.exit(1 if main(*sys.argv[1:2]) else 0)
