"""Record the trace-off engine capture used by tests/test_telemetry.py.

Runs every scheduler x data-plane x engine-path combination with
telemetry disabled (the default) and stores a per-config SHA-256 digest
of the final SimState bytes in ``tests/captures/trace_off_digests.json``.
The telemetry suite recomputes the digests on the same grid and asserts
bitwise identity, proving the trace machinery's off path never perturbs
the simulation.

Digests are only comparable on the machine class that recorded them
(same backend, same arch): the capture file records both and the test
skips on mismatch rather than chasing cross-platform ULPs.

    PYTHONPATH=src python tools/record_telemetry_capture.py
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import platform
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

CAPTURE = REPO / "tests" / "captures" / "trace_off_digests.json"

ALL_SCHEDULERS = [
    "naive", "priority", "priority_pool", "sjf", "cache_aware",
    "locality_pool",
]
DATA_PLANE = dict(
    cache_gb_per_pool=4.0,
    scan_ticks_per_gb=50.0,
    cold_start_ticks=40,
    container_warm_ticks=2_000,
)
FLEET_SEEDS = [0, 1, 2, 3, 4, 5]  # 6 lanes on 4 devices -> padding too


def capture_params(algo: str, dp: bool):
    from repro.core import SimParams

    kw = dict(DATA_PLANE) if dp else {}
    return SimParams(
        duration=0.03,
        scheduling_algo=algo,
        num_pools=1 if algo == "naive" else 2,
        waiting_ticks_mean=300.0,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.0,
        max_pipelines=32,
        max_containers=32,
        **kw,
    )


def state_digest(state) -> str:
    import numpy as np

    h = hashlib.sha256()
    for f in state._fields:
        a = np.ascontiguousarray(np.asarray(getattr(state, f)))
        h.update(f.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def run_grid() -> dict[str, str]:
    from repro.core import fleet_run, run

    digests: dict[str, str] = {}
    for algo in ALL_SCHEDULERS:
        for dp in (False, True):
            params = capture_params(algo, dp).replace(seed=7)
            tag = f"{algo}/dp={int(dp)}"
            digests[f"{tag}/run"] = state_digest(run(params).state)
            digests[f"{tag}/fleet"] = state_digest(
                fleet_run(params, FLEET_SEEDS, shard=None)
            )
            digests[f"{tag}/shard"] = state_digest(
                fleet_run(params, FLEET_SEEDS, shard="auto", bin_lanes=True)
            )
            digests[f"{tag}/shard_nobin"] = state_digest(
                fleet_run(params, FLEET_SEEDS, shard="auto", bin_lanes=False)
            )
            print(f"captured {tag}", flush=True)
    return digests


def main() -> None:
    import jax

    payload = {
        "backend": jax.default_backend(),
        "machine": platform.machine(),
        "n_devices": jax.local_device_count(),
        "fleet_seeds": FLEET_SEEDS,
        "digests": run_grid(),
    }
    CAPTURE.parent.mkdir(parents=True, exist_ok=True)
    CAPTURE.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {CAPTURE} ({len(payload['digests'])} configs)")


if __name__ == "__main__":
    main()
