"""Record the trace-off engine capture used by tests/test_telemetry.py.

Runs every scheduler x data-plane x engine-path combination with
telemetry disabled (the default) and stores a per-config SHA-256 digest
of the final SimState bytes in ``tests/captures/trace_off_digests.json``.
The telemetry suite recomputes the digests on the same grid and asserts
bitwise identity, proving the trace machinery's off path never perturbs
the simulation.

Two digest families live in the capture:

* ``digests`` — the faults-off grid, hashing the PRE-FAULT field list
  (``SimState._fields`` minus ``state.CHAOS_FIELDS``). Pinning the list
  keeps these digests valid verbatim across chaos-layer schema growth:
  with every fault knob at its zero default the legacy fields are
  bitwise what they were before the chaos layer existed (and the new
  fields are deterministic zeros, asserted separately by
  tests/test_faults.py).
* ``digests_chaos`` — a faults-ON grid, hashing the chaos-era field
  list (``SimState._fields`` minus ``state.CLOSED_LOOP_FIELDS``, i.e.
  everything that existed when these digests were recorded): the
  reproducibility pin for the chaos layer itself, verbatim-valid
  across later schema growth by the same complement trick.
* ``digests_closed_loop`` — a closed-loop-ON grid (admission control +
  client retries + faults), hashing ALL fields: the reproducibility
  pin for the overload layer.

Re-running this tool PRESERVES previously recorded families verbatim
(they are pinned forever; the tests prove today's engine still matches
them) and only records families missing from the capture file. Delete
the file to re-record from scratch on a new machine class.

Digests are only comparable on the machine class that recorded them
(same backend, same arch): the capture file records both and the test
skips on mismatch rather than chasing cross-platform ULPs.

    PYTHONPATH=src python tools/record_telemetry_capture.py
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import platform
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

CAPTURE = REPO / "tests" / "captures" / "trace_off_digests.json"

ALL_SCHEDULERS = [
    "naive", "priority", "priority_pool", "sjf", "cache_aware",
    "locality_pool",
]
DATA_PLANE = dict(
    cache_gb_per_pool=4.0,
    scan_ticks_per_gb=50.0,
    cold_start_ticks=40,
    container_warm_ticks=2_000,
)
FLEET_SEEDS = [0, 1, 2, 3, 4, 5]  # 6 lanes on 4 devices -> padding too


def capture_params(algo: str, dp: bool):
    from repro.core import SimParams

    kw = dict(DATA_PLANE) if dp else {}
    return SimParams(
        duration=0.03,
        scheduling_algo=algo,
        num_pools=1 if algo == "naive" else 2,
        waiting_ticks_mean=300.0,
        op_base_seconds_mean=0.005,
        op_base_seconds_sigma=1.0,
        max_pipelines=32,
        max_containers=32,
        **kw,
    )


CHAOS = dict(
    crash_mtbf_ticks=400.0,
    outage_mtbf_ticks=1_200.0,
    outage_duration_ticks=250.0,
    straggler_prob=0.1,
    timeout_ticks=40_000,
    max_retries=3,
    base_backoff_ticks=50,
)
CHAOS_SCHEDULERS = ["naive", "priority_pool"]


def state_digest(state, fields=None) -> str:
    import numpy as np

    h = hashlib.sha256()
    for f in fields if fields is not None else state._fields:
        a = np.ascontiguousarray(np.asarray(getattr(state, f)))
        h.update(f.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def legacy_fields():
    """The pre-fault SimState field list the faults-off digests hash."""
    from repro.core.state import CHAOS_FIELDS, CLOSED_LOOP_FIELDS, SimState

    skip = set(CHAOS_FIELDS) | set(CLOSED_LOOP_FIELDS)
    return [f for f in SimState._fields if f not in skip]


def chaos_era_fields():
    """The field list of the chaos-capture era: everything before the
    closed-loop block was appended."""
    from repro.core.state import CLOSED_LOOP_FIELDS, SimState

    return [f for f in SimState._fields if f not in CLOSED_LOOP_FIELDS]


def run_grid() -> dict[str, str]:
    from repro.core import fleet_run, run

    fields = legacy_fields()
    digests: dict[str, str] = {}
    for algo in ALL_SCHEDULERS:
        for dp in (False, True):
            params = capture_params(algo, dp).replace(seed=7)
            tag = f"{algo}/dp={int(dp)}"
            digests[f"{tag}/run"] = state_digest(run(params).state, fields)
            digests[f"{tag}/fleet"] = state_digest(
                fleet_run(params, FLEET_SEEDS, shard=None), fields
            )
            digests[f"{tag}/shard"] = state_digest(
                fleet_run(params, FLEET_SEEDS, shard="auto", bin_lanes=True),
                fields,
            )
            digests[f"{tag}/shard_nobin"] = state_digest(
                fleet_run(params, FLEET_SEEDS, shard="auto", bin_lanes=False),
                fields,
            )
            print(f"captured {tag}", flush=True)
    return digests


def run_chaos_grid() -> dict[str, str]:
    from repro.core import fleet_run, run

    fields = chaos_era_fields()
    digests: dict[str, str] = {}
    for algo in CHAOS_SCHEDULERS:
        params = capture_params(algo, dp=True).replace(seed=7, **CHAOS)
        tag = f"{algo}/chaos"
        digests[f"{tag}/run"] = state_digest(run(params).state, fields)
        digests[f"{tag}/fleet"] = state_digest(
            fleet_run(params, FLEET_SEEDS, shard=None), fields
        )
        print(f"captured {tag}", flush=True)
    return digests


CLOSED_LOOP = dict(
    client_max_inflight=6,
    client_think_ticks=30,
    client_max_retries=3,
    client_backoff_ticks=40,
    admission_policy="queue_threshold",
    admit_queue_limit=4,
    metastable_window_ticks=400,
)
CLOSED_LOOP_SCHEDULERS = ["naive", "priority_pool"]


def run_closed_loop_grid() -> dict[str, str]:
    from repro.core import fleet_run, run

    digests: dict[str, str] = {}
    for algo in CLOSED_LOOP_SCHEDULERS:
        params = capture_params(algo, dp=True).replace(
            seed=7, **CHAOS, **CLOSED_LOOP
        )
        tag = f"{algo}/closed_loop"
        digests[f"{tag}/run"] = state_digest(run(params).state)
        digests[f"{tag}/fleet"] = state_digest(
            fleet_run(params, FLEET_SEEDS, shard=None)
        )
        print(f"captured {tag}", flush=True)
    return digests


GRIDS = {
    "digests": run_grid,
    "digests_chaos": run_chaos_grid,
    "digests_closed_loop": run_closed_loop_grid,
}


def main() -> None:
    import jax

    payload = {
        "backend": jax.default_backend(),
        "machine": platform.machine(),
        "n_devices": jax.local_device_count(),
        "fleet_seeds": FLEET_SEEDS,
    }
    if CAPTURE.exists():
        # recorded digest families are pinned forever: keep them
        # verbatim and only fill in families this tool grew since
        old = json.loads(CAPTURE.read_text())
        payload.update(
            {k: old[k] for k in GRIDS if k in old}
        )
    for family, grid in GRIDS.items():
        if family not in payload:
            payload[family] = grid()
    CAPTURE.parent.mkdir(parents=True, exist_ok=True)
    CAPTURE.write_text(json.dumps(payload, indent=2) + "\n")
    print(
        f"wrote {CAPTURE} ("
        + ", ".join(f"{len(payload[k])} {k}" for k in GRIDS)
        + ")"
    )


if __name__ == "__main__":
    main()
