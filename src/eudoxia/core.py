"""``from eudoxia.core import Scheduler, Failure, Assignment, Pipeline``
(paper Listing 4)."""
from repro.core import (  # noqa: F401
    Assignment,
    Failure,
    Operator,
    Pipeline,
    PipeStatus,
    Priority,
    Scheduler,
    SimParams,
    Suspension,
)
