"""Paper-verbatim facade: ``import eudoxia`` (Listings 3-6).

Everything re-exports from :mod:`repro.core`, where the implementation
lives; this package exists so the paper's code snippets run unchanged::

    import eudoxia

    def main():
        paramfile = "project.toml"
        eudoxia.run_simulator(paramfile)
"""
from repro.core import *  # noqa: F401,F403
from repro.core import run_simulator, SimResult  # noqa: F401

from . import algorithm, core  # noqa: F401
