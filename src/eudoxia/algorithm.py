"""``from eudoxia.algorithm import register_scheduler,
register_scheduler_init`` (paper Listing 4)."""
from repro.core.algorithm import (  # noqa: F401
    register_scheduler,
    register_scheduler_init,
)
from repro.core.scheduler import (  # noqa: F401
    register_vector_scheduler,
    register_vector_scheduler_init,
)
