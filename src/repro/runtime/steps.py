"""Train / serve step factories, generic over the architecture zoo."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models import encdec as ed
from repro.models import lm
from repro.optim.optimizers import OptConfig, make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt: Any


def model_init(cfg: ModelConfig, key):
    if cfg.family == "audio":
        return ed.encdec_init(cfg, key)
    return lm.lm_init(cfg, key)


def loss_fn(cfg: ModelConfig, params, batch, vocab_chunk: int = 512):
    if cfg.family == "audio":
        return ed.encdec_loss(cfg, params, batch)
    return lm.lm_loss(cfg, params, batch, vocab_chunk=vocab_chunk)


def make_train_step(
    cfg: ModelConfig, opt_cfg: OptConfig, microbatches: int = 1
):
    """Returns (init_fn(key) -> TrainState, step_fn(state, batch)).

    ``microbatches > 1`` enables gradient accumulation: the global batch
    is split along axis 0 and swept with ``lax.scan``, dividing
    activation memory by M (the knob that fits 4k-seq training of the
    400B-class archs into 16 GB/chip).
    """
    opt_init, opt_update = make_optimizer(opt_cfg)

    def init_fn(key):
        params, axes = model_init(cfg, key)
        return TrainState(params=params, opt=opt_init(opt_cfg, params)), axes

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)

    def step_fn(state: TrainState, batch):
        if microbatches > 1:
            mb = jax.tree.map(
                lambda x: x.reshape(
                    (microbatches, x.shape[0] // microbatches) + x.shape[1:]
                ),
                batch,
            )

            def acc(carry, b):
                loss_sum, g_sum = carry
                loss, g = grads_of(state.params, b)
                g_sum = jax.tree.map(
                    lambda a, b_: a + b_.astype(a.dtype), g_sum, g
                )
                return (loss_sum + loss, g_sum), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (loss_sum, g_sum), _ = jax.lax.scan(acc, (0.0, g0), mb)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
        else:
            loss, grads = grads_of(state.params, batch)
        p2, opt2, gnorm = opt_update(opt_cfg, grads, state.opt, state.params)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": opt2.step}
        return TrainState(params=p2, opt=opt2), metrics

    return init_fn, step_fn


def make_serve_steps(cfg: ModelConfig):
    """Returns (prefill_fn, decode_fn) for the architecture."""
    if cfg.family == "audio":
        def prefill(params, batch, max_len):
            return ed.encdec_prefill(cfg, params, batch, max_dec=max_len)

        def decode(params, caches, token, pos):
            return ed.encdec_decode_step(cfg, params, caches, token, pos)
    else:
        def prefill(params, batch, max_len):
            return lm.lm_prefill(cfg, params, batch, max_len=max_len)

        def decode(params, caches, token, pos):
            return lm.lm_decode_step(cfg, params, caches, token, pos)

    return prefill, decode


def init_serve_caches(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family == "audio":
        raise NotImplementedError(
            "whisper caches come from encdec_prefill (they embed cross-KV)"
        )
    return lm.init_caches(cfg, batch, max_len)


__all__ = [
    "TrainState",
    "model_init",
    "loss_fn",
    "make_train_step",
    "make_serve_steps",
    "init_serve_caches",
]
