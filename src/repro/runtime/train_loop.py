"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler monitoring, elastic re-mesh.

``run_training`` is the real loop used by ``launch/train.py`` and the
end-to-end example; ``run_elastic_demo`` additionally injects failures
and restarts from the newest checkpoint — on a *different* mesh shape if
requested — proving the elastic-restore path end to end on CPU devices.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.registry import ArchSpec
from repro.data.pipeline import SyntheticLM, make_batch_iterator
from repro.launch.lowering import (
    arch_rules,
    model_axes_and_shapes,
    opt_config,
    shardings_of,
)
from repro.launch.shapes import opt_axes
from repro.runtime.failures import FailureInjector, StragglerMonitor
from repro.runtime.steps import TrainState, make_train_step


@dataclasses.dataclass
class TrainResult:
    steps_done: int
    losses: list
    restarts: int
    straggler_events: int
    final_state: TrainState


def _state_shardings(arch: ArchSpec, cfg, mesh):
    rules = arch_rules(arch)
    p_axes, p_shapes = model_axes_and_shapes(cfg)
    o_axes = opt_axes(arch.optimizer, p_axes, p_shapes)
    state_axes = TrainState(params=p_axes, opt=o_axes)

    def shapes_of(state):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
        )

    return state_axes, rules, shapes_of


def run_training(
    arch: ArchSpec,
    *,
    steps: int,
    mesh=None,
    use_smoke_config: bool = True,
    global_batch: int = 8,
    seq_len: int = 64,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    start_seed: int = 0,
    injector: Optional[FailureInjector] = None,
    microbatches: int = 1,
    log_every: int = 10,
    on_metrics: Optional[Callable] = None,
) -> TrainResult:
    cfg = arch.smoke if use_smoke_config else arch.model
    ocfg = opt_config(arch)
    ocfg = dataclasses.replace(ocfg, total_steps=max(steps, 10))
    init_fn, step_fn = make_train_step(cfg, ocfg, microbatches=microbatches)

    ds = SyntheticLM(
        vocab=cfg.vocab,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=start_seed,
        family=cfg.family,
        n_img_tokens=cfg.n_img_tokens,
    )
    state_axes, rules, shapes_of = _state_shardings(arch, cfg, mesh)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    monitor = StragglerMonitor()
    losses: list[float] = []
    restarts = 0

    step0 = 0
    state, _ = init_fn(jax.random.PRNGKey(start_seed))
    if mesh is not None:
        sh = shardings_of(state_axes, shapes_of(state), mesh, rules.param)
        state = jax.tree.map(jax.device_put, state, sh)
    if mgr and mgr.latest_step() is not None:
        sh = (
            shardings_of(state_axes, shapes_of(state), mesh, rules.param)
            if mesh is not None
            else None
        )
        state, manifest = mgr.restore(state, shardings=sh)
        step0 = manifest["step"] + 1

    jstep = jax.jit(step_fn, donate_argnums=(0,))
    it = make_batch_iterator(ds, mesh, start_step=step0)

    step = step0
    while step < steps:
        batch = next(it)
        if injector is not None and injector.should_fail(step):
            # simulated hard failure: drop in-memory state, restart from
            # the newest checkpoint (elastic path handled by caller remesh)
            restarts += 1
            if mgr is None or mgr.latest_step() is None:
                state, _ = init_fn(jax.random.PRNGKey(start_seed))
                step = 0
                it = make_batch_iterator(ds, mesh, start_step=0)
                continue
            state, _ = init_fn(jax.random.PRNGKey(start_seed))
            if mesh is not None:
                sh = shardings_of(
                    state_axes, shapes_of(state), mesh, rules.param
                )
                state = jax.tree.map(jax.device_put, state, sh)
                state, manifest = mgr.restore(state, shardings=sh)
            else:
                state, manifest = mgr.restore(state)
            step = manifest["step"] + 1
            it = make_batch_iterator(ds, mesh, start_step=step)
            continue

        t0 = time.time()
        state, metrics = jstep(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        monitor.observe(step, dt)
        losses.append(loss)
        if on_metrics:
            on_metrics(step, {"loss": loss, "dt": dt})
        if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.async_save(state, step)
        step += 1

    if mgr:
        mgr.wait()
    return TrainResult(
        steps_done=step,
        losses=losses,
        restarts=restarts,
        straggler_events=len(monitor.flagged),
        final_state=state,
    )


__all__ = ["run_training", "TrainResult"]
