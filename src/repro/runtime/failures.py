"""Failure injection, straggler detection, and the Eudoxia bridge for
checkpoint-cadence policy.

At 1000+ nodes, mean-time-between-failures is hours, not days; the
runtime must (a) detect dead/slow hosts, (b) restart from the newest
checkpoint on a possibly-smaller mesh, and (c) choose a checkpoint
cadence that balances write cost against expected lost work. (c) is
answered by ``advise_checkpoint_cadence`` with a small purpose-built
replay of the failure/restart process — deterministic, but NOT a run
of the Eudoxia engine (see its docstring for why, and for how the
failure model is kept honest against the engine's chaos layer,
docs/faults.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for chaos testing the train loop."""

    seed: int = 0
    mtbf_steps: float = 200.0   # mean steps between injected failures
    max_failures: int = 3

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        gaps = rng.exponential(self.mtbf_steps, size=self.max_failures)
        self.schedule = np.cumsum(np.maximum(gaps, 2.0)).astype(int).tolist()
        self._injected = 0

    def should_fail(self, step: int) -> bool:
        if self._injected >= self.max_failures:
            return False
        if self.schedule[self._injected] <= step:
            self._injected += 1
            return True
        return False


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time monitor; flags steps slower than k x the average.

    On real pods this drives hot-spare swap / mesh shrink; here it feeds
    the elastic runner's decision to re-mesh.
    """

    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 3

    def __post_init__(self):
        self.ewma: Optional[float] = None
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = (
            self.n > self.warmup and dt > self.threshold * self.ewma
        )
        # stragglers don't poison the average
        if not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler


def advise_checkpoint_cadence(
    *,
    step_time_s: float,
    ckpt_write_s: float,
    restart_s: float,
    mtbf_steps: float,
    horizon_steps: int = 2000,
    candidates: tuple[int, ...] = (10, 25, 50, 100, 250, 500),
    seed: int = 0,
) -> dict:
    """Pick the checkpoint interval that minimises wall-clock time to
    ``horizon_steps`` useful steps under failures.

    This is a purpose-built deterministic replay, not a Eudoxia engine
    run: the engine simulates many *independent* pipelines under a
    scheduler, while cadence choice needs one *sequential* job with
    checkpoint/restart state the engine deliberately does not model.
    What IS shared with the engine is the failure process — exponential
    inter-failure gaps, exactly how the chaos layer's
    ``repro.core.faults.generate_fault_trace`` draws crash times
    (docs/faults.md) — and tests/test_faults.py cross-checks the two:
    lost work predicted here and the engine's ``wasted_ticks`` counter
    under crash injection must both grow as MTBF shrinks.

    The job replays as ``horizon_steps`` sequential steps; failures
    arrive at exponential times; each failure rolls back to the last
    checkpoint (losing the steps since) and pays ``restart_s``; each
    checkpoint pays ``ckpt_write_s``. One deterministic replay per
    candidate interval.
    """
    rng = np.random.default_rng(seed)
    fail_times = np.cumsum(
        rng.exponential(mtbf_steps * step_time_s, size=64)
    )
    results = {}
    for interval in candidates:
        t = 0.0
        done = 0
        last_ckpt = 0
        fi = 0
        while done < horizon_steps:
            t += step_time_s
            done += 1
            if done - last_ckpt >= interval:
                t += ckpt_write_s
                last_ckpt = done
            if fi < len(fail_times) and t >= fail_times[fi]:
                fi += 1
                lost = done - last_ckpt
                done = last_ckpt
                t += restart_s
        results[interval] = t
    best = min(results, key=results.get)
    return {
        "best_interval": int(best),
        "total_time_s": {int(k): float(v) for k, v in results.items()},
    }


__all__ = [
    "FailureInjector",
    "StragglerMonitor",
    "advise_checkpoint_cadence",
]
