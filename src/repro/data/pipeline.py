"""Deterministic synthetic data pipeline with document packing.

Production-shaped even though the tokens are synthetic: documents of
random length are generated from a seeded Zipf-ish unigram model, packed
into fixed-length rows with EOS separators, sharded per host, and handed
to jax as globally-sharded arrays. Determinism contract: (seed, step) ->
identical batch on every restart, which is what makes checkpoint/resume
bit-reproducible (tests/test_checkpoint.py relies on it).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

EOS = 1


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    family: str = "lm"           # lm | vlm | audio
    n_img_tokens: int = 0
    vit_dim: int = 1024

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The full global batch for `step` (host slicing done by caller)."""
        rng = self._rng(step)
        B, S = self.global_batch, self.seq_len
        # Zipf-ish unigram over the vocab, cheap but non-uniform
        tokens = np.empty((B, S), np.int32)
        for b in range(B):
            row: list[int] = []
            while len(row) < S:
                n = int(rng.geometric(1.0 / self.mean_doc_len))
                n = max(8, min(n, S - len(row)))
                doc = (
                    rng.zipf(1.3, size=n).astype(np.int64) % (self.vocab - 2)
                ) + 2
                row.extend(doc.tolist()[: n - 1])
                row.append(EOS)
            tokens[b] = np.asarray(row[:S], np.int32)
        out = {"tokens": tokens}
        if self.family == "vlm":
            out["frontend_embeds"] = rng.standard_normal(
                (B, self.n_img_tokens, self.vit_dim), np.float32
            ).astype(np.float32)
        if self.family == "audio":
            out["frontend_embeds"] = rng.standard_normal(
                (B, S, self.vit_dim), np.float32
            ).astype(np.float32)
        return out


def make_batch_iterator(
    ds: SyntheticLM,
    mesh: Optional[Mesh] = None,
    start_step: int = 0,
    batch_axes: tuple[str, ...] = ("pod", "data"),
) -> Iterator[dict]:
    """Yields device-ready batches; sharded over the mesh batch axes."""
    step = start_step
    sharding = None
    if mesh is not None:
        axes = tuple(a for a in batch_axes if a in mesh.axis_names)
        sharding = NamedSharding(mesh, P(axes if axes else None))
    while True:
        batch = ds.batch_at(step)
        if sharding is not None:
            batch = {k: jax.device_put(v, sharding) for k, v in batch.items()}
        yield batch
        step += 1


__all__ = ["SyntheticLM", "make_batch_iterator", "EOS"]
