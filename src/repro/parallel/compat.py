"""JAX version compatibility shims for the parallel substrate.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its ``check_rep`` knob was renamed ``check_vma``
along the way. ``shard_map`` below presents the modern signature on
either version so call sites stay clean.
"""
from __future__ import annotations

import functools
import inspect

try:
    from jax import shard_map as _shard_map
except ImportError:  # jax < 0.5: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = inspect.signature(_shard_map).parameters
_HAS_VMA = "check_vma" in _PARAMS


@functools.wraps(_shard_map)
def shard_map(*args, **kwargs):
    if not _HAS_VMA and "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(*args, **kwargs)


__all__ = ["shard_map"]
