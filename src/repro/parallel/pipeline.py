"""Pipeline parallelism (GPipe) over a mesh axis via shard_map + ppermute.

For the 398–480B archs the pod axis can serve as a pipeline axis instead
of plain DP: stage s holds layers [s*L/S, (s+1)*L/S); microbatches
stream through with the classic GPipe schedule (M + S - 1 ticks, bubble
fraction (S-1)/(M+S-1)). Activations cross pods once per stage boundary
per microbatch — O(B*S_seq*d) per tick — instead of the DP gradient
all-reduce of every parameter; for parameter-dominated steps
(giant MoE, small global batch) that is the better trade, and
EXPERIMENTS.md §Perf-A quantifies exactly when.

`gpipe` is generic over a stage function, differentiable (grads flow
through `ppermute`), and composes with the in-stage TP/FSDP rules: the
shard_map maps ONLY the stage axis; `model`/`data` stay auto axes inside.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from repro.parallel.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe(
    stage_fn: Callable,
    mesh: Mesh,
    *,
    stage_axis: str = "pod",
    num_microbatches: int | None = None,
):
    """Build a pipelined apply: (stage_params, x) -> y.

    stage_params: pytree stacked on a leading [n_stages, ...] axis
                  (stage s's slice lives on pipeline rank s).
    x:            [M, mb, ...] microbatches (replicated along the stage
                  axis; other mesh axes may shard trailing dims as usual).
    stage_fn:     (params_slice, x_mb) -> y_mb, same shape.
    Returns y [M, mb, ...] (valid on every rank after the final bcast).
    """
    S = mesh.shape[stage_axis]

    def pipelined(stage_params, x):
        M = x.shape[0]

        def body(params_s, x_local):
            # params_s: this rank's stage params ([1, ...] -> squeeze)
            params_s = jax.tree.map(lambda t: t[0], params_s)
            s = jax.lax.axis_index(stage_axis)
            buf = jnp.zeros_like(x_local[0])
            outs = jnp.zeros_like(x_local)
            fwd_perm = [(i, i + 1) for i in range(S - 1)]
            for t in range(M + S - 1):
                mb_ix = min(max(t, 0), M - 1)
                x_in = jnp.where(s == 0, x_local[mb_ix], buf)
                y = stage_fn(params_s, x_in)
                active = (t - s >= 0) & (t - s <= M - 1)
                y = jnp.where(active, y, 0.0)
                # last stage retires microbatch t-(S-1)
                out_ix = t - (S - 1)
                if 0 <= out_ix < M:
                    emit = jnp.where(s == S - 1, y, 0.0)
                    outs = outs.at[out_ix].set(emit)
                buf = jax.lax.ppermute(y, stage_axis, fwd_perm)
            # results live on the last stage; share them with every rank
            outs = jax.lax.psum(outs, stage_axis) - (S - 1) * 0.0
            return outs

        in_specs = (P(stage_axis), P())
        out_specs = P()
        return shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )(stage_params, x)

    return pipelined


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


__all__ = ["gpipe", "bubble_fraction"]
