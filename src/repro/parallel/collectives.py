"""Distributed-optimization collectives: error-feedback compressed
gradient all-reduce.

``compressed_psum_mean``: int8-quantised data-parallel gradient
reduction with per-tensor scale and an error-feedback buffer (the
quantisation residual is added back into the next step's gradient, which
keeps SGD/Adam convergence — Seide et al. / EF-SGD). Cuts the DP
all-reduce wire bytes 4x vs f32 / 2x vs bf16, the right trade on the
slow inter-pod links.

Implemented inside ``shard_map`` so the collective is explicit (a psum
of int32-accumulated int8 payloads), not GSPMD-chosen.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from repro.parallel.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grad(g: jax.Array, err: jax.Array):
    """Error-feedback compression of one gradient tensor.

    Returns (q int8, scale f32, new_err f32): quantises (g + err) and
    stores the residual for the next step.
    """
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_psum_mean(
    grads: Any,
    errs: Any,
    mesh: Mesh,
    axis: str = "data",
):
    """Mean-reduce a gradient pytree over `axis` with int8 + EF.

    grads/errs: pytrees with identical structure; every leaf must be
    fully replicated along `axis` shards... in practice this is applied
    to the *locally-accumulated* per-shard gradient inside a shard_map'd
    DP step. Returns (mean_grads f32, new_errs).
    """
    n = mesh.shape[axis]

    def one(g, e):
        def body(g_local, e_local):
            corrected = g_local.astype(jnp.float32) + e_local
            local_scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
            # shared scale across ranks (tiny pmax) so the int8 payloads
            # sum exactly; then one int8->int32 psum carries the wire
            scale = jax.lax.pmax(local_scale, axis)
            q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(
                jnp.int8
            )
            new_e = corrected - q.astype(jnp.float32) * scale
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)
            mean = qsum.astype(jnp.float32) * scale / n
            return mean, new_e

        spec = P()  # leaves replicated along the reduce axis
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(spec, spec),
            out_specs=(spec, spec),
            check_vma=False,
        )(g, e)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        mg, ne = one(g, e)
        out_g.append(mg)
        out_e.append(ne)
    return (
        jax.tree_util.tree_unflatten(treedef, out_g),
        jax.tree_util.tree_unflatten(treedef, out_e),
    )


__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "ef_compress_grad",
    "compressed_psum_mean",
]
