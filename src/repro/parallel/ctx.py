"""Trace-time sharding context.

Model code calls ``constrain(x, "batch seq embed")`` at key activation
sites; when a mesh+rules context is active (set by the launcher/dry-run
around tracing), this becomes ``with_sharding_constraint`` — otherwise a
no-op, so single-device tests and smoke runs are untouched.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "sharding_ctx", default=None
)


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, act_rules):
    token = _CTX.set((mesh, act_rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain(x, axes: str):
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    from repro.parallel.sharding import spec_for

    spec = spec_for(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def get_ctx():
    """(mesh, act_rules) of the active sharding context, or None."""
    return _CTX.get()


def batch_axes_in_mesh(batch_size: int):
    """The mesh axes the batch dim is sharded over under the active
    context (respecting divisibility), or None if no context."""
    ctx = _CTX.get()
    if ctx is None:
        return None
    mesh, rules = ctx
    picked = []
    prod = 1
    for cand in rules.get("batch", ()):
        if cand not in mesh.axis_names:
            continue
        nxt = prod * mesh.shape[cand]
        if batch_size % nxt == 0 and batch_size >= nxt:
            picked.append(cand)
            prod = nxt
    return tuple(picked)


__all__ = ["sharding_ctx", "constrain", "get_ctx", "batch_axes_in_mesh"]
