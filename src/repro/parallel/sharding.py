"""Logical-axis -> mesh-axis sharding rules (t5x-style, divisibility-aware).

Every parameter carries a space-separated logical axis string (one name
per dim, produced at init). Rules map logical names to an ordered
preference of mesh axes; an assignment is dropped (replicated) when the
dim size is not divisible by the mesh axis size or the axis is already
taken by another dim of the same tensor. This is what lets one rule set
drive MQA (kv=1 -> replicated) and GQA (kv=16 -> TP) alike.

Parallelism styles expressed purely through rules:
* TP  — heads/ff/expert/vocab on "model"
* FSDP — embed (the weight dim every tensor shares) on "data"
* EP  — expert on "model"
* DP  — activation batch on ("pod", "data")
* SP  — decode-time KV/context seq on "model" (kv_seq rule)
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


DEFAULT_PARAM_RULES: dict[str, tuple[str, ...]] = {
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ff": ("model",),
    "expert": ("model",),
    "embed": ("data",),          # FSDP
    "embed_moe": ("data",),      # FSDP for expert weights (giants opt out)
    "layers": (),
    "conv": (),
    "state": (),
}

DEFAULT_ACT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "expert_cap": (),
    "embed_moe": (),
    "kv_seq": ("model",),
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ff": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
    "layers": (),
    "state": (),
    "conv": (),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    param: Mapping[str, tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_PARAM_RULES)
    )
    act: Mapping[str, tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_ACT_RULES)
    )

    def override(self, *, param=None, act=None) -> "ShardingRules":
        p = dict(self.param)
        p.update(param or {})
        a = dict(self.act)
        a.update(act or {})
        return ShardingRules(param=p, act=a)


def spec_for(
    shape: Sequence[int],
    axes: str,
    mesh: Mesh,
    rules: Mapping[str, tuple[str, ...]],
) -> P:
    """Build a PartitionSpec for `shape` with logical axes `axes`."""
    names = axes.split() if axes else []
    if len(names) != len(shape):
        # axes annotations must line up; treat mismatch as replicated
        return P()
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, names):
        picked: list[str] = []
        prod = 1
        # a dim may absorb several mesh axes (batch -> pod x data)
        for cand in rules.get(name, ()):
            if cand in used or cand not in mesh.axis_names:
                continue
            nxt = prod * mesh.shape[cand]
            if dim % nxt == 0 and dim >= nxt:
                picked.append(cand)
                used.add(cand)
                prod = nxt
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(axes_tree, shape_tree, mesh: Mesh, rules: ShardingRules):
    """Tree of NamedShardings for a param tree (axes + abstract shapes)."""
    return jax.tree.map(
        lambda ax, sh: NamedSharding(
            mesh, spec_for(sh.shape, ax, mesh, rules.param)
        ),
        axes_tree,
        shape_tree,
    )


def shard_params(params, axes_tree, mesh: Mesh, rules: ShardingRules):
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    sh = param_shardings(axes_tree, shapes, mesh, rules)
    return jax.tree.map(jax.device_put, params, sh)


def logical_constraint(x, axes: str, mesh: Mesh | None, rules: ShardingRules):
    """with_sharding_constraint via logical names (no-op without mesh)."""
    if mesh is None:
        return x
    spec = spec_for(x.shape, axes, mesh, rules.act)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


__all__ = [
    "DEFAULT_PARAM_RULES",
    "DEFAULT_ACT_RULES",
    "ShardingRules",
    "spec_for",
    "param_shardings",
    "shard_params",
    "logical_constraint",
]
