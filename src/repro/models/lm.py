"""Causal LM (+ VLM variant): init, train loss, prefill, decode.

Layer stack = period-scan (see common.py): `lax.scan` over repeats of
the layer pattern with per-position stacked parameters + an unrolled
tail for non-divisible depths. Caches follow the same layout: one
stacked cache pytree per pattern position.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.ctx import constrain
from .attention import Param, unzip
from .blocks import block_apply, block_init, init_block_cache
from .common import (
    AX_EMBED,
    AX_LAYERS,
    AX_STATE,
    AX_VOCAB,
    ModelConfig,
    rms_norm,
)

VIT_DIM = 1024  # stubbed vision/audio frontend embedding width


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _stacked_init(fn, key, n):
    """Stack `n` independent inits of `fn(key)` along a leading axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def lm_init(cfg: ModelConfig, key) -> tuple[Any, Any]:
    """Returns (params, axes) — axes leaves are space-separated logical
    axis names aligned with each param's dims."""
    cfg.validate()
    ks = jax.random.split(key, 8)
    tree: dict[str, Any] = {
        "embed": Param(
            (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02).astype(
                cfg.param_dtype
            ),
            (AX_VOCAB, AX_EMBED),
        ),
        "final_norm": Param(jnp.zeros((cfg.d_model,), jnp.float32), (AX_EMBED,)),
    }
    if not cfg.tie_embeddings:
        tree["head"] = Param(
            (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab)) * 0.02).astype(
                cfg.param_dtype
            ),
            (AX_EMBED, AX_VOCAB),
        )
    if cfg.family in ("vlm", "audio"):
        tree["frontend_proj"] = Param(
            (jax.random.normal(ks[2], (VIT_DIM, cfg.d_model)) * 0.02).astype(
                cfg.param_dtype
            ),
            (AX_STATE, AX_EMBED),
        )

    params, axes = unzip(tree)
    params["stack"] = {"periods": [], "tail": []}
    axes["stack"] = {"periods": [], "tail": []}
    for i, spec in enumerate(cfg.pattern):
        sub = jax.random.fold_in(ks[3], i)

        def only_params(k, spec=spec):
            return unzip(block_init(cfg, spec, k))[0]

        stacked = _stacked_init(only_params, sub, cfg.n_periods)
        _, ax = unzip(block_init(cfg, spec, jax.random.PRNGKey(0)))
        ax = jax.tree.map(lambda s: f"{AX_LAYERS} {s}".strip(), ax)
        params["stack"]["periods"].append(stacked)
        axes["stack"]["periods"].append(ax)
    for t in range(cfg.n_tail):
        spec = cfg.pattern[t % cfg.period]
        sub = jax.random.fold_in(ks[4], t)
        p, ax = unzip(block_init(cfg, spec, sub))
        params["stack"]["tail"].append(p)
        axes["stack"]["tail"].append(ax)
    return params, axes


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Cache pytree: per pattern position, stacked over periods; plus tail."""
    periods = []
    for i, spec in enumerate(cfg.pattern):
        one = init_block_cache(cfg, spec, batch, max_len)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape), one
        )
        periods.append(stacked)
    tail = [
        init_block_cache(cfg, cfg.pattern[t % cfg.period], batch, max_len)
        for t in range(cfg.n_tail)
    ]
    return {"periods": periods, "tail": tail}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _embed(cfg: ModelConfig, params, batch: dict) -> jax.Array:
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(
        cfg.compute_dtype
    )
    if cfg.family in ("vlm", "audio") and "frontend_embeds" in batch:
        fe = jnp.einsum(
            "bsv,vd->bsd",
            batch["frontend_embeds"].astype(cfg.compute_dtype),
            params["frontend_proj"],
        )
        n_img = fe.shape[1]
        x = jnp.concatenate([fe, x[:, n_img:]], axis=1)
    return constrain(x, "batch seq embed")


def _stack_apply(
    cfg: ModelConfig,
    params,
    x: jax.Array,
    *,
    positions,
    mode: str,
    caches=None,
    cache_index=None,
    remat: bool = False,
):
    """Run the full layer stack. Returns (x, new_caches, aux_loss)."""
    P = cfg.period

    def period_body(carry, xs):
        x, aux = carry
        period_params, cache_slices = xs
        new_slices = []
        for i in range(P):
            c = None if cache_slices is None else cache_slices[i]
            x, nc, a = block_apply(
                cfg,
                cfg.pattern[i],
                period_params[i],
                x,
                positions=positions,
                mode=mode,
                cache=c,
                cache_index=cache_index,
            )
            x = constrain(x, "batch seq embed")
            aux = aux + a
            new_slices.append(nc)
        ys = None if mode == "train" else tuple(new_slices)
        return (x, aux), ys

    body = period_body
    if remat:
        body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.nothing_saveable
        )

    aux0 = jnp.zeros((), jnp.float32)
    xs_params = tuple(params["stack"]["periods"])
    new_period_caches = None
    if cfg.n_periods > 0:
        if caches is None:
            (x, aux), _ = jax.lax.scan(
                lambda c, xs: body(c, (xs, None)), (x, aux0), xs_params
            )
        else:
            (x, aux), new_period_caches = jax.lax.scan(
                body, (x, aux0), (xs_params, tuple(caches["periods"]))
            )
    else:
        aux = aux0

    new_tail = []
    for t in range(cfg.n_tail):
        spec = cfg.pattern[t % P]
        c = None if caches is None else caches["tail"][t]
        x, nc, a = block_apply(
            cfg,
            spec,
            params["stack"]["tail"][t],
            x,
            positions=positions,
            mode=mode,
            cache=c,
            cache_index=cache_index,
        )
        aux = aux + a
        new_tail.append(nc)

    new_caches = None
    if mode in ("prefill", "decode"):
        new_caches = {
            "periods": list(new_period_caches) if new_period_caches else [],
            "tail": new_tail,
        }
    return x, new_caches, aux


def _logits(cfg: ModelConfig, params, h: jax.Array) -> jax.Array:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", h, w)


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------
def lm_loss(
    cfg: ModelConfig,
    params,
    batch: dict,
    *,
    vocab_chunk: int = 0,
    constrain_logits=None,
) -> jax.Array:
    """Next-token cross entropy; labels = tokens shifted left."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(cfg, params, batch)
    positions = jnp.arange(S)
    h, _, aux = _stack_apply(
        cfg, params, x, positions=positions, mode="train",
        remat=cfg.remat != "none",
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate(
        [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)],
        axis=1,
    )
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"].astype(jnp.float32)

    def ce_of(hc, lc, mc):
        logits = jnp.einsum("bsd,dv->bsv", hc, w).astype(jnp.float32)
        if constrain_logits is not None:
            logits = constrain_logits(logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mc), jnp.sum(mc)

    if vocab_chunk and S > vocab_chunk:
        n = S // vocab_chunk

        def body(carry, xs):
            hc, lc, mc = xs
            s, c = ce_of(hc, lc, mc)
            return (carry[0] + s, carry[1] + c), None

        hs = h.reshape(B, n, vocab_chunk, -1).transpose(1, 0, 2, 3)
        ls = labels.reshape(B, n, vocab_chunk).transpose(1, 0, 2)
        ms = mask.reshape(B, n, vocab_chunk).transpose(1, 0, 2)
        (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hs, ls, ms))
    else:
        tot, cnt = ce_of(h, labels, mask)
    loss = tot / jnp.maximum(cnt, 1.0)
    n_moe = sum(s.mlp in ("moe", "moe_dense") for s in cfg.pattern)
    if n_moe:
        loss = loss + 0.01 * aux / jnp.maximum(
            float(n_moe * max(cfg.n_periods, 1)), 1.0
        )
    return loss


def lm_prefill(cfg: ModelConfig, params, batch: dict, max_len: int | None = None):
    """Full-sequence prefill. Returns (last-token logits [B, V], caches)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    x = _embed(cfg, params, batch)
    positions = jnp.arange(S)
    caches = init_caches(cfg, B, max_len)
    h, caches, _ = _stack_apply(
        cfg,
        params,
        x,
        positions=positions,
        mode="prefill",
        caches=caches,
        cache_index=0,
    )
    logits = _logits(cfg, params, h[:, -1:, :])
    return logits[:, 0, :], caches


def lm_decode_step(cfg: ModelConfig, params, caches, token: jax.Array, pos):
    """One decode step. token [B] int32; pos = #tokens already cached.
    Returns (logits [B, V], new caches)."""
    batch = {"tokens": token[:, None]}
    x = _embed(cfg, params, batch)
    positions = jnp.asarray(pos)[None]
    h, caches, _ = _stack_apply(
        cfg,
        params,
        x,
        positions=positions,
        mode="decode",
        caches=caches,
        cache_index=pos,
    )
    logits = _logits(cfg, params, h)
    return logits[:, 0, :], caches


__all__ = [
    "VIT_DIM",
    "lm_init",
    "init_caches",
    "lm_loss",
    "lm_prefill",
    "lm_decode_step",
]
