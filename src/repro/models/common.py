"""Model configuration + shared building blocks for the architecture zoo.

Design notes
------------
* Pure-functional: ``params = init(cfg, key)``; apply fns take params
  explicitly. Everything is a pytree of jnp arrays.
* **Period-scan**: layer stacks are described by a *pattern* — a short
  tuple of per-layer :class:`LayerSpec` that repeats. Parameters for each
  position in the pattern are stacked over repeats and the stack is
  traversed with ``lax.scan`` (+ optional tail for non-divisible depths).
  This keeps the lowered HLO at O(pattern) rather than O(n_layers) —
  essential for the 512-device dry-run compiles — while supporting
  heterogeneous interleaves (gemma3 local:global 5:1, jamba attn:mamba
  1:7, llama4 dense:MoE 1:1) with exact memory/FLOP accounting.
* Sharding is expressed with *logical axis names* attached to every
  parameter (see ``parallel/sharding.py`` for the logical->mesh rules).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

# logical axis vocabulary (mapped to mesh axes in parallel/sharding.py)
AX_VOCAB = "vocab"
AX_EMBED = "embed"        # d_model
AX_HEADS = "heads"
AX_KV_HEADS = "kv_heads"
AX_HEAD_DIM = "head_dim"
AX_FF = "ff"
AX_EXPERT = "expert"
AX_LAYERS = "layers"      # stacked period axis — never sharded
AX_CONV = "conv"
AX_STATE = "state"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position inside the repeating layer pattern."""

    kind: str = "attn"          # "attn" | "mamba" | "rwkv"
    mlp: str = "dense"          # "dense" | "moe" | "moe_dense" (parallel both)
    window: int = 0             # 0 = global attention; >0 = sliding window


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    expert_ff: int = 0
    shared_expert_ff: int = 0   # 0 = no shared expert
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    conv_k: int = 4
    expand: int = 2
    dt_rank: int = 0            # 0 -> ceil(d_model / 16)
    chunk: int = 256            # scan chunk length (memory/compute knob)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    chunk: int = 32             # chunked-scan length (numerics knob)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "lm"          # lm | moe | ssm | hybrid | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0           # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: MoEConfig = MoEConfig()
    mamba: MambaConfig = MambaConfig()
    rwkv: RWKVConfig = RWKVConfig()
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    mlp_type: str = "swiglu"    # "swiglu" | "gelu" (non-gated, 2 matmuls)
    tie_embeddings: bool = False
    # enc-dec (whisper): n_layers is the decoder depth
    n_enc_layers: int = 0
    # vlm: number of leading positions fed by the (stubbed) vision frontend
    n_img_tokens: int = 0
    # numerics / memory
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"         # "none" | "full" | "dots"
    # attention implementation: "auto" picks pallas on TPU, blocked-jnp ref
    # elsewhere; "ref" forces the pure-jnp oracle
    attn_impl: str = "auto"
    # sequence-parallel attention (shard seq over 'model' axis for norms/mlp)
    seq_shard_decode: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def n_tail(self) -> int:
        return self.n_layers - self.n_periods * self.period

    def layer_spec(self, i: int) -> LayerSpec:
        return self.pattern[i % self.period]

    def validate(self) -> "ModelConfig":
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA divisibility"
        for spec in self.pattern:
            if spec.mlp in ("moe", "moe_dense"):
                assert self.moe.n_experts > 0
        return self


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Logical-axis annotation: params are stored as plain arrays; a parallel
# "axes" pytree of tuples carries the logical names for sharding rules.
# ---------------------------------------------------------------------------
class Annotated(dict):
    """dict pytree of params with `.axes` side table (same tree structure,
    leaves are tuples of logical axis names)."""


def dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Primitive layers (pure fns over explicit params)
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rotary(x, positions, theta):
    """x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


__all__ = [
    "AX_VOCAB",
    "AX_EMBED",
    "AX_HEADS",
    "AX_KV_HEADS",
    "AX_HEAD_DIM",
    "AX_FF",
    "AX_EXPERT",
    "AX_LAYERS",
    "AX_CONV",
    "AX_STATE",
    "LayerSpec",
    "MoEConfig",
    "MambaConfig",
    "RWKVConfig",
    "ModelConfig",
    "param_count",
    "dense_init",
    "rms_norm",
    "rotary",
    "swiglu",
]
