"""RWKV-6 "Finch" block: data-dependent-decay time mix + channel mix.

Faithful structure: token-shift interpolation with data-dependent mix
(simplified: per-channel learned mix vectors; the low-rank "ddlerp" of
the full release is noted in DESIGN.md), LoRA-projected decay
w = exp(-exp(..)), the WKV6 recurrence (repro.kernels.rwkv6_scan), bonus
u, per-head group-norm (plain RMS here), gated output, and the
squared-ReLU channel mix. Decode carries the [B,H,N,N] WKV state and the
one-token shift state per mixer.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan import rwkv6_scan, rwkv6_decode_step
from .attention import Param
from .common import (
    AX_EMBED,
    AX_FF,
    AX_HEAD_DIM,
    AX_HEADS,
    AX_STATE,
    ModelConfig,
    dense_init,
)


class RWKVState(NamedTuple):
    wkv: jax.Array      # [B, H, N, N] f32
    shift_t: jax.Array  # [B, 1, d] last token (time mix)
    shift_c: jax.Array  # [B, 1, d] last token (channel mix)


def _dims(cfg: ModelConfig):
    N = cfg.rwkv.head_dim
    H = cfg.d_model // N
    return H, N


def rwkv_init(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    H, N = _dims(cfg)
    dt = cfg.param_dtype
    lora = max(32, d // 64)
    ks = jax.random.split(key, 12)
    mix = lambda k: Param(
        jax.random.uniform(k, (5, d), jnp.float32, minval=0.0, maxval=1.0).astype(dt),
        (AX_STATE, AX_EMBED),
    )
    return {
        "mix": mix(ks[0]),  # interpolation weights for (r,k,v,w,g)
        "wr": Param(dense_init(ks[1], (d, H, N), d, dt), (AX_EMBED, AX_HEADS, AX_HEAD_DIM)),
        "wk": Param(dense_init(ks[2], (d, H, N), d, dt), (AX_EMBED, AX_HEADS, AX_HEAD_DIM)),
        "wv": Param(dense_init(ks[3], (d, H, N), d, dt), (AX_EMBED, AX_HEADS, AX_HEAD_DIM)),
        "wg": Param(dense_init(ks[4], (d, H, N), d, dt), (AX_EMBED, AX_HEADS, AX_HEAD_DIM)),
        # decay LoRA: w = exp(-exp(base + tanh(x W1) W2))
        "w_base": Param(
            jnp.linspace(-6.0, -0.3, d, dtype=jnp.float32).reshape(1, d),
            (AX_STATE, AX_EMBED),
        ),
        "w_lora1": Param(dense_init(ks[5], (d, lora), d, dt), (AX_EMBED, AX_STATE)),
        "w_lora2": Param(
            (jax.random.normal(ks[6], (lora, d)) * 0.01).astype(jnp.float32),
            (AX_STATE, AX_EMBED),
        ),
        "u": Param(
            (jax.random.normal(ks[7], (H, N)) * 0.3).astype(jnp.float32),
            (AX_HEADS, AX_HEAD_DIM),
        ),
        "ln_scale": Param(jnp.zeros((H, N), jnp.float32), (AX_HEADS, AX_HEAD_DIM)),
        "wo": Param(dense_init(ks[8], (H, N, d), d, dt), (AX_HEADS, AX_HEAD_DIM, AX_EMBED)),
        # channel mix
        "cmix": Param(
            jax.random.uniform(ks[9], (2, d), jnp.float32, minval=0.0, maxval=1.0).astype(dt),
            (AX_STATE, AX_EMBED),
        ),
        "ck": Param(dense_init(ks[10], (d, cfg.d_ff), d, dt), (AX_EMBED, AX_FF)),
        "cv": Param(dense_init(ks[11], (cfg.d_ff, d), cfg.d_ff, dt), (AX_FF, AX_EMBED)),
        "cr": Param(dense_init(jax.random.fold_in(key, 99), (d, d), d, dt), (AX_EMBED, AX_EMBED)),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int) -> RWKVState:
    H, N = _dims(cfg)
    return RWKVState(
        wkv=jnp.zeros((batch, H, N, N), jnp.float32),
        shift_t=jnp.zeros((batch, 1, cfg.d_model), cfg.compute_dtype),
        shift_c=jnp.zeros((batch, 1, cfg.d_model), cfg.compute_dtype),
    )


def _token_shift(x, prev):
    """Shift right by one; position 0 sees `prev` (zeros at seq start)."""
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1, :]], axis=1)


def _group_rms(x, scale, eps):
    # x [B,S,H,N] — per-head normalisation
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale)[None, None]).astype(
        x.dtype
    )


def _time_mix_inputs(cfg, p, x, shifted):
    H, N = _dims(cfg)
    mix = p["mix"].astype(x.dtype)  # [5, d]
    xr, xk, xv, xw, xg = (
        x * mix[i][None, None, :] + shifted * (1 - mix[i][None, None, :])
        for i in range(5)
    )
    from repro.parallel.ctx import constrain

    B, S, d = x.shape
    hax = "batch seq heads head_dim"
    r = constrain(jnp.einsum("bsd,dhn->bshn", xr, p["wr"]), hax)
    k = constrain(jnp.einsum("bsd,dhn->bshn", xk, p["wk"]), hax)
    v = constrain(jnp.einsum("bsd,dhn->bshn", xv, p["wv"]), hax)
    g = constrain(jnp.einsum("bsd,dhn->bshn", xg, p["wg"]), hax)
    # data-dependent decay (log-space LoRA)
    wl = jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["w_lora1"]).astype(jnp.float32))
    logw_in = p["w_base"][0][None, None, :] + jnp.einsum(
        "bsl,ld->bsd", wl, p["w_lora2"]
    )
    w = jnp.exp(-jnp.exp(logw_in)).reshape(B, S, H, N)
    return r, k, v, g, w


def _channel_mix(p, xc, shifted_c, dtype):
    cmix = p["cmix"].astype(dtype)
    xk_c = xc * cmix[0][None, None] + shifted_c * (1 - cmix[0][None, None])
    xr_c = xc * cmix[1][None, None] + shifted_c * (1 - cmix[1][None, None])
    kk = jnp.einsum("bsd,df->bsf", xk_c, p["ck"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(dtype)
    return jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr_c, p["cr"]).astype(jnp.float32)
    ).astype(dtype) * jnp.einsum("bsf,fd->bsd", kk, p["cv"])


def rwkv_apply(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    n1,
    n2,
    state: Optional[RWKVState] = None,
    *,
    return_state: bool = False,
):
    """Full RWKV block on the raw residual stream:
    x1 = x + time_mix(rms(x, n1)); out = x1 + channel_mix(rms(x1, n2))."""
    from .common import rms_norm

    B, S, d = x.shape
    xn = rms_norm(x, n1, cfg.norm_eps)
    prev_t = (
        state.shift_t if state is not None else jnp.zeros((B, 1, d), x.dtype)
    )
    shifted = _token_shift(xn, prev_t)
    r, k, v, g, w = _time_mix_inputs(cfg, p, xn, shifted)
    s0 = state.wkv if state is not None else None
    out, wkv = rwkv6_scan(
        r, k, v, w, p["u"], s0,
        chunk=cfg.rwkv.chunk,
        impl="ref" if cfg.attn_impl == "ref" else "auto",
    )
    out = _group_rms(out, p["ln_scale"], cfg.norm_eps)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(out.dtype)
    tm = jnp.einsum("bshn,hnd->bsd", out, p["wo"])

    x1 = x + tm
    xc = rms_norm(x1, n2, cfg.norm_eps)
    prev_c = (
        state.shift_c if state is not None else jnp.zeros((B, 1, d), x.dtype)
    )
    shifted_c = _token_shift(xc, prev_c)
    y = x1 + _channel_mix(p, xc, shifted_c, x.dtype)
    if return_state:
        new_state = RWKVState(
            wkv=wkv, shift_t=xn[:, -1:, :], shift_c=xc[:, -1:, :]
        )
        return y, new_state
    return y, None


def rwkv_decode(cfg: ModelConfig, p: dict, x: jax.Array, n1, n2, state: RWKVState):
    """One token (S=1) using the sequential recurrence."""
    from .common import rms_norm

    B, S, d = x.shape
    xn = rms_norm(x, n1, cfg.norm_eps)
    shifted = state.shift_t.astype(x.dtype)
    r, k, v, g, w = _time_mix_inputs(cfg, p, xn, shifted)
    out, wkv = rwkv6_decode_step(
        r[:, 0], k[:, 0], v[:, 0], w[:, 0], p["u"], state.wkv
    )
    out = out[:, None]  # [B,1,H,N]
    out = _group_rms(out, p["ln_scale"], cfg.norm_eps)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(out.dtype)
    tm = jnp.einsum("bshn,hnd->bsd", out, p["wo"])

    x1 = x + tm
    xc = rms_norm(x1, n2, cfg.norm_eps)
    shifted_c = state.shift_c.astype(x.dtype)
    y = x1 + _channel_mix(p, xc, shifted_c, x.dtype)
    return y, RWKVState(wkv=wkv, shift_t=xn, shift_c=xc)


__all__ = [
    "RWKVState",
    "rwkv_init",
    "rwkv_apply",
    "rwkv_decode",
    "init_rwkv_state",
]
