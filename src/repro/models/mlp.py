"""Feed-forward blocks: dense SwiGLU and Mixture-of-Experts.

MoE uses argsort-based token dispatch with a static per-expert capacity
(GShard-style, but the dispatch is a gather rather than a one-hot
matmul: the one-hot "dispatch einsum" is O(T^2 k d) FLOPs at 32k tokens
and would dominate the expert compute itself — the sort+gather is
memory-bound instead, which is the TPU-correct trade).

Supports the zoo's three MoE shapes:
* llama4-maverick: 128e top-1 + shared expert, alternating dense/MoE
* arctic: 128e top-2 + parallel dense residual MLP ("moe_dense")
* jamba: 16e top-2 every other layer
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import Param
from .common import (
    AX_EMBED,
    AX_EXPERT,
    AX_FF,
    ModelConfig,
    dense_init,
)


# ---------------------------------------------------------------------------
# dense SwiGLU
# ---------------------------------------------------------------------------
def mlp_init(cfg: ModelConfig, key, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": Param(dense_init(k2, (d, f), d, dt), (AX_EMBED, AX_FF)),
        "w_down": Param(dense_init(k3, (f, d), f, dt), (AX_FF, AX_EMBED)),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = Param(dense_init(k1, (d, f), d, dt), (AX_EMBED, AX_FF))
    return p


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    from repro.parallel.ctx import constrain

    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    h = constrain(h, "batch seq ff")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def moe_init(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    m = cfg.moe
    f = m.expert_ff or cfg.d_ff
    dt = cfg.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "router": Param(
            dense_init(k1, (d, m.n_experts), d, jnp.float32),
            (AX_EMBED, AX_EXPERT),
        ),
        # expert weights use a dedicated FSDP axis name: the giants
        # exempt them (EP-sharded already; FSDP would re-gather them per
        # microbatch — measured dominant collective, EXPERIMENTS.md §Perf)
        "we_gate": Param(
            dense_init(k2, (m.n_experts, d, f), d, dt),
            (AX_EXPERT, "embed_moe", AX_FF),
        ),
        "we_up": Param(
            dense_init(k3, (m.n_experts, d, f), d, dt),
            (AX_EXPERT, "embed_moe", AX_FF),
        ),
        "we_down": Param(
            dense_init(k4, (m.n_experts, f, d), f, dt),
            (AX_EXPERT, AX_FF, "embed_moe"),
        ),
    }
    if m.shared_expert_ff:
        sub = jax.random.fold_in(key, 17)
        p["shared"] = mlp_init(cfg, sub, d_ff=m.shared_expert_ff)
    return p


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array, constrain=None):
    """x [B, S, d] -> ([B, S, d], aux_loss).

    **Row-local dispatch**: routing, argsort, capacity and the
    gather/scatter all preserve the batch dim, so under DP sharding no
    token ever crosses a data shard — the only cross-shard traffic is
    the TP all-reduce of the combined output (a global-argsort dispatch
    measured 3.8 GB/layer of all-gather on arctic; see EXPERIMENTS.md
    §Perf). Capacity is per sequence: C = cf * S * K / E.

    aux_loss is the Switch-style load-balance term E * sum(f_e * p_e).
    Capacity-dropped tokens pass through with zero MoE contribution."""
    from repro.parallel.ctx import constrain as ctx_constrain

    B, S, d = x.shape
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    SK = S * K
    if SK < E:
        # decode / tiny-sequence regime: per-row capacity floors would
        # pad E*C slots per row for K routed pairs (measured 6-8x decode
        # regression on the MoE giants); a global dispatch over the
        # whole (small) token set is cheap and exact here.
        return _moe_apply_global(cfg, p, x)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)                      # [B, S, E]
    gate_k, expert_k = jax.lax.top_k(gates, K)                   # [B, S, K]
    gate_k = gate_k / jnp.maximum(
        jnp.sum(gate_k, axis=-1, keepdims=True), 1e-9
    )
    f = jnp.mean(
        jax.nn.one_hot(expert_k[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    aux = E * jnp.sum(f * jnp.mean(gates, axis=(0, 1)))

    # ---- per-row argsort dispatch with static per-row capacity ---------
    C = max(8, int(m.capacity_factor * SK / E))
    C = min(C, SK)
    flat_e = expert_k.reshape(B, SK)                             # [B, SK]
    tok_ix = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)       # [SK]
    flat_g = gate_k.reshape(B, SK)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    stok = jnp.take_along_axis(
        jnp.broadcast_to(tok_ix[None], (B, SK)), order, axis=1
    )
    sg = jnp.take_along_axis(flat_g, order, axis=1)
    first = jax.vmap(
        lambda row: jnp.searchsorted(row, row, side="left")
    )(se)
    pos_in_e = jnp.arange(SK)[None, :] - first
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)             # drop -> OOB
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, SK))
    tok_table = (
        jnp.full((B, E * C + 1), S, jnp.int32)
        .at[rows, slot]
        .set(stok, mode="drop")[:, : E * C]
    )
    gate_table = (
        jnp.zeros((B, E * C + 1), jnp.float32)
        .at[rows, slot]
        .set(jnp.where(keep, sg, 0.0), mode="drop")[:, : E * C]
    )

    x_pad = ctx_constrain(
        jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1),
        "batch seq embed",
    )
    gathered = _batch_local_gather(x_pad, tok_table)
    xe = gathered.reshape(B, E, C, d)
    xe = ctx_constrain(xe, "batch expert expert_cap embed")
    if constrain is not None:
        xe = constrain(xe)

    g = jnp.einsum("becd,edf->becf", xe, p["we_gate"])
    u = jnp.einsum("becd,edf->becf", xe, p["we_up"])
    h = ctx_constrain(
        jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
        "batch expert expert_cap ff",
    )
    ye = jnp.einsum("becf,efd->becd", h, p["we_down"])           # [B,E,C,d]
    ye = ye * gate_table.reshape(B, E, C, 1).astype(ye.dtype)

    # ---- combine: per-row scatter-add back to tokens --------------------
    out = _batch_local_combine(ye, tok_table, S)[:, :S]
    if "shared" in p:
        out = out + mlp_apply(p["shared"], x)
    return out, aux


def _moe_apply_global(cfg: ModelConfig, p: dict, x: jax.Array):
    """Global-argsort dispatch over all B*S tokens — the decode path
    (B*S*K < E), where the token table is tiny and per-row capacity
    would be pure padding."""
    B, S, d = x.shape
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    T = B * S
    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    gate_k, expert_k = jax.lax.top_k(gates, K)
    gate_k = gate_k / jnp.maximum(jnp.sum(gate_k, -1, keepdims=True), 1e-9)
    f = jnp.mean(jax.nn.one_hot(expert_k[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(f * jnp.mean(gates, axis=0))

    C = max(1, min(int(m.capacity_factor * T * K / E) + 1, T))
    flat_e = expert_k.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = gate_k.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, stok, sg = flat_e[order], flat_t[order], flat_g[order]
    pos = jnp.arange(T * K) - jnp.searchsorted(se, se, side="left")
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)
    tok_table = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        stok, mode="drop")[: E * C]
    gate_table = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sg, 0.0), mode="drop")[: E * C]

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = xt_pad[tok_table].reshape(E, C, d)
    g = jnp.einsum("ecd,edf->ecf", xe, p["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["we_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["we_down"])
    ye = ye * gate_table.reshape(E, C, 1).astype(ye.dtype)
    out = (
        jnp.zeros((T + 1, d), ye.dtype)
        .at[tok_table]
        .add(ye.reshape(E * C, d), mode="drop")[:T]
    ).reshape(B, S, d)
    if "shared" in p:
        out = out + mlp_apply(p["shared"], x)
    return out, aux


# ---------------------------------------------------------------------------
# Dispatch gather/scatter with *explicit* per-shard semantics. Under a
# sharding context these run inside shard_map over the batch axes —
# GSPMD's auto-partitioner otherwise solves the remat-replayed gather by
# all-gathering the full [B, S, d] token array per MoE layer (measured
# 3.8 GB/layer on arctic; EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------
def _batch_local_gather(x_pad, tok_table):
    from repro.parallel.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel.ctx import batch_axes_in_mesh, get_ctx

    def gather(xp, tt):
        return jnp.take_along_axis(
            xp, tt[..., None].astype(jnp.int32), axis=1
        )

    ctx = get_ctx()
    bd = batch_axes_in_mesh(x_pad.shape[0]) if ctx else None
    if not bd:
        return gather(x_pad, tok_table)
    mesh, _ = ctx
    return shard_map(
        gather,
        mesh=mesh,
        in_specs=(P(bd, None, None), P(bd, None)),
        out_specs=P(bd, None, None),
        check_vma=False,
    )(x_pad, tok_table)


def _batch_local_combine(ye, tok_table, S):
    """ye [B, E, C, d] (experts sharded on 'model'), tok_table [B, E*C]
    -> [B, S+1, d] combined (psum over the expert/model axis)."""
    from repro.parallel.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel.ctx import batch_axes_in_mesh, get_ctx

    B, E, C, d = ye.shape

    def scatter(ye_l, tt_l, e0):
        b = ye_l.shape[0]
        e_loc = ye_l.shape[1]
        # local slice of the dispatch table for this expert shard
        tt_slice = jax.lax.dynamic_slice_in_dim(
            tt_l, e0 * e_loc * C, e_loc * C, axis=1
        )
        rows = jnp.broadcast_to(jnp.arange(b)[:, None], (b, e_loc * C))
        out = (
            jnp.zeros((b, S + 1, d), ye_l.dtype)
            .at[rows, tt_slice]
            .add(ye_l.reshape(b, e_loc * C, d), mode="drop")
        )
        return out

    ctx = get_ctx()
    bd = batch_axes_in_mesh(B) if ctx else None
    mesh = ctx[0] if ctx else None
    use_model = (
        bd is not None
        and mesh is not None
        and "model" in mesh.axis_names
        and E % mesh.shape["model"] == 0
    )
    if not bd or not use_model:
        rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, E * C))
        return (
            jnp.zeros((B, S + 1, d), ye.dtype)
            .at[rows, tok_table]
            .add(ye.reshape(B, E * C, d), mode="drop")
        )

    def body(ye_l, tt_l):
        e0 = jax.lax.axis_index("model")
        partial = scatter(ye_l, tt_l, e0)
        return jax.lax.psum(partial, "model")

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(bd, "model", None, None), P(bd, None)),
        out_specs=P(bd, None, None),
        check_vma=False,
    )(ye, tok_table)


__all__ = ["mlp_init", "mlp_apply", "moe_init", "moe_apply"]
