"""GQA attention block: projections + RoPE + flash attention + KV cache.

Supports the zoo's full attention variety: MQA (granite kv=1), MHA
(phi3 kv=32), GQA (everything else), sliding-window local layers
(gemma3 5:1 local:global), non-causal encoder attention and
cross-attention (whisper), and one-token decode against a cache.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from .common import (
    AX_EMBED,
    AX_HEAD_DIM,
    AX_HEADS,
    AX_KV_HEADS,
    ModelConfig,
    dense_init,
    rotary,
)


class Param(NamedTuple):
    value: jax.Array
    axes: tuple


def unzip(tree):
    """Split a tree with Param leaves into (params, axes) trees.

    Axes become space-separated strings (atomic pytree leaves) so the
    axes tree is structurally identical to the params tree."""
    is_p = lambda x: isinstance(x, Param)
    params = jax.tree.map(lambda p: p.value, tree, is_leaf=is_p)
    axes = jax.tree.map(lambda p: " ".join(p.axes), tree, is_leaf=is_p)
    return params, axes


def attn_init(cfg: ModelConfig, key) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": Param(
            dense_init(k1, (d, H, hd), d, dt), (AX_EMBED, AX_HEADS, AX_HEAD_DIM)
        ),
        "wk": Param(
            dense_init(k2, (d, KV, hd), d, dt),
            (AX_EMBED, AX_KV_HEADS, AX_HEAD_DIM),
        ),
        "wv": Param(
            dense_init(k3, (d, KV, hd), d, dt),
            (AX_EMBED, AX_KV_HEADS, AX_HEAD_DIM),
        ),
        "wo": Param(
            dense_init(k4, (H, hd, d), H * hd, dt),
            (AX_HEADS, AX_HEAD_DIM, AX_EMBED),
        ),
    }


def cross_attn_init(cfg: ModelConfig, key) -> dict:
    return attn_init(cfg, key)


class KVCache(NamedTuple):
    k: jax.Array  # [B, S_max, KV, hd]
    v: jax.Array


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int) -> KVCache:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(
        k=jnp.zeros(shape, cfg.compute_dtype),
        v=jnp.zeros(shape, cfg.compute_dtype),
    )


def attn_apply(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                      # [B, S, d]
    *,
    positions: jax.Array,              # [S] (or scalar position for decode)
    window: int = 0,
    causal: bool = True,
    use_rope: bool = True,
    cache: Optional[KVCache] = None,
    cache_index=None,                  # scalar: #tokens already in cache
    kv_override: Optional[tuple] = None,  # (k, v) for cross-attention
):
    """Returns (y [B,S,d], new_cache)."""
    from repro.parallel.ctx import constrain

    B, S, _ = x.shape
    q = constrain(jnp.einsum("bsd,dhn->bshn", x, p["wq"]),
                  "batch seq heads head_dim")
    if kv_override is None:
        k = constrain(jnp.einsum("bsd,dkn->bskn", x, p["wk"]),
                      "batch seq kv_heads head_dim")
        v = constrain(jnp.einsum("bsd,dkn->bskn", x, p["wv"]),
                      "batch seq kv_heads head_dim")
        if use_rope:
            kv_pos = positions
            k = rotary(k, kv_pos, cfg.rope_theta)
    else:
        k, v = kv_override
    if use_rope:
        q = rotary(q, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and kv_override is None:
        idx = jnp.asarray(cache_index, jnp.int32)
        W_cache = cache.k.shape[1]
        ring = window > 0 and W_cache == window
        if ring:
            return _ring_cache_attend(
                cfg, p, q, k, v, cache, idx, S, window
            )
        # plain cache: write the fresh K/V at cache_index
        ck = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, idx, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, idx, 0, 0)
        )
        new_cache = KVCache(ck, cv)
        kv_len = idx + S
        if S == 1:
            # one-token decode: a single masked einsum over the cache.
            # The KV-block scan would force per-block resharding of a
            # seq-sharded cache; the einsum keeps KV local and lets
            # GSPMD reduce only the [B,H] softmax partials across
            # context-parallel shards.
            y = decode_attention(
                q, ck, cv, kv_len=kv_len, window=window, q_pos=idx
            )
        else:
            y = flash_attention(
                q,
                ck,
                cv,
                causal=causal,
                window=window,
                q_offset=idx,
                kv_len=kv_len,
                impl=cfg.attn_impl if cfg.attn_impl != "auto" else "auto",
            )
    else:
        attn = lambda q_, k_, v_: flash_attention(
            q_, k_, v_, causal=causal, window=window,
            impl=cfg.attn_impl if cfg.attn_impl != "auto" else "auto",
        )
        if cfg.remat != "none":
            # recompute score blocks in backward instead of saving every
            # [B,Sq,H,block_k] f32 panel (dominant peak for wide-head
            # archs whose heads replicate across TP)
            attn = jax.checkpoint(
                attn, policy=jax.checkpoint_policies.nothing_saveable
            )
        y = attn(q, k, v)
    out = jnp.einsum("bshn,hnd->bsd", y, p["wo"])
    return out, new_cache


def _ring_cache_attend(cfg, p, q, k, v, cache, idx, S, window):
    """Sliding-window layer with a ring-buffer cache of `window` slots.
    Slot j holds position p_j = idx' - ((idx' - j) mod W) for the newest
    idx'; masking by p_j >= 0 covers the not-yet-full phase, and every
    resident position is inside the window by construction."""
    W = window
    if S == 1:
        slot = idx % W
        ck = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0)
        )
        j = jnp.arange(W)
        slot_pos = idx - ((idx - j) % W)          # in (idx-W, idx]
        y = decode_attention(
            q, ck, cv, kv_len=idx + 1, window=W, q_pos=idx,
            slot_pos=slot_pos,
        )
        out = jnp.einsum("bshn,hnd->bsd", y, p["wo"])
        return out, KVCache(ck, cv)
    # prefill (assumes idx == 0): attend over the in-flight K/V, then
    # retire only the last `window` positions into the ring
    y = flash_attention(
        q, k, v, causal=True, window=W,
        impl=cfg.attn_impl if cfg.attn_impl != "auto" else "auto",
    )
    start = max(S - W, 0)
    n = S - start
    positions = jnp.arange(start, S)
    slots = positions % W
    ck = cache.k.at[:, slots].set(k[:, start:].astype(cache.k.dtype))
    cv = cache.v.at[:, slots].set(v[:, start:].astype(cache.v.dtype))
    out = jnp.einsum("bshn,hnd->bsd", y, p["wo"])
    return out, KVCache(ck, cv)


def decode_attention(q, k, v, *, kv_len, window=0, q_pos=0, slot_pos=None):
    """Single-query attention over a (possibly seq-sharded) KV cache.

    q [B,1,H,D]; k/v [B,S,KV,D]. Softmax over the full S with masking by
    kv_len (and sliding window). `slot_pos` overrides the position of
    each cache slot (ring buffers). Numerically: plain max-subtracted
    softmax in f32 — one token's scores are [B,H,S], tiny per shard.
    """
    B, _, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qf = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qf, k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) / (D ** 0.5)
    if slot_pos is None:
        pos = jnp.arange(Skv)
        ok = pos[None, None, None, :] < jnp.asarray(kv_len)
        if window > 0:
            ok = ok & (pos[None, None, None, :] > jnp.asarray(q_pos) - window)
    else:
        pos = slot_pos
        ok = (pos >= 0)[None, None, None, :] & (
            pos[None, None, None, :] <= jnp.asarray(q_pos)
        )
    s = jnp.where(ok, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p / jnp.maximum(denom, 1e-30),
        v.astype(jnp.float32),
    )
    return out.reshape(B, 1, H, D).astype(q.dtype)


def encode_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array):
    """Precompute cross-attention K/V from encoder output (whisper)."""
    k = jnp.einsum("bsd,dkn->bskn", enc_out, p["wk"])
    v = jnp.einsum("bsd,dkn->bskn", enc_out, p["wv"])
    return k, v


__all__ = [
    "Param",
    "unzip",
    "KVCache",
    "init_kv_cache",
    "attn_init",
    "cross_attn_init",
    "attn_apply",
    "encode_kv",
]
