"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, S_enc, VIT_DIM] (as if the two
conv-downsampling layers already ran); the transformer backbone is real.
Positions are fixed sinusoidal (computed on the fly — no giant learned
tables at 32k+ frames); attention: encoder bidirectional, decoder causal
self-attention + cross-attention over encoder output.

Shape mapping for the assigned input shapes: seq_len = encoder frame
count (long-form audio), decoder length = max(64, seq_len // 8).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .attention import (
    Param,
    attn_apply,
    attn_init,
    cross_attn_init,
    encode_kv,
    init_kv_cache,
    unzip,
)
from .common import (
    AX_EMBED,
    AX_LAYERS,
    AX_STATE,
    AX_VOCAB,
    ModelConfig,
    rms_norm,
)
from .lm import VIT_DIM, _stacked_init
from .mlp import mlp_apply, mlp_init


def dec_len(cfg: ModelConfig, s_enc: int) -> int:
    return max(64, s_enc // 8)


def sinusoidal(S, d, offset=0):
    pos = (jnp.arange(S) + offset)[:, None].astype(jnp.float32)
    half = d // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _enc_block_init(cfg, key):
    k1, k2 = jax.random.split(key)
    zero = lambda: Param(jnp.zeros((cfg.d_model,), jnp.float32), (AX_EMBED,))
    return {"n1": zero(), "attn": attn_init(cfg, k1), "n2": zero(),
            "mlp": mlp_init(cfg, k2)}


def _dec_block_init(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    zero = lambda: Param(jnp.zeros((cfg.d_model,), jnp.float32), (AX_EMBED,))
    return {
        "n1": zero(), "self": attn_init(cfg, k1),
        "nc": zero(), "cross": cross_attn_init(cfg, k2),
        "n2": zero(), "mlp": mlp_init(cfg, k3),
    }


def encdec_init(cfg: ModelConfig, key):
    assert cfg.n_enc_layers > 0
    ks = jax.random.split(key, 6)
    tree = {
        "frontend_proj": Param(
            (jax.random.normal(ks[0], (VIT_DIM, cfg.d_model)) * 0.02).astype(
                cfg.param_dtype
            ),
            (AX_STATE, AX_EMBED),
        ),
        "embed": Param(
            (jax.random.normal(ks[1], (cfg.vocab, cfg.d_model)) * 0.02).astype(
                cfg.param_dtype
            ),
            (AX_VOCAB, AX_EMBED),
        ),
        "enc_norm": Param(jnp.zeros((cfg.d_model,), jnp.float32), (AX_EMBED,)),
        "final_norm": Param(jnp.zeros((cfg.d_model,), jnp.float32), (AX_EMBED,)),
        "head": Param(
            (jax.random.normal(ks[2], (cfg.d_model, cfg.vocab)) * 0.02).astype(
                cfg.param_dtype
            ),
            (AX_EMBED, AX_VOCAB),
        ),
    }
    params, axes = unzip(tree)
    for name, init_fn, n, kk in (
        ("enc", _enc_block_init, cfg.n_enc_layers, ks[3]),
        ("dec", _dec_block_init, cfg.n_layers, ks[4]),
    ):
        stacked = _stacked_init(lambda k: unzip(init_fn(cfg, k))[0], kk, n)
        _, ax = unzip(init_fn(cfg, jax.random.PRNGKey(0)))
        ax = jax.tree.map(lambda s: f"{AX_LAYERS} {s}".strip(), ax)
        params[name] = stacked
        axes[name] = ax
    return params, axes


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def encode(cfg: ModelConfig, params, frames: jax.Array) -> jax.Array:
    """frames [B, S_enc, VIT_DIM] -> encoder output [B, S_enc, d]."""
    B, S, _ = frames.shape
    x = jnp.einsum(
        "bsv,vd->bsd", frames.astype(cfg.compute_dtype), params["frontend_proj"]
    )
    x = x + sinusoidal(S, cfg.d_model)[None].astype(x.dtype)
    positions = jnp.arange(S)

    def body(x, layer):
        h = rms_norm(x, layer["n1"], cfg.norm_eps)
        y, _ = attn_apply(
            cfg, layer["attn"], h, positions=positions, causal=False,
            use_rope=False,
        )
        x = x + y
        h2 = rms_norm(x, layer["n2"], cfg.norm_eps)
        return x + mlp_apply(layer["mlp"], h2), None

    if cfg.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer(cfg, layer, x, positions, cross_kv, *, mode, cache=None,
               cache_index=None):
    h = rms_norm(x, layer["n1"], cfg.norm_eps)
    if mode == "train":
        y, nc = attn_apply(
            cfg, layer["self"], h, positions=positions, use_rope=False
        ), None
        y = y[0]
    else:
        y, nc = attn_apply(
            cfg, layer["self"], h, positions=positions, use_rope=False,
            cache=cache, cache_index=cache_index,
        )
    x = x + y
    hc = rms_norm(x, layer["nc"], cfg.norm_eps)
    yc, _ = attn_apply(
        cfg, layer["cross"], hc, positions=positions, causal=False,
        use_rope=False, kv_override=cross_kv,
    )
    x = x + yc
    h2 = rms_norm(x, layer["n2"], cfg.norm_eps)
    return x + mlp_apply(layer["mlp"], h2), nc


def decode_train(cfg: ModelConfig, params, tokens, enc_out):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = x + sinusoidal(S, cfg.d_model)[None].astype(x.dtype)
    positions = jnp.arange(S)

    def body(x, layer):
        cross_kv = encode_kv(cfg, layer["cross"], enc_out)
        y, _ = _dec_layer(cfg, layer, x, positions, cross_kv, mode="train")
        return y, None

    if cfg.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(body, x, params["dec"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def encdec_loss(cfg: ModelConfig, params, batch, vocab_chunk: int = 0):
    tokens = batch["tokens"]
    enc_out = encode(cfg, params, batch["frontend_embeds"])
    h = decode_train(cfg, params, tokens, enc_out)
    logits = jnp.einsum("bsd,dv->bsv", h, params["head"]).astype(jnp.float32)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    B, S = tokens.shape
    mask = jnp.concatenate(
        [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)], 1
    )
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


class EncDecCaches(NamedTuple):
    self_kv: Any      # stacked KVCache over dec layers
    cross_kv: Any     # stacked (k, v) over dec layers


def encdec_prefill(cfg: ModelConfig, params, batch, max_dec: int):
    """Encode audio + prefill the decoder with its BOS tokens.
    Returns (last logits [B, V], caches)."""
    enc_out = encode(cfg, params, batch["frontend_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = x + sinusoidal(S, cfg.d_model)[None].astype(x.dtype)
    positions = jnp.arange(S)
    self0 = jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (cfg.n_layers,) + t.shape),
        init_kv_cache(cfg, B, max_dec),
    )

    def body(x, xs):
        layer, cache = xs
        cross_kv = encode_kv(cfg, layer["cross"], enc_out)
        y, nc = _dec_layer(
            cfg, layer, x, positions, cross_kv, mode="prefill",
            cache=cache, cache_index=0,
        )
        return y, (nc, cross_kv)

    x, (self_kv, cross_kv) = jax.lax.scan(body, x, (params["dec"], self0))
    h = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["head"])
    return logits[:, 0].astype(jnp.float32), EncDecCaches(self_kv, cross_kv)


def encdec_decode_step(cfg: ModelConfig, params, caches: EncDecCaches,
                       token, pos):
    B = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(
        cfg.compute_dtype
    )
    x = x + sinusoidal(1, cfg.d_model, offset=pos)[None].astype(x.dtype)
    positions = jnp.asarray(pos)[None]

    def body(x, xs):
        layer, cache, cross_kv = xs
        y, nc = _dec_layer(
            cfg, layer, x, positions, cross_kv, mode="decode",
            cache=cache, cache_index=pos,
        )
        return y, nc

    x, self_kv = jax.lax.scan(
        body, x, (params["dec"], caches.self_kv, caches.cross_kv)
    )
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["head"])
    return logits[:, 0].astype(jnp.float32), EncDecCaches(self_kv, caches.cross_kv)


__all__ = [
    "dec_len",
    "sinusoidal",
    "encdec_init",
    "encode",
    "encdec_loss",
    "encdec_prefill",
    "encdec_decode_step",
    "EncDecCaches",
]
