"""Layer assembly: (norm + mixer + norm + mlp/moe) per LayerSpec.

A *block* is one layer of the stack. Mixer kinds: attn / mamba / rwkv
(rwkv handles its own channel-mix + norms, matching the reference RWKV
block structure). MLP kinds: dense / moe / moe_dense (arctic's parallel
dense-residual + MoE).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .attention import (
    Param,
    attn_apply,
    attn_init,
    init_kv_cache,
)
from .common import AX_EMBED, LayerSpec, ModelConfig, rms_norm
from .mlp import mlp_apply, mlp_init, moe_apply, moe_init
from .rwkv import (
    init_rwkv_state,
    rwkv_apply,
    rwkv_decode,
    rwkv_init,
)
from .ssm import (
    init_mamba_state,
    mamba_apply,
    mamba_decode,
    mamba_init,
)


def _norm_param(cfg: ModelConfig) -> Param:
    return Param(jnp.zeros((cfg.d_model,), jnp.float32), (AX_EMBED,))


def block_init(cfg: ModelConfig, spec: LayerSpec, key) -> dict:
    ks = jax.random.split(key, 4)
    if spec.kind == "rwkv":
        return {
            "n1": _norm_param(cfg),
            "n2": _norm_param(cfg),
            "rwkv": rwkv_init(cfg, ks[0]),
        }
    p: dict[str, Any] = {"n1": _norm_param(cfg), "n2": _norm_param(cfg)}
    if spec.kind == "attn":
        p["attn"] = attn_init(cfg, ks[0])
    elif spec.kind == "mamba":
        p["mamba"] = mamba_init(cfg, ks[0])
    else:
        raise ValueError(spec.kind)
    if spec.mlp == "dense":
        p["mlp"] = mlp_init(cfg, ks[1])
    elif spec.mlp == "moe":
        p["moe"] = moe_init(cfg, ks[1])
    elif spec.mlp == "moe_dense":
        p["moe"] = moe_init(cfg, ks[1])
        p["mlp"] = mlp_init(cfg, ks[2])
    else:
        raise ValueError(spec.mlp)
    return p


def init_block_cache(
    cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int
):
    if spec.kind == "attn":
        # sliding-window layers keep a ring buffer of `window` slots —
        # for gemma3 decode that is 1024 instead of 32768 positions on
        # 5 of every 6 layers (~4.9x less KV memory+traffic)
        eff = min(max_len, spec.window) if spec.window > 0 else max_len
        return init_kv_cache(cfg, batch, eff)
    if spec.kind == "mamba":
        return init_mamba_state(cfg, batch)
    if spec.kind == "rwkv":
        return init_rwkv_state(cfg, batch)
    raise ValueError(spec.kind)


def block_apply(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    mode: str,                 # "train" | "prefill" | "decode"
    cache=None,
    cache_index=None,
):
    """Returns (x, new_cache, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    new_cache = None
    if spec.kind == "rwkv":
        if mode == "decode":
            y, nc = _rwkv_block_decode(cfg, p, x, cache)
        else:
            y, nc = _rwkv_block(cfg, p, x, cache, return_state=mode == "prefill")
        return y, nc, zero

    h = rms_norm(x, p["n1"], cfg.norm_eps)
    if spec.kind == "attn":
        if mode == "train":
            y, _ = attn_apply(
                cfg, p["attn"], h, positions=positions, window=spec.window
            )
        else:
            y, new_cache = attn_apply(
                cfg,
                p["attn"],
                h,
                positions=positions,
                window=spec.window,
                cache=cache,
                cache_index=cache_index,
            )
    elif spec.kind == "mamba":
        if mode == "decode":
            y, new_cache = mamba_decode(cfg, p["mamba"], h, cache)
        else:
            y, new_cache = mamba_apply(
                cfg, p["mamba"], h, cache if mode == "decode" else None,
                return_state=mode == "prefill",
            )
    x = x + y

    aux = zero
    h2 = rms_norm(x, p["n2"], cfg.norm_eps)
    if spec.mlp == "dense":
        x = x + mlp_apply(p["mlp"], h2)
    elif spec.mlp == "moe":
        y2, aux = moe_apply(cfg, p["moe"], h2)
        x = x + y2
    else:  # moe_dense: arctic's parallel residual
        y2, aux = moe_apply(cfg, p["moe"], h2)
        x = x + y2 + mlp_apply(p["mlp"], h2)
    return x, new_cache, aux


def _rwkv_block(cfg, p, x, state, return_state):
    return rwkv_apply(
        cfg, p["rwkv"], x, p["n1"], p["n2"], state, return_state=return_state
    )


def _rwkv_block_decode(cfg, p, x, state):
    return rwkv_decode(cfg, p["rwkv"], x, p["n1"], p["n2"], state)


__all__ = [
    "block_init",
    "block_apply",
    "init_block_cache",
]
