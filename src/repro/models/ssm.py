"""Mamba-1 block (jamba's sequence mixer).

in_proj -> (x, z gate); short causal conv on x; data-dependent (dt, B, C)
projections; selective scan (repro.kernels.ssm_scan); gated out_proj.
Decode keeps two tiny states per layer: the SSM state [B, d_inner, N]
and the conv tail [B, conv_k-1, d_inner].
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import ssm_scan, ssm_decode_step
from .attention import Param
from .common import AX_CONV, AX_EMBED, AX_FF, AX_STATE, ModelConfig, dense_init


class MambaState(NamedTuple):
    h: jax.Array        # [B, d_inner, N] f32
    conv: jax.Array     # [B, conv_k - 1, d_inner]


def _dims(cfg: ModelConfig):
    d_inner = cfg.mamba.expand * cfg.d_model
    dt_rank = cfg.mamba.dt_rank or max(1, math.ceil(cfg.d_model / 16))
    return d_inner, dt_rank, cfg.mamba.d_state, cfg.mamba.conv_k


def mamba_init(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    di, dtr, N, K = _dims(cfg)
    dt = cfg.param_dtype
    ks = jax.random.split(key, 8)
    A = -jnp.exp(
        jax.random.uniform(
            ks[0], (di, N), jnp.float32, minval=0.0, maxval=math.log(16.0)
        )
    )
    return {
        "in_proj": Param(
            dense_init(ks[1], (d, 2 * di), d, dt), (AX_EMBED, AX_FF)
        ),
        "conv_w": Param(
            dense_init(ks[2], (K, di), K, dt), (AX_CONV, AX_FF)
        ),
        "conv_b": Param(jnp.zeros((di,), dt), (AX_FF,)),
        "x_proj": Param(
            dense_init(ks[3], (di, dtr + 2 * N), di, dt), (AX_FF, AX_STATE)
        ),
        "dt_proj": Param(
            dense_init(ks[4], (dtr, di), dtr, dt), (AX_STATE, AX_FF)
        ),
        "dt_bias": Param(
            jnp.log(
                jnp.exp(
                    jax.random.uniform(
                        ks[5], (di,), jnp.float32, minval=1e-3, maxval=0.1
                    )
                )
                - 1.0
            ).astype(jnp.float32),
            (AX_FF,),
        ),
        "A_log": Param(jnp.log(-A), (AX_FF, AX_STATE)),
        "D": Param(jnp.ones((di,), jnp.float32), (AX_FF,)),
        "out_proj": Param(
            dense_init(ks[6], (di, d), di, dt), (AX_FF, AX_EMBED)
        ),
    }


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    di, _, N, K = _dims(cfg)
    return MambaState(
        h=jnp.zeros((batch, di, N), jnp.float32),
        conv=jnp.zeros((batch, K - 1, di), cfg.compute_dtype),
    )


def _causal_conv(x, w, b, tail=None):
    """x [B,S,di], w [K,di] depthwise; optional tail [B,K-1,di] prefix."""
    K = w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # [B, S+K-1, di]
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    return out + b[None, None, :], xp[:, -(K - 1) :, :]


def mamba_apply(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                       # [B, S, d]
    state: Optional[MambaState] = None,
    *,
    return_state: bool = False,
):
    from repro.parallel.ctx import constrain

    di, dtr, N, K = _dims(cfg)
    xz = constrain(jnp.einsum("bsd,de->bse", x, p["in_proj"]), "batch seq ff")
    xi, z = jnp.split(xz, 2, axis=-1)                 # [B,S,di] each
    xi, conv_tail = _causal_conv(
        xi, p["conv_w"], p["conv_b"], None if state is None else state.conv
    )
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("bse,ez->bsz", xi, p["x_proj"])
    dt_in, B_in, C_in = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsz,ze->bse", dt_in, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"][None, None, :]
    )
    A = -jnp.exp(p["A_log"])
    h0 = None if state is None else state.h
    y, h = ssm_scan(
        xi, dt, A, B_in, C_in, p["D"], h0,
        chunk=cfg.mamba.chunk, impl="ref" if cfg.attn_impl == "ref" else "auto",
    )
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_state:
        return out, MambaState(h=h, conv=conv_tail)
    return out, None


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: MambaState):
    """One-token step. x [B, 1, d] -> (y [B,1,d], new state)."""
    di, dtr, N, K = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)                  # [B,1,di]
    window = jnp.concatenate([state.conv.astype(xi.dtype), xi], axis=1)
    conv_out = (
        jnp.einsum("bke,ke->be", window, p["conv_w"]) + p["conv_b"][None, :]
    )[:, None, :]
    xi = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("bse,ez->bsz", xi, p["x_proj"])
    dt_in, B_in, C_in = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsz,ze->bse", dt_in, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"][None, None, :]
    )
    A = -jnp.exp(p["A_log"])
    y, h = ssm_decode_step(
        xi[:, 0], dt[:, 0], A, B_in[:, 0], C_in[:, 0], p["D"], state.h
    )
    y = y[:, None, :] * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, MambaState(h=h, conv=window[:, 1:, :])


__all__ = [
    "MambaState",
    "mamba_init",
    "mamba_apply",
    "mamba_decode",
    "init_mamba_state",
]
