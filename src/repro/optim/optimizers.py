"""Optimizers: AdamW and Adafactor (factored second moment).

Built in-repo (no optax in the image). Design points for the ≥398B
MoE/hybrid archs on 16 GB/chip:

* Optimizer state inherits every parameter's sharding (the state trees
  are `tree_map`s of the param tree, so pjit shards them identically —
  ZeRO-style by construction when params are FSDP-sharded).
* Adafactor keeps the second moment factored over the last two dims
  (rows/cols), cutting optimizer HBM from 8 bytes/param to ~0; moments
  are stored in the configured `state_dtype` (f32 default, bf16 for the
  giants).
* Global-norm clipping + warmup-cosine schedule included.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: Any                  # per-optimizer state tree


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"         # "adamw" | "adafactor"
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32


def warmup_cosine(cfg: OptConfig) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) + 1.0  # first update at warm > 0
        warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
        t = jnp.clip(
            (step - cfg.warmup_steps)
            / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return cfg.peak_lr * warm * (0.1 + 0.9 * cos)

    return lr


def _global_norm(tree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip(tree, clip_norm):
    g = _global_norm(tree)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), g


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(cfg: OptConfig, params):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        inner={
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        },
    )


def adamw_update(cfg: OptConfig, grads, state: OptState, params):
    lr = warmup_cosine(cfg)(state.step)
    grads, gnorm = _clip(grads, cfg.clip_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = p.astype(jnp.float32) - lr * delta
        return (
            p_new.astype(p.dtype),
            m_new.astype(cfg.state_dtype),
            v_new.astype(cfg.state_dtype),
        )

    out = jax.tree.map(upd, params, grads, state.inner["m"], state.inner["v"])
    p_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return p_new, OptState(step=step, inner={"m": m_new, "v": v_new}), gnorm


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; first moment omitted, beta1=0 style)
# ---------------------------------------------------------------------------
def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(cfg: OptConfig, params):
    def init_v(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], cfg.state_dtype),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], cfg.state_dtype),
            }
        return {"v": jnp.zeros(p.shape, cfg.state_dtype)}

    return OptState(
        step=jnp.zeros((), jnp.int32),
        inner=jax.tree.map(
            init_v, params, is_leaf=lambda x: isinstance(x, jax.Array)
        ),
    )


def adafactor_update(cfg: OptConfig, grads, state: OptState, params):
    lr = warmup_cosine(cfg)(state.step)
    grads, gnorm = _clip(grads, cfg.clip_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8

    def upd(p, g, v):
        gf = g.astype(jnp.float32)
        g2 = jnp.square(gf) + 1e-30
        if "vr" in v:
            vr = beta2 * v["vr"].astype(jnp.float32) + (1 - beta2) * jnp.mean(
                g2, axis=-1
            )
            vc = beta2 * v["vc"].astype(jnp.float32) + (1 - beta2) * jnp.mean(
                g2, axis=-2
            )
            denom = (
                vr[..., None]
                * vc[..., None, :]
                / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True)[..., None], 1e-30
                )
            )
            precond = gf * jax.lax.rsqrt(jnp.maximum(denom, 1e-30))
            v_new = {
                "vr": vr.astype(cfg.state_dtype),
                "vc": vc.astype(cfg.state_dtype),
            }
        else:
            vf = beta2 * v["v"].astype(jnp.float32) + (1 - beta2) * g2
            precond = gf * jax.lax.rsqrt(jnp.maximum(vf, 1e-30))
            v_new = {"v": vf.astype(cfg.state_dtype)}
        # relative-scale update clipping (Adafactor's d=1.0)
        rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-30)
        precond = precond / jnp.maximum(1.0, rms)
        p_new = p.astype(jnp.float32) - lr * (
            precond + cfg.weight_decay * p.astype(jnp.float32)
        )
        return p_new.astype(p.dtype), v_new

    is_v = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    out = jax.tree.map(
        upd, params, grads, state.inner,
        is_leaf=lambda x: isinstance(x, jax.Array) or is_v(x),
    )
    leaf_pair = lambda x: isinstance(x, tuple) and len(x) == 2
    p_new = jax.tree.map(lambda o: o[0], out, is_leaf=leaf_pair)
    v_new = jax.tree.map(lambda o: o[1], out, is_leaf=leaf_pair)
    return p_new, OptState(step=step, inner=v_new), gnorm


# ---------------------------------------------------------------------------
def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return adamw_init, adamw_update
    if cfg.name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(cfg.name)


__all__ = [
    "OptConfig",
    "OptState",
    "warmup_cosine",
    "adamw_init",
    "adamw_update",
    "adafactor_init",
    "adafactor_update",
    "make_optimizer",
]
