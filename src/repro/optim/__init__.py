from .optimizers import (
    OptState,
    adafactor_init,
    adamw_init,
    make_optimizer,
    warmup_cosine,
)

__all__ = [
    "OptState",
    "adamw_init",
    "adafactor_init",
    "make_optimizer",
    "warmup_cosine",
]
