"""Continuous-batching serving runtime.

Slot-based continuous batching (vLLM-style, adapted to fixed-shape JAX):
a fixed pool of B sequence slots shares one KV cache; prefill fills a
free slot, every decode step advances all live slots together. The
admission/preemption policy (who gets a slot first, who is evicted when
an interactive request arrives) is *chosen by replaying the trace in
Eudoxia first* (bridge.evaluate_policies) — the paper's tool closing the
loop on the real runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # prompt
    max_new: int
    interactive: bool = True
    out: Optional[list] = None


class ContinuousBatcher:
    """Fixed-slot continuous batcher over the functional LM API."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_len: int,
                 policy: str = "priority"):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.policy = policy
        self.caches = lm.init_caches(cfg, slots, max_len)
        self.live: list[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int32)       # per-slot next position
        self.last_tok = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self.done: list[Request] = []

        self._decode = jax.jit(
            lambda p, c, t, pos: lm.lm_decode_step(cfg, p, c, t, pos)
        )

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)
        if self.policy.startswith("priority"):
            self.queue.sort(key=lambda r: (not r.interactive,))

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.live):
            if r is None:
                return i
        return None

    def _admit(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None and self.policy.startswith("priority"):
                # interactive head may preempt a batch job (Eudoxia's
                # priority semantics, applied to slots)
                head = self.queue[0]
                if head.interactive:
                    victims = [
                        i for i, r in enumerate(self.live)
                        if r is not None and not r.interactive
                    ]
                    if victims:
                        v = victims[-1]
                        evicted = self.live[v]
                        self.live[v] = None
                        # re-queue with progress kept in its token list
                        evicted.tokens = np.concatenate(
                            [evicted.tokens, np.asarray(evicted.out, np.int32)]
                        )
                        evicted.max_new -= len(evicted.out)
                        evicted.out = []
                        self.queue.append(evicted)
                        slot = v
            if slot is None:
                return
            req = self.queue.pop(0)
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        # single-sequence prefill, written into the slot of the shared cache
        toks = jnp.asarray(req.tokens, jnp.int32)[None, :]
        logits, cache1 = lm.lm_prefill(
            self.cfg, self.params, {"tokens": toks}, max_len=self.max_len
        )
        # splice this sequence's cache into slot `slot`
        def splice(shared, single):
            if shared.ndim >= 2 and single.shape[0] == 1:
                return jax.lax.dynamic_update_slice_in_dim(
                    shared, single.astype(shared.dtype), slot, axis=0
                )
            return shared

        def splice_entry(shared, single):
            return jax.tree.map(splice, shared, single)

        # caches trees have leading [layers/period] axes inside; batch is
        # axis 0 of each leaf for tail, axis 1 for stacked periods
        def splice_leaf(shared, single):
            if shared.ndim == single.ndim and shared.shape[1:] == single.shape[1:]:
                return jax.lax.dynamic_update_slice_in_dim(
                    shared, single.astype(shared.dtype), slot, axis=0
                )
            # stacked periods: [P, B, ...]
            return jax.lax.dynamic_update_slice_in_dim(
                shared, single.astype(shared.dtype), slot, axis=1
            )

        self.caches = jax.tree.map(splice_leaf, self.caches, cache1)
        self.live[slot] = req
        self.pos[slot] = len(req.tokens)
        self.last_tok[slot] = int(jnp.argmax(logits[0]))
        req.out.append(int(self.last_tok[slot]))

    # ------------------------------------------------------------------
    def step(self):
        """One decode step for all live slots."""
        self._admit()
        if not any(r is not None for r in self.live):
            return False
        pos = int(self.pos.max())  # uniform position (fixed-shape decode)
        toks = jnp.asarray(self.last_tok, jnp.int32)
        logits, self.caches = self._decode(
            self.params, self.caches, toks, pos
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i, req in enumerate(self.live):
            if req is None:
                continue
            req.out.append(int(nxt[i]))
            self.last_tok[i] = nxt[i]
            self.pos[i] += 1
            if len(req.out) >= req.max_new or self.pos[i] >= self.max_len - 1:
                self.done.append(req)
                self.live[i] = None
        return True

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or any(self.live)) and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return self.done


__all__ = ["Request", "ContinuousBatcher"]
