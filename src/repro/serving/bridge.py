"""Eudoxia <-> serving bridge: the paper's simulator as a first-class
scheduling component of the LM serving runtime.

An inference request is a two-operator pipeline in Eudoxia's terms:

* prefill  — compute-bound; runtime scales ~linearly with allocated
  compute (alpha ~ 1), RAM ~ KV cache for the prompt;
* decode   — memory-bound sequential generation; does NOT scale with
  extra compute (alpha ~ 0), runtime ~ new_tokens x per-token latency.

``requests_to_pipelines`` converts a request trace into Eudoxia
pipelines (priority INTERACTIVE for chat, BATCH for offline jobs);
``evaluate_policies`` replays the trace under each candidate scheduler
in the simulator and returns the metrics table — this is how
``launch/serve.py`` picks its admission/preemption policy before
touching the real cluster.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import (
    Operator,
    Pipeline,
    Priority,
    SimParams,
    TICKS_PER_SECOND,
    run,
    workload_from_pipelines,
)


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    arrival_s: float
    prompt_tokens: int
    new_tokens: int
    interactive: bool = True


def _kv_gb(cfg_like, tokens: int) -> float:
    """KV-cache GB for `tokens` (per request)."""
    L = getattr(cfg_like, "n_layers", 32)
    kv = getattr(cfg_like, "n_kv_heads", 8)
    hd = getattr(cfg_like, "hd", 128)
    return 2 * L * kv * hd * tokens * 2 / 1e9


def requests_to_pipelines(
    requests: Sequence[ServeRequest],
    cfg_like,
    *,
    prefill_tok_per_s_per_cpu: float = 4000.0,
    decode_tok_per_s: float = 50.0,
) -> list[Pipeline]:
    """Map a request trace onto Eudoxia pipelines (one per request).

    The CPU-scaling abstraction carries the roofline insight: prefill is
    compute-bound (alpha=1 — more chips, faster), decode is bandwidth-
    bound (alpha=0 — extra chips don't help a single sequence).
    """
    out = []
    for i, r in enumerate(requests):
        prefill_s = r.prompt_tokens / prefill_tok_per_s_per_cpu
        decode_s = r.new_tokens / decode_tok_per_s
        ram = max(_kv_gb(cfg_like, r.prompt_tokens + r.new_tokens), 0.05)
        ops = [
            Operator(
                ram_gb=ram,
                base_ticks=max(int(prefill_s * TICKS_PER_SECOND), 1),
                alpha=1.0,
                level=0,
            ),
            Operator(
                ram_gb=ram,
                base_ticks=max(int(decode_s * TICKS_PER_SECOND), 1),
                alpha=0.0,
                level=1,
            ),
        ]
        out.append(
            Pipeline(
                pid=i,
                priority=Priority.INTERACTIVE if r.interactive else Priority.BATCH,
                arrival_tick=int(r.arrival_s * TICKS_PER_SECOND),
                ops=ops,
            )
        )
    return out


def evaluate_policies(
    requests: Sequence[ServeRequest],
    cfg_like,
    *,
    duration_s: float = 10.0,
    total_cpus: float = 64.0,
    total_ram_gb: float = 128.0,
    policies: Sequence[str] = ("naive", "priority", "priority_pool"),
    num_pools: int = 2,
) -> dict[str, dict]:
    """Replay the trace under each scheduling policy; returns metrics."""
    results = {}
    for policy in policies:
        params = SimParams(
            duration=duration_s,
            scheduling_algo=policy,
            num_pools=num_pools if policy == "priority_pool" else 1,
            total_cpus=total_cpus,
            total_ram_gb=total_ram_gb,
            max_pipelines=max(64, len(requests)),
            max_containers=128,
        )
        pipelines = requests_to_pipelines(requests, cfg_like)
        wl = workload_from_pipelines(pipelines, params)
        res = run(params, workload=wl, engine="event")
        results[policy] = res.summary()
    return results


def pick_policy(results: dict[str, dict], objective: str = "interactive_p99"):
    """Choose the policy: lowest interactive latency, ties by throughput."""
    def key(name):
        s = results[name]
        inter = s["per_priority"]["interactive"]
        lat = inter["mean_latency_s"]
        lat = float("inf") if lat != lat else lat  # NaN -> inf
        return (lat, -s["throughput_per_s"])

    return min(results, key=key)


__all__ = [
    "ServeRequest",
    "requests_to_pipelines",
    "evaluate_policies",
    "pick_policy",
]
