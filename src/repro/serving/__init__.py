from .bridge import requests_to_pipelines, evaluate_policies
from .batching import ContinuousBatcher, Request

__all__ = [
    "requests_to_pipelines",
    "evaluate_policies",
    "ContinuousBatcher",
    "Request",
]
