"""Sharded, atomic, async checkpointing with elastic restore.

Design (multi-host production shape, exercised single-host here):

* Every host writes only the *addressable shards* of every array
  (``host_<k>.msgpack.zst``); a JSON manifest records the tree
  structure, global shapes/dtypes and each shard's index ranges.
* Writes go to ``step_<n>.tmp/`` then ``rename`` to ``step_<n>/`` —
  a crashed writer never corrupts the latest checkpoint (restart-safe).
* ``async_save`` runs serialisation on a background thread with a copy
  of the host-local buffers, so the train loop keeps stepping.
* **Elastic restore**: arrays are reassembled from shard metadata and
  ``device_put`` with the *target* sharding — the restoring job may run
  on a different mesh shape than the writer (tests cover 4->8 and 8->4
  device resharding).
* Keep-last-k garbage collection.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ModuleNotFoundError:  # gate the optional dep: fall back to zlib
    zstandard = None
import zlib


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, level=3)


def _decompress(payload: bytes) -> bytes:
    # zstd frames start with magic 0xFD2FB528 (little-endian on disk);
    # sniff it so either codec's checkpoints restore on any host.
    if payload[:4] == b"\x28\xb5\x2f\xfd":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but zstandard is not "
                "installed; `pip install zstandard` to restore it"
            )
        return zstandard.ZstdDecompressor().decompress(payload)
    return zlib.decompress(payload)


def _tree_flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat], treedef


def _pack_array(x: np.ndarray) -> dict:
    return {
        "dtype": str(x.dtype),
        "shape": list(x.shape),
        "data": x.tobytes(),
    }


def _unpack_array(d: dict) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(
        d["shape"]
    )


def save_checkpoint(state: Any, directory: str | pathlib.Path, step: int,
                    extra: Optional[dict] = None) -> pathlib.Path:
    """Write `state` (pytree of jax/np arrays) for `step`. Atomic."""
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, _ = _tree_flatten_with_paths(state)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    shards: dict[str, dict] = {}
    for path, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"].append(
            {"path": path, "dtype": str(arr.dtype), "shape": list(arr.shape)}
        )
        shards[path] = _pack_array(arr)

    payload = _compress(msgpack.packb(shards, use_bin_type=True))
    host = jax.process_index()
    (tmp / f"host_{host}.msgpack.zst").write_bytes(payload)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(directory: str | pathlib.Path) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str | pathlib.Path,
    template: Any,
    step: Optional[int] = None,
    shardings: Any = None,
):
    """Restore into the structure of `template`; `shardings` (same tree
    shape, NamedSharding leaves) enables elastic restore onto any mesh."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = directory / f"step_{step:08d}"
    shards: dict[str, dict] = {}
    for f in sorted(d.glob("host_*.msgpack.zst")):
        shards.update(
            msgpack.unpackb(_decompress(f.read_bytes()), raw=False)
        )

    leaves, treedef = _tree_flatten_with_paths(template)
    sh_leaves = None
    if shardings is not None:
        sh_flat, _ = _tree_flatten_with_paths(shardings)
        sh_leaves = dict(sh_flat)
    out = []
    for path, leaf in leaves:
        if path not in shards:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = _unpack_array(shards[path])
        if sh_leaves is not None and path in sh_leaves:
            out.append(jax.device_put(arr, sh_leaves[path]))
        else:
            out.append(jnp.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, out)
    manifest = json.loads((d / "manifest.json").read_text())
    return state, manifest


@dataclasses.dataclass
class CheckpointManager:
    directory: str | pathlib.Path
    keep: int = 3

    def __post_init__(self):
        self.directory = pathlib.Path(self.directory)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---- sync ----------------------------------------------------------
    def save(self, state, step: int, extra: Optional[dict] = None):
        p = save_checkpoint(state, self.directory, step, extra)
        self._gc()
        return p

    # ---- async ---------------------------------------------------------
    def async_save(self, state, step: int, extra: Optional[dict] = None):
        """Snapshot to host memory now; compress+write on a thread."""
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save_checkpoint(snapshot, self.directory, step, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, template, step=None, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, template, step, shardings)

    def latest_step(self):
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(
            p for p in pathlib.Path(self.directory).glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp")
        )
        for p in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(p, ignore_errors=True)


__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "CheckpointManager",
]
