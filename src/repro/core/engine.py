"""Simulation engines.

Two compiled engines advance the same transition functions:

* **tick** — the paper-faithful loop: one `lax.scan` iteration per 10 µs
  tick ("Each iteration represents 1 CPU tick", §3.2).
* **event** — an event-skip engine (`lax.while_loop`) that jumps straight
  to the next arrival / completion / OOM / suspension-release / decision
  follow-up tick. Because scheduler decisions are pure functions of the
  state and the state is constant between events, both engines produce
  identical metrics — a property the test-suite checks. This is the
  headline performance optimisation over the paper's implementation
  (see EXPERIMENTS.md §Perf).

Both are pure JAX: a whole simulation is one XLA program, so fleets of
simulations vmap/shard over devices (see ``sweep.py``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import executor
from .params import SimParams, load_params
from .scheduler import (
    SchedDecision,
    get_vector_scheduler,
    get_vector_scheduler_init,
)
from .state import INF_TICK, SimState, Workload, init_state
from .types import ContainerStatus, PipeStatus
from .workload import get_workload


@dataclasses.dataclass
class SimResult:
    state: SimState
    workload: Workload
    params: SimParams
    sched_state: Any = None

    def summary(self) -> dict:
        from .metrics import summarize

        return summarize(self.state, self.workload, self.params)


# ---------------------------------------------------------------------------
# One tick worth of work (shared by both engines).
# ---------------------------------------------------------------------------
def _tick_body(
    state: SimState,
    sched_state: Any,
    wl: Workload,
    params: SimParams,
    scheduler_fn: Callable,
    tick: jax.Array,
):
    state = executor.process_arrivals(state, wl, tick)
    state = executor.process_releases(state, tick)
    state = executor.process_completions(state, wl, tick, params)
    sched_state, dec = scheduler_fn(sched_state, state, wl, params)
    state = executor.apply_decision(state, wl, dec, tick, params)
    acted = (
        jnp.any(dec.suspend)
        | jnp.any(dec.reject)
        | jnp.any(dec.assign_pipe >= 0)
    )
    return state, sched_state, acted


def _sorted_arrivals(arrival: jax.Array) -> jax.Array:
    """Arrival ticks sorted ascending, INF-padded by one slot so a cursor
    that has consumed every arrival reads INF_TICK. Works along the last
    axis, so it serves both single workloads [MP] and fleets [F, MP]."""
    pad_shape = arrival.shape[:-1] + (1,)
    return jnp.concatenate(
        [jnp.sort(arrival, axis=-1), jnp.full(pad_shape, INF_TICK, jnp.int32)],
        axis=-1,
    )


def _next_event_registers(
    state: SimState, arr_sorted: jax.Array, tick: jax.Array, acted
):
    """Register-based twin of :func:`_next_event`.

    Instead of re-reducing the pipeline/container tables, reads the
    executor-maintained ``nxt_retire``/``nxt_release`` registers and
    binary-searches the arrival-sorted workload — O(log MP) per event
    rather than O(MP + MC). Provably equal to the full recompute:
    after ``process_arrivals`` at tick t, a pipeline slot is EMPTY iff
    its arrival tick is > t, so the pending-arrival minimum is the first
    sorted arrival beyond t; the register invariants cover the rest
    (see the property test in tests/test_fleet.py).

    Returns ``(next_tick, cursor)``; the cursor (count of arrivals <= t)
    is stored on the state as ``nxt_arrival_cursor``.
    """
    cursor = jnp.searchsorted(arr_sorted[:-1], tick, side="right").astype(
        jnp.int32
    )
    next_arrival = arr_sorted[cursor]
    nxt = jnp.minimum(
        jnp.minimum(next_arrival, state.nxt_retire), state.nxt_release
    )
    nxt = jnp.where(acted, jnp.minimum(nxt, tick + 1), nxt)
    return jnp.maximum(nxt, tick + 1), cursor


def _next_event(state: SimState, wl: Workload, tick: jax.Array, acted) -> jax.Array:
    """Earliest tick strictly after ``tick`` at which state can change."""
    pending = state.pipe_status == int(PipeStatus.EMPTY)
    arr = jnp.where(pending & (wl.arrival > tick), wl.arrival, INF_TICK)
    next_arrival = jnp.min(arr)

    # ctr_end/ctr_oom include the data-plane warm-up (cold-start + scan
    # ticks) baked in at creation, so release ticks of cold containers are
    # accounted for here without a separate event source. Cache contents
    # and slot warmth change only when the executor acts, never passively,
    # so they add no event sources either (warmth *expiry* is passive, but
    # it is only read at assignment ticks, which are always events).
    running = state.ctr_status == int(ContainerStatus.RUNNING)
    ends = jnp.where(running, jnp.minimum(state.ctr_end, state.ctr_oom), INF_TICK)
    next_retire = jnp.min(ends)

    suspended = state.pipe_status == int(PipeStatus.SUSPENDED)
    rel = jnp.where(suspended, state.pipe_release, INF_TICK)
    next_release = jnp.min(rel)

    nxt = jnp.minimum(jnp.minimum(next_arrival, next_retire), next_release)
    # if the scheduler acted, it may act again next tick (queue longer than
    # one decision's capacity, freshly freed resources, ...)
    nxt = jnp.where(acted, jnp.minimum(nxt, tick + 1), nxt)
    return jnp.maximum(nxt, tick + 1)


# ---------------------------------------------------------------------------
# Engines.
# ---------------------------------------------------------------------------
def _run_tick_engine(params, wl, scheduler_fn, sched_state0):
    horizon = params.horizon_ticks

    def step(carry, tick):
        state, sched_state = carry
        state, sched_state, _ = _tick_body(
            state, sched_state, wl, params, scheduler_fn, tick
        )
        state = executor.integrate(state, tick, tick + 1, params, exact_buckets=False)
        return (state, sched_state), None

    state0 = init_state(params)
    (state, sched_state), _ = jax.lax.scan(
        step,
        (state0, sched_state0),
        jnp.arange(horizon, dtype=jnp.int32),
    )
    state = state._replace(tick=jnp.asarray(horizon, jnp.int32))
    return state, sched_state


def _run_event_engine(params, wl, scheduler_fn, sched_state0):
    horizon = jnp.int32(params.horizon_ticks)
    arr_sorted = _sorted_arrivals(wl.arrival)

    def cond(carry):
        state, _ = carry
        return state.tick < horizon

    def body(carry):
        state, sched_state = carry
        tick = state.tick
        state, sched_state, acted = _tick_body(
            state, sched_state, wl, params, scheduler_fn, tick
        )
        # register-based next event: executor-maintained nxt_retire /
        # nxt_release + a binary search of the sorted arrivals, instead
        # of the full-table reduction (_next_event stays as the
        # recompute-from-scratch reference, property-tested against this)
        nxt, cursor = _next_event_registers(state, arr_sorted, tick, acted)
        nxt = jnp.minimum(nxt, horizon)
        state = executor.integrate(state, tick, nxt, params, exact_buckets=True)
        state = state._replace(tick=nxt, nxt_arrival_cursor=cursor)
        return state, sched_state

    state0 = init_state(params)
    state, sched_state = jax.lax.while_loop(cond, body, (state0, sched_state0))
    return state, sched_state


# ---------------------------------------------------------------------------
# Fleet-native event engine: one shared while_loop over the whole batch.
#
# ``vmap(_run_event_engine)`` (the legacy fleet path) keeps every lane in
# lockstep paying the *full* generic tick body until the slowest lane
# exhausts its events. This engine batches the loop by hand instead:
#
# * phase 1 (completions + releases + arrival admission + per-pool freed
#   resources + next-event registers) is one fused [F, MC]/[F, MP] pass
#   through ``repro.kernels.sim_tick.fleet_tick`` (Pallas on TPU, the
#   bitwise-equivalent jnp reference elsewhere);
# * the scheduler and ``apply_decision`` run their *early-exit* variants,
#   whose inner while_loops vmap into max-over-lanes trip counts — an
#   event with an empty queue no longer pays K sequential steps;
# * each lane skips to its own next event via the incremental registers
#   (O(log MP) binary search instead of O(MP + MC) table reductions);
# * finished lanes pass through untouched (`jnp.where` on the carry) and
#   the loop exits when every lane is done.
#
# Per-lane results are bitwise-identical to ``run(..., engine="event")``
# (property-tested in tests/test_fleet.py).
# ---------------------------------------------------------------------------
def _run_fleet_event_engine(params, wls, scheduler_fn, sched_state0, impl="auto"):
    from repro.kernels.sim_tick import fleet_tick

    horizon = jnp.int32(params.horizon_ticks)
    F = wls.arrival.shape[0]
    arr_sorted = _sorted_arrivals(wls.arrival)  # [F, MP + 1]

    def bcast(x):
        x = jnp.asarray(x)
        return jnp.broadcast_to(x, (F,) + x.shape)

    states0 = jax.tree.map(bcast, init_state(params))
    scheds0 = jax.tree.map(bcast, sched_state0)

    def cond(carry):
        states, _ = carry
        return jnp.any(states.tick < horizon)

    def body(carry):
        states, scheds = carry
        tick = states.tick                     # [F]
        active = tick < horizon                # [F]

        ph = fleet_tick(
            states.ctr_status, states.ctr_end, states.ctr_oom,
            states.ctr_cpus, states.ctr_ram, states.ctr_pool,
            states.pipe_status, wls.arrival, states.pipe_release,
            tick, num_pools=params.num_pools, impl=impl,
        )

        def lane(st, ss, wl, arr_l, t, ph_l):
            st = executor.apply_fused_phase1(st, wl, t, params, ph_l)
            ss, dec = scheduler_fn(ss, st, wl, params)
            st = executor.apply_decision(
                st, wl, dec, t, params, early_exit=True
            )
            acted = (
                jnp.any(dec.suspend)
                | jnp.any(dec.reject)
                | jnp.any(dec.assign_pipe >= 0)
            )
            nxt, cursor = _next_event_registers(st, arr_l, t, acted)
            nxt = jnp.minimum(nxt, horizon)
            st = executor.integrate(st, t, nxt, params, exact_buckets=True)
            return st._replace(tick=nxt, nxt_arrival_cursor=cursor), ss

        new_states, new_scheds = jax.vmap(lane)(
            states, scheds, wls, arr_sorted, tick, ph
        )

        # finished lanes pass through untouched
        def keep(n, o):
            mask = jnp.reshape(active, (F,) + (1,) * (n.ndim - 1))
            return jnp.where(mask, n, o)

        states = jax.tree.map(keep, new_states, states)
        scheds = jax.tree.map(keep, new_scheds, scheds)
        return states, scheds

    return jax.lax.while_loop(cond, body, (states0, scheds0))


@functools.partial(jax.jit, static_argnames=("params", "scheduler_key", "engine"))
def _run_compiled(
    params: SimParams,
    wl: Workload,
    scheduler_key: str,
    engine: str,
    sched_state0: Any,
):
    scheduler_fn = get_vector_scheduler(scheduler_key)
    if engine == "tick":
        return _run_tick_engine(params, wl, scheduler_fn, sched_state0)
    if engine == "event":
        return _run_event_engine(params, wl, scheduler_fn, sched_state0)
    raise ValueError(f"unknown engine {engine!r}")


def run(
    paramfile: str | dict | SimParams,
    workload: Workload | None = None,
    engine: str | None = None,
) -> SimResult:
    """Run one simulation; this is what ``eudoxia.run_simulator`` wraps."""
    params = load_params(paramfile)
    engine = engine or params.engine
    wl = workload if workload is not None else get_workload(params)
    if engine == "python":
        from .engine_python import run_python_engine

        return run_python_engine(params, wl)
    sched_state0 = get_vector_scheduler_init(params.scheduling_algo)(params)
    state, sched_state = _run_compiled(
        params, wl, params.scheduling_algo, engine, sched_state0
    )
    return SimResult(state=state, workload=wl, params=params, sched_state=sched_state)


__all__ = [
    "SimResult",
    "run",
    "_tick_body",
    "_next_event",
    "_next_event_registers",
    "_sorted_arrivals",
    "_run_fleet_event_engine",
]
