"""The lane-major simulation core.

One compiled engine advances every simulation. State is batched
*lane-major* — every array carries a leading fleet axis ``[F, ...]`` —
and a single shared ``lax.while_loop`` steps all lanes at once:

* phase 1 (completions + releases + arrival admission + per-pool freed
  resources + next-event registers) is one fused [F, MC]/[F, MP] pass
  through ``repro.kernels.sim_tick.fleet_tick`` (Pallas on TPU, the
  bitwise-equivalent jnp reference elsewhere);
* the scheduler and ``apply_decision`` run with early-exit inner loops
  (``decision_loop(early_exit=True)``), whose while_loops vmap into
  max-over-lanes trip counts — an event with an empty queue no longer
  pays K sequential steps;
* each lane skips to its own next event via the incremental
  ``nxt_retire``/``nxt_release`` registers plus an O(log MP) binary
  search of the sorted arrivals (``_next_event`` stays as the
  recompute-from-scratch oracle, property-tested in tests/test_fleet.py);
* finished lanes pass through untouched (``jnp.where`` on the carry)
  and the loop exits when every lane is done.

``run()`` is the F=1 special case (squeezed on return); ``fleet_run``
(``sweep.py``) is the N-lane case, optionally sharded across local
devices with ``shard_map``. Both engines the paper's design implied —
the per-tick ``lax.scan`` loop and a per-simulation event loop — were
deleted in the lane-major unification; the Python reference engine
(``engine="python"``) remains as the readable executable specification,
and the property suite checks the compiled core against it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import admission, executor
from .params import SimParams, load_params
from .scheduler import (
    get_vector_scheduler,
    get_vector_scheduler_init,
    mask_down_pools,
)
from .state import INF_TICK, SimState, Workload, broadcast_lanes, init_state
from .telemetry.record import TraceBuffer, record_step, step_block_rows
from .telemetry.schema import DEFAULT_TRACE_CAPACITY, RECORD_WIDTH
from .types import ContainerStatus, PipeStatus
from .workload import get_workload


@dataclasses.dataclass
class SimResult:
    state: SimState
    workload: Workload
    params: SimParams
    sched_state: Any = None
    trace: Any = None  # telemetry.TraceEvents when run(trace=True)

    def summary(self) -> dict:
        from .metrics import summarize

        return summarize(
            self.state, self.workload, self.params, trace=self.trace
        )


# ---------------------------------------------------------------------------
# One tick worth of work, as the sequential composition of executor
# passes. This is the *reference* body: the lane-major engine fuses the
# first three passes (see ``lane_event_step``), and the property suite +
# the benchmark reconstruction of the deleted vmap baseline drive this
# composition to prove the fusion semantics-preserving.
# ---------------------------------------------------------------------------
def _tick_body(
    state: SimState,
    sched_state: Any,
    wl: Workload,
    params: SimParams,
    scheduler_fn: Callable,
    tick: jax.Array,
):
    state = executor.process_arrivals(state, wl, tick)
    state = executor.process_releases(state, tick)
    state = executor.process_completions(state, wl, tick, params)
    if params.fault_events_active:
        state, _ = executor.apply_faults(state, wl, tick, params)
    if params.closed_loop_active:
        state = admission.apply_closed_loop(state, wl, tick, params)
    view = (
        mask_down_pools(state, tick)
        if params.outage_mtbf_ticks > 0
        else state
    )
    sched_state, dec = scheduler_fn(sched_state, view, wl, params)
    if params.outage_mtbf_ticks > 0:
        dec = _filter_down_pool_assignments(dec, state, tick, params)
    state = executor.apply_decision(state, wl, dec, tick, params)
    acted = (
        jnp.any(dec.suspend)
        | jnp.any(dec.reject)
        | jnp.any(dec.assign_pipe >= 0)
    )
    return state, sched_state, acted


def _filter_down_pool_assignments(
    dec, state: SimState, tick: jax.Array, params: SimParams
):
    """Drop scheduler assignments that target a down pool.

    Free-resource-driven schedulers already avoid down pools through the
    masked view (:func:`mask_down_pools`); this filter is the safety net
    for schedulers that size allocations off pool *caps* (``naive``),
    which would otherwise commit onto dead capacity — and, because the
    filtered decision feeds ``acted``, it also keeps an un-placeable
    head-of-queue from spinning the event loop tick-by-tick for the
    whole outage."""
    down = tick < state.pool_down_until
    NP = params.num_pools
    bad = (dec.assign_pipe >= 0) & down[jnp.clip(dec.assign_pool, 0, NP - 1)]
    return dec._replace(assign_pipe=jnp.where(bad, -1, dec.assign_pipe))


def _sorted_arrivals(arrival: jax.Array) -> jax.Array:
    """Arrival ticks sorted ascending, INF-padded by one slot so a cursor
    that has consumed every arrival reads INF_TICK. Works along the last
    axis, so it serves both single workloads [MP] and fleets [F, MP]."""
    pad_shape = arrival.shape[:-1] + (1,)
    return jnp.concatenate(
        [jnp.sort(arrival, axis=-1), jnp.full(pad_shape, INF_TICK, jnp.int32)],
        axis=-1,
    )


def _next_event_registers(
    state: SimState, arr_sorted: jax.Array, tick: jax.Array, acted
):
    """Register-based twin of :func:`_next_event`.

    Instead of re-reducing the pipeline/container tables, reads the
    executor-maintained ``nxt_retire``/``nxt_release`` registers and
    binary-searches the arrival-sorted workload — O(log MP) per event
    rather than O(MP + MC). Provably equal to the full recompute:
    after arrival admission at tick t, a pipeline slot is EMPTY iff
    its arrival tick is > t, so the pending-arrival minimum is the first
    sorted arrival beyond t; the register invariants cover the rest
    (see the property test in tests/test_fleet.py).

    Returns ``(next_tick, cursor)``; the cursor (count of arrivals <= t)
    is stored on the state as ``nxt_arrival_cursor``.
    """
    cursor = jnp.searchsorted(arr_sorted[:-1], tick, side="right").astype(
        jnp.int32
    )
    next_arrival = arr_sorted[cursor]
    nxt = jnp.minimum(
        jnp.minimum(next_arrival, state.nxt_retire), state.nxt_release
    )
    # chaos layer: ``apply_faults`` keeps nxt_fault at the next crash /
    # outage start / pool recovery tick; faults-off it is pinned at
    # INF_TICK, so the min is the identity there
    nxt = jnp.minimum(nxt, state.nxt_fault)
    nxt = jnp.where(acted, jnp.minimum(nxt, tick + 1), nxt)
    return jnp.maximum(nxt, tick + 1), cursor


def _next_event(state: SimState, wl: Workload, tick: jax.Array, acted) -> jax.Array:
    """Earliest tick strictly after ``tick`` at which state can change.

    The recompute-from-scratch oracle for the ``nxt_retire`` /
    ``nxt_release`` registers the engine actually navigates by:
    tests/test_fleet.py steps ``lane_event_step`` and asserts this full
    table reduction equals :func:`_next_event_registers` at every event.
    """
    pending = state.pipe_status == int(PipeStatus.EMPTY)
    arr = jnp.where(pending & (wl.arrival > tick), wl.arrival, INF_TICK)
    next_arrival = jnp.min(arr)

    # ctr_end/ctr_oom include the data-plane warm-up (cold-start + scan
    # ticks) baked in at creation, so release ticks of cold containers are
    # accounted for here without a separate event source. Cache contents
    # and slot warmth change only when the executor acts, never passively,
    # so they add no event sources either (warmth *expiry* is passive, but
    # it is only read at assignment ticks, which are always events).
    running = state.ctr_status == int(ContainerStatus.RUNNING)
    ends = jnp.where(running, jnp.minimum(state.ctr_end, state.ctr_oom), INF_TICK)
    next_retire = jnp.min(ends)

    suspended = state.pipe_status == int(PipeStatus.SUSPENDED)
    rel = jnp.where(suspended, state.pipe_release, INF_TICK)
    next_release = jnp.min(rel)

    nxt = jnp.minimum(jnp.minimum(next_arrival, next_retire), next_release)

    if wl.faults is not None:
        # chaos layer event sources, recomputed from scratch: the fault
        # trace is sorted, so "next crash/outage" is the earliest entry
        # strictly beyond ``tick`` (= what the ``crash_cursor`` /
        # ``outage_cursor`` registers index to), plus the earliest pool
        # recovery still pending.
        ft = wl.faults
        nxt_crash = jnp.min(
            jnp.where(ft.crash_time > tick, ft.crash_time, INF_TICK)
        )
        nxt_outage = jnp.min(
            jnp.where(ft.outage_start > tick, ft.outage_start, INF_TICK)
        )
        nxt_recover = jnp.min(
            jnp.where(
                state.pool_down_until > tick, state.pool_down_until, INF_TICK
            )
        )
        nxt = jnp.minimum(
            nxt, jnp.minimum(nxt_crash, jnp.minimum(nxt_outage, nxt_recover))
        )

    # if the scheduler acted, it may act again next tick (queue longer than
    # one decision's capacity, freshly freed resources, ...)
    nxt = jnp.where(acted, jnp.minimum(nxt, tick + 1), nxt)
    return jnp.maximum(nxt, tick + 1)


@contextlib.contextmanager
def _quiet_partial_donation():
    """Silence XLA's partial-donation lowering warning.

    The workload batch is donated so its big ops tables can be aliased
    into outputs; a few small leaves (op metadata with no same-shaped
    output) are not aliasable, which XLA reports once per compilation.
    That partial reuse is exactly the intent, so the note is noise here
    — but only here: the filter is scoped to the compiled-engine call
    sites, not installed globally.
    """
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        yield


# ---------------------------------------------------------------------------
# The lane-major engine.
# ---------------------------------------------------------------------------
def _zero_fault_aux(state: SimState):
    """The ``fault_aux`` of a not-due :func:`executor.apply_faults` call,
    constructed without running it — bitwise what the skipped call would
    have returned: empty kill masks, causes defaulting to 1 (= outage),
    no new outages/recoveries (``pool_down_until == tick`` would have
    made the event due), and ``pool_down_until`` passed through. Shape-
    polymorphic over a leading fleet axis."""
    i32 = jnp.int32
    MC = state.ctr_status.shape[-1]
    NP = state.pool_cpu_cap.shape[-1]
    batch = state.ctr_status.shape[:-1]
    return (
        jnp.zeros(batch + (MC,), bool),       # kill
        jnp.full(batch + (MC,), -1, i32),     # kill_pipe
        jnp.full(batch + (MC,), -1, i32),     # kill_pool
        jnp.ones(batch + (MC,), i32),         # kill_cause
        jnp.zeros(batch + (MC,), i32),        # kill_wasted
        jnp.zeros(batch + (NP,), bool),       # down_new
        jnp.zeros(batch + (NP,), bool),       # up_now
        state.pool_down_until,
    )


def _fleet_gated_faults(
    params: SimParams,
    states: SimState,
    wls: Workload,
    tick: jax.Array,
    active: jax.Array,
):
    """Run the fault pass only on events where some active lane's
    ``nxt_fault`` register is due; event-skip steps with no fault due
    pay one scalar compare instead of the full pass. The skipped call
    is a provable identity (``tick < nxt_fault`` means the searchsorted
    cursors do not move, the kill masks are empty, and the register
    recompute reproduces itself), so the gate is bitwise-neutral. The
    predicate is hoisted to the fleet level because a per-lane cond
    under ``vmap`` lowers to a select that runs both branches."""
    due = jnp.any(active & (tick >= states.nxt_fault))

    def apply(sts):
        with jax.named_scope("faults"):
            return jax.vmap(
                lambda s, w, t: executor.apply_faults(s, w, t, params)
            )(sts, wls, tick)

    def skip(sts):
        return sts, _zero_fault_aux(sts)

    return jax.lax.cond(due, apply, skip, states)


def _lane_decide(
    params: SimParams,
    horizon: jax.Array,
    scheduler_fn: Callable,
    with_aux: bool,
    state: SimState,
    sched_state: Any,
    wl: Workload,
    arr_sorted: jax.Array,
    tick: jax.Array,
):
    """One lane, one event, from the scheduler onward (the post-phase-1 /
    post-faults half of the step): schedule, apply the decision, and
    jump to the lane's next event. The named scopes label the engine
    phases in XLA/profiler output; they change HLO metadata only, never
    the computation."""
    if params.closed_loop_active:
        with jax.named_scope("closed_loop"):
            state = admission.apply_closed_loop(state, wl, tick, params)
    st1 = state
    with jax.named_scope("scheduler"):
        view = (
            mask_down_pools(state, tick)
            if params.outage_mtbf_ticks > 0
            else state
        )
        sched_state, dec = scheduler_fn(sched_state, view, wl, params)
        if params.outage_mtbf_ticks > 0:
            dec = _filter_down_pool_assignments(dec, state, tick, params)
    with jax.named_scope("apply"):
        if with_aux:
            state, aux = executor.apply_decision(
                state, wl, dec, tick, params, early_exit=True, with_aux=True
            )
        else:
            state = executor.apply_decision(
                state, wl, dec, tick, params, early_exit=True
            )
            aux = None
    acted = (
        jnp.any(dec.suspend)
        | jnp.any(dec.reject)
        | jnp.any(dec.assign_pipe >= 0)
    )
    with jax.named_scope("advance"):
        nxt, cursor = _next_event_registers(state, arr_sorted, tick, acted)
        nxt = jnp.minimum(nxt, horizon)
        state = executor.integrate(state, tick, nxt, params, exact_buckets=True)
    state = state._replace(tick=nxt, nxt_arrival_cursor=cursor)
    return state, sched_state, st1, dec, aux


def _lane_step_core(
    params: SimParams,
    horizon: jax.Array,
    scheduler_fn: Callable,
    state: SimState,
    sched_state: Any,
    wl: Workload,
    arr_sorted: jax.Array,
    tick: jax.Array,
    ph,
    with_aux: bool,
):
    """One lane, one event. Returns the advanced ``(state, sched_state)``
    plus — for the telemetry recorder — the post-phase-1 state the
    scheduler saw, its decision, and (``with_aux=True`` only) the
    per-slot assignment aux from ``apply_decision``. This single-lane
    composition gates the fault pass on the lane's own ``nxt_fault``
    register (here the cond genuinely branches — the fleet engine uses
    :func:`_fleet_gated_faults` instead, since a vmapped cond would
    run both sides)."""
    with jax.named_scope("phase1"):
        state = executor.apply_fused_phase1(state, wl, tick, params, ph)
    if params.fault_events_active:
        with jax.named_scope("faults"):
            state, fault_aux = jax.lax.cond(
                tick >= state.nxt_fault,
                lambda s: executor.apply_faults(s, wl, tick, params),
                lambda s: (s, _zero_fault_aux(s)),
                state,
            )
    else:
        fault_aux = None
    state, sched_state, st1, dec, aux = _lane_decide(
        params, horizon, scheduler_fn, with_aux, state, sched_state, wl,
        arr_sorted, tick,
    )
    return state, sched_state, st1, dec, aux, fault_aux


def lane_event_step(
    params: SimParams,
    horizon: jax.Array,
    scheduler_fn: Callable,
    state: SimState,
    sched_state: Any,
    wl: Workload,
    arr_sorted: jax.Array,
    tick: jax.Array,
    ph,
):
    """Advance ONE lane by one event: apply the fused phase-1 masks,
    schedule, apply the decision, and jump to the lane's next event.

    Module-level so the oracle test can drive a single lane directly
    (``_next_event`` vs ``_next_event_registers`` at every event); the
    engine vmaps it over the fleet axis.
    """
    state, sched_state, _, _, _, _ = _lane_step_core(
        params, horizon, scheduler_fn, state, sched_state, wl,
        arr_sorted, tick, ph, with_aux=False,
    )
    return state, sched_state


def lane_event_step_traced(
    params: SimParams,
    trace_capacity: int,
    horizon: jax.Array,
    scheduler_fn: Callable,
    state: SimState,
    sched_state: Any,
    tbuf: TraceBuffer,
    wl: Workload,
    arr_sorted: jax.Array,
    tick: jax.Array,
    ph,
    active: jax.Array,
):
    """:func:`lane_event_step` plus the telemetry recorder: identical
    state/scheduler updates (the recorder only reads), with every event
    of the step appended to the lane's trace buffer. ``active`` gates
    all buffer writes so finished lanes record nothing while the fleet
    loop drains stragglers."""
    pre = state
    state, sched_state, st1, dec, aux, fault_aux = _lane_step_core(
        params, horizon, scheduler_fn, state, sched_state, wl,
        arr_sorted, tick, ph, with_aux=True,
    )
    with jax.named_scope("telemetry"):
        tbuf = record_step(
            tbuf, trace_capacity, active, pre, st1, state, wl, params,
            tick, ph, dec, aux, fault_aux,
        )
    return state, sched_state, tbuf


def _run_lane_major_engine(
    params, wls, scheduler_fn, sched_state0, impl="auto", trace_capacity=0
):
    """Shared masked while_loop over the whole batch ``wls`` [F, ...].

    ``trace_capacity`` is static: 0 (the default) compiles exactly the
    untraced loop below — telemetry off costs nothing and perturbs
    nothing — while a positive capacity swaps in the traced lane step
    and threads per-lane :class:`TraceBuffer`\\ s through the carry,
    returning ``(states, scheds, tbufs)``. Trace buffers deliberately
    skip the finished-lane ``keep`` masking (that jnp.where would copy
    the whole [F, cap, W] table every event); the recorder itself gates
    writes on ``active``, so an inactive lane's cursor never advances
    and its valid prefix stays untouched. In the carry the tables hold
    ``step_block_rows`` scratch rows past ``capacity`` (the recorder's
    contiguous writer spills there on overflow); the scratch is sliced
    off before returning, so callers see exactly ``[F, cap, W]``.
    """
    from repro.kernels.sim_tick import fleet_tick

    horizon = jnp.int32(params.horizon_ticks)
    F = wls.arrival.shape[0]
    arr_sorted = _sorted_arrivals(wls.arrival)  # [F, MP + 1]

    states0 = broadcast_lanes(init_state(params), F)
    scheds0 = broadcast_lanes(sched_state0, F)
    faults_on = params.fault_events_active

    def phase1(state, wl, tick, ph):
        with jax.named_scope("phase1"):
            return executor.apply_fused_phase1(state, wl, tick, params, ph)

    # finished lanes pass through untouched
    def keep_fn(active):
        def keep(n, o):
            mask = jnp.reshape(active, (F,) + (1,) * (n.ndim - 1))
            return jnp.where(mask, n, o)

        return keep

    if trace_capacity == 0:
        lane = functools.partial(
            lane_event_step, params, horizon, scheduler_fn
        )
        decide = functools.partial(
            _lane_decide, params, horizon, scheduler_fn, False
        )

        def cond(carry):
            states, _ = carry
            return jnp.any(states.tick < horizon)

        def body(carry):
            states, scheds = carry
            tick = states.tick                     # [F]
            active = tick < horizon                # [F]

            ph = fleet_tick(
                states.ctr_status, states.ctr_end, states.ctr_oom,
                states.ctr_cpus, states.ctr_ram, states.ctr_pool,
                states.pipe_status, wls.arrival, states.pipe_release,
                tick, num_pools=params.num_pools, impl=impl,
            )

            if faults_on:
                # split body: vmap(phase1) -> fleet-gated faults ->
                # vmap(decide). vmap of the composition == composition
                # of the vmaps, so this is bitwise the single-vmap body
                # below with the fault pass hoisted behind its register.
                sts1 = jax.vmap(phase1)(states, wls, tick, ph)
                sts1, _ = _fleet_gated_faults(params, sts1, wls, tick, active)
                new_states, new_scheds, _, _, _ = jax.vmap(decide)(
                    sts1, scheds, wls, arr_sorted, tick
                )
            else:
                new_states, new_scheds = jax.vmap(lane)(
                    states, scheds, wls, arr_sorted, tick, ph
                )

            keep = keep_fn(active)
            states = jax.tree.map(keep, new_states, states)
            scheds = jax.tree.map(keep, new_scheds, scheds)
            return states, scheds

        return jax.lax.while_loop(cond, body, (states0, scheds0))

    scratch = step_block_rows(
        params.max_pipelines, params.max_containers,
        params.max_assignments_per_tick, params,
    )
    tbufs0 = TraceBuffer(
        records=jnp.zeros(
            (F, trace_capacity + scratch, RECORD_WIDTH), jnp.int32
        ),
        count=jnp.zeros((F,), jnp.int32),
        dropped=jnp.zeros((F,), jnp.int32),
    )
    lane_t = functools.partial(
        lane_event_step_traced, params, trace_capacity, horizon, scheduler_fn
    )

    def decide_t(pre, st1_in, sched_state, tbuf, wl, arr_sorted_l, tick,
                 ph, active, fault_aux):
        state, sched_state, st1, dec, aux = _lane_decide(
            params, horizon, scheduler_fn, True, st1_in, sched_state, wl,
            arr_sorted_l, tick,
        )
        with jax.named_scope("telemetry"):
            tbuf = record_step(
                tbuf, trace_capacity, active, pre, st1, state, wl, params,
                tick, ph, dec, aux, fault_aux,
            )
        return state, sched_state, tbuf

    def cond_t(carry):
        states, _, _ = carry
        return jnp.any(states.tick < horizon)

    def body_t(carry):
        states, scheds, tbufs = carry
        tick = states.tick
        active = tick < horizon

        ph = fleet_tick(
            states.ctr_status, states.ctr_end, states.ctr_oom,
            states.ctr_cpus, states.ctr_ram, states.ctr_pool,
            states.pipe_status, wls.arrival, states.pipe_release,
            tick, num_pools=params.num_pools, impl=impl,
        )

        if faults_on:
            # same split as the untraced body; the recorder consumes the
            # batched fault_aux (zeros on skipped events — bitwise what
            # the ungated pass would have reported)
            sts1 = jax.vmap(phase1)(states, wls, tick, ph)
            sts1, fault_auxs = _fleet_gated_faults(
                params, sts1, wls, tick, active
            )
            new_states, new_scheds, tbufs = jax.vmap(decide_t)(
                states, sts1, scheds, tbufs, wls, arr_sorted, tick, ph,
                active, fault_auxs,
            )
        else:
            new_states, new_scheds, tbufs = jax.vmap(lane_t)(
                states, scheds, tbufs, wls, arr_sorted, tick, ph, active
            )

        keep = keep_fn(active)
        states = jax.tree.map(keep, new_states, states)
        scheds = jax.tree.map(keep, new_scheds, scheds)
        return states, scheds, tbufs

    states, scheds, tbufs = jax.lax.while_loop(
        cond_t, body_t, (states0, scheds0, tbufs0)
    )
    tbufs = tbufs._replace(records=tbufs.records[:, :trace_capacity])
    return states, scheds, tbufs


@functools.partial(
    jax.jit,
    static_argnames=("params", "scheduler_key", "impl", "trace_capacity"),
    donate_argnames=("workloads",),
)
def _fleet_compiled(
    params: SimParams,
    workloads: Workload,  # batched: leading axis = fleet
    scheduler_key: str,
    impl: str = "auto",
    trace_capacity: int = 0,
):
    """THE compiled simulation core: every entry point lands here.

    ``run()`` passes a batch of one lane, ``fleet_run`` a batch of N
    (possibly one shard of a device-sharded fleet). Returns the batched
    final ``(SimState, sched_state)`` — plus batched ``TraceBuffer``\\ s
    when the static ``trace_capacity`` is positive (telemetry on).

    The workload batch is DONATED: XLA may reuse the ops tables' buffers
    for outputs, so a large fleet never holds two copies of them across
    the call. Callers must treat their ``workloads`` pytree as consumed
    (every in-repo entry point passes a freshly built batch).
    """
    scheduler_fn = get_vector_scheduler(scheduler_key, early_exit=True)
    sched_state0 = get_vector_scheduler_init(scheduler_key)(params)
    return _run_lane_major_engine(
        params, workloads, scheduler_fn, sched_state0, impl, trace_capacity
    )


def run(
    paramfile: str | dict | SimParams,
    workload: Workload | None = None,
    engine: str | None = None,
    *,
    trace: bool = False,
    trace_capacity: int = DEFAULT_TRACE_CAPACITY,
) -> SimResult:
    """Run one simulation; this is what ``eudoxia.run_simulator`` wraps.

    A single run is a fleet of one: the workload gains a lane axis, the
    lane-major core advances it, and the result is squeezed back —
    bitwise-identical to the dedicated single-sim event engine this
    replaced (checked against a frozen capture during the unification
    refactor; continuously guarded by the Python-reference equivalence
    suite and the run-vs-fleet-lane tests in tests/test_fleet.py).

    ``trace=True`` records an on-device event trace of up to
    ``trace_capacity`` records (compiled engine only) and decodes it
    into ``result.trace`` (:class:`repro.core.telemetry.TraceEvents`);
    the simulated state is bitwise-identical either way (guarded by
    tests/test_telemetry.py). On overflow the earliest records win and
    ``result.trace.events_dropped`` counts the rest.
    """
    params = load_params(paramfile)
    engine = engine or params.engine
    wl = workload if workload is not None else get_workload(params)
    if params.fault_trace_active and wl.faults is None:
        # chaos layer on but the workload came in bare (trace replay /
        # caller-built): materialise the fault trace from params.seed so
        # both engines replay the identical fault sequence
        from .faults import attach_fault_trace

        wl = attach_fault_trace(wl, params)
    if engine == "python":
        if trace:
            raise ValueError(
                "trace=True requires the compiled event engine; the "
                "Python reference engine records no telemetry"
            )
        from .engine_python import run_python_engine

        return run_python_engine(params, wl)
    if engine != "event":
        raise ValueError(
            f"unknown engine {engine!r}: the per-tick scan engine was removed "
            "in the lane-major unification (the event core is "
            "bitwise-identical and strictly faster); use engine='event' "
            "(default) or the reference engine='python'"
        )
    capacity = int(trace_capacity) if trace else 0
    if trace and capacity <= 0:
        raise ValueError(f"trace_capacity must be positive, got {trace_capacity}")
    wls = jax.tree.map(lambda x: x[None], wl)
    with _quiet_partial_donation():
        out = _fleet_compiled(
            params, wls, params.scheduling_algo, trace_capacity=capacity
        )
    events = None
    if capacity:
        states, scheds, tbufs = out
        from .telemetry.decode import decode_lane

        events = decode_lane(tbufs, 0)
    else:
        states, scheds = out
    state = jax.tree.map(lambda x: x[0], states)
    sched_state = jax.tree.map(lambda x: x[0], scheds)
    return SimResult(
        state=state, workload=wl, params=params, sched_state=sched_state,
        trace=events,
    )


__all__ = [
    "SimResult",
    "run",
    "lane_event_step",
    "lane_event_step_traced",
    "_fleet_compiled",
    "_tick_body",
    "_next_event",
    "_next_event_registers",
    "_sorted_arrivals",
    "_run_lane_major_engine",
]
