"""The lane-major simulation core.

One compiled engine advances every simulation. State is batched
*lane-major* — every array carries a leading fleet axis ``[F, ...]`` —
and a single shared ``lax.while_loop`` steps all lanes at once:

* phase 1 (completions + releases + arrival admission + per-pool freed
  resources + next-event registers) is one fused [F, MC]/[F, MP] pass
  through ``repro.kernels.sim_tick.fleet_tick`` (Pallas on TPU, the
  bitwise-equivalent jnp reference elsewhere);
* the scheduler and ``apply_decision`` run with early-exit inner loops
  (``decision_loop(early_exit=True)``), whose while_loops vmap into
  max-over-lanes trip counts — an event with an empty queue no longer
  pays K sequential steps;
* each lane skips to its own next event via the incremental
  ``nxt_retire``/``nxt_release`` registers plus an O(log MP) binary
  search of the sorted arrivals (``_next_event`` stays as the
  recompute-from-scratch oracle, property-tested in tests/test_fleet.py);
* finished lanes pass through untouched (``jnp.where`` on the carry)
  and the loop exits when every lane is done.

``run()`` is the F=1 special case (squeezed on return); ``fleet_run``
(``sweep.py``) is the N-lane case, optionally sharded across local
devices with ``shard_map``. Both engines the paper's design implied —
the per-tick ``lax.scan`` loop and a per-simulation event loop — were
deleted in the lane-major unification; the Python reference engine
(``engine="python"``) remains as the readable executable specification,
and the property suite checks the compiled core against it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import executor
from .params import SimParams, load_params
from .scheduler import (
    get_vector_scheduler,
    get_vector_scheduler_init,
)
from .state import INF_TICK, SimState, Workload, broadcast_lanes, init_state
from .types import ContainerStatus, PipeStatus
from .workload import get_workload


@dataclasses.dataclass
class SimResult:
    state: SimState
    workload: Workload
    params: SimParams
    sched_state: Any = None

    def summary(self) -> dict:
        from .metrics import summarize

        return summarize(self.state, self.workload, self.params)


# ---------------------------------------------------------------------------
# One tick worth of work, as the sequential composition of executor
# passes. This is the *reference* body: the lane-major engine fuses the
# first three passes (see ``lane_event_step``), and the property suite +
# the benchmark reconstruction of the deleted vmap baseline drive this
# composition to prove the fusion semantics-preserving.
# ---------------------------------------------------------------------------
def _tick_body(
    state: SimState,
    sched_state: Any,
    wl: Workload,
    params: SimParams,
    scheduler_fn: Callable,
    tick: jax.Array,
):
    state = executor.process_arrivals(state, wl, tick)
    state = executor.process_releases(state, tick)
    state = executor.process_completions(state, wl, tick, params)
    sched_state, dec = scheduler_fn(sched_state, state, wl, params)
    state = executor.apply_decision(state, wl, dec, tick, params)
    acted = (
        jnp.any(dec.suspend)
        | jnp.any(dec.reject)
        | jnp.any(dec.assign_pipe >= 0)
    )
    return state, sched_state, acted


def _sorted_arrivals(arrival: jax.Array) -> jax.Array:
    """Arrival ticks sorted ascending, INF-padded by one slot so a cursor
    that has consumed every arrival reads INF_TICK. Works along the last
    axis, so it serves both single workloads [MP] and fleets [F, MP]."""
    pad_shape = arrival.shape[:-1] + (1,)
    return jnp.concatenate(
        [jnp.sort(arrival, axis=-1), jnp.full(pad_shape, INF_TICK, jnp.int32)],
        axis=-1,
    )


def _next_event_registers(
    state: SimState, arr_sorted: jax.Array, tick: jax.Array, acted
):
    """Register-based twin of :func:`_next_event`.

    Instead of re-reducing the pipeline/container tables, reads the
    executor-maintained ``nxt_retire``/``nxt_release`` registers and
    binary-searches the arrival-sorted workload — O(log MP) per event
    rather than O(MP + MC). Provably equal to the full recompute:
    after arrival admission at tick t, a pipeline slot is EMPTY iff
    its arrival tick is > t, so the pending-arrival minimum is the first
    sorted arrival beyond t; the register invariants cover the rest
    (see the property test in tests/test_fleet.py).

    Returns ``(next_tick, cursor)``; the cursor (count of arrivals <= t)
    is stored on the state as ``nxt_arrival_cursor``.
    """
    cursor = jnp.searchsorted(arr_sorted[:-1], tick, side="right").astype(
        jnp.int32
    )
    next_arrival = arr_sorted[cursor]
    nxt = jnp.minimum(
        jnp.minimum(next_arrival, state.nxt_retire), state.nxt_release
    )
    nxt = jnp.where(acted, jnp.minimum(nxt, tick + 1), nxt)
    return jnp.maximum(nxt, tick + 1), cursor


def _next_event(state: SimState, wl: Workload, tick: jax.Array, acted) -> jax.Array:
    """Earliest tick strictly after ``tick`` at which state can change.

    The recompute-from-scratch oracle for the ``nxt_retire`` /
    ``nxt_release`` registers the engine actually navigates by:
    tests/test_fleet.py steps ``lane_event_step`` and asserts this full
    table reduction equals :func:`_next_event_registers` at every event.
    """
    pending = state.pipe_status == int(PipeStatus.EMPTY)
    arr = jnp.where(pending & (wl.arrival > tick), wl.arrival, INF_TICK)
    next_arrival = jnp.min(arr)

    # ctr_end/ctr_oom include the data-plane warm-up (cold-start + scan
    # ticks) baked in at creation, so release ticks of cold containers are
    # accounted for here without a separate event source. Cache contents
    # and slot warmth change only when the executor acts, never passively,
    # so they add no event sources either (warmth *expiry* is passive, but
    # it is only read at assignment ticks, which are always events).
    running = state.ctr_status == int(ContainerStatus.RUNNING)
    ends = jnp.where(running, jnp.minimum(state.ctr_end, state.ctr_oom), INF_TICK)
    next_retire = jnp.min(ends)

    suspended = state.pipe_status == int(PipeStatus.SUSPENDED)
    rel = jnp.where(suspended, state.pipe_release, INF_TICK)
    next_release = jnp.min(rel)

    nxt = jnp.minimum(jnp.minimum(next_arrival, next_retire), next_release)
    # if the scheduler acted, it may act again next tick (queue longer than
    # one decision's capacity, freshly freed resources, ...)
    nxt = jnp.where(acted, jnp.minimum(nxt, tick + 1), nxt)
    return jnp.maximum(nxt, tick + 1)


@contextlib.contextmanager
def _quiet_partial_donation():
    """Silence XLA's partial-donation lowering warning.

    The workload batch is donated so its big ops tables can be aliased
    into outputs; a few small leaves (op metadata with no same-shaped
    output) are not aliasable, which XLA reports once per compilation.
    That partial reuse is exactly the intent, so the note is noise here
    — but only here: the filter is scoped to the compiled-engine call
    sites, not installed globally.
    """
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        yield


# ---------------------------------------------------------------------------
# The lane-major engine.
# ---------------------------------------------------------------------------
def lane_event_step(
    params: SimParams,
    horizon: jax.Array,
    scheduler_fn: Callable,
    state: SimState,
    sched_state: Any,
    wl: Workload,
    arr_sorted: jax.Array,
    tick: jax.Array,
    ph,
):
    """Advance ONE lane by one event: apply the fused phase-1 masks,
    schedule, apply the decision, and jump to the lane's next event.

    Module-level so the oracle test can drive a single lane directly
    (``_next_event`` vs ``_next_event_registers`` at every event); the
    engine vmaps it over the fleet axis.
    """
    state = executor.apply_fused_phase1(state, wl, tick, params, ph)
    sched_state, dec = scheduler_fn(sched_state, state, wl, params)
    state = executor.apply_decision(state, wl, dec, tick, params, early_exit=True)
    acted = (
        jnp.any(dec.suspend)
        | jnp.any(dec.reject)
        | jnp.any(dec.assign_pipe >= 0)
    )
    nxt, cursor = _next_event_registers(state, arr_sorted, tick, acted)
    nxt = jnp.minimum(nxt, horizon)
    state = executor.integrate(state, tick, nxt, params, exact_buckets=True)
    return state._replace(tick=nxt, nxt_arrival_cursor=cursor), sched_state


def _run_lane_major_engine(params, wls, scheduler_fn, sched_state0, impl="auto"):
    """Shared masked while_loop over the whole batch ``wls`` [F, ...]."""
    from repro.kernels.sim_tick import fleet_tick

    horizon = jnp.int32(params.horizon_ticks)
    F = wls.arrival.shape[0]
    arr_sorted = _sorted_arrivals(wls.arrival)  # [F, MP + 1]

    states0 = broadcast_lanes(init_state(params), F)
    scheds0 = broadcast_lanes(sched_state0, F)

    lane = functools.partial(lane_event_step, params, horizon, scheduler_fn)

    def cond(carry):
        states, _ = carry
        return jnp.any(states.tick < horizon)

    def body(carry):
        states, scheds = carry
        tick = states.tick                     # [F]
        active = tick < horizon                # [F]

        ph = fleet_tick(
            states.ctr_status, states.ctr_end, states.ctr_oom,
            states.ctr_cpus, states.ctr_ram, states.ctr_pool,
            states.pipe_status, wls.arrival, states.pipe_release,
            tick, num_pools=params.num_pools, impl=impl,
        )

        new_states, new_scheds = jax.vmap(lane)(
            states, scheds, wls, arr_sorted, tick, ph
        )

        # finished lanes pass through untouched
        def keep(n, o):
            mask = jnp.reshape(active, (F,) + (1,) * (n.ndim - 1))
            return jnp.where(mask, n, o)

        states = jax.tree.map(keep, new_states, states)
        scheds = jax.tree.map(keep, new_scheds, scheds)
        return states, scheds

    return jax.lax.while_loop(cond, body, (states0, scheds0))


@functools.partial(
    jax.jit,
    static_argnames=("params", "scheduler_key", "impl"),
    donate_argnames=("workloads",),
)
def _fleet_compiled(
    params: SimParams,
    workloads: Workload,  # batched: leading axis = fleet
    scheduler_key: str,
    impl: str = "auto",
):
    """THE compiled simulation core: every entry point lands here.

    ``run()`` passes a batch of one lane, ``fleet_run`` a batch of N
    (possibly one shard of a device-sharded fleet). Returns the batched
    final ``(SimState, sched_state)``.

    The workload batch is DONATED: XLA may reuse the ops tables' buffers
    for outputs, so a large fleet never holds two copies of them across
    the call. Callers must treat their ``workloads`` pytree as consumed
    (every in-repo entry point passes a freshly built batch).
    """
    scheduler_fn = get_vector_scheduler(scheduler_key, early_exit=True)
    sched_state0 = get_vector_scheduler_init(scheduler_key)(params)
    return _run_lane_major_engine(
        params, workloads, scheduler_fn, sched_state0, impl
    )


def run(
    paramfile: str | dict | SimParams,
    workload: Workload | None = None,
    engine: str | None = None,
) -> SimResult:
    """Run one simulation; this is what ``eudoxia.run_simulator`` wraps.

    A single run is a fleet of one: the workload gains a lane axis, the
    lane-major core advances it, and the result is squeezed back —
    bitwise-identical to the dedicated single-sim event engine this
    replaced (checked against a frozen capture during the unification
    refactor; continuously guarded by the Python-reference equivalence
    suite and the run-vs-fleet-lane tests in tests/test_fleet.py).
    """
    params = load_params(paramfile)
    engine = engine or params.engine
    wl = workload if workload is not None else get_workload(params)
    if engine == "python":
        from .engine_python import run_python_engine

        return run_python_engine(params, wl)
    if engine != "event":
        raise ValueError(
            f"unknown engine {engine!r}: the per-tick scan engine was removed "
            "in the lane-major unification (the event core is "
            "bitwise-identical and strictly faster); use engine='event' "
            "(default) or the reference engine='python'"
        )
    wls = jax.tree.map(lambda x: x[None], wl)
    with _quiet_partial_donation():
        states, scheds = _fleet_compiled(params, wls, params.scheduling_algo)
    state = jax.tree.map(lambda x: x[0], states)
    sched_state = jax.tree.map(lambda x: x[0], scheds)
    return SimResult(state=state, workload=wl, params=params, sched_state=sched_state)


__all__ = [
    "SimResult",
    "run",
    "lane_event_step",
    "_fleet_compiled",
    "_tick_body",
    "_next_event",
    "_next_event_registers",
    "_sorted_arrivals",
    "_run_lane_major_engine",
]
