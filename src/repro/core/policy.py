"""Parameterised scheduler policies: every built-in scheduler is one
point in a flat f32 knob vector (the policy-search substrate).

The paper pitches Eudoxia as "a cheap mechanism for developers to
evaluate different scheduling algorithms"; the Bauplan follow-up
(PAPERS.md, arXiv 2505.13750-adjacent) closes the loop by *searching*
over policies with the simulator as the oracle. That search needs a
continuous policy space in which the hand-written schedulers are
particular points — this module defines that space.

:class:`PolicyParams` lifts every hard-coded knob of the decision loop
in ``scheduler.py`` / ``extra_schedulers.py`` into one flat vector:
chunk sizing, the OOM-retry multiplier and cap, the sjf-vs-fifo queue
ordering weights, preemption thresholds, and the pool-selection
(cache-affinity / locality-bonus) rules. ``DEFAULT_POINTS`` maps each
registered named scheduler to its exact point; the family
implementation (``scheduler._policy_family``) evaluated at that point
is bitwise-identical to the named scheduler (tests/test_policy_family.py
asserts final-state equality across engines, fleets and shardings; the
48-config digest grid in tests/captures/ stays verbatim-valid).

Everything here is plain numpy/python — no jax import — so the search
package, the compiled schedulers and the Python reference engine all
share one definition without circular imports.

>>> from repro.core.policy import DEFAULT_POINTS, PolicyParams
>>> DEFAULT_POINTS["priority"].chunk_frac
0.1
>>> PolicyParams.from_vector(DEFAULT_POINTS["sjf"].to_vector()) == \
DEFAULT_POINTS["sjf"]
True
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class PolicyParams(NamedTuple):
    """One scheduling policy as a flat f32 knob vector.

    Field order IS the vector layout (``to_vector``/``from_vector``);
    the compiled family and the Python mirror index it positionally.
    Boolean knobs are encoded as floats with an ``> 0.5`` threshold so
    the whole vector lives in one dtype and gradient-free searches can
    sample it uniformly.
    """

    # ---- allocation sizing (paper §4.1.2) ---------------------------------
    chunk_frac: float = 0.10    # fresh-arrival grant, fraction of total
    cap_frac: float = 0.50      # allocation cap fraction (also the
    #                             OOM-reject threshold when ram_gate is on)
    retry_mult: float = 2.0     # OOM-retry multiplier on the last grant
    # ---- queue ordering (sjf-vs-fifo mixing) ------------------------------
    # The waiting queue is ordered by a lexicographic key whose LEAD
    # component is  size_weight*n_ops + age_weight*entered
    # - prio_weight*prio  (f32), followed by the classic
    # (priority desc, entered asc, pid asc) tie-break. All-zero weights
    # reproduce the paper's priority order exactly; size_weight=1 with
    # the rest zero reproduces sjf's (n_ops, -prio, entered) order.
    size_weight: float = 0.0
    prio_weight: float = 0.0
    age_weight: float = 0.0
    # ---- preemption -------------------------------------------------------
    preempt: float = 1.0            # > 0.5: preemption enabled
    preempt_min_prio: float = 0.0   # preemptor must have prio STRICTLY above
    victim_prio_gap: float = 0.0    # victim prio must be < preemptor - gap
    # ---- pool selection (data plane) --------------------------------------
    multi_pool: float = 0.0     # > 0.5: score-based pool choice (else pool 0)
    cache_pin: float = 0.0      # > 0.5: pin to the pool caching parent data
    locality_bonus: float = 0.0  # pool-score bonus for pools holding data
    # ---- naive-mode switches ----------------------------------------------
    exclusive: float = 0.0      # > 0.5: only assign to an idle cluster,
    #                             at most one assignment per decision
    grab_all: float = 0.0       # > 0.5: grant the chosen pool's full caps
    ram_gate: float = 1.0       # > 0.5: reject only OOMs at the RAM cap
    #                             (off: any prior OOM is rejected — naive)

    def to_vector(self) -> np.ndarray:
        """The flat f32 vector the engines consume (``wl.policy``)."""
        return np.asarray(self, dtype=np.float32)

    @classmethod
    def from_vector(cls, vec) -> "PolicyParams":
        vec = np.asarray(vec, dtype=np.float32).reshape(-1)
        if vec.shape[0] != N_POLICY_PARAMS:
            raise ValueError(
                f"policy vector must have {N_POLICY_PARAMS} entries "
                f"({', '.join(cls._fields)}), got {vec.shape[0]}"
            )
        return cls(*(float(v) for v in vec))


N_POLICY_PARAMS = len(PolicyParams._fields)


# ---------------------------------------------------------------------------
# The named schedulers as policy points. Evaluating the parameterised
# family at each point is bitwise-identical to the named scheduler
# (the identity suite in tests/test_policy_family.py is the proof).
# ---------------------------------------------------------------------------
DEFAULT_POINTS: dict[str, PolicyParams] = {
    # one pool, everything to the queue head, only on an idle cluster;
    # a pipeline that OOMed with all resources is rejected outright
    "naive": PolicyParams(
        preempt=0.0, exclusive=1.0, grab_all=1.0, ram_gate=0.0,
    ),
    # 10% chunks, OOM doubling capped at 50%, preemption, single pool
    "priority": PolicyParams(),
    # ditto on the most-free pool
    "priority_pool": PolicyParams(multi_pool=1.0),
    # priority_pool pinned to the pool caching the pipe's parent outputs
    "cache_aware": PolicyParams(multi_pool=1.0, cache_pin=1.0),
    # priority_pool with a small locality bonus on the pool score
    "locality_pool": PolicyParams(multi_pool=1.0, locality_bonus=1e-3),
    # smallest-job-first: 25% chunks, no preemption, op-count lead key
    "sjf": PolicyParams(
        chunk_frac=0.25, size_weight=1.0, preempt=0.0,
    ),
}


# search-space box per knob (lo, hi), in PolicyParams field order —
# the normalised [0, 1]^P cube the CEM driver samples maps through this
POLICY_BOUNDS: dict[str, tuple[float, float]] = {
    "chunk_frac": (0.02, 0.60),
    "cap_frac": (0.10, 1.00),
    "retry_mult": (1.0, 4.0),
    "size_weight": (0.0, 2.0),
    "prio_weight": (0.0, 2.0),
    "age_weight": (0.0, 1e-3),
    "preempt": (0.0, 1.0),
    "preempt_min_prio": (0.0, 2.0),
    "victim_prio_gap": (0.0, 2.0),
    "multi_pool": (0.0, 1.0),
    "cache_pin": (0.0, 1.0),
    "locality_bonus": (0.0, 0.05),
    "exclusive": (0.0, 1.0),
    "grab_all": (0.0, 1.0),
    "ram_gate": (0.0, 1.0),
}
assert tuple(POLICY_BOUNDS) == PolicyParams._fields


def policy_bounds() -> tuple[np.ndarray, np.ndarray]:
    """``(lo, hi)`` f32 vectors of the search box, field order."""
    lo = np.asarray([POLICY_BOUNDS[f][0] for f in PolicyParams._fields],
                    np.float32)
    hi = np.asarray([POLICY_BOUNDS[f][1] for f in PolicyParams._fields],
                    np.float32)
    return lo, hi


__all__ = [
    "PolicyParams",
    "N_POLICY_PARAMS",
    "DEFAULT_POINTS",
    "POLICY_BOUNDS",
    "policy_bounds",
]
