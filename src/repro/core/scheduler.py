"""Schedulers (paper §3.2.3, §4.1.2).

A scheduler "accept[s] a set of Pipelines from the workload generator,
and output[s] a list of new Container allocations and Container
preemptions to the Executor". In the compiled engines this is a pure
function over the struct-of-arrays state:

    fn(sched_state, sim: SimState, wl: Workload, params) ->
        (sched_state, SchedDecision)

``SchedDecision`` carries fixed-capacity arrays (suspension mask over
containers, rejection mask over pipelines, up to K new assignments).

Three built-ins mirror §4.1.2:

* ``naive``          — one pool; all resources to the head of the queue.
* ``priority``       — 10 % chunks, OOM-retry doubling capped at 50 %,
                       preemption of lower-priority containers.
* ``priority_pool``  — ditto, but allocates on the pool with the most
                       available resources.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.sched_select import masked_lex_argmin

from .params import SimParams
from .policy import DEFAULT_POINTS, N_POLICY_PARAMS, PolicyParams
from .state import INF_TICK, SimState, Workload
from .types import ContainerStatus, PipeStatus, Priority

EPS = 1e-5


class SchedDecision(NamedTuple):
    suspend: jax.Array      # [MC] bool — containers to preempt
    reject: jax.Array       # [MP] bool — pipelines failed back to the user
    assign_pipe: jax.Array  # [K] int32 (-1 = unused slot)
    assign_pool: jax.Array  # [K] int32
    assign_cpus: jax.Array  # [K] f32
    assign_ram: jax.Array   # [K] f32


def empty_decision(params: SimParams) -> SchedDecision:
    K = params.max_assignments_per_tick
    return SchedDecision(
        suspend=jnp.zeros((params.max_containers,), bool),
        reject=jnp.zeros((params.max_pipelines,), bool),
        assign_pipe=jnp.full((K,), -1, jnp.int32),
        assign_pool=jnp.zeros((K,), jnp.int32),
        assign_cpus=jnp.zeros((K,), jnp.float32),
        assign_ram=jnp.zeros((K,), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Masked selection helpers (queue semantics without materialised queues):
# waiting order = priority desc, then (re-)entry tick asc, then pid asc.
#
# These three-pass forms are the *oracles*: the schedulers below run the
# fused ``repro.kernels.sched_select.masked_lex_argmin`` instead (one
# narrowing sweep, Pallas on TPU), which tests/test_sched_select.py
# property-tests bitwise against these on the engine's domain.
# ---------------------------------------------------------------------------
def select_next_pipe(mask: jax.Array, prio: jax.Array, entered: jax.Array):
    any_ = jnp.any(mask)
    p = jnp.where(mask, prio, -1)
    m2 = mask & (prio == jnp.max(p))
    e = jnp.where(m2, entered, INF_TICK)
    m3 = m2 & (entered == jnp.min(e))
    idx = jnp.argmax(m3).astype(jnp.int32)
    return jnp.where(any_, idx, -1)


def select_victim(
    live: jax.Array, ctr_prio: jax.Array, ctr_start: jax.Array, below_prio: jax.Array
):
    """Preemption victim: lowest priority, then latest start (least progress
    lost). ``below_prio`` is the exclusive priority upper bound."""
    m = live & (ctr_prio < below_prio)
    any_ = jnp.any(m)
    p = jnp.where(m, ctr_prio, jnp.int32(2**30))
    m2 = m & (ctr_prio == jnp.min(p))
    s = jnp.where(m2, ctr_start, -1)
    m3 = m2 & (ctr_start == jnp.max(s))
    idx = jnp.argmax(m3).astype(jnp.int32)
    return jnp.where(any_, idx, -1)


def onehot_set(arr: jax.Array, idx: jax.Array, val):
    """``arr.at[idx].set(val)`` as an elementwise select.

    Dynamic-index scatters lower to a serialized ``while`` thunk per
    scatter on XLA:CPU under the engine's per-lane ``vmap``; the
    one-hot select stays elementwise. Bitwise identical: only position
    ``idx`` takes ``val``, every other element is passed through."""
    iota = jnp.arange(arr.shape[0], dtype=jnp.int32)
    return jnp.where(iota == idx, val, arr)


def onehot_add(arr: jax.Array, idx: jax.Array, val):
    """``arr.at[idx].add(val)`` as an elementwise select (see
    :func:`onehot_set`). Exact: the selected element is the same single
    ``arr[idx] + val`` the scatter-add performs; the rest pass through
    untouched (no reassociation anywhere)."""
    iota = jnp.arange(arr.shape[0], dtype=jnp.int32)
    return jnp.where(iota == idx, arr + val, arr)


# ---------------------------------------------------------------------------
# Decision-slot loop runner shared by the K-assignment schedulers.
# ---------------------------------------------------------------------------
def decision_loop(step, K: int, carry0, early_exit: bool):
    """Run ``step(k, carry) -> (carry, keep_going)`` over the K decision
    slots. With ``early_exit`` the loop stops at the first ``keep_going
    = False`` — valid whenever later iterations are provable no-ops
    (the waiting-queue mask can only shrink); under vmap the while_loop
    trip count becomes the max over lanes of actual queue length. This
    knob is what parameterises a scheduler *family* in the unified
    registry below: both variants are bitwise-identical, and the
    lane-major core compiles ``early_exit=True``."""
    if early_exit:

        def w_cond(c):
            k, go, _ = c
            return (k < K) & go

        def w_body(c):
            k, _, carry = c
            carry, go = step(k, carry)
            return k + 1, go, carry

        *_, carry = jax.lax.while_loop(
            w_cond, w_body, (jnp.int32(0), jnp.bool_(True), carry0)
        )
        return carry

    def body(k, carry):
        carry, _ = step(k, carry)
        return carry

    return jax.lax.fori_loop(0, K, body, carry0)


# ---------------------------------------------------------------------------
# NAIVE (paper §4.1.2): single pool, everything to the queue head, no
# concurrency, no preemption. A pipeline that OOMed with all resources can
# never succeed -> permanent failure.
# ---------------------------------------------------------------------------
def naive_scheduler(
    sched_state: Any, sim: SimState, wl: Workload, params: SimParams
):
    dec = empty_decision(params)
    waiting = sim.pipe_status == int(PipeStatus.WAITING)
    # fail-back: it already had every resource, doubling is impossible
    reject = waiting & sim.pipe_fail_flag
    waiting = waiting & ~reject

    idle = ~jnp.any(sim.ctr_status == int(ContainerStatus.RUNNING))
    pipe = masked_lex_argmin(waiting, (-wl.prio, sim.pipe_entered))
    do = idle & (pipe >= 0)
    dec = dec._replace(
        reject=reject,
        assign_pipe=dec.assign_pipe.at[0].set(jnp.where(do, pipe, -1)),
        assign_pool=dec.assign_pool.at[0].set(0),
        assign_cpus=dec.assign_cpus.at[0].set(sim.pool_cpu_cap[0]),
        assign_ram=dec.assign_ram.at[0].set(sim.pool_ram_cap[0]),
    )
    return sched_state, dec


def decision_provenance(sim: SimState, wl: Workload, dec: SchedDecision):
    """``(chosen, runner_up)`` pipeline ids behind a decision's first
    assignment slot — the runner-up is the pipeline the head-of-queue
    rule (priority desc, arrival asc) would have picked had the chosen
    one not been waiting. Both are -1 when not applicable. Used by the
    telemetry recorder for SCHED_DECISION provenance records; reads
    only, never part of the simulation step."""
    chosen = dec.assign_pipe[0]
    waiting = sim.pipe_status == int(PipeStatus.WAITING)
    others = waiting & (
        jnp.arange(wl.max_pipelines, dtype=jnp.int32) != chosen
    )
    runner = masked_lex_argmin(others, (-wl.prio, sim.pipe_entered))
    return chosen, jnp.where(chosen >= 0, runner, -1)


# ---------------------------------------------------------------------------
# PRIORITY / PRIORITY-POOL (paper §4.1.2) and the data-plane variants
# (cache_aware / locality_pool, registered from extra_schedulers.py).
#
# ``pool_mode`` picks the pool-selection rule; every rule is mirrored
# f32-op-for-op by ``engine_python._pool_select_py``:
#   "single"   — always pool 0 (paper ``priority``)
#   "free"     — most free resources (paper ``priority_pool``)
#   "cache"    — pool holding the pipeline's parent outputs, else "free"
#   "locality" — "free" score with a small bonus for pools holding any
#                of the pipeline's data (locality tie-break)
# ---------------------------------------------------------------------------
LOCALITY_BONUS = 1e-3


def _pool_select(pool_mode: str, free_cpu, free_ram, sim: SimState, pipe_c):
    if pool_mode == "single":
        return jnp.int32(0)
    score = free_cpu / jnp.maximum(sim.pool_cpu_cap, EPS) + (
        free_ram / jnp.maximum(sim.pool_ram_cap, EPS)
    )
    if pool_mode == "free":
        return jnp.argmax(score).astype(jnp.int32)
    row = sim.cache_bytes[:, pipe_c]  # [NP] bytes of this pipe's data
    if pool_mode == "cache":
        return jnp.where(
            jnp.max(row) > 0, jnp.argmax(row), jnp.argmax(score)
        ).astype(jnp.int32)
    if pool_mode == "locality":
        bonus = jnp.where(row > 0, jnp.float32(LOCALITY_BONUS), 0.0)
        return jnp.argmax(score + bonus).astype(jnp.int32)
    raise ValueError(f"unknown pool_mode {pool_mode!r}")


def _priority_like(pool_mode: str, early_exit: bool = False):
    """The generalised priority scheduler family.

    ``early_exit=True`` swaps the fixed K-iteration ``fori_loop`` for a
    ``while_loop`` that stops as soon as the waiting queue is exhausted
    (once ``select_next_pipe`` returns -1 the candidate mask can only
    shrink, so every later iteration is a no-op). Bitwise-identical
    decisions; the lane-major core compiles the early-exit variant so
    events with short queues stop paying K sequential scheduler steps.
    """
    multi_pool = pool_mode != "single"

    def scheduler(
        sched_state: Any, sim: SimState, wl: Workload, params: SimParams
    ):
        K = params.max_assignments_per_tick
        NP = params.num_pools
        total_cpu = jnp.sum(sim.pool_cpu_cap)
        total_ram = jnp.sum(sim.pool_ram_cap)
        chunk_cpu = 0.10 * total_cpu
        chunk_ram = 0.10 * total_ram
        cap_cpu = 0.50 * total_cpu
        cap_ram = 0.50 * total_ram

        dec = empty_decision(params)
        free_cpu = sim.pool_cpu_free
        free_ram = sim.pool_ram_free
        live = sim.ctr_status == int(ContainerStatus.RUNNING)
        waiting0 = sim.pipe_status == int(PipeStatus.WAITING)
        # OOMed at the RAM cap already -> return failure to the user.
        reject = waiting0 & sim.pipe_fail_flag & (sim.pipe_last_ram >= cap_ram - EPS)
        dec = dec._replace(reject=reject)
        # Fused-selection keys, hoisted out of the decision loop: the
        # candidate masks are the only per-slot inputs (``tried`` grows,
        # ``live`` shrinks); priorities and entry/start ticks are fixed
        # for the whole decision, so each slot pays one narrowing sweep
        # instead of re-deriving the three-pass reductions.
        head_keys = (-wl.prio, sim.pipe_entered)
        victim_keys = (sim.ctr_prio, -sim.ctr_start)
        base_mask = waiting0 & ~reject

        def step(k, carry):
            dec, free_cpu, free_ram, live, tried = carry
            mask = base_mask & ~tried
            pipe = masked_lex_argmin(mask, head_keys)
            valid = pipe >= 0
            pipe_c = jnp.maximum(pipe, 0)

            failed = sim.pipe_fail_flag[pipe_c]
            seen = sim.pipe_last_ram[pipe_c] > 0.0
            # doubling for OOM retries; same-as-last for preempted pipes;
            # 10% chunk for fresh arrivals (paper §4.1.2)
            want_cpu = jnp.where(
                failed,
                jnp.minimum(2.0 * sim.pipe_last_cpus[pipe_c], cap_cpu),
                jnp.where(seen, sim.pipe_last_cpus[pipe_c], chunk_cpu),
            )
            want_ram = jnp.where(
                failed,
                jnp.minimum(2.0 * sim.pipe_last_ram[pipe_c], cap_ram),
                jnp.where(seen, sim.pipe_last_ram[pipe_c], chunk_ram),
            )

            pool = _pool_select(pool_mode, free_cpu, free_ram, sim, pipe_c)

            fits = (free_cpu[pool] >= want_cpu - EPS) & (
                free_ram[pool] >= want_ram - EPS
            )

            # ---- preemption path: high-priority pipe, no room ------------
            can_preempt = valid & ~fits & (wl.prio[pipe_c] > int(Priority.BATCH))
            victim = masked_lex_argmin(
                live & (sim.ctr_prio < wl.prio[pipe_c]), victim_keys
            )
            has_victim = can_preempt & (victim >= 0)
            victim_c = jnp.maximum(victim, 0)
            vpool = sim.ctr_pool[victim_c]
            free_cpu2 = jnp.where(
                has_victim,
                onehot_add(free_cpu, vpool, sim.ctr_cpus[victim_c]),
                free_cpu,
            )
            free_ram2 = jnp.where(
                has_victim,
                onehot_add(free_ram, vpool, sim.ctr_ram[victim_c]),
                free_ram,
            )
            live2 = jnp.where(
                has_victim, onehot_set(live, victim_c, False), live
            )
            if multi_pool:
                pool2 = jnp.where(
                    has_victim,
                    vpool,
                    _pool_select(pool_mode, free_cpu2, free_ram2, sim, pipe_c),
                ).astype(jnp.int32)
            else:
                pool2 = pool
            fits2 = (free_cpu2[pool2] >= want_cpu - EPS) & (
                free_ram2[pool2] >= want_ram - EPS
            )

            do = valid & (fits | (has_victim & fits2))
            use_pool = jnp.where(fits, pool, pool2)
            # commit preemption only when it actually enables the assignment
            commit_victim = has_victim & ~fits & fits2
            suspend = jnp.where(
                commit_victim,
                onehot_set(dec.suspend, victim_c, True),
                dec.suspend,
            )
            free_cpu3 = jnp.where(commit_victim, free_cpu2, free_cpu)
            free_ram3 = jnp.where(commit_victim, free_ram2, free_ram)
            live3 = jnp.where(commit_victim, live2, live)

            free_cpu4 = jnp.where(
                do, onehot_add(free_cpu3, use_pool, -want_cpu), free_cpu3
            )
            free_ram4 = jnp.where(
                do, onehot_add(free_ram3, use_pool, -want_ram), free_ram3
            )
            dec = dec._replace(
                suspend=suspend,
                assign_pipe=onehot_set(
                    dec.assign_pipe, k, jnp.where(do, pipe_c, -1)
                ),
                assign_pool=onehot_set(dec.assign_pool, k, use_pool),
                assign_cpus=onehot_set(dec.assign_cpus, k, want_cpu),
                assign_ram=onehot_set(dec.assign_ram, k, want_ram),
            )
            # whether assigned or blocked, don't reconsider this pipe today
            tried = jnp.where(valid, onehot_set(tried, pipe_c, True), tried)
            return (dec, free_cpu4, free_ram4, live3, tried), valid

        tried0 = jnp.zeros((params.max_pipelines,), bool)
        carry0 = (dec, free_cpu, free_ram, live, tried0)
        dec, *_ = decision_loop(step, K, carry0, early_exit)
        return sched_state, dec

    return scheduler


# ---------------------------------------------------------------------------
# THE PARAMETERISED SCHEDULER FAMILY (policy search substrate).
#
# One decision loop generalising every built-in: the hard-coded knobs of
# ``naive_scheduler`` / ``_priority_like`` / ``extra_schedulers._sjf_like``
# become the flat f32 :class:`repro.core.policy.PolicyParams` vector.
# Evaluated at a named scheduler's ``DEFAULT_POINTS`` entry the family
# makes BITWISE-identical decisions (the zero-weight lead key is a
# constant and narrows nothing; disabled preemption passes every carry
# through; a zero locality bonus adds +0.0 to a nonnegative pool score;
# f32 images of the small-int priorities compare exactly), so the final
# states — and the 48-config digest grid in tests/captures/ — are
# preserved verbatim. tests/test_policy_family.py asserts this identity
# against the legacy implementations above, which remain as oracles.
#
# Two modes:
#   * static point (named schedulers): the point's floats are baked
#     into the jaxpr as constants — ``register_vector_scheduler_family
#     (key, params=point)`` wires the registry;
#   * dynamic (key ``"policy"``): the vector is read from
#     ``wl.policy``, so a vmapped fleet evaluates a different candidate
#     policy per lane in ONE compiled program (repro.search).
# ---------------------------------------------------------------------------
def _policy_pool_select(pol: PolicyParams, free_cpu, free_ram,
                        sim: SimState, pipe_c):
    """Knob-driven pool selection generalising :func:`_pool_select`.

    ``multi_pool`` off reproduces "single" (pool 0); on, the most-free
    score rule with ``locality_bonus`` added where the pipe has cached
    data ("free" at bonus 0, "locality" at 1e-3) and ``cache_pin``
    overriding to the best caching pool when one exists ("cache")."""
    score = free_cpu / jnp.maximum(sim.pool_cpu_cap, EPS) + (
        free_ram / jnp.maximum(sim.pool_ram_cap, EPS)
    )
    row = sim.cache_bytes[:, pipe_c]  # [NP] bytes of this pipe's data
    bonus = jnp.where(row > 0, jnp.float32(pol.locality_bonus),
                      jnp.float32(0.0))
    best = jnp.argmax(score + bonus)
    use_cache = (pol.cache_pin > 0.5) & (jnp.max(row) > 0)
    pool = jnp.where(use_cache, jnp.argmax(row), best)
    return jnp.where(pol.multi_pool > 0.5, pool, 0).astype(jnp.int32)


def _policy_family(early_exit: bool, static_policy: PolicyParams | None):
    """Build the parameterised scheduler (see the block comment above).

    ``static_policy`` is a :class:`PolicyParams` of python floats (the
    named-scheduler points) or None, which reads the traced vector from
    ``wl.policy`` — the per-lane axis policy-grid fleets vmap over."""

    def scheduler(
        sched_state: Any, sim: SimState, wl: Workload, params: SimParams
    ):
        if static_policy is not None:
            pol = PolicyParams(
                *(jnp.float32(v) for v in static_policy)
            )
        else:
            if wl.policy is None:
                raise ValueError(
                    "scheduler 'policy' needs a workload with a policy "
                    "vector attached; see repro.search.attach_policies / "
                    "sweep.policy_grid_workloads"
                )
            vec = wl.policy.astype(jnp.float32)
            pol = PolicyParams(*(vec[i] for i in range(N_POLICY_PARAMS)))

        K = params.max_assignments_per_tick
        total_cpu = jnp.sum(sim.pool_cpu_cap)
        total_ram = jnp.sum(sim.pool_ram_cap)
        chunk_cpu = pol.chunk_frac * total_cpu
        chunk_ram = pol.chunk_frac * total_ram
        cap_cpu = pol.cap_frac * total_cpu
        cap_ram = pol.cap_frac * total_ram

        preempt_on = pol.preempt > 0.5
        excl_on = pol.exclusive > 0.5
        grab_on = pol.grab_all > 0.5
        gate_on = pol.ram_gate > 0.5

        dec = empty_decision(params)
        live0 = sim.ctr_status == int(ContainerStatus.RUNNING)
        idle0 = ~jnp.any(live0)
        waiting0 = sim.pipe_status == int(PipeStatus.WAITING)
        # OOM fail-back: at the RAM cap already (ram_gate on), or any
        # prior OOM at all (ram_gate off — the naive rule: it held every
        # resource, doubling is impossible)
        over_cap = sim.pipe_last_ram >= cap_ram - EPS
        reject = waiting0 & sim.pipe_fail_flag & jnp.where(
            gate_on, over_cap, True
        )
        dec = dec._replace(reject=reject)

        # fused-selection keys, hoisted out of the decision loop. The
        # f32 lead key mixes sjf-vs-fifo ordering; at all-zero weights
        # it is constantly +0.0 (every term is a product with +0.0 over
        # nonnegative finite operands) and the narrowing sweep passes
        # the mask through untouched.
        prio_f = wl.prio.astype(jnp.float32)
        lead = (
            pol.size_weight * wl.n_ops.astype(jnp.float32)
            + pol.age_weight * sim.pipe_entered.astype(jnp.float32)
            - pol.prio_weight * prio_f
        )
        head_keys = (lead, -wl.prio, sim.pipe_entered)
        victim_keys = (sim.ctr_prio, -sim.ctr_start)
        ctr_prio_f = sim.ctr_prio.astype(jnp.float32)
        base_mask = waiting0 & ~reject

        def step(k, carry):
            dec, free_cpu, free_ram, live, tried, assigned = carry
            mask = base_mask & ~tried
            pipe = masked_lex_argmin(mask, head_keys)
            valid = pipe >= 0
            pipe_c = jnp.maximum(pipe, 0)

            failed = sim.pipe_fail_flag[pipe_c]
            seen = sim.pipe_last_ram[pipe_c] > 0.0
            want_cpu = jnp.where(
                failed,
                jnp.minimum(pol.retry_mult * sim.pipe_last_cpus[pipe_c],
                            cap_cpu),
                jnp.where(seen, sim.pipe_last_cpus[pipe_c], chunk_cpu),
            )
            want_ram = jnp.where(
                failed,
                jnp.minimum(pol.retry_mult * sim.pipe_last_ram[pipe_c],
                            cap_ram),
                jnp.where(seen, sim.pipe_last_ram[pipe_c], chunk_ram),
            )

            pool = _policy_pool_select(pol, free_cpu, free_ram, sim, pipe_c)
            # naive's grab-everything grant: the chosen pool's full caps
            want_cpu = jnp.where(grab_on, sim.pool_cpu_cap[pool], want_cpu)
            want_ram = jnp.where(grab_on, sim.pool_ram_cap[pool], want_ram)

            fits = (free_cpu[pool] >= want_cpu - EPS) & (
                free_ram[pool] >= want_ram - EPS
            )

            # ---- preemption path: gated by the policy knobs -------------
            can_preempt = (
                valid & ~fits & preempt_on
                & (prio_f[pipe_c] > pol.preempt_min_prio)
            )
            victim = masked_lex_argmin(
                live & (ctr_prio_f < prio_f[pipe_c] - pol.victim_prio_gap),
                victim_keys,
            )
            has_victim = can_preempt & (victim >= 0)
            victim_c = jnp.maximum(victim, 0)
            vpool = sim.ctr_pool[victim_c]
            free_cpu2 = jnp.where(
                has_victim,
                onehot_add(free_cpu, vpool, sim.ctr_cpus[victim_c]),
                free_cpu,
            )
            free_ram2 = jnp.where(
                has_victim,
                onehot_add(free_ram, vpool, sim.ctr_ram[victim_c]),
                free_ram,
            )
            live2 = jnp.where(
                has_victim, onehot_set(live, victim_c, False), live
            )
            pool2_multi = jnp.where(
                has_victim,
                vpool,
                _policy_pool_select(pol, free_cpu2, free_ram2, sim, pipe_c),
            ).astype(jnp.int32)
            pool2 = jnp.where(pol.multi_pool > 0.5, pool2_multi, pool)
            fits2 = (free_cpu2[pool2] >= want_cpu - EPS) & (
                free_ram2[pool2] >= want_ram - EPS
            )

            do_norm = valid & (fits | (has_victim & fits2))
            # exclusive (naive) mode: idle cluster, one assignment, no
            # fits test — the grant is the full pool anyway
            do_excl = valid & idle0 & ~assigned
            do = jnp.where(excl_on, do_excl, do_norm)
            use_pool = jnp.where(fits, pool, pool2)
            commit_victim = has_victim & ~fits & fits2
            suspend = jnp.where(
                commit_victim,
                onehot_set(dec.suspend, victim_c, True),
                dec.suspend,
            )
            free_cpu3 = jnp.where(commit_victim, free_cpu2, free_cpu)
            free_ram3 = jnp.where(commit_victim, free_ram2, free_ram)
            live3 = jnp.where(commit_victim, live2, live)

            free_cpu4 = jnp.where(
                do, onehot_add(free_cpu3, use_pool, -want_cpu), free_cpu3
            )
            free_ram4 = jnp.where(
                do, onehot_add(free_ram3, use_pool, -want_ram), free_ram3
            )
            dec = dec._replace(
                suspend=suspend,
                assign_pipe=onehot_set(
                    dec.assign_pipe, k, jnp.where(do, pipe_c, -1)
                ),
                assign_pool=onehot_set(dec.assign_pool, k, use_pool),
                assign_cpus=onehot_set(dec.assign_cpus, k, want_cpu),
                assign_ram=onehot_set(dec.assign_ram, k, want_ram),
            )
            assigned = assigned | do
            tried = jnp.where(valid, onehot_set(tried, pipe_c, True), tried)
            return (dec, free_cpu4, free_ram4, live3, tried, assigned), valid

        tried0 = jnp.zeros((params.max_pipelines,), bool)
        carry0 = (
            dec, sim.pool_cpu_free, sim.pool_ram_free, live0, tried0,
            jnp.bool_(False),
        )
        dec, *_ = decision_loop(step, K, carry0, early_exit)
        return sched_state, dec

    return scheduler


def policy_family_make(point: PolicyParams | None, early_exit: bool):
    """Family factory for the registry: ``make(early_exit)`` with the
    policy point partially applied (``functools.partial``-friendly)."""
    return _policy_family(early_exit, point)


# ---------------------------------------------------------------------------
# Vector-scheduler registry (the compiled lane-major core). The
# Python-API registry (paper Listing 4 decorators) lives in
# ``algorithm.py``.
#
# ONE registry of scheduler *families*: a family is a factory
# ``make(early_exit: bool) -> scheduler`` over the existing
# ``decision_loop(early_exit=...)`` knob. Both variants of a family make
# bitwise-identical decisions; ``early_exit=True`` (what the engine
# compiles) trades the fixed K-iteration loop for a while_loop that
# vmaps into max-over-lanes trip counts. Plain schedulers (the custom
# user path) register a single function that serves both variants.
# Builds are cached per (key, early_exit) so repeated lookups hand jit
# the same callable.
# ---------------------------------------------------------------------------
VectorScheduler = Callable[
    [Any, SimState, Workload, SimParams], tuple[Any, SchedDecision]
]
SchedulerFamily = Callable[[bool], VectorScheduler]

_VECTOR_FAMILIES: dict[str, SchedulerFamily] = {}
_VECTOR_INITS: dict[str, Callable[[SimParams], Any]] = {}
_BUILT: dict[tuple[str, bool], VectorScheduler] = {}
# scheduler key -> the PolicyParams point it sits at in the policy
# space (the ``params=`` registry axis). Only schedulers registered
# with a point appear; the dynamic "policy" family reads its vector
# from the workload instead and is deliberately absent.
_POLICY_POINTS: dict[str, PolicyParams] = {}
# early-exit overrides installed via the deprecated fleet-registry shim;
# kept separate so (re-)registering a plain scheduler cannot clobber
# them — registration order stays irrelevant, as under the old dual
# registries. Dies with the shim.
_SHIM_EARLY_EXIT: dict[str, VectorScheduler] = {}


def _norm(key: str) -> str:
    return key.replace("-", "_").lower()


def _invalidate(k: str) -> None:
    _BUILT.pop((k, False), None)
    _BUILT.pop((k, True), None)
    if k in _SHIM_EARLY_EXIT:
        _BUILT[(k, True)] = _SHIM_EARLY_EXIT[k]


def register_vector_scheduler(key: str):
    """Register a plain lane-major scheduler (used for both variants)."""

    def deco(fn: VectorScheduler) -> VectorScheduler:
        k = _norm(key)
        _VECTOR_FAMILIES[k] = lambda early_exit, _fn=fn: _fn
        _invalidate(k)
        return fn

    return deco


def register_vector_scheduler_family(
    key: str, params: PolicyParams | None = None
):
    """Register a scheduler family ``make(early_exit: bool) -> fn``.

    With ``params=`` (the policy-search axis) the decorated factory is
    instead called ``make(params, early_exit)`` — pass
    :func:`policy_family_make` to place a named scheduler at a
    :class:`PolicyParams` point of the parameterised family — and the
    point is recorded for :func:`get_policy_point`, so searches can seed
    populations from (and compare against) every named scheduler.
    """

    def deco(make) -> SchedulerFamily:
        k = _norm(key)
        if params is not None:
            _VECTOR_FAMILIES[k] = functools.partial(make, params)
            _POLICY_POINTS[k] = params
        else:
            _VECTOR_FAMILIES[k] = make
            _POLICY_POINTS.pop(k, None)
        _invalidate(k)
        return make

    return deco


def get_policy_point(key: str) -> PolicyParams:
    """The :class:`PolicyParams` point scheduler ``key`` sits at.

    Raises ``KeyError`` for schedulers registered without ``params=``
    (custom schedulers, the dynamic "policy" family itself).
    """
    k = _norm(key)
    if k not in _POLICY_POINTS:
        raise KeyError(
            f"scheduler {key!r} has no registered policy point; "
            f"pointed schedulers: {sorted(_POLICY_POINTS)}"
        )
    return _POLICY_POINTS[k]


def has_policy_point(key: str) -> bool:
    return _norm(key) in _POLICY_POINTS


def policy_points() -> dict[str, PolicyParams]:
    """All named schedulers with a policy point (search baselines)."""
    return dict(_POLICY_POINTS)


def register_vector_scheduler_init(key: str):
    def deco(fn: Callable[[SimParams], Any]):
        _VECTOR_INITS[_norm(key)] = fn
        return fn

    return deco


def get_vector_scheduler(key: str, early_exit: bool = False) -> VectorScheduler:
    k = _norm(key)
    if k not in _VECTOR_FAMILIES:
        raise KeyError(
            f"unknown scheduler {key!r}; registered: "
            f"{sorted(_VECTOR_FAMILIES)}"
        )
    ck = (k, bool(early_exit))
    if ck not in _BUILT:
        _BUILT[ck] = _VECTOR_FAMILIES[k](bool(early_exit))
    return _BUILT[ck]


def get_vector_scheduler_init(key: str) -> Callable[[SimParams], Any]:
    return _VECTOR_INITS.get(_norm(key), lambda params: None)


def has_vector_scheduler(key: str) -> bool:
    return _norm(key) in _VECTOR_FAMILIES


# ---------------------------------------------------------------------------
# Deprecated fleet-registry shims (one release). The single/fleet split
# collapsed into the family registry above; these keep old call sites
# working while warning.
# ---------------------------------------------------------------------------
def register_fleet_vector_scheduler(key: str):
    import warnings

    warnings.warn(
        "register_fleet_vector_scheduler is deprecated: the scheduler "
        "registries were unified — register a family with "
        "register_vector_scheduler_family(key)(make) instead",
        DeprecationWarning,
        stacklevel=2,
    )

    def deco(fn: VectorScheduler) -> VectorScheduler:
        k = _norm(key)
        # honour the old semantics: this fn is the variant the engine
        # runs, regardless of plain-registration order
        _SHIM_EARLY_EXIT[k] = fn
        _BUILT[(k, True)] = fn
        if k not in _VECTOR_FAMILIES:
            _VECTOR_FAMILIES[k] = lambda early_exit, _fn=fn: _fn
        return fn

    return deco


def get_fleet_vector_scheduler(key: str) -> VectorScheduler:
    """Deprecated alias for ``get_vector_scheduler(key, early_exit=True)``."""
    import warnings

    warnings.warn(
        "get_fleet_vector_scheduler is deprecated: use "
        "get_vector_scheduler(key, early_exit=True)",
        DeprecationWarning,
        stacklevel=2,
    )
    return get_vector_scheduler(key, early_exit=True)


# The named schedulers ARE points of the parameterised family: each
# registers through `policy_family_make` at its DEFAULT_POINTS entry
# (bitwise-identical to the legacy implementations — see the family
# block comment). The legacy implementations stay registered under
# `*_ref` keys as independent oracles for the identity test wall; the
# sjf pair registers from extra_schedulers.py.
register_vector_scheduler_family("naive", params=DEFAULT_POINTS["naive"])(
    policy_family_make
)
register_vector_scheduler_family(
    "priority", params=DEFAULT_POINTS["priority"]
)(policy_family_make)
register_vector_scheduler_family(
    "priority_pool", params=DEFAULT_POINTS["priority_pool"]
)(policy_family_make)
register_vector_scheduler_family(
    "cache_aware", params=DEFAULT_POINTS["cache_aware"]
)(policy_family_make)
register_vector_scheduler_family(
    "locality_pool", params=DEFAULT_POINTS["locality_pool"]
)(policy_family_make)
# the dynamic family: per-lane vectors from ``wl.policy`` (vmapped
# policy grids — repro.search evaluates candidate populations with it)
register_vector_scheduler_family("policy")(
    functools.partial(policy_family_make, None)
)

register_vector_scheduler("naive_ref")(naive_scheduler)
register_vector_scheduler_family("priority_ref")(
    functools.partial(_priority_like, "single")
)
register_vector_scheduler_family("priority_pool_ref")(
    functools.partial(_priority_like, "free")
)
register_vector_scheduler_family("cache_aware_ref")(
    functools.partial(_priority_like, "cache")
)
register_vector_scheduler_family("locality_pool_ref")(
    functools.partial(_priority_like, "locality")
)

# stable aliases for the no-early-exit builds (public API compat) — all
# four resolve through the registry so they ARE the `_BUILT`-cached
# instances jit sees everywhere else (a bare `_priority_like(...)` call
# here would build uncached duplicates and defeat the jit-identity
# cache).
def mask_down_pools(sim: SimState, tick: jax.Array) -> SimState:
    """Scheduler view of ``sim`` with down pools' free capacity zeroed.

    A pool is down while ``tick < pool_down_until`` (chaos layer, see
    docs/faults.md). The engine hands the scheduler this masked *view*
    — free-resource-driven schedulers then treat the pool as full and
    place elsewhere — while the committed state keeps the true free
    counts (the outage killed containers and refunded their resources;
    recovery must not re-inflate capacity). Schedulers that read pool
    *caps* rather than free counts (``naive``) are caught by the
    engine's decision filter, which drops assignments onto down pools
    before they commit.
    """
    down = tick < sim.pool_down_until
    return sim._replace(
        pool_cpu_free=jnp.where(down, 0.0, sim.pool_cpu_free),
        pool_ram_free=jnp.where(down, 0.0, sim.pool_ram_free),
    )


priority_scheduler = get_vector_scheduler("priority")
priority_pool_scheduler = get_vector_scheduler("priority_pool")
cache_aware_scheduler = get_vector_scheduler("cache_aware")
locality_pool_scheduler = get_vector_scheduler("locality_pool")


__all__ = [
    "SchedDecision",
    "decision_loop",
    "empty_decision",
    "mask_down_pools",
    "select_next_pipe",
    "select_victim",
    "naive_scheduler",
    "policy_family_make",
    "get_policy_point",
    "has_policy_point",
    "policy_points",
    "priority_scheduler",
    "priority_pool_scheduler",
    "cache_aware_scheduler",
    "locality_pool_scheduler",
    "register_vector_scheduler",
    "register_vector_scheduler_family",
    "register_vector_scheduler_init",
    "register_fleet_vector_scheduler",
    "get_vector_scheduler",
    "get_vector_scheduler_init",
    "get_fleet_vector_scheduler",
    "has_vector_scheduler",
]
