"""Schedulers (paper §3.2.3, §4.1.2).

A scheduler "accept[s] a set of Pipelines from the workload generator,
and output[s] a list of new Container allocations and Container
preemptions to the Executor". In the compiled engines this is a pure
function over the struct-of-arrays state:

    fn(sched_state, sim: SimState, wl: Workload, params) ->
        (sched_state, SchedDecision)

``SchedDecision`` carries fixed-capacity arrays (suspension mask over
containers, rejection mask over pipelines, up to K new assignments).

Three built-ins mirror §4.1.2:

* ``naive``          — one pool; all resources to the head of the queue.
* ``priority``       — 10 % chunks, OOM-retry doubling capped at 50 %,
                       preemption of lower-priority containers.
* ``priority_pool``  — ditto, but allocates on the pool with the most
                       available resources.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.sched_select import masked_lex_argmin

from .params import SimParams
from .state import INF_TICK, SimState, Workload
from .types import ContainerStatus, PipeStatus, Priority

EPS = 1e-5


class SchedDecision(NamedTuple):
    suspend: jax.Array      # [MC] bool — containers to preempt
    reject: jax.Array       # [MP] bool — pipelines failed back to the user
    assign_pipe: jax.Array  # [K] int32 (-1 = unused slot)
    assign_pool: jax.Array  # [K] int32
    assign_cpus: jax.Array  # [K] f32
    assign_ram: jax.Array   # [K] f32


def empty_decision(params: SimParams) -> SchedDecision:
    K = params.max_assignments_per_tick
    return SchedDecision(
        suspend=jnp.zeros((params.max_containers,), bool),
        reject=jnp.zeros((params.max_pipelines,), bool),
        assign_pipe=jnp.full((K,), -1, jnp.int32),
        assign_pool=jnp.zeros((K,), jnp.int32),
        assign_cpus=jnp.zeros((K,), jnp.float32),
        assign_ram=jnp.zeros((K,), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Masked selection helpers (queue semantics without materialised queues):
# waiting order = priority desc, then (re-)entry tick asc, then pid asc.
#
# These three-pass forms are the *oracles*: the schedulers below run the
# fused ``repro.kernels.sched_select.masked_lex_argmin`` instead (one
# narrowing sweep, Pallas on TPU), which tests/test_sched_select.py
# property-tests bitwise against these on the engine's domain.
# ---------------------------------------------------------------------------
def select_next_pipe(mask: jax.Array, prio: jax.Array, entered: jax.Array):
    any_ = jnp.any(mask)
    p = jnp.where(mask, prio, -1)
    m2 = mask & (prio == jnp.max(p))
    e = jnp.where(m2, entered, INF_TICK)
    m3 = m2 & (entered == jnp.min(e))
    idx = jnp.argmax(m3).astype(jnp.int32)
    return jnp.where(any_, idx, -1)


def select_victim(
    live: jax.Array, ctr_prio: jax.Array, ctr_start: jax.Array, below_prio: jax.Array
):
    """Preemption victim: lowest priority, then latest start (least progress
    lost). ``below_prio`` is the exclusive priority upper bound."""
    m = live & (ctr_prio < below_prio)
    any_ = jnp.any(m)
    p = jnp.where(m, ctr_prio, jnp.int32(2**30))
    m2 = m & (ctr_prio == jnp.min(p))
    s = jnp.where(m2, ctr_start, -1)
    m3 = m2 & (ctr_start == jnp.max(s))
    idx = jnp.argmax(m3).astype(jnp.int32)
    return jnp.where(any_, idx, -1)


def onehot_set(arr: jax.Array, idx: jax.Array, val):
    """``arr.at[idx].set(val)`` as an elementwise select.

    Dynamic-index scatters lower to a serialized ``while`` thunk per
    scatter on XLA:CPU under the engine's per-lane ``vmap``; the
    one-hot select stays elementwise. Bitwise identical: only position
    ``idx`` takes ``val``, every other element is passed through."""
    iota = jnp.arange(arr.shape[0], dtype=jnp.int32)
    return jnp.where(iota == idx, val, arr)


def onehot_add(arr: jax.Array, idx: jax.Array, val):
    """``arr.at[idx].add(val)`` as an elementwise select (see
    :func:`onehot_set`). Exact: the selected element is the same single
    ``arr[idx] + val`` the scatter-add performs; the rest pass through
    untouched (no reassociation anywhere)."""
    iota = jnp.arange(arr.shape[0], dtype=jnp.int32)
    return jnp.where(iota == idx, arr + val, arr)


# ---------------------------------------------------------------------------
# Decision-slot loop runner shared by the K-assignment schedulers.
# ---------------------------------------------------------------------------
def decision_loop(step, K: int, carry0, early_exit: bool):
    """Run ``step(k, carry) -> (carry, keep_going)`` over the K decision
    slots. With ``early_exit`` the loop stops at the first ``keep_going
    = False`` — valid whenever later iterations are provable no-ops
    (the waiting-queue mask can only shrink); under vmap the while_loop
    trip count becomes the max over lanes of actual queue length. This
    knob is what parameterises a scheduler *family* in the unified
    registry below: both variants are bitwise-identical, and the
    lane-major core compiles ``early_exit=True``."""
    if early_exit:

        def w_cond(c):
            k, go, _ = c
            return (k < K) & go

        def w_body(c):
            k, _, carry = c
            carry, go = step(k, carry)
            return k + 1, go, carry

        *_, carry = jax.lax.while_loop(
            w_cond, w_body, (jnp.int32(0), jnp.bool_(True), carry0)
        )
        return carry

    def body(k, carry):
        carry, _ = step(k, carry)
        return carry

    return jax.lax.fori_loop(0, K, body, carry0)


# ---------------------------------------------------------------------------
# NAIVE (paper §4.1.2): single pool, everything to the queue head, no
# concurrency, no preemption. A pipeline that OOMed with all resources can
# never succeed -> permanent failure.
# ---------------------------------------------------------------------------
def naive_scheduler(
    sched_state: Any, sim: SimState, wl: Workload, params: SimParams
):
    dec = empty_decision(params)
    waiting = sim.pipe_status == int(PipeStatus.WAITING)
    # fail-back: it already had every resource, doubling is impossible
    reject = waiting & sim.pipe_fail_flag
    waiting = waiting & ~reject

    idle = ~jnp.any(sim.ctr_status == int(ContainerStatus.RUNNING))
    pipe = masked_lex_argmin(waiting, (-wl.prio, sim.pipe_entered))
    do = idle & (pipe >= 0)
    dec = dec._replace(
        reject=reject,
        assign_pipe=dec.assign_pipe.at[0].set(jnp.where(do, pipe, -1)),
        assign_pool=dec.assign_pool.at[0].set(0),
        assign_cpus=dec.assign_cpus.at[0].set(sim.pool_cpu_cap[0]),
        assign_ram=dec.assign_ram.at[0].set(sim.pool_ram_cap[0]),
    )
    return sched_state, dec


def decision_provenance(sim: SimState, wl: Workload, dec: SchedDecision):
    """``(chosen, runner_up)`` pipeline ids behind a decision's first
    assignment slot — the runner-up is the pipeline the head-of-queue
    rule (priority desc, arrival asc) would have picked had the chosen
    one not been waiting. Both are -1 when not applicable. Used by the
    telemetry recorder for SCHED_DECISION provenance records; reads
    only, never part of the simulation step."""
    chosen = dec.assign_pipe[0]
    waiting = sim.pipe_status == int(PipeStatus.WAITING)
    others = waiting & (
        jnp.arange(wl.max_pipelines, dtype=jnp.int32) != chosen
    )
    runner = masked_lex_argmin(others, (-wl.prio, sim.pipe_entered))
    return chosen, jnp.where(chosen >= 0, runner, -1)


# ---------------------------------------------------------------------------
# PRIORITY / PRIORITY-POOL (paper §4.1.2) and the data-plane variants
# (cache_aware / locality_pool, registered from extra_schedulers.py).
#
# ``pool_mode`` picks the pool-selection rule; every rule is mirrored
# f32-op-for-op by ``engine_python._pool_select_py``:
#   "single"   — always pool 0 (paper ``priority``)
#   "free"     — most free resources (paper ``priority_pool``)
#   "cache"    — pool holding the pipeline's parent outputs, else "free"
#   "locality" — "free" score with a small bonus for pools holding any
#                of the pipeline's data (locality tie-break)
# ---------------------------------------------------------------------------
LOCALITY_BONUS = 1e-3


def _pool_select(pool_mode: str, free_cpu, free_ram, sim: SimState, pipe_c):
    if pool_mode == "single":
        return jnp.int32(0)
    score = free_cpu / jnp.maximum(sim.pool_cpu_cap, EPS) + (
        free_ram / jnp.maximum(sim.pool_ram_cap, EPS)
    )
    if pool_mode == "free":
        return jnp.argmax(score).astype(jnp.int32)
    row = sim.cache_bytes[:, pipe_c]  # [NP] bytes of this pipe's data
    if pool_mode == "cache":
        return jnp.where(
            jnp.max(row) > 0, jnp.argmax(row), jnp.argmax(score)
        ).astype(jnp.int32)
    if pool_mode == "locality":
        bonus = jnp.where(row > 0, jnp.float32(LOCALITY_BONUS), 0.0)
        return jnp.argmax(score + bonus).astype(jnp.int32)
    raise ValueError(f"unknown pool_mode {pool_mode!r}")


def _priority_like(pool_mode: str, early_exit: bool = False):
    """The generalised priority scheduler family.

    ``early_exit=True`` swaps the fixed K-iteration ``fori_loop`` for a
    ``while_loop`` that stops as soon as the waiting queue is exhausted
    (once ``select_next_pipe`` returns -1 the candidate mask can only
    shrink, so every later iteration is a no-op). Bitwise-identical
    decisions; the lane-major core compiles the early-exit variant so
    events with short queues stop paying K sequential scheduler steps.
    """
    multi_pool = pool_mode != "single"

    def scheduler(
        sched_state: Any, sim: SimState, wl: Workload, params: SimParams
    ):
        K = params.max_assignments_per_tick
        NP = params.num_pools
        total_cpu = jnp.sum(sim.pool_cpu_cap)
        total_ram = jnp.sum(sim.pool_ram_cap)
        chunk_cpu = 0.10 * total_cpu
        chunk_ram = 0.10 * total_ram
        cap_cpu = 0.50 * total_cpu
        cap_ram = 0.50 * total_ram

        dec = empty_decision(params)
        free_cpu = sim.pool_cpu_free
        free_ram = sim.pool_ram_free
        live = sim.ctr_status == int(ContainerStatus.RUNNING)
        waiting0 = sim.pipe_status == int(PipeStatus.WAITING)
        # OOMed at the RAM cap already -> return failure to the user.
        reject = waiting0 & sim.pipe_fail_flag & (sim.pipe_last_ram >= cap_ram - EPS)
        dec = dec._replace(reject=reject)
        # Fused-selection keys, hoisted out of the decision loop: the
        # candidate masks are the only per-slot inputs (``tried`` grows,
        # ``live`` shrinks); priorities and entry/start ticks are fixed
        # for the whole decision, so each slot pays one narrowing sweep
        # instead of re-deriving the three-pass reductions.
        head_keys = (-wl.prio, sim.pipe_entered)
        victim_keys = (sim.ctr_prio, -sim.ctr_start)
        base_mask = waiting0 & ~reject

        def step(k, carry):
            dec, free_cpu, free_ram, live, tried = carry
            mask = base_mask & ~tried
            pipe = masked_lex_argmin(mask, head_keys)
            valid = pipe >= 0
            pipe_c = jnp.maximum(pipe, 0)

            failed = sim.pipe_fail_flag[pipe_c]
            seen = sim.pipe_last_ram[pipe_c] > 0.0
            # doubling for OOM retries; same-as-last for preempted pipes;
            # 10% chunk for fresh arrivals (paper §4.1.2)
            want_cpu = jnp.where(
                failed,
                jnp.minimum(2.0 * sim.pipe_last_cpus[pipe_c], cap_cpu),
                jnp.where(seen, sim.pipe_last_cpus[pipe_c], chunk_cpu),
            )
            want_ram = jnp.where(
                failed,
                jnp.minimum(2.0 * sim.pipe_last_ram[pipe_c], cap_ram),
                jnp.where(seen, sim.pipe_last_ram[pipe_c], chunk_ram),
            )

            pool = _pool_select(pool_mode, free_cpu, free_ram, sim, pipe_c)

            fits = (free_cpu[pool] >= want_cpu - EPS) & (
                free_ram[pool] >= want_ram - EPS
            )

            # ---- preemption path: high-priority pipe, no room ------------
            can_preempt = valid & ~fits & (wl.prio[pipe_c] > int(Priority.BATCH))
            victim = masked_lex_argmin(
                live & (sim.ctr_prio < wl.prio[pipe_c]), victim_keys
            )
            has_victim = can_preempt & (victim >= 0)
            victim_c = jnp.maximum(victim, 0)
            vpool = sim.ctr_pool[victim_c]
            free_cpu2 = jnp.where(
                has_victim,
                onehot_add(free_cpu, vpool, sim.ctr_cpus[victim_c]),
                free_cpu,
            )
            free_ram2 = jnp.where(
                has_victim,
                onehot_add(free_ram, vpool, sim.ctr_ram[victim_c]),
                free_ram,
            )
            live2 = jnp.where(
                has_victim, onehot_set(live, victim_c, False), live
            )
            if multi_pool:
                pool2 = jnp.where(
                    has_victim,
                    vpool,
                    _pool_select(pool_mode, free_cpu2, free_ram2, sim, pipe_c),
                ).astype(jnp.int32)
            else:
                pool2 = pool
            fits2 = (free_cpu2[pool2] >= want_cpu - EPS) & (
                free_ram2[pool2] >= want_ram - EPS
            )

            do = valid & (fits | (has_victim & fits2))
            use_pool = jnp.where(fits, pool, pool2)
            # commit preemption only when it actually enables the assignment
            commit_victim = has_victim & ~fits & fits2
            suspend = jnp.where(
                commit_victim,
                onehot_set(dec.suspend, victim_c, True),
                dec.suspend,
            )
            free_cpu3 = jnp.where(commit_victim, free_cpu2, free_cpu)
            free_ram3 = jnp.where(commit_victim, free_ram2, free_ram)
            live3 = jnp.where(commit_victim, live2, live)

            free_cpu4 = jnp.where(
                do, onehot_add(free_cpu3, use_pool, -want_cpu), free_cpu3
            )
            free_ram4 = jnp.where(
                do, onehot_add(free_ram3, use_pool, -want_ram), free_ram3
            )
            dec = dec._replace(
                suspend=suspend,
                assign_pipe=onehot_set(
                    dec.assign_pipe, k, jnp.where(do, pipe_c, -1)
                ),
                assign_pool=onehot_set(dec.assign_pool, k, use_pool),
                assign_cpus=onehot_set(dec.assign_cpus, k, want_cpu),
                assign_ram=onehot_set(dec.assign_ram, k, want_ram),
            )
            # whether assigned or blocked, don't reconsider this pipe today
            tried = jnp.where(valid, onehot_set(tried, pipe_c, True), tried)
            return (dec, free_cpu4, free_ram4, live3, tried), valid

        tried0 = jnp.zeros((params.max_pipelines,), bool)
        carry0 = (dec, free_cpu, free_ram, live, tried0)
        dec, *_ = decision_loop(step, K, carry0, early_exit)
        return sched_state, dec

    return scheduler


# ---------------------------------------------------------------------------
# Vector-scheduler registry (the compiled lane-major core). The
# Python-API registry (paper Listing 4 decorators) lives in
# ``algorithm.py``.
#
# ONE registry of scheduler *families*: a family is a factory
# ``make(early_exit: bool) -> scheduler`` over the existing
# ``decision_loop(early_exit=...)`` knob. Both variants of a family make
# bitwise-identical decisions; ``early_exit=True`` (what the engine
# compiles) trades the fixed K-iteration loop for a while_loop that
# vmaps into max-over-lanes trip counts. Plain schedulers (the custom
# user path) register a single function that serves both variants.
# Builds are cached per (key, early_exit) so repeated lookups hand jit
# the same callable.
# ---------------------------------------------------------------------------
VectorScheduler = Callable[
    [Any, SimState, Workload, SimParams], tuple[Any, SchedDecision]
]
SchedulerFamily = Callable[[bool], VectorScheduler]

_VECTOR_FAMILIES: dict[str, SchedulerFamily] = {}
_VECTOR_INITS: dict[str, Callable[[SimParams], Any]] = {}
_BUILT: dict[tuple[str, bool], VectorScheduler] = {}
# early-exit overrides installed via the deprecated fleet-registry shim;
# kept separate so (re-)registering a plain scheduler cannot clobber
# them — registration order stays irrelevant, as under the old dual
# registries. Dies with the shim.
_SHIM_EARLY_EXIT: dict[str, VectorScheduler] = {}


def _norm(key: str) -> str:
    return key.replace("-", "_").lower()


def _invalidate(k: str) -> None:
    _BUILT.pop((k, False), None)
    _BUILT.pop((k, True), None)
    if k in _SHIM_EARLY_EXIT:
        _BUILT[(k, True)] = _SHIM_EARLY_EXIT[k]


def register_vector_scheduler(key: str):
    """Register a plain lane-major scheduler (used for both variants)."""

    def deco(fn: VectorScheduler) -> VectorScheduler:
        k = _norm(key)
        _VECTOR_FAMILIES[k] = lambda early_exit, _fn=fn: _fn
        _invalidate(k)
        return fn

    return deco


def register_vector_scheduler_family(key: str):
    """Register a scheduler family ``make(early_exit: bool) -> fn``."""

    def deco(make: SchedulerFamily) -> SchedulerFamily:
        k = _norm(key)
        _VECTOR_FAMILIES[k] = make
        _invalidate(k)
        return make

    return deco


def register_vector_scheduler_init(key: str):
    def deco(fn: Callable[[SimParams], Any]):
        _VECTOR_INITS[_norm(key)] = fn
        return fn

    return deco


def get_vector_scheduler(key: str, early_exit: bool = False) -> VectorScheduler:
    k = _norm(key)
    if k not in _VECTOR_FAMILIES:
        raise KeyError(
            f"unknown scheduler {key!r}; registered: "
            f"{sorted(_VECTOR_FAMILIES)}"
        )
    ck = (k, bool(early_exit))
    if ck not in _BUILT:
        _BUILT[ck] = _VECTOR_FAMILIES[k](bool(early_exit))
    return _BUILT[ck]


def get_vector_scheduler_init(key: str) -> Callable[[SimParams], Any]:
    return _VECTOR_INITS.get(_norm(key), lambda params: None)


def has_vector_scheduler(key: str) -> bool:
    return _norm(key) in _VECTOR_FAMILIES


# ---------------------------------------------------------------------------
# Deprecated fleet-registry shims (one release). The single/fleet split
# collapsed into the family registry above; these keep old call sites
# working while warning.
# ---------------------------------------------------------------------------
def register_fleet_vector_scheduler(key: str):
    import warnings

    warnings.warn(
        "register_fleet_vector_scheduler is deprecated: the scheduler "
        "registries were unified — register a family with "
        "register_vector_scheduler_family(key)(make) instead",
        DeprecationWarning,
        stacklevel=2,
    )

    def deco(fn: VectorScheduler) -> VectorScheduler:
        k = _norm(key)
        # honour the old semantics: this fn is the variant the engine
        # runs, regardless of plain-registration order
        _SHIM_EARLY_EXIT[k] = fn
        _BUILT[(k, True)] = fn
        if k not in _VECTOR_FAMILIES:
            _VECTOR_FAMILIES[k] = lambda early_exit, _fn=fn: _fn
        return fn

    return deco


def get_fleet_vector_scheduler(key: str) -> VectorScheduler:
    """Deprecated alias for ``get_vector_scheduler(key, early_exit=True)``."""
    import warnings

    warnings.warn(
        "get_fleet_vector_scheduler is deprecated: use "
        "get_vector_scheduler(key, early_exit=True)",
        DeprecationWarning,
        stacklevel=2,
    )
    return get_vector_scheduler(key, early_exit=True)


register_vector_scheduler("naive")(naive_scheduler)
register_vector_scheduler_family("priority")(
    functools.partial(_priority_like, "single")
)
register_vector_scheduler_family("priority_pool")(
    functools.partial(_priority_like, "free")
)
# The data-plane families are `_priority_like` too, so they register
# here (their Python twins live in extra_schedulers.py); the sjf family
# is registered from extra_schedulers.py.
register_vector_scheduler_family("cache_aware")(
    functools.partial(_priority_like, "cache")
)
register_vector_scheduler_family("locality_pool")(
    functools.partial(_priority_like, "locality")
)

# stable aliases for the no-early-exit builds (public API compat) — all
# four resolve through the registry so they ARE the `_BUILT`-cached
# instances jit sees everywhere else (a bare `_priority_like(...)` call
# here would build uncached duplicates and defeat the jit-identity
# cache).
def mask_down_pools(sim: SimState, tick: jax.Array) -> SimState:
    """Scheduler view of ``sim`` with down pools' free capacity zeroed.

    A pool is down while ``tick < pool_down_until`` (chaos layer, see
    docs/faults.md). The engine hands the scheduler this masked *view*
    — free-resource-driven schedulers then treat the pool as full and
    place elsewhere — while the committed state keeps the true free
    counts (the outage killed containers and refunded their resources;
    recovery must not re-inflate capacity). Schedulers that read pool
    *caps* rather than free counts (``naive``) are caught by the
    engine's decision filter, which drops assignments onto down pools
    before they commit.
    """
    down = tick < sim.pool_down_until
    return sim._replace(
        pool_cpu_free=jnp.where(down, 0.0, sim.pool_cpu_free),
        pool_ram_free=jnp.where(down, 0.0, sim.pool_ram_free),
    )


priority_scheduler = get_vector_scheduler("priority")
priority_pool_scheduler = get_vector_scheduler("priority_pool")
cache_aware_scheduler = get_vector_scheduler("cache_aware")
locality_pool_scheduler = get_vector_scheduler("locality_pool")


__all__ = [
    "SchedDecision",
    "decision_loop",
    "empty_decision",
    "mask_down_pools",
    "select_next_pipe",
    "select_victim",
    "naive_scheduler",
    "priority_scheduler",
    "priority_pool_scheduler",
    "cache_aware_scheduler",
    "locality_pool_scheduler",
    "register_vector_scheduler",
    "register_vector_scheduler_family",
    "register_vector_scheduler_init",
    "register_fleet_vector_scheduler",
    "get_vector_scheduler",
    "get_vector_scheduler_init",
    "get_fleet_vector_scheduler",
    "has_vector_scheduler",
]
