"""Scenario library: parameterised workload families as trace records.

Every family is a pure function producing the trace-record schema
(docs/trace-format.md), so scenarios and recorded production days are
the same thing to the simulator — both flow through
``workload_from_trace_records`` (one lane) or
``workload_batch_from_traces`` (a fleet) and run on every compiled
path. See docs/scenarios.md for each family's story and knobs.

Three layers:

* family functions (``diurnal``/``bursty``/``heavy_tail``/
  ``priority_skew``/``spot_churn``/``retry_storm``) — one trace each;
* ``scenario_lane_batch`` — n_lanes independent draws of one family
  (per-lane seeds), the fleet Monte-Carlo shape;
* ``scenario_fleet`` — the same, ingested: returns ``(workloads,
  params)`` ready for ``fleet_run(params, workloads=workloads)``.

>>> from repro.core import SimParams
>>> from repro.core.scenarios import get_scenario, list_scenarios
>>> list_scenarios()
['bursty', 'diurnal', 'heavy_tail', 'priority_skew', 'retry_storm', 'spot_churn']
>>> fn = get_scenario("diurnal")
>>> recs = fn(SimParams(duration=0.5), seed=0)
>>> len(recs) > 0
True
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

from ..params import SimParams
from ..state import Workload
from ..workload import workload_batch_from_traces
from .families import (
    bursty,
    diurnal,
    heavy_tail,
    priority_skew,
    retry_storm,
    retry_storm_params,
    spot_churn,
    spot_churn_params,
)

ScenarioFn = Callable[..., "list[dict[str, Any]]"]

SCENARIOS: dict[str, ScenarioFn] = {
    "diurnal": diurnal,
    "bursty": bursty,
    "heavy_tail": heavy_tail,
    "priority_skew": priority_skew,
    "retry_storm": retry_storm,
    "spot_churn": spot_churn,
}


def list_scenarios() -> list[str]:
    return sorted(SCENARIOS)


def get_scenario(name: str) -> ScenarioFn:
    key = name.replace("-", "_").lower()
    if key not in SCENARIOS:
        raise KeyError(
            f"unknown scenario {name!r}; available: {list_scenarios()}"
        )
    return SCENARIOS[key]


def scenario_lane_batch(
    name: str | ScenarioFn,
    params: SimParams,
    n_lanes: int,
    *,
    seed: int = 0,
    **knobs: Any,
) -> list[list[dict[str, Any]]]:
    """n_lanes independent draws of one family: lane i uses seed+i.

    The result is a plain list of record lists — compose lanes from
    different families freely before ingesting (the trace-replay
    example mixes all four into one fleet).

    >>> from repro.core import SimParams
    >>> lanes = scenario_lane_batch("bursty", SimParams(duration=0.5), 3)
    >>> len(lanes)
    3
    >>> lanes[0] != lanes[1]  # per-lane seeds -> independent draws
    True
    """
    fn = get_scenario(name) if isinstance(name, str) else name
    return [fn(params, seed=seed + lane, **knobs) for lane in range(n_lanes)]


def scenario_fleet(
    name: str | ScenarioFn | Sequence[str],
    params: SimParams,
    n_lanes: int,
    *,
    seed: int = 0,
    **knobs: Any,
) -> tuple[Workload, SimParams]:
    """One family (or a round-robin mix of families) as an ingested
    fleet batch: returns ``(workloads, params)`` for ``fleet_run(params,
    workloads=workloads)``. With a list of names, lane i draws family
    ``i % len(names)`` — a mixed fleet in one call. Capacity knobs at 0
    are derived from the batch (see ``workload_batch_from_traces``).

    >>> from repro.core import SimParams
    >>> p = SimParams(duration=0.5, max_pipelines=0,
    ...               max_ops_per_pipeline=0)
    >>> wls, p2 = scenario_fleet(["diurnal", "bursty"], p, 4)
    >>> int(wls.arrival.shape[0]), p2.max_pipelines > 0
    (4, True)
    """
    if isinstance(name, (list, tuple)):
        if not name:
            raise ValueError(
                "scenario_fleet needs at least one family name; "
                f"available: {list_scenarios()}"
            )
        lanes = [
            get_scenario(name[lane % len(name)])(
                params, seed=seed + lane, **knobs
            )
            for lane in range(n_lanes)
        ]
    else:
        lanes = scenario_lane_batch(
            name, params, n_lanes, seed=seed, **knobs
        )
    return workload_batch_from_traces(lanes, params)


__all__ = [
    "SCENARIOS",
    "list_scenarios",
    "get_scenario",
    "scenario_lane_batch",
    "scenario_fleet",
    "diurnal",
    "bursty",
    "heavy_tail",
    "priority_skew",
    "retry_storm",
    "retry_storm_params",
    "spot_churn",
    "spot_churn_params",
]
