"""The scenario families: named workload generators beyond the paper.

The paper's generator (§3.2.1) is a single open-loop process —
exponential inter-arrivals at one fixed rate, one priority mix, one
runtime distribution. Real lakehouse days are not like that: load
breathes with the clock, CI pushes arrive in bursts, a handful of
elephant pipelines dominate the runtime mass, and the query/pipeline
mix shifts with who is online. Each family below models ONE of those
departures as a pure, deterministic function

    family(params, *, seed=0, **knobs) -> list[trace records]

producing the JSON trace schema of docs/trace-format.md — so a scenario
is just a synthetic *recorded day*: it flows through the same ingestion
path as a real production trace (``workload_from_trace_records`` /
``workload_batch_from_traces``) and runs on every compiled path
(``run``, ``fleet_run``, ``shard="auto"``, lane binning).

Determinism: everything is drawn from one ``numpy.random.default_rng
(seed)`` stream; the same ``(params, seed, knobs)`` triple always
produces the identical record list. Arrival counts are truncated at
``params.max_pipelines`` when it is positive (the arrival-table
capacity, mirroring the seed generator's fixed table); set it to 0 and
ingest with ``workload_batch_from_traces`` to derive capacity from the
scenario instead.
"""
from __future__ import annotations

from typing import Any, Callable

import numpy as np

from ..params import SimParams
from ..types import TICKS_PER_SECOND

_PRIORITY_NAMES = ("BATCH", "QUERY", "INTERACTIVE")


def _base_rate_per_s(params: SimParams) -> float:
    """The paper generator's mean arrival rate, in pipelines/second."""
    return TICKS_PER_SECOND / params.waiting_ticks_mean


def _max_arrivals(params: SimParams) -> int:
    return params.max_pipelines if params.max_pipelines > 0 else 1 << 20


def _prio_scale(params: SimParams, prio: int) -> float:
    return (1.0, params.query_scale, params.interactive_scale)[prio]


def _draw_priority(rng: np.random.Generator, probs) -> int:
    p = np.asarray(probs, np.float64)
    return int(rng.choice(3, p=p / p.sum()))


def _draw_ops(
    rng: np.random.Generator,
    params: SimParams,
    prio: int,
    *,
    n_ops: int | None = None,
    base_s_mean: float | None = None,
    base_factor: float = 1.0,
    out_factor: float = 1.0,
) -> list[dict[str, Any]]:
    """Draw one pipeline's operator list, mirroring the seed generator's
    distributions (lognormal sizes, chain/join DAG shape, categorical
    CPU-scaling alpha, priority-dependent scale-down)."""
    if n_ops is None:
        lam = max(params.mean_ops_per_pipeline - 1.0, 0.0)
        n_ops = 1 + int(rng.poisson(lam))
    if params.max_ops_per_pipeline > 0:
        n_ops = min(n_ops, params.max_ops_per_pipeline)
    scale = _prio_scale(params, prio)
    base_mean = (
        params.op_base_seconds_mean if base_s_mean is None else base_s_mean
    )
    aprobs = np.asarray(params.alpha_probs, np.float64)
    aprobs = aprobs / aprobs.sum()
    level = 0
    ops = []
    for j in range(n_ops):
        if j > 0 and rng.random() < params.chain_prob:
            level += 1
        base_s = (
            float(np.exp(rng.normal() * params.op_base_seconds_sigma))
            * base_mean * scale * base_factor
        )
        ops.append(
            {
                "ram_gb": max(
                    float(np.exp(rng.normal() * params.op_ram_gb_sigma))
                    * params.op_ram_gb_mean * scale,
                    0.05,
                ),
                "base_s": max(base_s, 1.0 / TICKS_PER_SECOND),
                "alpha": float(
                    np.asarray(params.alpha_choices)[rng.choice(
                        len(aprobs), p=aprobs
                    )]
                ),
                "level": level,
                "out_gb": (
                    float(np.exp(rng.normal() * params.op_out_gb_sigma))
                    * params.op_out_gb_mean * scale * out_factor
                ),
            }
        )
    return ops


def _records(
    rng: np.random.Generator,
    params: SimParams,
    arrivals_s: list[float],
    probs=None,
    **op_kw,
) -> list[dict[str, Any]]:
    probs = params.priority_probs if probs is None else probs
    records = []
    for t in arrivals_s:
        prio = _draw_priority(rng, probs)
        records.append(
            {
                "arrival_s": float(t),
                "priority": _PRIORITY_NAMES[prio],
                "ops": _draw_ops(rng, params, prio, **op_kw),
            }
        )
    return records


def _thinned_arrivals(
    rng: np.random.Generator,
    rate_fn: Callable[[float], float],
    lam_max: float,
    horizon_s: float,
    max_n: int,
) -> list[float]:
    """Non-homogeneous Poisson arrivals by thinning: candidates at the
    envelope rate ``lam_max``, kept with probability rate(t)/lam_max."""
    out: list[float] = []
    t = 0.0
    while len(out) < max_n:
        t += rng.exponential(1.0 / lam_max)
        if t >= horizon_s:
            break
        if rng.random() * lam_max <= rate_fn(t):
            out.append(t)
    return out


# ---------------------------------------------------------------------------
# The families.
# ---------------------------------------------------------------------------
def diurnal(
    params: SimParams,
    *,
    seed: int = 0,
    amplitude: float = 0.75,
    period_s: float | None = None,
    phase: float = -np.pi / 2,
) -> list[dict[str, Any]]:
    """Sinusoidal arrival rate — the compressed day/night cycle.

    rate(t) = base * (1 + amplitude * sin(2*pi*t/period + phase)), a
    non-homogeneous Poisson process sampled by thinning. The default
    phase starts the trace in the trough (night) so the ramp into the
    peak stresses admission policies mid-trace. ``period_s`` defaults
    to the whole horizon: one full cycle per trace.

    >>> from repro.core import SimParams
    >>> recs = diurnal(SimParams(duration=0.5), seed=0)
    >>> recs == diurnal(SimParams(duration=0.5), seed=0)  # deterministic
    True
    >>> sorted(recs[0])
    ['arrival_s', 'ops', 'priority']
    """
    rng = np.random.default_rng(seed)
    base = _base_rate_per_s(params)
    period = params.duration if period_s is None else period_s
    amp = float(np.clip(amplitude, 0.0, 1.0))

    def rate(t: float) -> float:
        return base * (1.0 + amp * np.sin(2.0 * np.pi * t / period + phase))

    arrivals = _thinned_arrivals(
        rng, rate, base * (1.0 + amp), params.duration, _max_arrivals(params)
    )
    return _records(rng, params, arrivals)


def bursty(
    params: SimParams,
    *,
    seed: int = 0,
    burst_factor: float = 6.0,
    duty_cycle: float = 0.2,
    mean_cycle_s: float | None = None,
) -> list[dict[str, Any]]:
    """Markov-modulated Poisson on/off bursts — CI pushes, backfills.

    A two-state MMPP: ON periods arrive at ``burst_factor`` times the
    base rate, OFF periods at the complementary rate that keeps the
    long-run average at the base rate (clipped at 0 when
    ``burst_factor >= 1/duty_cycle``). Sojourns are exponential with
    means ``duty_cycle * mean_cycle_s`` (ON) and the rest (OFF);
    ``mean_cycle_s`` defaults to a quarter of the horizon. The result
    is the clumpy arrival tape that makes event-density lane binning
    and preemption policies earn their keep.

    >>> from repro.core import SimParams
    >>> recs = bursty(SimParams(duration=0.5), seed=1)
    >>> recs == bursty(SimParams(duration=0.5), seed=1)
    True
    >>> all(r["arrival_s"] < 0.5 for r in recs)
    True
    """
    rng = np.random.default_rng(seed)
    base = _base_rate_per_s(params)
    duty = float(np.clip(duty_cycle, 1e-3, 1.0 - 1e-3))
    cycle = (
        params.duration / 4.0 if mean_cycle_s is None else float(mean_cycle_s)
    )
    on_rate = base * burst_factor
    off_rate = max(base * (1.0 - duty * burst_factor) / (1.0 - duty), 0.0)
    on_mean, off_mean = duty * cycle, (1.0 - duty) * cycle

    arrivals: list[float] = []
    t, on = 0.0, False  # start quiet, like the end of a night
    max_n = _max_arrivals(params)
    while t < params.duration and len(arrivals) < max_n:
        sojourn = rng.exponential(on_mean if on else off_mean)
        t_end = min(t + sojourn, params.duration)
        rate = on_rate if on else off_rate
        if rate > 0.0:
            u = t
            while len(arrivals) < max_n:
                u += rng.exponential(1.0 / rate)
                if u >= t_end:
                    break
                arrivals.append(u)
        t, on = t_end, not on
    return _records(rng, params, arrivals)


def heavy_tail(
    params: SimParams,
    *,
    seed: int = 0,
    tail_index: float = 1.3,
    body_scale: float = 0.3,
    out_runtime_exp: float = 0.5,
) -> list[dict[str, Any]]:
    """Pareto runtime mix — a few elephant pipelines own the runtime mass.

    Arrivals are plain Poisson at the base rate, but every pipeline
    draws a Pareto(``tail_index``) runtime factor: most pipelines run
    at ``body_scale`` of the configured mean, while the power-law tail
    produces rare 10-1000x elephants (the smaller the index, the
    heavier the tail). Each pipeline's intermediate dataset sizes scale
    with the factor**``out_runtime_exp`` — long pipelines emit large
    intermediates, so the data plane and SJF-style policies see the
    skew too.

    >>> from repro.core import SimParams
    >>> recs = heavy_tail(SimParams(duration=0.5), seed=2)
    >>> recs == heavy_tail(SimParams(duration=0.5), seed=2)
    True
    >>> len(recs) > 0
    True
    """
    rng = np.random.default_rng(seed)
    base = _base_rate_per_s(params)
    arrivals = _thinned_arrivals(
        rng, lambda t: base, base, params.duration, _max_arrivals(params)
    )
    records = []
    for t in arrivals:
        prio = _draw_priority(rng, params.priority_probs)
        factor = body_scale * (1.0 + rng.pareto(tail_index))
        records.append(
            {
                "arrival_s": float(t),
                "priority": _PRIORITY_NAMES[prio],
                "ops": _draw_ops(
                    rng, params, prio,
                    base_factor=factor,
                    out_factor=factor ** out_runtime_exp,
                ),
            }
        )
    return records


def priority_skew(
    params: SimParams,
    *,
    seed: int = 0,
    interactive_frac: float = 0.55,
    query_frac: float = 0.30,
    batch_ops_factor: float = 2.0,
) -> list[dict[str, Any]]:
    """Query-vs-pipeline mix inversion — the analyst-hours workload.

    The paper's default mix is 60 % BATCH; here the default is 55 %
    INTERACTIVE + 30 % QUERY with only the remainder BATCH — but each
    BATCH pipeline is ``batch_ops_factor`` times longer (more ops) than
    the configured mean, so a small number of heavy background
    pipelines run under a storm of short interactive queries. This is
    the regime where preemption and priority-pool isolation separate
    the policies (paper §4.1.2).

    >>> from repro.core import SimParams
    >>> recs = priority_skew(SimParams(duration=0.5), seed=3)
    >>> recs == priority_skew(SimParams(duration=0.5), seed=3)
    True
    >>> {r["priority"] for r in recs} <= {"BATCH", "QUERY", "INTERACTIVE"}
    True
    """
    rng = np.random.default_rng(seed)
    if interactive_frac + query_frac >= 1.0:
        raise ValueError("interactive_frac + query_frac must be < 1")
    probs = (
        1.0 - interactive_frac - query_frac, query_frac, interactive_frac
    )
    base = _base_rate_per_s(params)
    arrivals = _thinned_arrivals(
        rng, lambda t: base, base, params.duration, _max_arrivals(params)
    )
    records = []
    lam = max(params.mean_ops_per_pipeline - 1.0, 0.0)
    for t in arrivals:
        prio = _draw_priority(rng, probs)
        n_ops = None
        if prio == 0:  # the rare, heavy background pipelines
            n_ops = 1 + int(rng.poisson(lam * batch_ops_factor))
        records.append(
            {
                "arrival_s": float(t),
                "priority": _PRIORITY_NAMES[prio],
                "ops": _draw_ops(rng, params, prio, n_ops=n_ops),
            }
        )
    return records


def spot_churn(
    params: SimParams,
    *,
    seed: int = 0,
    batch_frac: float = 0.8,
    runtime_factor: float = 3.0,
) -> list[dict[str, Any]]:
    """Spot-instance fleet day — restartable batch work under churn.

    The arrival tape itself is calm: steady Poisson arrivals at the base
    rate, ``batch_frac`` of them BATCH, each running
    ``runtime_factor`` times the configured mean so every pipeline is
    long enough that a mid-flight kill actually costs something. The
    churn comes from the chaos layer (docs/faults.md): this family is
    meant to run with the fault knobs on — pair it with
    :func:`spot_churn_params`, which turns on crash/outage injection and
    a retry budget tuned so the workload survives on retries rather
    than failing back to the user. Scheduler-resilience comparisons
    (benchmarks/scheduler_comparison.py ``--resilience``) measure
    goodput and wasted work per policy on exactly this pairing.

    >>> from repro.core import SimParams
    >>> recs = spot_churn(SimParams(duration=0.5), seed=4)
    >>> recs == spot_churn(SimParams(duration=0.5), seed=4)
    True
    >>> sum(r["priority"] == "BATCH" for r in recs) > len(recs) // 2
    True
    """
    rng = np.random.default_rng(seed)
    frac = float(np.clip(batch_frac, 0.0, 1.0))
    probs = (frac, (1.0 - frac) * 0.5, (1.0 - frac) * 0.5)
    base = _base_rate_per_s(params)
    arrivals = _thinned_arrivals(
        rng, lambda t: base, base, params.duration, _max_arrivals(params)
    )
    return _records(rng, params, arrivals, probs=probs,
                    base_factor=runtime_factor)


def spot_churn_params(
    params: SimParams,
    *,
    crash_mtbf_s: float = 0.05,
    outage_mtbf_s: float = 0.2,
    outage_duration_s: float = 0.02,
    max_retries: int = 3,
    base_backoff_s: float = 0.001,
) -> SimParams:
    """The chaos-knob half of the ``spot_churn`` scenario.

    Returns ``params`` with crash/outage injection on at the given MTBFs
    (seconds of simulated time, converted to ticks) and an exponential
    retry budget sized so transient kills are absorbed by re-queues.
    ``max_retries=0`` leaves every faulted pipeline FAILED — the CI
    chaos smoke asserts both sides of that contract.
    """
    return params.replace(
        crash_mtbf_ticks=crash_mtbf_s * TICKS_PER_SECOND,
        outage_mtbf_ticks=outage_mtbf_s * TICKS_PER_SECOND,
        outage_duration_ticks=outage_duration_s * TICKS_PER_SECOND,
        max_retries=max_retries,
        base_backoff_ticks=max(int(base_backoff_s * TICKS_PER_SECOND), 1),
    )


def retry_storm(
    params: SimParams,
    *,
    seed: int = 0,
    surge_factor: float = 4.0,
    surge_start_frac: float = 0.25,
    surge_duration_frac: float = 0.35,
    interactive_frac: float = 0.5,
) -> list[dict[str, Any]]:
    """Overload surge — the arrival tape half of a retry storm.

    Steady Poisson arrivals at the base rate, except for a surge window
    (``surge_start_frac`` to ``surge_start_frac + surge_duration_frac``
    of the horizon) where the rate jumps to ``surge_factor`` times the
    base — an incident tape: a launch, a backfill, a thundering herd
    after an outage. Half the traffic is INTERACTIVE by default, so
    admission policies have a latency-sensitive class to protect. The
    storm itself comes from the closed loop (docs/closed-loop.md): pair
    this family with :func:`retry_storm_params`, which turns on
    client-side retries (the amplification mechanism) plus a pool
    outage mid-surge, and choose an admission policy to see whether the
    backlog drains or goes metastable. The CI overload smoke
    (benchmarks/run.py ``--overload-smoke``) asserts both outcomes.

    >>> from repro.core import SimParams
    >>> recs = retry_storm(SimParams(duration=0.5), seed=5)
    >>> recs == retry_storm(SimParams(duration=0.5), seed=5)
    True
    >>> all(0.0 <= r["arrival_s"] < 0.5 for r in recs)
    True
    """
    rng = np.random.default_rng(seed)
    frac = float(np.clip(interactive_frac, 0.0, 1.0))
    probs = ((1.0 - frac) * 0.6, (1.0 - frac) * 0.4, frac)
    base = _base_rate_per_s(params)
    surge = max(float(surge_factor), 1.0)
    t0 = params.duration * float(np.clip(surge_start_frac, 0.0, 1.0))
    t1 = min(
        t0 + params.duration * max(float(surge_duration_frac), 0.0),
        params.duration,
    )

    def rate(t: float) -> float:
        return base * surge if t0 <= t < t1 else base

    arrivals = _thinned_arrivals(
        rng, rate, base * surge, params.duration, _max_arrivals(params)
    )
    return _records(rng, params, arrivals, probs=probs)


def retry_storm_params(
    params: SimParams,
    *,
    outage_mtbf_s: float = 0.3,
    outage_duration_s: float = 0.05,
    max_retries: int = 2,
    base_backoff_s: float = 0.001,
    client_max_retries: int = 4,
    client_backoff_s: float = 0.002,
    client_max_inflight: int = 0,
    client_think_s: float = 0.002,
    admission_policy: str = "admit_all",
    admit_queue_limit: int = 0,
    metastable_window_s: float = 0.0,
) -> SimParams:
    """The closed-loop-knob half of the ``retry_storm`` scenario.

    Returns ``params`` with client-side retries on (rejected offers come
    back after a capped exponential backoff — the amplification
    mechanism), a pool-outage schedule that strikes mid-surge, a modest
    server-side retry budget for the fault kills, and the chosen
    admission policy. The default ``admit_all`` is the control arm: the
    storm hits the scheduler unfiltered. Swap in ``queue_threshold``
    (with ``admit_queue_limit``) or any registered policy
    (docs/closed-loop.md) for the treatment arm. Window 0 means
    metastability is judged "by the end of the run".
    """
    return params.replace(
        outage_mtbf_ticks=outage_mtbf_s * TICKS_PER_SECOND,
        outage_duration_ticks=outage_duration_s * TICKS_PER_SECOND,
        max_retries=max_retries,
        base_backoff_ticks=max(int(base_backoff_s * TICKS_PER_SECOND), 1),
        client_max_retries=client_max_retries,
        client_backoff_ticks=max(
            int(client_backoff_s * TICKS_PER_SECOND), 1
        ),
        client_max_inflight=client_max_inflight,
        client_think_ticks=max(int(client_think_s * TICKS_PER_SECOND), 1)
        if client_max_inflight > 0
        else 0,
        admission_policy=admission_policy,
        admit_queue_limit=admit_queue_limit,
        metastable_window_ticks=int(metastable_window_s * TICKS_PER_SECOND),
    )


__all__ = [
    "diurnal", "bursty", "heavy_tail", "priority_skew",
    "spot_churn", "spot_churn_params",
    "retry_storm", "retry_storm_params",
]
