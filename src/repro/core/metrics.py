"""Derived metrics ("execution statistics" consumed by visualisers and
downstream applications, paper Fig. 2)."""
from __future__ import annotations

import numpy as np

from .params import SimParams
from .state import INF_TICK, SimState, Workload
from .types import PipeStatus, Priority, TICKS_PER_SECOND


def summarize(
    state: SimState,
    wl: Workload,
    params: SimParams,
    trace=None,
) -> dict:
    """Execution statistics of one finished simulation.

    ``trace`` (a :class:`repro.core.telemetry.TraceEvents`, as produced
    by ``run(..., trace=True)``) is optional; when given, the summary
    also reports ``trace_enabled`` and the recorder's overflow counter
    ``events_dropped``.
    """
    status = np.asarray(state.pipe_status)
    arrival = np.asarray(wl.arrival)
    completion = np.asarray(state.pipe_completion)
    prio = np.asarray(wl.prio)

    submitted = int(np.sum(arrival < INF_TICK))
    done = status == int(PipeStatus.DONE)
    failed = status == int(PipeStatus.FAILED)
    lat_ticks = np.where(done, completion - arrival, 0)
    lat_s = lat_ticks[done] / TICKS_PER_SECOND

    offered_prio = np.asarray(state.offered_prio)
    admitted_prio = np.asarray(state.admitted_prio)
    per_prio = {}
    for p in Priority:
        sel = done & (prio == int(p))
        sel_lat_s = (completion - arrival)[sel] / TICKS_PER_SECOND
        # every bucket statistic is guarded against an empty bucket (a
        # priority class with no finished — or no offered — pipelines
        # reports NaN, never a divide-by-zero or an empty-percentile)
        per_prio[p.name.lower()] = {
            "done": int(np.sum(sel)),
            "submitted": int(np.sum((arrival < INF_TICK) & (prio == int(p)))),
            "mean_latency_s": float(np.mean(sel_lat_s))
            if sel_lat_s.size
            else float("nan"),
            "p99_latency_s": float(np.percentile(sel_lat_s, 99))
            if sel_lat_s.size
            else float("nan"),
            # per-tenant admitted fraction (closed loop; NaN when the
            # class was never offered, e.g. closed loop off)
            "admitted_fraction": float(admitted_prio[int(p)])
            / float(offered_prio[int(p)])
            if offered_prio[int(p)] > 0
            else float("nan"),
        }

    dur_s = params.duration
    cap_cpu_s = float(np.sum(np.asarray(state.pool_cpu_cap))) * dur_s
    cap_ram_s = float(np.sum(np.asarray(state.pool_ram_cap))) * dur_s
    util_cpu = float(np.sum(np.asarray(state.util_cpu_s)))
    util_ram = float(np.sum(np.asarray(state.util_ram_s)))

    out = {
        "submitted": submitted,
        "done": int(np.sum(done)),
        "failed": int(np.sum(failed)),
        "in_flight": int(
            np.sum(
                (arrival < INF_TICK)
                & ~done
                & ~failed
                & (status != int(PipeStatus.EMPTY))
            )
        ),
        "throughput_per_s": float(np.sum(done)) / dur_s,
        "mean_latency_s": float(np.mean(lat_s)) if lat_s.size else float("nan"),
        "p50_latency_s": float(np.percentile(lat_s, 50)) if lat_s.size else float("nan"),
        "p99_latency_s": float(np.percentile(lat_s, 99)) if lat_s.size else float("nan"),
        "oom_events": int(state.oom_events),
        "preempt_events": int(state.preempt_events),
        "cpu_utilization": util_cpu / cap_cpu_s if cap_cpu_s else 0.0,
        "ram_utilization": util_ram / cap_ram_s if cap_ram_s else 0.0,
        "cost_dollars": float(state.cost_dollars),
        "per_priority": per_prio,
        # ---- data plane ---------------------------------------------------
        "cache_hit_gb": float(state.cache_hit_gb),
        "bytes_moved_gb": float(state.bytes_moved_gb),
        "cache_hit_rate": _cache_hit_rate(state),
        "cache_hits": int(state.cache_hits),
        "cache_lookups": int(state.cache_lookups),
        "cache_resident_gb": float(np.sum(np.asarray(state.pool_cache_used))),
        "cold_starts": int(state.cold_starts),
        "warm_starts": int(state.warm_starts),
        "cold_start_ticks": int(state.cold_start_tick_total),
        "cold_start_s": float(state.cold_start_tick_total) / TICKS_PER_SECOND,
        # ---- chaos layer (fault injection + retry, docs/faults.md) --------
        "faults_injected": int(state.crash_events) + int(state.outage_events),
        "crash_events": int(state.crash_events),
        "outage_events": int(state.outage_events),
        "fault_kills": int(state.fault_kills),
        "timeouts": int(state.timeout_events),
        "retries": int(state.retry_events),
        "wasted_work_s": float(state.wasted_ticks) / TICKS_PER_SECOND,
        "pool_down_s": float(state.pool_down_s),
        "mttr_s": float(state.pool_down_s) / int(state.outage_events)
        if int(state.outage_events) > 0
        else float("nan"),
        # goodput: completions that survived to DONE per simulated second
        # (same as throughput, named for resilience comparisons where the
        # interesting delta is vs. the faults-off run)
        "goodput_per_s": float(np.sum(done)) / dur_s,
        "slo_attainment": _slo_attainment(
            params, prio, arrival, completion, done
        ),
    }
    out.update(
        _closed_loop_stats(state, params, float(np.sum(done)), dur_s)
    )
    # ---- fairness (Jain's index; docs/closed-loop.md) ---------------------
    # over per-pipeline latency of finished pipelines (1.0 = perfectly
    # even service), and over per-tenant admitted fractions (closed loop)
    out["fairness_jain_latency"] = _jain(lat_s)
    out["fairness_jain_admission"] = _jain(
        np.asarray(state.admitted_prio)[offered_prio > 0]
        / np.maximum(offered_prio[offered_prio > 0], 1)
    )
    if trace is not None:
        out["trace_enabled"] = True
        out["events_dropped"] = int(trace.events_dropped)
    return out


def _closed_loop_stats(
    state: SimState, params: SimParams, n_done: float, dur_s: float
) -> dict:
    """Overload / graceful-degradation statistics (docs/closed-loop.md).

    With the closed loop off every counter is zero and the ratios are
    NaN — the keys are always present so summaries stay uniform.

    * ``retry_amplification`` — offers presented per distinct pipeline
      offered; 1.0 means no client re-offers, >1 is the retry storm.
    * ``time_to_drain_s`` — seconds from the last fault until the
      backlog first returned to its pre-fault level (NaN: no fault, or
      never drained).
    * ``metastable`` — the backlog had NOT recovered within
      ``params.metastable_window_ticks`` after the last fault (window 0
      = "by the end of the run"): the signature of a retry storm that
      outlives its trigger.
    """
    offered = int(state.offered_total)
    unique = int(state.offered_unique)
    admitted = int(state.admitted_total)
    last_fault = int(state.last_fault_tick)
    drain = int(state.drain_tick)
    had_fault = last_fault < int(INF_TICK)
    drained = drain < int(INF_TICK)
    window = params.metastable_window_ticks
    if not had_fault:
        metastable = False
    elif window > 0:
        metastable = (not drained) or (drain - last_fault > window)
    else:
        metastable = not drained
    return {
        "offered": offered,
        "admitted": admitted,
        "shed": int(state.shed_total),
        "deferred": int(state.deferred_total),
        "client_retries": int(state.client_retry_events),
        "offered_load_per_s": offered / dur_s,
        "admitted_fraction": admitted / offered if offered else float("nan"),
        "retry_amplification": offered / unique if unique else float("nan"),
        "time_to_drain_s": (drain - last_fault) / TICKS_PER_SECOND
        if had_fault and drained
        else float("nan"),
        "metastable": bool(metastable),
    }


def _jain(x) -> float:
    """Jain's fairness index (Σx)²/(n·Σx²) over nonnegative shares —
    1.0 = perfectly even, →1/n as one element dominates. NaN for an
    empty or all-zero vector."""
    x = np.asarray(x, np.float64)
    x = x[np.isfinite(x)]
    if x.size == 0:
        return float("nan")
    s2 = float(np.sum(x * x))
    if s2 <= 0:
        return float("nan")
    return float(np.sum(x)) ** 2 / (x.size * s2)


def _slo_attainment(params, prio, arrival, completion, done) -> dict:
    """Per-priority SLO attainment: the fraction of *submitted* pipelines
    of each class that completed within ``params.slo_latency_s`` —
    pipelines that failed, timed out of their retry budget, or never
    finished count against the SLO. NaN for classes without a target
    (``slo_latency_s[p] == 0``) or with no submissions."""
    out = {}
    lat_s = (completion - arrival) / TICKS_PER_SECOND
    for p in Priority:
        name = p.name.lower()
        target = (
            params.slo_latency_s[int(p)]
            if int(p) < len(params.slo_latency_s)
            else 0.0
        )
        sel = (arrival < INF_TICK) & (prio == int(p))
        n = int(np.sum(sel))
        if target <= 0 or n == 0:
            out[name] = float("nan")
            continue
        ok = sel & done & (lat_s <= target)
        out[name] = float(np.sum(ok)) / n
    return out


def _cache_hit_rate(state: SimState) -> float:
    """Byte-level hit rate of the zero-copy caches (0.0 when no lookups)."""
    hit = float(state.cache_hit_gb)
    moved = float(state.bytes_moved_gb)
    total = hit + moved
    return hit / total if total > 0 else 0.0


def fleet_lane_stats(
    states: SimState, params: SimParams, arrival=None
) -> dict[str, np.ndarray]:
    """Per-lane fleet statistics as ``[F]`` numpy arrays (the policy
    search objectives; ``repro.search.grid`` consumes this).

    ``arrival`` is the batch's ``[F, MP]`` arrival table, copied to host
    BEFORE the ``fleet_run`` call — the engine donates (consumes) the
    workload batch, so latency can't be derived from it afterwards.
    Without it, latency columns are NaN.

    Empty lanes (nothing finished — shed, overloaded, or padding) report
    NaN latency, never a divide-by-zero or an empty-mean warning; the
    NaN rides into Pareto ranking as +inf (worst), per the
    ``repro.search.pareto`` contract.

    The ``censored_*`` latency columns are the search objectives: every
    ARRIVED pipeline contributes — completed ones their true latency,
    unfinished ones the lower bound ``horizon - arrival`` (a censored
    observation). Completed-only means reward a policy for ignoring
    work (serve two easy pipelines fast, strand the queue, report a
    tiny "mean latency"); censoring makes stranded work visible, so an
    admission-starved policy can't dominate a search grid.
    """
    status = np.asarray(states.pipe_status)  # [F, MP]
    completion = np.asarray(states.pipe_completion, np.float64)
    done_mask = status == int(PipeStatus.DONE)
    done = done_mask.sum(axis=1)  # [F]
    dur_s = params.duration

    F = status.shape[0]
    mean_lat = np.full((F,), np.nan)
    p99_lat = np.full((F,), np.nan)
    cens_mean = np.full((F,), np.nan)
    cens_p99 = np.full((F,), np.nan)
    if arrival is not None:
        arrival = np.asarray(arrival, np.float64)
        arrived = arrival < float(INF_TICK)  # [F, MP] real (in-horizon) slots
        horizon = float(params.horizon_ticks)
        lat_s = (completion - arrival) / TICKS_PER_SECOND
        cens_s = (
            np.where(done_mask, completion, horizon) - arrival
        ) / TICKS_PER_SECOND
        for i in range(F):
            lane = lat_s[i][done_mask[i]]
            if lane.size:
                mean_lat[i] = lane.mean()
                p99_lat[i] = np.percentile(lane, 99)
            clane = cens_s[i][arrived[i]]
            if clane.size:
                cens_mean[i] = clane.mean()
                cens_p99[i] = np.percentile(clane, 99)

    cap_cpu_s = np.sum(np.asarray(states.pool_cpu_cap), axis=-1) * dur_s
    util_cpu = np.sum(np.asarray(states.util_cpu_s), axis=-1)
    return {
        "done": done.astype(np.int64),
        "failed": (status == int(PipeStatus.FAILED)).sum(axis=1),
        "throughput_per_s": done / dur_s,
        "mean_latency_s": mean_lat,
        "p99_latency_s": p99_lat,
        "censored_mean_latency_s": cens_mean,
        "censored_p99_latency_s": cens_p99,
        "cpu_utilization": np.where(
            cap_cpu_s > 0, util_cpu / np.maximum(cap_cpu_s, 1e-12), 0.0
        ),
        "cost_dollars": np.asarray(states.cost_dollars, np.float64),
        "oom_events": np.asarray(states.oom_events, np.int64),
        "preempt_events": np.asarray(states.preempt_events, np.int64),
    }


def completion_table(state: SimState, wl: Workload) -> np.ndarray:
    """[MP, 4] array: (arrival, completion, status, priority) for analysis."""
    return np.stack(
        [
            np.asarray(wl.arrival),
            np.asarray(state.pipe_completion),
            np.asarray(state.pipe_status),
            np.asarray(wl.prio),
        ],
        axis=1,
    )


__all__ = ["summarize", "completion_table", "fleet_lane_stats"]
