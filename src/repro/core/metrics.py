"""Derived metrics ("execution statistics" consumed by visualisers and
downstream applications, paper Fig. 2)."""
from __future__ import annotations

import numpy as np

from .params import SimParams
from .state import INF_TICK, SimState, Workload
from .types import PipeStatus, Priority, TICKS_PER_SECOND


def summarize(
    state: SimState,
    wl: Workload,
    params: SimParams,
    trace=None,
) -> dict:
    """Execution statistics of one finished simulation.

    ``trace`` (a :class:`repro.core.telemetry.TraceEvents`, as produced
    by ``run(..., trace=True)``) is optional; when given, the summary
    also reports ``trace_enabled`` and the recorder's overflow counter
    ``events_dropped``.
    """
    status = np.asarray(state.pipe_status)
    arrival = np.asarray(wl.arrival)
    completion = np.asarray(state.pipe_completion)
    prio = np.asarray(wl.prio)

    submitted = int(np.sum(arrival < INF_TICK))
    done = status == int(PipeStatus.DONE)
    failed = status == int(PipeStatus.FAILED)
    lat_ticks = np.where(done, completion - arrival, 0)
    lat_s = lat_ticks[done] / TICKS_PER_SECOND

    per_prio = {}
    for p in Priority:
        sel = done & (prio == int(p))
        sel_lat_s = (completion - arrival)[sel] / TICKS_PER_SECOND
        per_prio[p.name.lower()] = {
            "done": int(np.sum(sel)),
            "submitted": int(np.sum((arrival < INF_TICK) & (prio == int(p)))),
            "mean_latency_s": float(np.mean(sel_lat_s))
            if np.any(sel)
            else float("nan"),
            "p99_latency_s": float(np.percentile(sel_lat_s, 99))
            if np.any(sel)
            else float("nan"),
        }

    dur_s = params.duration
    cap_cpu_s = float(np.sum(np.asarray(state.pool_cpu_cap))) * dur_s
    cap_ram_s = float(np.sum(np.asarray(state.pool_ram_cap))) * dur_s
    util_cpu = float(np.sum(np.asarray(state.util_cpu_s)))
    util_ram = float(np.sum(np.asarray(state.util_ram_s)))

    out = {
        "submitted": submitted,
        "done": int(np.sum(done)),
        "failed": int(np.sum(failed)),
        "in_flight": int(
            np.sum(
                (arrival < INF_TICK)
                & ~done
                & ~failed
                & (status != int(PipeStatus.EMPTY))
            )
        ),
        "throughput_per_s": float(np.sum(done)) / dur_s,
        "mean_latency_s": float(np.mean(lat_s)) if lat_s.size else float("nan"),
        "p50_latency_s": float(np.percentile(lat_s, 50)) if lat_s.size else float("nan"),
        "p99_latency_s": float(np.percentile(lat_s, 99)) if lat_s.size else float("nan"),
        "oom_events": int(state.oom_events),
        "preempt_events": int(state.preempt_events),
        "cpu_utilization": util_cpu / cap_cpu_s if cap_cpu_s else 0.0,
        "ram_utilization": util_ram / cap_ram_s if cap_ram_s else 0.0,
        "cost_dollars": float(state.cost_dollars),
        "per_priority": per_prio,
        # ---- data plane ---------------------------------------------------
        "cache_hit_gb": float(state.cache_hit_gb),
        "bytes_moved_gb": float(state.bytes_moved_gb),
        "cache_hit_rate": _cache_hit_rate(state),
        "cache_hits": int(state.cache_hits),
        "cache_lookups": int(state.cache_lookups),
        "cache_resident_gb": float(np.sum(np.asarray(state.pool_cache_used))),
        "cold_starts": int(state.cold_starts),
        "warm_starts": int(state.warm_starts),
        "cold_start_ticks": int(state.cold_start_tick_total),
        "cold_start_s": float(state.cold_start_tick_total) / TICKS_PER_SECOND,
        # ---- chaos layer (fault injection + retry, docs/faults.md) --------
        "faults_injected": int(state.crash_events) + int(state.outage_events),
        "crash_events": int(state.crash_events),
        "outage_events": int(state.outage_events),
        "fault_kills": int(state.fault_kills),
        "timeouts": int(state.timeout_events),
        "retries": int(state.retry_events),
        "wasted_work_s": float(state.wasted_ticks) / TICKS_PER_SECOND,
        "pool_down_s": float(state.pool_down_s),
        "mttr_s": float(state.pool_down_s) / int(state.outage_events)
        if int(state.outage_events) > 0
        else float("nan"),
        # goodput: completions that survived to DONE per simulated second
        # (same as throughput, named for resilience comparisons where the
        # interesting delta is vs. the faults-off run)
        "goodput_per_s": float(np.sum(done)) / dur_s,
        "slo_attainment": _slo_attainment(
            params, prio, arrival, completion, done
        ),
    }
    if trace is not None:
        out["trace_enabled"] = True
        out["events_dropped"] = int(trace.events_dropped)
    return out


def _slo_attainment(params, prio, arrival, completion, done) -> dict:
    """Per-priority SLO attainment: the fraction of *submitted* pipelines
    of each class that completed within ``params.slo_latency_s`` —
    pipelines that failed, timed out of their retry budget, or never
    finished count against the SLO. NaN for classes without a target
    (``slo_latency_s[p] == 0``) or with no submissions."""
    out = {}
    lat_s = (completion - arrival) / TICKS_PER_SECOND
    for p in Priority:
        name = p.name.lower()
        target = (
            params.slo_latency_s[int(p)]
            if int(p) < len(params.slo_latency_s)
            else 0.0
        )
        sel = (arrival < INF_TICK) & (prio == int(p))
        n = int(np.sum(sel))
        if target <= 0 or n == 0:
            out[name] = float("nan")
            continue
        ok = sel & done & (lat_s <= target)
        out[name] = float(np.sum(ok)) / n
    return out


def _cache_hit_rate(state: SimState) -> float:
    """Byte-level hit rate of the zero-copy caches (0.0 when no lookups)."""
    hit = float(state.cache_hit_gb)
    moved = float(state.bytes_moved_gb)
    total = hit + moved
    return hit / total if total > 0 else 0.0


def completion_table(state: SimState, wl: Workload) -> np.ndarray:
    """[MP, 4] array: (arrival, completion, status, priority) for analysis."""
    return np.stack(
        [
            np.asarray(wl.arrival),
            np.asarray(state.pipe_completion),
            np.asarray(state.pipe_status),
            np.asarray(wl.prio),
        ],
        axis=1,
    )


__all__ = ["summarize", "completion_table"]
