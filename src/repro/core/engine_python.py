"""Reference Python engine — the paper's exact developer experience.

This engine runs user schedulers with the paper's Listing-4 signature
(``(sch, failures, new_pipelines) -> (suspends, assignments)``) on plain
Python objects. It is event-driven but semantically identical to the
compiled engines (the property suite checks builtin-for-builtin metric
equality against the vector engines), and doubles as the readable
executable specification of the simulator's semantics.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .algorithm import (
    get_python_scheduler,
    get_python_scheduler_init,
    register_scheduler,
    register_scheduler_init,
)
from .params import SimParams
from .state import INF_TICK, Workload, init_state
from .types import (
    Assignment,
    Failure,
    Operator,
    Pipeline,
    PipeStatus,
    Priority,
    Suspension,
    TICKS_PER_SECOND,
)

EPS = 1e-5


class Container:
    __slots__ = (
        "slot", "pipe", "pool", "cpus", "ram", "start", "end", "oom", "warm",
        "timed",
    )

    def __init__(self, slot, pipe, pool, cpus, ram, start, end, oom,
                 warm=False, timed=False):
        self.slot = slot
        self.pipe = pipe
        self.pool = pool
        self.cpus = cpus
        self.ram = ram
        self.start = start
        self.end = end
        self.oom = oom
        self.warm = warm  # started on a warm slot (no cold-start charge)
        self.timed = timed  # ``end`` is a timeout deadline, not completion


class Scheduler:
    """The object handed to user scheduler functions (paper Listing 4).

    Exposes the queues and pool state a policy needs; ``self.data`` is
    free storage for user state initialised by the init function.
    """

    def __init__(self, params: SimParams, pipelines: List[Pipeline]):
        self.params = params
        self.num_pools = params.num_pools
        factor = params.cloud_scale_max_factor if params.cloud_scaling else 1.0
        # all resource arithmetic is float32, bit-matching the compiled
        # engines (engine-equivalence property tests rely on this)
        f32 = np.float32
        self.pool_cpu_cap = np.full(params.num_pools, params.pool_cpus * factor, f32)
        self.pool_ram_cap = np.full(
            params.num_pools, params.pool_ram_gb * factor, f32
        )
        self.pool_cpu_free = self.pool_cpu_cap.copy()
        self.pool_ram_free = self.pool_ram_cap.copy()
        self.pipelines = pipelines
        self.status = {p.pid: PipeStatus.PENDING for p in pipelines}
        self.entered = {p.pid: INF_TICK for p in pipelines}
        self.running: dict[int, Container] = {}  # pid -> container
        self.data: dict = {}
        # ---- data plane: per-pool zero-copy cache + warm slots ------------
        MP, MC = params.max_pipelines, params.max_containers
        self.cache_bytes = np.zeros((params.num_pools, MP), f32)
        self.cache_last = np.zeros((params.num_pools, MP), np.int64)
        self.pool_cache_used = np.zeros((params.num_pools,), f32)
        self.slot_warm_pool = np.full((MC,), -1, np.int64)
        self.slot_warm_until = np.zeros((MC,), np.int64)

    def cached_gb(self, pool: int, pid: int) -> np.float32:
        """Bytes of ``pid``'s intermediates resident in ``pool``'s cache."""
        return self.cache_bytes[pool, pid]

    # -- queue views ------------------------------------------------------
    def waiting_pids(self) -> list[int]:
        """Waiting queue in scheduling order: priority desc, entry asc, pid.

        This sort IS the readable specification of the compiled
        engines' masked selection: the lane-major core picks the same
        head via ``repro.kernels.sched_select.masked_lex_argmin`` (one
        fused lexicographic argmin over ``(-prio, entered, pid)``; the
        three-pass oracle form lives in ``scheduler.select_next_pipe``).
        """
        pids = [pid for pid, st in self.status.items() if st == PipeStatus.WAITING]
        pids.sort(
            key=lambda pid: (
                -int(self.pipelines[pid].priority),
                self.entered[pid],
                pid,
            )
        )
        return pids

    def pipeline(self, pid: int) -> Pipeline:
        return self.pipelines[pid]

    @property
    def total_cpus(self) -> np.float32:
        return np.sum(self.pool_cpu_cap, dtype=np.float32)

    @property
    def total_ram_gb(self) -> np.float32:
        return np.sum(self.pool_ram_cap, dtype=np.float32)


# ---------------------------------------------------------------------------
# Container runtime model — numpy mirror of state.container_schedule (f32
# math so the engines agree bit-for-bit on tick counts).
# ---------------------------------------------------------------------------
def container_schedule_py(pipe: Pipeline, cpus: float, ram: float):
    f32 = np.float32
    levels: dict[int, list[Operator]] = {}
    for o in pipe.ops:
        levels.setdefault(o.level, []).append(o)
    duration = 0
    oom_offset: Optional[int] = None
    cum = f32(0.0)
    for lvl in sorted(levels):
        ops = levels[lvl]
        width = f32(len(ops))
        c_eff = max(f32(cpus) / max(width, f32(1.0)), f32(1e-6))
        t_level = f32(0.0)
        ram_level = f32(0.0)
        for o in ops:
            t_op = f32(o.base_ticks) / np.power(c_eff, f32(o.alpha), dtype=f32)
            t_level = max(t_level, f32(t_op))
            ram_level = f32(ram_level + f32(o.ram_gb))
        t_level = f32(np.ceil(max(t_level, f32(1.0))))
        if oom_offset is None and ram_level > f32(ram) + f32(1e-6):
            oom_offset = max(int(cum), 1)
        cum = f32(cum + t_level)
        duration += int(t_level)
    duration = max(duration, 1)
    if oom_offset is not None:
        oom_offset = min(oom_offset, duration)
    return duration, oom_offset


# ---------------------------------------------------------------------------
# Built-in schedulers, paper-API edition (cross-validated vs. vector ones).
# ---------------------------------------------------------------------------
@register_scheduler_init(key="naive")
def _naive_init(sch: Scheduler) -> None:
    pass


@register_scheduler(key="naive")
def _naive(sch: Scheduler, failures: List[Failure], new: List[Pipeline]):
    suspends: list[Suspension] = []
    assignments: list[Assignment] = []
    rejects = [
        pid
        for pid in sch.waiting_pids()
        if sch.pipelines[pid].failed_before
    ]
    sch.data["rejects"] = rejects
    if sch.running:
        return suspends, assignments
    for pid in sch.waiting_pids():
        if pid in rejects:
            continue
        assignments.append(
            Assignment(
                pipeline=sch.pipelines[pid],
                pool=0,
                cpus=sch.pool_cpu_cap[0],
                ram_gb=sch.pool_ram_cap[0],
            )
        )
        break
    return suspends, assignments


def _pool_select_py(pool_mode: str, free_cpu, free_ram, sch: Scheduler, pid):
    """numpy mirror of ``scheduler._pool_select`` (f32 op-for-op)."""
    if pool_mode == "single":
        return 0
    eps = np.float32(EPS)
    score = free_cpu / np.maximum(sch.pool_cpu_cap, eps) + (
        free_ram / np.maximum(sch.pool_ram_cap, eps)
    )
    if pool_mode == "free":
        return int(np.argmax(score))
    row = sch.cache_bytes[:, pid]
    if pool_mode == "cache":
        if row.max() > 0:
            return int(np.argmax(row))
        return int(np.argmax(score))
    if pool_mode == "locality":
        from .scheduler import LOCALITY_BONUS

        bonus = np.where(row > 0, np.float32(LOCALITY_BONUS), np.float32(0.0))
        return int(np.argmax(score + bonus))
    raise ValueError(f"unknown pool_mode {pool_mode!r}")


def _priority_like_py(sch: Scheduler, pool_mode: str):
    multi_pool = pool_mode != "single"
    params = sch.params
    f32 = np.float32
    K = params.max_assignments_per_tick
    total_cpu = sch.total_cpus
    total_ram = sch.total_ram_gb
    chunk_cpu, chunk_ram = f32(0.10) * total_cpu, f32(0.10) * total_ram
    cap_cpu, cap_ram = f32(0.50) * total_cpu, f32(0.50) * total_ram
    eps = f32(EPS)

    suspends: list[Suspension] = []
    assignments: list[Assignment] = []
    free_cpu = sch.pool_cpu_free.copy()
    free_ram = sch.pool_ram_free.copy()
    live = dict(sch.running)  # pid -> Container, shrinks as we preempt
    rejects = [
        pid
        for pid in sch.waiting_pids()
        if sch.pipelines[pid].failed_before
        and f32(sch.pipelines[pid].last_ram_gb) >= cap_ram - eps
    ]
    sch.data["rejects"] = rejects
    tried: set[int] = set(rejects)

    for _ in range(K):
        cands = [pid for pid in sch.waiting_pids() if pid not in tried]
        if not cands:
            break
        pid = cands[0]
        tried.add(pid)
        p = sch.pipelines[pid]
        if p.failed_before:
            want_cpu = np.minimum(f32(2.0) * f32(p.last_cpus), cap_cpu)
            want_ram = np.minimum(f32(2.0) * f32(p.last_ram_gb), cap_ram)
        elif p.last_ram_gb > 0.0:
            want_cpu, want_ram = f32(p.last_cpus), f32(p.last_ram_gb)
        else:
            want_cpu, want_ram = chunk_cpu, chunk_ram

        pool = _pool_select_py(pool_mode, free_cpu, free_ram, sch, pid)
        fits = free_cpu[pool] >= want_cpu - eps and free_ram[pool] >= want_ram - eps

        if fits:
            assignments.append(Assignment(p, pool, want_cpu, want_ram))
            free_cpu[pool] -= want_cpu
            free_ram[pool] -= want_ram
            continue

        # preemption path (high-priority arrivals only, paper §4.1.2)
        if p.priority <= Priority.BATCH:
            continue
        victims = [
            c
            for c in live.values()
            if int(sch.pipelines[c.pipe].priority) < int(p.priority)
        ]
        if not victims:
            continue
        victims.sort(
            key=lambda c: (int(sch.pipelines[c.pipe].priority), -c.start, c.slot)
        )
        v = victims[0]
        f_cpu2 = free_cpu.copy()
        f_ram2 = free_ram.copy()
        f_cpu2[v.pool] += f32(v.cpus)
        f_ram2[v.pool] += f32(v.ram)
        pool2 = v.pool if multi_pool else pool
        if f_cpu2[pool2] >= want_cpu - eps and f_ram2[pool2] >= want_ram - eps:
            suspends.append(Suspension(sch.pipelines[v.pipe]))
            del live[v.pipe]
            free_cpu, free_ram = f_cpu2, f_ram2
            assignments.append(Assignment(p, pool2, want_cpu, want_ram))
            free_cpu[pool2] -= want_cpu
            free_ram[pool2] -= want_ram
    return suspends, assignments


def _policy_like_py(sch: Scheduler, pol) -> tuple:
    """numpy mirror of the parameterised policy family
    (``scheduler._policy_family``), f32 op-for-op — the reference the
    fused engine's dynamic "policy" scheduler is parity-tested against
    (tests/test_search.py). ``pol`` is a ``PolicyParams``; the knob
    semantics and association order follow the vector implementation
    exactly (lead-key composition, want sizing, preemption commit)."""
    params = sch.params
    f32 = np.float32
    K = params.max_assignments_per_tick
    total_cpu = sch.total_cpus
    total_ram = sch.total_ram_gb
    chunk_cpu = f32(pol.chunk_frac) * total_cpu
    chunk_ram = f32(pol.chunk_frac) * total_ram
    cap_cpu = f32(pol.cap_frac) * total_cpu
    cap_ram = f32(pol.cap_frac) * total_ram
    eps = f32(EPS)

    preempt_on = f32(pol.preempt) > 0.5
    excl_on = f32(pol.exclusive) > 0.5
    grab_on = f32(pol.grab_all) > 0.5
    gate_on = f32(pol.ram_gate) > 0.5
    multi_on = f32(pol.multi_pool) > 0.5
    pin_on = f32(pol.cache_pin) > 0.5
    size_w, prio_w = f32(pol.size_weight), f32(pol.prio_weight)
    age_w, loc_b = f32(pol.age_weight), f32(pol.locality_bonus)

    suspends: list[Suspension] = []
    assignments: list[Assignment] = []
    free_cpu = sch.pool_cpu_free.copy()
    free_ram = sch.pool_ram_free.copy()
    live = dict(sch.running)  # pid -> Container, shrinks as we preempt
    idle0 = not sch.running
    rejects = [
        pid
        for pid in sch.waiting_pids()
        if sch.pipelines[pid].failed_before
        and (
            not gate_on
            or f32(sch.pipelines[pid].last_ram_gb) >= cap_ram - eps
        )
    ]
    sch.data["rejects"] = rejects
    tried: set[int] = set(rejects)
    assigned = False

    def lead(pid):
        # same composition order as the vector lead key: (a + b) - c
        p = sch.pipelines[pid]
        return f32(
            f32(size_w * f32(p.num_ops))
            + f32(age_w * f32(sch.entered[pid]))
        ) - f32(prio_w * f32(int(p.priority)))

    def pool_select(f_cpu, f_ram, pid):
        score = f_cpu / np.maximum(sch.pool_cpu_cap, eps) + (
            f_ram / np.maximum(sch.pool_ram_cap, eps)
        )
        row = sch.cache_bytes[:, pid]
        bonus = np.where(row > 0, loc_b, f32(0.0))
        best = int(np.argmax(score + bonus))
        if pin_on and row.max() > 0:
            best = int(np.argmax(row))
        return best if multi_on else 0

    for _ in range(K):
        cands = [pid for pid in sch.waiting_pids() if pid not in tried]
        if not cands:
            break
        pid = min(
            cands,
            key=lambda pid: (
                lead(pid),
                -int(sch.pipelines[pid].priority),
                sch.entered[pid],
                pid,
            ),
        )
        tried.add(pid)
        p = sch.pipelines[pid]
        if p.failed_before:
            want_cpu = np.minimum(f32(pol.retry_mult) * f32(p.last_cpus), cap_cpu)
            want_ram = np.minimum(
                f32(pol.retry_mult) * f32(p.last_ram_gb), cap_ram
            )
        elif p.last_ram_gb > 0.0:
            want_cpu, want_ram = f32(p.last_cpus), f32(p.last_ram_gb)
        else:
            want_cpu, want_ram = chunk_cpu, chunk_ram

        pool = pool_select(free_cpu, free_ram, pid)
        if grab_on:
            want_cpu = sch.pool_cpu_cap[pool]
            want_ram = sch.pool_ram_cap[pool]
        fits = free_cpu[pool] >= want_cpu - eps and free_ram[pool] >= want_ram - eps

        if excl_on:
            # naive mode: idle cluster, one assignment, no fits test
            if idle0 and not assigned:
                assignments.append(Assignment(p, pool, want_cpu, want_ram))
                free_cpu[pool] -= want_cpu
                free_ram[pool] -= want_ram
                assigned = True
            continue

        if fits:
            assignments.append(Assignment(p, pool, want_cpu, want_ram))
            free_cpu[pool] -= want_cpu
            free_ram[pool] -= want_ram
            assigned = True
            continue

        # preemption path, knob-gated
        if not preempt_on or not (f32(int(p.priority)) > f32(pol.preempt_min_prio)):
            continue
        thresh = f32(int(p.priority)) - f32(pol.victim_prio_gap)
        victims = [
            c
            for c in live.values()
            if f32(int(sch.pipelines[c.pipe].priority)) < thresh
        ]
        if not victims:
            continue
        victims.sort(
            key=lambda c: (int(sch.pipelines[c.pipe].priority), -c.start, c.slot)
        )
        v = victims[0]
        f_cpu2 = free_cpu.copy()
        f_ram2 = free_ram.copy()
        f_cpu2[v.pool] += f32(v.cpus)
        f_ram2[v.pool] += f32(v.ram)
        pool2 = v.pool if multi_on else pool
        if f_cpu2[pool2] >= want_cpu - eps and f_ram2[pool2] >= want_ram - eps:
            suspends.append(Suspension(sch.pipelines[v.pipe]))
            del live[v.pipe]
            free_cpu, free_ram = f_cpu2, f_ram2
            assignments.append(Assignment(p, pool2, want_cpu, want_ram))
            free_cpu[pool2] -= want_cpu
            free_ram[pool2] -= want_ram
            assigned = True
    return suspends, assignments


@register_scheduler_init(key="policy")
def _policy_init(sch: Scheduler) -> None:
    pass


@register_scheduler(key="policy")
def _policy(sch: Scheduler, failures, new):
    vec = sch.data.get("policy")
    if vec is None:
        raise ValueError(
            "scheduler 'policy' needs a workload with a policy vector "
            "attached; see sweep.attach_policies"
        )
    from .policy import PolicyParams

    return _policy_like_py(sch, PolicyParams.from_vector(vec))


@register_scheduler_init(key="priority")
def _priority_init(sch: Scheduler) -> None:
    pass


@register_scheduler(key="priority")
def _priority(sch: Scheduler, failures, new):
    return _priority_like_py(sch, "single")


@register_scheduler_init(key="priority_pool")
def _priority_pool_init(sch: Scheduler) -> None:
    pass


@register_scheduler(key="priority_pool")
def _priority_pool(sch: Scheduler, failures, new):
    return _priority_like_py(sch, "free")


# ---------------------------------------------------------------------------
# Data-plane transitions — numpy mirrors of state.cache_insert and the
# executor's warm-slot selection (f32 math, same association order; the
# engine-equivalence suite checks bitwise agreement on cache state).
# ---------------------------------------------------------------------------
def _cache_insert_py(sch: Scheduler, pool: int, pid: int, size, tick: int,
                     cap: float) -> None:
    f32 = np.float32
    cap32 = f32(cap)
    size = f32(size)
    if not size <= cap32:  # dataset larger than the whole cache: skip
        return
    row_b = sch.cache_bytes[pool]
    row_l = sch.cache_last[pool]
    used = sch.pool_cache_used[pool]
    cached = row_b[pid]
    need = f32(f32(f32(used - cached) + size) - cap32)
    freed = f32(0.0)
    if need > 0:
        victims = sorted(
            (int(row_l[j]), j)
            for j in range(row_b.shape[0])
            if row_b[j] > 0 and j != pid
        )
        for _, j in victims:
            if not (freed < need):  # mirrors (cum - freed) < need
                break
            freed = f32(freed + row_b[j])
            row_b[j] = 0.0
            row_l[j] = 0
    row_b[pid] = size
    row_l[pid] = tick
    sch.pool_cache_used[pool] = f32(f32(f32(used - freed) - cached) + size)


def _backoff_release_py(attempt: int, tick: int, params: SimParams) -> int:
    """Backoff re-queue tick — np.float32 mirror of the compiled
    ``executor._requeue_faulted`` arithmetic (bitwise-equal releases)."""
    backoff = np.minimum(
        np.float32(params.base_backoff_ticks)
        * np.exp2(np.float32(min(attempt, 30))),
        np.float32(2**30),
    ).astype(np.int32)
    return tick + max(int(backoff), 1)


def _pick_slot(free_slots, pool: int, tick: int, sch: Scheduler,
               prefer_warm: bool) -> int:
    """Lowest free slot, preferring warm-for-pool slots when the cold-start
    model is on (mirrors the executor's compiled slot selection)."""
    if prefer_warm:
        warm = [
            s
            for s in free_slots
            if sch.slot_warm_pool[s] == pool and tick < sch.slot_warm_until[s]
        ]
        if warm:
            return min(warm)
    return min(free_slots)


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------
def pipelines_from_workload(wl: Workload) -> List[Pipeline]:
    arrival = np.asarray(wl.arrival)
    prio = np.asarray(wl.prio)
    n_ops = np.asarray(wl.n_ops)
    valid = np.asarray(wl.op_valid)
    level = np.asarray(wl.op_level)
    ram = np.asarray(wl.op_ram)
    base = np.asarray(wl.op_base)
    alpha = np.asarray(wl.op_alpha)
    out_gb = np.asarray(wl.op_out)
    out = []
    for i in range(arrival.shape[0]):
        ops = [
            Operator(
                ram_gb=float(ram[i, j]),
                base_ticks=float(base[i, j]),
                alpha=float(alpha[i, j]),
                level=int(level[i, j]),
                out_gb=float(out_gb[i, j]),
            )
            for j in range(valid.shape[1])
            if valid[i, j]
        ]
        out.append(
            Pipeline(
                pid=i,
                priority=Priority(int(prio[i])),
                arrival_tick=int(arrival[i]),
                ops=ops,
            )
        )
    return out


def run_python_engine(params: SimParams, wl: Workload):
    from .engine import SimResult

    horizon = params.horizon_ticks
    pipelines = pipelines_from_workload(wl)
    sch = Scheduler(params, pipelines)
    if wl.policy is not None:
        # the dynamic "policy" scheduler reads its PolicyParams vector
        # from the workload, same as the vector engine
        sch.data["policy"] = np.asarray(wl.policy, np.float32)
    algo = get_python_scheduler(params.scheduling_algo)
    get_python_scheduler_init(params.scheduling_algo)(sch)

    MP = params.max_pipelines
    MC = params.max_containers
    NP = params.num_pools
    free_slots = set(range(MC))
    release: dict[int, int] = {}  # pid -> release tick
    completion = np.full((MP,), INF_TICK, np.int64)
    first_start = np.full((MP,), INF_TICK, np.int64)
    fails = np.zeros((MP,), np.int64)
    preempts = np.zeros((MP,), np.int64)
    done_count = failed_count = oom_events = preempt_events = 0
    util_cpu_s = np.zeros((NP,))
    util_ram_s = np.zeros((NP,))
    util_log = np.zeros((params.util_log_buckets, NP, 2))
    cost = 0.0
    sum_lat = 0.0
    sum_lat_prio = np.zeros((3,))
    done_prio = np.zeros((3,), np.int64)
    # ---- data-plane metrics (f32 accumulators, assignment order — the
    # compiled engines accumulate identically) ------------------------------
    pipe_out = np.asarray(wl.pipe_out)
    cache_hit_gb = np.float32(0.0)
    bytes_moved_gb = np.float32(0.0)
    cache_hits = cache_lookups = cold_starts = warm_starts = 0
    cold_start_tick_total = 0
    prefer_warm = params.cold_start_ticks > 0

    # ---- chaos layer: pre-materialised fault trace + retry policy ---------
    # (docs/faults.md; mirrors executor.apply_faults / _requeue_faulted)
    ft = wl.faults
    crash_on = params.crash_mtbf_ticks > 0 and ft is not None
    outage_on = params.outage_mtbf_ticks > 0 and ft is not None
    straggler_on = params.straggler_prob > 0 and ft is not None
    if ft is not None:
        crash_time = np.asarray(ft.crash_time, np.int64)
        outage_start_t = np.asarray(ft.outage_start, np.int64)
        outage_end_t = np.asarray(ft.outage_end, np.int64)
        outage_pool_t = np.asarray(ft.outage_pool, np.int64)
        straggler = np.asarray(ft.straggler, np.float32)
    pool_down_until = np.zeros((NP,), np.int64)
    crash_cursor = outage_cursor = 0
    nxt_fault = int(INF_TICK)
    pipe_retries = np.zeros((MP,), np.int64)
    crash_events = outage_events = timeout_events = retry_events = 0
    fault_kills = 0
    wasted_ticks = 0
    pool_down_s = 0.0

    # ---- closed loop: client model + admission control --------------------
    # (docs/closed-loop.md; mirrors admission.apply_closed_loop op-for-op)
    closed_on = params.closed_loop_active
    pipe_offered = np.zeros((MP,), bool)
    pipe_presented = np.zeros((MP,), bool)
    pipe_client_attempts = np.zeros((MP,), np.int64)
    offered_total = offered_unique = admitted_total = 0
    shed_total = deferred_total = client_retry_events = 0
    offered_prio = np.zeros((3,), np.int64)
    admitted_prio = np.zeros((3,), np.int64)
    adm_regs = {
        "tokens": np.float32(params.admit_burst),
        "last_tick": 0,
        "above_since": int(INF_TICK),
    }
    last_fault_tick = int(INF_TICK)
    prefault_backlog = -1
    drain_tick = int(INF_TICK)
    if params.admission_active:
        from .admission import AdmissionView, get_admission_policy_py

        adm_policy = get_admission_policy_py(params.admission_policy)

    def _requeue_faulted_py(pid: int, t: int) -> None:
        """Retry policy for a fault-killed / timed-out pipeline: backoff
        re-queue while budget lasts, FAILED once it is exhausted. Does
        NOT set ``failed_before`` (the allocation was fine — the worker
        died), exactly like the compiled engine."""
        nonlocal failed_count, retry_events
        attempt = int(pipe_retries[pid])
        if attempt >= params.max_retries:
            sch.status[pid] = PipeStatus.FAILED
            completion[pid] = t
            failed_count += 1
        else:
            sch.status[pid] = PipeStatus.SUSPENDED
            release[pid] = _backoff_release_py(attempt, t, params)
            pipe_retries[pid] += 1
            retry_events += 1

    def _mark_warm(c: Container, t: int) -> None:
        sch.slot_warm_pool[c.slot] = c.pool
        sch.slot_warm_until[c.slot] = t + params.container_warm_ticks

    arrivals_sorted = sorted(
        (p.arrival_tick, p.pid) for p in pipelines if p.arrival_tick < horizon
    )
    arr_ix = 0

    tick = 0
    while tick < horizon:
        # ---- arrivals -----------------------------------------------------
        new_pipes: list[Pipeline] = []
        while arr_ix < len(arrivals_sorted) and arrivals_sorted[arr_ix][0] <= tick:
            _, pid = arrivals_sorted[arr_ix]
            arr_ix += 1
            sch.status[pid] = PipeStatus.WAITING
            sch.entered[pid] = pipelines[pid].arrival_tick
            new_pipes.append(pipelines[pid])
        # ---- suspension releases -----------------------------------------
        for pid in [p for p, r in release.items() if r <= tick]:
            sch.status[pid] = PipeStatus.WAITING
            sch.entered[pid] = release.pop(pid)
        # ---- completions / OOMs -------------------------------------------
        failures: list[Failure] = []
        for pid, c in list(sch.running.items()):
            fire_oom = c.oom is not None and c.oom <= tick
            fire_end = c.end <= tick
            if not (fire_oom or fire_end):
                continue
            sch.pool_cpu_free[c.pool] += c.cpus
            sch.pool_ram_free[c.pool] += c.ram
            free_slots.add(c.slot)
            _mark_warm(c, tick)
            del sch.running[pid]
            p = pipelines[pid]
            if fire_oom:
                sch.status[pid] = PipeStatus.WAITING
                sch.entered[pid] = tick
                p.failed_before = True
                fails[pid] += 1
                oom_events += 1
                failures.append(Failure(p, tick, c.cpus, c.ram))
            elif c.timed:
                # wall-clock timeout: the slot retires normally (it ran
                # fine until the deadline, so it stays warm) but the
                # pipeline re-queues under the retry policy
                timeout_events += 1
                wasted_ticks += tick - c.start
                _requeue_faulted_py(pid, tick)
            else:
                sch.status[pid] = PipeStatus.DONE
                completion[pid] = c.end
                done_count += 1
                lat = (c.end - p.arrival_tick) / TICKS_PER_SECOND
                sum_lat += lat
                sum_lat_prio[int(p.priority)] += lat
                done_prio[int(p.priority)] += 1

        # ---- chaos layer: crashes + pool outages due at this tick -----------
        if crash_on or outage_on:
            kills: list[Container] = []
            k_due_now = o_due_now = 0
            backlog_at_fault = sum(
                1 for s2 in sch.status.values() if s2 == PipeStatus.WAITING
            )
            if crash_on:
                new_ccur = int(np.searchsorted(crash_time, tick, side="right"))
                k_due = new_ccur - crash_cursor
                k_due_now = k_due
                crash_cursor = new_ccur
                crash_events += k_due
                if k_due > 0:
                    # each crash strikes the longest-running container
                    # (start asc, slot asc); a crash with nothing left
                    # running strikes an idle worker and kills nothing
                    victims = sorted(
                        sch.running.values(), key=lambda c: (c.start, c.slot)
                    )
                    kills.extend(victims[:k_due])
            down_new = np.zeros((NP,), bool)
            if outage_on:
                new_ocur = int(
                    np.searchsorted(outage_start_t, tick, side="right")
                )
                for i in range(outage_cursor, new_ocur):
                    p_ix = int(outage_pool_t[i])
                    down_new[p_ix] = True
                    pool_down_until[p_ix] = max(
                        pool_down_until[p_ix], int(outage_end_t[i])
                    )
                o_due_now = new_ocur - outage_cursor
                outage_events += new_ocur - outage_cursor
                outage_cursor = new_ocur
                if down_new.any():
                    struck = {c.slot for c in kills}
                    kills.extend(
                        c for c in sch.running.values()
                        if down_new[c.pool] and c.slot not in struck
                    )
            for c in kills:
                pid = c.pipe
                sch.pool_cpu_free[c.pool] += c.cpus
                sch.pool_ram_free[c.pool] += c.ram
                free_slots.add(c.slot)
                # a struck slot hands off no warmth (the worker died)
                sch.slot_warm_pool[c.slot] = -1
                sch.slot_warm_until[c.slot] = 0
                del sch.running[pid]
                fault_kills += 1
                wasted_ticks += tick - c.start
                _requeue_faulted_py(pid, tick)
            if outage_on and down_new.any():
                # a newly-down pool loses its warm slots and its cache
                for s in range(MC):
                    wp = int(sch.slot_warm_pool[s])
                    if wp >= 0 and down_new[wp]:
                        sch.slot_warm_pool[s] = -1
                        sch.slot_warm_until[s] = 0
                if params.cache_gb_per_pool > 0:
                    for p_ix in range(NP):
                        if down_new[p_ix]:
                            sch.cache_bytes[p_ix, :] = 0.0
                            sch.cache_last[p_ix, :] = 0
                            sch.pool_cache_used[p_ix] = 0.0
            # next-fault register: next crash / outage start / recovery
            nxt_fault = int(INF_TICK)
            if crash_on and crash_cursor < crash_time.shape[0]:
                nxt_fault = min(nxt_fault, int(crash_time[crash_cursor]))
            if outage_on:
                if outage_cursor < outage_start_t.shape[0]:
                    nxt_fault = min(
                        nxt_fault, int(outage_start_t[outage_cursor])
                    )
                for p_ix in range(NP):
                    if pool_down_until[p_ix] > tick:
                        nxt_fault = min(nxt_fault, int(pool_down_until[p_ix]))
            if closed_on and (k_due_now > 0 or o_due_now > 0):
                # overload bookkeeping (mirrors executor.apply_faults):
                # stamp the fault tick, snapshot the pre-fault backlog
                # once, and re-arm drain detection
                last_fault_tick = tick
                if prefault_backlog < 0:
                    prefault_backlog = backlog_at_fault
                drain_tick = int(INF_TICK)

        # ---- closed loop: client offer gate + admission (pre-scheduler) -----
        # (mirrors admission.apply_closed_loop; docs/closed-loop.md)
        if closed_on:
            fresh = [
                pid for pid in sorted(sch.status)
                if sch.status[pid] == PipeStatus.WAITING
                and first_start[pid] == INF_TICK
                and not pipe_offered[pid]
            ]
            if params.client_max_inflight > 0:
                inflight = sum(
                    1 for pid2, s2 in sch.status.items()
                    if pipe_offered[pid2]
                    and s2 in (PipeStatus.WAITING, PipeStatus.RUNNING,
                               PipeStatus.SUSPENDED)
                )
                open_slots = max(params.client_max_inflight - inflight, 0)
                offer = fresh[:open_slots]
                gate_defer = fresh[open_slots:]
            else:
                offer = fresh
                gate_defer = []
            if params.admission_active:
                adm_waiting = [
                    pid2 for pid2, s2 in sch.status.items()
                    if s2 == PipeStatus.WAITING and pipe_offered[pid2]
                ]
                view = AdmissionView(
                    admitted_waiting=len(adm_waiting),
                    oldest_admitted_entered=min(
                        (int(sch.entered[pid2]) for pid2 in adm_waiting),
                        default=int(INF_TICK),
                    ),
                    regs=adm_regs,
                )
                reject, defer, defer_ticks = adm_policy(
                    params, tick, offer, view
                )
            else:
                reject, defer, defer_ticks = [], [], 1
            bounced = set(reject) | set(defer)
            admit = [pid for pid in offer if pid not in bounced]
            offered_total += len(offer)
            for pid in offer:
                offered_prio[int(pipelines[pid].priority)] += 1
                if not pipe_presented[pid]:
                    offered_unique += 1
                    pipe_presented[pid] = True
            admitted_total += len(admit)
            for pid in admit:
                admitted_prio[int(pipelines[pid].priority)] += 1
                pipe_offered[pid] = True
            think = max(params.client_think_ticks, 1)
            for pid in gate_defer:
                sch.status[pid] = PipeStatus.SUSPENDED
                release[pid] = tick + think
            pol_delay = max(defer_ticks, 1)
            for pid in defer:
                sch.status[pid] = PipeStatus.SUSPENDED
                release[pid] = tick + pol_delay
            deferred_total += len(gate_defer) + len(defer)
            shed_total += len(reject)
            for pid in reject:
                attempt = int(pipe_client_attempts[pid])
                if attempt < params.client_max_retries:
                    # client-side capped exponential backoff (np.float32
                    # mirror of the compiled arithmetic)
                    backoff = np.minimum(
                        np.float32(params.client_backoff_ticks)
                        * np.exp2(np.float32(min(attempt, 30))),
                        np.float32(2**30),
                    ).astype(np.int32)
                    sch.status[pid] = PipeStatus.SUSPENDED
                    release[pid] = tick + max(int(backoff), 1)
                    pipe_client_attempts[pid] += 1
                    client_retry_events += 1
                else:
                    sch.status[pid] = PipeStatus.FAILED
                    completion[pid] = tick
                    failed_count += 1
            if params.fault_events_active:
                backlog = sum(
                    1 for s2 in sch.status.values()
                    if s2 == PipeStatus.WAITING
                )
                if (
                    last_fault_tick != int(INF_TICK)
                    and tick > last_fault_tick
                    and backlog <= max(prefault_backlog, 0)
                    and drain_tick == int(INF_TICK)
                ):
                    drain_tick = tick

        # ---- scheduler (down pools masked to zero free capacity) ------------
        down = pool_down_until > tick
        if outage_on and down.any():
            saved_free = (sch.pool_cpu_free, sch.pool_ram_free)
            sch.pool_cpu_free = np.where(
                down, np.float32(0.0), sch.pool_cpu_free
            ).astype(np.float32)
            sch.pool_ram_free = np.where(
                down, np.float32(0.0), sch.pool_ram_free
            ).astype(np.float32)
            suspends, assignments = algo(sch, failures, new_pipes)
            sch.pool_cpu_free, sch.pool_ram_free = saved_free
            # decision filter: cap-driven schedulers (naive) can still
            # target a dead pool — drop those before they commit
            assignments = [
                a for a in assignments
                if not down[min(max(int(a.pool), 0), NP - 1)]
            ]
        else:
            suspends, assignments = algo(sch, failures, new_pipes)
        acted = bool(suspends or assignments or sch.data.get("rejects"))

        # rejects (permanent failures back to the user)
        for pid in sch.data.pop("rejects", []):
            if sch.status[pid] == PipeStatus.WAITING:
                sch.status[pid] = PipeStatus.FAILED
                completion[pid] = tick
                failed_count += 1

        # suspensions
        for s in suspends:
            pid = s.pipeline.pid
            c = sch.running.pop(pid, None)
            if c is None:
                continue
            sch.pool_cpu_free[c.pool] += c.cpus
            sch.pool_ram_free[c.pool] += c.ram
            free_slots.add(c.slot)
            _mark_warm(c, tick)
            sch.status[pid] = PipeStatus.SUSPENDED
            release[pid] = tick + 1
            preempts[pid] += 1
            preempt_events += 1

        # assignments
        for a in assignments:
            pid = a.pipeline.pid
            if sch.status[pid] != PipeStatus.WAITING or not free_slots:
                continue
            pool = int(a.pool)
            slot = _pick_slot(free_slots, pool, tick, sch, prefer_warm)
            free_slots.discard(slot)
            is_warm = bool(
                sch.slot_warm_pool[slot] == pool
                and tick < sch.slot_warm_until[slot]
            )
            cold_ticks = 0 if is_warm else params.cold_start_ticks
            # data plane: scan whatever input bytes the pool's cache lacks
            total_out = np.float32(pipe_out[pid])
            cached = sch.cache_bytes[pool, pid]
            hit_gb = np.minimum(cached, total_out)
            miss_gb = np.maximum(np.float32(total_out - cached), np.float32(0))
            scan_ticks = int(
                np.ceil(np.float32(params.scan_ticks_per_gb) * miss_gb)
            )
            startup = cold_ticks + scan_ticks
            cpus, ram_gb = np.float32(a.cpus), np.float32(a.ram_gb)
            dur, oom_off = container_schedule_py(a.pipeline, cpus, ram_gb)
            if straggler_on:
                # straggler stretch (f32, mirrors the compiled stretch;
                # ceil is monotone so stretching the pre-clamped offset
                # equals the compiled stretch-then-min)
                f = np.float32(straggler[pid])

                def _stretch(t: int) -> int:
                    return int(np.minimum(
                        np.ceil(np.float32(t) * f), np.float32(2**30)
                    ).astype(np.int32))

                dur = _stretch(dur)
                if oom_off is not None:
                    oom_off = _stretch(oom_off)
            end = tick + startup + dur
            timed = False
            if params.timeout_ticks > 0:
                # wall-clock deadline; a same-tick OOM wins at retirement
                deadline = tick + params.timeout_ticks
                timed = end > deadline
                end = min(end, deadline)
            c = Container(
                slot,
                pid,
                pool,
                cpus,
                ram_gb,
                tick,
                end,
                (tick + startup + oom_off) if oom_off is not None else None,
                warm=is_warm,
                timed=timed,
            )
            cache_hit_gb = np.float32(cache_hit_gb + hit_gb)
            bytes_moved_gb = np.float32(bytes_moved_gb + miss_gb)
            cache_hits += int(hit_gb > 0)
            cache_lookups += int(total_out > 0)
            cold_starts += int(not is_warm)
            warm_starts += int(is_warm)
            cold_start_tick_total += cold_ticks
            if params.cache_gb_per_pool > 0:
                _cache_insert_py(
                    sch, pool, pid, total_out, tick, params.cache_gb_per_pool
                )
            sch.running[pid] = c
            sch.status[pid] = PipeStatus.RUNNING
            a.pipeline.last_cpus = a.cpus
            a.pipeline.last_ram_gb = a.ram_gb
            a.pipeline.failed_before = False
            first_start[pid] = min(first_start[pid], tick)
            sch.pool_cpu_free[a.pool] -= a.cpus
            sch.pool_ram_free[a.pool] -= a.ram_gb

        # ---- next event -----------------------------------------------------
        nxt = horizon
        if arr_ix < len(arrivals_sorted):
            nxt = min(nxt, arrivals_sorted[arr_ix][0])
        for c in sch.running.values():
            nxt = min(nxt, c.end if c.oom is None else min(c.end, c.oom))
        for r in release.values():
            nxt = min(nxt, r)
        if crash_on or outage_on:
            nxt = min(nxt, nxt_fault)
        if acted:
            nxt = min(nxt, tick + 1)
        nxt = max(nxt, tick + 1)
        nxt = min(nxt, horizon)

        # ---- integrate utilisation over [tick, nxt) -------------------------
        dt_s = (nxt - tick) / TICKS_PER_SECOND
        used_cpu = np.zeros((NP,))
        used_ram = np.zeros((NP,))
        for c in sch.running.values():
            used_cpu[c.pool] += c.cpus
            used_ram[c.pool] += c.ram
        util_cpu_s += used_cpu * dt_s
        util_ram_s += used_ram * dt_s
        base_cpu = params.pool_cpus
        over = np.maximum(used_cpu - base_cpu, 0.0)
        cost += (
            float(np.sum(np.minimum(used_cpu, base_cpu) + params.cloud_premium_factor * over))
            * params.cloud_cost_per_cpu_second
            * dt_s
        )
        B = params.util_log_buckets
        edges = np.linspace(0.0, float(horizon), B + 1)
        lo = np.maximum(edges[:-1], tick)
        hi = np.minimum(edges[1:], nxt)
        overlap_s = np.maximum(hi - lo, 0.0) / TICKS_PER_SECOND
        util_log += overlap_s[:, None, None] * np.stack(
            [used_cpu, used_ram], axis=-1
        )[None, :, :]
        if outage_on:
            # a pool down at tick is down for all of [tick, nxt): the
            # next-fault register includes every recovery tick
            pool_down_s += float(dt_s) * int(np.sum(pool_down_until > tick))

        tick = nxt

    # ---- pack a SimState for uniform downstream consumption ----------------
    import jax.numpy as jnp

    st = init_state(params)
    status_arr = np.full((MP,), int(PipeStatus.EMPTY), np.int32)
    for pid, s in sch.status.items():
        # not-yet-arrived pipelines are indistinguishable from empty slots
        # in the SoA representation — normalise for engine equivalence
        status_arr[pid] = int(PipeStatus.EMPTY if s == PipeStatus.PENDING else s)
    # next-event registers: same invariants the compiled executor keeps
    # (min end/oom over running containers, min release over suspended,
    # count of consumed arrivals)
    nxt_retire = min(
        (
            c.end if c.oom is None else min(c.end, c.oom)
            for c in sch.running.values()
        ),
        default=int(INF_TICK),
    )
    nxt_release = min(release.values(), default=int(INF_TICK))
    st = st._replace(
        nxt_retire=jnp.asarray(min(nxt_retire, int(INF_TICK)), jnp.int32),
        nxt_release=jnp.asarray(min(nxt_release, int(INF_TICK)), jnp.int32),
        nxt_arrival_cursor=jnp.asarray(arr_ix, jnp.int32),
        tick=jnp.asarray(horizon, jnp.int32),
        pipe_status=jnp.asarray(status_arr),
        pipe_completion=jnp.asarray(
            np.minimum(completion, INF_TICK).astype(np.int32)
        ),
        pipe_first_start=jnp.asarray(
            np.minimum(first_start, INF_TICK).astype(np.int32)
        ),
        pipe_fails=jnp.asarray(fails.astype(np.int32)),
        pipe_preempts=jnp.asarray(preempts.astype(np.int32)),
        pipe_fail_flag=jnp.asarray(
            np.array([pipelines[i].failed_before for i in range(MP)])
        ),
        pool_cpu_free=jnp.asarray(np.array(sch.pool_cpu_free, np.float32)),
        pool_ram_free=jnp.asarray(np.array(sch.pool_ram_free, np.float32)),
        pool_cache_used=jnp.asarray(
            np.array(sch.pool_cache_used, np.float32)
        ),
        cache_bytes=jnp.asarray(np.array(sch.cache_bytes, np.float32)),
        cache_last=jnp.asarray(sch.cache_last.astype(np.int32)),
        slot_warm_pool=jnp.asarray(sch.slot_warm_pool.astype(np.int32)),
        slot_warm_until=jnp.asarray(
            np.minimum(sch.slot_warm_until, INF_TICK).astype(np.int32)
        ),
        ctr_warm=jnp.asarray(
            np.array(
                [
                    any(
                        c.slot == s and c.warm
                        for c in sch.running.values()
                    )
                    for s in range(MC)
                ]
            )
        ),
        cache_hit_gb=jnp.asarray(cache_hit_gb, jnp.float32),
        bytes_moved_gb=jnp.asarray(bytes_moved_gb, jnp.float32),
        cache_hits=jnp.asarray(cache_hits, jnp.int32),
        cache_lookups=jnp.asarray(cache_lookups, jnp.int32),
        cold_starts=jnp.asarray(cold_starts, jnp.int32),
        warm_starts=jnp.asarray(warm_starts, jnp.int32),
        cold_start_tick_total=jnp.asarray(cold_start_tick_total, jnp.int32),
        done_count=jnp.asarray(done_count, jnp.int32),
        failed_count=jnp.asarray(failed_count, jnp.int32),
        oom_events=jnp.asarray(oom_events, jnp.int32),
        preempt_events=jnp.asarray(preempt_events, jnp.int32),
        sum_latency_s=jnp.asarray(sum_lat, jnp.float32),
        sum_latency_s_prio=jnp.asarray(sum_lat_prio.astype(np.float32)),
        done_prio=jnp.asarray(done_prio.astype(np.int32)),
        util_cpu_s=jnp.asarray(util_cpu_s.astype(np.float32)),
        util_ram_s=jnp.asarray(util_ram_s.astype(np.float32)),
        cost_dollars=jnp.asarray(cost, jnp.float32),
        util_log=jnp.asarray(util_log.astype(np.float32)),
        # ---- chaos layer registers + counters -----------------------------
        pipe_retries=jnp.asarray(pipe_retries.astype(np.int32)),
        ctr_timed=jnp.asarray(
            np.array(
                [
                    any(
                        c.slot == s and c.timed
                        for c in sch.running.values()
                    )
                    for s in range(MC)
                ]
            )
        ),
        pool_down_until=jnp.asarray(
            np.minimum(pool_down_until, INF_TICK).astype(np.int32)
        ),
        crash_cursor=jnp.asarray(crash_cursor, jnp.int32),
        outage_cursor=jnp.asarray(outage_cursor, jnp.int32),
        nxt_fault=jnp.asarray(min(nxt_fault, int(INF_TICK)), jnp.int32),
        crash_events=jnp.asarray(crash_events, jnp.int32),
        outage_events=jnp.asarray(outage_events, jnp.int32),
        timeout_events=jnp.asarray(timeout_events, jnp.int32),
        retry_events=jnp.asarray(retry_events, jnp.int32),
        fault_kills=jnp.asarray(fault_kills, jnp.int32),
        wasted_ticks=jnp.asarray(wasted_ticks, jnp.int32),
        pool_down_s=jnp.asarray(pool_down_s, jnp.float32),
        # ---- closed-loop registers + counters -----------------------------
        pipe_offered=jnp.asarray(pipe_offered),
        pipe_presented=jnp.asarray(pipe_presented),
        pipe_client_attempts=jnp.asarray(
            pipe_client_attempts.astype(np.int32)
        ),
        offered_total=jnp.asarray(offered_total, jnp.int32),
        offered_unique=jnp.asarray(offered_unique, jnp.int32),
        admitted_total=jnp.asarray(admitted_total, jnp.int32),
        shed_total=jnp.asarray(shed_total, jnp.int32),
        deferred_total=jnp.asarray(deferred_total, jnp.int32),
        client_retry_events=jnp.asarray(client_retry_events, jnp.int32),
        offered_prio=jnp.asarray(offered_prio.astype(np.int32)),
        admitted_prio=jnp.asarray(admitted_prio.astype(np.int32)),
        admit_tokens=jnp.asarray(adm_regs["tokens"], jnp.float32),
        admit_last_tick=jnp.asarray(adm_regs["last_tick"], jnp.int32),
        codel_above_since=jnp.asarray(
            min(adm_regs["above_since"], int(INF_TICK)), jnp.int32
        ),
        last_fault_tick=jnp.asarray(last_fault_tick, jnp.int32),
        prefault_backlog=jnp.asarray(prefault_backlog, jnp.int32),
        drain_tick=jnp.asarray(drain_tick, jnp.int32),
    )
    return SimResult(state=st, workload=wl, params=params, sched_state=sch)


__all__ = [
    "Scheduler",
    "Container",
    "container_schedule_py",
    "pipelines_from_workload",
    "run_python_engine",
]
