"""Struct-of-arrays simulator state.

The original Eudoxia is a Python object graph; here the whole simulation
world is a pytree of dense arrays so the engine can be a single compiled
XLA program, ``vmap``-ed into fleets and sharded across a TPU mesh.

Capacity convention: tables are fixed-size (``max_pipelines``,
``max_ops_per_pipeline``, ``max_containers``, ``num_pools``); validity is
encoded in status columns. ``INF_TICK`` marks "never".
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .params import SimParams
from .types import ContainerStatus, PipeStatus, TICKS_PER_SECOND

INF_TICK = np.int32(2**31 - 1)


class FaultTrace(NamedTuple):
    """Pre-materialised fault events for one lane (chaos layer).

    Like the arrival table, every fault is drawn up front from the seed —
    no on-device RNG — so each engine replays the exact same faults.
    Shapes: MF = ``params.max_fault_events``, MP = max_pipelines. Unused
    slots hold ``INF_TICK`` (crash/outage times) or 1.0 (stragglers), so
    an all-padding trace is inert.
    """

    crash_time: jax.Array    # [MF] int32 sorted crash ticks (INF = unused)
    outage_start: jax.Array  # [MF] int32 sorted outage start ticks
    outage_end: jax.Array    # [MF] int32 outage recovery ticks
    outage_pool: jax.Array   # [MF] int32 struck pool per outage
    straggler: jax.Array     # [MP] f32 per-pipeline slowdown factor (1 = none)

    @property
    def max_fault_events(self) -> int:
        return self.crash_time.shape[-1]


class Workload(NamedTuple):
    """Immutable arrival table produced by the workload generator.

    Shapes: MP = max_pipelines, MO = max_ops_per_pipeline.
    """

    arrival: jax.Array      # [MP] int32 arrival tick (INF_TICK = unused slot)
    prio: jax.Array         # [MP] int32 Priority
    n_ops: jax.Array        # [MP] int32
    op_valid: jax.Array     # [MP, MO] bool
    op_level: jax.Array     # [MP, MO] int32 topological level
    op_ram: jax.Array       # [MP, MO] f32 GB
    op_base: jax.Array      # [MP, MO] f32 runtime ticks at 1 CPU
    op_alpha: jax.Array     # [MP, MO] f32 CPU-scaling exponent
    # ---- data plane: intermediate output dataset sizes -------------------
    op_out: jax.Array       # [MP, MO] f32 GB produced by each operator
    pipe_out: jax.Array     # [MP] f32 GB — precomputed Σ op_out per pipe
    #   (precomputed once at generation so every engine reads identical
    #    bits instead of re-reducing f32 arrays in engine-specific order)
    # ---- chaos layer: pre-materialised fault events (None = faults off) --
    faults: "FaultTrace | None" = None
    # ---- policy search: flat PolicyParams f32 vector consumed by the
    # dynamic "policy" scheduler family (None = named schedulers only).
    # Riding the Workload (not SimParams) puts it on the vmapped fleet
    # axis, so a policy-grid fleet evaluates one candidate per lane.
    policy: "jax.Array | None" = None  # [N_POLICY_PARAMS] f32

    @property
    def max_pipelines(self) -> int:
        return self.arrival.shape[0]

    @property
    def max_ops(self) -> int:
        return self.op_valid.shape[1]


class SimState(NamedTuple):
    """Full dynamic state advanced by the engine (one pytree)."""

    tick: jax.Array               # [] int32 current tick

    # ---- pipelines -------------------------------------------------------
    pipe_status: jax.Array        # [MP] int32 PipeStatus
    pipe_entered: jax.Array       # [MP] int32 tick it (re-)entered waiting
    pipe_fail_flag: jax.Array     # [MP] bool OOM-failed before (paper §4.1.2)
    pipe_last_cpus: jax.Array     # [MP] f32 last container CPU allocation
    pipe_last_ram: jax.Array      # [MP] f32 last container RAM allocation
    pipe_release: jax.Array       # [MP] int32 suspension release tick
    pipe_completion: jax.Array    # [MP] int32 completion tick (INF = not yet)
    pipe_first_start: jax.Array   # [MP] int32 first scheduling tick
    pipe_fails: jax.Array         # [MP] int32 OOM count
    pipe_preempts: jax.Array      # [MP] int32 preemption count

    # ---- containers ------------------------------------------------------
    ctr_status: jax.Array         # [MC] int32 ContainerStatus
    ctr_pipe: jax.Array           # [MC] int32 pipeline index (-1)
    ctr_pool: jax.Array           # [MC] int32
    ctr_cpus: jax.Array           # [MC] f32
    ctr_ram: jax.Array            # [MC] f32
    ctr_start: jax.Array          # [MC] int32
    ctr_end: jax.Array            # [MC] int32 completion tick
    ctr_oom: jax.Array            # [MC] int32 OOM tick (INF = will not OOM)
    ctr_prio: jax.Array           # [MC] int32 cached pipeline priority
    # ---- containers: warm/cold status (data plane) -----------------------
    ctr_warm: jax.Array           # [MC] bool — live container started warm
    slot_warm_pool: jax.Array     # [MC] int32 pool kept warm in slot (-1)
    slot_warm_until: jax.Array    # [MC] int32 warmth expiry tick

    # ---- next-event registers (incremental event tracking) ---------------
    # Invariants maintained by the executor after every transition:
    #   nxt_retire  == min over RUNNING containers of min(ctr_end, ctr_oom)
    #   nxt_release == min over SUSPENDED pipelines of pipe_release
    # so the event engines read O(1) registers instead of re-reducing the
    # container/pipeline tables at every event. ``nxt_arrival_cursor`` is
    # the engine-maintained count of arrivals <= current tick in the
    # arrival-sorted workload (binary search, not a table scan).
    nxt_retire: jax.Array         # [] int32 (INF_TICK = no running ctr)
    nxt_release: jax.Array        # [] int32 (INF_TICK = nothing suspended)
    nxt_arrival_cursor: jax.Array  # [] int32 index into sorted arrivals

    # ---- pools -----------------------------------------------------------
    pool_cpu_cap: jax.Array       # [NP] f32
    pool_ram_cap: jax.Array       # [NP] f32
    pool_cpu_free: jax.Array      # [NP] f32
    pool_ram_free: jax.Array      # [NP] f32
    # ---- pools: zero-copy intermediate-dataset cache (data plane) --------
    pool_cache_used: jax.Array    # [NP] f32 GB resident
    cache_bytes: jax.Array        # [NP, MP] f32 cached bytes per pipeline
    cache_last: jax.Array         # [NP, MP] int32 LRU last-touch tick

    # ---- metrics ---------------------------------------------------------
    done_count: jax.Array         # [] int32
    failed_count: jax.Array       # [] int32
    oom_events: jax.Array         # [] int32
    preempt_events: jax.Array     # [] int32
    sum_latency_s: jax.Array      # [] f32  Σ (completion - arrival) seconds
    sum_latency_s_prio: jax.Array  # [3] f32 per-priority latency sums
    done_prio: jax.Array          # [3] int32 per-priority completions
    util_cpu_s: jax.Array         # [NP] f32 ∫ used_cpus dt (cpu-seconds)
    util_ram_s: jax.Array         # [NP] f32 ∫ used_ram dt (GB-seconds)
    cost_dollars: jax.Array       # [] f32 allocated-resource cost integral
    util_log: jax.Array           # [B, NP, 2] f32 bucketed (cpu, ram) usage-s
    # ---- data-plane metrics ----------------------------------------------
    cache_hit_gb: jax.Array       # [] f32 input bytes served from cache
    bytes_moved_gb: jax.Array     # [] f32 input bytes scanned from storage
    cache_hits: jax.Array         # [] int32 assignments with a cache hit
    cache_lookups: jax.Array      # [] int32 assignments with any input data
    cold_starts: jax.Array        # [] int32 containers started cold
    warm_starts: jax.Array        # [] int32 containers reusing a warm slot
    cold_start_tick_total: jax.Array  # [] int32 Σ cold-start ticks charged

    # ---- chaos layer (fault injection + retry policy) --------------------
    # NOTE: every field below was appended AFTER the pre-fault schema; the
    # digest tools hash the legacy prefix by a pinned field list, so the
    # faults-off captures in tests/captures/ stay valid verbatim.
    pipe_retries: jax.Array       # [MP] int32 fault/timeout retry count
    ctr_timed: jax.Array          # [MC] bool — ctr_end is a timeout deadline
    pool_down_until: jax.Array    # [NP] int32 — pool down while tick < value
    crash_cursor: jax.Array       # [] int32 crash-trace events consumed
    outage_cursor: jax.Array      # [] int32 outage-trace events consumed
    nxt_fault: jax.Array          # [] int32 next crash/outage/recovery tick
    crash_events: jax.Array       # [] int32 crash events fired
    outage_events: jax.Array      # [] int32 outage events fired
    timeout_events: jax.Array     # [] int32 containers killed at the deadline
    retry_events: jax.Array       # [] int32 fault/timeout re-queues
    fault_kills: jax.Array        # [] int32 containers killed by crash/outage
    wasted_ticks: jax.Array       # [] int32 Σ elapsed ticks of killed work
    pool_down_s: jax.Array        # [] f32 ∫ #down-pools dt (pool-seconds)

    # ---- closed loop (client model + admission control) ------------------
    # NOTE: appended AFTER the chaos schema; the digest tools hash the
    # pre-closed-loop prefix as the complement of CLOSED_LOOP_FIELDS, so
    # the PR-6/7 captures in tests/captures/ stay valid verbatim.
    pipe_offered: jax.Array       # [MP] bool — admitted and not yet finished
    pipe_presented: jax.Array     # [MP] bool — ever offered to admission
    pipe_client_attempts: jax.Array  # [MP] int32 client-side retry count
    offered_total: jax.Array      # [] int32 offers presented (re-offers count)
    offered_unique: jax.Array     # [] int32 distinct pipelines ever offered
    admitted_total: jax.Array     # [] int32 offers admitted
    shed_total: jax.Array         # [] int32 offers REJECTed by admission
    deferred_total: jax.Array     # [] int32 offers deferred (client or policy)
    client_retry_events: jax.Array  # [] int32 rejects turned into client retries
    offered_prio: jax.Array       # [3] int32 per-priority offers
    admitted_prio: jax.Array      # [3] int32 per-priority admissions
    admit_tokens: jax.Array       # [] f32 token-bucket level
    admit_last_tick: jax.Array    # [] int32 last token-bucket refill tick
    codel_above_since: jax.Array  # [] int32 first tick delay exceeded target
    last_fault_tick: jax.Array    # [] int32 most recent crash/outage tick
    prefault_backlog: jax.Array   # [] int32 WAITING count at the first fault
    drain_tick: jax.Array         # [] int32 backlog-drained tick post-fault

    @property
    def max_containers(self) -> int:
        return self.ctr_status.shape[0]


# the chaos-layer fields, in declaration order — the single source of
# truth for the digest tools' pinned legacy field list (everything NOT
# here predates fault injection, so tests/captures/ hashes stay valid)
CHAOS_FIELDS = (
    "pipe_retries",
    "ctr_timed",
    "pool_down_until",
    "crash_cursor",
    "outage_cursor",
    "nxt_fault",
    "crash_events",
    "outage_events",
    "timeout_events",
    "retry_events",
    "fault_kills",
    "wasted_ticks",
    "pool_down_s",
)


# the closed-loop fields, in declaration order — everything NOT in
# CHAOS_FIELDS or CLOSED_LOOP_FIELDS predates both layers, so the digest
# tools can keep hashing the legacy prefix (and the chaos captures hash
# everything but this tuple) without re-recording.
CLOSED_LOOP_FIELDS = (
    "pipe_offered",
    "pipe_presented",
    "pipe_client_attempts",
    "offered_total",
    "offered_unique",
    "admitted_total",
    "shed_total",
    "deferred_total",
    "client_retry_events",
    "offered_prio",
    "admitted_prio",
    "admit_tokens",
    "admit_last_tick",
    "codel_above_since",
    "last_fault_tick",
    "prefault_backlog",
    "drain_tick",
)


def init_state(params: SimParams) -> SimState:
    MP = params.max_pipelines
    MC = params.max_containers
    NP = params.num_pools
    B = params.util_log_buckets
    f32 = jnp.float32
    i32 = jnp.int32
    # cloud scaling (§3.2.2): extra capacity is available at a cost premium;
    # the cost integral charges the premium for usage beyond the base cap.
    factor = params.cloud_scale_max_factor if params.cloud_scaling else 1.0
    pool_cpu = jnp.full((NP,), params.pool_cpus * factor, f32)
    pool_ram = jnp.full((NP,), params.pool_ram_gb * factor, f32)
    return SimState(
        tick=jnp.asarray(0, i32),
        pipe_status=jnp.full((MP,), int(PipeStatus.EMPTY), i32),
        pipe_entered=jnp.full((MP,), INF_TICK, i32),
        pipe_fail_flag=jnp.zeros((MP,), bool),
        pipe_last_cpus=jnp.zeros((MP,), f32),
        pipe_last_ram=jnp.zeros((MP,), f32),
        pipe_release=jnp.full((MP,), INF_TICK, i32),
        pipe_completion=jnp.full((MP,), INF_TICK, i32),
        pipe_first_start=jnp.full((MP,), INF_TICK, i32),
        pipe_fails=jnp.zeros((MP,), i32),
        pipe_preempts=jnp.zeros((MP,), i32),
        ctr_status=jnp.full((MC,), int(ContainerStatus.EMPTY), i32),
        ctr_pipe=jnp.full((MC,), -1, i32),
        ctr_pool=jnp.zeros((MC,), i32),
        ctr_cpus=jnp.zeros((MC,), f32),
        ctr_ram=jnp.zeros((MC,), f32),
        ctr_start=jnp.full((MC,), INF_TICK, i32),
        ctr_end=jnp.full((MC,), INF_TICK, i32),
        ctr_oom=jnp.full((MC,), INF_TICK, i32),
        ctr_prio=jnp.full((MC,), -1, i32),
        ctr_warm=jnp.zeros((MC,), bool),
        slot_warm_pool=jnp.full((MC,), -1, i32),
        slot_warm_until=jnp.zeros((MC,), i32),
        nxt_retire=jnp.asarray(INF_TICK, i32),
        nxt_release=jnp.asarray(INF_TICK, i32),
        nxt_arrival_cursor=jnp.asarray(0, i32),
        pool_cpu_cap=pool_cpu,
        pool_ram_cap=pool_ram,
        pool_cpu_free=pool_cpu,
        pool_ram_free=pool_ram,
        pool_cache_used=jnp.zeros((NP,), f32),
        cache_bytes=jnp.zeros((NP, MP), f32),
        cache_last=jnp.zeros((NP, MP), i32),
        done_count=jnp.asarray(0, i32),
        failed_count=jnp.asarray(0, i32),
        oom_events=jnp.asarray(0, i32),
        preempt_events=jnp.asarray(0, i32),
        sum_latency_s=jnp.asarray(0.0, f32),
        sum_latency_s_prio=jnp.zeros((3,), f32),
        done_prio=jnp.zeros((3,), i32),
        util_cpu_s=jnp.zeros((NP,), f32),
        util_ram_s=jnp.zeros((NP,), f32),
        cost_dollars=jnp.asarray(0.0, f32),
        util_log=jnp.zeros((B, NP, 2), f32),
        cache_hit_gb=jnp.asarray(0.0, f32),
        bytes_moved_gb=jnp.asarray(0.0, f32),
        cache_hits=jnp.asarray(0, i32),
        cache_lookups=jnp.asarray(0, i32),
        cold_starts=jnp.asarray(0, i32),
        warm_starts=jnp.asarray(0, i32),
        cold_start_tick_total=jnp.asarray(0, i32),
        pipe_retries=jnp.zeros((MP,), i32),
        ctr_timed=jnp.zeros((MC,), bool),
        pool_down_until=jnp.zeros((NP,), i32),
        crash_cursor=jnp.asarray(0, i32),
        outage_cursor=jnp.asarray(0, i32),
        # seeded *due* (0) when the chaos layer is on so the engine's
        # register-gated fault pass runs at the first event and computes
        # the true register; the seed value never reaches a final state.
        # Faults off it stays pinned at INF_TICK (and the gate never
        # fires), keeping the faults-off captures valid verbatim.
        nxt_fault=jnp.asarray(
            0 if params.fault_events_active else INF_TICK, i32
        ),
        crash_events=jnp.asarray(0, i32),
        outage_events=jnp.asarray(0, i32),
        timeout_events=jnp.asarray(0, i32),
        retry_events=jnp.asarray(0, i32),
        fault_kills=jnp.asarray(0, i32),
        wasted_ticks=jnp.asarray(0, i32),
        pool_down_s=jnp.asarray(0.0, f32),
        pipe_offered=jnp.zeros((MP,), bool),
        pipe_presented=jnp.zeros((MP,), bool),
        pipe_client_attempts=jnp.zeros((MP,), i32),
        offered_total=jnp.asarray(0, i32),
        offered_unique=jnp.asarray(0, i32),
        admitted_total=jnp.asarray(0, i32),
        shed_total=jnp.asarray(0, i32),
        deferred_total=jnp.asarray(0, i32),
        client_retry_events=jnp.asarray(0, i32),
        offered_prio=jnp.zeros((3,), i32),
        admitted_prio=jnp.zeros((3,), i32),
        # the token bucket starts full (burst capacity)
        admit_tokens=jnp.asarray(params.admit_burst, f32),
        admit_last_tick=jnp.asarray(0, i32),
        codel_above_since=jnp.asarray(INF_TICK, i32),
        last_fault_tick=jnp.asarray(INF_TICK, i32),
        prefault_backlog=jnp.asarray(-1, i32),
        drain_tick=jnp.asarray(INF_TICK, i32),
    )


# ---------------------------------------------------------------------------
# Container runtime model (paper §3.2.2): at creation, the container uses
# its operator set + allocation to compute completion / OOM ticks.
# DAG semantics (DESIGN.md §2): ops grouped by topological level; same-level
# ops share CPUs evenly; level RAM = Σ op RAM; OOM at first over-RAM level.
# ---------------------------------------------------------------------------
def container_schedule(
    wl: Workload,
    pipe: jax.Array,
    cpus: jax.Array,
    ram: jax.Array,
    ops_mask: jax.Array | None = None,
):
    """Return (duration_ticks, oom_offset_ticks) for running ``pipe``.

    ``oom_offset`` is INF_TICK when the allocation is RAM-sufficient.
    All inputs may be traced; vectorise with vmap over assignments.
    """
    MO = wl.max_ops
    valid = wl.op_valid[pipe]
    if ops_mask is not None:
        valid = valid & ops_mask
    level = wl.op_level[pipe]
    ram_op = wl.op_ram[pipe]
    base = wl.op_base[pipe]
    alpha = wl.op_alpha[pipe]

    levels = jnp.arange(MO, dtype=jnp.int32)
    onehot = (level[None, :] == levels[:, None]) & valid[None, :]  # [MO, MO]
    width = jnp.sum(onehot, axis=1).astype(jnp.float32)            # [MO]
    has_level = width > 0
    c_eff = cpus / jnp.maximum(width, 1.0)                          # [MO]
    c_eff = jnp.maximum(c_eff, 1e-6)
    # per-op runtime at its level's effective CPUs
    t_op = base / jnp.power(c_eff[level], alpha)                    # [MO]
    t_op = jnp.where(valid, t_op, 0.0)
    t_level = jnp.max(jnp.where(onehot, t_op[None, :], 0.0), axis=1)  # [MO]
    t_level = jnp.where(has_level, jnp.ceil(jnp.maximum(t_level, 1.0)), 0.0)
    ram_level = jnp.sum(jnp.where(onehot, ram_op[None, :], 0.0), axis=1)

    cum_start = jnp.cumsum(t_level) - t_level                       # [MO]
    duration = jnp.sum(t_level).astype(jnp.int32)
    duration = jnp.maximum(duration, 1)

    oom_at = has_level & (ram_level > ram + 1e-6)
    oom_start = jnp.where(oom_at, cum_start, jnp.inf)
    oom_min = jnp.min(oom_start)
    oom_offset = jnp.where(
        jnp.isinf(oom_min),
        INF_TICK,
        jnp.maximum(oom_min.astype(jnp.int32), 1),
    )
    return duration, oom_offset


# ---------------------------------------------------------------------------
# Zero-copy cache transition (data plane). One pool row at a time: the
# executor calls this per assignment. Mirrored op-for-op (f32, same
# association order) by ``engine_python._cache_insert_py`` — engine
# equivalence depends on the two staying in lockstep.
# ---------------------------------------------------------------------------
def cache_insert(
    row_bytes: jax.Array,   # [MP] f32 cached bytes on this pool
    row_last: jax.Array,    # [MP] int32 last-touch ticks
    used: jax.Array,        # [] f32 pool cache occupancy
    pipe: jax.Array,        # [] int32 pipeline whose dataset is materialised
    size: jax.Array,        # [] f32 dataset size (GB)
    tick: jax.Array,        # [] int32 insertion tick (becomes last-touch)
    cap: float,             # python float — per-pool cache capacity
):
    """Insert ``pipe``'s intermediates, LRU-evicting (last-touch asc,
    pipe asc) until the dataset fits. Datasets larger than the whole
    cache are never inserted. Returns (row_bytes, row_last, used)."""
    MP = row_bytes.shape[0]
    cap32 = jnp.float32(cap)
    cached = row_bytes[pipe]
    fits_cache = size <= cap32
    # bytes that must be freed before the (re-)insert fits
    need = used - cached + size - cap32
    evictable = (row_bytes > 0) & (jnp.arange(MP, dtype=jnp.int32) != pipe)
    order = jnp.argsort(jnp.where(evictable, row_last, INF_TICK), stable=True)
    freed_sorted = jnp.where(evictable[order], row_bytes[order], 0.0)
    cum = jnp.cumsum(freed_sorted)
    evict_sorted = evictable[order] & ((cum - freed_sorted) < need) & (need > 0)
    evict = jnp.zeros((MP,), bool).at[order].set(evict_sorted)
    freed_total = jnp.max(jnp.where(evict_sorted, cum, 0.0))
    new_bytes = jnp.where(evict, 0.0, row_bytes).at[pipe].set(size)
    new_last = jnp.where(evict, 0, row_last).at[pipe].set(tick)
    new_used = used - freed_total - cached + size
    return (
        jnp.where(fits_cache, new_bytes, row_bytes),
        jnp.where(fits_cache, new_last, row_last),
        jnp.where(fits_cache, new_used, used),
    )


def broadcast_lanes(tree, n_lanes: int):
    """Broadcast a single-sim pytree to ``n_lanes`` lane-major copies.

    Every leaf gains a leading fleet axis ``[F, ...]`` — the layout the
    unified engine advances. Works on ``SimState``, scheduler states and
    any other pytree (including ``None``-leaved ones).
    """

    def b(x):
        x = jnp.asarray(x)
        return jnp.broadcast_to(x, (n_lanes,) + x.shape)

    return jax.tree.map(b, tree)


def used_resources(state: SimState):
    """Per-pool (used_cpus, used_ram) from live containers."""
    NP = state.pool_cpu_cap.shape[0]
    live = state.ctr_status == int(ContainerStatus.RUNNING)
    pool_onehot = (
        state.ctr_pool[None, :] == jnp.arange(NP, dtype=jnp.int32)[:, None]
    ) & live[None, :]
    used_cpu = jnp.sum(jnp.where(pool_onehot, state.ctr_cpus[None, :], 0.0), axis=1)
    used_ram = jnp.sum(jnp.where(pool_onehot, state.ctr_ram[None, :], 0.0), axis=1)
    return used_cpu, used_ram


def seconds(ticks: jax.Array) -> jax.Array:
    return ticks.astype(jnp.float32) / TICKS_PER_SECOND


__all__ = [
    "INF_TICK",
    "CHAOS_FIELDS",
    "CLOSED_LOOP_FIELDS",
    "FaultTrace",
    "Workload",
    "SimState",
    "init_state",
    "broadcast_lanes",
    "container_schedule",
    "used_resources",
    "seconds",
]
