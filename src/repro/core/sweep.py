"""Simulation fleets: the device-scale payoff of the lane-major core.

The paper pitches Eudoxia as "a cheap mechanism for developers to
evaluate different scheduling algorithms against their infrastructure".
Because the whole simulator is one lane-major XLA program
(``engine._fleet_compiled``), *cheap* becomes *massively parallel*:

* a fleet of seeds is just more lanes in the batch axis — Monte-Carlo
  policy evaluation in a single compiled program (and with
  ``workloads=`` the lanes are recorded traces or scenario-library
  batches instead: replay yesterday's production day under four
  candidate policies in one call), and
* ``fleet_run(..., shard="auto")`` splits the fleet axis across every
  local device with ``shard_map``: each device runs the engine's shared
  while_loop on its own lanes and exits when *its* lanes drain, with no
  cross-device synchronisation at all (there are no collectives in the
  engine). Before sharding, lanes are *binned by event density*
  (``bin_lanes_by_density``): sorted by predicted event count so each
  device gets a contiguous block of similar drain time — the slow lanes
  share one device instead of dragging every device's max-over-lanes
  loops. Lanes are padded to a device multiple inside this module, and
  both the padding and the binning permutation are undone before
  returning.

``fleet_run`` is also what the serving layer uses to pick an admission /
preemption policy before it touches the real cluster (DESIGN.md §4).
"""
from __future__ import annotations

import functools
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.compat import shard_map

from . import metrics
from .engine import _fleet_compiled, _quiet_partial_donation
from .params import SimParams
from .state import INF_TICK, SimState, Workload
from .types import TICKS_PER_SECOND
from .workload import generate_workload, workload_batch_from_traces  # noqa: F401  (re-export: batch ingestion pairs with fleet_run)


def make_workload_batch(params: SimParams, seeds: Sequence[int]) -> Workload:
    """One seed-generated workload per fleet lane, built in one vmap.

    The key derivation is vmapped too (no per-seed Python round-trip),
    so fleets in the thousands construct host-loop-free; lane ``i`` is
    bitwise ``generate_workload(params, PRNGKey(seeds[i]))``.

    >>> from repro.core import SimParams, make_workload_batch
    >>> p = SimParams(max_pipelines=8, max_ops_per_pipeline=4)
    >>> batch = make_workload_batch(p, seeds=[0, 1, 2])
    >>> batch.arrival.shape, batch.op_ram.shape
    ((3, 8), (3, 8, 4))
    """
    keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(seeds, jnp.uint32))
    return jax.vmap(lambda k: generate_workload(params, k))(keys)


def pad_lanes(wls: Workload, n_lanes: int) -> Workload:
    """Pad the fleet axis of ``wls`` up to ``n_lanes``.

    Padding lanes replicate lane 0's shapes but have every arrival at
    INF_TICK, so the engine retires them in a single event (no arrivals
    -> the first next-event jump lands on the horizon) — they cost one
    loop iteration, not a simulation.

    >>> import numpy as np
    >>> from repro.core import SimParams, make_workload_batch
    >>> from repro.core.sweep import pad_lanes
    >>> from repro.core.state import INF_TICK
    >>> p = SimParams(max_pipelines=8, max_ops_per_pipeline=4)
    >>> padded = pad_lanes(make_workload_batch(p, [0, 1]), 4)
    >>> padded.arrival.shape
    (4, 8)
    >>> bool((np.asarray(padded.arrival)[2:] == INF_TICK).all())
    True
    """
    F = wls.arrival.shape[0]
    pad = n_lanes - F
    if pad <= 0:
        return wls

    def pad_leaf(x):
        fill = jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])
        return jnp.concatenate([x, fill], axis=0)

    padded = jax.tree.map(pad_leaf, wls)
    padded = padded._replace(
        arrival=padded.arrival.at[F:].set(INF_TICK)
    )
    if padded.faults is not None:
        # padding lanes must stay single-event: a replicated fault trace
        # would wake them for every crash/outage of lane 0
        padded = padded._replace(
            faults=padded.faults._replace(
                crash_time=padded.faults.crash_time.at[F:].set(INF_TICK),
                outage_start=padded.faults.outage_start.at[F:].set(INF_TICK),
            )
        )
    return padded


def attach_policies(wls: Workload, policies) -> Workload:
    """Attach :class:`~repro.core.policy.PolicyParams` vectors to a
    workload batch for the dynamic ``"policy"`` scheduler family.

    ``policies`` is ``[F, P]`` (one policy per lane), a single ``[P]``
    vector broadcast to every lane, or a ``PolicyParams`` /sequence of
    them. The vectors ride the workload pytree, so ``pad_lanes``,
    ``bin_lanes_by_density`` and device sharding treat them like any
    other per-lane leaf — lane ``i`` always simulates under policy
    ``i``, whatever the binning or sharding.

    >>> import numpy as np
    >>> from repro.core import SimParams, make_workload_batch
    >>> from repro.core.policy import DEFAULT_POINTS
    >>> from repro.core.sweep import attach_policies
    >>> p = SimParams(max_pipelines=8, max_ops_per_pipeline=4)
    >>> wls = attach_policies(make_workload_batch(p, [0, 1]),
    ...                       DEFAULT_POINTS["sjf"])
    >>> wls.policy.shape
    (2, 15)
    """
    from .policy import N_POLICY_PARAMS, PolicyParams

    if isinstance(policies, PolicyParams):
        policies = policies.to_vector()
    elif isinstance(policies, (list, tuple)) and policies and isinstance(
        policies[0], PolicyParams
    ):
        policies = np.stack([p.to_vector() for p in policies])
    pol = jnp.asarray(policies, jnp.float32)
    F = wls.arrival.shape[0]
    if pol.ndim == 1:
        pol = jnp.broadcast_to(pol, (F, pol.shape[0]))
    if pol.shape != (F, N_POLICY_PARAMS):
        raise ValueError(
            f"policies must be [{F}, {N_POLICY_PARAMS}] (one PolicyParams "
            f"vector per lane) or a single [{N_POLICY_PARAMS}] vector, "
            f"got {pol.shape}"
        )
    return wls._replace(policy=pol)


def policy_grid_workloads(
    wls: Workload, policies
) -> tuple[Workload, int, int]:
    """Tile a scenario batch across a policy grid on the fleet axis.

    ``wls`` is an ``[S, ...]`` scenario batch (e.g. from
    ``scenario_fleet``), ``policies`` a ``[C, P]`` candidate grid (or a
    sequence of ``PolicyParams``). Returns ``(grid_wls, C, S)`` where
    ``grid_wls`` is the ``[C*S, ...]`` batch whose lane ``c*S + s``
    runs scenario ``s`` under candidate ``c`` — one ``fleet_run`` with
    ``scheduler_key="policy"`` evaluates the whole grid, sharded and
    lane-binned like any other fleet.

    >>> import numpy as np
    >>> from repro.core import SimParams, make_workload_batch
    >>> from repro.core.policy import DEFAULT_POINTS
    >>> from repro.core.sweep import policy_grid_workloads
    >>> p = SimParams(max_pipelines=8, max_ops_per_pipeline=4)
    >>> grid, C, S = policy_grid_workloads(
    ...     make_workload_batch(p, [0, 1, 2]),
    ...     [DEFAULT_POINTS["priority"], DEFAULT_POINTS["sjf"]])
    >>> grid.arrival.shape, (C, S)
    ((6, 8), (2, 3))
    >>> grid.policy.shape
    (6, 15)
    """
    from .policy import N_POLICY_PARAMS, PolicyParams

    if isinstance(policies, (list, tuple)) and policies and isinstance(
        policies[0], PolicyParams
    ):
        policies = np.stack([p.to_vector() for p in policies])
    pol = jnp.asarray(policies, jnp.float32)
    if pol.ndim != 2 or pol.shape[1] != N_POLICY_PARAMS:
        raise ValueError(
            f"policies must be a [C, {N_POLICY_PARAMS}] grid, got "
            f"{pol.shape}"
        )
    if wls.policy is not None:
        raise ValueError(
            "scenario batch already carries policy vectors; build the "
            "grid from a policy-free batch"
        )
    C = int(pol.shape[0])
    S = int(wls.arrival.shape[0])
    tiled = jax.tree.map(
        lambda x: jnp.tile(x, (C,) + (1,) * (x.ndim - 1)), wls
    )
    return tiled._replace(policy=jnp.repeat(pol, S, axis=0)), C, S


@functools.partial(
    jax.jit,
    static_argnames=(
        "params", "scheduler_key", "impl", "n_shards", "trace_capacity"
    ),
    donate_argnames=("workloads",),
)
def _fleet_sharded(
    params: SimParams,
    workloads: Workload,  # [F, ...] with F a multiple of n_shards
    scheduler_key: str,
    impl: str,
    n_shards: int,
    trace_capacity: int = 0,
):
    """shard_map the lane-major core over the fleet axis of a 1-D local
    device mesh. Each shard is an independent run of the same engine on
    F/n_shards lanes; per-lane results are bitwise those of the
    unsharded call (tests/test_fleet.py asserts it lane-for-lane).
    ``workloads`` is donated, as in ``engine._fleet_compiled``. With a
    positive (static) ``trace_capacity``, each shard also records its
    lanes' trace buffers and the return is ``(states, tbufs)``, both
    fleet-sharded."""
    mesh = jax.sharding.Mesh(
        np.asarray(jax.local_devices()[:n_shards]), ("fleet",)
    )
    spec = jax.sharding.PartitionSpec("fleet")

    def shard_fn(wls):
        out = _fleet_compiled(
            params, wls, scheduler_key, impl, trace_capacity=trace_capacity
        )
        if trace_capacity:
            states, _, tbufs = out
            return states, tbufs
        states, _ = out
        return states

    return shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(spec,),
        out_specs=spec,
        check_vma=False,
    )(workloads)


def predicted_lane_events(wls: Workload, params: SimParams) -> np.ndarray:
    """Per-lane predicted event count, the lane-binning sort key.

    The engine's work per lane is proportional to its event count, and
    (absent preemption storms) events are dominated by arrivals: each
    arrival inside the horizon admits once and retires once. The count
    of realised arrivals IS the lane's realised arrival density — the
    per-lane draw of the ``waiting_ticks_mean``-controlled arrival
    process — so it predicts drain time without running anything.
    """
    horizon = params.horizon_ticks
    return np.asarray(jnp.sum(wls.arrival < horizon, axis=-1))


def bin_lanes_by_density(
    wls: Workload, params: SimParams
) -> tuple[Workload, np.ndarray]:
    """Sort the fleet axis by predicted event count, heaviest first.

    Returns ``(sorted_wls, inverse_permutation)``. Device-sharding the
    *sorted* fleet gives each device a contiguous block of
    similar-drain-time lanes, so the per-device shared while_loop (and
    every early-exit scheduler loop inside it, whose vmapped trip count
    is the max over that device's lanes) stops as its own block drains
    instead of every device paying the global tail. The sort is stable,
    so equal-density lanes keep their order; padding lanes (appended
    after binning) are the lightest and land on the last device.

    >>> from repro.core import SimParams, make_workload_batch
    >>> from repro.core.sweep import bin_lanes_by_density
    >>> p = SimParams(max_pipelines=8, max_ops_per_pipeline=4)
    >>> sorted_wls, inv = bin_lanes_by_density(
    ...     make_workload_batch(p, [0, 1, 2]), p)
    >>> sorted_wls.arrival.shape, inv.shape
    ((3, 8), (3,))
    """
    score = predicted_lane_events(wls, params)
    order = np.argsort(-score, kind="stable")
    inv = np.argsort(order)
    return jax.tree.map(lambda x: x[order], wls), inv


@functools.partial(jax.jit, donate_argnames=("states",))
def _unbin_states(states: SimState, inv):
    """Undo the binning permutation (and drop padding lanes: ``inv``
    only addresses real lanes, which binning sorted ahead of the
    padding) in ONE compiled gather. Doing this eagerly — one host
    gather per SimState field on device-sharded arrays — costs more
    than the binning saves; compiled, it is a single fused reshard.
    ``states`` is donated: the binned-order copy dies here."""
    return jax.tree.map(lambda x: x[inv], states)


def _resolve_shards(shard, fleet_size: int) -> int:
    if shard is None:
        return 1
    n_dev = jax.local_device_count()
    n = n_dev if shard == "auto" else int(shard)
    if n > n_dev:
        raise ValueError(
            f"shard={shard!r} asks for {n} devices but only {n_dev} are "
            "local (hint for CPU testing: "
            "XLA_FLAGS=--xla_force_host_platform_device_count=4)"
        )
    return max(1, min(n, fleet_size))


def fleet_run(
    params: SimParams,
    seeds: Sequence[int] | None = None,
    scheduler_key: str | None = None,
    *,
    workloads: Workload | None = None,
    shard: str | int | None = None,
    impl: str = "auto",
    bin_lanes: bool = True,
    fleet_engine: str | None = None,
    trace: bool = False,
    trace_capacity: int | None = None,
) -> SimState:
    """Run a fleet of simulations in parallel on the lane-major core.

    The fleet is either ``len(seeds)`` seed-generated lanes (Monte-Carlo
    policy evaluation) or, with ``workloads=``, a caller-built batch —
    e.g. one recorded trace per lane via ``workload_batch_from_traces``
    or the scenario library (``repro.core.scenarios``). Exactly one of
    ``seeds`` / ``workloads`` must be given; a ``workloads`` batch is
    treated as CONSUMED (it is donated to the compiled core — rebuild
    it if you need the arrays afterwards).

    ``shard=None`` (default) keeps the whole fleet on one device;
    ``shard="auto"`` splits the fleet axis across all local devices with
    ``shard_map`` (``shard=n`` for the first n). Lane padding to a
    device multiple is handled here and stripped from the result.
    Returns the batched final SimState (leading axis = fleet member),
    per-lane bitwise-identical whatever the sharding.

    ``bin_lanes`` (sharded runs only) sorts the fleet axis by predicted
    event count before sharding — each device gets lanes of similar
    drain time, cutting the tail iterations every max-over-lanes loop
    pays — and unpermutes the result, so lane ``i`` of the output is
    lane ``i`` of the input bitwise whatever the binning (lanes are
    independent; tests/test_sched_select.py asserts on-vs-off
    equality).

    ``fleet_engine`` is deprecated: the fused lane-major engine is the
    only simulation core (the legacy ``"vmap"`` path was deleted).

    ``trace=True`` records an on-device event trace per lane (capacity
    ``trace_capacity`` records each, default
    ``telemetry.DEFAULT_TRACE_CAPACITY``) and returns
    ``(states, traces)`` with ``traces[i]`` the lane-``i``
    :class:`repro.core.telemetry.TraceEvents`; per-lane states stay
    bitwise-identical to an untraced run, whatever the sharding or
    binning (traces ride the same unbinning permutation as the states).

    >>> from repro.core import SimParams, fleet_run, fleet_summary
    >>> p = SimParams(duration=0.01, max_pipelines=8, max_containers=8,
    ...               max_ops_per_pipeline=4, waiting_ticks_mean=300.0,
    ...               op_base_seconds_mean=0.002)
    >>> states = fleet_run(p, seeds=[0, 1])
    >>> int(states.done_count.shape[0])
    2
    >>> sorted(fleet_summary(states, p))[:2]
    ['admitted_fraction_mean', 'admitted_mean']
    """
    if (seeds is None) == (workloads is None):
        raise ValueError(
            "fleet_run needs exactly one of seeds= (generated lanes) or "
            "workloads= (a trace/scenario batch)"
        )
    if fleet_engine is not None:
        warnings.warn(
            "fleet_engine is deprecated and ignored unless it names the "
            "removed path: the fused lane-major engine is the only core",
            DeprecationWarning,
            stacklevel=2,
        )
        if fleet_engine != "fused":
            raise ValueError(
                f"fleet_engine={fleet_engine!r} was removed in the "
                "lane-major unification; the fused engine is the only path"
            )
    scheduler_key = scheduler_key or params.scheduling_algo
    if workloads is not None:
        # catch the returned-params footgun early: a batch built with
        # derived capacities must run with the params that carry them,
        # or the schedulers' [MP]-shaped masks break deep inside jit
        if workloads.arrival.ndim != 2:
            raise ValueError(
                f"workloads must be a BATCH (arrival [F, MP]), got "
                f"arrival shape {workloads.arrival.shape}; wrap a single "
                "trace with workload_batch_from_traces([records], params)"
            )
        got = (workloads.arrival.shape[-1], workloads.op_valid.shape[-1])
        want = (params.max_pipelines, params.max_ops_per_pipeline)
        if got != want:
            raise ValueError(
                f"workloads batch is shaped {got} "
                "(max_pipelines, max_ops_per_pipeline) but params say "
                f"{want}; run with the params returned by "
                "workload_batch_from_traces / scenario_fleet"
            )
    capacity = 0
    if trace:
        from .telemetry.schema import DEFAULT_TRACE_CAPACITY

        capacity = int(
            DEFAULT_TRACE_CAPACITY if trace_capacity is None else trace_capacity
        )
        if capacity <= 0:
            raise ValueError(
                f"trace_capacity must be positive, got {trace_capacity}"
            )
    wls = workloads if seeds is None else make_workload_batch(params, seeds)
    if scheduler_key.replace("-", "_").lower() == "policy" and wls.policy is None:
        raise ValueError(
            "scheduler 'policy' needs per-lane PolicyParams vectors on "
            "the workload batch; attach them with attach_policies(wls, "
            "policies) or build a grid with policy_grid_workloads"
        )
    if params.fault_trace_active and wls.faults is None:
        # trace/scenario batches carry no fault traces of their own;
        # derive the per-lane chaos schedule from params.seed so replays
        # under fault injection stay reproducible (docs/faults.md)
        from .faults import attach_fault_traces

        wls = attach_fault_traces(wls, params)
    F = wls.arrival.shape[0]
    n_shards = _resolve_shards(shard, F)
    tbufs = None
    if n_shards <= 1:
        with _quiet_partial_donation():
            out = _fleet_compiled(
                params, wls, scheduler_key, impl, trace_capacity=capacity
            )
        if capacity:
            states, _, tbufs = out
            return states, _decode_traces(tbufs)
        states, _ = out
        return states
    inv = None
    if bin_lanes:
        wls, inv = bin_lanes_by_density(wls, params)
    F_pad = -(-F // n_shards) * n_shards
    with _quiet_partial_donation():
        out = _fleet_sharded(
            params, pad_lanes(wls, F_pad), scheduler_key, impl, n_shards,
            trace_capacity=capacity,
        )
    states, tbufs = out if capacity else (out, None)
    if inv is not None:
        # one gather: unpermute AND strip padding (inv addresses only
        # real lanes; binning put the padding last). Trace buffers join
        # the states in one pytree so they ride the same permutation.
        inv = jnp.asarray(inv)
        if tbufs is not None:
            states, tbufs = _unbin_states((states, tbufs), inv)
        else:
            states = _unbin_states(states, inv)
    elif F_pad != F:
        states = jax.tree.map(lambda x: x[:F], states)
        if tbufs is not None:
            tbufs = jax.tree.map(lambda x: x[:F], tbufs)
    if capacity:
        return states, _decode_traces(tbufs)
    return states


def _decode_traces(tbufs):
    import numpy as np

    from .telemetry.decode import decode_fleet

    # only ship the populated prefix to the host: slice the device-side
    # tables to the fleet's max count, rounded up to a power of two so
    # the slice shapes (and their compiled executables) stay cached
    counts = np.asarray(tbufs.count)
    cap = int(tbufs.records.shape[1])
    hi = int(counts.max(initial=0))
    keep = min(cap, 1 << max(hi - 1, 0).bit_length()) if hi else 0
    if keep < cap:
        tbufs = tbufs._replace(records=tbufs.records[:, :keep])
    return decode_fleet(tbufs, capacity=cap)


def fleet_summary(states: SimState, params: SimParams, traces=None) -> dict:
    """Aggregate fleet statistics (mean/std across fleet members).

    ``traces`` (the list returned by ``fleet_run(..., trace=True)``) is
    optional; when given, the summary also reports the fleet-total
    recorder overflow counter ``events_dropped_total``.
    """
    done = np.asarray(states.done_count)
    lat = np.asarray(states.sum_latency_s) / np.maximum(done, 1)
    util = np.asarray(states.util_cpu_s).sum(-1) / (
        params.total_cpus * params.duration
    )
    out = {
        "fleet_size": int(done.shape[0]),
        "throughput_per_s_mean": float(done.mean() / params.duration),
        "throughput_per_s_std": float(done.std() / params.duration),
        "mean_latency_s_mean": float(lat.mean()),
        "mean_latency_s_std": float(lat.std()),
        "cpu_utilization_mean": float(util.mean()),
        "oom_events_mean": float(np.asarray(states.oom_events).mean()),
        "preempt_events_mean": float(np.asarray(states.preempt_events).mean()),
        "cost_dollars_mean": float(np.asarray(states.cost_dollars).mean()),
        # ---- data plane (fleet means) -------------------------------------
        "cache_hit_gb_mean": float(np.asarray(states.cache_hit_gb).mean()),
        "bytes_moved_gb_mean": float(
            np.asarray(states.bytes_moved_gb).mean()
        ),
        "cache_hit_rate_mean": _fleet_hit_rate(states),
        "cold_starts_mean": float(np.asarray(states.cold_starts).mean()),
        "warm_starts_mean": float(np.asarray(states.warm_starts).mean()),
        # ---- chaos layer (fleet means, zero when faults are off) ----------
        "crash_events_mean": float(np.asarray(states.crash_events).mean()),
        "outage_events_mean": float(np.asarray(states.outage_events).mean()),
        "fault_kills_mean": float(np.asarray(states.fault_kills).mean()),
        "timeouts_mean": float(np.asarray(states.timeout_events).mean()),
        "retries_mean": float(np.asarray(states.retry_events).mean()),
        "failed_mean": float(np.asarray(states.failed_count).mean()),
        "wasted_work_s_mean": float(
            np.asarray(states.wasted_ticks).mean() / TICKS_PER_SECOND
        ),
        "pool_down_s_mean": float(np.asarray(states.pool_down_s).mean()),
    }
    # ---- closed loop / overload (fleet means, zero when the loop is off) --
    offered = np.asarray(states.offered_total, dtype=np.float64)
    admitted = np.asarray(states.admitted_total, dtype=np.float64)
    out.update(
        {
            "offered_mean": float(offered.mean()),
            "admitted_mean": float(admitted.mean()),
            "shed_mean": float(np.asarray(states.shed_total).mean()),
            "deferred_mean": float(np.asarray(states.deferred_total).mean()),
            "client_retries_mean": float(
                np.asarray(states.client_retry_events).mean()
            ),
            "admitted_fraction_mean": float(
                (admitted[offered > 0] / offered[offered > 0]).mean()
            )
            if np.any(offered > 0)
            else float("nan"),
            # Jain's index over per-lane completed work: how evenly the
            # fleet's lanes were served (docs/closed-loop.md)
            "fairness_jain_done": metrics._jain(done),
        }
    )
    if traces is not None:
        out["events_dropped_total"] = int(
            sum(t.events_dropped for t in traces)
        )
    return out


def _fleet_hit_rate(states: SimState) -> float:
    hit = np.asarray(states.cache_hit_gb, dtype=np.float64)
    moved = np.asarray(states.bytes_moved_gb, dtype=np.float64)
    total = hit + moved
    rates = np.where(total > 0, hit / np.maximum(total, 1e-12), 0.0)
    return float(rates.mean())


__all__ = [
    "attach_policies",
    "fleet_run",
    "fleet_summary",
    "make_workload_batch",
    "policy_grid_workloads",
    "workload_batch_from_traces",
    "pad_lanes",
    "bin_lanes_by_density",
    "predicted_lane_events",
    "_fleet_compiled",
]
