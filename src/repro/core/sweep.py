"""Simulation fleets: the TPU-native payoff of the SoA redesign.

The paper pitches Eudoxia as "a cheap mechanism for developers to
evaluate different scheduling algorithms against their infrastructure".
On a TPU pod, *cheap* becomes *massively parallel*: because one
simulation is a pure JAX program over fixed-shape arrays, we can

* ``vmap`` it over seeds / workload parameters -> Monte-Carlo policy
  evaluation in a single XLA program, and
* ``shard_map`` that batch over the ``data`` axis of a production mesh,
  scaling to thousands of concurrent simulations.

``fleet_run`` is also what the serving layer uses to pick an admission /
preemption policy before it touches the real cluster (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .engine import (
    _run_event_engine,
    _run_fleet_event_engine,
    _run_tick_engine,
)
from .params import SimParams
from .scheduler import (
    get_fleet_vector_scheduler,
    get_vector_scheduler,
    get_vector_scheduler_init,
)
from .state import SimState, Workload
from .workload import generate_workload


@functools.partial(
    jax.jit,
    static_argnames=("params", "scheduler_key", "engine", "fleet_engine"),
)
def _fleet_compiled(
    params: SimParams,
    workloads: Workload,  # batched: leading axis = fleet
    scheduler_key: str,
    engine: str,
    fleet_engine: str = "fused",
):
    sched_state0 = get_vector_scheduler_init(scheduler_key)(params)
    if engine == "event" and fleet_engine == "fused":
        # fleet-native engine: shared while_loop, fused phase-1 pass,
        # early-exit schedulers, incremental next-event registers
        scheduler_fn = get_fleet_vector_scheduler(scheduler_key)
        states, _ = _run_fleet_event_engine(
            params, workloads, scheduler_fn, sched_state0
        )
        return states

    # legacy path: vmap the single-sim engine (kept as the comparison
    # baseline; see benchmarks/engine_throughput.py)
    scheduler_fn = get_vector_scheduler(scheduler_key)
    runner = _run_event_engine if engine == "event" else _run_tick_engine

    def one(wl: Workload) -> SimState:
        state, _ = runner(params, wl, scheduler_fn, sched_state0)
        return state

    return jax.vmap(one)(workloads)


def make_workload_batch(params: SimParams, seeds: Sequence[int]) -> Workload:
    # host-loop-free batch construction: vmap the key derivation too, so
    # fleets in the thousands don't pay a per-seed Python round-trip
    keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(seeds, jnp.uint32))
    return jax.vmap(lambda k: generate_workload(params, k))(keys)


def fleet_run(
    params: SimParams,
    seeds: Sequence[int],
    scheduler_key: str | None = None,
    engine: str = "event",
    mesh: jax.sharding.Mesh | None = None,
    axis: str = "data",
    fleet_engine: str = "fused",
) -> SimState:
    """Run len(seeds) simulations in parallel; optionally sharded on a mesh.

    ``fleet_engine="fused"`` (default) runs the fleet-native event engine
    — one shared masked while_loop over the batch; ``"vmap"`` keeps the
    legacy vmap-of-while_loop path. Both are bitwise-identical per lane
    to ``run(..., engine="event")``. Returns the batched final SimState
    (leading axis = fleet member).
    """
    scheduler_key = scheduler_key or params.scheduling_algo
    wls = make_workload_batch(params, seeds)
    if mesh is not None:
        pspec = jax.sharding.PartitionSpec(axis)
        sharding = jax.sharding.NamedSharding(mesh, pspec)
        wls = jax.tree.map(lambda x: jax.device_put(x, sharding), wls)
    return _fleet_compiled(params, wls, scheduler_key, engine, fleet_engine)


def fleet_summary(states: SimState, params: SimParams) -> dict:
    """Aggregate fleet statistics (mean/std across fleet members)."""
    done = np.asarray(states.done_count)
    lat = np.asarray(states.sum_latency_s) / np.maximum(done, 1)
    util = np.asarray(states.util_cpu_s).sum(-1) / (
        params.total_cpus * params.duration
    )
    return {
        "fleet_size": int(done.shape[0]),
        "throughput_per_s_mean": float(done.mean() / params.duration),
        "throughput_per_s_std": float(done.std() / params.duration),
        "mean_latency_s_mean": float(lat.mean()),
        "mean_latency_s_std": float(lat.std()),
        "cpu_utilization_mean": float(util.mean()),
        "oom_events_mean": float(np.asarray(states.oom_events).mean()),
        "preempt_events_mean": float(np.asarray(states.preempt_events).mean()),
        "cost_dollars_mean": float(np.asarray(states.cost_dollars).mean()),
        # ---- data plane (fleet means) -------------------------------------
        "cache_hit_gb_mean": float(np.asarray(states.cache_hit_gb).mean()),
        "bytes_moved_gb_mean": float(
            np.asarray(states.bytes_moved_gb).mean()
        ),
        "cache_hit_rate_mean": _fleet_hit_rate(states),
        "cold_starts_mean": float(np.asarray(states.cold_starts).mean()),
        "warm_starts_mean": float(np.asarray(states.warm_starts).mean()),
    }


def _fleet_hit_rate(states: SimState) -> float:
    hit = np.asarray(states.cache_hit_gb, dtype=np.float64)
    moved = np.asarray(states.bytes_moved_gb, dtype=np.float64)
    total = hit + moved
    rates = np.where(total > 0, hit / np.maximum(total, 1e-12), 0.0)
    return float(rates.mean())


__all__ = ["fleet_run", "fleet_summary", "make_workload_batch"]
