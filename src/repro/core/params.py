"""Simulation parameters + TOML loading (paper §4.1.1).

The paper configures Eudoxia through a TOML file with ``parameter = value``
lines; the most important knobs called out in §4.1.1 are ``duration``,
``waiting_ticks_mean``, ``num_pools`` and ``scheduling_algo``. We keep
those names verbatim (case-insensitive on load) and add the distribution
parameters §3.2.1 alludes to ("a wide range of parameters ... how many
resources pipelines require, how long pipelines will take ...").

Every stochastic quantity is drawn from a distribution *centred at a
user-provided (or default) parameter* — exactly the paper's phrasing.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Any

from .types import TICKS_PER_SECOND

try:  # Python >= 3.11
    import tomllib as _toml
except ModuleNotFoundError:  # pragma: no cover - depends on interpreter
    try:
        import tomli as _toml  # type: ignore[no-redef]
    except ModuleNotFoundError:
        _toml = None


def _toml_descend(out: dict, parts: list[str], *, array: bool):
    """Walk a dotted table path, creating tables as needed; a list node
    means an array-of-tables, where the path continues in its LAST
    element (TOML semantics). With ``array`` the leaf appends a fresh
    table; otherwise it is (created and) entered."""
    node: Any = out
    path = ".".join(parts)
    for part in parts[:-1]:
        node = node.setdefault(part, {})
        if isinstance(node, list):
            node = node[-1]
        if not isinstance(node, dict):
            raise ValueError(
                f"TOML table path {path!r} collides with non-table "
                f"key {part!r}"
            )
    leaf = parts[-1]
    existing = node.get(leaf)
    if array:
        if existing is not None and not isinstance(existing, list):
            raise ValueError(
                f"TOML array-of-tables [[{path}]] collides with "
                f"existing key {leaf!r}"
            )
        node.setdefault(leaf, []).append({})
        return node[leaf][-1]
    if existing is not None and not isinstance(existing, (dict, list)):
        raise ValueError(
            f"TOML table [{path}] collides with existing key {leaf!r}"
        )
    node = node.setdefault(leaf, {})
    return node[-1] if isinstance(node, list) else node


def _toml_loads(text: str) -> dict:
    """Parse TOML via stdlib/tomli, else a minimal fallback parser.

    The fallback covers the flat parameter files the paper uses
    (§4.1.1) — scalars, strings, booleans, one-level arrays — plus the
    ``[table]`` / ``[[array-of-tables]]`` headers the trace format
    needs (docs/trace-format.md: repeated ``[[pipeline]]`` +
    ``[[pipeline.ops]]`` tables).
    """
    if _toml is not None:
        return _toml.loads(text)
    import ast

    out: dict[str, Any] = {}
    current = out
    for line in text.splitlines():
        line = line.split("#", 1)[0].strip()
        if not line:
            continue
        if line.startswith("[[") and line.endswith("]]"):
            current = _toml_descend(
                out, line[2:-2].strip().split("."), array=True
            )
            continue
        if line.startswith("[") and line.endswith("]"):
            current = _toml_descend(
                out, line[1:-1].strip().split("."), array=False
            )
            continue
        key, _, value = line.partition("=")
        if not _:
            raise ValueError(f"cannot parse TOML line: {line!r}")
        value = value.strip()
        low = value.lower()
        if low in ("true", "false"):
            current[key.strip()] = low == "true"
        else:
            current[key.strip()] = ast.literal_eval(value)
    return out


@dataclasses.dataclass(frozen=True)
class SimParams:
    # ---- paper-named core knobs (§4.1.1) ----------------------------------
    duration: float = 1.0              # simulated SECONDS
    waiting_ticks_mean: float = 5_000  # mean ticks between pipeline arrivals
    num_pools: int = 1
    scheduling_algo: str = "priority"

    # ---- resources (executor, §3.2.2) --------------------------------------
    total_cpus: float = 16.0           # summed over all pools
    total_ram_gb: float = 32.0         # summed over all pools
    cloud_scaling: bool = False        # may more resources be bought?
    cloud_scale_max_factor: float = 2.0
    cloud_cost_per_cpu_second: float = 0.000011  # ~c5ad.4xlarge $/vCPU-s
    cloud_premium_factor: float = 1.5  # premium on scaled resources

    # ---- workload generator (§3.2.1) ---------------------------------------
    seed: int = 0
    # capacity of the arrival table / ops tables. 0 = "derive from the
    # traces" (only meaningful through workload_batch_from_traces /
    # the scenario helpers, which return params carrying the derived
    # capacities; the seed generator needs positive values).
    max_pipelines: int = 256
    max_ops_per_pipeline: int = 8
    mean_ops_per_pipeline: float = 3.0
    chain_prob: float = 0.65           # P(op starts a new DAG level)
    op_ram_gb_mean: float = 2.0        # lognormal centre
    op_ram_gb_sigma: float = 0.6
    op_base_seconds_mean: float = 0.5  # lognormal centre of 1-CPU runtime
    op_base_seconds_sigma: float = 0.8
    # CPU scaling exponents and their probabilities: IO-bound ops do not
    # scale (alpha=0), some scale sub-linearly, stateless ops ~linearly.
    alpha_choices: tuple[float, ...] = (0.0, 0.5, 1.0)
    alpha_probs: tuple[float, ...] = (0.25, 0.35, 0.40)
    # priority mix: (BATCH, QUERY, INTERACTIVE)
    priority_probs: tuple[float, ...] = (0.6, 0.25, 0.15)
    # interactive queries are typically much shorter / smaller:
    interactive_scale: float = 0.15
    query_scale: float = 0.5

    # ---- data plane (intermediate datasets, caches, warm starts) -----------
    # Per-operator output dataset size ~ LogNormal centred at
    # ``op_out_gb_mean``; log-correlated with the op's 1-CPU runtime via
    # ``out_runtime_corr`` (long ops tend to produce big intermediates).
    op_out_gb_mean: float = 1.0
    op_out_gb_sigma: float = 0.6
    out_runtime_corr: float = 0.5
    # Zero-copy intermediate-dataset cache per pool (Arrow-style; 0 = off).
    cache_gb_per_pool: float = 0.0
    # Ticks charged per GB of input data NOT resident in the pool's cache.
    scan_ticks_per_gb: float = 0.0
    # Ticks charged to boot a container on a cold slot (0 = off).
    cold_start_ticks: int = 0
    # How long a retired container keeps its slot warm on its pool.
    container_warm_ticks: int = 20_000

    # ---- fault injection + retry policy (all zero-default = off) -----------
    # Transient function crashes: mean ticks between crash events (each
    # kills the longest-running container; its pipeline re-queues).
    crash_mtbf_ticks: float = 0.0
    # Pool outages: mean ticks between outage events and the mean outage
    # length. An outage kills every container on the struck pool, flushes
    # that pool's cache, and masks its capacity from the scheduler until
    # it recovers.
    outage_mtbf_ticks: float = 0.0
    outage_duration_ticks: float = 0.0
    # Stragglers: probability a pipeline's containers run slowed down by
    # ``straggler_factor`` (sampled per pipeline in the fault trace).
    straggler_prob: float = 0.0
    straggler_factor: float = 4.0
    # Wall-clock deadline on a container (0 = none): a container that
    # would run longer is killed at the deadline and its pipeline retried.
    timeout_ticks: int = 0
    # Retry policy for fault-killed / timed-out pipelines: re-queue at
    # ``tick + base_backoff_ticks * 2**attempt`` until ``max_retries`` is
    # exhausted, then FAILED.
    max_retries: int = 0
    base_backoff_ticks: int = 0
    # Capacity of the pre-materialised crash/outage tables in the fault
    # trace (events beyond it never fire).
    max_fault_events: int = 64
    # Per-priority SLO latency targets in seconds (BATCH, QUERY,
    # INTERACTIVE); 0 = no target for that class (attainment reported as
    # NaN by metrics.summarize).
    slo_latency_s: tuple[float, ...] = (0.0, 0.0, 0.0)

    # ---- closed-loop clients + admission control (all zero-default = off) --
    # Client concurrency cap: at most this many of a lane's pipelines may
    # be outstanding (admitted and unfinished) at once; excess arrivals
    # wait at the client and are re-offered after ``client_think_ticks``.
    # 0 = open loop (every arrival is offered immediately).
    client_max_inflight: int = 0
    # Think time before a concurrency-deferred arrival is re-offered.
    client_think_ticks: int = 0
    # Client-side retry budget for admission REJECTs (distinct from the
    # server-side ``max_retries`` above, which governs fault-killed
    # pipelines). 0 = a reject is a permanent shed (pipeline FAILED).
    client_max_retries: int = 0
    # Client backoff base: a rejected offer with ``attempt`` prior tries
    # returns at ``tick + client_backoff_ticks * 2**attempt`` (capped).
    client_backoff_ticks: int = 0
    # Admission policy ahead of the scheduler (core/admission.py
    # registry): "admit_all" | "queue_threshold" | "token_bucket" |
    # "codel", or any registered custom policy.
    admission_policy: str = "admit_all"
    # queue_threshold: max admitted-and-waiting pipelines; offers beyond
    # the limit are REJECTED (shed / client-retried).
    admit_queue_limit: int = 0
    # token_bucket: sustained admission rate (per simulated second) and
    # burst capacity in tokens; offers beyond the bucket are DEFERRED
    # until tokens accrue.
    admit_rate_per_s: float = 0.0
    admit_burst: float = 0.0
    # codel: target queue delay (oldest admitted-waiting sojourn, ticks)
    # and how long the delay must stay above target before offers are
    # REJECTED (CoDel-style overload detection).
    codel_target_ticks: int = 0
    codel_interval_ticks: int = 0
    # Metastability detection window: the run is flagged metastable when
    # the backlog has not returned to its pre-fault level within this
    # many ticks after the last fault (0 = "by end of run").
    metastable_window_ticks: int = 0

    # ---- engine -------------------------------------------------------------
    engine: str = "event"              # "event" (lane-major core) | "python"
    max_containers: int = 64
    max_assignments_per_tick: int = 16
    util_log_buckets: int = 512        # downsampled utilisation log length
    trace_path: str = ""               # optional: replay a trace instead

    # -------------------------------------------------------------------------
    @property
    def horizon_ticks(self) -> int:
        return int(round(self.duration * TICKS_PER_SECOND))

    @property
    def data_plane_active(self) -> bool:
        """True when any data-plane cost/capacity knob is switched on.

        With everything at the 0 defaults the simulator is bit-identical
        to the pre-data-plane behaviour (backward-compat invariant the
        test-suite checks)."""
        return (
            self.cache_gb_per_pool > 0
            or self.scan_ticks_per_gb > 0
            or self.cold_start_ticks > 0
        )

    @property
    def faults_active(self) -> bool:
        """True when any fault/retry knob is switched on.

        With everything at the 0 defaults the fault layer is compiled out
        entirely: the faults-off engine is the identical XLA program
        (digest-pinned in tests/captures/trace_off_digests.json)."""
        return (
            self.fault_events_active
            or self.straggler_prob > 0
            or self.timeout_ticks > 0
        )

    @property
    def fault_events_active(self) -> bool:
        """True when the engine needs the per-event fault pass (crash or
        outage events can fire). Stragglers/timeouts alone ride the
        container end ticks and need no extra event source."""
        return self.crash_mtbf_ticks > 0 or self.outage_mtbf_ticks > 0

    @property
    def fault_trace_active(self) -> bool:
        """True when the workload needs a materialised fault trace."""
        return (
            self.crash_mtbf_ticks > 0
            or self.outage_mtbf_ticks > 0
            or self.straggler_prob > 0
        )

    @property
    def client_loop_active(self) -> bool:
        """True when any client-model knob is switched on (concurrency
        cap, think time, or client-side retry-on-reject)."""
        return (
            self.client_max_inflight > 0
            or self.client_think_ticks > 0
            or self.client_max_retries > 0
            or self.client_backoff_ticks > 0
        )

    @property
    def admission_active(self) -> bool:
        """True when a non-trivial admission policy is configured."""
        return (
            self.admission_policy.replace("-", "_").lower() != "admit_all"
        )

    @property
    def closed_loop_active(self) -> bool:
        """True when the engine needs the closed-loop client/admission
        pass. With every knob at its zero default the pass is compiled
        out entirely and the engine is the identical XLA program
        (digest-pinned in tests/captures/trace_off_digests.json)."""
        return self.client_loop_active or self.admission_active

    @property
    def pool_cpus(self) -> float:
        return self.total_cpus / self.num_pools

    @property
    def pool_ram_gb(self) -> float:
        return self.total_ram_gb / self.num_pools

    def replace(self, **kw: Any) -> "SimParams":
        return dataclasses.replace(self, **kw)

    # -------------------------------------------------------------------------
    @staticmethod
    def from_toml(path: str | pathlib.Path) -> "SimParams":
        raw = _toml_loads(pathlib.Path(path).read_text())
        return SimParams.from_dict(raw)

    @staticmethod
    def from_dict(raw: dict[str, Any]) -> "SimParams":
        fields = {f.name: f for f in dataclasses.fields(SimParams)}
        kw: dict[str, Any] = {}
        for key, value in raw.items():
            k = key.lower()
            if k not in fields:
                raise KeyError(
                    f"unknown Eudoxia parameter {key!r}; "
                    f"known: {sorted(fields)}"
                )
            ftype = fields[k].type
            if isinstance(value, list):
                value = tuple(value)
            if ftype in ("float", float) and isinstance(value, int):
                value = float(value)
            kw[k] = value
        return SimParams(**kw)


def load_params(paramfile: str | pathlib.Path | dict | SimParams) -> SimParams:
    if isinstance(paramfile, SimParams):
        return paramfile
    if isinstance(paramfile, dict):
        return SimParams.from_dict(paramfile)
    return SimParams.from_toml(paramfile)


__all__ = ["SimParams", "load_params"]
