"""Execution-statistics visualisation (paper Fig. 2: "visualizers or
other downstream applications can access execution statistics").

Text/CSV renderings of the per-pool utilisation timeline (the bucketed
`util_log` integral) and the pipeline latency distribution — what a
platform engineer actually looks at after a policy simulation.
"""
from __future__ import annotations

import numpy as np

from .engine import SimResult
from .types import PipeStatus, Priority, TICKS_PER_SECOND

BLOCKS = " ▁▂▃▄▅▆▇█"


def utilization_timeline(res: SimResult, *, width: int = 64) -> str:
    """Unicode sparkline of CPU (and RAM) utilisation per pool."""
    log = np.asarray(res.state.util_log)          # [B, NP, 2] resource-sec
    B, NP, _ = log.shape
    caps_c = np.asarray(res.state.pool_cpu_cap)
    caps_r = np.asarray(res.state.pool_ram_cap)
    bucket_s = res.params.duration / B
    lines = []
    # resample to `width` buckets; never upsample: with width > B the
    # linspace edges repeat and a bucket lands in several columns,
    # over-weighting it in the printed mean (regression in
    # tests/test_viz.py)
    width = min(width, B)
    ix = np.linspace(0, B, width + 1).astype(int)
    for pool in range(NP):
        for ri, (name, cap) in enumerate(
            (("cpu", caps_c[pool]), ("ram", caps_r[pool]))
        ):
            frac = []
            for i in range(width):
                seg = log[ix[i]: max(ix[i + 1], ix[i] + 1), pool, ri]
                denom = cap * bucket_s * max(len(seg), 1)
                frac.append(min(seg.sum() / denom, 1.0) if denom else 0.0)
            bars = "".join(BLOCKS[int(f * (len(BLOCKS) - 1))] for f in frac)
            lines.append(f"pool{pool} {name} |{bars}| "
                         f"mean {np.mean(frac) * 100:5.1f}%")
    return "\n".join(lines)


_GANTT_END = {
    "complete": "C", "preempt": "P", "oom": "O", "open": ">",
    "fault": "X", "timeout": "T",
}


def pipeline_gantt(res: SimResult, *, width: int = 64) -> str:
    """Trace-driven text Gantt: one row per pipeline, its container
    executions drawn on a shared time axis.

    Needs a telemetry trace (``run(..., trace=True)``); each span is a
    run of ``=`` from its START to its end event, terminated by ``C``
    (complete), ``P`` (preempt), ``O`` (oom), ``X`` (killed by an
    injected fault), ``T`` (wall-clock timeout) or ``>`` (still running
    at the end of the trace). Priorities are taken from the spans' end
    records.
    """
    trace = getattr(res, "trace", None)
    if trace is None:
        return "(no trace: run with trace=True to record spans)"
    spans = trace.spans()
    if not spans:
        return "(trace holds no container executions)"
    horizon = max(int(res.params.horizon_ticks), 1)

    def col(tick: int) -> int:
        return min(int(tick * width / horizon), width - 1)

    by_pipe: dict[int, list] = {}
    for s in spans:
        by_pipe.setdefault(s.pipe, []).append(s)
    prio_names = {int(p): p.name for p in Priority}
    lines = [
        f"{'pipeline':>8s} {'prio':11s} |{'time -> ' + ' ' * (width - 8)}| spans"
    ]
    for pipe in sorted(by_pipe):
        row = [" "] * width
        prio = -1
        for s in by_pipe[pipe]:
            lo, hi = col(s.start_tick), col(max(s.end_tick, s.start_tick))
            for c in range(lo, hi):
                row[c] = "="
            row[hi] = _GANTT_END.get(s.end_kind, "?")
            if s.priority >= 0:
                prio = s.priority
        lines.append(
            f"{pipe:8d} {prio_names.get(prio, '?'):11s} |{''.join(row)}| "
            f"{len(by_pipe[pipe])}"
        )
    if trace.events_dropped:
        lines.append(f"(trace overflow: {trace.events_dropped} events dropped)")
    return "\n".join(lines)


def latency_histogram(res: SimResult, *, bins: int = 10) -> str:
    comp = np.asarray(res.state.pipe_completion)
    arr = np.asarray(res.workload.arrival)
    done = np.asarray(res.state.pipe_status) == int(PipeStatus.DONE)
    if not done.any():
        return "(no completed pipelines)"
    lat = (comp[done] - arr[done]) / TICKS_PER_SECOND
    hist, edges = np.histogram(lat, bins=bins)
    peak = hist.max() or 1
    lines = []
    for h, lo, hi in zip(hist, edges[:-1], edges[1:]):
        bar = "#" * int(40 * h / peak)
        lines.append(f"{lo:8.3f}-{hi:8.3f}s |{bar} {h}")
    return "\n".join(lines)


def per_priority_table(res: SimResult) -> str:
    s = res.summary()
    rows = [f"{'priority':12s} {'submitted':>9s} {'done':>6s} {'mean lat':>10s}"]
    for p in Priority:
        v = s["per_priority"][p.name.lower()]
        rows.append(
            f"{p.name:12s} {v['submitted']:9d} {v['done']:6d} "
            f"{v['mean_latency_s']:10.4f}"
        )
    return "\n".join(rows)


def timeline_csv(res: SimResult) -> str:
    """CSV: bucket_start_s, pool, cpu_util, ram_util."""
    log = np.asarray(res.state.util_log)
    B, NP, _ = log.shape
    caps_c = np.asarray(res.state.pool_cpu_cap)
    caps_r = np.asarray(res.state.pool_ram_cap)
    bucket_s = res.params.duration / B
    out = ["t_s,pool,cpu_util,ram_util"]
    for b in range(B):
        for pool in range(NP):
            cu = log[b, pool, 0] / max(caps_c[pool] * bucket_s, 1e-12)
            ru = log[b, pool, 1] / max(caps_r[pool] * bucket_s, 1e-12)
            out.append(f"{b * bucket_s:.4f},{pool},{cu:.4f},{ru:.4f}")
    return "\n".join(out)


__all__ = [
    "utilization_timeline",
    "pipeline_gantt",
    "latency_histogram",
    "per_priority_table",
    "timeline_csv",
]
