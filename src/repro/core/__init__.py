"""Eudoxia core: deterministic FaaS scheduling simulator in JAX.

Public API mirrors the paper (§4.1): ``run_simulator(paramfile)``,
the ``Scheduler`` class, the ``Failure``/``Assignment``/``Pipeline``
records and the registration decorators in ``repro.core.algorithm``.
"""
from .admission import (
    AdmissionView,
    has_admission_policy,
    list_admission_policies,
    register_admission_policy,
    register_admission_policy_py,
)
from .algorithm import (
    register_scheduler,
    register_scheduler_init,
)
from .engine import SimResult, run
from .engine_python import Scheduler
from .faults import (
    FaultTrace,
    attach_fault_trace,
    attach_fault_traces,
    fault_trace_from_records,
    fault_trace_to_records,
    generate_fault_trace,
)
from .metrics import completion_table, fleet_lane_stats, summarize
from .params import SimParams, load_params
from .policy import DEFAULT_POINTS, N_POLICY_PARAMS, PolicyParams
from .scheduler import (
    SchedDecision,
    get_policy_point,
    has_policy_point,
    mask_down_pools,
    policy_points,
    register_vector_scheduler,
    register_vector_scheduler_family,
    register_vector_scheduler_init,
)
from .state import (
    SimState,
    Workload,
    broadcast_lanes,
    cache_insert,
    container_schedule,
    init_state,
)
from .sweep import (
    attach_policies,
    fleet_run,
    fleet_summary,
    make_workload_batch,
    pad_lanes,
    policy_grid_workloads,
)
from . import telemetry
from .telemetry import (
    EventKind,
    Span,
    TraceEvents,
    summarize_timeline,
    to_perfetto_json,
)
from .types import (
    Assignment,
    Failure,
    Operator,
    Pipeline,
    PipeStatus,
    Priority,
    Suspension,
    TICKS_PER_SECOND,
)
# registers 'sjf' + data-plane schedulers 'cache_aware'/'locality_pool'
from . import extra_schedulers  # noqa: F401
from .workload import (
    generate_workload,
    load_trace,
    workload_batch_from_traces,
    workload_from_pipelines,
    workload_from_trace_records,
    workload_to_trace_records,
)


def run_simulator(paramfile, **kw) -> SimResult:
    """Paper Listing 3 entry point."""
    return run(paramfile, **kw)


__all__ = [
    "run_simulator",
    "run",
    "SimResult",
    "SimParams",
    "load_params",
    "Scheduler",
    "SchedDecision",
    "SimState",
    "Workload",
    "Assignment",
    "Failure",
    "Operator",
    "Pipeline",
    "PipeStatus",
    "Priority",
    "Suspension",
    "TICKS_PER_SECOND",
    "register_scheduler",
    "register_scheduler_init",
    "register_vector_scheduler",
    "register_vector_scheduler_family",
    "register_vector_scheduler_init",
    "generate_workload",
    "workload_from_pipelines",
    "workload_from_trace_records",
    "workload_to_trace_records",
    "workload_batch_from_traces",
    "load_trace",
    "container_schedule",
    "cache_insert",
    "init_state",
    "broadcast_lanes",
    "summarize",
    "completion_table",
    "FaultTrace",
    "generate_fault_trace",
    "attach_fault_trace",
    "attach_fault_traces",
    "fault_trace_to_records",
    "fault_trace_from_records",
    "mask_down_pools",
    "AdmissionView",
    "register_admission_policy",
    "register_admission_policy_py",
    "has_admission_policy",
    "list_admission_policies",
    "fleet_run",
    "fleet_summary",
    "fleet_lane_stats",
    "make_workload_batch",
    "pad_lanes",
    "attach_policies",
    "policy_grid_workloads",
    "PolicyParams",
    "N_POLICY_PARAMS",
    "DEFAULT_POINTS",
    "get_policy_point",
    "has_policy_point",
    "policy_points",
    "telemetry",
    "TraceEvents",
    "Span",
    "EventKind",
    "to_perfetto_json",
    "summarize_timeline",
]
