"""Trace exporters: Perfetto/Chrome ``trace_event`` JSON and windowed
timeline metrics.

``to_perfetto_json`` emits the Chrome trace-event format (the JSON
flavour Perfetto and ``chrome://tracing`` both load): one *process* per
fleet lane, one *track* (thread) per pool carrying the pipeline
execution spans, instant markers for the point events, and counter
tracks for queue depth, CPU/RAM in use, and cache residency. Every
emitted event carries its schema kind in ``cat``, so per-kind counts
round-trip through the JSON (tests/test_telemetry.py reconciles them
against ``summarize()``).

>>> from repro.core import SimParams, run
>>> from repro.core.telemetry import summarize_timeline, to_perfetto_json
>>> import json
>>> p = SimParams(duration=0.02, max_pipelines=8, max_containers=8,
...               max_ops_per_pipeline=4, waiting_ticks_mean=300.0,
...               op_base_seconds_mean=0.002)
>>> res = run(p, trace=True)
>>> doc = json.loads(to_perfetto_json(res.trace, res.params))
>>> sorted(doc) == ['displayTimeUnit', 'traceEvents']
True
>>> tl = summarize_timeline(res.trace, res.params, n_windows=4)
>>> len(tl['windows']), sorted(tl['overall'])[:2]
(4, ['backlog_max', 'backlog_p50'])
"""
from __future__ import annotations

import json

import numpy as np

from ..params import SimParams
from ..types import TICK_SECONDS
from .decode import TraceEvents
from .schema import COL_A, COL_PIPE, COL_POOL, COL_TICK, EventKind

_US_PER_TICK = TICK_SECONDS * 1e6

# point events rendered as instant markers on their pool track
_INSTANT_KINDS = (
    EventKind.ARRIVAL,
    EventKind.SCHED_DECISION,
    EventKind.COLD_START,
    EventKind.CACHE_HIT,
    EventKind.CACHE_MISS,
    EventKind.PREEMPT,
    EventKind.OOM,
    EventKind.REJECT,
    EventKind.FAULT,
    EventKind.POOL_DOWN,
    EventKind.POOL_UP,
    EventKind.TIMEOUT,
    EventKind.RETRY,
)


def to_perfetto_json(
    trace: TraceEvents,
    params: SimParams | None = None,
    *,
    lane: int = 0,
    max_counter_samples: int = 2048,
) -> str:
    """Chrome/Perfetto ``trace_event`` JSON for one lane's trace.

    Load the returned string (saved as a ``.json`` file) in
    https://ui.perfetto.dev or ``chrome://tracing``. ``lane`` sets the
    process id so per-lane exports of a fleet can be concatenated.
    Counter tracks are downsampled to ``max_counter_samples`` points;
    span and instant events are never dropped.
    """
    events: list[dict] = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": lane,
            "args": {"name": f"eudoxia lane {lane}"},
        }
    ]
    pools = sorted({int(p) for p in trace.pool if p >= 0}) or [0]
    for pool in pools:
        events.append({
            "name": "thread_name",
            "ph": "M",
            "pid": lane,
            "tid": pool,
            "args": {"name": f"pool {pool}"},
        })

    # ---- pipeline spans on their pool track --------------------------------
    for s in trace.spans():
        events.append({
            "name": f"pipe {s.pipe}",
            "cat": "span",
            "ph": "X",
            "ts": s.start_tick * _US_PER_TICK,
            "dur": max(s.end_tick - s.start_tick, 1) * _US_PER_TICK,
            "pid": lane,
            "tid": max(s.pool, 0),
            "args": {
                "pipe": s.pipe,
                "priority": s.priority,
                "cpus": s.cpus,
                "ram_gb": s.ram_gb,
                "end": s.end_kind,
            },
        })
    # one countable event per COMPLETE record (spans can outlive a
    # truncated trace; the JSON still reconciles per-kind counts)
    for row in trace.of_kind(EventKind.COMPLETE):
        events.append({
            "name": f"pipe {int(row[COL_PIPE])} done",
            "cat": "complete",
            "ph": "i",
            "s": "t",
            "ts": int(row[COL_TICK]) * _US_PER_TICK,
            "pid": lane,
            "tid": max(int(row[COL_POOL]), 0),
        })

    # ---- instant markers ---------------------------------------------------
    for kind in _INSTANT_KINDS:
        for row in trace.of_kind(kind):
            events.append({
                "name": f"{kind.name.lower()} pipe {int(row[COL_PIPE])}",
                "cat": kind.name.lower(),
                "ph": "i",
                "s": "t",
                "ts": int(row[COL_TICK]) * _US_PER_TICK,
                "pid": lane,
                "tid": max(int(row[COL_POOL]), 0),
                "args": {"a": int(row[COL_A])},
            })

    # ---- counter tracks ----------------------------------------------------
    ticks, qdepth, free_cpu, free_ram, cache_gb = trace.series()
    stride = max(1, int(np.ceil(len(ticks) / max_counter_samples)))
    sel = np.arange(0, len(ticks), stride)
    cpu_cap = ram_cap = None
    if params is not None:
        factor = params.cloud_scale_max_factor if params.cloud_scaling else 1.0
        cpu_cap = params.total_cpus * factor
        ram_cap = params.total_ram_gb * factor
    for i in sel:
        ts = int(ticks[i]) * _US_PER_TICK
        counters = {"queue_depth": int(qdepth[i])}
        if cpu_cap is not None:
            counters["cpus_in_use"] = round(cpu_cap - float(free_cpu[i]), 4)
            counters["ram_gb_in_use"] = round(
                ram_cap - float(free_ram[i]), 4
            )
        else:
            counters["free_cpu"] = round(float(free_cpu[i]), 4)
            counters["free_ram_gb"] = round(float(free_ram[i]), 4)
        counters["cache_gb"] = round(float(cache_gb[i]), 4)
        for name, value in counters.items():
            events.append({
                "name": name,
                "cat": "counter",
                "ph": "C",
                "ts": ts,
                "pid": lane,
                "args": {"value": value},
            })

    return json.dumps(
        {"traceEvents": events, "displayTimeUnit": "ms"}, indent=None
    )


def summarize_timeline(
    trace: TraceEvents,
    params: SimParams,
    *,
    n_windows: int = 8,
) -> dict:
    """Windowed latency and backlog percentiles from one lane's trace.

    The horizon splits into ``n_windows`` equal windows; each reports
    completion count, p50/p99 end-to-end latency of the pipelines that
    *completed* in the window (arrival taken from their ARRIVAL
    records), and p50/p99/max queue depth over the records sampled in
    the window. ``overall`` aggregates the same statistics across the
    whole run.
    """
    horizon = max(params.horizon_ticks, 1)
    edges = np.linspace(0, horizon, n_windows + 1)

    arrivals = trace.of_kind(EventKind.ARRIVAL)
    arrival_tick = {
        int(r[COL_PIPE]): int(r[COL_TICK]) for r in arrivals[::-1]
    }  # first arrival wins (end-to-end latency incl. OOM retries)
    completes = trace.of_kind(EventKind.COMPLETE)
    comp_ticks = completes[:, COL_TICK].astype(np.int64)
    lat_s = np.array([
        (int(r[COL_TICK]) - arrival_tick.get(int(r[COL_PIPE]), 0))
        * TICK_SECONDS
        for r in completes
    ])
    qd_ticks = trace.tick.astype(np.int64)
    qd = trace.queue_depth

    def _pct(x, q):
        return float(np.percentile(x, q)) if len(x) else float("nan")

    windows = []
    for lo, hi in zip(edges[:-1], edges[1:]):
        in_w = (comp_ticks >= lo) & (comp_ticks < hi)
        qd_w = qd[(qd_ticks >= lo) & (qd_ticks < hi)]
        windows.append({
            "t0_s": lo * TICK_SECONDS,
            "t1_s": hi * TICK_SECONDS,
            "completed": int(np.sum(in_w)),
            "p50_latency_s": _pct(lat_s[in_w], 50),
            "p99_latency_s": _pct(lat_s[in_w], 99),
            "backlog_p50": _pct(qd_w, 50),
            "backlog_p99": _pct(qd_w, 99),
            "backlog_max": int(qd_w.max()) if len(qd_w) else 0,
        })
    return {
        "n_windows": n_windows,
        "window_s": horizon * TICK_SECONDS / n_windows,
        "windows": windows,
        "overall": {
            "completed": int(len(lat_s)),
            "p50_latency_s": _pct(lat_s, 50),
            "p99_latency_s": _pct(lat_s, 99),
            "backlog_p50": _pct(qd, 50),
            "backlog_p99": _pct(qd, 99),
            "backlog_max": int(qd.max()) if len(qd) else 0,
            "events_dropped": trace.events_dropped,
        },
    }


__all__ = ["to_perfetto_json", "summarize_timeline"]
