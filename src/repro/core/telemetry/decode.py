"""Host-side decode of on-device trace buffers.

``TraceEvents`` wraps one lane's record table as numpy columns and
derives the structures downstream consumers want: per-kind counts,
per-pipeline execution spans (start -> complete/preempt/oom pairing),
queue-depth / resource-gauge time series, and CSV export. The decode
is exact: int columns are raw, float gauges are bit-for-bit the f32
values the engine observed (stored as IEEE-754 bits, viewed back).

>>> from repro.core import SimParams, run
>>> p = SimParams(duration=0.02, max_pipelines=8, max_containers=8,
...               max_ops_per_pipeline=4, waiting_ticks_mean=300.0,
...               op_base_seconds_mean=0.002)
>>> res = run(p, trace=True)
>>> res.trace.counts_by_kind()["complete"] == res.summary()["done"]
True
>>> res.trace.events_dropped
0
>>> spans = res.trace.spans()
>>> bool(all(s.end_tick >= s.start_tick for s in spans))
True
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .schema import (
    COL_A,
    COL_B,
    COL_CACHE_GB,
    COL_FREE_CPU,
    COL_FREE_RAM,
    COL_KIND,
    COL_OP,
    COL_PIPE,
    COL_POOL,
    COL_QDEPTH,
    COL_TICK,
    KIND_NAMES,
    EventKind,
)

CSV_HEADER = (
    "tick,kind,pipe,op,pool,queue_depth,free_cpu,free_ram_gb,"
    "cache_gb,a,b"
)

# kinds whose a/b payloads are f32 bits (schema.py payload table)
_FLOAT_A = {EventKind.START, EventKind.CACHE_HIT, EventKind.CACHE_MISS}
_FLOAT_B = {EventKind.START}


@dataclasses.dataclass(frozen=True)
class Span:
    """One container execution of a pipeline (START .. end event)."""

    pipe: int
    pool: int
    priority: int
    start_tick: int
    end_tick: int
    end_kind: str  # "complete" | "preempt" | "oom" | "fault" | "timeout" | "open"
    cpus: float
    ram_gb: float


def _f32(col: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(col.astype(np.int32)).view(np.float32)


@dataclasses.dataclass(frozen=True)
class TraceEvents:
    """Decoded per-lane event trace (time-ordered valid records only)."""

    records: np.ndarray  # [n, RECORD_WIDTH] int32
    events_dropped: int
    capacity: int

    @staticmethod
    def from_arrays(records, count, dropped, capacity=None) -> "TraceEvents":
        records = np.asarray(records, dtype=np.int32)
        n = int(count)
        return TraceEvents(
            records=records[:n].copy(),
            events_dropped=int(dropped),
            # callers that ship only the populated prefix to the host
            # pass the true ring capacity explicitly
            capacity=int(records.shape[0] if capacity is None else capacity),
        )

    # ---- columns ----------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.records.shape[0])

    @property
    def tick(self) -> np.ndarray:
        return self.records[:, COL_TICK]

    @property
    def kind(self) -> np.ndarray:
        return self.records[:, COL_KIND]

    @property
    def pipe(self) -> np.ndarray:
        return self.records[:, COL_PIPE]

    @property
    def pool(self) -> np.ndarray:
        return self.records[:, COL_POOL]

    @property
    def queue_depth(self) -> np.ndarray:
        return self.records[:, COL_QDEPTH]

    @property
    def free_cpu(self) -> np.ndarray:
        return _f32(self.records[:, COL_FREE_CPU])

    @property
    def free_ram_gb(self) -> np.ndarray:
        return _f32(self.records[:, COL_FREE_RAM])

    @property
    def cache_gb(self) -> np.ndarray:
        return _f32(self.records[:, COL_CACHE_GB])

    # ---- derived views ----------------------------------------------------
    def counts_by_kind(self) -> dict:
        """``{"arrival": n, "start": n, ...}`` over all valid records."""
        counts = np.bincount(self.kind, minlength=len(KIND_NAMES))
        return {name: int(counts[i]) for i, name in enumerate(KIND_NAMES)}

    def of_kind(self, kind: EventKind) -> np.ndarray:
        """The record rows of one event kind."""
        return self.records[self.kind == int(kind)]

    def spans(self) -> list:
        """Per-pipeline execution spans, START paired with the next
        COMPLETE / PREEMPT / OOM of the same pipeline (records are
        time-ordered as stored). An unterminated span is closed at the
        last recorded tick with ``end_kind="open"``."""
        open_by_pipe: dict[int, tuple] = {}
        out: list[Span] = []
        enders = {
            int(EventKind.COMPLETE): "complete",
            int(EventKind.PREEMPT): "preempt",
            int(EventKind.OOM): "oom",
            int(EventKind.FAULT): "fault",
            int(EventKind.TIMEOUT): "timeout",
        }
        for row in self.records:
            kind = int(row[COL_KIND])
            pipe = int(row[COL_PIPE])
            if kind == int(EventKind.START):
                cpus = float(_f32(row[COL_A : COL_A + 1])[0])
                ram = float(_f32(row[COL_B : COL_B + 1])[0])
                open_by_pipe[pipe] = (
                    int(row[COL_TICK]), int(row[COL_POOL]), cpus, ram
                )
            elif kind in enders and pipe in open_by_pipe:
                start, pool, cpus, ram = open_by_pipe.pop(pipe)
                out.append(Span(
                    pipe=pipe, pool=pool, priority=int(row[COL_B]),
                    start_tick=start, end_tick=int(row[COL_TICK]),
                    end_kind=enders[kind], cpus=cpus, ram_gb=ram,
                ))
        last = int(self.tick.max()) if self.n else 0
        for pipe, (start, pool, cpus, ram) in sorted(open_by_pipe.items()):
            out.append(Span(
                pipe=pipe, pool=pool, priority=-1, start_tick=start,
                end_tick=last, end_kind="open", cpus=cpus, ram_gb=ram,
            ))
        return out

    def series(self):
        """``(tick, queue_depth, free_cpu, free_ram_gb, cache_gb)``
        sampled at every record — the counter-track inputs."""
        return (
            self.tick, self.queue_depth, self.free_cpu,
            self.free_ram_gb, self.cache_gb,
        )

    def to_csv(self) -> str:
        """CSV export (floats decoded, kinds named)."""
        lines = [CSV_HEADER]
        for row in self.records:
            kind = int(row[COL_KIND])
            a: float | int = int(row[COL_A])
            b: float | int = int(row[COL_B])
            if kind in {int(k) for k in _FLOAT_A}:
                a = float(_f32(row[COL_A : COL_A + 1])[0])
            if kind in {int(k) for k in _FLOAT_B}:
                b = float(_f32(row[COL_B : COL_B + 1])[0])
            lines.append(
                f"{int(row[COL_TICK])},{KIND_NAMES[kind]},"
                f"{int(row[COL_PIPE])},{int(row[COL_OP])},"
                f"{int(row[COL_POOL])},{int(row[COL_QDEPTH])},"
                f"{float(_f32(row[COL_FREE_CPU: COL_FREE_CPU + 1])[0]):g},"
                f"{float(_f32(row[COL_FREE_RAM: COL_FREE_RAM + 1])[0]):g},"
                f"{float(_f32(row[COL_CACHE_GB: COL_CACHE_GB + 1])[0]):g},"
                f"{a},{b}"
            )
        return "\n".join(lines)


def decode_lane(tbuf, lane: int, capacity: int | None = None) -> TraceEvents:
    """Decode one lane of a fleet :class:`TraceBuffer` pytree."""
    return TraceEvents.from_arrays(
        np.asarray(tbuf.records)[lane],
        np.asarray(tbuf.count)[lane],
        np.asarray(tbuf.dropped)[lane],
        capacity=capacity,
    )


def decode_fleet(tbuf, capacity: int | None = None) -> list:
    """Decode every lane of a fleet trace into ``[TraceEvents, ...]``."""
    records = np.asarray(tbuf.records)
    counts = np.asarray(tbuf.count)
    dropped = np.asarray(tbuf.dropped)
    return [
        TraceEvents.from_arrays(
            records[i], counts[i], dropped[i], capacity=capacity
        )
        for i in range(records.shape[0])
    ]


__all__ = ["TraceEvents", "Span", "decode_lane", "decode_fleet"]
