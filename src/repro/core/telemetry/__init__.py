"""In-engine telemetry: on-device event tracing and host-side export.

The recorder (:mod:`.record`) rides the lane-major engine's
``while_loop`` carry and appends one int32 row per simulation event
(:mod:`.schema`). The host side (:mod:`.decode`, :mod:`.export`) turns
captured buffers into :class:`TraceEvents`, Perfetto/Chrome trace JSON,
CSV, and windowed timeline metrics. Enable with ``run(p, trace=True)``
or ``fleet_run(..., trace=True)``; the default-off path is bitwise
identical to an untraced build. See ``docs/observability.md``.
"""
from .decode import Span, TraceEvents, decode_fleet, decode_lane
from .export import summarize_timeline, to_perfetto_json
from .record import TraceBuffer, init_trace_buffer, record_step
from .schema import DEFAULT_TRACE_CAPACITY, KIND_NAMES, RECORD_WIDTH, EventKind

__all__ = [
    "EventKind",
    "KIND_NAMES",
    "RECORD_WIDTH",
    "DEFAULT_TRACE_CAPACITY",
    "TraceBuffer",
    "init_trace_buffer",
    "record_step",
    "TraceEvents",
    "Span",
    "decode_lane",
    "decode_fleet",
    "to_perfetto_json",
    "summarize_timeline",
]
