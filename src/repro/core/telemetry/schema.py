"""Trace record schema shared by the on-device recorder and the host
decoder.

One trace record is one int32 row of ``RECORD_WIDTH`` columns. Float
payloads (resource gauges, allocation sizes, cached GB) are stored as
their raw IEEE-754 bits (``bitcast``, not a cast) so the decode is
exact; the decoder views them back as float32.

Columns
-------

====  ===========  ====================================================
 idx  name         meaning
====  ===========  ====================================================
  0   tick         event tick (simulation time, 1 tick = 10 us)
  1   kind         :class:`EventKind`
  2   pipe         pipeline id (-1 when not applicable)
  3   op           kind-specific small int (see payload table)
  4   pool         pool id (-1 when not applicable)
  5   queue_depth  WAITING pipelines after the engine step
  6   free_cpu     f32 bits — total free CPUs after the step
  7   free_ram     f32 bits — total free RAM GB after the step
  8   cache_gb     f32 bits — total cache-resident GB after the step
  9   a            kind-specific payload (see payload table)
 10   b            kind-specific payload (see payload table)
====  ===========  ====================================================

Payloads per kind (``op`` / ``a`` / ``b``)
------------------------------------------

================  =====================  ======================  =================
 kind              op                     a                       b
================  =====================  ======================  =================
 ARRIVAL           -1                     priority                arrival tick
 SCHED_DECISION    runner-up priority     runner-up pipeline      chosen priority
 START             -1                     f32 bits: cpus          f32 bits: ram GB
 COLD_START        -1                     cold-start ticks        0
 CACHE_HIT         -1                     f32 bits: hit GB        0
 CACHE_MISS        -1                     f32 bits: miss GB       0
 PREEMPT           -1                     container slot          priority
 OOM               -1                     container slot          priority
 COMPLETE          -1                     container slot          priority
 REJECT            -1                     priority                0
 FAULT             cause (0=crash,        container slot          priority
                   1=outage)
 POOL_DOWN         -1                     down-until tick         0
 POOL_UP           -1                     0                       0
 TIMEOUT           -1                     container slot          priority
 RETRY             -1                     attempt number          release tick
 ADMIT_REJECT      -1                     priority                0
 CLIENT_RETRY      -1                     attempt number          release tick
 SHED              -1                     priority                0
================  =====================  ======================  =================

Within one engine step, records appear in the fixed order arrivals ->
ooms -> completes -> preempts -> rejects -> scheduler decision ->
starts -> cold-starts -> cache hits -> cache misses, and steps append
chronologically, so a lane's record array is time-ordered as stored.
The chaos-layer kinds (FAULT, TIMEOUT, POOL_DOWN, POOL_UP, RETRY,
emitted only when the matching fault knobs are on — see docs/faults.md)
extend that order at the end of each step: faults -> timeouts ->
pool-downs -> pool-ups -> retries. The closed-loop kinds (ADMIT_REJECT,
CLIENT_RETRY, SHED, emitted only when the closed-loop knobs are on —
see docs/closed-loop.md) follow last: admit-rejects -> client-retries
-> sheds. ADMIT_REJECT fires for every admission rejection; each is
also either a CLIENT_RETRY (budget left, re-offered with backoff) or a
SHED (budget exhausted, pipeline FAILED).
"""
from __future__ import annotations

import enum


class EventKind(enum.IntEnum):
    """Per-event record kinds (see the payload table above)."""

    ARRIVAL = 0         # pipeline admitted to the waiting queue
    SCHED_DECISION = 1  # scheduler picked a head-of-queue (chosen vs runner-up)
    START = 2           # container created for a pipeline
    COLD_START = 3      # that container started on a cold slot
    CACHE_HIT = 4       # assignment found input bytes in the pool cache
    CACHE_MISS = 5      # assignment scanned input bytes from storage
    PREEMPT = 6         # container suspended by the scheduler
    OOM = 7             # container killed by the RAM model
    COMPLETE = 8        # pipeline finished
    REJECT = 9          # pipeline failed back to the user
    FAULT = 10          # container killed by the chaos layer (crash/outage)
    POOL_DOWN = 11      # pool struck by an outage (capacity masked)
    POOL_UP = 12        # pool recovered from its outage
    TIMEOUT = 13        # container killed at its wall-clock deadline
    RETRY = 14          # faulted/timed-out pipeline re-queued with backoff
    ADMIT_REJECT = 15   # offer rejected by the admission policy
    CLIENT_RETRY = 16   # rejected offer re-queued by the client (backoff)
    SHED = 17           # rejected offer permanently shed (client budget out)


KIND_NAMES = tuple(k.name.lower() for k in EventKind)

# column indices of one int32 record row
COL_TICK = 0
COL_KIND = 1
COL_PIPE = 2
COL_OP = 3
COL_POOL = 4
COL_QDEPTH = 5
COL_FREE_CPU = 6   # f32 bits
COL_FREE_RAM = 7   # f32 bits
COL_CACHE_GB = 8   # f32 bits
COL_A = 9
COL_B = 10
RECORD_WIDTH = 11

# f32-bits columns, viewed back as float32 on decode
FLOAT_COLS = (COL_FREE_CPU, COL_FREE_RAM, COL_CACHE_GB)

DEFAULT_TRACE_CAPACITY = 4096

# The recorder emits at most this many records per engine step; larger
# bursts are counted in ``events_dropped``. The cap is what keeps the
# recorder cheap: per step it compacts and writes a fixed
# ``[TRACE_STEP_EVENTS, RECORD_WIDTH]`` block instead of the full
# candidate table (every pipeline x every container x every assignment
# slot, ~hundreds of rows), and the compaction search cost scales with
# the block size. Event-driven steps carry ~1-5 records in practice;
# the worst observed across the test matrix and the scenario library
# (bursty arrivals at 10x base rate) is 9, so 16 still has headroom —
# and a clipped burst is counted in ``events_dropped``, never silent.
TRACE_STEP_EVENTS = 16

__all__ = [
    "EventKind",
    "KIND_NAMES",
    "RECORD_WIDTH",
    "FLOAT_COLS",
    "DEFAULT_TRACE_CAPACITY",
    "TRACE_STEP_EVENTS",
    "COL_TICK",
    "COL_KIND",
    "COL_PIPE",
    "COL_OP",
    "COL_POOL",
    "COL_QDEPTH",
    "COL_FREE_CPU",
    "COL_FREE_RAM",
    "COL_CACHE_GB",
    "COL_A",
    "COL_B",
]
