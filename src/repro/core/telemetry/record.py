"""On-device event recorder for the lane-major engine.

One :class:`TraceBuffer` per lane rides the engine's ``while_loop``
carry: a fixed-capacity record table plus a write cursor and an
overflow counter. Each engine step appends every event it caused —
arrivals, retirements, preemptions, rejections, the scheduler's
chosen-vs-runner-up decision, container starts and their data-plane
cost components.

The append is built for while-loop throughput. Candidate events are
assembled **column-wise**: one concatenate per varying schema column
over the candidate axis (every pipeline, container and assignment slot
— ``step_record_count`` entries), with the tick/gauge columns held as
step scalars and the kind column a compile-time constant. Compaction
then never touches full candidate *rows*: a cumulative sum over the
emit masks scatters each selected candidate's **index** into a small
``[TRACE_STEP_EVENTS]`` slot vector (scalar scatter, unique indices),
the block's columns are gathered through that vector, and the
resulting ``[TRACE_STEP_EVENTS, RECORD_WIDTH]`` block lands with one
contiguous ``dynamic_update_slice`` at the cursor.

The record table carries ``TRACE_STEP_EVENTS`` rows of tail scratch so
a full buffer's writes land past ``capacity`` and fall off instead of
wrapping: earlier records are never overwritten, an overflowing trace
is a truncated prefix, and ``dropped`` counts what fell off (as well
as any burst past ``TRACE_STEP_EVENTS`` records in one step — never
seen in practice; see schema.py). Rows between ``count`` and
``capacity`` are compaction padding, not events — hosts must decode
``records[:count]`` only (:mod:`repro.core.telemetry.decode` does).

The recorder only *reads* simulation state; the engine states it is
handed flow through untouched, which is what keeps trace-on runs
bitwise-identical to trace-off runs (tests/test_telemetry.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..params import SimParams
from ..scheduler import SchedDecision, decision_provenance
from ..state import INF_TICK, SimState, Workload
from ..types import ContainerStatus, PipeStatus
from .schema import RECORD_WIDTH, TRACE_STEP_EVENTS, EventKind


class TraceBuffer(NamedTuple):
    """Per-lane on-device event table (a pytree leaf group in the
    engine carry). ``records[:count]`` are valid, time-ordered rows;
    in the carry the table holds step-block scratch past ``capacity``
    for the contiguous writer (see module docstring)."""

    records: jax.Array  # [capacity + scratch, RECORD_WIDTH] int32
    count: jax.Array    # [] int32 rows written (<= capacity)
    dropped: jax.Array  # [] int32 rows lost to overflow


def step_record_count(max_pipelines: int, max_containers: int,
                      max_assignments: int,
                      params: SimParams | None = None) -> int:
    """Candidate records one engine step can emit: arrivals + rejects
    over pipelines, oom/complete/preempt over containers, one scheduler
    decision, and start/cold/hit/miss per assignment slot. With fault
    knobs on (``params`` given, see docs/faults.md) the chaos-layer
    groups are appended: fault kills / timeouts over containers,
    pool-down/-up markers over pools, and retries over pipelines."""
    n = 2 * max_pipelines + 3 * max_containers + 1 + 4 * max_assignments
    if params is not None:
        if params.fault_events_active:
            n += max_containers                 # FAULT
        if params.timeout_ticks > 0:
            n += max_containers                 # TIMEOUT
        if params.outage_mtbf_ticks > 0:
            n += 2 * params.num_pools           # POOL_DOWN + POOL_UP
        if params.faults_active:
            n += max_pipelines                  # RETRY
        if params.closed_loop_active:
            # ADMIT_REJECT + CLIENT_RETRY + SHED (docs/closed-loop.md)
            n += 3 * max_pipelines
    return n


def step_block_rows(max_pipelines: int, max_containers: int,
                    max_assignments: int,
                    params: SimParams | None = None) -> int:
    """Rows in the per-step write block (the buffer's tail scratch)."""
    return min(
        step_record_count(max_pipelines, max_containers, max_assignments,
                          params),
        TRACE_STEP_EVENTS,
    )


def init_trace_buffer(capacity: int, scratch: int = 0) -> TraceBuffer:
    return TraceBuffer(
        records=jnp.zeros((capacity + scratch, RECORD_WIDTH), jnp.int32),
        count=jnp.asarray(0, jnp.int32),
        dropped=jnp.asarray(0, jnp.int32),
    )


def _find_slots(pos: jax.Array, G: int) -> jax.Array:
    """Block slot ``j`` holds the j-th selected candidate: the first
    index whose running count ``pos`` (a sorted cumsum) reaches j+1.
    A branch-free binary search, unrolled at trace time, finds all G
    of them with log2(n) tiny gathers — no scatter (XLA:CPU scatters
    are per-element and dominated the recorder) and no inner scan
    (``jnp.searchsorted``'s loop carries while-loop machinery through
    every engine step). Slots past the step's selection count land at
    ``n`` and clamp into the block's padding tail."""
    n = pos.shape[0]
    i32 = jnp.int32
    targets = jnp.arange(1, G + 1, dtype=i32)
    lo = jnp.zeros((G,), i32)
    hi = jnp.full((G,), n, i32)
    for _ in range((n - 1).bit_length()):
        mid = (lo + hi) // 2
        go_right = pos[jnp.minimum(mid, n - 1)] < targets
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    return lo


def _f32_bits(x) -> jax.Array:
    """IEEE-754 bits of a float32 value, as int32 (exact round-trip)."""
    return jax.lax.bitcast_convert_type(
        jnp.asarray(x, jnp.float32), jnp.int32
    )


def record_step(
    tbuf: TraceBuffer,
    capacity: int,
    active: jax.Array,   # [] bool — lane still running (gates all writes)
    pre: SimState,       # state at step entry (container identities)
    st1: SimState,       # state after fused phase 1 (queue the scheduler saw)
    post: SimState,      # state after the full step (gauges)
    wl: Workload,
    params: SimParams,
    tick: jax.Array,
    ph,                  # fused phase-1 masks (repro.kernels.sim_tick)
    dec: SchedDecision,
    aux,                 # (aux_i [K,4], aux_f [K,5]) from apply_decision
    fault_aux=None,      # chaos-layer step outputs from executor.apply_faults
) -> TraceBuffer:
    """Append one engine step's events to the lane's trace buffer."""
    (oomed, done, _st, _fc, _fr, fresh, _rel, _nr, _nl) = ph
    aux_i, aux_f = aux
    MP = wl.max_pipelines
    MC = pre.max_containers
    K = aux_i.shape[0]
    n = step_record_count(MP, MC, K, params)
    G = step_block_rows(MP, MC, K, params)
    i32 = jnp.int32

    # step-wide gauges, sampled once on the post-step state and attached
    # to every record of the step
    qdepth = jnp.sum(post.pipe_status == int(PipeStatus.WAITING)).astype(i32)
    free_cpu = _f32_bits(jnp.sum(post.pool_cpu_free))
    free_ram = _f32_bits(jnp.sum(post.pool_ram_free))
    cache_gb = _f32_bits(jnp.sum(post.pool_cache_used))

    pipes = jnp.arange(MP, dtype=i32)
    slots = jnp.arange(MC, dtype=i32)
    susp = dec.suspend & (st1.ctr_status == int(ContainerStatus.RUNNING))
    rej = dec.reject & (st1.pipe_status == int(PipeStatus.WAITING))
    chosen, runner = decision_provenance(st1, wl, dec)
    chosen_c = jnp.maximum(chosen, 0)
    runner_c = jnp.maximum(runner, 0)
    a_pipe, a_pool, a_cold, a_warm = (aux_i[:, j] for j in range(4))
    a_cpus, a_ram, a_hit, a_miss, a_out = (aux_f[:, j] for j in range(5))
    started = a_pipe >= 0

    # a timed-out retirement is a TIMEOUT record, not a COMPLETE: split
    # the phase-1 done mask on the deadline marker (knob-gated so the
    # faults-off candidate table is byte-identical to before)
    if params.timeout_ticks > 0:
        timed = done & pre.ctr_timed
        done_c = done & ~timed
    else:
        done_c = done

    # candidate columns, one concatenate per varying column; group order
    # (the fixed within-step record order, schema.py) is:
    #   arrival[MP] oom[MC] complete[MC] preempt[MC] reject[MP]
    #   sched_decision[1] start[K] cold_start[K] cache_hit[K] cache_miss[K]
    # plus, knob-gated at the end (chaos layer, docs/faults.md):
    #   fault[MC] timeout[MC] pool_down[NP] pool_up[NP] retry[MP]
    mask_parts = [
        fresh, oomed, done_c, susp, rej, (chosen >= 0)[None],
        started, started & (a_warm == 0), started & (a_hit > 0),
        started & (a_out > 0) & (a_miss > 0),
    ]
    kind_parts = [
        np.full(MP, int(EventKind.ARRIVAL)),
        np.full(MC, int(EventKind.OOM)),
        np.full(MC, int(EventKind.COMPLETE)),
        np.full(MC, int(EventKind.PREEMPT)),
        np.full(MP, int(EventKind.REJECT)),
        [int(EventKind.SCHED_DECISION)],
        np.full(K, int(EventKind.START)),
        np.full(K, int(EventKind.COLD_START)),
        np.full(K, int(EventKind.CACHE_HIT)),
        np.full(K, int(EventKind.CACHE_MISS)),
    ]
    pipe_parts = [
        pipes, pre.ctr_pipe, pre.ctr_pipe, st1.ctr_pipe, pipes,
        chosen[None], a_pipe, a_pipe, a_pipe, a_pipe,
    ]
    neg1_mp = jnp.full((MP,), -1, i32)
    pool_parts = [
        neg1_mp, pre.ctr_pool, pre.ctr_pool, st1.ctr_pool, neg1_mp,
        dec.assign_pool[:1], a_pool, a_pool, a_pool, a_pool,
    ]
    a_parts = [
        wl.prio, slots, slots, slots, wl.prio, runner[None],
        _f32_bits(a_cpus), a_cold, _f32_bits(a_hit), _f32_bits(a_miss),
    ]
    zeros_k = jnp.zeros((K,), i32)
    b_parts = [
        wl.arrival, pre.ctr_prio, pre.ctr_prio, st1.ctr_prio,
        jnp.zeros((MP,), i32), wl.prio[chosen_c][None],
        _f32_bits(a_ram), zeros_k, zeros_k, zeros_k,
    ]
    # op is -1 everywhere except the decision record's runner-up priority
    # and the FAULT group's cause code (set by offset below)
    op_sets = [(2 * MP + 3 * MC,
                jnp.where(runner >= 0, wl.prio[runner_c], -1).astype(i32))]

    off = 2 * MP + 3 * MC + 1 + 4 * K
    if params.fault_events_active:
        (kill, kill_pipe, kill_pool, kill_cause, _kill_wasted,
         down_new, up_now, pool_down_until) = fault_aux
        mask_parts.append(kill)
        kind_parts.append(np.full(MC, int(EventKind.FAULT)))
        pipe_parts.append(kill_pipe)
        pool_parts.append(kill_pool)
        a_parts.append(slots)
        # killed slots were RUNNING since step entry (phase 1 never
        # starts containers), so pre still holds their priority
        b_parts.append(pre.ctr_prio)
        op_sets.append((slice(off, off + MC), kill_cause))
        off += MC
    if params.timeout_ticks > 0:
        mask_parts.append(timed)
        kind_parts.append(np.full(MC, int(EventKind.TIMEOUT)))
        pipe_parts.append(pre.ctr_pipe)
        pool_parts.append(pre.ctr_pool)
        a_parts.append(slots)
        b_parts.append(pre.ctr_prio)
        off += MC
    if params.outage_mtbf_ticks > 0:
        NP = pool_down_until.shape[0]
        pools = jnp.arange(NP, dtype=i32)
        neg1_np = jnp.full((NP,), -1, i32)
        zeros_np = jnp.zeros((NP,), i32)
        mask_parts += [down_new, up_now]
        kind_parts += [np.full(NP, int(EventKind.POOL_DOWN)),
                       np.full(NP, int(EventKind.POOL_UP))]
        pipe_parts += [neg1_np, neg1_np]
        pool_parts += [pools, pools]
        a_parts += [pool_down_until, zeros_np]
        b_parts += [zeros_np, zeros_np]
        off += 2 * NP
    if params.faults_active:
        # retried = attempt counter bumped this step (fault kill or
        # timeout); the new count and the backoff release tick ride along
        retried = st1.pipe_retries > pre.pipe_retries
        mask_parts.append(retried)
        kind_parts.append(np.full(MP, int(EventKind.RETRY)))
        pipe_parts.append(pipes)
        pool_parts.append(neg1_mp)
        a_parts.append(st1.pipe_retries)
        b_parts.append(st1.pipe_release)
        off += MP
    if params.closed_loop_active:
        # the closed-loop pass runs before the st1 snapshot, so its
        # transitions show up as pre -> st1 deltas: a bumped client
        # attempt counter is a CLIENT_RETRY; a fresh FAILED that never
        # started (first_start still INF) can only be an admission shed.
        client_retried = st1.pipe_client_attempts > pre.pipe_client_attempts
        shed_now = (
            (st1.pipe_status == int(PipeStatus.FAILED))
            & (pre.pipe_status != int(PipeStatus.FAILED))
            & (st1.pipe_first_start == INF_TICK)
        )
        zeros_mp = jnp.zeros((MP,), i32)
        mask_parts += [client_retried | shed_now, client_retried, shed_now]
        kind_parts += [np.full(MP, int(EventKind.ADMIT_REJECT)),
                       np.full(MP, int(EventKind.CLIENT_RETRY)),
                       np.full(MP, int(EventKind.SHED))]
        pipe_parts += [pipes, pipes, pipes]
        pool_parts += [neg1_mp, neg1_mp, neg1_mp]
        a_parts += [wl.prio, st1.pipe_client_attempts, wl.prio]
        b_parts += [zeros_mp, st1.pipe_release, zeros_mp]
        off += 3 * MP
    assert off == n

    mask = jnp.concatenate(mask_parts) & active
    kind_col = jnp.asarray(np.concatenate(kind_parts).astype(np.int32))
    pipe_col = jnp.concatenate(pipe_parts).astype(i32)
    pool_col = jnp.concatenate(pool_parts).astype(i32)
    a_col = jnp.concatenate(a_parts).astype(i32)
    b_col = jnp.concatenate(b_parts).astype(i32)
    op_col = jnp.full((n,), -1, i32)
    for idx, val in op_sets:
        op_col = op_col.at[idx].set(val)

    # in-step compaction without touching candidate rows: scatter each
    # selected candidate's INDEX into its ordered block slot (a scalar
    # scatter; slots past G drop), gather the block's columns through
    # it, and land the block with ONE contiguous write at the cursor.
    # The block's padding tail overwrites only not-yet-valid rows (the
    # next step's writes start where this one's valid rows end), and a
    # full buffer's writes land in the tail scratch and fall off.
    pos = jnp.cumsum(mask.astype(i32))
    n_step = pos[-1]
    sel = _find_slots(pos, G)

    def const(v):
        return jnp.broadcast_to(jnp.asarray(v, i32), (G,))

    block = jnp.stack([
        const(tick), kind_col[sel], pipe_col[sel], op_col[sel],
        pool_col[sel], const(qdepth), const(free_cpu), const(free_ram),
        const(cache_gb), a_col[sel], b_col[sel],
    ], axis=1)
    assert block.shape == (G, RECORD_WIDTH)
    records = jax.lax.dynamic_update_slice(
        tbuf.records, block, (tbuf.count, jnp.int32(0))
    )
    count = jnp.minimum(tbuf.count + jnp.minimum(n_step, G), capacity)
    return TraceBuffer(
        records=records,
        count=count,
        dropped=tbuf.dropped + (tbuf.count + n_step - count),
    )


__all__ = [
    "TraceBuffer", "init_trace_buffer", "record_step",
    "step_record_count", "step_block_rows",
]
