"""Closed-loop client model + admission control (overload layer).

Everything the open-loop simulator lacks for overload studies lives
here: a client model deciding which pending arrivals are *offered* this
event, and an admission-control stage ahead of the scheduler that can
REJECT (shed / client-retry) or DEFER offers. Both are zero-default: with
every knob off ``params.closed_loop_active`` is False and the engine
compiles the identical XLA program it did before this layer existed
(digest-pinned in tests/captures/trace_off_digests.json).

Admission policies are pluggable and registered exactly like scheduler
families (see scheduler.py):

>>> sorted(list_admission_policies())
['admit_all', 'codel', 'queue_threshold', 'token_bucket']
>>> has_admission_policy("queue-threshold")
True

A compiled policy has signature::

    policy(state, wl, params, tick, offered) -> (state, reject, defer,
                                                 defer_ticks)

where ``offered`` / ``reject`` / ``defer`` are ``[MP]`` bool masks
(reject/defer subsets of offered), the returned state may carry updated
policy registers (token bucket level, CoDel clock), and ``defer_ticks``
is a static python int — deferred offers re-land ``max(defer_ticks, 1)``
ticks later through the ordinary suspension-release registers, so
event-skip stays exact. Rankings MUST be pipe-index order (cumsum over
the mask), because the numpy mirrors iterate pids ascending.

Every built-in policy has a numpy mirror (``*_py``, registered under the
same key) used by ``engine_python`` — op-for-op identical, including f32
association order for the token bucket. Mirrors see a tiny
:class:`AdmissionView` instead of ``SimState``:

>>> view = AdmissionView(admitted_waiting=3, oldest_admitted_entered=0,
...                      regs={"tokens": np.float32(2.0), "last_tick": 0,
...                            "above_since": int(INF_TICK)})
>>> p = SimParams(admission_policy="queue_threshold", admit_queue_limit=4)
>>> reject, defer, _ = queue_threshold_py(p, 10, [5, 6, 7], view)
>>> (reject, defer)   # one free slot below the limit -> admit pid 5 only
([6, 7], [])
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .params import SimParams
from .state import INF_TICK, SimState, Workload
from .types import PipeStatus, TICKS_PER_SECOND

# (state, wl, params, tick, offered) -> (state, reject, defer, defer_ticks)
AdmissionPolicy = Callable[
    [SimState, Workload, SimParams, jax.Array, jax.Array],
    tuple[SimState, jax.Array, jax.Array, int],
]
# (params, tick, offered_pids, view) -> (reject_pids, defer_pids, defer_ticks)
AdmissionPolicyPy = Callable[
    [SimParams, int, list, "AdmissionView"], tuple[list, list, int]
]

_POLICIES: dict[str, AdmissionPolicy] = {}
_POLICIES_PY: dict[str, AdmissionPolicyPy] = {}


def _norm(key: str) -> str:
    return key.replace("-", "_").lower()


def register_admission_policy(key: str):
    """Register a compiled (lane-major, vmap-safe) admission policy."""

    def deco(fn: AdmissionPolicy) -> AdmissionPolicy:
        _POLICIES[_norm(key)] = fn
        return fn

    return deco


def register_admission_policy_py(key: str):
    """Register the numpy mirror used by ``engine_python``."""

    def deco(fn: AdmissionPolicyPy) -> AdmissionPolicyPy:
        _POLICIES_PY[_norm(key)] = fn
        return fn

    return deco


def get_admission_policy(key: str) -> AdmissionPolicy:
    k = _norm(key)
    if k not in _POLICIES:
        raise KeyError(
            f"unknown admission policy {key!r}; registered: "
            f"{sorted(_POLICIES)}"
        )
    return _POLICIES[k]


def get_admission_policy_py(key: str) -> AdmissionPolicyPy:
    k = _norm(key)
    if k not in _POLICIES_PY:
        raise KeyError(
            f"admission policy {key!r} has no python mirror; registered: "
            f"{sorted(_POLICIES_PY)}"
        )
    return _POLICIES_PY[k]


def has_admission_policy(key: str) -> bool:
    return _norm(key) in _POLICIES


def list_admission_policies() -> list[str]:
    return sorted(_POLICIES)


class AdmissionView:
    """Queue statistics + mutable policy registers for the numpy mirrors.

    ``admitted_waiting`` counts pipelines admitted and still WAITING (the
    backlog the scheduler sees); ``oldest_admitted_entered`` is the
    smallest ``entered`` tick among them (``INF_TICK`` when none);
    ``regs`` holds the policy registers {"tokens": np.float32,
    "last_tick": int, "above_since": int} that policies mutate in place.
    """

    __slots__ = ("admitted_waiting", "oldest_admitted_entered", "regs")

    def __init__(self, admitted_waiting, oldest_admitted_entered, regs):
        self.admitted_waiting = admitted_waiting
        self.oldest_admitted_entered = oldest_admitted_entered
        self.regs = regs


def _zeros_like_mask(offered: jax.Array) -> jax.Array:
    return jnp.zeros_like(offered)


# ---------------------------------------------------------------------------
# Built-in policies. Each compiled policy is immediately followed by its
# numpy mirror; keep them in visual lockstep when editing.
# ---------------------------------------------------------------------------
@register_admission_policy("admit_all")
def admit_all(state, wl, params, tick, offered):
    """Default open-door policy: nothing rejected, nothing deferred."""
    z = _zeros_like_mask(offered)
    return state, z, z, 1


@register_admission_policy_py("admit_all")
def admit_all_py(params, tick, offered, view):
    return [], [], 1


@register_admission_policy("queue_threshold")
def queue_threshold(state, wl, params, tick, offered):
    """REJECT offers beyond a cap on admitted-and-waiting pipelines.

    Classic load shedding: the backlog the scheduler may accumulate is
    bounded by ``params.admit_queue_limit``; everything else bounces to
    the client (which may retry with backoff — the retry-storm mechanism
    when the limit is hit during an outage).
    """
    i32 = jnp.int32
    waiting = state.pipe_status == int(PipeStatus.WAITING)
    q = jnp.sum(waiting & state.pipe_offered).astype(i32)
    slots = jnp.maximum(jnp.int32(params.admit_queue_limit) - q, 0)
    rank = jnp.cumsum(offered.astype(i32))
    reject = offered & (rank > slots)
    return state, reject, _zeros_like_mask(offered), 1


@register_admission_policy_py("queue_threshold")
def queue_threshold_py(params, tick, offered, view):
    slots = max(params.admit_queue_limit - view.admitted_waiting, 0)
    return list(offered[slots:]), [], 1


def _token_bucket_consts(params: SimParams) -> tuple[np.float32, int]:
    """(per-tick refill rate as f32, defer interval in ticks) — static."""
    rate = np.float32(params.admit_rate_per_s / TICKS_PER_SECOND)
    if params.admit_rate_per_s > 0:
        defer_ticks = max(
            int(np.ceil(TICKS_PER_SECOND / params.admit_rate_per_s)), 1
        )
    else:  # zero rate: only the initial burst ever admits
        defer_ticks = int(TICKS_PER_SECOND)
    return rate, defer_ticks


@register_admission_policy("token_bucket")
def token_bucket(state, wl, params, tick, offered):
    """DEFER offers beyond a token-bucket rate limit.

    Tokens accrue at ``admit_rate_per_s`` up to ``admit_burst``; each
    admission consumes one. Offers without a token are deferred one
    refill interval (the bucket never rejects — pair it with a client
    concurrency cap or a queue threshold for shedding).
    """
    f32 = jnp.float32
    i32 = jnp.int32
    rate, defer_ticks = _token_bucket_consts(params)
    elapsed = (tick - state.admit_last_tick).astype(f32)
    # the max is value-neutral (elapsed, rate >= 0) but blocks XLA from
    # contracting the mul+add into an FMA, which would round differently
    # from the np.float32 mirror below (mirror discipline: every f32 op
    # must round identically in both engines)
    refill = jnp.maximum(elapsed * jnp.float32(rate), jnp.float32(0.0))
    tokens = jnp.minimum(
        state.admit_tokens + refill,
        jnp.float32(params.admit_burst),
    )
    n_admit = jnp.floor(tokens).astype(i32)
    rank = jnp.cumsum(offered.astype(i32))
    admit = offered & (rank <= n_admit)
    defer = offered & ~admit
    tokens = tokens - jnp.sum(admit).astype(f32)
    state = state._replace(admit_tokens=tokens, admit_last_tick=tick)
    return state, _zeros_like_mask(offered), defer, defer_ticks


@register_admission_policy_py("token_bucket")
def token_bucket_py(params, tick, offered, view):
    regs = view.regs
    rate, defer_ticks = _token_bucket_consts(params)
    elapsed = np.float32(tick - regs["last_tick"])
    tokens = np.minimum(
        np.float32(regs["tokens"] + np.float32(elapsed * rate)),
        np.float32(params.admit_burst),
    )
    n_admit = int(np.floor(tokens).astype(np.int32))
    admit = offered[:n_admit] if n_admit > 0 else []
    defer = list(offered[len(admit):])
    regs["tokens"] = np.float32(tokens - np.float32(len(admit)))
    regs["last_tick"] = tick
    return [], defer, defer_ticks


@register_admission_policy("codel")
def codel(state, wl, params, tick, offered):
    """REJECT all offers while queue delay stays above target (CoDel).

    Delay = sojourn of the oldest admitted-and-waiting pipeline. Once it
    exceeds ``codel_target_ticks`` continuously for
    ``codel_interval_ticks``, every offer is rejected until the delay
    recovers — bounding queue *delay* rather than queue *depth*.
    """
    i32 = jnp.int32
    waiting_adm = (
        state.pipe_status == int(PipeStatus.WAITING)
    ) & state.pipe_offered
    oldest = jnp.min(jnp.where(waiting_adm, state.pipe_entered, INF_TICK))
    delay = jnp.where(oldest == INF_TICK, 0, tick - oldest).astype(i32)
    above = delay > jnp.int32(params.codel_target_ticks)
    above_since = jnp.where(
        above,
        jnp.minimum(state.codel_above_since, tick),
        INF_TICK,
    )
    overload = above & (
        (tick - above_since) >= jnp.int32(params.codel_interval_ticks)
    )
    reject = offered & overload
    state = state._replace(codel_above_since=above_since)
    return state, reject, _zeros_like_mask(offered), 1


@register_admission_policy_py("codel")
def codel_py(params, tick, offered, view):
    regs = view.regs
    oldest = view.oldest_admitted_entered
    delay = 0 if oldest == int(INF_TICK) else tick - oldest
    above = delay > params.codel_target_ticks
    if above:
        regs["above_since"] = min(regs["above_since"], tick)
    else:
        regs["above_since"] = int(INF_TICK)
    overload = above and (tick - regs["above_since"]
                          >= params.codel_interval_ticks)
    return (list(offered) if overload else []), [], 1


# ---------------------------------------------------------------------------
# The closed-loop pass. Runs at the top of every engine event (fused:
# engine._lane_decide before the pre-decision snapshot; reference:
# engine._tick_body after the fault pass; python: engine_python between
# the chaos block and the scheduler) — statically compiled out when
# ``params.closed_loop_active`` is False.
# ---------------------------------------------------------------------------
def apply_closed_loop(
    state: SimState, wl: Workload, tick: jax.Array, params: SimParams
) -> SimState:
    """Offer pending arrivals through the client gate + admission policy.

    Fresh presentations are WAITING pipelines that never started and are
    not currently admitted (``~pipe_offered``) — i.e. new arrivals plus
    deferred/client-retried ones re-landed by the release machinery.
    Each presentation re-counts toward ``offered_total``, which is what
    makes the retry-amplification factor observable. Deferred and
    client-retried offers park as SUSPENDED with a release tick folded
    into ``nxt_release``, so the event-skip registers stay exact with no
    new event source.
    """
    i32 = jnp.int32
    f32 = jnp.float32
    WAITING = int(PipeStatus.WAITING)
    waiting = state.pipe_status == WAITING
    fresh = (
        waiting & (state.pipe_first_start == INF_TICK) & ~state.pipe_offered
    )

    # ---- client concurrency gate (closed-loop think time) ----------------
    if params.client_max_inflight > 0:
        active = (
            waiting
            | (state.pipe_status == int(PipeStatus.RUNNING))
            | (state.pipe_status == int(PipeStatus.SUSPENDED))
        )
        inflight = jnp.sum(state.pipe_offered & active).astype(i32)
        slots = jnp.maximum(jnp.int32(params.client_max_inflight) - inflight, 0)
        rank = jnp.cumsum(fresh.astype(i32))
        offer = fresh & (rank <= slots)
        gate_defer = fresh & ~offer
    else:
        offer = fresh
        gate_defer = jnp.zeros_like(fresh)

    prio_rows = jnp.arange(3, dtype=i32)[:, None] == wl.prio[None, :]  # [3,MP]
    off_prio = jnp.sum(prio_rows & offer[None, :], axis=1).astype(i32)

    # ---- admission policy (reads the pre-admission queue) ----------------
    if params.admission_active:
        policy = get_admission_policy(params.admission_policy)
        state, reject, defer, defer_ticks = policy(
            state, wl, params, tick, offer
        )
    else:
        reject = jnp.zeros_like(offer)
        defer = jnp.zeros_like(offer)
        defer_ticks = 1
    admit = offer & ~reject & ~defer
    adm_prio = jnp.sum(prio_rows & admit[None, :], axis=1).astype(i32)

    # ---- rejects: client retry with capped exponential backoff, or shed --
    attempts = state.pipe_client_attempts
    can_retry = reject & (attempts < jnp.int32(params.client_max_retries))
    shed = reject & ~can_retry
    backoff = jnp.minimum(
        jnp.float32(params.client_backoff_ticks)
        * jnp.exp2(jnp.minimum(attempts, 30).astype(f32)),
        jnp.float32(2**30),
    ).astype(i32)
    retry_release = tick + jnp.maximum(backoff, 1)

    gate_release = tick + jnp.int32(max(int(params.client_think_ticks), 1))
    pol_release = tick + jnp.int32(max(int(defer_ticks), 1))
    to_suspend = gate_defer | defer | can_retry
    release = jnp.where(
        gate_defer,
        gate_release,
        jnp.where(defer, pol_release, retry_release),
    )

    new_status = jnp.where(
        to_suspend,
        int(PipeStatus.SUSPENDED),
        jnp.where(shed, int(PipeStatus.FAILED), state.pipe_status),
    )
    state = state._replace(
        pipe_status=new_status,
        pipe_release=jnp.where(to_suspend, release, state.pipe_release),
        pipe_completion=jnp.where(shed, tick, state.pipe_completion),
        pipe_offered=state.pipe_offered | admit,
        pipe_presented=state.pipe_presented | offer,
        pipe_client_attempts=attempts + can_retry.astype(i32),
        offered_total=state.offered_total + jnp.sum(offer).astype(i32),
        offered_unique=state.offered_unique
        + jnp.sum(offer & ~state.pipe_presented).astype(i32),
        admitted_total=state.admitted_total + jnp.sum(admit).astype(i32),
        shed_total=state.shed_total + jnp.sum(reject).astype(i32),
        deferred_total=state.deferred_total
        + jnp.sum(gate_defer | defer).astype(i32),
        client_retry_events=state.client_retry_events
        + jnp.sum(can_retry).astype(i32),
        offered_prio=state.offered_prio + off_prio,
        admitted_prio=state.admitted_prio + adm_prio,
        failed_count=state.failed_count + jnp.sum(shed).astype(i32),
        nxt_release=jnp.minimum(
            state.nxt_release,
            jnp.min(jnp.where(to_suspend, release, INF_TICK)),
        ),
    )

    # ---- drain detection (overload recovery, needs the chaos layer) ------
    if params.fault_events_active:
        backlog = jnp.sum(state.pipe_status == WAITING).astype(i32)
        drained = (
            (state.last_fault_tick != INF_TICK)
            & (tick > state.last_fault_tick)
            & (backlog <= jnp.maximum(state.prefault_backlog, 0))
            & (state.drain_tick == INF_TICK)
        )
        state = state._replace(
            drain_tick=jnp.where(drained, tick, state.drain_tick)
        )
    return state


__all__ = [
    "AdmissionPolicy",
    "AdmissionPolicyPy",
    "AdmissionView",
    "apply_closed_loop",
    "admit_all",
    "codel",
    "get_admission_policy",
    "get_admission_policy_py",
    "has_admission_policy",
    "list_admission_policies",
    "queue_threshold",
    "register_admission_policy",
    "register_admission_policy_py",
    "token_bucket",
]
