"""Deterministic fault injection: the chaos layer's trace generator.

Real FaaS infrastructure fails — workers crash, pools get preempted,
functions hang (cf. Bauplan's worker-loss/re-execution model). The
simulator injects three fault classes, all **pre-materialised from the
seed** exactly like the arrival table (no on-device RNG), so every
engine — fused lane-major, device-sharded fleets, the Python reference —
replays the identical fault sequence bit-for-bit:

* **transient crashes** (``crash_mtbf_ticks``): at each sampled tick the
  longest-running container is killed and its pipeline re-queued;
* **pool outages** (``outage_mtbf_ticks`` / ``outage_duration_ticks``):
  a sampled pool goes down for an interval — every container on it is
  killed, its LRU cache flushed (cold data plane on recovery), and its
  capacity masked from the scheduler until the recovery tick;
* **stragglers** (``straggler_prob`` / ``straggler_factor``): a sampled
  per-pipeline slowdown multiplier stretches container durations.

Recovery is governed by the retry policy in ``params``: fault-killed and
timed-out pipelines re-queue at ``tick + base_backoff_ticks *
2**attempt`` until ``max_retries`` is exhausted, then fail. See
docs/faults.md for the full contract.

The trace generator folds the workload key at indices 8..12 —
``generate_workload`` consumes split indices 0..6 and fold-in 7, so a
workload's arrival/ops draws are bitwise-unchanged whether faults are on
or off.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .params import SimParams
from .state import INF_TICK, FaultTrace, Workload

# fold-in indices reserved by the fault generator (workload.py owns 0..7)
_K_CRASH, _K_OUTAGE_START, _K_OUTAGE_DUR, _K_OUTAGE_POOL, _K_STRAGGLER = (
    8, 9, 10, 11, 12,
)


def empty_fault_trace(params: SimParams) -> FaultTrace:
    """An all-padding (inert) fault trace shaped by ``params``."""
    MF = params.max_fault_events
    MP = params.max_pipelines
    i32 = jnp.int32
    return FaultTrace(
        crash_time=jnp.full((MF,), INF_TICK, i32),
        outage_start=jnp.full((MF,), INF_TICK, i32),
        outage_end=jnp.full((MF,), INF_TICK, i32),
        outage_pool=jnp.zeros((MF,), i32),
        straggler=jnp.ones((MP,), jnp.float32),
    )


def _event_times(key, mtbf_ticks: float, horizon: int, MF: int) -> jax.Array:
    """Sorted Poisson-process event ticks, INF-padded past the horizon
    (the same cumsum-of-exponential-gaps construction as arrivals)."""
    gaps = jax.random.exponential(key, (MF,)) * mtbf_ticks
    t = jnp.cumsum(gaps).astype(jnp.int32)
    return jnp.where(t < horizon, t, INF_TICK)


def generate_fault_trace(
    params: SimParams, key: jax.Array | None = None
) -> FaultTrace:
    """Materialise the fault trace for one lane from ``key``.

    Only the classes whose knobs are on draw anything; the rest stay
    padding. ``key`` defaults to ``PRNGKey(params.seed)`` — the same key
    the workload generator uses, so ``run()``'s workload and fault trace
    derive from one seed.
    """
    if key is None:
        key = jax.random.PRNGKey(params.seed)
    ft = empty_fault_trace(params)
    MF = params.max_fault_events
    MP = params.max_pipelines
    horizon = params.horizon_ticks
    if params.crash_mtbf_ticks > 0:
        ft = ft._replace(crash_time=_event_times(
            jax.random.fold_in(key, _K_CRASH),
            params.crash_mtbf_ticks, horizon, MF,
        ))
    if params.outage_mtbf_ticks > 0:
        start = _event_times(
            jax.random.fold_in(key, _K_OUTAGE_START),
            params.outage_mtbf_ticks, horizon, MF,
        )
        dur = jax.random.exponential(
            jax.random.fold_in(key, _K_OUTAGE_DUR), (MF,)
        ) * params.outage_duration_ticks
        dur = jnp.maximum(
            jnp.minimum(dur, jnp.float32(2**30)).astype(jnp.int32), 1
        )
        end = jnp.where(start < INF_TICK, start + dur, INF_TICK)
        pool = jax.random.randint(
            jax.random.fold_in(key, _K_OUTAGE_POOL),
            (MF,), 0, params.num_pools, jnp.int32,
        )
        ft = ft._replace(outage_start=start, outage_end=end, outage_pool=pool)
    if params.straggler_prob > 0:
        slow = jax.random.bernoulli(
            jax.random.fold_in(key, _K_STRAGGLER),
            params.straggler_prob, (MP,),
        )
        ft = ft._replace(straggler=jnp.where(
            slow, jnp.float32(params.straggler_factor), jnp.float32(1.0)
        ))
    return ft


def attach_fault_trace(
    wl: Workload, params: SimParams, key: jax.Array | None = None
) -> Workload:
    """Return ``wl`` with a generated fault trace attached (single lane)."""
    return wl._replace(faults=generate_fault_trace(params, key))


def attach_fault_traces(wls: Workload, params: SimParams) -> Workload:
    """Attach per-lane fault traces to a workload *batch* (trace-replay /
    scenario lanes, which carry no per-lane seed): lane ``i`` draws from
    ``fold_in(PRNGKey(params.seed), i)``, so the batch is reproducible
    from ``params.seed`` alone and every lane's faults differ."""
    F = wls.arrival.shape[0]
    base = jax.random.PRNGKey(params.seed)
    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(
        jnp.arange(F, dtype=jnp.uint32)
    )
    faults = jax.vmap(lambda k: generate_fault_trace(params, k))(keys)
    return wls._replace(faults=faults)


# ---------------------------------------------------------------------------
# Record round-trip (trace-format companion, docs/trace-format.md): a
# fault trace serialises to one plain dict of lists and back bitwise.
# ---------------------------------------------------------------------------
def fault_trace_to_records(ft: FaultTrace) -> dict[str, list]:
    """Serialise a fault trace to a JSON-able dict (exact round-trip:
    ``fault_trace_from_records(fault_trace_to_records(ft), params)``
    reproduces every array bitwise).

    >>> from repro.core import SimParams
    >>> p = SimParams(max_pipelines=4, max_fault_events=4,
    ...               crash_mtbf_ticks=500.0, straggler_prob=0.5,
    ...               duration=0.01)
    >>> recs = fault_trace_to_records(generate_fault_trace(p))
    >>> sorted(recs) == ['crash_time', 'outage_end', 'outage_pool',
    ...                  'outage_start', 'straggler']
    True
    """
    return {
        "crash_time": [int(t) for t in np.asarray(ft.crash_time)],
        "outage_start": [int(t) for t in np.asarray(ft.outage_start)],
        "outage_end": [int(t) for t in np.asarray(ft.outage_end)],
        "outage_pool": [int(p) for p in np.asarray(ft.outage_pool)],
        "straggler": [float(f) for f in np.asarray(ft.straggler)],
    }


def fault_trace_from_records(
    records: dict[str, Sequence[Any]], params: SimParams
) -> FaultTrace:
    """Rebuild a :class:`FaultTrace` from its record dict, padding short
    lists to ``params``' capacities (missing keys stay inert padding)."""
    MF = params.max_fault_events
    MP = params.max_pipelines

    def _pad_i32(name: str, fill: int, n: int) -> jax.Array:
        vals = [int(v) for v in records.get(name, ())]
        if len(vals) > n:
            raise ValueError(
                f"fault trace {name!r} has {len(vals)} entries > capacity {n}"
            )
        return jnp.asarray(
            vals + [fill] * (n - len(vals)), jnp.int32
        )

    strag = [float(v) for v in records.get("straggler", ())]
    if len(strag) > MP:
        raise ValueError(
            f"fault trace straggler has {len(strag)} entries > {MP} pipelines"
        )
    return FaultTrace(
        crash_time=_pad_i32("crash_time", int(INF_TICK), MF),
        outage_start=_pad_i32("outage_start", int(INF_TICK), MF),
        outage_end=_pad_i32("outage_end", int(INF_TICK), MF),
        outage_pool=_pad_i32("outage_pool", 0, MF),
        straggler=jnp.asarray(
            strag + [1.0] * (MP - len(strag)), jnp.float32
        ),
    )


__all__ = [
    "empty_fault_trace",
    "generate_fault_trace",
    "attach_fault_trace",
    "attach_fault_traces",
    "fault_trace_to_records",
    "fault_trace_from_records",
]
