"""Executor (paper §3.2.2): manager of the simulated physical resources.

Pure-JAX transition functions shared by the tick engine and the
event-skip engine. Order inside one tick:

    arrivals -> suspension releases -> completions/OOMs ->
    scheduler -> apply (suspend, reject, assign) -> integrate utilisation

Containers compute their completion tick and (if the RAM allocation is
insufficient) their OOM tick *at creation time*, exactly as §3.2.2
describes, via :func:`repro.core.state.container_schedule`.

Data plane (beyond the paper; cf. Bauplan, arXiv 2410.17465): at
creation the container is additionally charged

* a **cold-start latency** unless it lands on a slot kept warm by a
  container that retired on the same pool within ``container_warm_ticks``,
* a **data-scan cost** (``scan_ticks_per_gb``) for the pipeline's
  intermediate bytes not resident in the pool's zero-copy cache,

and the pipeline's intermediates are inserted into the pool's cache
(LRU by last-touch tick, capacity ``cache_gb_per_pool``). Both charges
are folded into ``ctr_end``/``ctr_oom`` at creation, so the event-skip
engine's ``_next_event`` accounts for cold-start release ticks for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import SimParams
from .scheduler import SchedDecision
from .state import (
    INF_TICK,
    SimState,
    Workload,
    cache_insert,
    container_schedule,
    used_resources,
)
from .types import ContainerStatus, PipeStatus, TICKS_PER_SECOND
from repro.kernels.state_update import assign_gather, retire_land


def _warm_until(tick: jax.Array, params: SimParams) -> jax.Array:
    """Warmth expiry tick, saturated at INF_TICK so huge warm windows
    ("keep slots warm forever") cannot overflow int32. Int32-safe even
    with x64 disabled: the window is clamped to the headroom INF_TICK -
    tick before the add. The python engine keeps these in int64 and
    clamps at export, so the saturation preserves engine equivalence."""
    window = jnp.int32(min(int(params.container_warm_ticks), int(INF_TICK)))
    return tick + jnp.minimum(window, INF_TICK - tick)


def process_arrivals(state: SimState, wl: Workload, tick: jax.Array) -> SimState:
    """PENDING/EMPTY slots whose arrival tick has come join the queue."""
    fresh = (state.pipe_status == int(PipeStatus.EMPTY)) & (wl.arrival <= tick)
    return state._replace(
        pipe_status=jnp.where(
            fresh, int(PipeStatus.WAITING), state.pipe_status
        ),
        pipe_entered=jnp.where(fresh, wl.arrival, state.pipe_entered),
    )


def process_releases(state: SimState, tick: jax.Array) -> SimState:
    """Suspended pipelines re-enter the waiting queue after their 1-tick
    stay in the suspending queue (paper §4.1.3 (1))."""
    suspended = state.pipe_status == int(PipeStatus.SUSPENDED)
    rel = suspended & (state.pipe_release <= tick)
    # next-event register: min release over the pipes still suspended
    still = suspended & ~rel
    nxt_release = jnp.min(jnp.where(still, state.pipe_release, INF_TICK))
    return state._replace(
        pipe_status=jnp.where(rel, int(PipeStatus.WAITING), state.pipe_status),
        pipe_entered=jnp.where(rel, state.pipe_release, state.pipe_entered),
        pipe_release=jnp.where(rel, INF_TICK, state.pipe_release),
        nxt_release=nxt_release,
    )


def _requeue_faulted(
    state: SimState,
    tick: jax.Array,
    params: SimParams,
    fault_hit: jax.Array,  # [MP] bool — pipelines whose container was killed
) -> SimState:
    """Re-queue fault-killed / timed-out pipelines under the retry policy.

    A struck pipeline with retry budget left re-enters the queue at
    ``tick + base_backoff_ticks * 2**attempt`` (through the existing
    SUSPENDED/release machinery, so the event registers need no new
    source); once ``max_retries`` attempts are spent it transitions to
    FAILED. Unlike an OOM, a fault kill does not set ``pipe_fail_flag``
    (the allocation was fine — the worker died), so the scheduler's
    doubling/reject rules are untouched. The backoff arithmetic is f32
    (exact for power-of-two scaling), mirrored op-for-op by
    ``engine_python._requeue_faulted_py``.
    """
    i32 = jnp.int32
    attempt = state.pipe_retries
    exhausted = fault_hit & (attempt >= params.max_retries)
    retry = fault_hit & ~exhausted
    backoff = jnp.minimum(
        jnp.float32(params.base_backoff_ticks)
        * jnp.exp2(jnp.minimum(attempt, 30).astype(jnp.float32)),
        jnp.float32(2**30),
    ).astype(i32)
    release = tick + jnp.maximum(backoff, 1)
    nxt_release = jnp.minimum(
        state.nxt_release,
        jnp.min(jnp.where(retry, release, INF_TICK)),
    )
    return state._replace(
        pipe_status=jnp.where(
            exhausted,
            int(PipeStatus.FAILED),
            jnp.where(retry, int(PipeStatus.SUSPENDED), state.pipe_status),
        ),
        pipe_completion=jnp.where(exhausted, tick, state.pipe_completion),
        pipe_release=jnp.where(retry, release, state.pipe_release),
        pipe_retries=state.pipe_retries + retry.astype(i32),
        failed_count=state.failed_count + jnp.sum(exhausted).astype(i32),
        retry_events=state.retry_events + jnp.sum(retry).astype(i32),
        nxt_release=nxt_release,
    )


def apply_faults(
    state: SimState, wl: Workload, tick: jax.Array, params: SimParams
):
    """Apply the crash/outage events due at ``tick`` (chaos layer).

    Runs between phase 1 and the scheduler when
    ``params.fault_events_active`` — the faults-off engine never calls
    it. Consumes the pre-materialised fault trace through the
    ``crash_cursor``/``outage_cursor`` registers:

    * each due **crash** kills the longest-running container (start tick
      asc, slot asc) — a crash with nothing running strikes an idle
      worker and kills nothing;
    * each due **outage** marks its pool down until ``outage_end``
      (scheduler capacity is masked while ``tick < pool_down_until``),
      kills every container on it, flushes the pool's LRU cache and its
      warm slots;

    killed pipelines re-queue via :func:`_requeue_faulted`, and the
    ``nxt_fault`` register is recomputed so the event-skip loop wakes at
    the next crash, outage start, or pool recovery.

    Returns ``(state, fault_aux)``; ``fault_aux = (kill, kill_pipe,
    kill_pool, kill_cause, kill_wasted, down_new, up_now,
    pool_down_until)`` feeds the telemetry recorder (reads only).
    """
    ft = wl.faults
    i32 = jnp.int32
    MC = state.ctr_status.shape[0]
    NP = state.pool_cpu_cap.shape[0]
    MP = state.pipe_status.shape[0]
    MF = ft.crash_time.shape[0]
    fidx = jnp.arange(MF, dtype=i32)
    slots = jnp.arange(MC, dtype=i32)
    running = state.ctr_status == int(ContainerStatus.RUNNING)

    # pools recovering exactly now (telemetry marker; the capacity unmask
    # is implicit — a pool is down iff tick < pool_down_until)
    up_now = (state.pool_down_until > 0) & (state.pool_down_until == tick)

    # ---- transient crashes -------------------------------------------------
    if params.crash_mtbf_ticks > 0:
        new_ccur = jnp.searchsorted(
            ft.crash_time, tick, side="right"
        ).astype(i32)
        k_due = new_ccur - state.crash_cursor
        # rank running containers by (start asc, slot asc); the k_due
        # longest-running are struck
        earlier = (state.ctr_start[None, :] < state.ctr_start[:, None]) | (
            (state.ctr_start[None, :] == state.ctr_start[:, None])
            & (slots[None, :] < slots[:, None])
        )
        rank = jnp.sum(running[None, :] & earlier, axis=1).astype(i32)
        crash_kill = running & (rank < k_due)
    else:
        new_ccur = state.crash_cursor
        k_due = jnp.int32(0)
        crash_kill = jnp.zeros((MC,), bool)

    # ---- pool outages ------------------------------------------------------
    pool_down_until = state.pool_down_until
    if params.outage_mtbf_ticks > 0:
        new_ocur = jnp.searchsorted(
            ft.outage_start, tick, side="right"
        ).astype(i32)
        due = (fidx >= state.outage_cursor) & (fidx < new_ocur)
        n_due = new_ocur - state.outage_cursor
        pool_t = jnp.where(due, ft.outage_pool, NP)  # NP = not due, no hit
        # one-hot forms, not ``.at[pool_t].add/max`` scatters: a vmapped
        # dynamic-index scatter serializes into a while thunk per
        # scatter on XLA:CPU (see docs/architecture.md §"Kernel
        # subsystems"). Bitwise identical — ``> 0`` == any for the hit
        # mask, and the int scatter-max is a reassociation-exact
        # max-fold.
        pool_oh_f = pool_t[:, None] == jnp.arange(NP, dtype=i32)[None, :]
        down_new = jnp.any(pool_oh_f, axis=0)
        pool_down_until = jnp.maximum(
            pool_down_until,
            jnp.max(
                jnp.where(
                    pool_oh_f, jnp.where(due, ft.outage_end, 0)[:, None], 0
                ),
                axis=0,
                initial=0,
            ),
        )
        out_kill = running & ~crash_kill & down_new[state.ctr_pool]
    else:
        new_ocur = state.outage_cursor
        n_due = jnp.int32(0)
        down_new = jnp.zeros((NP,), bool)
        out_kill = jnp.zeros((MC,), bool)

    kill = crash_kill | out_kill
    kill_pipe = jnp.where(kill, state.ctr_pipe, -1)
    kill_pool = jnp.where(kill, state.ctr_pool, -1)
    kill_cause = jnp.where(crash_kill, 0, 1).astype(i32)
    kill_wasted = jnp.where(kill, tick - state.ctr_start, 0).astype(i32)

    # ---- free struck resources, clear struck containers --------------------
    pool_oh = (
        state.ctr_pool[None, :] == jnp.arange(NP, dtype=i32)[:, None]
    ) & kill[None, :]
    freed_cpu = jnp.sum(
        jnp.where(pool_oh, state.ctr_cpus[None, :], 0.0), axis=1
    )
    freed_ram = jnp.sum(
        jnp.where(pool_oh, state.ctr_ram[None, :], 0.0), axis=1
    )
    still = running & ~kill
    nxt_retire = jnp.min(
        jnp.where(still, jnp.minimum(state.ctr_end, state.ctr_oom), INF_TICK)
    )
    pid = jnp.where(kill, state.ctr_pipe, MP)  # MP = not killed, no hit
    # one-hot membership, not a ``.at[pid].add`` scatter (see the outage
    # landing above for why); ``> 0`` == any, bitwise
    fault_hit = jnp.any(
        pid[:, None] == jnp.arange(MP, dtype=i32)[None, :], axis=0
    )

    # a struck slot is cold (no warm hand-off), and every slot kept warm
    # for a newly-down pool loses its warmth with the pool
    slot_warm_pool = jnp.where(kill, -1, state.slot_warm_pool)
    slot_warm_until = jnp.where(kill, 0, state.slot_warm_until)
    if params.outage_mtbf_ticks > 0:
        warm_down = (slot_warm_pool >= 0) & down_new[
            jnp.clip(slot_warm_pool, 0, NP - 1)
        ]
        slot_warm_pool = jnp.where(warm_down, -1, slot_warm_pool)
        slot_warm_until = jnp.where(warm_down, 0, slot_warm_until)

    # ---- next-fault register (next crash / outage start / recovery) --------
    nxt_fault = jnp.asarray(INF_TICK, i32)
    if params.crash_mtbf_ticks > 0:
        nxt_fault = jnp.minimum(
            nxt_fault,
            jnp.min(jnp.where(fidx >= new_ccur, ft.crash_time, INF_TICK)),
        )
    if params.outage_mtbf_ticks > 0:
        nxt_fault = jnp.minimum(
            nxt_fault,
            jnp.min(jnp.where(fidx >= new_ocur, ft.outage_start, INF_TICK)),
        )
        nxt_fault = jnp.minimum(
            nxt_fault,
            jnp.min(
                jnp.where(pool_down_until > tick, pool_down_until, INF_TICK)
            ),
        )

    state = state._replace(
        ctr_status=jnp.where(
            kill, int(ContainerStatus.EMPTY), state.ctr_status
        ),
        ctr_pipe=jnp.where(kill, -1, state.ctr_pipe),
        ctr_end=jnp.where(kill, INF_TICK, state.ctr_end),
        ctr_oom=jnp.where(kill, INF_TICK, state.ctr_oom),
        ctr_start=jnp.where(kill, INF_TICK, state.ctr_start),
        ctr_prio=jnp.where(kill, -1, state.ctr_prio),
        ctr_warm=jnp.where(kill, False, state.ctr_warm),
        ctr_timed=jnp.where(kill, False, state.ctr_timed),
        slot_warm_pool=slot_warm_pool,
        slot_warm_until=slot_warm_until,
        pool_cpu_free=state.pool_cpu_free + freed_cpu,
        pool_ram_free=state.pool_ram_free + freed_ram,
        nxt_retire=nxt_retire,
        pool_down_until=pool_down_until,
        crash_cursor=new_ccur,
        outage_cursor=new_ocur,
        nxt_fault=nxt_fault,
        crash_events=state.crash_events + k_due,
        outage_events=state.outage_events + n_due,
        fault_kills=state.fault_kills + jnp.sum(kill).astype(i32),
        wasted_ticks=state.wasted_ticks + jnp.sum(kill_wasted),
    )
    if params.outage_mtbf_ticks > 0 and params.cache_gb_per_pool > 0:
        # outage flushes the pool's zero-copy cache: recovery is cold
        state = state._replace(
            cache_bytes=jnp.where(down_new[:, None], 0.0, state.cache_bytes),
            cache_last=jnp.where(down_new[:, None], 0, state.cache_last),
            pool_cache_used=jnp.where(down_new, 0.0, state.pool_cache_used),
        )
    if params.closed_loop_active:
        # overload bookkeeping (docs/closed-loop.md): remember the last
        # crash/outage tick and the backlog at the FIRST fault; a new
        # fault re-arms drain detection (apply_closed_loop re-stamps
        # drain_tick once the backlog recovers). Kills and re-queues
        # never touch WAITING pipelines, so the backlog count is the
        # same anywhere in this pass.
        fault_now = (k_due > 0) | (n_due > 0)
        backlog_now = jnp.sum(
            state.pipe_status == int(PipeStatus.WAITING)
        ).astype(i32)
        state = state._replace(
            last_fault_tick=jnp.where(
                fault_now, tick, state.last_fault_tick
            ),
            prefault_backlog=jnp.where(
                fault_now & (state.prefault_backlog < 0),
                backlog_now,
                state.prefault_backlog,
            ),
            drain_tick=jnp.where(
                fault_now, INF_TICK, state.drain_tick
            ),
        )
    state = _requeue_faulted(state, tick, params, fault_hit)
    fault_aux = (
        kill, kill_pipe, kill_pool, kill_cause, kill_wasted,
        down_new, up_now, pool_down_until,
    )
    return state, fault_aux


def _apply_retirements(
    state: SimState,
    wl: Workload,
    tick: jax.Array,
    params: SimParams,
    oomed: jax.Array,
    done: jax.Array,
    freed_cpu: jax.Array,
    freed_ram: jax.Array,
    nxt_retire: jax.Array,
) -> SimState:
    """Apply precomputed retire masks + freed-resource sums to the state.

    Shared by :func:`process_completions` (which derives the masks
    itself) and :func:`apply_fused_phase1` (which gets them from the
    fused ``sim_tick`` pass) — one body, so the bitwise fused-vs-
    sequential invariant cannot drift when completion effects change.
    """
    retired = oomed | done

    # ---- timeout split (chaos layer, compiled out when the knob is 0) ------
    # a container whose ``ctr_end`` is the timeout deadline (not a real
    # completion) retires like a completion — the slot frees and stays
    # warm — but its pipeline re-queues under the retry policy instead
    # of completing.
    if params.timeout_ticks > 0:
        timed = done & state.ctr_timed
        done_eff = done & ~timed
    else:
        timed = jnp.zeros_like(done)
        done_eff = done

    # ---- per-pipeline effects (scatter via segment-sum over containers) ----
    MP = state.pipe_status.shape[0]
    pid = jnp.where(retired, state.ctr_pipe, MP)  # out-of-range = dropped
    oom_hit = (
        jnp.zeros((MP,), jnp.int32)
        .at[pid]
        .add(oomed.astype(jnp.int32), mode="drop")
    ) > 0
    done_hit = (
        jnp.zeros((MP,), jnp.int32)
        .at[pid]
        .add(done_eff.astype(jnp.int32), mode="drop")
    ) > 0
    end_of = (
        jnp.full((MP,), 0, jnp.int32)
        .at[pid]
        .max(jnp.where(done_eff, state.ctr_end, 0), mode="drop")
    )
    if params.timeout_ticks > 0:
        # timed-out pipelines and wasted work, read before the container
        # table is cleared below
        timed_hit = (
            jnp.zeros((MP,), jnp.int32)
            .at[jnp.where(timed, state.ctr_pipe, MP)]
            .add(timed.astype(jnp.int32), mode="drop")
        ) > 0
        timed_wasted = jnp.sum(
            jnp.where(timed, tick - state.ctr_start, 0)
        ).astype(jnp.int32)

    lat_s = (end_of - wl.arrival).astype(jnp.float32) / TICKS_PER_SECOND
    lat_s = jnp.where(done_hit, lat_s, 0.0)
    prio_oh = (
        wl.prio[None, :] == jnp.arange(3, dtype=jnp.int32)[:, None]
    )  # [3, MP]

    state = state._replace(
        nxt_retire=nxt_retire,
        pipe_status=jnp.where(
            oom_hit,
            int(PipeStatus.WAITING),
            jnp.where(done_hit, int(PipeStatus.DONE), state.pipe_status),
        ),
        pipe_entered=jnp.where(oom_hit, tick, state.pipe_entered),
        pipe_fail_flag=state.pipe_fail_flag | oom_hit,
        pipe_fails=state.pipe_fails + oom_hit.astype(jnp.int32),
        pipe_completion=jnp.where(done_hit, end_of, state.pipe_completion),
        ctr_status=jnp.where(
            retired, int(ContainerStatus.EMPTY), state.ctr_status
        ),
        ctr_pipe=jnp.where(retired, -1, state.ctr_pipe),
        ctr_end=jnp.where(retired, INF_TICK, state.ctr_end),
        ctr_oom=jnp.where(retired, INF_TICK, state.ctr_oom),
        ctr_start=jnp.where(retired, INF_TICK, state.ctr_start),
        ctr_prio=jnp.where(retired, -1, state.ctr_prio),
        # retired containers keep their slot warm on their pool for a while
        ctr_warm=jnp.where(retired, False, state.ctr_warm),
        slot_warm_pool=jnp.where(retired, state.ctr_pool, state.slot_warm_pool),
        slot_warm_until=jnp.where(
            retired, _warm_until(tick, params), state.slot_warm_until
        ),
        pool_cpu_free=state.pool_cpu_free + freed_cpu,
        pool_ram_free=state.pool_ram_free + freed_ram,
        done_count=state.done_count + jnp.sum(done_hit).astype(jnp.int32),
        oom_events=state.oom_events + jnp.sum(oom_hit).astype(jnp.int32),
        sum_latency_s=state.sum_latency_s + jnp.sum(lat_s),
        sum_latency_s_prio=state.sum_latency_s_prio
        + jnp.sum(jnp.where(prio_oh, lat_s[None, :], 0.0), axis=1),
        done_prio=state.done_prio
        + jnp.sum(prio_oh & done_hit[None, :], axis=1).astype(jnp.int32),
    )
    if params.timeout_ticks > 0:
        state = state._replace(
            ctr_timed=jnp.where(retired, False, state.ctr_timed),
            timeout_events=state.timeout_events
            + jnp.sum(timed).astype(jnp.int32),
            wasted_ticks=state.wasted_ticks + timed_wasted,
        )
        state = _requeue_faulted(state, tick, params, timed_hit)
    return state


def process_completions(
    state: SimState, wl: Workload, tick: jax.Array, params: SimParams
) -> SimState:
    """Retire containers whose OOM or completion tick has arrived."""
    running = state.ctr_status == int(ContainerStatus.RUNNING)
    oomed = running & (state.ctr_oom <= tick)
    done = running & ~oomed & (state.ctr_end <= tick)
    retired = oomed | done

    # ---- free pool resources ------------------------------------------------
    NP = state.pool_cpu_cap.shape[0]
    pool_oh = (
        state.ctr_pool[None, :] == jnp.arange(NP, dtype=jnp.int32)[:, None]
    ) & retired[None, :]
    freed_cpu = jnp.sum(jnp.where(pool_oh, state.ctr_cpus[None, :], 0.0), axis=1)
    freed_ram = jnp.sum(jnp.where(pool_oh, state.ctr_ram[None, :], 0.0), axis=1)

    # next-event register: min(end, oom) over the containers still running
    still = running & ~retired
    nxt_retire = jnp.min(
        jnp.where(still, jnp.minimum(state.ctr_end, state.ctr_oom), INF_TICK)
    )

    return _apply_retirements(
        state, wl, tick, params, oomed, done, freed_cpu, freed_ram, nxt_retire
    )


def apply_decision(
    state: SimState,
    wl: Workload,
    dec: SchedDecision,
    tick: jax.Array,
    params: SimParams,
    early_exit: bool = False,
    with_aux: bool = False,
) -> SimState:
    """Apply one scheduler decision.

    ``early_exit=True`` replaces the fixed ``fori_loop`` over the K
    assignment slots with a ``while_loop`` that stops after the last
    populated slot — bitwise-identical (skipped slots are provable
    no-ops: ``assign_one`` ignores slots with ``assign_pipe < 0``), but
    events with empty decisions no longer pay K sequential iterations.
    The fleet engine uses it; the legacy paths keep the static loop.

    ``with_aux=True`` (early-exit path only) additionally returns the
    per-slot assignment provenance the telemetry recorder needs:
    ``aux_i [K, 4]`` int32 columns ``(pipe, pool, cold_ticks, is_warm)``
    and ``aux_f [K, 5]`` float32 columns ``(cpus, ram, hit_gb, miss_gb,
    total_out)``, with ``pipe = -1`` marking slots that assigned
    nothing. The aux values are the exact intermediates of the commit,
    read out of the same computation — collecting them does not change
    the state update.
    """
    # ---- 1. suspensions (preemptions) --------------------------------------
    susp = dec.suspend & (state.ctr_status == int(ContainerStatus.RUNNING))
    NP = params.num_pools
    pool_oh = (
        state.ctr_pool[None, :] == jnp.arange(NP, dtype=jnp.int32)[:, None]
    ) & susp[None, :]
    freed_cpu = jnp.sum(jnp.where(pool_oh, state.ctr_cpus[None, :], 0.0), axis=1)
    freed_ram = jnp.sum(jnp.where(pool_oh, state.ctr_ram[None, :], 0.0), axis=1)
    MP = params.max_pipelines
    pid = jnp.where(susp, state.ctr_pipe, MP)  # MP = not suspended, no hit
    # one-hot membership, not a ``.at[pid].add`` scatter: vmapped
    # dynamic scatters serialize on XLA:CPU (``> 0`` == any, bitwise)
    susp_hit = jnp.any(
        pid[:, None] == jnp.arange(MP, dtype=jnp.int32)[None, :], axis=0
    )

    # next-event registers: preempted containers leave the running set
    # (recompute the retire min over the survivors); every new suspension
    # releases at tick + 1, so the release min is a running minimum.
    any_susp = jnp.any(susp)
    still = (state.ctr_status == int(ContainerStatus.RUNNING)) & ~susp
    nxt_retire = jnp.min(
        jnp.where(still, jnp.minimum(state.ctr_end, state.ctr_oom), INF_TICK)
    )
    nxt_release = jnp.where(
        any_susp, jnp.minimum(state.nxt_release, tick + 1), state.nxt_release
    )

    state = state._replace(
        nxt_retire=nxt_retire,
        nxt_release=nxt_release,
        pipe_status=jnp.where(
            susp_hit, int(PipeStatus.SUSPENDED), state.pipe_status
        ),
        pipe_release=jnp.where(susp_hit, tick + 1, state.pipe_release),
        pipe_preempts=state.pipe_preempts + susp_hit.astype(jnp.int32),
        ctr_status=jnp.where(susp, int(ContainerStatus.EMPTY), state.ctr_status),
        ctr_pipe=jnp.where(susp, -1, state.ctr_pipe),
        ctr_end=jnp.where(susp, INF_TICK, state.ctr_end),
        ctr_oom=jnp.where(susp, INF_TICK, state.ctr_oom),
        ctr_start=jnp.where(susp, INF_TICK, state.ctr_start),
        ctr_prio=jnp.where(susp, -1, state.ctr_prio),
        ctr_warm=jnp.where(susp, False, state.ctr_warm),
        slot_warm_pool=jnp.where(susp, state.ctr_pool, state.slot_warm_pool),
        slot_warm_until=jnp.where(
            susp, _warm_until(tick, params), state.slot_warm_until
        ),
        pool_cpu_free=state.pool_cpu_free + freed_cpu,
        pool_ram_free=state.pool_ram_free + freed_ram,
        preempt_events=state.preempt_events + jnp.sum(susp).astype(jnp.int32),
    )
    if params.timeout_ticks > 0:
        state = state._replace(
            ctr_timed=jnp.where(susp, False, state.ctr_timed)
        )

    # ---- 2. rejections (failures returned to the user) ---------------------
    rej = dec.reject & (state.pipe_status == int(PipeStatus.WAITING))
    state = state._replace(
        pipe_status=jnp.where(rej, int(PipeStatus.FAILED), state.pipe_status),
        pipe_completion=jnp.where(rej, tick, state.pipe_completion),
        failed_count=state.failed_count + jnp.sum(rej).astype(jnp.int32),
    )

    # ---- 3. assignments ------------------------------------------------------
    def assign_one(k, st: SimState, collect_aux: bool = False):
        pipe = dec.assign_pipe[k]
        valid = pipe >= 0
        pipe_c = jnp.maximum(pipe, 0)
        # only assign pipelines still waiting (belt & braces vs. stale dec)
        valid = valid & (st.pipe_status[pipe_c] == int(PipeStatus.WAITING))
        empty = st.ctr_status == int(ContainerStatus.EMPTY)
        has_slot = jnp.any(empty)
        pool = dec.assign_pool[k]
        if params.cold_start_ticks > 0:
            # prefer the lowest warm slot for the target pool (mirrors
            # engine_python._pick_slot); gated on the knob so the slot
            # order is bit-identical to pre-data-plane when it is off
            warm_ok = (
                empty
                & (st.slot_warm_pool == pool)
                & (tick < st.slot_warm_until)
            )
            slot = jnp.where(
                jnp.any(warm_ok), jnp.argmax(warm_ok), jnp.argmax(empty)
            ).astype(jnp.int32)
        else:
            slot = jnp.argmax(empty).astype(jnp.int32)
        valid = valid & has_slot
        is_warm = (st.slot_warm_pool[slot] == pool) & (
            tick < st.slot_warm_until[slot]
        )
        cold_ticks = jnp.where(is_warm, 0, jnp.int32(params.cold_start_ticks))
        cpus = dec.assign_cpus[k]
        ram = dec.assign_ram[k]
        # ---- data plane: scan inputs missing from the pool's cache ---------
        total_out = wl.pipe_out[pipe_c]
        cached = st.cache_bytes[pool, pipe_c]
        hit_gb = jnp.minimum(cached, total_out)
        miss_gb = jnp.maximum(total_out - cached, 0.0)
        scan_ticks = jnp.ceil(
            jnp.float32(params.scan_ticks_per_gb) * miss_gb
        ).astype(jnp.int32)
        startup = cold_ticks + scan_ticks
        dur, oom_off = container_schedule(wl, pipe_c, cpus, ram)
        if params.straggler_prob > 0:
            # straggler stretch: the sampled per-pipeline slowdown factor
            # (>= 1) scales the compute duration and the OOM offset alike
            # (both are "progress clocks"). f32 stretch mirrored by
            # engine_python; min-then-stretch == stretch-then-min since
            # ceil is monotone, so both engines may pick either order.
            f = wl.faults.straggler[pipe_c]
            stretch = lambda t: jnp.minimum(  # noqa: E731
                jnp.ceil(t.astype(jnp.float32) * f), jnp.float32(2**30)
            ).astype(jnp.int32)
            dur = stretch(dur)
            oom_off = jnp.where(oom_off == INF_TICK, INF_TICK, stretch(oom_off))
        end = tick + startup + dur
        oom = jnp.where(
            oom_off == INF_TICK,
            INF_TICK,
            tick + startup + jnp.minimum(oom_off, dur),
        )
        if params.timeout_ticks > 0:
            # wall-clock deadline: a container that would outlive it is
            # killed there instead (ctr_timed marks the retirement as a
            # TIMEOUT -> retry, not a completion). An OOM due at the same
            # tick wins (``done`` excludes ``oomed`` at retirement).
            deadline = tick + jnp.int32(params.timeout_ticks)
            timed = end > deadline
            end = jnp.minimum(end, deadline)
        else:
            timed = jnp.zeros((), bool)

        def commit(st: SimState) -> SimState:
            st = st._replace(
                nxt_retire=jnp.minimum(st.nxt_retire, jnp.minimum(end, oom)),
                pipe_status=st.pipe_status.at[pipe_c].set(int(PipeStatus.RUNNING)),
                pipe_last_cpus=st.pipe_last_cpus.at[pipe_c].set(cpus),
                pipe_last_ram=st.pipe_last_ram.at[pipe_c].set(ram),
                pipe_fail_flag=st.pipe_fail_flag.at[pipe_c].set(False),
                pipe_first_start=st.pipe_first_start.at[pipe_c].min(tick),
                ctr_status=st.ctr_status.at[slot].set(int(ContainerStatus.RUNNING)),
                ctr_pipe=st.ctr_pipe.at[slot].set(pipe_c),
                ctr_pool=st.ctr_pool.at[slot].set(pool),
                ctr_cpus=st.ctr_cpus.at[slot].set(cpus),
                ctr_ram=st.ctr_ram.at[slot].set(ram),
                ctr_start=st.ctr_start.at[slot].set(tick),
                ctr_end=st.ctr_end.at[slot].set(end),
                ctr_oom=st.ctr_oom.at[slot].set(oom),
                ctr_prio=st.ctr_prio.at[slot].set(wl.prio[pipe_c]),
                ctr_warm=st.ctr_warm.at[slot].set(is_warm),
                pool_cpu_free=st.pool_cpu_free.at[pool].add(-cpus),
                pool_ram_free=st.pool_ram_free.at[pool].add(-ram),
                cache_hit_gb=st.cache_hit_gb + hit_gb,
                bytes_moved_gb=st.bytes_moved_gb + miss_gb,
                cache_hits=st.cache_hits + (hit_gb > 0).astype(jnp.int32),
                cache_lookups=st.cache_lookups
                + (total_out > 0).astype(jnp.int32),
                cold_starts=st.cold_starts + (~is_warm).astype(jnp.int32),
                warm_starts=st.warm_starts + is_warm.astype(jnp.int32),
                cold_start_tick_total=st.cold_start_tick_total + cold_ticks,
            )
            if params.timeout_ticks > 0:
                st = st._replace(ctr_timed=st.ctr_timed.at[slot].set(timed))
            if params.cache_gb_per_pool > 0:
                # materialise the pipeline's intermediates in the pool's
                # zero-copy cache (LRU-evicting under the capacity)
                row_b, row_l, used = cache_insert(
                    st.cache_bytes[pool],
                    st.cache_last[pool],
                    st.pool_cache_used[pool],
                    pipe_c,
                    total_out,
                    tick,
                    params.cache_gb_per_pool,
                )
                st = st._replace(
                    cache_bytes=st.cache_bytes.at[pool].set(row_b),
                    cache_last=st.cache_last.at[pool].set(row_l),
                    pool_cache_used=st.pool_cache_used.at[pool].set(used),
                )
            return st

        new_st = jax.lax.cond(valid, commit, lambda s: s, st)
        if not collect_aux:
            return new_st
        aux_i = jnp.where(
            valid,
            jnp.stack([pipe_c, pool, cold_ticks, is_warm.astype(jnp.int32)]),
            jnp.array([-1, -1, 0, 0], jnp.int32),
        )
        aux_f = jnp.where(
            valid,
            jnp.stack([cpus, ram, hit_gb, miss_gb, total_out]),
            jnp.float32(0.0),
        )
        return new_st, aux_i, aux_f

    K = params.max_assignments_per_tick
    if with_aux and not early_exit:
        raise ValueError("with_aux requires early_exit=True")
    if early_exit:
        # fused landing (kernels/state_update): the early-exit loop
        # collects one row of commit values per populated slot and the
        # table writes land afterwards as one masked scatter —
        # bitwise-identical to the per-slot cond-commit loop below,
        # which stays as the property-tested oracle
        return _apply_assignments_fused(
            state, wl, dec, tick, params, with_aux=with_aux
        )
    return jax.lax.fori_loop(0, K, assign_one, state)


def _apply_assignments_fused(
    state: SimState,
    wl: Workload,
    dec: SchedDecision,
    tick: jax.Array,
    params: SimParams,
    with_aux: bool = False,
):
    """Vectorised assignment pass with a fused landing (Pallas phase 3).

    Bitwise-identical to the legacy per-slot ``lax.cond`` commit loop
    (``apply_decision(early_exit=False)``, the oracle), but the per-row
    math runs over all K slots at once instead of a while_loop carrying
    the full SimState:

    * **validity** is closed-form: a row can only commit if it is the
      first occurrence of its pipeline (any earlier same-pipe row either
      consumed the pipeline or failed for a reason that persists), the
      pipeline was waiting before the loop, and its rank among such rows
      does not exceed the number of empty slots (capacity once exhausted
      never recovers inside the loop);
    * **slot pick**: with cold starts off every valid row takes the
      lowest remaining empty slot, so the rank-r row lands on the r-th
      lowest empty slot (cumsum matching). With warm-slot preference the
      pick order is pool-dependent, so a minimal while_loop carrying
      only the empty mask computes the picks;
    * **order-sensitive f32 accumulators** (pool frees, cache sums, LRU
      inserts) keep the seed's left-fold association in a small
      sequential loop over the populated slots — everything else (int
      counters, the ``nxt_retire`` min-fold) is reassociation-exact and
      reduces vectorised.

    The container/pipeline table writes land once through
    ``kernels/state_update.assign_gather`` (unique indices -> masked
    overwrite scatters, fp-exact). ``with_aux=True`` reads the telemetry
    aux straight out of the same row vectors the landing commits.
    """
    i32, f32 = jnp.int32, jnp.float32
    MC = state.ctr_status.shape[0]
    MP = state.pipe_status.shape[0]
    K = params.max_assignments_per_tick
    cache_on = params.cache_gb_per_pool > 0
    timeout_on = params.timeout_ticks > 0

    ks = jnp.arange(K, dtype=i32)
    # loops below only walk the populated prefix; most events carry
    # zero or one assignment, so they usually run 0-1 iterations
    n_slots = jnp.max(jnp.where(dec.assign_pipe >= 0, ks + 1, 0))

    pipe = dec.assign_pipe
    pipe_c = jnp.maximum(pipe, 0)
    pool = dec.assign_pool
    cpus = dec.assign_cpus
    ram = dec.assign_ram

    waiting0 = state.pipe_status == int(PipeStatus.WAITING)
    empty0 = state.ctr_status == int(ContainerStatus.EMPTY)
    n_empty = jnp.sum(empty0).astype(i32)

    # -- closed-form validity (proof in the docstring) -----------------------
    # a row repeating an earlier row's pipeline can never commit: the
    # earlier row either took the pipeline (no longer waiting) or failed
    # because it never waited / capacity ran out — conditions that still
    # hold at the later row
    dup_before = jnp.any(
        (pipe[None, :] == pipe[:, None]) & (ks[None, :] < ks[:, None]),
        axis=1,
    )
    pre = (pipe >= 0) & waiting0[pipe_c] & ~dup_before
    rank = jnp.cumsum(pre.astype(i32))  # 1-based, inclusive
    valid = pre & (rank <= n_empty)

    # -- slot pick -----------------------------------------------------------
    if params.cold_start_ticks > 0:
        # warm-slot preference makes the pick order pool-dependent, so
        # walk the populated slots with the smallest possible carry
        # (just the evolving empty mask). Commits never write slot
        # warmth, so the pre-loop warmth view is the loop-invariant
        # truth (mirrors engine_python._pick_slot).
        def _pick_body(c):
            k, empty, slots = c
            warm_ok = (
                empty
                & (state.slot_warm_pool == pool[k])
                & (tick < state.slot_warm_until)
            )
            s = jnp.where(
                jnp.any(warm_ok), jnp.argmax(warm_ok), jnp.argmax(empty)
            ).astype(i32)
            # one-hot selects, not ``.at[]`` scatters (vmapped dynamic
            # scatters serialize on XLA:CPU); bitwise identical
            return (
                k + 1,
                jnp.where(
                    valid[k] & (jnp.arange(empty.shape[0]) == s),
                    False,
                    empty,
                ),
                jnp.where(jnp.arange(slots.shape[0]) == k, s, slots),
            )

        _, _, slot = jax.lax.while_loop(
            lambda c: c[0] < n_slots,
            _pick_body,
            (jnp.int32(0), empty0, jnp.zeros((K,), i32)),
        )
    else:
        # every valid row takes the lowest remaining empty slot, so the
        # rank-r row lands on the r-th lowest empty slot
        cum = jnp.cumsum(empty0.astype(i32))
        eq = empty0[None, :] & (cum[None, :] == rank[:, None])
        slot = jnp.argmax(eq, axis=1).astype(i32)

    is_warm = (state.slot_warm_pool[slot] == pool) & (
        tick < state.slot_warm_until[slot]
    )
    cold_ticks = jnp.where(is_warm, 0, jnp.int32(params.cold_start_ticks))
    total_out = wl.pipe_out[pipe_c]

    # -- sequential walk over the populated slots ----------------------------
    # One small loop keeps (a) the order-sensitive f32 state — pool
    # frees, cache sums, LRU inserts — in the seed's left-fold
    # association, and (b) ``container_schedule`` (a [MO, MO] level
    # reduction) priced per *populated* slot only, exactly like the
    # legacy loop. The carry is a handful of small rows, not the whole
    # SimState.
    pcf0, prf0 = state.pool_cpu_free, state.pool_ram_free
    chg0, bmg0 = state.cache_hit_gb, state.bytes_moved_gb
    durs0 = jnp.zeros((K,), i32)
    ooms0 = jnp.zeros((K,), i32)
    if cache_on:
        # the cache gather must see earlier rows' LRU inserts, so the
        # data plane rides in the same loop
        def _slot_body(c):
            k, cb, cl, pcu, pcf, prf, chg, bmg, hits, misses, durs, ooms = c
            v, p, pc = valid[k], pool[k], pipe_c[k]
            to = total_out[k]
            cached = cb[p, pc]
            hg = jnp.minimum(cached, to)
            mg = jnp.maximum(to - cached, 0.0)
            row_b, row_l, used = cache_insert(
                cb[p], cl[p], pcu[p], pc, to, tick,
                params.cache_gb_per_pool,
            )
            d, o = container_schedule(wl, pc, cpus[k], ram[k])
            # one-hot selects, not ``.at[]`` scatters: a vmapped scatter
            # lowers to a serialized while loop on XLA:CPU; these stay
            # elementwise (and a select is trivially bitwise-exact)
            onp = jnp.arange(pcf.shape[0]) == p
            onk = ks == k
            return (
                k + 1,
                jnp.where(v & onp[:, None], row_b[None, :], cb),
                jnp.where(v & onp[:, None], row_l[None, :], cl),
                jnp.where(v & onp, used, pcu),
                jnp.where(v & onp, pcf - cpus[k], pcf),
                jnp.where(v & onp, prf - ram[k], prf),
                jnp.where(v, chg + hg, chg),
                jnp.where(v, bmg + mg, bmg),
                jnp.where(onk, hg, hits),
                jnp.where(onk, mg, misses),
                jnp.where(onk, d, durs),
                jnp.where(onk, o, ooms),
            )

        (_, cache_bytes, cache_last, pool_cache_used, pool_cpu_free,
         pool_ram_free, cache_hit_gb, bytes_moved_gb, hit_gb, miss_gb,
         dur, oom_off) = jax.lax.while_loop(
            lambda c: c[0] < n_slots,
            _slot_body,
            (jnp.int32(0), state.cache_bytes, state.cache_last,
             state.pool_cache_used, pcf0, prf0, chg0, bmg0,
             jnp.zeros((K,), f32), jnp.zeros((K,), f32), durs0, ooms0),
        )
    else:
        cached = state.cache_bytes[pool, pipe_c]
        hit_gb = jnp.minimum(cached, total_out)
        miss_gb = jnp.maximum(total_out - cached, 0.0)

        def _slot_body(c):
            k, pcf, prf, chg, bmg, durs, ooms = c
            v, p = valid[k], pool[k]
            d, o = container_schedule(wl, pipe_c[k], cpus[k], ram[k])
            # one-hot selects, not ``.at[]`` scatters: a vmapped scatter
            # lowers to a serialized while loop on XLA:CPU; these stay
            # elementwise (and a select is trivially bitwise-exact)
            onp = jnp.arange(pcf.shape[0]) == p
            onk = ks == k
            return (
                k + 1,
                jnp.where(v & onp, pcf - cpus[k], pcf),
                jnp.where(v & onp, prf - ram[k], prf),
                jnp.where(v, chg + hit_gb[k], chg),
                jnp.where(v, bmg + miss_gb[k], bmg),
                jnp.where(onk, d, durs),
                jnp.where(onk, o, ooms),
            )

        (_, pool_cpu_free, pool_ram_free, cache_hit_gb, bytes_moved_gb,
         dur, oom_off) = jax.lax.while_loop(
            lambda c: c[0] < n_slots,
            _slot_body,
            (jnp.int32(0), pcf0, prf0, chg0, bmg0, durs0, ooms0),
        )

    # -- row timing, vectorised ----------------------------------------------
    scan_ticks = jnp.ceil(
        jnp.float32(params.scan_ticks_per_gb) * miss_gb
    ).astype(i32)
    startup = cold_ticks + scan_ticks
    if params.straggler_prob > 0:
        fct = wl.faults.straggler[pipe_c]
        stretch = lambda t: jnp.minimum(  # noqa: E731
            jnp.ceil(t.astype(f32) * fct), jnp.float32(2**30)
        ).astype(i32)
        dur = stretch(dur)
        oom_off = jnp.where(oom_off == INF_TICK, INF_TICK, stretch(oom_off))
    end = tick + startup + dur
    oom = jnp.where(
        oom_off == INF_TICK,
        INF_TICK,
        tick + startup + jnp.minimum(oom_off, dur),
    )
    if timeout_on:
        deadline = tick + jnp.int32(params.timeout_ticks)
        timed = end > deadline
        end = jnp.minimum(end, deadline)
    else:
        timed = jnp.zeros((K,), bool)

    # reassociation-exact reductions (int sums / min-folds)
    nxt_retire = jnp.minimum(
        state.nxt_retire,
        jnp.min(jnp.where(valid, jnp.minimum(end, oom), INF_TICK)),
    )
    n_hit = jnp.sum(valid & (hit_gb > 0)).astype(i32)
    n_look = jnp.sum(valid & (total_out > 0)).astype(i32)
    n_warm = jnp.sum(valid & is_warm).astype(i32)
    n_cold = jnp.sum(valid & ~is_warm).astype(i32)
    cold_total = jnp.sum(jnp.where(valid, cold_ticks, 0)).astype(i32)

    # -- fused landing (kernels/state_update) --------------------------------
    prio = wl.prio[pipe_c]
    (hit_c, l_pipe, l_pool, l_cpus, l_ram, l_end, l_oom, l_prio, l_warm,
     l_timed, hit_p, l_pcpus, l_pram) = assign_gather(
        valid, slot, pipe_c, pool, cpus, ram, end, oom, prio, is_warm,
        timed, max_containers=MC, max_pipelines=MP,
    )
    state = state._replace(
        nxt_retire=nxt_retire,
        pipe_status=jnp.where(
            hit_p, int(PipeStatus.RUNNING), state.pipe_status
        ),
        pipe_last_cpus=jnp.where(hit_p, l_pcpus, state.pipe_last_cpus),
        pipe_last_ram=jnp.where(hit_p, l_pram, state.pipe_last_ram),
        pipe_fail_flag=jnp.where(hit_p, False, state.pipe_fail_flag),
        pipe_first_start=jnp.where(
            hit_p, jnp.minimum(state.pipe_first_start, tick),
            state.pipe_first_start,
        ),
        ctr_status=jnp.where(
            hit_c, int(ContainerStatus.RUNNING), state.ctr_status
        ),
        ctr_pipe=jnp.where(hit_c, l_pipe, state.ctr_pipe),
        ctr_pool=jnp.where(hit_c, l_pool, state.ctr_pool),
        ctr_cpus=jnp.where(hit_c, l_cpus, state.ctr_cpus),
        ctr_ram=jnp.where(hit_c, l_ram, state.ctr_ram),
        ctr_start=jnp.where(hit_c, tick, state.ctr_start),
        ctr_end=jnp.where(hit_c, l_end, state.ctr_end),
        ctr_oom=jnp.where(hit_c, l_oom, state.ctr_oom),
        ctr_prio=jnp.where(hit_c, l_prio, state.ctr_prio),
        ctr_warm=jnp.where(hit_c, l_warm, state.ctr_warm),
        pool_cpu_free=pool_cpu_free,
        pool_ram_free=pool_ram_free,
        cache_hit_gb=cache_hit_gb,
        bytes_moved_gb=bytes_moved_gb,
        cache_hits=state.cache_hits + n_hit,
        cache_lookups=state.cache_lookups + n_look,
        cold_starts=state.cold_starts + n_cold,
        warm_starts=state.warm_starts + n_warm,
        cold_start_tick_total=state.cold_start_tick_total + cold_total,
    )
    if timeout_on:
        state = state._replace(
            ctr_timed=jnp.where(hit_c, l_timed, state.ctr_timed)
        )
    if cache_on:
        state = state._replace(
            cache_bytes=cache_bytes,
            cache_last=cache_last,
            pool_cache_used=pool_cache_used,
        )
    if not with_aux:
        return state
    aux_i = jnp.where(
        valid[:, None],
        jnp.stack(
            [pipe_c, pool, cold_ticks, is_warm.astype(i32)], axis=1
        ),
        jnp.array([-1, -1, 0, 0], i32),
    )
    aux_f = jnp.where(
        valid[:, None],
        jnp.stack([cpus, ram, hit_gb, miss_gb, total_out], axis=1),
        jnp.float32(0.0),
    )
    return state, (aux_i, aux_f)


# ---------------------------------------------------------------------------
# Fused phase 1 (fleet-native event engine): apply the masks produced by
# ``repro.kernels.sim_tick.fleet_tick`` — arrivals + suspension releases +
# completions/OOMs in one pass. Bitwise-identical to the sequential
# ``process_arrivals -> process_releases -> process_completions``
# composition: the three phases read disjoint status partitions (EMPTY /
# SUSPENDED / RUNNING-container), so masks computed from the pre-state
# and applied together commute with the sequential wheres; each field is
# written once with its wheres chained in the sequential order
# (arrivals, then releases, then retirements). The retirement scatters
# (``.at[pid].add/max`` in ``_apply_retirements``, kept as the oracle)
# are replaced by the fused ``kernels/state_update.retire_land`` pass.
# ---------------------------------------------------------------------------
def apply_fused_phase1(
    state: SimState, wl: Workload, tick: jax.Array, params: SimParams, ph
) -> SimState:
    (oomed, done, _new_ctr_status, freed_cpu, freed_ram,
     fresh, rel, nxt_retire, nxt_release) = ph
    i32 = jnp.int32
    retired = oomed | done
    timeout_on = params.timeout_ticks > 0

    (oom_hit, done_hit, timed_hit, end_of, timed_wasted,
     lat_sum, lat_prio, dprio, n_done, n_oom) = retire_land(
        state.ctr_pipe, state.ctr_end, state.ctr_start, oomed, done,
        state.ctr_timed if timeout_on else None,
        wl.arrival, wl.prio, tick, timeout_on=timeout_on,
    )

    # ---- one write per field: arrivals -> releases -> retirements ----------
    W = int(PipeStatus.WAITING)
    pipe_status = jnp.where(fresh, W, state.pipe_status)
    pipe_entered = jnp.where(fresh, wl.arrival, state.pipe_entered)
    pipe_status = jnp.where(rel, W, pipe_status)
    pipe_entered = jnp.where(rel, state.pipe_release, pipe_entered)
    pipe_release = jnp.where(rel, INF_TICK, state.pipe_release)
    pipe_status = jnp.where(
        oom_hit, W, jnp.where(done_hit, int(PipeStatus.DONE), pipe_status)
    )
    pipe_entered = jnp.where(oom_hit, tick, pipe_entered)

    state = state._replace(
        nxt_retire=nxt_retire,
        nxt_release=nxt_release,
        pipe_status=pipe_status,
        pipe_entered=pipe_entered,
        pipe_release=pipe_release,
        pipe_fail_flag=state.pipe_fail_flag | oom_hit,
        pipe_fails=state.pipe_fails + oom_hit.astype(i32),
        pipe_completion=jnp.where(done_hit, end_of, state.pipe_completion),
        ctr_status=jnp.where(
            retired, int(ContainerStatus.EMPTY), state.ctr_status
        ),
        ctr_pipe=jnp.where(retired, -1, state.ctr_pipe),
        ctr_end=jnp.where(retired, INF_TICK, state.ctr_end),
        ctr_oom=jnp.where(retired, INF_TICK, state.ctr_oom),
        ctr_start=jnp.where(retired, INF_TICK, state.ctr_start),
        ctr_prio=jnp.where(retired, -1, state.ctr_prio),
        # retired containers keep their slot warm on their pool for a while
        ctr_warm=jnp.where(retired, False, state.ctr_warm),
        slot_warm_pool=jnp.where(retired, state.ctr_pool, state.slot_warm_pool),
        slot_warm_until=jnp.where(
            retired, _warm_until(tick, params), state.slot_warm_until
        ),
        pool_cpu_free=state.pool_cpu_free + freed_cpu,
        pool_ram_free=state.pool_ram_free + freed_ram,
        done_count=state.done_count + n_done,
        oom_events=state.oom_events + n_oom,
        sum_latency_s=state.sum_latency_s + lat_sum,
        sum_latency_s_prio=state.sum_latency_s_prio + lat_prio,
        done_prio=state.done_prio + dprio,
    )
    if timeout_on:
        state = state._replace(
            ctr_timed=jnp.where(retired, False, state.ctr_timed),
            timeout_events=state.timeout_events
            + jnp.sum(done & state.ctr_timed).astype(i32),
            wasted_ticks=state.wasted_ticks + timed_wasted,
        )
        state = _requeue_faulted(state, tick, params, timed_hit)
    return state


# ---------------------------------------------------------------------------
# Utilisation / cost integration over [t0, t1).
# ---------------------------------------------------------------------------
def integrate(
    state: SimState,
    t0: jax.Array,
    t1: jax.Array,
    params: SimParams,
    exact_buckets: bool,
) -> SimState:
    dt_s = (t1 - t0).astype(jnp.float32) / TICKS_PER_SECOND
    used_cpu, used_ram = used_resources(state)

    # cost model: base-rate for capacity within the un-scaled pool, premium
    # rate for cloud-scaled overflow (paper §3.2.2 "additional monetary cost")
    base_cpu = jnp.full_like(used_cpu, params.pool_cpus)
    over = jnp.maximum(used_cpu - base_cpu, 0.0)
    base_used = jnp.minimum(used_cpu, base_cpu)
    rate = params.cloud_cost_per_cpu_second
    cost = jnp.sum(base_used + params.cloud_premium_factor * over) * rate * dt_s

    B = params.util_log_buckets
    horizon = max(params.horizon_ticks, 1)
    if exact_buckets:
        # exact overlap of [t0, t1) with every bucket (event engine)
        edges = jnp.linspace(0.0, float(horizon), B + 1)
        lo = jnp.maximum(edges[:-1], t0.astype(jnp.float32))
        hi = jnp.minimum(edges[1:], t1.astype(jnp.float32))
        overlap_s = jnp.maximum(hi - lo, 0.0) / TICKS_PER_SECOND  # [B]
        add = overlap_s[:, None, None] * jnp.stack(
            [used_cpu, used_ram], axis=-1
        )[None, :, :]
        util_log = state.util_log + add
    else:
        # tick engine: the whole tick lands in one bucket (scatter-add)
        b = jnp.clip(t0 * B // horizon, 0, B - 1)
        util_log = state.util_log.at[b].add(
            dt_s * jnp.stack([used_cpu, used_ram], axis=-1)
        )

    state = state._replace(
        util_cpu_s=state.util_cpu_s + used_cpu * dt_s,
        util_ram_s=state.util_ram_s + used_ram * dt_s,
        cost_dollars=state.cost_dollars + cost,
        util_log=util_log,
    )
    if params.outage_mtbf_ticks > 0:
        # downtime integral (MTTR numerator). Exact: the event engine's
        # ``nxt_fault`` register includes every recovery tick, so an
        # integration interval never straddles a pool coming back up —
        # a pool down at t0 is down for the whole of [t0, t1).
        n_down = jnp.sum((t0 < state.pool_down_until).astype(jnp.float32))
        state = state._replace(pool_down_s=state.pool_down_s + dt_s * n_down)
    return state


__all__ = [
    "process_arrivals",
    "process_releases",
    "process_completions",
    "apply_faults",
    "apply_decision",
    "apply_fused_phase1",
    "integrate",
]
