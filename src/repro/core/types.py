"""Core datatypes for the Eudoxia simulator.

The paper (§3.2) models the world with three abstractions:

* **Pipeline** — a DAG of *Operators* submitted by a user, carrying a
  priority level (BATCH < QUERY < INTERACTIVE).
* **Operator** — one SQL/Python function; carries a minimum RAM
  requirement and a CPU-scaling function ``t(cpus) = base / cpus**alpha``.
* **Container** — a (CPUs, RAM, operator-set) allocation on a resource
  pool, created by the Scheduler and managed by the Executor.

Two representations exist side by side:

1. The **struct-of-arrays** (``state.SimState``) used by the compiled
   engines — every field below appears as a column there.
2. The lightweight Python views in this module (``Pipeline``,
   ``Failure``, ``Assignment``, ``Suspension``) which mirror the paper's
   public API (Listing 4) for user-written schedulers running in the
   Python engine.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# Time base (paper §3.2: one loop iteration == 1 tick ~= 10 microseconds).
# ---------------------------------------------------------------------------
TICK_SECONDS: float = 10e-6
TICKS_PER_SECOND: int = int(round(1.0 / TICK_SECONDS))  # 100_000


class Priority(enum.IntEnum):
    """Ascending priority order (paper §3.2.1 / §4.1.2)."""

    BATCH = 0        # batch data pipelines (throughput-oriented)
    QUERY = 1        # iterative data pipelines (dev loops)
    INTERACTIVE = 2  # interactive queries (latency-critical)


class PipeStatus(enum.IntEnum):
    EMPTY = 0      # slot unused / pipeline never materialises
    PENDING = 1    # generated, has not arrived yet (arrival tick in future)
    WAITING = 2    # in the scheduler's waiting queue
    RUNNING = 3    # assigned to a live container
    SUSPENDED = 4  # preempted; sits 1 tick in the suspending queue
    DONE = 5       # completed successfully
    FAILED = 6     # permanently failed back to the user (OOM at cap)


class ContainerStatus(enum.IntEnum):
    EMPTY = 0
    RUNNING = 1


# ---------------------------------------------------------------------------
# Python-facing records (paper Listing 4 signature compatibility).
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Operator:
    """One function node of a pipeline DAG."""

    ram_gb: float          # max RAM required to avoid OOM
    base_ticks: float      # runtime at exactly 1 CPU (may be fractional:
    #   generated runtimes are f32 ticks; trace records carry them
    #   exactly via the ``base_ticks`` field — see docs/trace-format.md)
    alpha: float           # CPU-scaling exponent: t(c) = base / c**alpha
    level: int             # topological depth inside the pipeline DAG
    out_gb: float = 0.0    # intermediate output dataset size (data plane)

    def runtime_ticks(self, cpus: float) -> int:
        eff = max(float(cpus), 1e-6)
        return max(1, int(np.ceil(self.base_ticks / (eff ** self.alpha))))


@dataclasses.dataclass
class Pipeline:
    """User-submitted DAG of operators (paper §3.2.1)."""

    pid: int
    priority: Priority
    arrival_tick: int
    ops: list[Operator]
    # -- retry bookkeeping (priority scheduler, paper §4.1.2) --
    failed_before: bool = False
    last_cpus: float = 0.0
    last_ram_gb: float = 0.0

    @property
    def num_ops(self) -> int:
        return len(self.ops)

    @property
    def total_ram_gb(self) -> float:
        return float(sum(o.ram_gb for o in self.ops))

    @property
    def total_out_gb(self) -> float:
        """Total intermediate dataset bytes the pipeline materialises."""
        return float(sum(o.out_gb for o in self.ops))

    def level_ram(self) -> list[float]:
        if not self.ops:
            return [0.0]
        depth = max(o.level for o in self.ops) + 1
        out = [0.0] * depth
        for o in self.ops:
            out[o.level] += o.ram_gb
        return out


@dataclasses.dataclass
class Failure:
    """An executor-reported failure (OOM) from the previous tick."""

    pipeline: Pipeline
    tick: int
    cpus: float
    ram_gb: float
    reason: str = "oom"


@dataclasses.dataclass
class Assignment:
    """Scheduler -> Executor: create this container (paper §4.1.3 (2))."""

    pipeline: Pipeline
    pool: int
    cpus: float
    ram_gb: float
    # Optional subset of operator indices to run (None == whole pipeline).
    op_indices: Optional[list[int]] = None


@dataclasses.dataclass
class Suspension:
    """Scheduler -> Executor: preempt the container running this pipeline."""

    pipeline: Pipeline
    reason: str = "preempted"


__all__ = [
    "TICK_SECONDS",
    "TICKS_PER_SECOND",
    "Priority",
    "PipeStatus",
    "ContainerStatus",
    "Operator",
    "Pipeline",
    "Failure",
    "Assignment",
    "Suspension",
]
