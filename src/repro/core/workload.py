"""Workload generation (paper §3.2.1) and trace ingestion.

"In a real setup, various users submit pipelines to the system at random
intervals." The generator materialises the *entire* arrival table up
front from a single PRNG key — a pre-pass rather than per-tick sampling —
so every engine (tick, event-skip, Python, vmap fleet) replays the exact
same deterministic workload. Per-tick sampling and a pre-materialised
arrival table are observationally equivalent for an open-loop arrival
process, and the pre-pass vectorises.

Every random quantity is "drawn from a distribution centered at one of
the user-provided (or system default) parameters" (§3.2.1):

* inter-arrival ticks   ~ Exponential(mean = waiting_ticks_mean)
* ops per pipeline      ~ 1 + Poisson(mean_ops_per_pipeline - 1), clipped
* DAG shape             ~ each op chains (new level) w.p. chain_prob else
                          joins the previous level (parallel fan-out)
* op RAM                ~ LogNormal centred at op_ram_gb_mean
* op base runtime       ~ LogNormal centred at op_base_seconds_mean
* op output dataset     ~ LogNormal centred at op_out_gb_mean, with its
                          log-domain noise correlated (out_runtime_corr)
                          with the runtime draw: long ops tend to emit
                          large intermediates (data plane, cf. Bauplan)
* CPU-scaling alpha     ~ Categorical(alpha_choices, alpha_probs)
* priority              ~ Categorical(priority_probs); interactive/query
                          pipelines are scaled shorter & smaller.

Dataset sizes are quantised to MiB granularity (multiples of 2**-10 GB)
so that every cache-occupancy sum the engines compute is exact in f32 —
the compiled and Python engines then agree bit-for-bit on cache state
regardless of reduction order.

Traces: ``load_trace`` accepts a list of dicts (or a JSON/TOML file) with
explicit pipelines — the TPC-H validation benchmark uses this path.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .params import SimParams
from .state import INF_TICK, Workload
from .types import Pipeline, Operator, Priority, TICKS_PER_SECOND


GB_QUANTUM = 1.0 / 1024.0  # cache sizes live on a MiB grid (see module doc)


def _quantize_gb(x: jax.Array) -> jax.Array:
    """Snap dataset sizes onto the MiB grid; exact in f32 below ~16 TB."""
    return jnp.maximum(jnp.round(x * 1024.0) / 1024.0, jnp.float32(GB_QUANTUM))


def generate_workload(params: SimParams, key: jax.Array | None = None) -> Workload:
    """Vectorised random workload table."""
    if key is None:
        key = jax.random.PRNGKey(params.seed)
    MP, MO = params.max_pipelines, params.max_ops_per_pipeline
    k_arr, k_prio, k_nops, k_chain, k_ram, k_base, k_alpha = jax.random.split(key, 7)
    # data-plane key is folded in (not split) so the seven draws above are
    # bit-identical to the pre-data-plane generator — backward compat.
    k_out = jax.random.fold_in(key, 7)

    # --- arrivals ----------------------------------------------------------
    gaps = jax.random.exponential(k_arr, (MP,)) * params.waiting_ticks_mean
    arrival = jnp.cumsum(gaps).astype(jnp.int32)
    horizon = params.horizon_ticks
    in_horizon = arrival < horizon
    arrival = jnp.where(in_horizon, arrival, INF_TICK)

    # --- priorities --------------------------------------------------------
    pprobs = jnp.asarray(params.priority_probs, jnp.float32)
    pprobs = pprobs / jnp.sum(pprobs)
    prio = jax.random.categorical(k_prio, jnp.log(pprobs), shape=(MP,)).astype(
        jnp.int32
    )

    # --- DAG shapes ---------------------------------------------------------
    lam = max(params.mean_ops_per_pipeline - 1.0, 0.0)
    n_ops = 1 + jax.random.poisson(k_nops, lam, (MP,)).astype(jnp.int32)
    n_ops = jnp.clip(n_ops, 1, MO)
    op_idx = jnp.arange(MO, dtype=jnp.int32)[None, :]
    op_valid = op_idx < n_ops[:, None]
    chains = jax.random.bernoulli(k_chain, params.chain_prob, (MP, MO))
    chains = chains.at[:, 0].set(True)  # first op opens level 0
    op_level = jnp.cumsum(chains.astype(jnp.int32), axis=1) - 1
    op_level = jnp.where(op_valid, op_level, 0)

    # --- per-priority scale factors (interactive queries are small/short) --
    scale = jnp.asarray(
        [1.0, params.query_scale, params.interactive_scale], jnp.float32
    )[prio][:, None]

    # --- op RAM / runtime / scaling ----------------------------------------
    ram = (
        jnp.exp(jax.random.normal(k_ram, (MP, MO)) * params.op_ram_gb_sigma)
        * params.op_ram_gb_mean
        * scale
    )
    ram = jnp.maximum(ram, 0.05)
    z_base = jax.random.normal(k_base, (MP, MO))
    base_s = (
        jnp.exp(z_base * params.op_base_seconds_sigma)
        * params.op_base_seconds_mean
        * scale
    )
    base = jnp.maximum(base_s * TICKS_PER_SECOND, 1.0)

    # --- intermediate output sizes (data plane) -----------------------------
    # log-domain mix of the runtime noise and fresh noise => corr knob
    rho = float(np.clip(params.out_runtime_corr, -1.0, 1.0))
    z_out = jax.random.normal(k_out, (MP, MO))
    z_mix = rho * z_base + np.sqrt(max(1.0 - rho * rho, 0.0)) * z_out
    out = (
        jnp.exp(z_mix * params.op_out_gb_sigma)
        * params.op_out_gb_mean
        * scale
    )
    out = _quantize_gb(out)
    aprobs = jnp.asarray(params.alpha_probs, jnp.float32)
    aprobs = aprobs / jnp.sum(aprobs)
    alpha_ix = jax.random.categorical(k_alpha, jnp.log(aprobs), shape=(MP, MO))
    alpha = jnp.asarray(params.alpha_choices, jnp.float32)[alpha_ix]

    zero_f = jnp.zeros((MP, MO), jnp.float32)
    op_out = jnp.where(op_valid, out, zero_f).astype(jnp.float32)
    return Workload(
        arrival=arrival,
        prio=prio,
        n_ops=n_ops,
        op_valid=op_valid,
        op_level=op_level,
        op_ram=jnp.where(op_valid, ram, zero_f),
        op_base=jnp.where(op_valid, base, zero_f),
        op_alpha=jnp.where(op_valid, alpha, zero_f),
        op_out=op_out,
        pipe_out=jnp.sum(op_out, axis=1, dtype=jnp.float32),
    )


# ---------------------------------------------------------------------------
# Trace ingestion (paper §3.2.1: "this interface allows users to format
# existing traces and feed them into the simulator").
# ---------------------------------------------------------------------------
def workload_from_pipelines(
    pipelines: Sequence[Pipeline], params: SimParams
) -> Workload:
    MP, MO = params.max_pipelines, params.max_ops_per_pipeline
    if len(pipelines) > MP:
        raise ValueError(f"trace has {len(pipelines)} pipelines > capacity {MP}")
    arrival = np.full((MP,), INF_TICK, np.int32)
    prio = np.zeros((MP,), np.int32)
    n_ops = np.zeros((MP,), np.int32)
    op_valid = np.zeros((MP, MO), bool)
    op_level = np.zeros((MP, MO), np.int32)
    op_ram = np.zeros((MP, MO), np.float32)
    op_base = np.zeros((MP, MO), np.float32)
    op_alpha = np.zeros((MP, MO), np.float32)
    op_out = np.zeros((MP, MO), np.float32)
    for i, p in enumerate(pipelines):
        if len(p.ops) > MO:
            raise ValueError(f"pipeline {p.pid} has {len(p.ops)} ops > {MO}")
        arrival[i] = p.arrival_tick
        prio[i] = int(p.priority)
        n_ops[i] = len(p.ops)
        for j, o in enumerate(p.ops):
            op_valid[i, j] = True
            op_level[i, j] = o.level
            op_ram[i, j] = o.ram_gb
            op_base[i, j] = o.base_ticks
            op_alpha[i, j] = o.alpha
            # MiB quantisation (see module doc); out_gb == 0 stays 0 so
            # data-plane-free traces remain inert
            op_out[i, j] = (
                max(round(o.out_gb * 1024.0) / 1024.0, GB_QUANTUM)
                if o.out_gb > 0
                else 0.0
            )
    return Workload(
        arrival=jnp.asarray(arrival),
        prio=jnp.asarray(prio),
        n_ops=jnp.asarray(n_ops),
        op_valid=jnp.asarray(op_valid),
        op_level=jnp.asarray(op_level),
        op_ram=jnp.asarray(op_ram),
        op_base=jnp.asarray(op_base),
        op_alpha=jnp.asarray(op_alpha),
        op_out=jnp.asarray(op_out),
        pipe_out=jnp.asarray(op_out.sum(axis=1, dtype=np.float32)),
    )


def load_trace(path: str | pathlib.Path, params: SimParams) -> Workload:
    """Load a JSON trace: [{arrival_s, priority, ops: [{ram_gb, base_s,
    alpha, level, out_gb}]}]. ``out_gb`` (intermediate dataset size) is
    optional and defaults to 0 (data plane inert for that op)."""
    raw = json.loads(pathlib.Path(path).read_text())
    return workload_from_trace_records(raw, params)


def workload_from_trace_records(
    records: Sequence[dict[str, Any]], params: SimParams
) -> Workload:
    pipelines = []
    for i, rec in enumerate(records):
        ops = [
            Operator(
                ram_gb=float(o["ram_gb"]),
                base_ticks=int(round(float(o["base_s"]) * TICKS_PER_SECOND)),
                alpha=float(o.get("alpha", 0.5)),
                level=int(o.get("level", j)),
                out_gb=float(o.get("out_gb", 0.0)),
            )
            for j, o in enumerate(rec["ops"])
        ]
        pri = rec.get("priority", "QUERY")
        if isinstance(pri, str):
            pri = Priority[pri.upper()]
        pipelines.append(
            Pipeline(
                pid=i,
                priority=Priority(int(pri)),
                arrival_tick=int(round(float(rec["arrival_s"]) * TICKS_PER_SECOND)),
                ops=ops,
            )
        )
    return workload_from_pipelines(pipelines, params)


def get_workload(params: SimParams) -> Workload:
    if params.trace_path:
        return load_trace(params.trace_path, params)
    return generate_workload(params)


__all__ = [
    "generate_workload",
    "workload_from_pipelines",
    "workload_from_trace_records",
    "load_trace",
    "get_workload",
]
