"""Workload generation (paper §3.2.1) and trace ingestion.

"In a real setup, various users submit pipelines to the system at random
intervals." The generator materialises the *entire* arrival table up
front from a single PRNG key — a pre-pass rather than per-tick sampling —
so every engine (tick, event-skip, Python, vmap fleet) replays the exact
same deterministic workload. Per-tick sampling and a pre-materialised
arrival table are observationally equivalent for an open-loop arrival
process, and the pre-pass vectorises.

Every random quantity is "drawn from a distribution centered at one of
the user-provided (or system default) parameters" (§3.2.1):

* inter-arrival ticks   ~ Exponential(mean = waiting_ticks_mean)
* ops per pipeline      ~ 1 + Poisson(mean_ops_per_pipeline - 1), clipped
* DAG shape             ~ each op chains (new level) w.p. chain_prob else
                          joins the previous level (parallel fan-out)
* op RAM                ~ LogNormal centred at op_ram_gb_mean
* op base runtime       ~ LogNormal centred at op_base_seconds_mean
* op output dataset     ~ LogNormal centred at op_out_gb_mean, with its
                          log-domain noise correlated (out_runtime_corr)
                          with the runtime draw: long ops tend to emit
                          large intermediates (data plane, cf. Bauplan)
* CPU-scaling alpha     ~ Categorical(alpha_choices, alpha_probs)
* priority              ~ Categorical(priority_probs); interactive/query
                          pipelines are scaled shorter & smaller.

Dataset sizes are quantised to MiB granularity (multiples of 2**-10 GB)
so that every cache-occupancy sum the engines compute is exact in f32 —
the compiled and Python engines then agree bit-for-bit on cache state
regardless of reduction order.

Traces: ``load_trace`` accepts a JSON or TOML file with explicit
pipelines (``workload_from_trace_records`` the in-memory list-of-dicts
form) — the TPC-H validation benchmark and the scenario library
(``repro.core.scenarios``) use this path.  The schema is specified in
docs/trace-format.md; ``workload_to_trace_records`` is the exact
inverse, so any ``Workload`` round-trips through trace records
losslessly (bitwise, including the MiB-grid ``out_gb`` sizes), and
``workload_batch_from_traces`` ingests one trace per fleet lane for
``fleet_run(..., workloads=...)``.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .params import SimParams
from .state import INF_TICK, Workload
from .types import Pipeline, Operator, Priority, TICKS_PER_SECOND


GB_QUANTUM = 1.0 / 1024.0  # cache sizes live on a MiB grid (see module doc)


def _quantize_gb(x: jax.Array) -> jax.Array:
    """Snap dataset sizes onto the MiB grid; exact in f32 below ~16 TB."""
    return jnp.maximum(jnp.round(x * 1024.0) / 1024.0, jnp.float32(GB_QUANTUM))


def generate_workload(params: SimParams, key: jax.Array | None = None) -> Workload:
    """Vectorised random workload table."""
    if key is None:
        key = jax.random.PRNGKey(params.seed)
    MP, MO = params.max_pipelines, params.max_ops_per_pipeline
    k_arr, k_prio, k_nops, k_chain, k_ram, k_base, k_alpha = jax.random.split(key, 7)
    # data-plane key is folded in (not split) so the seven draws above are
    # bit-identical to the pre-data-plane generator — backward compat.
    k_out = jax.random.fold_in(key, 7)

    # --- arrivals ----------------------------------------------------------
    gaps = jax.random.exponential(k_arr, (MP,)) * params.waiting_ticks_mean
    arrival = jnp.cumsum(gaps).astype(jnp.int32)
    horizon = params.horizon_ticks
    in_horizon = arrival < horizon
    arrival = jnp.where(in_horizon, arrival, INF_TICK)

    # --- priorities --------------------------------------------------------
    pprobs = jnp.asarray(params.priority_probs, jnp.float32)
    pprobs = pprobs / jnp.sum(pprobs)
    prio = jax.random.categorical(k_prio, jnp.log(pprobs), shape=(MP,)).astype(
        jnp.int32
    )

    # --- DAG shapes ---------------------------------------------------------
    lam = max(params.mean_ops_per_pipeline - 1.0, 0.0)
    n_ops = 1 + jax.random.poisson(k_nops, lam, (MP,)).astype(jnp.int32)
    n_ops = jnp.clip(n_ops, 1, MO)
    op_idx = jnp.arange(MO, dtype=jnp.int32)[None, :]
    op_valid = op_idx < n_ops[:, None]
    chains = jax.random.bernoulli(k_chain, params.chain_prob, (MP, MO))
    chains = chains.at[:, 0].set(True)  # first op opens level 0
    op_level = jnp.cumsum(chains.astype(jnp.int32), axis=1) - 1
    op_level = jnp.where(op_valid, op_level, 0)

    # --- per-priority scale factors (interactive queries are small/short) --
    scale = jnp.asarray(
        [1.0, params.query_scale, params.interactive_scale], jnp.float32
    )[prio][:, None]

    # --- op RAM / runtime / scaling ----------------------------------------
    ram = (
        jnp.exp(jax.random.normal(k_ram, (MP, MO)) * params.op_ram_gb_sigma)
        * params.op_ram_gb_mean
        * scale
    )
    ram = jnp.maximum(ram, 0.05)
    z_base = jax.random.normal(k_base, (MP, MO))
    base_s = (
        jnp.exp(z_base * params.op_base_seconds_sigma)
        * params.op_base_seconds_mean
        * scale
    )
    base = jnp.maximum(base_s * TICKS_PER_SECOND, 1.0)

    # --- intermediate output sizes (data plane) -----------------------------
    # log-domain mix of the runtime noise and fresh noise => corr knob
    rho = float(np.clip(params.out_runtime_corr, -1.0, 1.0))
    z_out = jax.random.normal(k_out, (MP, MO))
    z_mix = rho * z_base + np.sqrt(max(1.0 - rho * rho, 0.0)) * z_out
    out = (
        jnp.exp(z_mix * params.op_out_gb_sigma)
        * params.op_out_gb_mean
        * scale
    )
    out = _quantize_gb(out)
    aprobs = jnp.asarray(params.alpha_probs, jnp.float32)
    aprobs = aprobs / jnp.sum(aprobs)
    alpha_ix = jax.random.categorical(k_alpha, jnp.log(aprobs), shape=(MP, MO))
    alpha = jnp.asarray(params.alpha_choices, jnp.float32)[alpha_ix]

    # --- fault trace (chaos layer; fold-in 8..12, see faults.py) ------------
    # generated from the SAME key, so the draws above stay bitwise-identical
    # whether faults are on or off (faults=None when every knob is 0).
    faults = None
    if params.fault_trace_active:
        from .faults import generate_fault_trace

        faults = generate_fault_trace(params, key)

    zero_f = jnp.zeros((MP, MO), jnp.float32)
    op_out = jnp.where(op_valid, out, zero_f).astype(jnp.float32)
    return Workload(
        arrival=arrival,
        prio=prio,
        n_ops=n_ops,
        op_valid=op_valid,
        op_level=op_level,
        op_ram=jnp.where(op_valid, ram, zero_f),
        op_base=jnp.where(op_valid, base, zero_f),
        op_alpha=jnp.where(op_valid, alpha, zero_f),
        op_out=op_out,
        pipe_out=jnp.sum(op_out, axis=1, dtype=jnp.float32),
        faults=faults,
    )


# ---------------------------------------------------------------------------
# Trace ingestion (paper §3.2.1: "this interface allows users to format
# existing traces and feed them into the simulator").
# ---------------------------------------------------------------------------
def workload_from_pipelines(
    pipelines: Sequence[Pipeline], params: SimParams
) -> Workload:
    MP, MO = params.max_pipelines, params.max_ops_per_pipeline
    if len(pipelines) > MP:
        raise ValueError(f"trace has {len(pipelines)} pipelines > capacity {MP}")
    arrival = np.full((MP,), INF_TICK, np.int32)
    prio = np.zeros((MP,), np.int32)
    n_ops = np.zeros((MP,), np.int32)
    op_valid = np.zeros((MP, MO), bool)
    op_level = np.zeros((MP, MO), np.int32)
    op_ram = np.zeros((MP, MO), np.float32)
    op_base = np.zeros((MP, MO), np.float32)
    op_alpha = np.zeros((MP, MO), np.float32)
    op_out = np.zeros((MP, MO), np.float32)
    for i, p in enumerate(pipelines):
        if len(p.ops) > MO:
            raise ValueError(f"pipeline {p.pid} has {len(p.ops)} ops > {MO}")
        arrival[i] = p.arrival_tick
        prio[i] = int(p.priority)
        n_ops[i] = len(p.ops)
        for j, o in enumerate(p.ops):
            op_valid[i, j] = True
            op_level[i, j] = o.level
            op_ram[i, j] = o.ram_gb
            op_base[i, j] = o.base_ticks
            op_alpha[i, j] = o.alpha
            op_out[i, j] = _op_out_gb_quantized(o.out_gb)
    return Workload(
        arrival=jnp.asarray(arrival),
        prio=jnp.asarray(prio),
        n_ops=jnp.asarray(n_ops),
        op_valid=jnp.asarray(op_valid),
        op_level=jnp.asarray(op_level),
        op_ram=jnp.asarray(op_ram),
        op_base=jnp.asarray(op_base),
        op_alpha=jnp.asarray(op_alpha),
        op_out=jnp.asarray(op_out),
        pipe_out=jnp.asarray(op_out.sum(axis=1, dtype=np.float32)),
    )


# --- record-field parsing, shared by the single-lane (Pipeline-object)
# --- and batched (array-filling) ingestion paths so both compute the
# --- exact same float32/int32 bits for every field.
def _rec_arrival_tick(rec: dict[str, Any]) -> int:
    """``arrival_tick`` (authoritative, exact) wins over ``arrival_s``.

    ``arrival_tick >= INF_TICK`` (2**31 - 1) marks a reserved slot that
    never arrives — emitted by :func:`workload_to_trace_records` so
    generated workloads round-trip bitwise (dead slots keep their drawn
    ops tables even though the simulation never admits them).
    """
    if "arrival_tick" in rec:
        return min(int(rec["arrival_tick"]), int(INF_TICK))
    # same INF clamp as the tick path: a recorded day in real seconds
    # can exceed the int32 tick range, which means "never arrives"
    return min(
        int(round(float(rec["arrival_s"]) * TICKS_PER_SECOND)), int(INF_TICK)
    )


def _rec_priority(rec: dict[str, Any]) -> Priority:
    pri = rec.get("priority", "QUERY")
    if isinstance(pri, str):
        pri = Priority[pri.upper()]
    return Priority(int(pri))


def _op_base_ticks(o: dict[str, Any]) -> float:
    """``base_ticks`` (exact f32 ticks) wins over second-resolution
    ``base_s`` — generated runtimes are fractional-tick float32 values,
    so a seconds round-trip would quantise them."""
    if "base_ticks" in o:
        return float(o["base_ticks"])
    return float(int(round(float(o["base_s"]) * TICKS_PER_SECOND)))


def _op_out_gb_quantized(out_gb: float) -> float:
    """MiB quantisation (see module doc); 0 stays 0 so data-plane-free
    traces remain inert. Exact inverse of the emitted grid values."""
    if out_gb > 0:
        return max(round(out_gb * 1024.0) / 1024.0, GB_QUANTUM)
    return 0.0


def load_trace(path: str | pathlib.Path, params: SimParams) -> Workload:
    """Load a trace file: JSON (default) or TOML (``.toml`` suffix).

    JSON traces are a list of records ``[{arrival_s, priority, ops:
    [{ram_gb, base_s, alpha, level, out_gb}]}]``; TOML traces spell the
    same records as repeated ``[[pipeline]]`` tables with nested
    ``[[pipeline.ops]]`` tables (parsed via the stdlib/tomli loader with
    the same minimal fallback ``params.py`` uses for parameter files).
    ``out_gb`` (intermediate dataset size) is optional and defaults to 0
    (data plane inert for that op). Full schema: docs/trace-format.md.
    """
    p = pathlib.Path(path)
    text = p.read_text()
    if p.suffix.lower() == ".toml":
        from .params import _toml_loads

        raw = _toml_loads(text)
        records = raw.get("pipeline", raw.get("pipelines"))
        if records is None:
            raise ValueError(
                f"TOML trace {p} has no [[pipeline]] tables"
            )
    else:
        raw = json.loads(text)
        if isinstance(raw, dict):
            records = raw.get("pipeline", raw.get("pipelines"))
            if records is None:
                raise ValueError(
                    f"JSON trace {p} is an object without a 'pipeline(s)' "
                    "key (expected a list of records or {'pipeline': [...]})"
                )
        else:
            records = raw
    return workload_from_trace_records(records, params)


def workload_from_trace_records(
    records: Sequence[dict[str, Any]], params: SimParams
) -> Workload:
    """One trace (a sequence of pipeline records) -> a single-lane
    :class:`Workload` shaped by ``params``' capacity knobs."""
    pipelines = []
    for i, rec in enumerate(records):
        ops = [
            Operator(
                ram_gb=float(o["ram_gb"]),
                base_ticks=_op_base_ticks(o),
                alpha=float(o.get("alpha", 0.5)),
                level=int(o.get("level", j)),
                out_gb=float(o.get("out_gb", 0.0)),
            )
            for j, o in enumerate(rec["ops"])
        ]
        pipelines.append(
            Pipeline(
                pid=i,
                priority=_rec_priority(rec),
                arrival_tick=_rec_arrival_tick(rec),
                ops=ops,
            )
        )
    return workload_from_pipelines(pipelines, params)


def workload_to_trace_records(wl: Workload) -> list[dict[str, Any]]:
    """The exact inverse of trace ingestion: ``Workload`` -> records.

    Emits both the human-readable seconds fields (``arrival_s``,
    ``base_s``) and the authoritative exact fields (``arrival_tick``,
    ``base_ticks`` — fractional f32 ticks) the ingestion path prefers,
    so ``workload_from_trace_records(workload_to_trace_records(wl), p)``
    reproduces every array of ``wl`` bitwise (tests/test_traces.py
    asserts it for generated workloads and every scenario family).
    Slots whose arrival is ``INF_TICK`` but that still carry drawn ops
    (a generator's beyond-horizon slots) are emitted with
    ``arrival_tick = 2**31 - 1``; fully-empty trailing slots (ingestion
    padding) are trimmed.

    >>> from repro.core import SimParams, generate_workload
    >>> params = SimParams(max_pipelines=4, max_ops_per_pipeline=2)
    >>> recs = workload_to_trace_records(generate_workload(params))
    >>> len(recs)
    4
    >>> sorted(recs[0]) == ['arrival_s', 'arrival_tick', 'ops', 'priority']
    True
    >>> sorted(recs[0]['ops'][0]) == [
    ...     'alpha', 'base_s', 'base_ticks', 'level', 'out_gb', 'ram_gb']
    True
    """
    arrival = np.asarray(wl.arrival)
    prio = np.asarray(wl.prio)
    n_ops = np.asarray(wl.n_ops)
    op_level = np.asarray(wl.op_level)
    op_ram = np.asarray(wl.op_ram)
    op_base = np.asarray(wl.op_base)
    op_alpha = np.asarray(wl.op_alpha)
    op_out = np.asarray(wl.op_out)

    live = (arrival < INF_TICK) | (n_ops > 0) | (prio != 0)
    last = int(np.max(np.nonzero(live)[0])) if live.any() else -1
    records: list[dict[str, Any]] = []
    for i in range(last + 1):
        ops = []
        for j in range(int(n_ops[i])):
            base = float(op_base[i, j])
            ops.append(
                {
                    "ram_gb": float(op_ram[i, j]),
                    "base_s": base / TICKS_PER_SECOND,
                    "base_ticks": base,
                    "alpha": float(op_alpha[i, j]),
                    "level": int(op_level[i, j]),
                    "out_gb": float(op_out[i, j]),
                }
            )
        tick = int(arrival[i])
        records.append(
            {
                "arrival_s": tick / TICKS_PER_SECOND,
                "arrival_tick": tick,
                "priority": Priority(int(prio[i])).name,
                "ops": ops,
            }
        )
    return records


def workload_batch_from_traces(
    records_per_lane: Sequence[Sequence[dict[str, Any]]],
    params: SimParams,
) -> tuple[Workload, SimParams]:
    """Vectorised batch ingestion: one trace per fleet lane.

    Fills the whole ``[L, MP, MO]`` ops tables in a single host pass
    (no per-lane ``Pipeline`` object graphs) and returns ``(workloads,
    params)`` ready for ``fleet_run(params, workloads=workloads)``.
    Every lane is padded to the batch capacity; a padded slot is
    identical to what single-lane ingestion would produce, so lane
    ``i`` of the batch is bitwise ``workload_from_trace_records
    (records_per_lane[i], params)``.

    Capacity: ``params.max_pipelines`` / ``params.max_ops_per_pipeline``
    set to ``0`` mean "derive from the traces" (the returned params
    carry the derived values — use those for the runs); positive values
    are validated against the batch maxima.

    >>> from repro.core import SimParams
    >>> recs = [{"arrival_s": 0.0, "priority": "QUERY",
    ...          "ops": [{"ram_gb": 1.0, "base_s": 0.01, "alpha": 1.0,
    ...                   "level": 0}]}]
    >>> wls, p = workload_batch_from_traces(
    ...     [recs, recs * 3], SimParams(max_pipelines=0,
    ...                                 max_ops_per_pipeline=0))
    >>> wls.arrival.shape, (p.max_pipelines, p.max_ops_per_pipeline)
    ((2, 3), (3, 1))
    """
    lanes = [list(recs) for recs in records_per_lane]
    L = len(lanes)
    if L == 0:
        raise ValueError("records_per_lane is empty: a batch needs >= 1 lane")
    need_mp = max(1, max(len(recs) for recs in lanes))
    need_mo = max(
        1,
        max((len(r["ops"]) for recs in lanes for r in recs), default=1),
    )
    MP = params.max_pipelines if params.max_pipelines > 0 else need_mp
    MO = (
        params.max_ops_per_pipeline
        if params.max_ops_per_pipeline > 0
        else need_mo
    )
    if need_mp > MP:
        raise ValueError(
            f"a lane has {need_mp} pipelines > capacity {MP} "
            "(set max_pipelines=0 to derive it from the traces)"
        )
    if need_mo > MO:
        raise ValueError(
            f"a pipeline has {need_mo} ops > capacity {MO} "
            "(set max_ops_per_pipeline=0 to derive it from the traces)"
        )
    if (MP, MO) != (params.max_pipelines, params.max_ops_per_pipeline):
        params = params.replace(max_pipelines=MP, max_ops_per_pipeline=MO)

    arrival = np.full((L, MP), INF_TICK, np.int32)
    prio = np.zeros((L, MP), np.int32)
    n_ops = np.zeros((L, MP), np.int32)
    op_level = np.zeros((L, MP, MO), np.int32)
    op_ram = np.zeros((L, MP, MO), np.float32)
    op_base = np.zeros((L, MP, MO), np.float32)
    op_alpha = np.zeros((L, MP, MO), np.float32)
    op_out = np.zeros((L, MP, MO), np.float32)
    for lane, recs in enumerate(lanes):
        for i, rec in enumerate(recs):
            arrival[lane, i] = _rec_arrival_tick(rec)
            prio[lane, i] = int(_rec_priority(rec))
            # "ops" is required (docs/trace-format.md): a typoed key
            # must fail loudly, not ingest as zero-op pipelines
            ops = rec["ops"]
            n_ops[lane, i] = len(ops)
            for j, o in enumerate(ops):
                op_level[lane, i, j] = int(o.get("level", j))
                op_ram[lane, i, j] = float(o["ram_gb"])
                op_base[lane, i, j] = _op_base_ticks(o)
                op_alpha[lane, i, j] = float(o.get("alpha", 0.5))
                op_out[lane, i, j] = _op_out_gb_quantized(
                    float(o.get("out_gb", 0.0))
                )
    op_idx = np.arange(MO, dtype=np.int32)[None, None, :]
    return (
        Workload(
            arrival=jnp.asarray(arrival),
            prio=jnp.asarray(prio),
            n_ops=jnp.asarray(n_ops),
            op_valid=jnp.asarray(op_idx < n_ops[:, :, None]),
            op_level=jnp.asarray(op_level),
            op_ram=jnp.asarray(op_ram),
            op_base=jnp.asarray(op_base),
            op_alpha=jnp.asarray(op_alpha),
            op_out=jnp.asarray(op_out),
            pipe_out=jnp.asarray(op_out.sum(axis=-1, dtype=np.float32)),
        ),
        params,
    )


def get_workload(params: SimParams) -> Workload:
    if params.trace_path:
        return load_trace(params.trace_path, params)
    return generate_workload(params)


__all__ = [
    "generate_workload",
    "workload_from_pipelines",
    "workload_from_trace_records",
    "workload_to_trace_records",
    "workload_batch_from_traces",
    "load_trace",
    "get_workload",
]
