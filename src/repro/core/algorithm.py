"""Custom-scheduler registry (paper §4.1.3, Listing 4).

Users extend Eudoxia with *two decorators*:

    @register_scheduler_init(key="my-scheduler")
    def scheduler_init(sch: Scheduler): ...

    @register_scheduler(key="my-scheduler")
    def scheduler_algo(sch: Scheduler, f: List[Failure], p: List[Pipeline]):
        ...
        return suspends, assignments

and reference the same key from ``scheduling_algo`` in the TOML file.
These run in the Python engine (``engine='python'``) with the exact
signature above. JAX-traceable *vector* schedulers (for the compiled
tick/event engines and the vmap fleets) register through
``repro.core.scheduler.register_vector_scheduler`` instead; a key may be
registered in both worlds and the engine picks the matching one.
"""
from __future__ import annotations

from typing import Callable, Dict

PY_SCHEDULERS: Dict[str, Callable] = {}
PY_SCHEDULER_INITS: Dict[str, Callable] = {}


def _norm(key: str) -> str:
    return key.replace("-", "_").lower()


def register_scheduler(key: str):
    def deco(fn: Callable) -> Callable:
        PY_SCHEDULERS[_norm(key)] = fn
        return fn

    return deco


def register_scheduler_init(key: str):
    def deco(fn: Callable) -> Callable:
        PY_SCHEDULER_INITS[_norm(key)] = fn
        return fn

    return deco


def get_python_scheduler(key: str) -> Callable:
    k = _norm(key)
    if k not in PY_SCHEDULERS:
        raise KeyError(
            f"no python scheduler registered for {key!r}; "
            f"known: {sorted(PY_SCHEDULERS)}"
        )
    return PY_SCHEDULERS[k]


def get_python_scheduler_init(key: str) -> Callable:
    return PY_SCHEDULER_INITS.get(_norm(key), lambda sch: None)


def has_python_scheduler(key: str) -> bool:
    return _norm(key) in PY_SCHEDULERS


__all__ = [
    "register_scheduler",
    "register_scheduler_init",
    "get_python_scheduler",
    "get_python_scheduler_init",
    "has_python_scheduler",
    "PY_SCHEDULERS",
    "PY_SCHEDULER_INITS",
]
