"""Beyond-paper built-in schedulers, registered in BOTH worlds (vector
for the compiled engines, Python for the reference engine) — the
extension path the paper's registry design enables.

* **sjf** — smallest-job-first: order the waiting queue by op count
  (fewest first), then priority, then arrival. Classic mean-latency
  optimiser; the custom-scheduler example showed a user-space version,
  this is the production twin with OOM-retry doubling and 25 % chunks.

* **cache_aware** — the data-plane flagship: like ``priority_pool`` but
  a pipeline whose parent outputs are resident in some pool's zero-copy
  cache is placed on that pool, so retried/preempted pipelines re-read
  their intermediates instead of re-scanning them (cf. Bauplan,
  arXiv 2410.17465).

* **locality_pool** — ``priority_pool`` with a locality tie-break: the
  most-free-resources score gets a small bonus for pools already holding
  any of the pipeline's data.
"""
from __future__ import annotations

from typing import Any, List

import jax.numpy as jnp

from repro.kernels.sched_select import masked_lex_argmin

from .algorithm import register_scheduler, register_scheduler_init
from .engine_python import Scheduler, _priority_like_py
from .params import SimParams
from .policy import DEFAULT_POINTS
from .scheduler import (
    EPS,
    decision_loop,
    empty_decision,
    get_vector_scheduler,
    onehot_set,
    policy_family_make,
    register_vector_scheduler_family,
)
from .state import INF_TICK, SimState, Workload
from .types import Failure, Pipeline, PipeStatus, Suspension

CHUNK = 0.25
CAP = 0.50


def _select_sjf(mask, n_ops, prio, entered):
    """Fewest ops, then highest priority, then earliest entry, then pid.

    Five-pass oracle form, kept (like ``scheduler.select_next_pipe``)
    as the reference the fused ``sched_select.select_sjf`` is
    property-tested against; the sjf scheduler below runs the fused op.
    """
    any_ = jnp.any(mask)
    n = jnp.where(mask, n_ops, jnp.int32(2**30))
    m1 = mask & (n_ops == jnp.min(n))
    p = jnp.where(m1, prio, -1)
    m2 = m1 & (prio == jnp.max(p))
    e = jnp.where(m2, entered, INF_TICK)
    m3 = m2 & (entered == jnp.min(e))
    idx = jnp.argmax(m3).astype(jnp.int32)
    return jnp.where(any_, idx, -1)


def _sjf_like(early_exit: bool = False):
    def sjf(sched_state: Any, sim: SimState, wl: Workload, params: SimParams):
        K = params.max_assignments_per_tick
        total_cpu = jnp.sum(sim.pool_cpu_cap)
        total_ram = jnp.sum(sim.pool_ram_cap)
        chunk_cpu, chunk_ram = CHUNK * total_cpu, CHUNK * total_ram
        cap_cpu, cap_ram = CAP * total_cpu, CAP * total_ram

        dec = empty_decision(params)
        waiting0 = sim.pipe_status == int(PipeStatus.WAITING)
        reject = waiting0 & sim.pipe_fail_flag & (sim.pipe_last_ram >= cap_ram - EPS)
        dec = dec._replace(reject=reject)
        # fused-selection keys, hoisted out of the decision loop (only
        # the ``tried`` mask varies per slot)
        sjf_keys = (wl.n_ops, -wl.prio, sim.pipe_entered)
        base_mask = waiting0 & ~reject

        def step(k, carry):
            dec, free_cpu, free_ram, tried = carry
            mask = base_mask & ~tried
            pipe = masked_lex_argmin(mask, sjf_keys)
            valid = pipe >= 0
            pipe_c = jnp.maximum(pipe, 0)
            failed = sim.pipe_fail_flag[pipe_c]
            seen = sim.pipe_last_ram[pipe_c] > 0.0
            want_cpu = jnp.where(
                failed, jnp.minimum(2.0 * sim.pipe_last_cpus[pipe_c], cap_cpu),
                jnp.where(seen, sim.pipe_last_cpus[pipe_c], chunk_cpu))
            want_ram = jnp.where(
                failed, jnp.minimum(2.0 * sim.pipe_last_ram[pipe_c], cap_ram),
                jnp.where(seen, sim.pipe_last_ram[pipe_c], chunk_ram))
            fits = (free_cpu[0] >= want_cpu - EPS) & (free_ram[0] >= want_ram - EPS)
            do = valid & fits
            dec = dec._replace(
                assign_pipe=onehot_set(
                    dec.assign_pipe, k, jnp.where(do, pipe_c, -1)
                ),
                assign_pool=onehot_set(dec.assign_pool, k, 0),
                assign_cpus=onehot_set(dec.assign_cpus, k, want_cpu),
                assign_ram=onehot_set(dec.assign_ram, k, want_ram),
            )
            free_cpu = jnp.where(do, free_cpu.at[0].add(-want_cpu), free_cpu)
            free_ram = jnp.where(do, free_ram.at[0].add(-want_ram), free_ram)
            tried = jnp.where(valid, onehot_set(tried, pipe_c, True), tried)
            return (dec, free_cpu, free_ram, tried), valid

        tried0 = jnp.zeros((params.max_pipelines,), bool)
        carry0 = (dec, sim.pool_cpu_free, sim.pool_ram_free, tried0)
        dec, *_ = decision_loop(step, K, carry0, early_exit)
        return sched_state, dec

    return sjf


# sjf is a point of the parameterised policy family (25 % chunks,
# op-count lead key, no preemption) — registered through the unified
# builder so searches can seed from it; ``_sjf_like`` stays registered
# as the independent oracle for the identity test wall.
register_vector_scheduler_family("sjf", params=DEFAULT_POINTS["sjf"])(
    policy_family_make
)
register_vector_scheduler_family("sjf_ref")(_sjf_like)
sjf_vector = get_vector_scheduler("sjf")


@register_scheduler_init(key="sjf")
def _sjf_init(sch: Scheduler) -> None:
    pass


@register_scheduler(key="sjf")
def sjf_python(sch: Scheduler, failures: List[Failure], new: List[Pipeline]):
    import numpy as np

    f32 = np.float32
    total_cpu, total_ram = sch.total_cpus, sch.total_ram_gb
    chunk_cpu, chunk_ram = f32(CHUNK) * total_cpu, f32(CHUNK) * total_ram
    cap_cpu, cap_ram = f32(CAP) * total_cpu, f32(CAP) * total_ram
    eps = f32(EPS)

    suspends: list[Suspension] = []
    assignments = []
    free_cpu = sch.pool_cpu_free.copy()
    free_ram = sch.pool_ram_free.copy()
    rejects = [
        pid for pid in sch.waiting_pids()
        if sch.pipelines[pid].failed_before
        and f32(sch.pipelines[pid].last_ram_gb) >= cap_ram - eps
    ]
    sch.data["rejects"] = rejects
    tried = set(rejects)
    for _ in range(sch.params.max_assignments_per_tick):
        cands = [
            pid for pid in sch.status
            if sch.status[pid] == PipeStatus.WAITING and pid not in tried
        ]
        if not cands:
            break
        pid = min(
            cands,
            key=lambda pid: (
                sch.pipelines[pid].num_ops,
                -int(sch.pipelines[pid].priority),
                sch.entered[pid],
                pid,
            ),
        )
        tried.add(pid)
        p = sch.pipelines[pid]
        if p.failed_before:
            want_cpu = np.minimum(f32(2.0) * f32(p.last_cpus), cap_cpu)
            want_ram = np.minimum(f32(2.0) * f32(p.last_ram_gb), cap_ram)
        elif p.last_ram_gb > 0.0:
            want_cpu, want_ram = f32(p.last_cpus), f32(p.last_ram_gb)
        else:
            want_cpu, want_ram = chunk_cpu, chunk_ram
        if free_cpu[0] >= want_cpu - eps and free_ram[0] >= want_ram - eps:
            from .types import Assignment

            assignments.append(Assignment(p, 0, want_cpu, want_ram))
            free_cpu[0] -= want_cpu
            free_ram[0] -= want_ram
    return suspends, assignments


# ---------------------------------------------------------------------------
# Data-plane schedulers: the vector families are the generalised
# priority machinery in scheduler.py, where they are also REGISTERED
# (so the public aliases resolve through the cached registry without a
# circular import); the Python twins below reuse the mirrored
# machinery in engine_python.py.
# ---------------------------------------------------------------------------
@register_scheduler_init(key="cache_aware")
def _cache_aware_init(sch: Scheduler) -> None:
    pass


@register_scheduler(key="cache_aware")
def cache_aware_python(
    sch: Scheduler, failures: List[Failure], new: List[Pipeline]
):
    return _priority_like_py(sch, "cache")


@register_scheduler_init(key="locality_pool")
def _locality_pool_init(sch: Scheduler) -> None:
    pass


@register_scheduler(key="locality_pool")
def locality_pool_python(
    sch: Scheduler, failures: List[Failure], new: List[Pipeline]
):
    return _priority_like_py(sch, "locality")


__all__ = [
    "sjf_vector",
    "sjf_python",
    "cache_aware_python",
    "locality_pool_python",
]
