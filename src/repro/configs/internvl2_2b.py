"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT + InternLM2. [arXiv:2404.16821; hf]

The InternViT frontend is a STUB: input_specs() provides 256 precomputed
patch embeddings [B, 256, 1024] which a learned projector maps into the
first 256 positions of the LM. vocab padded 92553 -> 92672.
"""
from repro.models.common import LayerSpec, ModelConfig
from .registry import ArchSpec, pad_vocab, register

register(
    ArchSpec(
        model=ModelConfig(
            name="internvl2_2b",
            family="vlm",
            n_layers=24,
            d_model=2048,
            n_heads=16,
            n_kv_heads=8,
            head_dim=128,
            d_ff=8192,
            vocab=pad_vocab(92553),
            n_img_tokens=256,
            pattern=(LayerSpec("attn", "dense"),),
        ),
        smoke=ModelConfig(
            name="internvl2_2b_smoke",
            family="vlm",
            n_layers=4,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            d_ff=128,
            vocab=512,
            n_img_tokens=8,
            pattern=(LayerSpec("attn", "dense"),),
            attn_impl="ref",
        ),
        optimizer="adamw",
        skip={"long_500k": "full attention (quadratic)"},
        notes="LM backbone only; vision tower stubbed per assignment.",
    )
)
