"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global interleave, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

head_dim=256 (gemma3-12b's actual head width; the assignment lists only
d_model/H). Local layers use a 1024-token sliding window; every 6th
layer is global — quadratic at 500k, so long_500k is skipped.
"""
from repro.models.common import LayerSpec, ModelConfig
from .registry import ArchSpec, register

LOCAL = LayerSpec("attn", "dense", window=1024)
GLOBAL = LayerSpec("attn", "dense", window=0)
PATTERN = (LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, GLOBAL)

register(
    ArchSpec(
        model=ModelConfig(
            name="gemma3_12b",
            family="lm",
            n_layers=48,
            d_model=3840,
            n_heads=16,
            n_kv_heads=8,
            head_dim=256,
            d_ff=15360,
            vocab=262144,
            pattern=PATTERN,
            rope_theta=1_000_000.0,
        ),
        smoke=ModelConfig(
            name="gemma3_12b_smoke",
            family="lm",
            n_layers=6,
            d_model=96,
            n_heads=4,
            n_kv_heads=2,
            head_dim=24,
            d_ff=192,
            vocab=512,
            pattern=(
                LayerSpec("attn", "dense", window=8),
                LayerSpec("attn", "dense", window=8),
                LayerSpec("attn", "dense", window=0),
            ),
            attn_impl="ref",
        ),
        optimizer="adamw",
        skip={"long_500k": "global layers are full attention (quadratic)"},
        notes="5:1 local:global via period-6 pattern; kv=8 < model axis -> "
        "KV projections replicate, Q heads shard (divisibility rule).",
    )
)
