"""whisper-small [audio]: 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865 — enc-dec, conv frontend (stub). [arXiv:2212.04356; unverified]

12L = 12 encoder + 12 decoder layers (whisper-small's actual split).
The conv frontend is stubbed: input_specs() provides precomputed frame
embeddings [B, seq_len, 1024]; decoder length = max(64, seq_len // 8).
vocab padded 51865 -> 51968. long_500k skipped: the decoder's
cross-attention is linear per decode step, but it presupposes a 500k-
frame *encoder* pass, which is quadratic self-attention.
"""
from repro.models.common import LayerSpec, ModelConfig
from .registry import ArchSpec, pad_vocab, register

register(
    ArchSpec(
        model=ModelConfig(
            name="whisper_small",
            family="audio",
            n_layers=12,
            n_enc_layers=12,
            d_model=768,
            n_heads=12,
            n_kv_heads=12,
            head_dim=64,
            d_ff=3072,
            vocab=pad_vocab(51865),
            mlp_type="gelu",
            pattern=(LayerSpec("attn", "dense"),),
        ),
        smoke=ModelConfig(
            name="whisper_small_smoke",
            family="audio",
            n_layers=2,
            n_enc_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=4,
            d_ff=128,
            vocab=512,
            mlp_type="gelu",
            pattern=(LayerSpec("attn", "dense"),),
            attn_impl="ref",
        ),
        optimizer="adamw",
        skip={"long_500k": "500k-frame encoder self-attention is quadratic"},
        notes="12 heads not divisible by model=16 -> attention projections "
        "replicate across TP; ff/vocab still shard (768-dim model is tiny).",
    )
)
