"""Architecture registry: full production configs + reduced smoke configs.

Every assigned architecture registers an :class:`ArchSpec` here via its
own module (``src/repro/configs/<id>.py``). ``get_arch(name)`` is the
single lookup used by the launcher, dry-run, tests and benchmarks
(``--arch <id>``).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Mapping

from repro.models.common import ModelConfig

ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    model: ModelConfig
    smoke: ModelConfig
    optimizer: str = "adamw"            # "adamw" | "adafactor"
    opt_state_dtype: str = "float32"    # "float32" | "bfloat16" (giants)
    train_microbatches: int = 4         # gradient-accumulation splits
    shapes: tuple[str, ...] = ALL_SHAPES
    skip: Mapping[str, str] = dataclasses.field(default_factory=dict)
    # sharding-rule overrides, e.g. {"param": {"head_dim": ("model",)}}
    rule_overrides: Mapping[str, Mapping] = dataclasses.field(
        default_factory=dict
    )
    notes: str = ""

    @property
    def name(self) -> str:
        return self.model.name

    def runnable_shapes(self) -> tuple[str, ...]:
        return tuple(s for s in self.shapes if s not in self.skip)


_REGISTRY: dict[str, ArchSpec] = {}

ARCH_MODULES = [
    "gemma3_12b",
    "granite_34b",
    "phi3_mini_3p8b",
    "gemma3_27b",
    "internvl2_2b",
    "llama4_maverick_400b_a17b",
    "arctic_480b",
    "whisper_small",
    "jamba_1p5_large_398b",
    "rwkv6_7b",
]


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.name] = spec
    return spec


def _load_all():
    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_arch(name: str) -> ArchSpec:
    if not _REGISTRY:
        _load_all()
    key = name.replace("-", "_").replace(".", "p")
    for cand in (name, key):
        if cand in _REGISTRY:
            return _REGISTRY[cand]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a multiple so it TP-shards cleanly (noted per config)."""
    return ((v + multiple - 1) // multiple) * multiple


__all__ = ["ArchSpec", "register", "get_arch", "list_archs", "pad_vocab",
           "ALL_SHAPES"]
