"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 — Finch, data-dependent decay. [arXiv:2404.05892; hf]

64 heads x head_dim 64; O(1) recurrent state per layer -> long_500k RUNS
(decode state is [B, 64, 64, 64] f32 per layer regardless of context).
"""
from repro.models.common import LayerSpec, ModelConfig, RWKVConfig
from .registry import ArchSpec, register

register(
    ArchSpec(
        model=ModelConfig(
            name="rwkv6_7b",
            family="ssm",
            n_layers=32,
            d_model=4096,
            d_ff=14336,
            vocab=65536,
            rwkv=RWKVConfig(head_dim=64, chunk=32),
            pattern=(LayerSpec("rwkv", "dense"),),
        ),
        smoke=ModelConfig(
            name="rwkv6_7b_smoke",
            family="ssm",
            n_layers=4,
            d_model=64,
            d_ff=128,
            vocab=512,
            rwkv=RWKVConfig(head_dim=16, chunk=8),
            pattern=(LayerSpec("rwkv", "dense"),),
            attn_impl="ref",
        ),
        optimizer="adamw",
        notes="attention-free; Eudoxia's scheduling layer treats its "
        "decode ops exactly like attention archs (technique is "
        "architecture-agnostic).",
    )
)
