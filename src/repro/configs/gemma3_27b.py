"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global, 128k. [hf:google/gemma-3-1b-pt; unverified]

62 layers = 10 full (5 local + 1 global) periods + 2 tail local layers —
exercises the period-scan tail path at production scale.
"""
from repro.models.common import LayerSpec, ModelConfig
from .registry import ArchSpec, register

LOCAL = LayerSpec("attn", "dense", window=1024)
GLOBAL = LayerSpec("attn", "dense", window=0)

register(
    ArchSpec(
        model=ModelConfig(
            name="gemma3_27b",
            family="lm",
            n_layers=62,
            d_model=5376,
            n_heads=32,
            n_kv_heads=16,
            head_dim=128,
            d_ff=21504,
            vocab=262144,
            pattern=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, GLOBAL),
            rope_theta=1_000_000.0,
        ),
        smoke=ModelConfig(
            name="gemma3_27b_smoke",
            family="lm",
            n_layers=8,  # 2 periods of 3 + 2 tail
            d_model=96,
            n_heads=4,
            n_kv_heads=2,
            head_dim=24,
            d_ff=192,
            vocab=512,
            pattern=(
                LayerSpec("attn", "dense", window=8),
                LayerSpec("attn", "dense", window=8),
                LayerSpec("attn", "dense", window=0),
            ),
            attn_impl="ref",
        ),
        optimizer="adamw",
        skip={"long_500k": "global layers are full attention (quadratic)"},
    )
)
