"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 — early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Maverick interleaves dense and MoE layers 1:1; MoE layers carry 128
routed experts (top-1) plus a shared expert. iRoPE's chunked-local
global layers are modelled as plain global attention (quadratic), so
long_500k is skipped. Adafactor + bf16 optimizer state: Adam moments for
400B params would not fit 16 GB/chip x 256.
"""
from repro.models.common import LayerSpec, MoEConfig, ModelConfig
from .registry import ArchSpec, register

register(
    ArchSpec(
        model=ModelConfig(
            name="llama4_maverick_400b_a17b",
            family="moe",
            n_layers=48,
            d_model=5120,
            # 40 semantic heads padded to 48 (TP divisibility; see arctic
            # note + EXPERIMENTS.md §Perf for the measured rationale)
            n_heads=48,
            n_kv_heads=8,
            head_dim=128,
            d_ff=16384,  # dense-layer FF (ff=8192 in the line is expert FF)
            vocab=202048,
            moe=MoEConfig(
                n_experts=128,
                top_k=1,
                expert_ff=8192,
                shared_expert_ff=8192,
                capacity_factor=1.25,
            ),
            pattern=(LayerSpec("attn", "dense"), LayerSpec("attn", "moe")),
        ),
        smoke=ModelConfig(
            name="llama4_maverick_smoke",
            family="moe",
            n_layers=4,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            d_ff=128,
            vocab=512,
            moe=MoEConfig(
                n_experts=4, top_k=1, expert_ff=96, shared_expert_ff=96
            ),
            pattern=(LayerSpec("attn", "dense"), LayerSpec("attn", "moe")),
            attn_impl="ref",
        ),
        optimizer="adafactor",
        opt_state_dtype="bfloat16",
        train_microbatches=8,
        skip={"long_500k": "global attention layers (quadratic)"},
        notes="Q heads padded 40->48 for 16-way TP.",
    )
)
