"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]

Arctic's signature dense-MoE hybrid: every layer runs a (small) dense
MLP **in parallel** with the 128-expert top-2 MoE ("moe_dense" spec).
56 heads don't divide the 16-way model axis, so attention shards on
head_dim (128/16=8). vocab padded 32000 -> 32000 (already 256-aligned
via 32000 % 256 == 0 ? no — padded to 32256).
"""
from repro.models.common import LayerSpec, MoEConfig, ModelConfig
from .registry import ArchSpec, pad_vocab, register

register(
    ArchSpec(
        model=ModelConfig(
            name="arctic_480b",
            family="moe",
            n_layers=35,
            d_model=7168,
            # 56 semantic heads padded to 64 so Q/O shard 16-way on the
            # model axis (head_dim sharding all-reduces every score panel —
            # measured 11.4 TB/step wire; see EXPERIMENTS.md §Perf). The
            # faithful 56-head baseline is recorded in dryrun_baseline.json.
            n_heads=64,
            n_kv_heads=8,
            head_dim=128,
            d_ff=4864,
            vocab=pad_vocab(32000),
            moe=MoEConfig(
                n_experts=128, top_k=2, expert_ff=4864, capacity_factor=1.25
            ),
            pattern=(LayerSpec("attn", "moe_dense"),),
        ),
        smoke=ModelConfig(
            name="arctic_480b_smoke",
            family="moe",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            d_ff=96,
            vocab=512,
            moe=MoEConfig(n_experts=8, top_k=2, expert_ff=96),
            pattern=(LayerSpec("attn", "moe_dense"),),
            attn_impl="ref",
        ),
        optimizer="adafactor",
        opt_state_dtype="bfloat16",
        train_microbatches=8,
        skip={"long_500k": "full attention (quadratic)"},
        notes="dense residual MLP parallel to 128e top-2 MoE every layer.",
    )
)
