"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave.
[arXiv:2403.19887; hf]

Period-8 pattern: one attention layer per 8 (position 4), the rest
Mamba; MoE replaces the dense MLP on every other layer. Sub-quadratic
sequence mixing -> long_500k RUNS (the 9 attention layers see a
524288-token KV cache, sharded over the model axis as context
parallelism).
"""
from repro.models.common import (
    LayerSpec,
    MambaConfig,
    MoEConfig,
    ModelConfig,
)
from .registry import ArchSpec, register

M_D = LayerSpec("mamba", "dense")
M_E = LayerSpec("mamba", "moe")
A_D = LayerSpec("attn", "dense")
A_E = LayerSpec("attn", "moe")

register(
    ArchSpec(
        model=ModelConfig(
            name="jamba_1p5_large_398b",
            family="hybrid",
            n_layers=72,
            d_model=8192,
            n_heads=64,
            n_kv_heads=8,
            head_dim=128,
            d_ff=24576,
            vocab=65536,
            moe=MoEConfig(
                n_experts=16, top_k=2, expert_ff=24576, capacity_factor=1.25
            ),
            mamba=MambaConfig(d_state=16, conv_k=4, expand=2, chunk=256),
            pattern=(M_D, M_E, M_D, M_E, A_D, M_E, M_D, M_E),
        ),
        smoke=ModelConfig(
            name="jamba_smoke",
            family="hybrid",
            n_layers=8,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            d_ff=128,
            vocab=512,
            moe=MoEConfig(n_experts=4, top_k=2, expert_ff=96),
            mamba=MambaConfig(d_state=8, conv_k=4, expand=2, chunk=8),
            pattern=(
                LayerSpec("mamba", "dense"),
                LayerSpec("mamba", "moe"),
                LayerSpec("attn", "dense"),
                LayerSpec("mamba", "moe"),
            ),
            attn_impl="ref",
        ),
        optimizer="adafactor",
        opt_state_dtype="bfloat16",
        train_microbatches=8,
        notes="long_500k runs: mamba state is O(1); attention KV at 500k "
        "shards over the model axis (SP/context parallelism).",
    )
)
