"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32 — full MHA)
d_ff=8192 vocab=32064 — RoPE SwiGLU. [arXiv:2404.14219; unverified]

vocab padded 32064 -> 32256 (divisible by 256) for clean TP sharding.
"""
from repro.models.common import LayerSpec, ModelConfig
from .registry import ArchSpec, pad_vocab, register

register(
    ArchSpec(
        model=ModelConfig(
            name="phi3_mini_3p8b",
            family="lm",
            n_layers=32,
            d_model=3072,
            n_heads=32,
            n_kv_heads=32,
            head_dim=96,
            d_ff=8192,
            vocab=pad_vocab(32064),
            pattern=(LayerSpec("attn", "dense"),),
        ),
        smoke=ModelConfig(
            name="phi3_mini_smoke",
            family="lm",
            n_layers=4,
            d_model=64,
            n_heads=4,
            n_kv_heads=4,
            d_ff=128,
            vocab=512,
            pattern=(LayerSpec("attn", "dense"),),
            attn_impl="ref",
        ),
        optimizer="adamw",
        skip={"long_500k": "full attention (quadratic)"},
    )
)
