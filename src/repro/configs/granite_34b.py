"""granite-34b [dense]: 88L d_model=6144 48H (GQA kv=1 — MQA) d_ff=24576
vocab=49152 — llama-arch, code. [arXiv:2405.04324; hf]
"""
from repro.models.common import LayerSpec, ModelConfig
from .registry import ArchSpec, register

register(
    ArchSpec(
        model=ModelConfig(
            name="granite_34b",
            family="lm",
            n_layers=88,
            d_model=6144,
            n_heads=48,
            n_kv_heads=1,
            head_dim=128,
            d_ff=24576,
            vocab=49152,
            mlp_type="gelu",
            pattern=(LayerSpec("attn", "dense"),),
        ),
        smoke=ModelConfig(
            name="granite_34b_smoke",
            family="lm",
            n_layers=4,
            d_model=64,
            n_heads=4,
            n_kv_heads=1,
            head_dim=16,
            d_ff=128,
            vocab=512,
            mlp_type="gelu",
            pattern=(LayerSpec("attn", "dense"),),
            attn_impl="ref",
        ),
        optimizer="adamw",
        skip={"long_500k": "full attention (quadratic)"},
        notes="MQA: kv=1 replicates KV projections across TP ranks; "
        "48 Q heads shard 16-way (48 % 16 == 0).",
    )
)
