from .registry import ALL_SHAPES, ArchSpec, get_arch, list_archs

__all__ = ["ALL_SHAPES", "ArchSpec", "get_arch", "list_archs"]
