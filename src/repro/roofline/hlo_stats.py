"""Static analyzer for post-SPMD optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop *body once* —
useless for scan-over-layers programs (every production model here
period-scans its stack, flash-attention KV blocks, loss vocab chunks,
rwkv/ssm chunks). This analyzer walks the HLO text and computes, per
executed instruction (i.e. multiplying loop bodies by their trip
counts):

* FLOPs        — dot (2*numel(result)*prod(contracting dims)) and
                 convolution; everything else treated as 0-FLOP or
                 1-FLOP/elem for a small elementwise set.
* HBM bytes    — per top-level instruction: operands + results, with
                 fusion internals ignored (they live in VMEM/registers).
* collective wire bytes — ring-model per collective kind (see
                 analysis.collective_bytes_by_type for the formulas),
                 multiplied through loops like everything else.

Trip counts: a scan/while condition region compares the induction
variable against an s32 constant — we take the max s32 constant found in
the condition computation (exact for lax.scan/fori_loop lowerings).
Conditionals contribute the max across branches.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from .hw import DTYPE_BYTES

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_OPND = re.compile(r"%([\w.\-]+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_ELTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "log", "rsqrt", "sqrt", "tanh", "power", "negate",
    "compare", "select", "and", "or",
}
COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _parse_shapes(text: str):
    """All dtype[dims] shapes in a type string -> list of (dtype, [dims])."""
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        dd = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((dt, dd))
    return out


def _numel(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes(shapes) -> float:
    return sum(_numel(d) * DTYPE_BYTES[t] for t, d in shapes)


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str           # full result type string (may be tuple)
    opcode: str
    rest: str                  # remainder of the line after the opcode
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict[str, str]     # instr name -> result type string


@dataclasses.dataclass
class Stats:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES}
    )
    coll_counts: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES}
    )

    def add(self, other: "Stats", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * times
            self.coll_counts[k] += other.coll_counts[k] * times

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


_OPCODE_RE = re.compile(
    r"^((?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?|\(\))\s+)?"
    r"([a-z][\w\-]*)\s*\("
)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(1), [], {})
            continue
        s = line.strip()
        if s == "}" or s.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        parsed = _split_type_opcode(rhs)
        if parsed is None:
            continue
        rtype, opcode, rest = parsed
        operands = _OPND.findall(rest.split("),")[0]) if rest else []
        cur.instrs.append(Instr(name, rtype, opcode, rest, operands))
        cur.shapes[name] = rtype
    return comps


def _split_type_opcode(rhs: str):
    """Split '<type> <opcode>(<rest>' handling nested tuple types."""
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        rtype = rhs[: end + 1]
        rest = rhs[end + 1 :].lstrip()
    else:
        tm = re.match(r"^([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+(.*)$", rhs)
        if not tm:
            return None
        rtype, rest = tm.group(1), tm.group(2)
    om = re.match(r"^([\w\-]+)\((.*)$", rest)
    if not om:
        return None
    return rtype, om.group(1), om.group(2)


def _tuple_component(type_str: str, index: int) -> str:
    """index into a tuple type string."""
    if not type_str.startswith("("):
        return type_str
    depth = 0
    parts = []
    buf = ""
    for ch in type_str[1:-1]:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append(buf)
            buf = ""
        else:
            buf += ch
    parts.append(buf)
    if index < len(parts):
        return parts[index].strip()
    return type_str


class HloAnalyzer:
    def __init__(self, text: str, chips: int = 1):
        self.comps = parse_module(text)
        self.chips = chips
        self._memo: dict[str, Stats] = {}
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
                if m:
                    entry = m.group(1)
        self.entry = entry

    # ----------------------------------------------------------------- utils
    def _trip_count(self, cond_name: str) -> int:
        """Loop bound = the largest s32 scalar constant in the condition
        region (exact for lax.scan / fori_loop lowerings: `iter < N`)."""
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for i in comp.instrs:
            if i.opcode == "constant" and i.result_type.strip() == "s32[]":
                m = re.match(r"(\d+)\)", i.rest)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    def _operand_shapes(self, comp: Computation, instr: Instr):
        out = []
        for op in instr.operands:
            t = comp.shapes.get(op)
            if t:
                out.extend(_parse_shapes(t))
        return out

    def _dot_flops(self, comp: Computation, instr: Instr) -> float:
        res = _parse_shapes(instr.result_type)
        if not res:
            return 0.0
        result_elems = _numel(res[0][1])
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
        lhs_name = instr.operands[0] if instr.operands else None
        lhs_t = comp.shapes.get(lhs_name or "", "")
        lhs_shapes = _parse_shapes(lhs_t)
        contracted = 1
        if m and lhs_shapes:
            dims = lhs_shapes[0][1]
            for ix in m.group(1).split(","):
                if ix and int(ix) < len(dims):
                    contracted *= dims[int(ix)]
        return 2.0 * result_elems * contracted

    def _conv_flops(self, comp: Computation, instr: Instr) -> float:
        res = _parse_shapes(instr.result_type)
        ops = self._operand_shapes(comp, instr)
        if not res or len(ops) < 2:
            return 0.0
        kernel = ops[1][1]
        return 2.0 * _numel(res[0][1]) * _numel(kernel[:-1])

    def _fusion_operand_bytes(
        self, comp: Computation, instr: Instr, callee: Optional[str]
    ) -> float:
        """Operand bytes for a fusion, counting only the sliced region of
        any operand the fused computation merely dynamic-slices (the
        scan-over-layers pattern passes the whole stacked parameter array
        into each iteration's slice fusion)."""
        cal = self.comps.get(callee or "")
        if cal is None:
            return _bytes(self._operand_shapes(comp, instr))
        # parameter index -> slice-only use size (or None = full use)
        param_types: list[str] = []
        uses_full: dict[str, bool] = {}
        slice_bytes: dict[str, float] = {}
        params: dict[str, int] = {}
        for ci in cal.instrs:
            if ci.opcode == "parameter":
                mi = re.match(r"(\d+)\)", ci.rest)
                if mi:
                    params[ci.name] = int(mi.group(1))
                    uses_full[ci.name] = False
                    slice_bytes[ci.name] = 0.0
        for ci in cal.instrs:
            if ci.opcode == "parameter":
                continue
            for op in ci.operands:
                if op not in params:
                    continue
                if ci.opcode in ("dynamic-slice", "slice", "gather"):
                    slice_bytes[op] += _bytes(_parse_shapes(ci.result_type))
                else:
                    uses_full[op] = True
        total = 0.0
        for op_ix, op_name in enumerate(instr.operands):
            t = comp.shapes.get(op_name)
            if not t:
                continue
            full = _bytes(_parse_shapes(t))
            # match operand position to callee parameter number
            pname = next(
                (n for n, ix in params.items() if ix == op_ix), None
            )
            if pname is not None and not uses_full[pname] and slice_bytes[pname]:
                total += min(slice_bytes[pname], full)
            else:
                total += full
        return total

    def _collective(self, stats: Stats, instr: Instr):
        op = instr.opcode.replace("-start", "")
        if op not in COLLECTIVES:
            return
        res_type = instr.result_type
        shapes = _parse_shapes(res_type)
        if instr.opcode.endswith("-start") and len(shapes) > 1:
            shapes = shapes[-1:]
        R = _bytes(shapes)
        m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", instr.rest)
        if m:
            n = len(m.group(1).split(","))
        else:
            m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.rest)
            n = int(m.group(2)) if m else self.chips
        n = max(n, 1)
        if op == "all-gather":
            wire = R * (n - 1) / n
        elif op == "all-reduce":
            wire = 2.0 * R * (n - 1) / n
        elif op == "reduce-scatter":
            wire = R * (n - 1)
        elif op == "all-to-all":
            wire = R * (n - 1) / n
        else:
            wire = R
        stats.coll[op] += wire
        stats.coll_counts[op] += 1

    # ------------------------------------------------------------------ main
    def stats_of(self, comp_name: str) -> Stats:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        stats = Stats()
        if comp is None:
            return stats
        self._memo[comp_name] = stats  # break cycles defensively
        for instr in comp.instrs:
            oc = instr.opcode
            if oc.endswith("-done"):
                continue
            if oc == "dot":
                stats.flops += self._dot_flops(comp, instr)
                stats.bytes += _bytes(
                    self._operand_shapes(comp, instr)
                ) + _bytes(_parse_shapes(instr.result_type))
            elif oc == "convolution":
                stats.flops += self._conv_flops(comp, instr)
                stats.bytes += _bytes(
                    self._operand_shapes(comp, instr)
                ) + _bytes(_parse_shapes(instr.result_type))
            elif oc == "while":
                m = re.search(r"condition=%?([\w.\-]+)", instr.rest)
                mb = re.search(r"body=%?([\w.\-]+)", instr.rest)
                trips = self._trip_count(m.group(1)) if m else 1
                if mb:
                    stats.add(self.stats_of(mb.group(1)), times=trips)
            elif oc == "conditional":
                branches = re.search(
                    r"branch_computations=\{([^}]*)\}", instr.rest
                )
                names = []
                if branches:
                    names = _OPND.findall(branches.group(1))
                else:
                    names = _OPND.findall(instr.rest)[len(instr.operands):]
                if names:
                    subs = [self.stats_of(n) for n in names]
                    best = max(subs, key=lambda s: s.flops + s.bytes)
                    stats.add(best)
            elif oc in ("fusion", "call", "custom-call", "async-start"):
                m = re.search(r"calls=%?([\w.\-]+)", instr.rest)
                callee = m.group(1) if m else None
                if callee:
                    sub = self.stats_of(callee)
                    stats.flops += sub.flops
                    for k in COLLECTIVES:
                        stats.coll[k] += sub.coll[k]
                        stats.coll_counts[k] += sub.coll_counts[k]
                # fusion HBM traffic: slice-aware operands + result
                stats.bytes += self._fusion_operand_bytes(
                    comp, instr, callee
                ) + _bytes(_parse_shapes(instr.result_type))
            elif oc in COLLECTIVES or oc.replace("-start", "") in COLLECTIVES:
                self._collective(stats, instr)
                stats.bytes += _bytes(_parse_shapes(instr.result_type))
            elif oc in _ELTWISE_1FLOP:
                res = _parse_shapes(instr.result_type)
                if res:
                    n = _numel(res[0][1])
                    stats.flops += n
                    stats.bytes += _bytes(
                        self._operand_shapes(comp, instr)
                    ) + _bytes(res)
            elif oc in ("dynamic-slice", "slice", "gather", "broadcast",
                        "iota"):
                # reads only the sliced/produced region, not the base
                stats.bytes += _bytes(_parse_shapes(instr.result_type))
            elif oc == "dynamic-update-slice":
                # in-place: read update + write the touched region
                ops = self._operand_shapes(comp, instr)
                upd = ops[1:2] if len(ops) > 1 else ops[:1]
                stats.bytes += 2.0 * _bytes(upd)
            elif oc == "scatter":
                ops = self._operand_shapes(comp, instr)
                upd = ops[2:3] if len(ops) > 2 else ops[-1:]
                stats.bytes += 2.0 * _bytes(upd)
            # `copy` excluded: while-loop carry copies are aliased
            # in-place on TPU (no HBM round-trip)
            elif oc in ("transpose", "concatenate", "reduce",
                        "pad", "sort", "reverse"):
                res = _parse_shapes(instr.result_type)
                stats.bytes += _bytes(
                    self._operand_shapes(comp, instr)
                ) + _bytes(res)
            # NOTE: `convert` is deliberately NOT counted — the CPU
            # backend legalises bf16 dots to f32 with convert pairs that
            # do not exist on the bf16-native TPU target.
        return stats

    def entry_stats(self) -> Stats:
        if self.entry is None:
            return Stats()
        return self.stats_of(self.entry)


def analyze_hlo(text: str, chips: int = 1) -> Stats:
    return HloAnalyzer(text, chips=chips).entry_stats()


__all__ = ["Stats", "HloAnalyzer", "analyze_hlo", "COLLECTIVES"]
