"""Three-term roofline from a compiled (dry-run) artifact.

    compute    = HLO_FLOPs          / (chips x peak FLOP/s)
    memory     = HLO_bytes_accessed / (chips x HBM bandwidth)
    collective = collective_bytes   / (chips x ICI link bandwidth)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device program —
multiplied by chip count for the global view). collective_bytes is NOT
in cost_analysis: we parse the post-SPMD HLO (``compiled.as_text()``)
and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

from .hw import DTYPE_BYTES, HBM_BW, ICI_BW, PEAK_FLOPS_BF16

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(" + "|".join(COLLECTIVE_OPS) + r")(-start)?[\s(.]"
)
_GROUPS_EXPLICIT = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=[total]
    return default


def collective_bytes_by_type(hlo_text: str, chips: int = 1) -> dict[str, Any]:
    """Per-device wire bytes per collective kind (ring-algorithm model).

    result bytes R, group size n:
      all-gather          R (n-1)/n      (operand is R/n, gathered)
      all-reduce          2 R (n-1)/n    (reduce-scatter + all-gather)
      reduce-scatter      R (n-1)        (operand R*n scattered)
      all-to-all          R (n-1)/n
      collective-permute  R
    Async -start/-done pairs are counted once (on -start; a bare -done's
    paired start already matched). The -start result is a tuple
    (operand, result); we take the last shape in the tuple.
    """
    out: dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    counts: dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    raw: dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        if re.search(r"(" + "|".join(COLLECTIVE_OPS) + r")-done", line):
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, op, started = m.group(1), m.group(2), m.group(3)
        if started and shape_str.startswith("("):
            # (operand_shapes..., result_shape) — use the last entry
            parts = _SHAPE_RE.findall(shape_str)
            if parts:
                dtype, dims = parts[-1]
                shape_str = f"{dtype}[{dims}]"
        R = _shape_bytes(shape_str)
        n = max(_group_size(line, chips), 1)
        if op == "all-gather":
            wire = R * (n - 1) / n
        elif op == "all-reduce":
            wire = 2.0 * R * (n - 1) / n
        elif op == "reduce-scatter":
            wire = R * (n - 1)
        elif op == "all-to-all":
            wire = R * (n - 1) / n
        else:  # collective-permute
            wire = R
        out[op] += wire
        raw[op] += R
        counts[op] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    out["_result_bytes"] = raw  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: dict[str, Any]
    model_flops: float            # 6*N*D (or 6*N_active*D for MoE)
    memory_per_device: dict[str, float]

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (remat/redundancy waste)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-resource roofline this step achieves
        on *useful* work: (model_flops / chips / peak) / bound_time."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS_BF16
        return ideal / self.bound_time if self.bound_time else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collectives": self.collectives,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "memory_per_device": self.memory_per_device,
        }


def model_flops_estimate(arch_spec, shape, n_params: int) -> float:
    """6*N*D with N = active params (MoE: routed fraction + shared)."""
    cfg = arch_spec.model
    from repro.launch.shapes import SHAPES
    from repro.models.encdec import dec_len

    sp = SHAPES[shape]
    if sp.kind == "train":
        if cfg.family == "audio":
            tokens = sp.batch * (sp.seq + dec_len(cfg, sp.seq))
        else:
            tokens = sp.batch * sp.seq
        factor = 6.0
    elif sp.kind == "prefill":
        tokens = sp.batch * sp.seq
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = sp.batch * 1
        factor = 2.0
    n_active = active_params(arch_spec, n_params)
    return factor * n_active * tokens


def active_params(arch_spec, n_params: int) -> float:
    """Active-per-token parameter count (MoE discounts unused experts)."""
    cfg = arch_spec.model
    m = cfg.moe
    if not m.n_experts:
        return float(n_params)
    # fraction of layers that are MoE; each token uses top_k experts
    n_moe_layers = sum(
        1
        for i in range(cfg.n_layers)
        if cfg.layer_spec(i).mlp in ("moe", "moe_dense")
    )
    per_expert = 3 * cfg.d_model * (m.expert_ff or cfg.d_ff)
    if cfg.mlp_type == "gelu":
        per_expert = 2 * cfg.d_model * (m.expert_ff or cfg.d_ff)
    total_expert = n_moe_layers * m.n_experts * per_expert
    active_expert = n_moe_layers * m.top_k * per_expert
    return float(n_params) - total_expert + active_expert


__all__ = [
    "Roofline",
    "collective_bytes_by_type",
    "model_flops_estimate",
    "active_params",
    "COLLECTIVE_OPS",
]
