"""Target hardware constants (TPU v5e-class chip, per assignment)."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per ICI link
HBM_BYTES = 16 * 1024**3      # 16 GiB per chip

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 0.5,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 0.5,
    "pred": 1, "c64": 8, "c128": 16,
}
