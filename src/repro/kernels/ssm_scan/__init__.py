from .ops import ssm_scan, ssm_decode_step
from .ref import ssm_scan_ref

__all__ = ["ssm_scan", "ssm_decode_step", "ssm_scan_ref"]
