"""Chunked Mamba selective scan — jit wrapper + chunked associative scan.

The oracle's token-sequential scan is latency-bound; here the sequence
is cut into chunks (default 256): inside a chunk a parallel
``lax.associative_scan`` computes the recurrence (materialising only
[B, C, dim, N] f32 per chunk — the chunk length is the VMEM/HBM memory
knob), across chunks a cheap sequential carry propagates the state.
Activation remat in the model wraps whole chunks, so the backward pass
replays one chunk at a time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssm_scan(
    x, dt, A, B, C, D, h0=None, *, chunk: int = 256, impl: str = "auto",
    interpret: bool = False,
):
    """Returns (y [B,S,dim], h [B,dim,N])."""
    Bsz, S, dim = x.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, dim, N), jnp.float32)
    # pad ragged sequences to a chunk multiple; dt=0, x=0 is the identity
    # update (a = exp(0) = 1, b = 0), so the carried state is untouched
    Cn = min(chunk, S)
    pad = (Cn - S % Cn) % Cn
    if pad:
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        x, dt, B, C = zpad(x), zpad(dt), zpad(B), zpad(C)
    use_kernel = impl == "kernel" or (
        impl == "auto" and jax.default_backend() == "tpu"
    )
    if use_kernel:
        from .kernel import ssm_scan_kernel

        y, h = ssm_scan_kernel(x, dt, A, B, C, D, h0, chunk=chunk,
                               interpret=interpret)
    else:
        y, h = _ssm_chunked(x, dt, A, B, C, D, h0, chunk=chunk)
    return (y[:, :S], h) if pad else (y, h)


def _ssm_chunked(x, dt, A, B, C, D, h0, *, chunk):
    Bsz, S, dim = x.shape
    N = A.shape[1]
    Cn = min(chunk, S)
    assert S % Cn == 0, f"seq {S} must divide chunk {Cn}"
    n_chunks = S // Cn
    f32 = jnp.float32
    xf, dtf, Bf, Cf = (t.astype(f32) for t in (x, dt, B, C))
    Af, Df = A.astype(f32), D.astype(f32)

    def to_chunks(t, last):
        return t.reshape(Bsz, n_chunks, Cn, last).transpose(1, 0, 2, 3)

    xc, dtc = to_chunks(xf, dim), to_chunks(dtf, dim)
    Bc, Cc = to_chunks(Bf, N), to_chunks(Cf, N)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def step(h, xs):
        x_, dt_, B_, C_ = xs  # [B, Cn, ...]
        a = jnp.exp(Af[None, None] * dt_[..., None])        # [B,Cn,dim,N]
        b = (dt_ * x_)[..., None] * B_[:, :, None, :]
        # prepend carry as the first element of the scan
        a0 = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b0 = jnp.concatenate([h[:, None], b], axis=1)
        _, hs = jax.lax.associative_scan(assoc, (a0, b0), axis=1)
        hs = hs[:, 1:]                                      # [B,Cn,dim,N]
        y = jnp.einsum("bcdn,bcn->bcd", hs, C_) + Df[None, None] * x_
        return hs[:, -1], y

    h, ys = jax.lax.scan(step, h0.astype(f32), (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, S, dim)
    return y.astype(x.dtype), h


def ssm_decode_step(x, dt, A, B, C, D, h):
    """One-token update. x/dt [B,dim]; B/C [B,N]; h [B,dim,N]."""
    f32 = jnp.float32
    xf, dtf, Bf, Cf = (t.astype(f32) for t in (x, dt, B, C))
    a = jnp.exp(A.astype(f32)[None] * dtf[..., None])
    b = (dtf * xf)[..., None] * Bf[:, None, :]
    h = a * h + b
    y = jnp.einsum("bdn,bn->bd", h, Cf) + D.astype(f32)[None] * xf
    return y.astype(x.dtype), h


__all__ = ["ssm_scan", "ssm_decode_step"]
