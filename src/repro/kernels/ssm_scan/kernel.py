"""Pallas TPU kernel for the Mamba-1 selective scan.

Grid = (batch, channel_block, chunk): channels are independent (the
[dim, N] state factorises over dim), so channel blocks ride the
parallel grid dims; the chunk axis is sequential with the [DB, N]
state slab resident in VMEM scratch. Inside a chunk the recurrence
steps token-by-token on the VPU — for Mamba-1's full [dim, N] decay
matrix the matmul-chunked trick of Mamba-2/SSD does not apply (the
exp(A dt) factor couples d and n), so the kernel optimises memory
traffic instead: x/dt/B/C stream through VMEM once per chunk and the
state never touches HBM between chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_kernel(
    x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref,
    y_ref, hout_ref,
    h_scr,
    *,
    chunk: int,
    n_chunks: int,
):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    f32 = jnp.float32
    A = A_ref[...].astype(f32)          # [DB, N]
    D = D_ref[0].astype(f32)            # [DB]

    def step(t, h):
        x_t = x_ref[0, t, :].astype(f32)     # [DB]
        dt_t = dt_ref[0, t, :].astype(f32)   # [DB]
        B_t = B_ref[0, t, :].astype(f32)     # [N]
        C_t = C_ref[0, t, :].astype(f32)     # [N]
        a = jnp.exp(A * dt_t[:, None])       # [DB, N]
        h = a * h + (dt_t * x_t)[:, None] * B_t[None, :]
        y_t = jnp.sum(h * C_t[None, :], axis=1) + D * x_t
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(c == n_chunks - 1)
    def _final():
        hout_ref[0] = h


@functools.partial(jax.jit, static_argnames=("chunk", "block_dim", "interpret"))
def ssm_scan_kernel(
    x, dt, A, B, C, D, h0, *, chunk: int = 256, block_dim: int = 128,
    interpret: bool = False,
):
    Bsz, S, dim = x.shape
    N = A.shape[1]
    Cn = min(chunk, S)
    assert S % Cn == 0
    n_chunks = S // Cn
    DB = min(block_dim, dim)
    assert dim % DB == 0
    nd = dim // DB

    D2 = D.reshape(1, dim)
    grid = (Bsz, nd, n_chunks)
    chan_spec = pl.BlockSpec((1, Cn, DB), lambda b, d, c: (b, c, d))
    stat_spec = pl.BlockSpec((1, Cn, N), lambda b, d, c: (b, c, 0))
    y, hout = pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=Cn, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            chan_spec,                                            # x
            chan_spec,                                            # dt
            pl.BlockSpec((DB, N), lambda b, d, c: (d, 0)),        # A
            stat_spec,                                            # B
            stat_spec,                                            # C
            pl.BlockSpec((1, DB), lambda b, d, c: (0, d)),        # D
            pl.BlockSpec((1, DB, N), lambda b, d, c: (b, d, 0)),  # h0
        ],
        out_specs=[
            chan_spec,
            pl.BlockSpec((1, DB, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, dim), x.dtype),
            jax.ShapeDtypeStruct((Bsz, dim, N), jnp.float32),
        ],
        scratch_shapes=[_vmem((DB, N), jnp.float32)],
        compiler_params=_compiler_params(interpret),
        interpret=interpret,
    )(x, dt, A, B, C, D2, h0)
    return y, hout


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _compiler_params(interpret: bool):
    if interpret:
        return None
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
