"""Pure-jnp oracle for the Mamba-1 selective scan.

Discretised SSM, per channel d and state dim n:

    h_t = exp(A[d,n] * dt_t[d]) * h_{t-1} + dt_t[d] * B_t[n] * x_t[d]
    y_t[d] = sum_n C_t[n] * h_t[d,n] + D[d] * x_t[d]

Shapes: x, dt [B,S,dim]; A [dim,N]; B, C [B,S,N]; D [dim];
state [B,dim,N].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def ssm_scan_ref(x, dt, A, B, C, D, h0=None):
    Bsz, S, dim = x.shape
    N = A.shape[1]
    f32 = jnp.float32
    xf, dtf, Bf, Cf = (t.astype(f32) for t in (x, dt, B, C))
    Af, Df = A.astype(f32), D.astype(f32)
    if h0 is None:
        h0 = jnp.zeros((Bsz, dim, N), f32)

    def step(h, xs):
        x_t, dt_t, B_t, C_t = xs  # [B,dim], [B,dim], [B,N], [B,N]
        a = jnp.exp(Af[None] * dt_t[..., None])           # [B,dim,N]
        b = (dt_t * x_t)[..., None] * B_t[:, None, :]     # [B,dim,N]
        h = a * h + b
        y = jnp.einsum("bdn,bn->bd", h, C_t) + Df[None] * x_t
        return h, y

    xs = tuple(t.transpose(1, 0, 2) for t in (xf, dtf, Bf, Cf))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), h
