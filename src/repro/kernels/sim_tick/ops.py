"""Dispatch wrapper for the fused fleet executor tick (phase 1)."""
from __future__ import annotations

import jax

from .kernel import fleet_tick_kernel
from .ref import fleet_tick_ref


def fleet_tick(
    ctr_status, ctr_end, ctr_oom, cpus, ram, pool,
    pipe_status, arrival, release, tick,
    *, num_pools: int, impl: str = "auto", interpret: bool = False,
):
    """Fused completions + releases + arrival admission + per-pool freed
    resources + next-event registers over a fleet batch.

    Returns ``(oomed, done, new_ctr_status, freed_cpu, freed_ram, fresh,
    rel, nxt_retire, nxt_release)``; see ``ref.fleet_tick_ref`` for
    shapes. ``impl="auto"`` picks the Pallas kernel on TPU and the
    bitwise-equivalent jnp reference elsewhere (CPU/interpret mode).
    """
    use_kernel = impl == "kernel" or (
        impl == "auto" and jax.default_backend() == "tpu"
    )
    if use_kernel:
        return fleet_tick_kernel(
            ctr_status, ctr_end, ctr_oom, cpus, ram, pool,
            pipe_status, arrival, release, tick,
            num_pools=num_pools, interpret=interpret,
        )
    return fleet_tick_ref(
        ctr_status, ctr_end, ctr_oom, cpus, ram, pool,
        pipe_status, arrival, release, tick, num_pools=num_pools,
    )


__all__ = ["fleet_tick"]
