"""Dispatch wrapper for the fleet executor tick."""
from __future__ import annotations

import jax

from .kernel import fleet_tick_kernel
from .ref import fleet_tick_ref


def fleet_tick(status, end, oom, cpus, ram, pool, tick, *, num_pools: int,
               impl: str = "auto", interpret: bool = False):
    use_kernel = impl == "kernel" or (
        impl == "auto" and jax.default_backend() == "tpu"
    )
    if use_kernel:
        return fleet_tick_kernel(
            status, end, oom, cpus, ram, pool, tick, num_pools=num_pools,
            interpret=interpret,
        )
    return fleet_tick_ref(status, end, oom, cpus, ram, pool, tick,
                          num_pools=num_pools)


__all__ = ["fleet_tick"]
