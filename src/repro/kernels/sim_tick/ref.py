"""Pure-jnp oracle for the fused executor tick (phase 1).

The hot inner loop of the lane-major core (EVERY simulation goes
through it — ``run()`` with one lane, ``fleet_run`` with thousands of
policy x seed lanes, possibly device-sharded) starts every event with
the same read of the container + pipeline tables: which containers
complete/OOM, which suspended pipelines release, which arrivals are
admitted, what resources the retirements free per pool, and the
next-event registers over the survivors. This oracle fuses all of that
into one batched pass — the Pallas kernel in ``kernel.py`` is the TPU
twin, tiled [FB, MC]/[FB, MP] in VMEM.

Shapes: F = fleet, MC = containers, MP = pipelines, NP = pools.
ctr_status/ctr_end/ctr_oom/pool [F, MC] i32; cpus/ram [F, MC] f32;
pipe_status/arrival/release [F, MP] i32; tick [F] i32.

The freed-resource reductions use the [F, NP, MC] one-hot layout with
the sum over the trailing MC axis — the exact batched analogue of
``executor.process_completions`` so the fused path stays bitwise equal
to the sequential single-sim path (engine equivalence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INF_TICK = 2**31 - 1

RUNNING = 1        # ContainerStatus.RUNNING
EMPTY = 0          # ContainerStatus.EMPTY
P_EMPTY = 0        # PipeStatus.EMPTY
P_SUSPENDED = 4    # PipeStatus.SUSPENDED


@functools.partial(jax.jit, static_argnames=("num_pools",))
def fleet_tick_ref(
    ctr_status, ctr_end, ctr_oom, cpus, ram, pool,
    pipe_status, arrival, release, tick, *, num_pools: int,
):
    t = tick[:, None]

    # ---- container completions / OOMs -------------------------------------
    running = ctr_status == RUNNING
    oomed = running & (ctr_oom <= t)
    done = running & ~oomed & (ctr_end <= t)
    retired = oomed | done
    new_status = jnp.where(retired, EMPTY, ctr_status)

    # ---- per-pool freed resources ([F, NP, MC], sum over MC) ---------------
    pools = jnp.arange(num_pools, dtype=jnp.int32)
    pool_oh = (pool[:, None, :] == pools[None, :, None]) & retired[:, None, :]
    freed_cpu = jnp.sum(jnp.where(pool_oh, cpus[:, None, :], 0.0), axis=2)
    freed_ram = jnp.sum(jnp.where(pool_oh, ram[:, None, :], 0.0), axis=2)

    # ---- arrival admission / suspension release ----------------------------
    fresh = (pipe_status == P_EMPTY) & (arrival <= t)
    suspended = pipe_status == P_SUSPENDED
    rel = suspended & (release <= t)

    # ---- next-event registers over the survivors ---------------------------
    still_run = running & ~retired
    nxt_retire = jnp.min(
        jnp.where(still_run, jnp.minimum(ctr_end, ctr_oom), INF_TICK), axis=1
    )
    still_susp = suspended & ~rel
    nxt_release = jnp.min(jnp.where(still_susp, release, INF_TICK), axis=1)

    return (
        oomed, done, new_status, freed_cpu, freed_ram,
        fresh, rel, nxt_retire, nxt_release,
    )
