"""Pure-jnp oracle for the fleet executor tick.

The hot inner loop of a *fleet* of Eudoxia simulations (sweep.py runs
thousands of policy x seed simulations in parallel) is the executor's
container-retirement step: for every fleet member, compare every live
container's completion/OOM tick against the member's clock, retire the
firing ones and return the per-pool freed resources.

Shapes: F = fleet, MC = containers, NP = pools.
status/end/oom/pool [F, MC] i32; cpus/ram [F, MC] f32; tick [F] i32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

RUNNING = 1
EMPTY = 0


@functools.partial(jax.jit, static_argnames=("num_pools",))
def fleet_tick_ref(status, end, oom, cpus, ram, pool, tick, *, num_pools: int):
    running = status == RUNNING
    t = tick[:, None]
    oomed = running & (oom <= t)
    done = running & ~oomed & (end <= t)
    retired = oomed | done
    new_status = jnp.where(retired, EMPTY, status)

    freed_c = jnp.where(retired, cpus, 0.0)
    freed_r = jnp.where(retired, ram, 0.0)
    pools = jnp.arange(num_pools, dtype=jnp.int32)
    onehot = pool[:, :, None] == pools[None, None, :]          # [F, MC, NP]
    freed_cpu = jnp.sum(jnp.where(onehot, freed_c[:, :, None], 0.0), axis=1)
    freed_ram = jnp.sum(jnp.where(onehot, freed_r[:, :, None], 0.0), axis=1)
    return oomed, done, new_status, freed_cpu, freed_ram
