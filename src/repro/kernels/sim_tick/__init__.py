from .ops import fleet_tick
from .ref import fleet_tick_ref

__all__ = ["fleet_tick", "fleet_tick_ref"]
