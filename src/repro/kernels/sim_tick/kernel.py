"""Pallas TPU kernel for the fused fleet executor tick (phase 1).

One grid step processes a [FB, MC] tile of the fleet x container table
and the matching [FB, MP] tile of the pipeline table entirely in VMEM:
retire/admission/release masks are VPU compares, the per-pool
freed-resource reduction is NP masked row-sums, and the next-event
registers (min end/oom over surviving containers, min release over
still-suspended pipelines) are masked row-mins. The tile pair is the
unit of HBM traffic — each lane's tables are read exactly once per
event, which is what makes the lane-major core memory-bound-optimal on
TPU (see benchmarks/kernels_bench.py).

Scalar-per-lane outputs (the registers) are emitted as [FB, 8] tiles
(sublane-aligned broadcast, same convention as the [FB, 8] tick input);
the dispatch wrapper takes column 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EMPTY, INF_TICK, P_EMPTY, P_SUSPENDED, RUNNING


def _tick_kernel(
    status_ref, end_ref, oom_ref, cpus_ref, ram_ref, pool_ref,
    pstat_ref, arr_ref, rel_ref, tick_ref,
    oomed_ref, done_ref, nstat_ref, fcpu_ref, fram_ref,
    fresh_ref, relm_ref, nret_ref, nrel_ref,
    *,
    num_pools: int,
):
    status = status_ref[...]
    t = tick_ref[...][:, :1]                      # [FB, 1]
    running = status == RUNNING
    end = end_ref[...]
    oom = oom_ref[...]
    oomed = running & (oom <= t)
    done = running & ~oomed & (end <= t)
    retired = oomed | done

    oomed_ref[...] = oomed.astype(jnp.int32)
    done_ref[...] = done.astype(jnp.int32)
    nstat_ref[...] = jnp.where(retired, EMPTY, status)

    freed_c = jnp.where(retired, cpus_ref[...], 0.0)
    freed_r = jnp.where(retired, ram_ref[...], 0.0)
    pool = pool_ref[...]
    for p in range(num_pools):
        sel = pool == p
        fcpu_ref[:, p] = jnp.sum(jnp.where(sel, freed_c, 0.0), axis=1)
        fram_ref[:, p] = jnp.sum(jnp.where(sel, freed_r, 0.0), axis=1)

    pstat = pstat_ref[...]
    fresh = (pstat == P_EMPTY) & (arr_ref[...] <= t)
    suspended = pstat == P_SUSPENDED
    rel = suspended & (rel_ref[...] <= t)
    fresh_ref[...] = fresh.astype(jnp.int32)
    relm_ref[...] = rel.astype(jnp.int32)

    still_run = running & ~retired
    nret = jnp.min(
        jnp.where(still_run, jnp.minimum(end, oom), INF_TICK),
        axis=1, keepdims=True,
    )
    nret_ref[...] = jnp.broadcast_to(nret, nret_ref.shape)
    still_susp = suspended & ~rel
    nrel = jnp.min(
        jnp.where(still_susp, rel_ref[...], INF_TICK), axis=1, keepdims=True
    )
    nrel_ref[...] = jnp.broadcast_to(nrel, nrel_ref.shape)


@functools.partial(
    jax.jit, static_argnames=("num_pools", "block_fleet", "interpret")
)
def fleet_tick_kernel(
    ctr_status, ctr_end, ctr_oom, cpus, ram, pool,
    pipe_status, arrival, release, tick,
    *, num_pools: int, block_fleet: int = 256, interpret: bool = False,
):
    F, MC = ctr_status.shape
    MP = pipe_status.shape[1]
    FB = min(block_fleet, F)
    # pad the fleet axis to a whole number of tiles; padding lanes carry
    # zeroed tables whose outputs are garbage (e.g. their `fresh` masks
    # are all true: status EMPTY, arrival 0 <= tick 0) and are sliced
    # off below — never reduce across the fleet axis inside the kernel
    pad = (-F) % FB
    if pad:
        def padded(x):
            return jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
            )

        ctr_status, ctr_end, ctr_oom, cpus, ram, pool = map(
            padded, (ctr_status, ctr_end, ctr_oom, cpus, ram, pool)
        )
        pipe_status, arrival, release, tick = map(
            padded, (pipe_status, arrival, release, tick)
        )
    FP = F + pad
    grid = (FP // FB,)
    tick2 = jnp.broadcast_to(tick[:, None], (FP, 8)).astype(jnp.int32)

    ctile = pl.BlockSpec((FB, MC), lambda i: (i, 0))
    ptile = pl.BlockSpec((FB, MP), lambda i: (i, 0))
    pool_tile = pl.BlockSpec((FB, num_pools), lambda i: (i, 0))
    reg_tile = pl.BlockSpec((FB, 8), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_tick_kernel, num_pools=num_pools),
        grid=grid,
        in_specs=[ctile, ctile, ctile, ctile, ctile, ctile,
                  ptile, ptile, ptile, reg_tile],
        out_specs=[ctile, ctile, ctile, pool_tile, pool_tile,
                   ptile, ptile, reg_tile, reg_tile],
        out_shape=[
            jax.ShapeDtypeStruct((FP, MC), jnp.int32),
            jax.ShapeDtypeStruct((FP, MC), jnp.int32),
            jax.ShapeDtypeStruct((FP, MC), ctr_status.dtype),
            jax.ShapeDtypeStruct((FP, num_pools), jnp.float32),
            jax.ShapeDtypeStruct((FP, num_pools), jnp.float32),
            jax.ShapeDtypeStruct((FP, MP), jnp.int32),
            jax.ShapeDtypeStruct((FP, MP), jnp.int32),
            jax.ShapeDtypeStruct((FP, 8), jnp.int32),
            jax.ShapeDtypeStruct((FP, 8), jnp.int32),
        ],
        interpret=interpret,
    )(ctr_status, ctr_end, ctr_oom, cpus, ram, pool,
      pipe_status, arrival, release, tick2)
    oomed, done, nstat, fcpu, fram, fresh, rel, nret, nrel = outs
    return (
        oomed[:F].astype(bool), done[:F].astype(bool), nstat[:F],
        fcpu[:F], fram[:F], fresh[:F].astype(bool), rel[:F].astype(bool),
        nret[:F, 0], nrel[:F, 0],
    )
