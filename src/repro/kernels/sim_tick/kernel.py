"""Pallas TPU kernel for the fleet executor tick.

One grid step processes a [FB, MC] tile of the fleet x container table
entirely in VMEM: the retire masks are VPU compares, the per-pool
freed-resource reduction is NP masked row-sums. The tile is the unit of
HBM traffic — each fleet member's container table is read exactly once
per tick, which is what makes the fleet engine memory-bound-optimal on
TPU (see benchmarks/kernels_bench.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EMPTY, RUNNING


def _tick_kernel(
    status_ref, end_ref, oom_ref, cpus_ref, ram_ref, pool_ref, tick_ref,
    oomed_ref, done_ref, nstat_ref, fcpu_ref, fram_ref,
    *,
    num_pools: int,
):
    status = status_ref[...]
    t = tick_ref[...][:, :1]                      # [FB, 1]
    running = status == RUNNING
    oomed = running & (oom_ref[...] <= t)
    done = running & ~oomed & (end_ref[...] <= t)
    retired = oomed | done

    oomed_ref[...] = oomed.astype(jnp.int32)
    done_ref[...] = done.astype(jnp.int32)
    nstat_ref[...] = jnp.where(retired, EMPTY, status)

    freed_c = jnp.where(retired, cpus_ref[...], 0.0)
    freed_r = jnp.where(retired, ram_ref[...], 0.0)
    pool = pool_ref[...]
    for p in range(num_pools):
        sel = pool == p
        fcpu_ref[:, p] = jnp.sum(jnp.where(sel, freed_c, 0.0), axis=1)
        fram_ref[:, p] = jnp.sum(jnp.where(sel, freed_r, 0.0), axis=1)


@functools.partial(
    jax.jit, static_argnames=("num_pools", "block_fleet", "interpret")
)
def fleet_tick_kernel(
    status, end, oom, cpus, ram, pool, tick, *, num_pools: int,
    block_fleet: int = 256, interpret: bool = False,
):
    F, MC = status.shape
    FB = min(block_fleet, F)
    assert F % FB == 0
    grid = (F // FB,)
    tick2 = jnp.broadcast_to(tick[:, None], (F, 8)).astype(jnp.int32)

    tile = pl.BlockSpec((FB, MC), lambda i: (i, 0))
    pool_tile = pl.BlockSpec((FB, num_pools), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_tick_kernel, num_pools=num_pools),
        grid=grid,
        in_specs=[tile, tile, tile, tile, tile, tile,
                  pl.BlockSpec((FB, 8), lambda i: (i, 0))],
        out_specs=[tile, tile, tile, pool_tile, pool_tile],
        out_shape=[
            jax.ShapeDtypeStruct((F, MC), jnp.int32),
            jax.ShapeDtypeStruct((F, MC), jnp.int32),
            jax.ShapeDtypeStruct((F, MC), status.dtype),
            jax.ShapeDtypeStruct((F, num_pools), jnp.float32),
            jax.ShapeDtypeStruct((F, num_pools), jnp.float32),
        ],
        interpret=interpret,
    )(status, end, oom, cpus, ram, pool, tick2)
    oomed, done, nstat, fcpu, fram = outs
    return oomed.astype(bool), done.astype(bool), nstat, fcpu, fram
