"""Shared impl-dispatch rule for the fused kernel subsystems.

All three subsystems (``sim_tick``, ``sched_select``, ``state_update``)
follow the same convention: a Pallas VMEM kernel, a bitwise-equivalent
jnp reference, and an ``impl="auto"`` wrapper that picks the kernel on
TPU (for shapes the kernel tiles — explicit lane-major batches) and
the reference everywhere else. This module is the one place that rule
lives, so benchmarks can report which implementation a given run
resolved to (BENCH_fleet.json ``phase_breakdown.impl``).
"""
from __future__ import annotations

import jax


def use_pallas(impl: str = "auto", *, batched: bool = True) -> bool:
    """True iff the dispatch rule selects the Pallas kernel."""
    if impl == "kernel":
        return True
    return impl == "auto" and batched and jax.default_backend() == "tpu"


def resolved_impl(impl: str = "auto", *, batched: bool = True) -> str:
    """``"pallas"`` or ``"ref"`` — what ``impl`` resolves to here."""
    return "pallas" if use_pallas(impl, batched=batched) else "ref"


__all__ = ["use_pallas", "resolved_impl"]
