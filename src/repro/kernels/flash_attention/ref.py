"""Pure-jnp oracle for flash attention (GQA + causal + sliding window).

This is simultaneously (a) the numerics oracle the Pallas kernel is
tested against, and (b) the XLA fallback used on non-TPU backends and in
the CPU dry-runs. It is written flash-style — an online-softmax scan
over KV blocks — so its *memory* profile matches the kernel (O(S·block)
rather than O(S^2)) and its HLO FLOPs match full attention, which keeps
the roofline numbers honest.

Shapes: q [B, Sq, H, D]; k, v [B, Skv, KV, D]; H = KV * G (GQA).
``q_offset`` positions the query block inside the KV timeline (prefill
continuation / decode). ``window > 0`` enables sliding-window locality
(gemma3-style local layers): key j is visible to query i iff
i - window < j <= i.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, causal: bool, window: int):
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return ok


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    block_k: int = 1024,
) -> jax.Array:
    """Public entry. For the static train/prefill case (q_offset == 0,
    no kv_len) this routes through a custom-VJP flash implementation
    whose backward *recomputes* score blocks — O(S·block) residuals
    instead of O(S^2) saved softmax panels."""
    if isinstance(q_offset, int) and q_offset == 0 and kv_len is None:
        return _flash_custom(causal, window, min(block_k, k.shape[1]))(q, k, v)
    return _flash_attention_scan(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_len=kv_len, block_k=block_k,
    )


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_k")
)
def _flash_attention_scan(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    block_k: int = 1024,
) -> jax.Array:
    B, Sq, H, D = q.shape
    _, Skv, KV, Dk = k.shape
    assert Dk == D and H % KV == 0
    G = H // KV
    block_k = min(block_k, Skv)
    n_blocks = (Skv + block_k - 1) // block_k
    pad = n_blocks * block_k - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    scale = 1.0 / (D ** 0.5)
    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, KV, G, D)
    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)

    # scan over KV blocks with running (max, denom, acc)
    kb = k.reshape(B, n_blocks, block_k, KV, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, block_k, KV, D).transpose(1, 0, 2, 3, 4)

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, blk_ix = xs
        k_pos = blk_ix * block_k + jnp.arange(block_k)
        s = jnp.einsum(
            "bqkgd,bckd->bqkgc",
            qf,
            kblk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        ok = _block_mask(q_pos, k_pos, causal, window)  # [Sq, C]
        if kv_len is not None:
            ok &= k_pos[None, :] < jnp.asarray(kv_len)[..., None, None]
        elif pad:
            ok &= (k_pos < Skv)[None, :]
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd",
            p,
            vblk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, acc0),
        (kb, vb, jnp.arange(n_blocks)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Custom-VJP flash attention: the backward recomputes score blocks from
# (q, k, v, out, lse) instead of letting autodiff save every softmax
# panel — O(S*block) residual memory, the flash-attention backward.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _flash_custom(causal: bool, window: int, block_k: int):
    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = _forward_with_lse(q, k, v, causal, window, block_k)
        return out

    def fwd(q, k, v):
        out, lse = _forward_with_lse(q, k, v, causal, window, block_k)
        return out, (q, k, v, out, lse)

    def bwd(res, dout):
        q, k, v, out, lse = res
        return _flash_backward(
            q, k, v, out, lse, dout, causal, window, block_k
        )

    attn.defvjp(fwd, bwd)
    return attn


def _forward_with_lse(q, k, v, causal, window, block_k):
    """Online-softmax forward; returns (out, lse [B,Sq,KV,G])."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    block_k = min(block_k, Skv)
    n_blocks = (Skv + block_k - 1) // block_k
    pad = n_blocks * block_k - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / (D ** 0.5)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, D)
    q_pos = jnp.arange(Sq)
    kb = k.reshape(B, n_blocks, block_k, KV, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, block_k, KV, D).transpose(1, 0, 2, 3, 4)

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, blk_ix = xs
        k_pos = blk_ix * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qf, kblk.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        ok = _block_mask(q_pos, k_pos, causal, window) & (k_pos < Skv)[None, :]
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vblk.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  (kb, vb, jnp.arange(n_blocks)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = (acc / jnp.maximum(l[..., None], 1e-30)).reshape(B, Sq, H, D)
    return out.astype(q.dtype), lse


def _flash_backward(q, k, v, out, lse, dout, causal, window, block_k):
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    block_k = min(block_k, Skv)
    n_blocks = (Skv + block_k - 1) // block_k
    pad = n_blocks * block_k - Skv
    kp, vp = k, v
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, D)
    do = dout.astype(jnp.float32).reshape(B, Sq, KV, G, D)
    of = out.astype(jnp.float32).reshape(B, Sq, KV, G, D)
    D_term = jnp.sum(do * of, axis=-1)                     # [B,Sq,KV,G]
    q_pos = jnp.arange(Sq)
    kb = kp.reshape(B, n_blocks, block_k, KV, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, n_blocks, block_k, KV, D).transpose(1, 0, 2, 3, 4)

    def step(dq_acc, xs):
        kblk, vblk, blk_ix = xs
        k_pos = blk_ix * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qf * scale,
                       kblk.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        ok = _block_mask(q_pos, k_pos, causal, window) & (k_pos < Skv)[None, :]
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                     # [B,Sq,KV,G,C]
        dv_blk = jnp.einsum("bqkgc,bqkgd->bckd", p, do,
                            preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqkgd,bckd->bqkgc", do,
                        vblk.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        ds = p * (dp - D_term[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bqkgc,bckd->bqkgd", ds,
                                     kblk.astype(jnp.float32),
                                     preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bqkgc,bqkgd->bckd", ds, qf,
                            preferred_element_type=jnp.float32)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, Sq, KV, G, D), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(
        step, dq0, (kb, vb, jnp.arange(n_blocks))
    )
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(B, n_blocks * block_k, KV, D)
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(B, n_blocks * block_k, KV, D)
    if pad:
        dk, dv = dk[:, :Skv], dv[:, :Skv]
    return (
        dq.reshape(B, Sq, H, D).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


def mha_reference(
    q, k, v, *, causal=True, window=0, q_offset=0, kv_len=None
) -> jax.Array:
    """Naive O(S^2)-memory reference (for small-shape kernel tests only)."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    kx = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vx = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx) / (D ** 0.5)
    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        ok &= (k_pos < kv_len)[None, :]
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vx)
    return out.astype(q.dtype)
