"""Public entry point: dispatches Pallas kernel on TPU, jnp oracle elsewhere."""
from __future__ import annotations

import jax

from .kernel import flash_attention_kernel
from .ref import flash_attention_ref, mha_reference  # noqa: F401


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset=0,
    kv_len=None,
    impl: str = "auto",
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    """q [B,Sq,H,D], k/v [B,Skv,KV,D] -> [B,Sq,H,D]."""
    use_kernel = impl == "kernel" or (
        impl == "auto"
        and jax.default_backend() == "tpu"
        # the kernel path currently assumes q starts at position 0 and a
        # full-length KV (training / full prefill); other cases fall back
        and (isinstance(q_offset, int) and q_offset == 0)
        and kv_len is None
    )
    if use_kernel:
        return flash_attention_kernel(
            q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k
        )
    return flash_attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset, kv_len=kv_len
    )


__all__ = ["flash_attention", "flash_attention_ref", "mha_reference"]
